"""Figure 4 reproduction: multiclass-SVM hyperparameter optimization —
implicit differentiation vs unrolling, for three inner solvers (mirror
descent / proximal gradient / block coordinate descent) and two fixed
points (MD and PG).

Paper claims validated:
  (a) implicit diff is faster than unrolling at equal outer quality (Fig 4);
  (b) the solver and the differentiation fixed point are independently
      choosable — BCD solutions differentiated with MD and PG fixed points
      give the same hypergradient (Fig 4c);
  (c) validation losses match across methods (Fig 14).
"""

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import (BlockCoordinateDescent, MirrorDescent,
                        ProjectedGradient, custom_fixed_point, optimality,
                        projections)

jax.config.update("jax_enable_x64", True)


def make_problem(key, m=80, p=40, k=5, m_val=40):
    """Synthetic multiclass problem à la sklearn.make_classification."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    centers = jax.random.normal(k1, (k, p)) * 2
    yt = jax.random.randint(k2, (m,), 0, k)
    Xt = centers[yt] + jax.random.normal(k3, (m, p))
    yv = jax.random.randint(k4, (m_val,), 0, k)
    Xv = centers[yv] + jax.random.normal(jax.random.fold_in(k4, 1),
                                         (m_val, p))
    Yt = jax.nn.one_hot(yt, k)
    Yv = jax.nn.one_hot(yv, k)
    return Xt, Yt, Xv, Yv


def build(Xt, Yt, Xv, Yv):
    m, k = Yt.shape

    def W(x, theta):               # dual-primal map
        return Xt.T @ (Yt - x) / theta

    def f(x, theta):               # inner objective (dual)
        return 0.5 * theta * jnp.sum(W(x, theta) ** 2) + jnp.vdot(x, Yt)

    proj_e = lambda y, tp: projections.projection_simplex(y)
    proj_kl = lambda y, tp: projections.projection_simplex_kl(y)

    def outer_loss(x_star, theta):
        return 0.5 * jnp.sum((Xv @ W(x_star, theta) - Yv) ** 2)

    return f, W, proj_e, proj_kl, outer_loss


def run(emit_fn=emit):
    key = jax.random.PRNGKey(0)
    Xt, Yt, Xv, Yv = make_problem(key)
    m, k = Yt.shape
    f, W, proj_e, proj_kl, outer_loss = build(Xt, Yt, Xv, Yv)
    init = jnp.full((m, k), 1.0 / k)
    # theta = exp(lam); lam0 sits in the smooth regime where the dual
    # solution is interior (for small theta the dual is vertex-pinned and
    # the hypergradient is identically zero — measured via FD probe)
    lam0 = 6.0
    Lxx = float(jnp.linalg.eigvalsh(Xt @ Xt.T).max())

    T_pg = optimality.projected_gradient_fp(f, proj_e, stepsize=1e-2)
    T_md = optimality.mirror_descent_fp(f, proj_kl, optimality.kl_phi_grad,
                                        stepsize=1e-2)

    # inner solvers (theta-adaptive stepsize: grad_x f is (Lxx/theta)-Lipschitz)
    def solve_pg(init_x, theta):
        pg = ProjectedGradient(f, proj_e, stepsize=theta / Lxx,
                               maxiter=2000, tol=1e-12, implicit_diff=False)
        return pg.run(init_x, (theta, None))[0]

    def solve_md(init_x, theta):
        md = MirrorDescent(f, proj_kl, stepsize=theta / Lxx * 5.0,
                           maxiter=6000, tol=1e-13, implicit_diff=False)
        return md.run(init_x, (theta, None))[0]

    def solve_bcd(init_x, theta):
        bcd = BlockCoordinateDescent(
            f, lambda r, tg, s: projections.projection_simplex(r),
            stepsize=theta / Lxx * m / 4, maxiter=100, tol=1e-12,
            implicit_diff=False)
        return bcd.run(init_x, (theta, None))[0]

    variants = {
        "md_solver_md_fp": (solve_md, T_md),
        "pg_solver_pg_fp": (solve_pg, T_pg),
        "bcd_solver_md_fp": (solve_bcd, T_md),
        "bcd_solver_pg_fp": (solve_bcd, T_pg),
    }

    grads, losses = {}, {}
    for name, (solver, T) in variants.items():
        Tt = lambda x, theta, T=T: T(x, (theta, None))
        wrapped = custom_fixed_point(Tt, solve="normal_cg", tol=1e-8,
                                     maxiter=800)(solver)

        def outer(lam):
            theta = jnp.exp(lam)
            x_star = wrapped(init, theta)
            return outer_loss(x_star, theta)

        g_fn = jax.jit(jax.grad(outer))
        t = time_fn(g_fn, lam0, iters=3)
        grads[name] = float(g_fn(lam0))
        losses[name] = float(outer(lam0))
        emit_fn(f"fig4_implicit_{name}", t,
                f"hypergrad={grads[name]:.5f}")

    # unrolling baseline (PG solver, backprop through iterations) -------
    def unrolled_outer(lam, steps=2000):
        theta = jnp.exp(lam)

        def body(x, _):
            y = x - theta / Lxx * jax.grad(f)(x, theta)
            return projections.projection_simplex(y), None

        x, _ = jax.lax.scan(body, init, None, length=steps)
        return outer_loss(x, theta)

    g_unr = jax.jit(jax.grad(unrolled_outer))
    t_unr = time_fn(g_unr, lam0, iters=3)
    emit_fn("fig4_unrolled_pg", t_unr, f"hypergrad={float(g_unr(lam0)):.5f}")

    # validations --------------------------------------------------------
    ref = grads["pg_solver_pg_fp"]
    agree = all(abs(g - ref) / (abs(ref) + 1e-9) < 0.05
                for g in grads.values())
    unroll_agree = abs(float(g_unr(lam0)) - ref) / (abs(ref) + 1e-9) < 0.05
    emit_fn("fig4_checks", 0.0,
            f"solver_fp_decoupling={agree};unroll_matches={unroll_agree}")
    return grads


if __name__ == "__main__":
    run()
