"""Matrix-free vs auto-materialized dense crossover for operator routing.

``solve(A, b, method="auto")`` dispatches on the ``LinearOperator``'s
structure and size: below ``MAX_DENSE_DIM`` the batch of systems is
materialized once (d probing matvecs — or O(1) for structured operators)
and solved by the fused dense kernels (``pallas_cg`` / ``dense_gmres``);
above it the solve stays matrix-free (``cg`` / ``normal_cg``).  This
benchmark sweeps the instance dimension ``d`` at fixed batch ``B`` and
times both regimes for a matrix-free SPD ``FunctionOperator``, locating
the crossover the auto heuristic is betting on:

  * matrix-free — batched masked-CG through the operator's matvec,
  * dense       — materialize (d probing matvecs) + fused batched-CG.

Small d: materialization is nearly free and the fused kernel wins.  Large
d: the d probing matvecs and the (B, d, d) memory dominate and matrix-free
wins.  Rows report the ratio (``dense/mf``: > 1 means matrix-free won).
"""
import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import linear_solve as ls
from repro.core import operators as ops


def _spd_factors(key, B, d):
    """Per-instance SPD operators given implicitly by factors: A = CᵀC + I,
    applied matrix-free as Cᵀ(Cv) + v (never formed densely)."""
    C = jax.random.normal(key, (B, d, d)) / jnp.sqrt(d)

    def matvec(v):                                    # (B, d) -> (B, d)
        return jnp.einsum("bji,bj->bi", C,
                          jnp.einsum("bij,bj->bi", C, v)) + v

    return matvec


def _bench_crossover(emit_fn, B=16, dims=(8, 32, 128), tol=1e-6):
    key = jax.random.PRNGKey(0)
    rows = {}
    for d in dims:
        matvec = _spd_factors(jax.random.fold_in(key, d), B, d)
        b = jax.random.normal(jax.random.fold_in(key, d + 1), (B, d))
        A = ops.FunctionOperator(matvec, jnp.zeros((B, d)), batch_ndim=1,
                                 positive_definite=True)

        mf = jax.jit(functools.partial(ls.solve, A, method="cg",
                                       tol=tol, maxiter=4 * d))
        dense = jax.jit(functools.partial(ls.solve, A, method="pallas_cg",
                                          tol=tol))
        t_mf = time_fn(lambda: mf(b), iters=3)
        t_dense = time_fn(lambda: dense(b), iters=3)
        ratio = t_dense / t_mf
        auto = ls._resolve_auto(A, b[0])
        emit_fn(f"oproute_mf_B{B}_d{d}", t_mf, f"auto={auto}")
        emit_fn(f"oproute_dense_B{B}_d{d}", t_dense,
                f"dense/mf={ratio:.2f}x")
        rows[d] = ratio
    return rows


def run(emit_fn=emit, smoke: bool = False):
    dims = (8, 32) if smoke else (8, 32, 128, 256)
    _bench_crossover(emit_fn, B=16, dims=dims)


if __name__ == "__main__":
    run()
