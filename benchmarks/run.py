"""Benchmark driver — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  fig3   Jacobian precision (ridge; Thm 1 bound + unroll comparison)
  fig4   multiclass-SVM hyperopt: implicit vs unrolled, 3 solvers x 2 FPs
  fig5   dataset distillation: implicit vs unrolled bilevel
  table2 task-driven dictionary learning vs baselines
  fig6   molecular-dynamics position sensitivity (implicit JVP)
  kernels micro-benchmarks of the Pallas ops (interpret mode on CPU)
  roofline per-(arch x shape) terms from the dry-run artifacts
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    args = ap.parse_args()

    from benchmarks import (dictionary_learning, distillation,
                            jacobian_precision, kernels_micro,
                            molecular_dynamics, roofline_report,
                            svm_hyperopt)
    all_benches = {
        "fig3": jacobian_precision.run,
        "fig4": svm_hyperopt.run,
        "fig5": distillation.run,
        "table2": dictionary_learning.run,
        "fig6": molecular_dynamics.run,
        "kernels": kernels_micro.run,
        "roofline": roofline_report.run,
    }
    names = args.only.split(",") if args.only else list(all_benches)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            all_benches[name]()
        except Exception:
            failed.append(name)
            traceback.print_exc()
            print(f"{name},nan,ERROR")
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
