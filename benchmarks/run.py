"""Benchmark driver — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  fig3    Jacobian precision (ridge; Thm 1 bound + unroll comparison)
  fig4    multiclass-SVM hyperopt: implicit vs unrolled, 3 solvers x 2 FPs
  fig5    dataset distillation: implicit vs unrolled bilevel
  table2  task-driven dictionary learning vs baselines
  fig6    molecular-dynamics position sensitivity (implicit JVP)
  kernels micro-benchmarks of the Pallas ops (interpret mode on CPU)
  batched batched-vs-looped linear-solve engine speedups
  bilevel batched-vs-looped hypergradients through the solver runtime
  fwdrev  JVP-mode vs VJP-mode implicit Jacobians across (p, d) regimes
  oproute matrix-free vs auto-materialized dense operator-routing crossover
  autotune offline tuning sweep: Pallas block_b schedules + solver/mesh
          candidates, recorded into the dispatch TuningCache (runs before
          "sharded" so downstream auto rows report tuned picks)
  sharded sharded vs single-device hypergradients (device-count scaling;
          run under XLA_FLAGS=--xla_force_host_platform_device_count=8
          for the full curve — the CI multi-device lane does)
  service solve-service scheduler: batched-bucket vs per-request dispatch
          at 64 concurrent requests, warm vs cold cache
  approx  approximate backward modes (one_step / neumann_k / jacobian_free)
          error-vs-cost sweep against the exact converged backward
  stochastic stochastic vs full-batch bilevel hypergradients at growing
          dataset size (B=64 quadratic sweep + LM data-scale demo with
          the hypergrad cosine-similarity gate)
  obs     observability overhead gates: disabled-mode telemetry must
          stage a jaxpr-identical program (<= 2% by construction),
          enabled-mode callbacks <= 15% wall-clock on the B=64 batched CG
  roofline per-(arch x shape) terms from the dry-run artifacts

``--smoke`` runs a fast CI subset (kernels + batched + bilevel + fwdrev +
oproute + autotune + sharded + service + approx + stochastic + obs) and
writes the rows to ``BENCH_smoke.json`` (override with ``--out``) for
artifact upload.  The report's ``speedup_summary`` aggregates every
``speedup=..x`` derived tag, excluding interpret-mode Pallas rows (CPU
interpreter timings are correctness-scale, not perf-scale) whose names it
lists under ``skipped``; ``dispatch_summary`` collects the ``dispatch=``
tags documenting every decision the autotuner made (chosen solver, mesh
size, block_b).
"""
import argparse
import sys
import traceback


# "autotune" runs BEFORE "sharded": the sweep populates the in-process
# TuningCache, so every auto-dispatch row downstream reports tuned picks
SMOKE_BENCHES = ["kernels", "batched", "bilevel", "fwdrev", "oproute",
                 "autotune", "sharded", "service", "approx", "stochastic",
                 "obs"]
# accept run(emit, smoke=True)
SMOKE_KWARG_BENCHES = {"batched", "bilevel", "fwdrev", "oproute", "autotune",
                       "sharded", "service", "approx", "stochastic", "obs"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset; writes a BENCH_*.json report")
    ap.add_argument("--out", default="BENCH_smoke.json",
                    help="JSON report path (with --smoke)")
    args = ap.parse_args()

    from benchmarks import (approx_backward, autotune_sweep, batched_solve,
                            bilevel_hypergrad, dictionary_learning,
                            distillation, fwd_vs_rev_hypergrad,
                            jacobian_precision, kernels_micro,
                            molecular_dynamics, obs_overhead,
                            operator_routing, roofline_report, sharded_solve,
                            solve_service, stochastic_bilevel, svm_hyperopt)
    from benchmarks.common import (Collector, emit, summarize_dispatch,
                                   summarize_speedups)
    all_benches = {
        "fig3": jacobian_precision.run,
        "fig4": svm_hyperopt.run,
        "fig5": distillation.run,
        "table2": dictionary_learning.run,
        "fig6": molecular_dynamics.run,
        "kernels": kernels_micro.run,
        "batched": batched_solve.run,
        "bilevel": bilevel_hypergrad.run,
        "fwdrev": fwd_vs_rev_hypergrad.run,
        "oproute": operator_routing.run,
        "autotune": autotune_sweep.run,
        "sharded": sharded_solve.run,
        "service": solve_service.run,
        "approx": approx_backward.run,
        "stochastic": stochastic_bilevel.run,
        "obs": obs_overhead.run,
        "roofline": roofline_report.run,
    }
    if args.only:
        names = args.only.split(",")     # --only wins, also under --smoke
    elif args.smoke:
        names = SMOKE_BENCHES
    else:
        names = list(all_benches)

    emit_fn = Collector() if args.smoke else emit
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            if args.smoke and name in SMOKE_KWARG_BENCHES:
                all_benches[name](emit_fn, smoke=True)
            else:
                all_benches[name](emit_fn)
        except Exception:
            failed.append(name)
            traceback.print_exc()
            print(f"{name},nan,ERROR")
    if args.smoke:
        import jax
        path = emit_fn.write_json(args.out, backend=jax.default_backend(),
                                  failed=failed,
                                  speedup_summary=summarize_speedups(
                                      emit_fn.rows),
                                  dispatch_summary=summarize_dispatch(
                                      emit_fn.rows))
        print(f"wrote {path}", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
