"""Stochastic vs full-batch bilevel hypergradients at growing dataset size.

Part A — strongly-convex quadratic (per-feature-regularized ridge
regression, hypergradient w.r.t. the d log-regularizers): at each dataset
size ``n`` the full-batch baseline runs ``GradientDescent`` over all ``n``
examples with the converged exact backward, while the stochastic path runs
one epoch of minibatch ``SGD`` (B=64) with Polyak tail averaging and takes
the hypergradient at the averaged iterate through a
``SampledJacobianOperator`` (the class-default ``neumann_k`` + Jacobi
treatment).  Both are timed end-to-end (inner solve + backward) and the
hypergradient **cosine similarity** between the two is asserted ≥ 0.9 —
a drifted stochastic hypergradient raises instead of emitting a row.

Part B — the data-scale LM demo, compacted: domain reweighting of a
``SyntheticLMStream`` training set (n ≥ 64·B examples) with a stochastic
``Adam`` inner solver.  Emits the stochastic-vs-full hypergrad cosine at
θ₀ (asserted ≥ 0.9) and the outer validation-loss drop over a short
``solve_bilevel`` run (asserted > 0).

Row format::

    stochastic_quad_full_n<n>  , us , n=..,residual=..
    stochastic_quad_sgd_n<n>_B64 , us , n=..,cos=..,est=..,speedup=..x
    stochastic_lm_datascale_B<B> , us , n=..,cos=..,val_drop=..

Run: PYTHONPATH=src python -m benchmarks.run --only stochastic
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core import GradientDescent, bilevel
from repro.stochastic import SGD, Adam, MinibatchSampler

jax.config.update("jax_enable_x64", True)


def _cosine(g1, g2):
    """Cosine similarity between two gradient pytrees."""
    l1 = jax.tree_util.tree_leaves(g1)
    l2 = jax.tree_util.tree_leaves(g2)
    dot = sum(jnp.vdot(a, b).real for a, b in zip(l1, l2))
    n1 = jnp.sqrt(sum(jnp.vdot(a, a).real for a in l1))
    n2 = jnp.sqrt(sum(jnp.vdot(b, b).real for b in l2))
    return float(dot / jnp.maximum(n1 * n2, 1e-30))


# ---------------------------------------------------------------------------
# Part A: quadratic, growing n
# ---------------------------------------------------------------------------

def _quad_point(emit_fn, n, d=16, B=64, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, kw, ke = jax.random.split(key, 3)
    X = jax.random.normal(kx, (n, d)) / jnp.sqrt(d)
    w_true = jax.random.normal(kw, (d,))
    y = X @ w_true + 0.1 * jax.random.normal(ke, (n,))
    lam = jnp.full((d,), -2.0)          # per-feature log-regularizers

    def fun(w, batch, lam):
        Xb, yb = batch
        r = Xb @ w - yb
        return 0.5 * jnp.mean(r ** 2) + 0.5 * jnp.sum(jnp.exp(lam) * w ** 2)

    def outer_loss(w, lam):
        return 0.5 * jnp.sum((w - w_true) ** 2)

    w0 = jnp.zeros(d)

    # full-batch baseline: converged GD + converged exact backward
    full = GradientDescent(lambda w, lam: fun(w, (X, y), lam),
                           stepsize=0.5, maxiter=400, tol=1e-10,
                           solve="cg")

    def hyper_full(lam):
        return jax.grad(lambda t: outer_loss(full.run(w0, t)[0], t))(lam)

    hyper_full = jax.jit(hyper_full)
    g_full = hyper_full(lam)
    t_full = time_fn(lambda: hyper_full(lam), iters=3)
    x_full, info_full = jax.jit(full.run)(w0, lam)
    emit_fn(f"stochastic_quad_full_n{n}", t_full,
            f"n={n},residual={float(info_full.error):.1e}")

    # stochastic path: one epoch of SGD, Polyak tail, sampled backward
    sampler = MinibatchSampler(data=(X, y), batch_size=B, seed=seed)
    sgd = SGD(fun, sampler=sampler,
              stepsize=lambda k: 0.5 / (1.0 + 0.02 * k),
              epochs=1, averaging="polyak",
              average_from=sampler.num_batches // 2,
              backward_batches=4, backward_iters=10)

    def hyper_sgd(lam):
        return jax.grad(lambda t: outer_loss(sgd.run(w0, t)[0], t))(lam)

    hyper_sgd = jax.jit(hyper_sgd)
    g_sgd = hyper_sgd(lam)
    t_sgd = time_fn(lambda: hyper_sgd(lam), iters=3)
    cos = _cosine(g_sgd, g_full)
    if cos < 0.9:
        raise RuntimeError(
            f"stochastic_quad n={n}: hypergrad cosine {cos:.3f} < 0.9 "
            "against the full-batch baseline")
    ct = jax.grad(outer_loss, argnums=0)(sgd.run(w0, lam)[0], lam)
    est = float(sgd.estimate_hypergrad_error(sgd.run(w0, lam)[0], lam,
                                             cotangent=ct))
    emit_fn(f"stochastic_quad_sgd_n{n}_B{B}", t_sgd,
            f"n={n},cos={cos:.3f},est={est:.2e},"
            f"speedup={t_full / t_sgd:.1f}x")


# ---------------------------------------------------------------------------
# Part B: LM data-scale demo (compact)
# ---------------------------------------------------------------------------

def _lm_datascale(emit_fn, outer_steps=4):
    from repro.data.pipeline import DataConfig, SyntheticLMStream

    vocab, seq_len, B = 32, 8, 16
    steps_per_domain = 16               # 2 × 16 × 32 = 1024 = 64·B examples

    def collect(seed, corrupt):
        cfg = DataConfig(vocab_size=vocab, seq_len=seq_len,
                         global_batch=32, seed=seed)
        stream = SyntheticLMStream(cfg)
        xs, ys = zip(*(stream.batch_at(s) for s in range(steps_per_domain)))
        x, y = np.concatenate(xs, axis=0), np.concatenate(ys, axis=0)
        if corrupt:
            rng = np.random.default_rng(seed + 999)
            y = rng.integers(0, vocab, size=y.shape).astype(np.int32)
        return x, y

    x0, y0 = collect(0, corrupt=False)
    x1, y1 = collect(1, corrupt=True)
    x = np.concatenate([x0, x1], axis=0)
    y = np.concatenate([y0, y1], axis=0)
    dom = np.concatenate([np.zeros(len(x0), np.int32),
                          np.ones(len(x1), np.int32)])
    n = len(x)
    assert n >= 64 * B, (n, B)          # dataset ≥ 64× minibatch

    val_stream = SyntheticLMStream(DataConfig(
        vocab_size=vocab, seq_len=seq_len, global_batch=32, seed=0))
    xv, yv = zip(*(val_stream.batch_at(steps_per_domain + s)
                   for s in range(4)))
    xv = jnp.asarray(np.concatenate(xv, axis=0))
    yv = jnp.asarray(np.concatenate(yv, axis=0))

    def example_ce(W, xb, yb):
        logp = jax.nn.log_softmax(W[xb], axis=-1)
        ce = -jnp.take_along_axis(logp, yb[..., None], axis=-1)[..., 0]
        return jnp.mean(ce, axis=-1)

    def fun(W, batch, lam):
        xb, (yb, db) = batch
        mix = jax.nn.softmax(lam)
        return (jnp.mean(2.0 * mix[db] * example_ce(W, xb, yb))
                + 1e-2 * jnp.sum(W ** 2))

    def outer_loss(W, lam):
        return jnp.mean(example_ce(W, xv, yv))

    sampler = MinibatchSampler(
        data=(jnp.asarray(x), (jnp.asarray(y), jnp.asarray(dom))),
        batch_size=B, seed=0)
    adam = Adam(fun, sampler=sampler, stepsize=5e-2, epochs=2,
                averaging="polyak", average_from=sampler.num_batches,
                backward="exact", solve="cg", precond=None,
                backward_batches=4, linsolve_tol=1e-4, linsolve_maxiter=100)
    W0 = jnp.zeros((vocab, vocab))
    lam0 = jnp.zeros(2)

    # stochastic-vs-full hypergrad cosine at θ₀
    def hyper_sto(lam):
        return jax.grad(lambda t: outer_loss(adam.run(W0, t)[0], t))(lam)

    full = GradientDescent(lambda W, lam: fun(W, sampler.data, lam),
                           stepsize=2.0, maxiter=300, tol=1e-8, solve="cg")

    def hyper_full(lam):
        return jax.grad(lambda t: outer_loss(full.run(W0, t)[0], t))(lam)

    g_sto = jax.jit(hyper_sto)(lam0)
    g_full = jax.jit(hyper_full)(lam0)
    cos = _cosine(g_sto, g_full)
    if cos < 0.9:
        raise RuntimeError(
            f"stochastic_lm_datascale: hypergrad cosine {cos:.3f} < 0.9 "
            "against the full-batch baseline")

    # short outer run: validation loss must decrease
    t = time_fn(lambda: jax.jit(hyper_sto)(lam0), iters=2)
    sol = bilevel.solve_bilevel(outer_loss, adam, lam0, W0,
                                outer_steps=outer_steps, outer_lr=2.0,
                                momentum=0.5)
    val_drop = float(sol.outer_values[0] - sol.outer_values[-1])
    if val_drop <= 0.0:
        raise RuntimeError(
            f"stochastic_lm_datascale: outer val loss did not decrease "
            f"({sol.outer_values[0]:.4f} -> {sol.outer_values[-1]:.4f})")
    emit_fn(f"stochastic_lm_datascale_B{B}", t,
            f"n={n},cos={cos:.3f},val_drop={val_drop:.2e}")


def run(emit_fn, smoke: bool = False):
    """Sweep dataset sizes (Part A) and run the LM data-scale demo (B)."""
    sizes = (1024, 4096) if smoke else (1024, 4096, 16384)
    for n in sizes:
        _quad_point(emit_fn, n)
    _lm_datascale(emit_fn, outer_steps=3 if smoke else 6)


if __name__ == "__main__":
    from benchmarks.common import emit
    run(emit, smoke=True)
