"""Figure 3 reproduction: Jacobian estimate error vs iterate error.

Ridge regression (closed-form x* and ∂x*) on a synthetic diabetes-like
matrix: run gradient descent for t iterations, compute J(x̂, θ) per
Definition 1 via the implicit linear system, and compare against:
  * the Theorem-1 linear bound C·‖x̂ − x*‖, and
  * differentiation of the unrolled iterates (the paper's comparison).

Claim validated (paper Fig. 3): implicit-diff error tracks the bound
linearly; unrolling is much worse at equal iterate error.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn

jax.config.update("jax_enable_x64", True)


def run(emit_fn=emit):
    key = jax.random.PRNGKey(0)
    m, d = 120, 10                      # diabetes-like scale
    X = jax.random.normal(key, (m, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    y = X @ w + 0.1 * jax.random.normal(jax.random.fold_in(key, 2), (m,))
    theta = 1.0

    def f(x, theta):
        return 0.5 * jnp.sum((X @ x - y) ** 2) + \
            0.5 * theta * jnp.sum(x ** 2)

    F = jax.grad(f, argnums=0)
    A = X.T @ X + theta * jnp.eye(d)
    x_star = jnp.linalg.solve(A, X.T @ y)
    J_star = -jnp.linalg.solve(A, jnp.linalg.solve(A, X.T @ y))
    L = float(jnp.linalg.eigvalsh(A).max())

    from repro.core import root_jvp

    def J_implicit(x_hat):
        return root_jvp(F, x_hat, (theta,), (1.0,), tol=1e-14,
                        maxiter=5000)

    def gd(t):
        x = jnp.zeros(d)
        for _ in range(t):
            x = x - (1.0 / L) * F(x, theta)
        return x

    def unrolled_jac(t):
        def solver(theta):
            x = jnp.zeros(d)
            for _ in range(t):
                x = x - (1.0 / L) * F(x, theta)
            return x
        return jax.jacobian(solver)(theta)

    rows = []
    for t in range(2, 120, 8):
        x_hat = gd(t)
        ex = float(jnp.linalg.norm(x_hat - x_star))
        ej_imp = float(jnp.linalg.norm(J_implicit(x_hat) - J_star))
        ej_unr = float(jnp.linalg.norm(unrolled_jac(t) - J_star))
        rows.append((t, ex, ej_imp, ej_unr))

    rows = np.asarray(rows)
    mask = rows[:, 1] > 1e-13
    ratios = rows[mask, 2] / rows[mask, 1]
    C_emp = float(ratios.max())
    # paper claim 1: linear scaling (bounded ratio)
    linear_ok = ratios.max() < 50 * max(ratios.min(), 1e-12)
    # paper claim 2: at matched iterate error, implicit beats unrolling in
    # the mid-convergence regime
    mid = rows[(rows[:, 1] < 1e-2) & (rows[:, 1] > 1e-10)]
    implicit_wins = bool(np.all(mid[:, 2] <= mid[:, 3] + 1e-12)) \
        if len(mid) else True
    t_imp = time_fn(lambda: J_implicit(gd(50)))
    emit_fn("fig3_jacobian_precision", t_imp,
            f"C_emp={C_emp:.3f};linear={linear_ok};"
            f"implicit_beats_unroll={implicit_wins}")
    return rows


if __name__ == "__main__":
    run()
