"""Error-vs-cost sweep of the approximate backward modes.

The workload: B hypergradients through one implicit solve of ``A x = θ``
with ``A = I − ρS`` SPD (``‖S‖₂ = 1``, so the Neumann contraction factor
is exactly ρ and the condition number grows as ``(1+ρ)/(1−ρ)``).  The
exact baseline runs the converged batched CG backward; each approximate
mode replaces it with its fixed O(k)-matvec polynomial.  Every timed
configuration is first VERIFIED against the closed-form polynomial in
BOTH autodiff directions (``jax.grad`` cotangent solve and ``jax.jvp``
tangent solve) — a drifted mode raises instead of emitting a row.

Row format::

    approx_backward_<mode>[_k<k>]_rho<rho>_B<B> , us , rho=..,est=..,
        matvecs=..,speedup=..x,dirs=vjp+jvp

``est`` is the mode's ``hypergrad_error_estimate`` (relative residual of
the cotangent system, the honesty contract of the approximate modes);
``speedup`` is exact-backward wall clock over this mode's wall clock for
the identical batched hypergradient.

Run: PYTHONPATH=src python -m benchmarks.run --only approx
"""
import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core import diff_api
from repro.core.implicit_diff import custom_root

jax.config.update("jax_enable_x64", True)


def _spd_system(key, d, rho):
    """``A = I − ρS`` with S symmetric, ``‖S‖₂ = 1`` (eigs in [1−ρ, 1+ρ])."""
    S = jax.random.normal(key, (d, d))
    S = (S + S.T) / 2.0
    S = S / jnp.linalg.norm(S, 2)
    return jnp.eye(d) - rho * S


def _poly_reference(mode, k, A, v):
    """Closed-form value of the mode's polynomial apply on vector ``v``."""
    if mode == "exact":
        return jnp.linalg.solve(A, v)
    if mode == "jacobian_free":
        return v
    if mode == "one_step":
        return 2.0 * v - A @ v
    u = v
    for _ in range(k):                   # neumann_k: Σ_{j≤k} (I−A)^j v
        u = u + (v - A @ u)
    return u


def _bench_point(emit_fn, rho, ks, B=64, d=128, seed=0):
    key = jax.random.PRNGKey(seed)
    A = _spd_system(key, d, rho)
    Ainv = jnp.linalg.inv(A)
    c = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    thetas = jax.random.normal(jax.random.fold_in(key, 2), (B, d))
    tangent = jax.random.normal(jax.random.fold_in(key, 3), (d,))

    def F(x, theta):
        return theta - A @ x

    modes = [("exact", 0), ("one_step", 1), ("jacobian_free", 0)]
    modes += [("neumann_k", k) for k in ks]

    times = {}
    for mode, k in modes:
        solver = custom_root(F, solve="cg", tol=1e-8, maxiter=4 * d,
                             backward=mode, backward_iters=max(k, 1))(
            lambda init, t: Ainv @ t)

        def loss(t):
            return c @ solver(jnp.zeros(d), t)

        # -- verify BOTH directions against the closed-form polynomial ----
        g = jax.grad(loss)(thetas[0])
        g_ref = _poly_reference(mode, k, A, c)      # Aᵀ = A (symmetric)
        err_vjp = float(jnp.max(jnp.abs(g - g_ref)))
        _, dx = jax.jvp(lambda t: solver(jnp.zeros(d), t),
                        (thetas[0],), (tangent,))
        dx_ref = _poly_reference(mode, k, A, tangent)
        err_jvp = float(jnp.max(jnp.abs(dx - dx_ref)))
        tol = 1e-5 if mode == "exact" else 1e-9
        if err_vjp > tol or err_jvp > tol:
            raise RuntimeError(
                f"approx_backward {mode} k={k} rho={rho}: drifted from the "
                f"closed-form polynomial (vjp {err_vjp:.2e}, "
                f"jvp {err_jvp:.2e})")

        hyper = jax.jit(jax.vmap(jax.grad(loss)))
        t = time_fn(lambda: hyper(thetas), iters=5)
        times[(mode, k)] = t

        if mode == "exact":
            derived = f"rho={rho},dirs=vjp+jvp"
            name = f"approx_backward_exact_rho{rho}_B{B}"
        else:
            _, info = diff_api.root_vjp(
                F, Ainv @ thetas[0], (thetas[0],), c, backward=mode,
                backward_iters=max(k, 1), error_estimate=True,
                return_info=True)
            est = float(info.hypergrad_error_estimate)
            speed = times[("exact", 0)] / t
            nmv = int(info.iterations)
            derived = (f"rho={rho},est={est:.2e},matvecs={nmv},"
                       f"speedup={speed:.1f}x,dirs=vjp+jvp")
            suffix = f"_k{k}" if mode == "neumann_k" else ""
            name = f"approx_backward_{mode}{suffix}_rho{rho}_B{B}"
        emit_fn(name, t, derived)
    return times


def run(emit_fn, smoke: bool = False):
    """Sweep modes x Neumann depth x conditioning; emit error-vs-cost rows."""
    if smoke:
        sweep, ks, B = (0.09, 0.9), (2, 8), 64
    else:
        sweep, ks, B = (0.09, 0.5, 0.9), (2, 4, 8), 64
    for rho in sweep:
        _bench_point(emit_fn, rho, ks, B=B)


if __name__ == "__main__":
    from benchmarks.common import emit
    run(emit, smoke=True)
