"""§4.2 / Figure 5 reproduction: dataset distillation as bilevel optimization.

Inner: multinomial logistic regression trained on k distilled prototypes θ;
outer: loss of x*(θ) on the real training set.  Implicit hypergradient via
the stationarity condition (ridge-regularized inner, ε = 1e-3, as in the
paper) vs differentiation of unrolled inner GD.

Claims validated: (a) implicit path is ≥2× faster per outer step than
unrolling-to-convergence (paper reports 4× end-to-end on MNIST-scale);
(b) outer loss decreases (distillation works); (c) both give the same
hypergradient direction.
"""

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import bilevel

jax.config.update("jax_enable_x64", True)


def make_mnist_like(key, m=256, p=64, k=10):
    """Synthetic class-structured data (MNIST is offline-unavailable)."""
    k1, k2, k3 = jax.random.split(key, 3)
    protos = jax.random.normal(k1, (k, p))
    y = jax.random.randint(k2, (m,), 0, k)
    X = protos[y] + 0.5 * jax.random.normal(k3, (m, p))
    return X, y, protos


def run(emit_fn=emit):
    key = jax.random.PRNGKey(0)
    p, k = 64, 10
    Xtr, ytr, _ = make_mnist_like(key, p=p, k=k)
    eps = 1e-3
    distilled_labels = jnp.arange(k)

    def inner_obj(x, theta):
        # x: (p, k) classifier; theta: (k, p) distilled images
        scores = theta @ x
        loss = -jnp.mean(jax.nn.log_softmax(scores)[
            jnp.arange(k), distilled_labels])
        return loss + eps * jnp.sum(x ** 2)

    def inner_solver(init_x, theta):
        # Newton-ish: LBFGS on the strongly-convex inner problem
        from repro.core import LBFGS
        solver = LBFGS(inner_obj, maxiter=150, stepsize=0.5, tol=1e-10,
                       implicit_diff=False)
        return solver.run(jnp.zeros((p, k)), theta)[0]

    def outer_loss(x_star, theta):
        scores = Xtr @ x_star
        return -jnp.mean(jax.nn.log_softmax(scores)[jnp.arange(len(ytr)),
                                                    ytr])

    theta0 = 0.01 * jax.random.normal(jax.random.fold_in(key, 3), (k, p))

    # implicit hypergradient ------------------------------------------------
    implicit = bilevel.make_implicit_inner(
        inner_solver, inner_objective=inner_obj, solve="cg", tol=1e-8)

    def outer_implicit(theta):
        return outer_loss(implicit(None, theta), theta)

    g_imp = jax.jit(jax.grad(outer_implicit))
    t_imp = time_fn(g_imp, theta0, iters=3)

    # unrolled baseline -------------------------------------------------
    def outer_unrolled(theta, steps=400):
        def body(x, _):
            return x - 0.5 * jax.grad(inner_obj)(x, theta), None
        x, _ = jax.lax.scan(body, jnp.zeros((p, k)), None, length=steps)
        return outer_loss(x, theta)

    g_unr = jax.jit(jax.grad(outer_unrolled))
    t_unr = time_fn(g_unr, theta0, iters=3)

    cos = float(jnp.vdot(g_imp(theta0), g_unr(theta0)) /
                (jnp.linalg.norm(g_imp(theta0))
                 * jnp.linalg.norm(g_unr(theta0))))

    # short outer optimization: distillation reduces the outer loss
    sol = bilevel.solve_bilevel(
        outer_loss, inner_solver, theta0, None,
        inner_objective=inner_obj, outer_steps=20, outer_lr=1.0,
        momentum=0.9, solve="cg")
    improved = bool(sol.outer_values[-1] < sol.outer_values[0] * 0.8)

    emit_fn("fig5_distill_implicit_step", t_imp,
            f"speedup_vs_unroll={t_unr / t_imp:.2f}x;grad_cos={cos:.4f};"
            f"outer_improves={improved}")
    emit_fn("fig5_distill_unrolled_step", t_unr, "")
    return sol


if __name__ == "__main__":
    run()
