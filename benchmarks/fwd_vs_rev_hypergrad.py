"""Forward-mode vs reverse-mode implicit hypergradients across regimes.

The mode-polymorphic ``implicit_diff`` wrapper makes the Jacobian-shape
trade-off (Margossian & Betancourt; the paper's MD-sensitivity workload) a
one-flag choice on ONE wrapped solver: ``jax.jacfwd`` costs one batched
tangent solve per *parameter* basis vector, ``jax.jacrev`` one batched
cotangent solve per *output* basis vector.  This benchmark times both
through the same wrapper on a generalized ridge problem

    F(x, θ) = Xᵀ(Xx − y) + (Pθ) ⊙ x,        x* ∈ R^d,  θ ∈ R^p,

sweeping (n_params=p, n_outputs=d) from JVP-dominant (p ≪ d) to
VJP-dominant (p ≫ d).  Both directions batch their basis solves into ONE
masked registry solve, so the measured difference is the p-vs-d system
count, not dispatch overhead.

Run: PYTHONPATH=src python -m benchmarks.run --only fwdrev
"""
import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core import ImplicitDiffSpec, implicit_diff

jax.config.update("jax_enable_x64", True)


def _make_wrapped_solver(key, p, d, m):
    kx, ky, kp = jax.random.split(key, 3)
    X = jax.random.normal(kx, (m, d))
    y = jax.random.normal(ky, (m,))
    # positive mixing: each of the p hyperparameters regularizes a soft
    # group of coordinates, so d outputs depend on p parameters densely
    P = jax.random.uniform(kp, (d, p), minval=0.1, maxval=1.0)

    def F(x, theta):
        return X.T @ (X @ x - y) + (P @ theta) * x

    spec = ImplicitDiffSpec(optimality_fun=F, solve="cg", tol=1e-10)

    @implicit_diff(spec)
    def solver(init, theta):
        del init
        return jnp.linalg.solve(X.T @ X + jnp.diag(P @ theta), X.T @ y)

    return solver


def _bench_regime(emit_fn, key, p, d):
    m = d + 16
    solver = _make_wrapped_solver(key, p, d, m)
    theta0 = jnp.ones(p)

    jac_fwd = jax.jit(jax.jacfwd(solver, argnums=1))
    jac_rev = jax.jit(jax.jacrev(solver, argnums=1))

    # correctness gate before timing: the two modes are the same Jacobian
    Jf = jac_fwd(None, theta0)
    Jr = jac_rev(None, theta0)
    err = float(jnp.max(jnp.abs(Jf - Jr)))
    assert err < 1e-6, f"jacfwd drifted from jacrev at (p={p}, d={d}): {err}"

    t_fwd = time_fn(lambda: jac_fwd(None, theta0), iters=5)
    t_rev = time_fn(lambda: jac_rev(None, theta0), iters=5)
    regime = ("jvp-dominant" if p < d else
              "vjp-dominant" if p > d else "square")
    emit_fn(f"fwdrev_jacfwd_p{p}_d{d}", t_fwd, regime)
    emit_fn(f"fwdrev_jacrev_p{p}_d{d}", t_rev,
            f"rev/fwd={t_rev / max(t_fwd, 1e-12):.2f}x")


def run(emit_fn, smoke: bool = False):
    key = jax.random.PRNGKey(0)
    regimes = ([(4, 64), (64, 4)] if smoke
               else [(4, 128), (32, 32), (128, 4)])
    for i, (p, d) in enumerate(regimes):
        _bench_regime(emit_fn, jax.random.fold_in(key, i), p, d)


if __name__ == "__main__":
    from benchmarks.common import emit
    run(emit)
