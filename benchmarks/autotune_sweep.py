"""Offline autotuning sweep: populate the dispatch TuningCache by timing.

Two candidate families, both recorded through ``analysis.autotune`` into
the process-default ``TuningCache`` (and optionally persisted with
``--save``, for shipping a pre-tuned cache via ``REPRO_AUTOTUNE_CACHE``):

  * Pallas batched-CG ``(block_b, lanes-padded d')`` schedules per
    ``(B, d)`` point — after this sweep, ``batched_cg(block_b="auto")``
    (and therefore ``pallas_cg`` routes, the solve service's buckets and
    ``IterativeSolver`` backward solves) resolves the measured-fastest
    tile.  Off-TPU the sweep times the kernel's interpret-mode grid,
    where ``block_b`` controls the emulated program count — the same
    schedule trade-off, observable without hardware — so rows are tagged
    ``interpret-mode`` (excluded from speedup statistics).  The
    ``tuned_vs_block8`` tag compares the legacy hardcoded schedule
    against the tuned pick from the SAME measured medians (≥ 1.0x by
    construction: the legacy schedule is itself a candidate).
  * solver/mesh candidates at the canonical hypergradient regime
    (B=64, d=16): the single-device dense route vs ``sharded_cg`` at
    every admissible mesh extent.  ``auto_mesh_size`` then has measured
    entries to rank, and the ``dispatch=mesh=<n>`` row documents what it
    picked (the CI gate asserts the pick never loses to single-device).

Run inside ``benchmarks/run.py --smoke`` (BEFORE the sharded benchmark,
so auto-dispatch rows downstream see a tuned cache) or standalone::

    python -m benchmarks.autotune_sweep --smoke --save tuned.json
"""
import argparse

from benchmarks.common import emit

# (B, d) points for the block-schedule sweep — small on purpose: the
# interpret-mode grid costs milliseconds per program, and schedule
# *ranking* only needs the relative tile trade-off.  (64, 16) is the
# canonical hypergradient regime, where taller tiles beat the legacy
# block_b=8 by ~3x in the emulated grid.
BLOCKB_POINTS_SMOKE = [(16, 8), (64, 16)]
BLOCKB_POINTS_FULL = [(8, 8), (16, 8), (32, 8), (64, 8), (16, 32),
                      (32, 32), (64, 16), (16, 64)]

# the mesh-crossover regime BENCH_smoke.json showed oversharding at
MESH_REGIME = (64, 16)


def run(emit_fn=emit, smoke: bool = False, save: str = None):
    import jax

    from repro.analysis import autotune

    cache = autotune.default_cache()
    backend = autotune.current_backend()

    # --- Pallas batched-CG block-schedule sweep ---------------------------
    interpret = backend != "tpu"
    for B, d in (BLOCKB_POINTS_SMOKE if smoke else BLOCKB_POINTS_FULL):
        recs = autotune.measure_block_schedule(
            B, d, interpret=interpret, cache=cache,
            iters=3 if smoke else 5)
        legacy = autotune.default_block_b(B, d)
        tuned = autotune.choose_block_b(B, d, cache=cache)
        ratio = recs[legacy].seconds / recs[tuned].seconds
        emit_fn(f"autotune_blockb_B{B}_d{d}", recs[tuned].seconds,
                f"interpret-mode,tuned_vs_block8={ratio:.1f}x,"
                f"dispatch=block_b={tuned}")

    # --- solver/mesh candidates at the crossover regime -------------------
    B, d = MESH_REGIME
    single = autotune.single_device_solver(True, d)
    rec_si = autotune.measure_solver(single, B, d, cache=cache,
                                     iters=2 if smoke else 5)
    emit_fn(f"autotune_single_B{B}_d{d}", rec_si.seconds,
            f"solver={single},baseline")
    best = None
    for m in autotune.mesh_candidates(B):
        rec = autotune.measure_solver("sharded_cg", B, d, mesh_size=m,
                                      cache=cache, iters=2 if smoke else 5)
        emit_fn(f"autotune_mesh{m}_B{B}_d{d}", rec.seconds,
                f"sharded/single={rec.seconds / rec_si.seconds:.2f}x")
        if best is None or rec.seconds < best[1]:
            best = (m, rec.seconds)
    n_auto = autotune.auto_mesh_size(B, d, cache=cache)
    t_auto = cache.get(autotune.TuningKey(
        backend, "sharded_cg", B, d, "float32", n_auto)).seconds
    emit_fn(f"autotune_mesh_auto_B{B}_d{d}", t_auto,
            f"sharded/single={t_auto / rec_si.seconds:.2f}x,"
            f"dispatch=mesh={n_auto}+solver=sharded_cg,auto-selected")
    assert n_auto == best[0], \
        f"auto_mesh_size picked {n_auto}, measured best is {best[0]}"

    if save:
        path = cache.save(save)
        print(f"saved tuning cache ({len(cache)} entries) to {path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="fast sweep (fewer points, fewer timing reps)")
    ap.add_argument("--save", default=None,
                    help="persist the populated TuningCache to this path")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, save=args.save)


if __name__ == "__main__":
    main()
