"""Observability overhead gate: telemetry must be free when off.

Measures the B=64 batched-CG engine solve three ways, all through the
same ``linear_solve.solve`` entry point so the only variable is the
telemetry seam:

  * raw      — the registry entry temporarily stripped of its telemetry
               wrapper (what the engine staged before the observability
               subsystem existed), traced while the seam is removed;
  * obs_off  — the stock routed solve with observability disabled (the
               default production configuration);
  * obs_on   — a fresh trace under ``observe(enabled=True)``: the program
               carries the ``solve_start``/``solve`` host callbacks.

The disabled-mode gate (<= 2%) is enforced *structurally*: ``jit_event``
returns before staging anything when the switch is off, so ``obs_off``
must trace to a jaxpr byte-identical to ``raw`` — identical programs
execute identically, which is a 0% guarantee, strictly stronger than any
timing bound.  The wall-clock comparison is still measured and reported,
and becomes the enforcement path only if the structural check ever finds
the programs diverging (shared CI boxes show a self-vs-self timing noise
floor above 2% at this ~400us/call scale, so a bare timing gate between
identical programs would flake).  The enabled-mode gate (<= 15%) is
wall-clock: callbacks are real work — a single staged
``jax.debug.callback`` costs hundreds of microseconds of host-sync on
CPU, which is why the telemetry seam stages the ``solve_start``/``solve``
pair as ONE callback and why the gate runs at d=192, where one callback
amortizes against a realistically-sized solve.  Measured as the median
of per-call times interleaved across variants.  A gate failure raises,
which ``run.py --smoke`` records in the report's ``failed`` list.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro import observability as obs
from repro.core import linear_solve as ls

DISABLED_MAX_OVERHEAD = 0.02
ENABLED_MAX_OVERHEAD = 0.15


def _spd_batch(key, B, d, cond=20.0):
    def one(k):
        A = jax.random.normal(k, (d, d))
        A = A @ A.T
        return A + (jnp.trace(A) / d / cond) * jnp.eye(d)
    return jax.vmap(one)(jax.random.split(key, B))


def _interleaved_medians(fns, samples):
    """Median per-call time per fn, interleaved call by call.

    Every round times one call of each variant, rotating the visit
    order, so scheduler noise and machine drift land on all variants
    equally.
    """
    for fn in fns:                       # warm every variant first
        jax.block_until_ready(fn())
    ts = [[] for _ in fns]
    for r in range(samples):
        for i in range(len(fns)):
            j = (i + r) % len(fns)
            t0 = time.perf_counter()
            jax.block_until_ready(fns[j]())
            ts[j].append(time.perf_counter() - t0)
    return [float(np.median(t)) for t in ts]


def run(emit_fn=emit, smoke: bool = False, B: int = 64, d: int = 192):
    assert not obs.observing(), \
        "obs_overhead must start from the disabled default"
    key = jax.random.PRNGKey(0)
    As = _spd_batch(key, B, d)
    bs = jax.random.normal(jax.random.fold_in(key, 1), (B, d))
    mv = lambda v: jnp.einsum("bij,bj->bi", As, v)
    tol, maxiter = 1e-8, 4 * d

    # three IDENTICAL bodies as three DISTINCT function objects: jax's
    # trace cache keys on callable identity, so reusing one function
    # across registry/observability states would silently serve the
    # first trace to every later variant (uninstrumented "on", vacuous
    # jaxpr comparison)
    def routed_raw(b):
        return ls.solve(mv, b, method="cg", batch_axes=0, tol=tol,
                        maxiter=maxiter)

    def routed_off(b):
        return ls.solve(mv, b, method="cg", batch_axes=0, tol=tol,
                        maxiter=maxiter)

    def routed_on(b):
        return ls.solve(mv, b, method="cg", batch_axes=0, tol=tol,
                        maxiter=maxiter)

    # raw: the identical routed path with the telemetry seam stripped
    # from the registry entry — traced eagerly while the strip is live
    spec = ls._REGISTRY["cg"]
    unwrapped = getattr(spec.fn, "__wrapped__", spec.fn)
    ls._REGISTRY["cg"] = dataclasses.replace(spec, fn=unwrapped)
    try:
        raw = jax.jit(routed_raw)
        jax.block_until_ready(raw(bs))
        jaxpr_raw = str(jax.make_jaxpr(routed_raw)(bs))
    finally:
        ls._REGISTRY["cg"] = spec

    # trace NOW, while disabled — jit is lazy and the timing loop below
    # runs inside the observe() block
    off = jax.jit(routed_off)
    jax.block_until_ready(off(bs))
    jaxpr_off = str(jax.make_jaxpr(routed_off)(bs))
    assert "callback" not in jaxpr_off, \
        "observability staged a callback while disabled"
    identical = jaxpr_off == jaxpr_raw

    seen = []
    unsubscribe = obs.subscribe(seen.append)
    with obs.observe(enabled=True):
        # fresh trace of a fresh callable: the switch is read at trace time
        on = jax.jit(routed_on)
        t_raw, t_off, t_on = _interleaved_medians(
            [lambda: raw(bs), lambda: off(bs), lambda: on(bs)],
            samples=40 if smoke else 100)
    unsubscribe()
    assert seen, "the enabled variant fired no events — it must have " \
                 "reused an uninstrumented cached trace"

    ov_off = t_off / t_raw - 1.0
    ov_on = t_on / t_raw - 1.0
    emit_fn(f"obs_raw_B{B}_d{d}", t_raw, "")
    emit_fn(f"obs_disabled_B{B}_d{d}", t_off,
            f"overhead={ov_off * 100:.1f}%+"
            f"jaxpr={'identical' if identical else 'DIVERGED'}")
    emit_fn(f"obs_enabled_B{B}_d{d}", t_on, f"overhead={ov_on * 100:.1f}%")

    # disabled gate: identical jaxprs mean identical programs — zero
    # execution overhead by construction; the timing bound only takes
    # over if the structural guarantee is ever lost
    if not identical and ov_off > DISABLED_MAX_OVERHEAD:
        raise RuntimeError(
            f"disabled-mode observability staged a different program AND "
            f"costs {ov_off * 100:.1f}% (> "
            f"{DISABLED_MAX_OVERHEAD * 100:.0f}% gate)")
    if ov_on > ENABLED_MAX_OVERHEAD:
        raise RuntimeError(
            f"enabled-mode observability overhead {ov_on * 100:.1f}% "
            f"exceeds the {ENABLED_MAX_OVERHEAD * 100:.0f}% gate")
    return ov_off, ov_on


if __name__ == "__main__":
    run()
