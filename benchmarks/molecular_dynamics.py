"""§4.4 / Figure 6 reproduction: sensitivity analysis of molecular dynamics.

Soft-sphere packing in a 2-D periodic box (JAX-MD's setup re-implemented in
pure JAX): half the particles have diameter 1, half diameter θ.  Energy is
minimized with FIRE (the discontinuous domain-specific optimizer [15]);
position sensitivities ∂x*(θ) are computed by forward-mode implicit
differentiation of the force root F(x, θ) = −∇E = 0 with BiCGSTAB.

Claims validated: (a) the implicit JVP solves the sensitivity system to a
small residual at the FIRE minimum; (b) differentiating through the unrolled
FIRE trajectory is orders-of-magnitude less stable across random seeds
(paper: "typically does not even converge").
"""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import root_jvp

jax.config.update("jax_enable_x64", True)

K_PARTICLES = 32
BOX = 4.0


def pair_energy(x, theta):
    """Soft-sphere potential with periodic boundary, x in [0,1]^{k×2}."""
    R = x * BOX
    diff = R[:, None, :] - R[None, :, :]
    diff = diff - BOX * jnp.round(diff / BOX)          # periodic
    dist = jnp.sqrt(jnp.sum(diff ** 2, -1) + 1e-12)
    k = x.shape[0]
    diam = jnp.where(jnp.arange(k) < k // 2, 1.0, theta)
    sigma = 0.5 * (diam[:, None] + diam[None, :])
    overlap = jnp.maximum(1.0 - dist / sigma, 0.0)
    e = (overlap ** 2.5) * (2.0 / 5.0)
    mask = 1.0 - jnp.eye(k)
    return 0.5 * jnp.sum(e * mask)


def fire_minimize(x0, theta, steps=400, dt0=0.02):
    """FIRE descent [15] — the discontinuous optimizer from the paper."""
    def force(x):
        return -jax.grad(pair_energy)(x, theta)

    def body(carry, _):
        x, v, dt, alpha = carry
        f = force(x)
        power = jnp.vdot(f, v)
        v = (1 - alpha) * v + alpha * f * (jnp.linalg.norm(v) /
                                           (jnp.linalg.norm(f) + 1e-12))
        uphill = power < 0
        v = jnp.where(uphill, jnp.zeros_like(v), v)
        dt = jnp.where(uphill, dt * 0.5, jnp.minimum(dt * 1.1, 10 * dt0))
        alpha = jnp.where(uphill, 0.1, alpha * 0.99)
        v = v + dt * f
        x = x + dt * v / BOX
        return (x, v, dt, alpha), None

    (x, _, _, _), _ = jax.lax.scan(
        body, (x0, jnp.zeros_like(x0), dt0, 0.1), None, length=steps)
    return x


def run(emit_fn=emit):
    theta = 0.6

    def F(x, theta):           # normalized forces — the optimality root
        return -jax.grad(lambda x: pair_energy(x, theta))(x)

    def sensitivity(seed):
        x0 = jax.random.uniform(jax.random.PRNGKey(seed),
                                (K_PARTICLES, 2))
        x_star = fire_minimize(x0, theta)
        dx = root_jvp(F, x_star, (theta,), (1.0,), solve="bicgstab",
                      tol=1e-8, maxiter=2000, ridge=1e-8)
        return x_star, dx

    x_star, dx = sensitivity(0)
    t_jvp = time_fn(lambda: sensitivity(0)[1], iters=2)

    # check: dx solves the implicit system A dx = B to small residual
    _, Adx = jax.jvp(lambda x: F(x, theta), (x_star,), (dx,))
    _, B = jax.jvp(lambda t: F(x_star, t), (theta,), (1.0,))
    resid = float(jnp.linalg.norm(-Adx - B) /
                  (jnp.linalg.norm(B) + 1e-12))

    # unrolled-FIRE comparison over seeds: L1 sensitivity norms
    def unrolled_sens(seed):
        x0 = jax.random.uniform(jax.random.PRNGKey(seed),
                                (K_PARTICLES, 2))
        g = jax.jacfwd(lambda t: fire_minimize(x0, t))(theta)
        return float(jnp.sum(jnp.abs(g)))

    imp_norms, unr_norms = [], []
    for seed in range(6):
        xs, dxs = sensitivity(seed)
        imp_norms.append(float(jnp.sum(jnp.abs(dxs))))
        unr_norms.append(unrolled_sens(seed))
    imp_spread = np.max(imp_norms) / max(np.median(imp_norms), 1e-12)
    unr_finite = [v for v in unr_norms if np.isfinite(v)]
    n_nan = len(unr_norms) - len(unr_finite)
    unr_spread = (np.max(unr_finite) / max(np.median(unr_finite), 1e-12)
                  if unr_finite else float("inf"))
    # paper: unrolled FIRE "typically does not even converge" — NaN/inf
    # sensitivities or an orders-of-magnitude spread both confirm it
    unstable = (n_nan > 0) or (not np.isfinite(unr_spread)) \
        or (unr_spread > 5 * imp_spread)
    emit_fn("fig6_md_sensitivity_jvp", t_jvp,
            f"residual={resid:.2e};imp_spread={imp_spread:.1f};"
            f"unroll_spread={unr_spread:.1f};unroll_nan_seeds={n_nan}/6;"
            f"unroll_unstable={unstable}")
    return dx


if __name__ == "__main__":
    run()
