"""Table 2 reproduction: task-driven dictionary learning vs baselines.

Binary classification from high-dimensional features (synthetic survival-
like cohort standing in for the TCGA data, which is offline-unavailable):
  * L2-regularized logistic regression on raw features,
  * L1-regularized logistic regression,
  * unsupervised DictL (sparse codes) + L2 logreg,
  * task-driven DictL (paper eq. 11): bilevel, codes differentiated
    implicitly through the elastic-net proximal-gradient fixed point.

Claim validated (Table 2's qualitative ordering): task-driven DictL ≥
unsupervised DictL and is competitive with (or better than) raw-feature
logreg while using k ≪ p variables.
"""

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import LBFGS, ProximalGradient, custom_fixed_point, prox

jax.config.update("jax_enable_x64", True)


def make_cohort(key, m=240, p=400, k_informative=10):
    """Labels depend on a sparse low-dim latent combination — the regime
    where task-driven codes should win."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    latent = jax.random.normal(k1, (m, k_informative))
    mix = jax.random.normal(k2, (k_informative, p)) * \
        (jax.random.uniform(jax.random.fold_in(k2, 1),
                            (k_informative, p)) < 0.05)
    X = latent @ mix + 0.5 * jax.random.normal(k3, (m, p))
    w = jax.random.normal(k4, (k_informative,))
    y = (latent @ w + 0.3 * jax.random.normal(jax.random.fold_in(k4, 1),
                                              (m,)) > 0).astype(jnp.float64)
    return X, y


def auc(scores, labels):
    order = jnp.argsort(scores)
    ranks = jnp.empty_like(order).at[order].set(jnp.arange(len(scores)))
    pos = labels > 0.5
    n_pos = jnp.sum(pos)
    n_neg = len(labels) - n_pos
    return float((jnp.sum(jnp.where(pos, ranks, 0)) -
                  n_pos * (n_pos - 1) / 2) / (n_pos * n_neg))


def logreg(X, y, l2=1e-2, l1=0.0, iters=400):
    def obj(w):
        z = X @ w
        ll = jnp.mean(jnp.logaddexp(0.0, z) - y * z)
        return ll + 0.5 * l2 * jnp.sum(w ** 2)

    if l1 == 0.0:
        return LBFGS(obj, maxiter=iters, stepsize=0.5,
                     implicit_diff=False).run(jnp.zeros(X.shape[1]))[0]
    L = float(jnp.linalg.eigvalsh(X.T @ X).max()) / len(y) + l2
    solver = ProximalGradient(lambda w, tf: obj(w),
                              lambda v, lam, s: prox.prox_lasso(v, lam, s),
                              stepsize=1.0 / L, maxiter=iters,
                              implicit_diff=False)
    return solver.run(jnp.zeros(X.shape[1]), (None, l1))[0]


def sparse_code(X, D, lam=0.1, gamma=0.1, iters=300):
    """codes x: (m, k) minimizing ||X − x D||² + elastic net."""
    # keep L traced (this runs inside jit for the task-driven bilevel path)
    L = jnp.linalg.eigvalsh(D @ D.T).max() + 1e-3

    def f(x, theta):
        return 0.5 * jnp.sum((X - x @ theta) ** 2)

    pr = lambda v, tg, s: prox.prox_elastic_net(v, tg, s)
    solver = ProximalGradient(f, pr, stepsize=1.0 / L, maxiter=iters,
                              tol=1e-9, implicit_diff=False)
    codes = solver.run(jnp.zeros((X.shape[0], D.shape[0])),
                       (D, (lam, gamma)))[0]
    return codes, f, pr, L


def run(emit_fn=emit):
    key = jax.random.PRNGKey(0)
    X, y = make_cohort(key)
    m = X.shape[0]
    ntr = int(0.6 * m)
    Xtr, ytr, Xte, yte = X[:ntr], y[:ntr], X[ntr:], y[ntr:]
    k_atoms = 10
    results = {}

    # baselines ----------------------------------------------------------
    w = logreg(Xtr, ytr, l2=1e-2)
    results["l2_logreg"] = auc(Xte @ w, yte)
    w = logreg(Xtr, ytr, l2=1e-4, l1=5e-3)
    results["l1_logreg"] = auc(Xte @ w, yte)

    # unsupervised dictionary + logreg ------------------------------------
    key_d = jax.random.fold_in(key, 1)
    D = jax.random.normal(key_d, (k_atoms, X.shape[1]))
    D = D / jnp.linalg.norm(D, axis=1, keepdims=True)
    for _ in range(30):    # alternating minimization
        codes, *_ = sparse_code(Xtr, D, iters=120)
        D = jnp.linalg.lstsq(codes, Xtr, rcond=None)[0]
        D = D / jnp.maximum(jnp.linalg.norm(D, axis=1, keepdims=True),
                            1e-8)
    codes_tr, *_ = sparse_code(Xtr, D, iters=300)
    codes_te, *_ = sparse_code(Xte, D, iters=300)
    wc = logreg(codes_tr, ytr, l2=1e-1)
    results["dictl_l2_logreg"] = auc(codes_te @ wc, yte)

    # task-driven DictL (eq. 11): bilevel with implicit codes -------------
    lam, gamma = 0.1, 0.1

    def inner_solver(init_x, theta):
        codes, f, pr, L = sparse_code(Xtr, theta, lam, gamma, iters=300)
        return codes

    def T(x, theta):
        L = jnp.linalg.norm(theta, ord=2) ** 2 + 1e-3
        g = (x @ theta - Xtr) @ theta.T
        return prox.prox_elastic_net(x - g / L, (lam, gamma), 1.0 / L)

    coder = custom_fixed_point(T, solve="normal_cg", tol=1e-6,
                               maxiter=300)(inner_solver)

    def outer(params):
        theta, w_out, b = params
        codes = coder(None, theta)
        z = codes @ w_out + b
        ll = jnp.mean(jnp.logaddexp(0.0, z) - ytr * z)
        return ll + 1e-2 * jnp.sum(w_out ** 2)

    params = (D, jnp.zeros(k_atoms), 0.0)
    val_and_grad = jax.jit(jax.value_and_grad(outer))
    mom = jax.tree_util.tree_map(jnp.zeros_like, params)
    t_step = time_fn(lambda: val_and_grad(params)[0], iters=2)
    for _ in range(40):       # Adam-lite: momentum GD
        v, g = val_and_grad(params)
        mom = jax.tree_util.tree_map(lambda m, gi: 0.9 * m + gi, mom, g)
        params = jax.tree_util.tree_map(
            lambda p_, m: p_ - 0.05 * m, params, mom)
    theta, w_out, b = params
    codes_te2, *_ = sparse_code(Xte, theta, lam, gamma, iters=300)
    results["task_driven_dictl"] = auc(codes_te2 @ w_out + b, yte)

    ok = results["task_driven_dictl"] >= results["dictl_l2_logreg"] - 0.02
    emit_fn("table2_dictionary_learning", t_step,
            ";".join(f"{k}={v:.3f}" for k, v in results.items())
            + f";task_beats_unsup={ok}")
    return results


if __name__ == "__main__":
    run()
