"""Shared benchmark utilities."""
import json
import re
import time

import jax
import numpy as np


class Collector:
    """emit-compatible sink that also accumulates rows for a JSON report."""

    def __init__(self):
        self.rows = []

    def __call__(self, name: str, seconds: float, derived: str = ""):
        self.rows.append({"name": name, "us_per_call": seconds * 1e6,
                          "derived": derived})
        emit(name, seconds, derived)

    def write_json(self, path: str, **meta):
        payload = dict(meta, rows=self.rows)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        return path


def time_fn(fn, *args, warmup: int = 1, iters: int = 5):
    """Median wall time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def summarize_dispatch(rows):
    """Aggregate ``dispatch=<decision>`` derived tags across report rows.

    Every row where the autotuner made a dispatch decision (chosen
    solver, mesh size, block_b) carries a ``dispatch=`` tag — tokens
    joined by ``+``, e.g. ``dispatch=mesh=1+solver=sharded_cg`` — so the
    report documents what the tuner picked.  Returns ``None`` when no
    row carries one.
    """
    decisions = {}
    for row in rows:
        m = re.search(r"dispatch=([^,]+)", row.get("derived", ""))
        if m:
            decisions[row["name"]] = m.group(1)
    if not decisions:
        return None
    return {"count": len(decisions), "rows": decisions}


def summarize_speedups(rows):
    """Aggregate ``speedup=<x>x`` derived tags across report rows.

    Interpret-mode Pallas rows (``derived`` tagged ``interpret-mode``) are
    excluded: the CPU Pallas interpreter is a correctness vehicle and its
    timings would poison any speedup statistic.  The names of excluded
    rows are listed under ``skipped`` so the report never silently drops
    a measurement.  Returns ``None`` when no row carries a speedup tag.
    """
    speedups = {}
    skipped = []
    for row in rows:
        derived = row.get("derived", "")
        if "interpret-mode" in derived:
            skipped.append(row["name"])
            continue
        m = re.search(r"speedup=([0-9.]+)x", derived)
        if m:
            speedups[row["name"]] = float(m.group(1))
    if not speedups:
        return None
    vals = sorted(speedups.values())
    return {"count": len(vals), "min": vals[0], "max": vals[-1],
            "median": float(np.median(vals)), "rows": speedups,
            "skipped": skipped}
