"""Shared benchmark utilities."""
import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 1, iters: int = 5):
    """Median wall time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
