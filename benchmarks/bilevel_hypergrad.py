"""Batched-vs-looped hypergradients through the solver runtime's ``run()``.

The workload: B independent ridge-regression hyperparameter problems (one
regularizer θᵢ per dataset).  Each hypergradient needs a full inner SOLVE
(``GradientDescent.run()``, a masked ``lax.while_loop``) plus one implicit
backward linear solve.  ``jax.vmap`` turns the whole batch into ONE masked
forward loop and ONE batched backward solve — this benchmark measures that
against the python-loop baseline.

Run: PYTHONPATH=src python -m benchmarks.run --only bilevel
"""
import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core import GradientDescent

jax.config.update("jax_enable_x64", True)


def _make_problems(key, B, m, d):
    X = jax.random.normal(key, (B, m, d))
    y = jax.random.normal(jax.random.fold_in(key, 1), (B, m))
    thetas = jnp.linspace(0.5, 5.0, B)
    return X, y, thetas


def _bench_hypergrad(emit_fn, B=32, m=24, d=12, maxiter=300):
    X, y, thetas = _make_problems(jax.random.PRNGKey(0), B, m, d)
    # one conservative stepsize covering the whole batch
    L = float(max(jnp.linalg.eigvalsh(X[i].T @ X[i]).max()
                  for i in range(B))) + 5.0

    def hypergrad(Xi, yi, theta):
        def inner_obj(x, t):
            r = Xi @ x - yi
            return 0.5 * jnp.sum(r ** 2) + 0.5 * t * jnp.sum(x ** 2)

        solver = GradientDescent(inner_obj, stepsize=1.0 / L,
                                 maxiter=maxiter, tol=1e-10, solve="cg")
        # outer loss: validation-style quadratic in the inner optimum
        return jnp.sum(solver.run(jnp.zeros(d), theta)[0] ** 2)

    grad_one = jax.jit(jax.grad(hypergrad, argnums=2))

    def looped():
        return [grad_one(X[i], y[i], thetas[i]) for i in range(B)]

    grad_vmap = jax.jit(jax.vmap(jax.grad(hypergrad, argnums=2)))

    # correctness gate before timing: batched == looped hypergradients
    g_loop = jnp.stack(looped())
    g_vmap = grad_vmap(X, y, thetas)
    err = float(jnp.max(jnp.abs(g_loop - g_vmap)))
    assert err < 1e-8, f"batched hypergrad drifted from looped: {err}"

    t_loop = time_fn(looped, iters=3)
    t_vmap = time_fn(lambda: grad_vmap(X, y, thetas), iters=3)
    emit_fn(f"bilevel_hypergrad_loop_B{B}_d{d}", t_loop, "")
    emit_fn(f"bilevel_hypergrad_vmap_B{B}_d{d}", t_vmap,
            f"speedup={t_loop / t_vmap:.1f}x,maxerr={err:.1e}")
    return t_loop / t_vmap


def run(emit_fn, smoke: bool = False):
    if smoke:
        _bench_hypergrad(emit_fn, B=16, m=16, d=8, maxiter=200)
    else:
        _bench_hypergrad(emit_fn, B=32, m=24, d=12)
        _bench_hypergrad(emit_fn, B=128, m=24, d=12)


if __name__ == "__main__":
    from benchmarks.common import emit
    run(emit, smoke=True)
