"""Pallas kernel micro-benchmarks (interpret mode — correctness-scale only;
real perf numbers come from the §Roofline dry-run model, not CPU timing)."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn


def run(emit_fn=emit):
    key = jax.random.PRNGKey(0)

    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    B, S, H, D = 1, 256, 4, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D),
                          jnp.float32)
    t = time_fn(lambda: flash_attention(q, k, v, interpret=True), iters=2)
    t_ref = time_fn(jax.jit(attention_ref), q, k, v, iters=3)
    emit_fn("kernel_flash_attention_interp", t,
            f"interpret-mode,jnp_ref={t_ref*1e6:.1f}us")

    from repro.kernels.rwkv_wkv.ops import wkv
    N = 64
    r = jax.random.normal(key, (1, 128, 2, N)) * 0.5
    kk = jax.random.normal(jax.random.fold_in(key, 3), (1, 128, 2, N)) * 0.5
    vv = jax.random.normal(jax.random.fold_in(key, 4), (1, 128, 2, N)) * 0.5
    w = jnp.full((1, 128, 2, N), 0.9)
    u = jnp.zeros((2, N))
    t = time_fn(lambda: wkv(r, kk, vv, w, u, interpret=True)[0], iters=2)
    emit_fn("kernel_rwkv_wkv_interp", t, "interpret-mode")

    # interpret-mode rows assert parity, not speed: problem sizes are the
    # smallest that still exercise the kernels' grids (PR 9 shrank them —
    # the old 64x128 / B=16,d=64 shapes cost 170-278 ms/call of pure
    # interpreter overhead in every smoke run)
    from repro.kernels.simplex_proj.ops import projection_simplex_batched
    Y = jax.random.normal(key, (16, 32))
    t = time_fn(lambda: projection_simplex_batched(Y, 1.0, True), iters=2)
    emit_fn("kernel_simplex_proj_interp", t, "interpret-mode")

    from repro.kernels.batched_cg.kernel import batched_cg_pallas
    from repro.kernels.batched_cg.ref import batched_cg_ref
    B, d = 4, 16
    R = jax.random.normal(key, (B, d, d), jnp.float32)
    A = jnp.einsum("bij,bkj->bik", R, R) + 8.0 * jnp.eye(d, dtype=jnp.float32)
    rhs = jax.random.normal(jax.random.fold_in(key, 5), (B, d), jnp.float32)
    t = time_fn(lambda: batched_cg_pallas(A, rhs, tol=1e-6, maxiter=d,
                                          block_b=B, interpret=True),
                iters=2)
    t_ref = time_fn(lambda: batched_cg_ref(A, rhs, tol=1e-6, maxiter=d),
                    iters=3)
    emit_fn("kernel_batched_cg_interp", t,
            f"interpret-mode,jnp_ref={t_ref*1e6:.1f}us")


if __name__ == "__main__":
    run()
