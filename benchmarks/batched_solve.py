"""Batched-vs-looped linear-solve benchmark (the engine's reason to exist).

Implicit-diff workloads solve many independent small systems per step:
per-example bilevel reweighting, per-dataset hyperparameter gradients,
per-molecule sensitivities.  This benchmark measures the wall-clock ratio of

  * looped   — one jitted solve per system, dispatched B times from Python
               (the pre-engine behavior), vs.
  * batched  — ONE masked while_loop over the whole batch through
               ``linear_solve.solve(..., batch_axes=0)``, vs.
  * vmap(custom_root grad) — a whole batched implicit-gradient pipeline.

Acceptance target: batched ≥ 3× faster than looped for B ≥ 64 small systems.
"""
import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import linear_solve as ls
from repro.core.implicit_diff import custom_root


def _spd_batch(key, B, d, cond=20.0):
    def one(k):
        A = jax.random.normal(k, (d, d))
        A = A @ A.T
        return A + (jnp.trace(A) / d / cond) * jnp.eye(d)
    return jax.vmap(one)(jax.random.split(key, B))


def _bench_solve(emit_fn, B=64, d=64, tol=1e-8):
    key = jax.random.PRNGKey(0)
    As = _spd_batch(key, B, d)
    bs = jax.random.normal(jax.random.fold_in(key, 1), (B, d))

    single = jax.jit(lambda A, b: ls.solve_cg(
        lambda v: A @ v, b, tol=tol, maxiter=4 * d))

    def looped():
        return [single(As[i], bs[i]) for i in range(B)]

    batched = jax.jit(functools.partial(
        ls.solve, lambda v: jnp.einsum("bij,bj->bi", As, v),
        method="cg", batch_axes=0, tol=tol, maxiter=4 * d))

    t_loop = time_fn(looped, iters=3)
    t_batch = time_fn(lambda: batched(bs), iters=3)
    speedup = t_loop / t_batch
    emit_fn(f"batched_solve_loop_B{B}_d{d}", t_loop, "")
    emit_fn(f"batched_solve_engine_B{B}_d{d}", t_batch,
            f"speedup={speedup:.1f}x")
    return speedup


def _bench_vmapped_implicit_grad(emit_fn, B=64, m=32, d=16):
    """Gradient of a vmapped @custom_root ridge solve: one batched bwd solve."""
    key = jax.random.PRNGKey(1)
    X = jax.random.normal(key, (B, m, d))
    y = jax.random.normal(jax.random.fold_in(key, 1), (B, m))
    thetas = jnp.linspace(0.5, 5.0, B)

    def loss(Xi, yi, theta):
        def f(x, t):
            r = Xi @ x - yi
            return (jnp.sum(r ** 2) + t * jnp.sum(x ** 2)) / 2
        F = jax.grad(f, argnums=0)

        def raw(init, t):
            del init
            return jnp.linalg.solve(Xi.T @ Xi + t * jnp.eye(d), Xi.T @ yi)

        return jnp.sum(custom_root(F, solve="cg", tol=1e-10)(raw)(None, theta)
                       ** 2)

    grad_one = jax.jit(jax.grad(loss, argnums=2))

    def looped():
        return [grad_one(X[i], y[i], thetas[i]) for i in range(B)]

    grad_vmap = jax.jit(jax.vmap(jax.grad(loss, argnums=2)))

    t_loop = time_fn(looped, iters=3)
    t_vmap = time_fn(lambda: grad_vmap(X, y, thetas), iters=3)
    emit_fn(f"implicit_grad_loop_B{B}", t_loop, "")
    emit_fn(f"implicit_grad_vmap_B{B}", t_vmap,
            f"speedup={t_loop / t_vmap:.1f}x")


def _bench_pallas_parity(emit_fn, B=64, d=64):
    """Fused-kernel path (interpret off-TPU: correctness-scale timing only)."""
    key = jax.random.PRNGKey(2)
    As = _spd_batch(key, B, d).astype(jnp.float32)
    bs = jax.random.normal(jax.random.fold_in(key, 1), (B, d), jnp.float32)
    from repro.kernels.batched_cg.ops import batched_cg
    t = time_fn(lambda: batched_cg(As, bs, tol=1e-6), iters=2)
    emit_fn(f"batched_cg_op_B{B}_d{d}", t, f"backend={jax.default_backend()}")


def run(emit_fn=emit, smoke: bool = False):
    if smoke:
        speedup = _bench_solve(emit_fn, B=64, d=32)
        _bench_pallas_parity(emit_fn, B=16, d=32)
    else:
        speedup = _bench_solve(emit_fn, B=64, d=64)
        _bench_solve(emit_fn, B=256, d=32)
        _bench_vmapped_implicit_grad(emit_fn)
        _bench_pallas_parity(emit_fn)
    return speedup


if __name__ == "__main__":
    run()
