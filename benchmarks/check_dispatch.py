"""CI dispatch-regression gate: the autotuner must never CHOOSE a loser.

Reads a BENCH json report and checks every ``auto-selected`` row (the
mesh extent ``auto_mesh_size`` actually picked, tagged
``dispatch=mesh=<n>`` by ``benchmarks/sharded_solve.py`` and
``benchmarks/autotune_sweep.py``): its ``sharded/single`` ratio must
stay ≤ the threshold (default 1.1).  Individual sweep rows MAY lose —
that's the curve the tuner learns from — but the selected point losing
means the cost model regressed.

Usage::

    python -m benchmarks.check_dispatch BENCH_sharded.json [--max-ratio 1.1]

Exits nonzero (naming the offending rows) on regression, or when the
report contains no auto-selected rows at all (a gate that checks nothing
must fail loudly, not pass silently).
"""
import argparse
import json
import re
import sys


def check(report: dict, max_ratio: float = 1.1):
    """Return (selected_rows, failures) for a parsed BENCH report."""
    selected, failures = [], []
    for row in report.get("rows", []):
        derived = row.get("derived", "")
        if "auto-selected" not in derived:
            continue
        m = re.search(r"sharded/single=([0-9.]+)x", derived)
        if not m:
            failures.append(f"{row['name']}: auto-selected row has no "
                            "sharded/single ratio tag")
            continue
        ratio = float(m.group(1))
        selected.append((row["name"], ratio))
        if ratio > max_ratio:
            failures.append(
                f"{row['name']}: auto-dispatch selected a losing mesh "
                f"(sharded/single={ratio}x > {max_ratio}x)")
    if not selected and not failures:
        failures.append("no auto-selected dispatch rows found in report — "
                        "the gate has nothing to check (did the autotune/"
                        "sharded benchmarks run?)")
    return selected, failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("report", help="BENCH json report path")
    ap.add_argument("--max-ratio", type=float, default=1.1,
                    help="max allowed sharded/single for selected meshes")
    args = ap.parse_args()
    with open(args.report) as f:
        report = json.load(f)
    selected, failures = check(report, args.max_ratio)
    for name, ratio in selected:
        print(f"OK {name}: sharded/single={ratio}x <= {args.max_ratio}x")
    if failures:
        for msg in failures:
            print(f"DISPATCH REGRESSION: {msg}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
