"""Sharded vs. single-device hypergradients: the device-count scaling curve.

The workload is the canonical batched implicit-diff hot path — ``jax.grad``
of an ``implicit_diff``-decorated batched ridge solver, whose backward pass
is ONE linear solve with ``A = -∂₁F`` — run two ways:

  * single-device: the classic ``cg`` registry route (the PR 2/3 baseline);
  * sharded: the batch split over an n-device mesh (``SolveSharding`` on
    the spec), forward solve under ``shard_map``, backward solve through
    the ``sharded_cg`` registry route — no host gather (the compiled
    all-gather census is asserted in ``tests/test_sharded_operators.py``).

Rows sweep the mesh size over the available devices (1, 2, 4, ... — the CI
multi-device lane forces 8 host devices), reporting ``sharded/single``
time ratios per device count: the scaling curve the ROADMAP's
sharded-solves item asked for.  On a 1-device process the curve degenerates
to the n=1 row, which then measures pure shard_map overhead.

The measured curve is fed into the dispatch ``TuningCache``, and a final
row routes through ``launch.mesh.auto_mesh_size`` — the tuned path the
examples use — tagged ``dispatch=mesh=<n>`` + ``auto-selected``.  The CI
dispatch-regression gate (``benchmarks/check_dispatch.py``) asserts that
row's ratio stays ≤ 1.1: the tuner must never *choose* a losing mesh.
"""
import functools

import jax
import jax.numpy as jnp

from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks.common import emit, time_fn
from repro.core.diff_api import ImplicitDiffSpec, implicit_diff
from repro.distributed.sharded_operators import SolveSharding
from repro.launch.mesh import auto_mesh_size, make_solve_mesh


def _problem(key, B, m, d):
    X = jax.random.normal(key, (B, m, d))
    y = jax.random.normal(jax.random.fold_in(key, 1), (B, m))
    theta = jnp.linspace(0.5, 2.0, B)
    return X, y, theta


def _ridge_F(x, theta, X, y):
    r = jnp.einsum("bmd,bd->bm", X, x) - y
    return jnp.einsum("bmd,bm->bd", X, r) + theta[:, None] * x


def _local_solver(theta, X, y):
    d = X.shape[-1]
    A = jnp.einsum("bmd,bme->bde", X, X) + theta[:, None, None] * jnp.eye(d)
    return jnp.linalg.solve(
        A, jnp.einsum("bmd,bm->bd", X, y)[..., None])[..., 0]


def _single_device_grad(X, y):
    spec = ImplicitDiffSpec(optimality_fun=_ridge_F, solve="cg", tol=1e-8)
    dec = implicit_diff(spec)(
        lambda init, theta, X, y: _local_solver(theta, X, y))
    return jax.jit(jax.grad(
        lambda t: jnp.sum(dec(None, t, X, y) ** 2)))


def _sharded_grad(mesh, X, y):
    from jax.experimental.shard_map import shard_map
    sharding = SolveSharding(mesh, P("data", None), batch_ndim=1,
                             theta_specs=(P("data"), P("data", None, None),
                                          P("data", None)))
    spec = ImplicitDiffSpec(optimality_fun=_ridge_F, solve="cg", tol=1e-8,
                            sharding=sharding)

    def fwd(init, theta, X, y):
        return shard_map(_local_solver, mesh=mesh,
                         in_specs=(P("data"), P("data", None, None),
                                   P("data", None)),
                         out_specs=P("data", None), check_rep=False)(
                             theta, X, y)

    dec = implicit_diff(spec)(fwd)
    X_sh = jax.device_put(X, NamedSharding(mesh, P("data", None, None)))
    y_sh = jax.device_put(y, NamedSharding(mesh, P("data", None)))
    grad = jax.jit(jax.grad(
        lambda t: jnp.sum(dec(None, t, X_sh, y_sh) ** 2)))
    put = functools.partial(jax.device_put,
                            device=NamedSharding(mesh, P("data")))
    return grad, put


def run(emit_fn=emit, smoke: bool = False):
    B, m, d = (64, 24, 16) if smoke else (256, 48, 32)
    key = jax.random.PRNGKey(0)
    X, y, theta = _problem(key, B, m, d)

    single = _single_device_grad(X, y)
    t_single = time_fn(lambda: single(theta), iters=3)
    emit_fn(f"sharded_hypergrad_single_B{B}_d{d}", t_single, "baseline")

    n_dev = len(jax.devices())
    counts, n = [], 1
    while n <= n_dev and B % n == 0:
        counts.append(n)
        n *= 2
    times = {}
    for n in counts:
        mesh = make_solve_mesh(devices=n)
        grad, put = _sharded_grad(mesh, X, y)
        theta_sh = put(theta)
        t_sh = time_fn(lambda: grad(theta_sh), iters=3)
        times[n] = t_sh
        emit_fn(f"sharded_hypergrad_mesh{n}_B{B}_d{d}", t_sh,
                f"sharded/single={t_sh / t_single:.2f}x")

    # Feed the measured end-to-end curve into the dispatch TuningCache
    # (keyed exactly as auto_mesh_size / should_shard look regimes up),
    # then report the extent the tuned path picks.  These puts overwrite
    # any raw-solve sweep entries from benchmarks/autotune_sweep.py with
    # hypergrad-representative timings from THIS process.
    from repro.analysis import autotune
    backend = autotune.current_backend()
    cache = autotune.default_cache()
    cache.put(autotune.TuningKey(
        backend, autotune.single_device_solver(True, d), B, d, "float32",
        1), t_single)
    for n, t_sh in times.items():
        cache.put(autotune.TuningKey(
            backend, "sharded_cg", B, d, "float32", n), t_sh)
    n_auto = auto_mesh_size(B, d)
    t_auto = times[n_auto]
    emit_fn(f"sharded_hypergrad_auto_mesh{n_auto}_B{B}_d{d}", t_auto,
            f"sharded/single={t_auto / t_single:.2f}x,"
            f"dispatch=mesh={n_auto}+solver=sharded_cg,auto-selected")


if __name__ == "__main__":
    run()
