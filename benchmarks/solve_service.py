"""Solve-service sweep: batched-bucket vs per-request dispatch, warm vs cold.

The service's whole reason to exist is that 64 *independent* concurrent
requests should cost ONE batched masked solve, not 64 dispatches.  This
benchmark measures exactly that claim plus the warm-start story:

  * ``service_per_request`` — the same 64 requests through a service with
    ``max_batch=1``: every request is its own bucket of capacity 1 (the
    compiled program is reused, so this measures dispatch multiplicity,
    not recompilation).
  * ``service_batched`` — ``max_batch=64``: all 64 requests land in one
    bucket → one batched dispatch.  The derived column reports the
    per-request/batched speedup (the acceptance bar is ≥ 5x).
  * ``service_warm`` vs ``service_cold`` — the same traffic replayed
    against a warm ``WarmStartCache``: repeat requests fingerprint-hit and
    start at the previous solution (0-iteration convergence for exact
    repeats); the derived column reports the measured cache hit rate.

All requests are SPD ridge-style systems of one shape, the hyperopt/DEQ
serving regime the batched dense engine targets.
"""
import time

import numpy as np

from benchmarks.common import emit
from repro.runtime.solve_service import SolveService, WarmStartCache


def _problems(n, d, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        M = rng.standard_normal((d, d))
        out.append((M @ M.T + d * np.eye(d), rng.standard_normal(d)))
    return out


def _round(svc, problems, warm_start=True):
    """One traffic round; returns ``(dispatch_s, end_to_end_s)``.

    Admission (``submit``) costs the same in every service configuration —
    the claim under test is the *dispatch* shape, so the dispatch timer
    covers ``flush()`` through the last resolved future, and the
    end-to-end timer additionally includes the submits.
    """
    t0 = time.perf_counter()
    futs = [svc.submit(A, b, positive_definite=True, warm_start=warm_start)
            for A, b in problems]
    t1 = time.perf_counter()
    svc.flush()
    for f in futs:
        f.result()
    t2 = time.perf_counter()
    return t2 - t1, t2 - t0


def _median_round(svc, problems, iters, **kw):
    ts = [_round(svc, problems, **kw) for _ in range(iters)]
    return (float(np.median([t[0] for t in ts])),
            float(np.median([t[1] for t in ts])))


def run(emit_fn=emit, smoke: bool = False):
    n_req, d = (64, 32)
    iters = 3 if smoke else 7
    problems = _problems(n_req, d)

    # -- batched-bucket vs per-request dispatch (both cache-off: the
    # comparison is about dispatch shape, not warm starts) ----------------
    per_req = SolveService(max_batch=1, cache=None)
    for _ in range(2):                              # compile cap=1 + warm jit
        _round(per_req, problems)
    t_per, e_per = _median_round(per_req, problems, iters)

    batched = SolveService(max_batch=n_req, cache=None)
    for _ in range(2):                              # compile cap=64 + warm jit
        _round(batched, problems)
    t_bat, e_bat = _median_round(batched, problems, iters)

    speedup = t_per / t_bat
    emit_fn(f"service_per_request_B{n_req}_d{d}", t_per / n_req,
            f"{n_req} dispatches")
    emit_fn(f"service_batched_B{n_req}_d{d}", t_bat / n_req,
            f"batched/per_request={speedup:.1f}x "
            f"end_to_end={e_per / e_bat:.1f}x")

    # -- warm-start cache: replay the same traffic --------------------------
    warm_svc = SolveService(max_batch=n_req,
                            cache=WarmStartCache(capacity=2 * n_req))
    compile_set = _problems(n_req, d, seed=1)       # compile + warm jit,
    for _ in range(2):                              # without touching the
        _round(warm_svc, compile_set, warm_start=False)   # cache
    t_cold, _ = _round(warm_svc, problems)          # cold: all misses
    t_warm, _ = _median_round(warm_svc, problems, iters)
    emit_fn(f"service_cold_B{n_req}_d{d}", t_cold / n_req,
            "first pass, all cache misses")
    emit_fn(f"service_warm_B{n_req}_d{d}", t_warm / n_req,
            f"hit_rate={warm_svc.hit_rate:.2f} "
            f"cold/warm={t_cold / t_warm:.1f}x")


if __name__ == "__main__":
    run()
