"""§Roofline report: aggregate the dry-run JSONs into the per-(arch × shape)
roofline table (compute/memory/collective terms, dominant bottleneck,
useful-compute ratio, roofline-model MFU)."""
import glob
import json
import os

from benchmarks.common import emit

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load(mesh="16x16", tag=""):
    rows = []
    suffix = f"_{tag}.json" if tag else ".json"
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*_{mesh}{suffix}"))):
        with open(path) as f:
            r = json.load(f)
        if tag == "" and r.get("tag"):
            continue
        rows.append(r)
    return rows


def run(emit_fn=emit):
    rows = load()
    if not rows:
        emit_fn("roofline_report", 0.0, "no dryrun results found")
        return []
    for r in rows:
        name = f"roofline_{r['arch']}_{r['shape']}"
        if r["status"] != "ok":
            emit_fn(name, 0.0, r["status"])
            continue
        t = r["roofline"]
        emit_fn(name, t["step_time_s"] * 1e6 / 1e6,
                f"dom={t['dominant']};mfu={t['mfu']:.4f};"
                f"useful={t['useful_ratio']:.3f};"
                f"compute={t['compute_s']:.3f}s;mem={t['memory_s']:.3f}s;"
                f"coll={t['collective_s']:.3f}s")
    return rows


if __name__ == "__main__":
    run()
