"""Tests for the pytree-native LinearOperator subsystem.

Covers the protocol (matvec/rmatvec/transpose/diagonal/materialize/
ravel_view against dense ground truth), the concrete operators, routing
integration (flag validation, ``"auto"`` dispatch, operator-derived
preconditioners), the solver symmetry-metadata contract for every registry
solver, and the diff-API invariants now routed through operators.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import diff_api, operators as ops
from repro.core import linear_solve as ls


@pytest.fixture
def rng():
    return np.random.RandomState(0)


def _spd(rng, d, scale=1.0):
    M = rng.randn(d, d)
    return jnp.asarray(M @ M.T * scale / d + np.eye(d))


def _tree_example(d=3):
    return {"w": jnp.zeros((d, 2)), "b": jnp.zeros(d)}


def _tree_map_fun(theta):
    """A linear tree→tree mapping with a nontrivial (nonsymmetric) dense
    form, for Jacobian ground-truthing."""
    def f(t):
        w, b = t["w"], t["b"]
        return {"w": 2.0 * w + b[:, None] * theta,
                "b": jnp.sin(theta) * b + w.sum(axis=1)}
    return f


# ---------------------------------------------------------------------------
# protocol defaults against dense ground truth
# ---------------------------------------------------------------------------

class TestProtocol:

    def test_jacobian_operator_matches_dense_jacobian(self, rng):
        x = {"w": jnp.asarray(rng.randn(3, 2)), "b": jnp.asarray(rng.randn(3))}
        f = _tree_map_fun(0.7)
        J = ops.JacobianOperator(f, x)
        flat = J.raveled()
        x_flat, unravel = jax.flatten_util.ravel_pytree(x)
        dense = jax.jacobian(lambda v: jax.flatten_util.ravel_pytree(
            f(unravel(v)))[0])(x_flat)
        np.testing.assert_allclose(J.materialize(), dense, atol=1e-6)
        v = jnp.asarray(rng.randn(x_flat.shape[0]))
        np.testing.assert_allclose(flat.matvec(v), dense @ v, atol=1e-6)
        np.testing.assert_allclose(flat.rmatvec(v), dense.T @ v, atol=1e-6)
        np.testing.assert_allclose(flat.diagonal(), jnp.diag(dense),
                                   atol=1e-6)

    def test_transpose_roundtrip_and_symmetric_shortcut(self, rng):
        A_dense = jnp.asarray(rng.randn(4, 4))
        J = ops.JacobianOperator(lambda v: A_dense @ v, jnp.zeros(4))
        assert isinstance(J.T, ops.TransposedOperator)
        assert J.T.transpose() is J          # transpose of transpose
        S = ops.DenseOperator(_spd(rng, 4), positive_definite=True)
        assert S.T is S          # symmetry certificate short-circuits
        A = ops.DenseOperator(A_dense, symmetric=False)
        v = jnp.asarray(rng.randn(4))
        np.testing.assert_allclose(A.T.matvec(v), A_dense.T @ v, atol=1e-6)
        np.testing.assert_allclose(J.T.matvec(v), A_dense.T @ v, atol=1e-6)

    def test_negate_flag(self, rng):
        x = jnp.asarray(rng.randn(5))
        A_dense = jnp.asarray(rng.randn(5, 5))
        J = ops.JacobianOperator(lambda v: A_dense @ v, x, negate=True)
        v = jnp.asarray(rng.randn(5))
        np.testing.assert_allclose(J.matvec(v), -A_dense @ v, atol=1e-6)
        np.testing.assert_allclose(J.T.matvec(v), -A_dense.T @ v, atol=1e-6)

    def test_pd_implies_symmetric_and_conflict_rejected(self, rng):
        A = ops.DenseOperator(_spd(rng, 3), positive_definite=True)
        assert A.symmetric is True
        with pytest.raises(ValueError, match="symmetric"):
            ops.DenseOperator(_spd(rng, 3), symmetric=False,
                              positive_definite=True)

    def test_ravel_view_roundtrip_batched(self, rng):
        b = {"w": jnp.asarray(rng.randn(4, 3, 2)),
             "b": jnp.asarray(rng.randn(4, 3))}
        view = ops.ravel_view(lambda t: jax.tree_util.tree_map(
            lambda l: 2.0 * l, t), b, batch_ndim=1)
        assert view.batched and view.b.shape == (4, 9)
        np.testing.assert_allclose(view.mv(view.b), 2.0 * view.b, atol=1e-6)
        rt = view.to_tree(view.b)
        jax.tree_util.tree_map(np.testing.assert_allclose, rt, b)

    def test_function_operator_explicit_rmatvec(self, rng):
        A_dense = jnp.asarray(rng.randn(4, 4))
        calls = []

        def rmv(v):
            calls.append(1)
            return A_dense.T @ v

        A = ops.FunctionOperator(lambda v: A_dense @ v, jnp.zeros(4),
                                 rmatvec=rmv, symmetric=False)
        v = jnp.asarray(rng.randn(4))
        np.testing.assert_allclose(A.rmatvec(v), A_dense.T @ v, atol=1e-6)
        assert calls  # the explicit rmatvec was used, not linear_transpose


# ---------------------------------------------------------------------------
# structured operators
# ---------------------------------------------------------------------------

class TestStructured:

    def test_ridge_shifted(self, rng):
        A_spd = _spd(rng, 5)
        A = ops.RidgeShifted(
            ops.DenseOperator(A_spd, positive_definite=True), 0.3)
        assert A.positive_definite   # PD survives damping
        np.testing.assert_allclose(A.materialize(),
                                   A_spd + 0.3 * jnp.eye(5), atol=1e-6)
        np.testing.assert_allclose(A.diagonal(), jnp.diag(A_spd) + 0.3,
                                   atol=1e-6)
        # symmetric-but-not-declared-PD does NOT get promoted (indefinite
        # symmetric operators stay indefinite under small ridge); the PSD
        # caller asserts explicitly
        S = ops.RidgeShifted(ops.DenseOperator(A_spd, symmetric=True), 0.3)
        assert not S.positive_definite
        P = ops.RidgeShifted(ops.DenseOperator(A_spd, symmetric=True), 0.3,
                             positive_definite=True)
        assert P.positive_definite

    def test_block_diagonal(self, rng):
        A1, A2 = _spd(rng, 3), jnp.asarray(rng.randn(2, 2))
        B = ops.BlockDiagonal([
            ops.DenseOperator(A1, positive_definite=True),
            ops.DenseOperator(A2, symmetric=False)])
        assert B.symmetric is False and not B.positive_definite
        full = B.materialize()
        np.testing.assert_allclose(full[:3, :3], A1, atol=1e-6)
        np.testing.assert_allclose(full[3:, 3:], A2, atol=1e-6)
        assert float(jnp.abs(full[:3, 3:]).sum()) == 0.0
        v = (jnp.asarray(rng.randn(3)), jnp.asarray(rng.randn(2)))
        out = B.matvec(v)
        np.testing.assert_allclose(out[0], A1 @ v[0], atol=1e-6)
        np.testing.assert_allclose(out[1], A2 @ v[1], atol=1e-6)

    def test_composed(self, rng):
        A1, A2 = jnp.asarray(rng.randn(4, 4)), jnp.asarray(rng.randn(4, 4))
        C = ops.ComposedOperator(ops.DenseOperator(A1, symmetric=False),
                                 ops.DenseOperator(A2, symmetric=False))
        v = jnp.asarray(rng.randn(4))
        np.testing.assert_allclose(C.matvec(v), A1 @ (A2 @ v), atol=1e-5)
        np.testing.assert_allclose(C.T.matvec(v), (A1 @ A2).T @ v, atol=1e-5)

    def test_dense_batched(self, rng):
        Ab = jnp.stack([_spd(rng, 3), _spd(rng, 3, 2.0)])
        A = ops.DenseOperator(Ab, positive_definite=True)
        assert A.batch_ndim == 1
        v = jnp.asarray(rng.randn(2, 3))
        np.testing.assert_allclose(A.matvec(v),
                                   jnp.einsum("bij,bj->bi", Ab, v),
                                   atol=1e-6)
        np.testing.assert_allclose(A.diagonal(),
                                   jnp.diagonal(Ab, axis1=-2, axis2=-1),
                                   atol=1e-6)

    def test_composed_transpose_keeps_flags(self, rng):
        A1, A2 = jnp.asarray(rng.randn(4, 4)), jnp.asarray(rng.randn(4, 4))
        C = ops.ComposedOperator(ops.DenseOperator(A1, symmetric=False),
                                 ops.DenseOperator(A2, symmetric=False),
                                 symmetric=False)
        assert C.T.symmetric is False   # validation survives transposition
        with pytest.raises(ValueError, match="symmetric"):
            ls.route_solve("cg", C.T, jnp.ones(4))

    def test_rmatvec_under_jit_then_eager_does_not_leak_tracers(self, rng):
        """Operators are long-lived public objects: the first rmatvec
        happening under jit must not poison later eager calls (regression:
        the linear-transpose/VJP closures used to be cached on the
        instance, leaking the jit trace's tracers)."""
        A_dense = jnp.asarray(rng.randn(3, 3))
        op = ops.FunctionOperator(lambda v: A_dense @ v, jnp.zeros(3))
        v = jnp.asarray(rng.randn(3))
        jitted = jax.jit(op.rmatvec)(v)
        eager = op.rmatvec(v)           # used to raise UnexpectedTracerError
        np.testing.assert_allclose(eager, A_dense.T @ v, atol=1e-12)
        np.testing.assert_allclose(jitted, eager, atol=1e-12)
        J = ops.JacobianOperator(lambda x: jnp.tanh(A_dense @ x),
                                 jnp.asarray(rng.randn(3)))
        jax.jit(J.rmatvec)(v)
        np.testing.assert_allclose(J.rmatvec(v),
                                   jax.jit(J.rmatvec)(v), atol=1e-12)

    def test_symmetric_refusal_names_solver_and_operator_flags(self, rng):
        """The refusal error must name BOTH sides of the mismatch: the
        requested solver AND the operator's declared symmetric /
        positive_definite flags (auto-routing failures are undebuggable
        when the operator side is omitted)."""
        A = ops.DenseOperator(jnp.asarray(rng.randn(4, 4)), symmetric=False)
        with pytest.raises(ValueError) as err:
            ls.route_solve("cg", A, jnp.ones(4))
        msg = str(err.value)
        assert "'cg'" in msg                      # the requested solver
        assert "symmetric=False" in msg           # the operator's flag
        assert "positive_definite=False" in msg   # ...and the PD flag
        with pytest.raises(ValueError, match="'pallas_cg'"):
            ls.solve(A, jnp.ones(4), method="pallas_cg")

    def test_as_operator(self, rng):
        A_dense = _spd(rng, 4)
        assert isinstance(ops.as_operator(A_dense), ops.DenseOperator)
        # plain numpy matrices coerce too
        assert isinstance(ops.as_operator(np.eye(4)), ops.DenseOperator)
        F = ops.as_operator(lambda v: A_dense @ v, jnp.zeros(4),
                            symmetric=True)
        assert isinstance(F, ops.FunctionOperator) and F.symmetric
        assert ops.as_operator(F) is F
        with pytest.raises(ValueError, match="example"):
            ops.as_operator(lambda v: v)

    def test_preconditioners_from_structure(self, rng):
        x = _tree_example()
        f = _tree_map_fun(0.3)
        A = ops.JacobianOperator(f, x)
        # jacobi: exact on the diagonal
        M = ops.jacobi_preconditioner_from(A)
        v = jax.tree_util.tree_map(lambda l: jnp.ones_like(l), x)
        expect = jax.tree_util.tree_map(lambda d_: 1.0 / d_, A.diagonal())
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(a, b, atol=1e-6),
            M(v), expect)
        # block-jacobi inverts each leaf block exactly
        Mb = ops.block_jacobi_preconditioner(A)
        dense = A.materialize()
        out_flat, _ = jax.flatten_util.ravel_pytree(Mb(v))
        v_flat, _ = jax.flatten_util.ravel_pytree(v)
        nb = x["b"].size    # dict leaves ravel in key order: "b" then "w"
        blocks = jnp.zeros_like(dense)
        blocks = blocks.at[:nb, :nb].set(dense[:nb, :nb])
        blocks = blocks.at[nb:, nb:].set(dense[nb:, nb:])
        np.testing.assert_allclose(out_flat,
                                   jnp.linalg.solve(blocks, v_flat),
                                   atol=1e-5)

    def test_block_jacobi_exact_for_block_diagonal(self, rng):
        A1, A2 = _spd(rng, 3), _spd(rng, 2)
        B = ops.BlockDiagonal([ops.DenseOperator(A1, positive_definite=True),
                               ops.DenseOperator(A2, positive_definite=True)])
        M = ops.block_jacobi_preconditioner(B)
        v = (jnp.asarray(rng.randn(3)), jnp.asarray(rng.randn(2)))
        out = M(B.matvec(v))   # M = B⁻¹ exactly
        np.testing.assert_allclose(out[0], v[0], atol=1e-5)
        np.testing.assert_allclose(out[1], v[1], atol=1e-5)
        # the exact per-block inverse survives a caller-supplied dense
        # matrix (the declared blocks slice it; no leaf-granularity fallback)
        Mm = ops.block_jacobi_preconditioner(B, materialized=B.materialize())
        out_m = Mm(B.matvec(v))
        np.testing.assert_allclose(out_m[0], v[0], atol=1e-5)
        np.testing.assert_allclose(out_m[1], v[1], atol=1e-5)


# ---------------------------------------------------------------------------
# routing integration: flags, auto dispatch, preconditioners
# ---------------------------------------------------------------------------

class TestRouting:

    def test_operator_through_solve_infers_batch(self, rng):
        Ab = jnp.stack([_spd(rng, 4), _spd(rng, 4, 3.0)])
        bb = jnp.asarray(rng.randn(2, 4))
        A = ops.DenseOperator(Ab, positive_definite=True)
        x = ls.solve(A, bb, method="cg", tol=1e-12)   # batch_axes inferred
        np.testing.assert_allclose(jnp.einsum("bij,bj->bi", Ab, x), bb,
                                   atol=1e-5)

    def test_batched_operator_with_callable_method(self, rng):
        """A callable method receives the batch-aware operator as-is (it
        owns batching) — the registry-only batch_axes implication must not
        reject it."""
        Ab = jnp.stack([_spd(rng, 4), _spd(rng, 4, 3.0)])
        bb = jnp.asarray(rng.randn(2, 4))
        A = ops.DenseOperator(Ab, positive_definite=True)

        def my_solve(matvec, b, **kw):
            return ls.solve_cg(matvec, b, tol=1e-12, batch_ndim=1)

        x = ls.solve(A, bb, method=my_solve)
        np.testing.assert_allclose(jnp.einsum("bij,bj->bi", Ab, x), bb,
                                   atol=1e-5)

    def test_batch_mismatch_rejected(self, rng):
        A = ops.DenseOperator(_spd(rng, 4), positive_definite=True)
        with pytest.raises(ValueError, match="batch"):
            ls.solve(A, jnp.ones((2, 4)), method="cg", batch_axes=0)

    def test_auto_dispatch_small_vs_large(self, rng):
        spd_small = ops.DenseOperator(_spd(rng, 8), positive_definite=True)
        gen_small = ops.DenseOperator(jnp.asarray(rng.randn(8, 8)) +
                                      8 * jnp.eye(8), symmetric=False)
        assert ls._resolve_auto(spd_small, jnp.zeros(8)) == "pallas_cg"
        assert ls._resolve_auto(gen_small, jnp.zeros(8)) == "dense_gmres"
        # a requested preconditioner or warm start steers SPD small systems
        # off pallas_cg (which supports neither) onto dense_gmres
        assert ls._resolve_auto(spd_small, jnp.zeros(8),
                                precond="jacobi") == "dense_gmres"
        assert ls._resolve_auto(spd_small, jnp.zeros(8),
                                init=jnp.ones(8)) == "dense_gmres"
        big = jnp.zeros(ls.MAX_DENSE_DIM + 1)
        spd_big = ops.FunctionOperator(lambda v: 2.0 * v, big,
                                       positive_definite=True)
        sym_big = ops.FunctionOperator(lambda v: 2.0 * v, big, symmetric=True)
        gen_big = ops.FunctionOperator(lambda v: 2.0 * v, big)
        assert ls._resolve_auto(spd_big, big) == "cg"
        # symmetric alone is NOT enough for CG (indefinite systems lie)
        assert ls._resolve_auto(sym_big, big) == "normal_cg"
        assert ls._resolve_auto(gen_big, big) == "normal_cg"

    def test_auto_solve_end_to_end(self, rng):
        A_spd = _spd(rng, 6)
        b = jnp.asarray(rng.randn(6))
        x = ls.solve(ops.DenseOperator(A_spd, positive_definite=True), b,
                     method="auto", tol=1e-10)
        np.testing.assert_allclose(A_spd @ x, b, atol=1e-4)
        # warm-started auto solve reroutes off pallas_cg instead of raising
        xw = ls.solve(ops.DenseOperator(A_spd, positive_definite=True), b,
                      method="auto", tol=1e-10, init=x)
        np.testing.assert_allclose(A_spd @ xw, b, atol=1e-4)
        A_gen = jnp.asarray(rng.randn(6, 6)) + 6 * jnp.eye(6)
        x2 = ls.solve(ops.DenseOperator(A_gen, symmetric=False), b,
                      method="auto", tol=1e-10)
        np.testing.assert_allclose(A_gen @ x2, b, atol=1e-4)

    def test_operator_jacobi_precond_skips_probing(self, rng):
        """'jacobi' on an operator reads diagonal() (O(1) for dense) rather
        than probing with d matvecs."""
        calls = []
        A_spd = _spd(rng, 5)

        class CountingDense(ops.DenseOperator):
            def matvec(self, v):
                calls.append(1)
                return super().matvec(v)

        A = CountingDense(A_spd, positive_definite=True)
        b = jnp.asarray(rng.randn(5))
        x = ls.solve(A, b, method="cg", precond="jacobi", tol=1e-12)
        np.testing.assert_allclose(A_spd @ x, b, atol=1e-5)
        # CG itself iterates; the diagonal probe would add exactly d=5
        # leading matvecs before the first iteration.  Resolve again
        # directly and check no matvec fires.
        n = len(calls)
        M = ls._resolve_precond("jacobi", A, b, 0)
        assert len(calls) == n and M is not None

    def test_block_jacobi_requires_operator(self, rng):
        with pytest.raises(ValueError, match="block_jacobi"):
            ls.solve(lambda v: v, jnp.ones(3), method="cg",
                     precond="block_jacobi")

    def test_dense_operator_materialize_feeds_lu(self, rng):
        A_dense = jnp.asarray(rng.randn(5, 5)) + 5 * jnp.eye(5)
        b = jnp.asarray(rng.randn(5))
        x = ls.solve(ops.DenseOperator(A_dense, symmetric=False), b,
                     method="lu")
        np.testing.assert_allclose(A_dense @ x, b, atol=1e-5)


# ---------------------------------------------------------------------------
# solver symmetry metadata: declared flags match numeric behavior
# ---------------------------------------------------------------------------

class TestSolverSymmetryMetadata:
    """Property: for every registry solver, (a) it solves a random SPD
    system it is routed (declared-symmetric operators are legal everywhere),
    and (b) symmetric-only solvers are never routed a declared-nonsymmetric
    operator by route_solve."""

    def _spd_system(self, seed, d=6):
        rng = np.random.RandomState(seed)
        # near-identity SPD so neumann's contraction condition also holds
        M = rng.randn(d, d) * 0.1
        A = jnp.asarray(0.5 * (M + M.T) + np.eye(d))
        b = jnp.asarray(rng.randn(d))
        return A, b

    @staticmethod
    def _maybe_shard(name, A):
        """The sharded registry variants demand a mesh-placed operator —
        the property extends to them through a ShardedOperator over the
        local devices (replicated specs: the metadata contract under test
        is independent of the split)."""
        if not name.startswith("sharded_"):
            return A
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharded_operators import ShardedOperator
        from repro.launch.mesh import make_solve_mesh
        return ShardedOperator(A, make_solve_mesh(), P(None))

    @pytest.mark.parametrize("name", sorted(ls.available_solvers()))
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_solves_declared_spd_system(self, name, seed):
        A_dense, b = self._spd_system(seed)
        A = self._maybe_shard(
            name, ops.DenseOperator(A_dense, positive_definite=True))
        x = ls.route_solve(name, A, b, tol=1e-10, maxiter=2000)
        np.testing.assert_allclose(A_dense @ x, b, atol=5e-4,
                                   err_msg=f"{name} failed its declared "
                                           "regime (SPD)")

    @pytest.mark.parametrize("name", sorted(ls.available_solvers()))
    def test_symmetric_only_never_gets_nonsymmetric_operator(self, name,
                                                             rng):
        # near-identity (general solvers all converge, incl. neumann's
        # contraction condition) but NOT symmetric
        A_dense = jnp.asarray(rng.randn(6, 6) * 0.1 + np.eye(6))
        A = self._maybe_shard(name,
                              ops.DenseOperator(A_dense, symmetric=False))
        b = jnp.asarray(rng.randn(6))
        spec = ls.get_spec(name)
        if spec.symmetric_only:
            with pytest.raises(ValueError, match="symmetric"):
                ls.route_solve(name, A, b, tol=1e-8)
        else:
            x = ls.route_solve(name, A, b, tol=1e-10, maxiter=2000)
            np.testing.assert_allclose(A_dense @ x, b, atol=5e-4,
                                       err_msg=f"general solver {name} "
                                               "failed a nonsymmetric solve")

    def test_undeclared_symmetry_trusts_solver_choice(self, rng):
        """symmetric=None keeps the historical contract: the caller's
        solver choice is the assertion (closures can't declare)."""
        A_spd = _spd(rng, 5)
        A = ops.FunctionOperator(lambda v: A_spd @ v, jnp.zeros(5))
        assert A.symmetric is None
        b = jnp.asarray(rng.randn(5))
        x = ls.route_solve("cg", A, b, tol=1e-10)
        np.testing.assert_allclose(A_spd @ x, b, atol=1e-5)


# ---------------------------------------------------------------------------
# diff API through operators
# ---------------------------------------------------------------------------

class TestDiffApiOperators:

    def _wrapped_ridge(self, rng, **spec_kw):
        X = jnp.asarray(rng.randn(12, 4))
        y = jnp.asarray(rng.randn(12))
        F = jax.grad(lambda w, t: 0.5 * jnp.sum((X @ w - y) ** 2)
                     + 0.5 * t * jnp.sum(w ** 2), argnums=0)
        spec = diff_api.ImplicitDiffSpec(optimality_fun=F, **spec_kw)
        solver = diff_api.implicit_diff(spec)(
            lambda init, t: jnp.linalg.solve(
                X.T @ X + t * jnp.eye(4), X.T @ y))
        closed = lambda t: jnp.linalg.solve(X.T @ X + t * jnp.eye(4), X.T @ y)
        return solver, closed

    @pytest.mark.parametrize("spec_kw", [
        dict(solve="cg"),
        dict(solve="auto"),
        dict(solve="cg", precond="jacobi"),
        dict(solve="cg", precond="block_jacobi"),
        # materializing route: the precond string rides through to the
        # dense solver, which derives it off its own materialized matrix
        dict(solve="dense_gmres", precond="jacobi"),
        dict(solve="dense_gmres", precond="block_jacobi"),
    ])
    def test_jacfwd_jacrev_agree_through_operators(self, rng, spec_kw):
        solver, closed = self._wrapped_ridge(rng, **spec_kw)
        t = 2.0
        Jf = jax.jacfwd(solver, argnums=1)(None, t)
        Jr = jax.jacrev(solver, argnums=1)(None, t)
        J_true = jax.jacobian(closed)(t)
        np.testing.assert_allclose(Jf, J_true, atol=1e-5)
        np.testing.assert_allclose(Jr, J_true, atol=1e-5)

    def test_root_vjp_jvp_operator_path(self, rng):
        A_spd = _spd(rng, 4)
        F = lambda x, t: A_spd @ x - t          # root: x*(t) = A⁻¹ t
        x_star = jnp.linalg.solve(A_spd, jnp.ones(4))
        v = jnp.asarray(rng.randn(4))
        (g,) = diff_api.root_vjp(F, x_star, (jnp.ones(4),), v, solve="cg",
                                 tol=1e-12)
        np.testing.assert_allclose(g, jnp.linalg.solve(A_spd, v), atol=1e-6)
        jv = diff_api.root_jvp(F, x_star, (jnp.ones(4),), (v,), solve="cg",
                               tol=1e-12)
        np.testing.assert_allclose(jv, jnp.linalg.solve(A_spd, v), atol=1e-6)

    def test_no_handrolled_ravel_closures_left(self):
        """Acceptance: diff_api contains no hand-rolled ravel closures and
        linear_solve no _FlatView — the operator layer owns raveling."""
        import inspect
        src = inspect.getsource(diff_api)
        assert "ravel_pytree" not in src
        ls_src = inspect.getsource(ls)
        assert "_FlatView" not in ls_src and "_flat_view" not in ls_src

    def test_vmap_grad_one_batched_operator_solve(self, rng):
        """The counting invariant survives the operator rebase: vmap of a
        gradient executes ONE batched masked solve, and the matvec the
        registry receives is a LinearOperator."""
        X = jnp.asarray(rng.randn(10, 3))
        y = jnp.asarray(rng.randn(10))
        executed, operator_seen = [], []

        def counting_cg(matvec, b, **kw):
            operator_seen.append(isinstance(matvec, ops.LinearOperator))
            jax.debug.callback(lambda _: executed.append(1), jnp.zeros(()))
            return ls.solve_cg(matvec, b, **kw)

        ls.register_solver("counting_cg_ops", counting_cg,
                           symmetric_only=True, supports_precond=True)
        try:
            F = jax.grad(lambda w, t: 0.5 * jnp.sum((X @ w - y) ** 2)
                         + 0.5 * t * jnp.sum(w ** 2), argnums=0)
            solver = diff_api.implicit_diff(
                diff_api.ImplicitDiffSpec(optimality_fun=F,
                                          solve="counting_cg_ops"))(
                lambda init, t: jnp.linalg.solve(
                    X.T @ X + t * jnp.eye(3), X.T @ y))
            loss = lambda t: jnp.sum(solver(None, t) ** 2)
            thetas = jnp.array([0.5, 1.0, 2.0])
            g_vmap = jax.vmap(jax.grad(loss))(thetas)
            jax.effects_barrier()
            assert len(executed) == 1
            assert operator_seen and all(operator_seen)
            g_loop = jnp.stack([jax.grad(loss)(t) for t in thetas])
        finally:
            ls._REGISTRY.pop("counting_cg_ops", None)
        np.testing.assert_allclose(g_vmap, g_loop, rtol=1e-10)


# ---------------------------------------------------------------------------
# kernel boundary: batched_cg takes an operator
# ---------------------------------------------------------------------------

class TestKernelOperatorEntry:

    def test_batched_cg_operator_input(self, rng):
        from repro.kernels.batched_cg.ops import batched_cg
        Ab = jnp.stack([_spd(rng, 4), _spd(rng, 4, 2.0)])
        bb = jnp.asarray(rng.randn(2, 4))
        A = ops.DenseOperator(Ab, positive_definite=True)
        x = batched_cg(A, bb, tol=1e-10)
        np.testing.assert_allclose(jnp.einsum("bij,bj->bi", Ab, x), bb,
                                   atol=1e-5)

    def test_batched_cg_rejects_nonsymmetric_operator(self, rng):
        from repro.kernels.batched_cg.ops import batched_cg
        A = ops.DenseOperator(jnp.asarray(rng.randn(2, 4, 4)),
                              symmetric=False)
        with pytest.raises(ValueError, match="SPD"):
            batched_cg(A, jnp.ones((2, 4)))
