"""Approximate backward modes: parity, accounting, and routing.

Covers the ``backward="one_step" | "neumann_k" | "jacobian_free"`` feature
end-to-end: the raw polynomial apply (hand formulas, preconditioned
Richardson, monotone error estimates), the wrapped decorators in BOTH
autodiff directions, the solver runtime's ``estimate_hypergrad_error``,
bilevel/DEQ threading, the solve service's approximate buckets, the
``WarmStartCache`` save/load satellite, and the deprecated shims'
``backward=`` rejection.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bilevel, diff_api
from repro.core import linear_solve as ls
from repro.core import solver_runtime as sr
from repro.core.implicit_diff import (custom_fixed_point,
                                      custom_fixed_point_jvp, custom_root,
                                      custom_root_jvp)
from repro.core.implicit_layer import deq_fixed_point
from repro.runtime.solve_service import (BucketKey, SolveService,
                                         WarmStartCache)


def _spd(key, d, rho):
    """``A = I − ρS`` with ``‖S‖₂ = 1``: eigenvalues in [1−ρ, 1+ρ]."""
    S = jax.random.normal(key, (d, d))
    S = (S + S.T) / 2.0
    S = S / jnp.linalg.norm(S, 2)
    return jnp.eye(d) - rho * S


def _neumann_ref(A, v, k):
    u = v
    for _ in range(k):
        u = u + (v - A @ u)
    return u


@pytest.fixture
def spd6(rng):
    A = _spd(rng, 6, 0.3)
    b = jax.random.normal(jax.random.fold_in(rng, 1), (6,))
    return A, b


class TestApproxInverseApply:
    """The raw polynomial apply against hand formulas."""

    def test_jacobian_free_is_identity(self, spd6):
        A, b = spd6
        u = ls.approx_inverse_apply(lambda v: A @ v, b,
                                    backward="jacobian_free")
        np.testing.assert_allclose(u, b, rtol=1e-12)

    def test_one_step_hand_formula(self, spd6):
        A, b = spd6
        u = ls.approx_inverse_apply(lambda v: A @ v, b, backward="one_step")
        np.testing.assert_allclose(u, 2.0 * b - A @ b, rtol=1e-12)

    def test_neumann_k_polynomial(self, spd6):
        A, b = spd6
        for k in (1, 3, 5):
            u = ls.approx_inverse_apply(lambda v: A @ v, b,
                                        backward="neumann_k",
                                        backward_iters=k)
            np.testing.assert_allclose(u, _neumann_ref(A, b, k), rtol=1e-10)

    def test_neumann_k1_equals_one_step(self, spd6):
        A, b = spd6
        u1 = ls.approx_inverse_apply(lambda v: A @ v, b, backward="one_step")
        uk = ls.approx_inverse_apply(lambda v: A @ v, b,
                                     backward="neumann_k", backward_iters=1)
        np.testing.assert_allclose(u1, uk, rtol=1e-12)

    def test_neumann_large_k_matches_exact(self, spd6):
        A, b = spd6
        u = ls.approx_inverse_apply(lambda v: A @ v, b,
                                    backward="neumann_k", backward_iters=60)
        np.testing.assert_allclose(u, jnp.linalg.solve(A, b), atol=1e-8)

    def test_preconditioned_neumann_fixes_negated_operator(self, rng):
        # A = −H (stationarity declaration): plain Neumann diverges,
        # jacobi-preconditioned Richardson restores convergence.
        H = _spd(rng, 6, 0.3)
        b = jax.random.normal(jax.random.fold_in(rng, 1), (6,))
        mv = lambda v: -(H @ v)
        u_plain, info_plain = ls.approx_inverse_apply(
            mv, b, backward="neumann_k", backward_iters=10, return_info=True)
        u_prec, info_prec = ls.approx_inverse_apply(
            mv, b, backward="neumann_k", backward_iters=10, precond="jacobi",
            return_info=True)
        assert float(info_plain.hypergrad_error_estimate) > 1.0  # diverged
        assert float(info_prec.hypergrad_error_estimate) < 5e-2
        np.testing.assert_allclose(u_prec, jnp.linalg.solve(-H, b),
                                   atol=5e-2)
        del u_plain

    def test_error_estimate_monotone_in_k(self, spd6):
        A, b = spd6
        ests = []
        for k in (1, 2, 4, 8, 16):
            _, info = ls.approx_inverse_apply(
                lambda v: A @ v, b, backward="neumann_k", backward_iters=k,
                return_info=True)
            ests.append(float(info.hypergrad_error_estimate))
        assert all(e1 > e2 for e1, e2 in zip(ests, ests[1:])), ests

    def test_matvec_accounting(self, spd6):
        A, b = spd6
        assert ls.approx_matvec_count("jacobian_free") == 0
        assert ls.approx_matvec_count("one_step") == 1
        assert ls.approx_matvec_count("neumann_k", 5) == 5
        calls = []

        def mv(v):
            # debug.callback counts EXECUTIONS (the fori_loop body traces
            # once but runs k times)
            jax.debug.callback(lambda _: calls.append(1), jnp.zeros(()))
            return A @ v

        for mode, k, expect in (("jacobian_free", 1, 0), ("one_step", 1, 1),
                                ("neumann_k", 4, 4)):
            calls.clear()
            jax.block_until_ready(ls.approx_inverse_apply(
                mv, b, backward=mode, backward_iters=k))
            jax.effects_barrier()
            assert len(calls) == expect, (mode, len(calls))

    def test_info_fields_and_estimate_off(self, spd6):
        A, b = spd6
        u, info = ls.approx_inverse_apply(
            lambda v: A @ v, b, backward="neumann_k", backward_iters=3,
            return_info=True)
        assert int(info.iterations) == 3
        assert info.hypergrad_error_estimate is not None
        _, info_off = ls.approx_inverse_apply(
            lambda v: A @ v, b, backward="neumann_k", backward_iters=3,
            error_estimate=False, return_info=True)
        assert info_off.hypergrad_error_estimate is None
        del u

    def test_rejects_exact_and_bad_iters(self, spd6):
        A, b = spd6
        with pytest.raises(ValueError, match="route 'exact'"):
            ls.approx_inverse_apply(lambda v: A @ v, b, backward="exact")
        with pytest.raises(ValueError, match="backward_iters"):
            ls.approx_inverse_apply(lambda v: A @ v, b,
                                    backward="neumann_k", backward_iters=0)


class TestSpecValidation:
    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError, match="backward"):
            diff_api.ImplicitDiffSpec(optimality_fun=lambda x, t: x,
                                      backward="bogus")

    def test_neumann_needs_positive_iters(self):
        with pytest.raises(ValueError, match="backward_iters"):
            diff_api.ImplicitDiffSpec(optimality_fun=lambda x, t: x,
                                      backward="neumann_k", backward_iters=0)

    def test_backward_kwargs_roundtrip(self):
        spec = diff_api.ImplicitDiffSpec(optimality_fun=lambda x, t: x,
                                         backward="neumann_k",
                                         backward_iters=5)
        assert spec.backward_kwargs() == {"backward": "neumann_k",
                                          "backward_iters": 5}


class TestWrappedModeParity:
    """Every mode, both autodiff directions, through the decorators."""

    d = 8

    def _solver(self, A, **kw):
        Ainv = jnp.linalg.inv(A)

        def F(x, theta):
            return theta - A @ x

        return custom_root(F, solve="cg", tol=1e-10, **kw)(
            lambda init, t: Ainv @ t)

    @pytest.mark.parametrize("mode,k", [("exact", 1), ("one_step", 1),
                                        ("jacobian_free", 1),
                                        ("neumann_k", 2), ("neumann_k", 6)])
    def test_vjp_and_jvp_match_polynomial(self, rng, mode, k):
        A = _spd(rng, self.d, 0.3)
        c = jax.random.normal(jax.random.fold_in(rng, 1), (self.d,))
        th = jax.random.normal(jax.random.fold_in(rng, 2), (self.d,))
        v = jax.random.normal(jax.random.fold_in(rng, 3), (self.d,))
        solver = self._solver(A, backward=mode, backward_iters=k)

        if mode == "exact":
            ref = lambda w: jnp.linalg.solve(A, w)
        elif mode == "jacobian_free":
            ref = lambda w: w
        elif mode == "one_step":
            ref = lambda w: 2.0 * w - A @ w
        else:
            ref = lambda w: _neumann_ref(A, w, k)

        g = jax.grad(lambda t: c @ solver(jnp.zeros(self.d), t))(th)
        np.testing.assert_allclose(g, ref(c), atol=1e-7)  # Aᵀ = A

        _, dx = jax.jvp(lambda t: solver(jnp.zeros(self.d), t), (th,), (v,))
        np.testing.assert_allclose(dx, ref(v), atol=1e-7)

    def test_neumann_large_k_recovers_exact_grad(self, rng):
        A = _spd(rng, self.d, 0.3)
        th = jax.random.normal(jax.random.fold_in(rng, 2), (self.d,))
        exact = self._solver(A)
        approx = self._solver(A, backward="neumann_k", backward_iters=60)
        loss = lambda s: (lambda t: jnp.sum(s(jnp.zeros(self.d), t) ** 2))
        np.testing.assert_allclose(jax.grad(loss(approx))(th),
                                   jax.grad(loss(exact))(th), atol=1e-7)

    def test_fixed_point_decorator_takes_backward(self, rng):
        # contractive T: neumann_k is the phantom-gradient approximation
        W = 0.4 * _spd(rng, self.d, 0.5)

        def T(x, t):
            return W @ x + t

        x_inf = jnp.linalg.solve(jnp.eye(self.d) - W, jnp.ones(self.d))

        def fp_solver(init, t):
            return x_inf * 0 + jnp.linalg.solve(jnp.eye(self.d) - W, t)

        th = jax.random.normal(jax.random.fold_in(rng, 2), (self.d,))
        g_ex = jax.grad(lambda t: jnp.sum(
            custom_fixed_point(T, solve="cg")(fp_solver)(None, t)))(th)
        g_nk = jax.grad(lambda t: jnp.sum(
            custom_fixed_point(T, backward="neumann_k", backward_iters=40)(
                fp_solver)(None, t)))(th)
        np.testing.assert_allclose(g_nk, g_ex, atol=1e-6)


class TestVmapOneBatchedPass:
    """Acceptance: the approximate backward under ``jax.vmap`` executes ONE
    batched polynomial pass — the traced-F evaluation count is independent
    of the batch size."""

    def _counted_grad(self, rng, B, mode, k):
        d = 4
        A = _spd(rng, d, 0.3)
        Ainv = jnp.linalg.inv(A)
        executed = []

        def F(x, theta):
            jax.debug.callback(lambda _: executed.append(1), jnp.zeros(()))
            return theta - A @ x

        solver = custom_root(F, backward=mode, backward_iters=k)(
            lambda init, t: Ainv @ t)
        loss = lambda t: jnp.sum(solver(jnp.zeros(d), t) ** 2)
        thetas = jax.random.normal(jax.random.fold_in(rng, 1), (B, d))
        g = jax.vmap(jax.grad(loss))(thetas)
        jax.effects_barrier()
        return len(executed), g

    @pytest.mark.parametrize("mode,k", [("one_step", 1), ("neumann_k", 3),
                                        ("jacobian_free", 1)])
    def test_count_independent_of_batch(self, rng, mode, k):
        n1, _ = self._counted_grad(rng, 1, mode, k)
        n8, g8 = self._counted_grad(rng, 8, mode, k)
        assert n1 == n8, (f"{mode}: F executed {n8} times at B=8 vs {n1} "
                          "at B=1 — the backward did not batch")
        assert g8.shape == (8, 4)


class TestDeprecatedShimsRejectBackward:
    def test_custom_root_jvp_rejects(self):
        F = lambda x, t: t - x
        with pytest.raises(TypeError, match="backward"):
            custom_root_jvp(F, backward="one_step")
        with pytest.raises(TypeError, match="backward"):
            custom_root_jvp(F, backward_iters=4)

    def test_custom_fixed_point_jvp_rejects(self):
        T = lambda x, t: 0.5 * x + t
        with pytest.raises(TypeError, match="backward"):
            custom_fixed_point_jvp(T, backward="jacobian_free")


class TestSolverRuntime:
    def _gd(self, A, **kw):
        return sr.GradientDescent(fun=lambda x, t: 0.5 * x @ A @ x - t @ x,
                                  maxiter=400, tol=1e-11, **kw)

    def test_estimate_hypergrad_error(self, rng):
        d = 6
        A = _spd(rng, d, 0.3)
        th = jax.random.normal(jax.random.fold_in(rng, 1), (d,))
        ests = []
        for k in (2, 6):
            gd = self._gd(A, backward="neumann_k", backward_iters=k,
                          precond="jacobi")
            params, _ = gd.run(jnp.zeros(d), th)
            ests.append(float(gd.estimate_hypergrad_error(params, th)))
        assert ests[1] < ests[0] < 1.0, ests

    def test_bilevel_populates_estimate(self, rng):
        d = 6
        A = _spd(rng, d, 0.3)
        gd = self._gd(A, precond="jacobi")
        outer = lambda x, t: 0.5 * jnp.sum((x - 1.0) ** 2)
        sol = bilevel.solve_bilevel(outer, gd, jnp.zeros(d), jnp.zeros(d),
                                    outer_steps=2, backward="neumann_k",
                                    backward_iters=6)
        est = sol.inner_info.hypergrad_error_estimate
        assert est is not None and float(est) < 0.05
        sol_exact = bilevel.solve_bilevel(outer, gd, jnp.zeros(d),
                                          jnp.zeros(d), outer_steps=2)
        assert sol_exact.inner_info.hypergrad_error_estimate is None

    def test_deq_neumann_k_matches_exact(self, rng):
        d = 6
        cell = lambda z, x, w: jnp.tanh(w * z * 0.3 + x)
        x_in = jax.random.normal(rng, (d,))
        out = lambda xx, **kw: jnp.sum(
            deq_fixed_point(cell, jnp.zeros(d), xx, 0.5, fwd_tol=1e-10,
                            **kw))
        g_ex = jax.grad(lambda xx: out(xx, bwd_solve="normal_cg"))(x_in)
        g_nk = jax.grad(lambda xx: out(xx, backward="neumann_k",
                                       backward_iters=30))(x_in)
        np.testing.assert_allclose(g_nk, g_ex, atol=1e-5)


class TestSolveService:
    def _system(self, rng, d=6):
        A = _spd(rng, d, 0.3)
        th = jax.random.normal(jax.random.fold_in(rng, 1), (d,))
        ct = jax.random.normal(jax.random.fold_in(rng, 2), (d,))
        F = lambda x, t: t - A @ x
        return A, th, ct, F, jnp.linalg.solve(A, th)

    def test_approx_buckets_and_estimates(self, rng):
        A, th, ct, F, x_star = self._system(rng)
        svc = SolveService()
        futs = {
            "exact": svc.submit_hypergrad(F, x_star, th, ct),
            "one_step": svc.submit_hypergrad(F, x_star, th, ct,
                                             backward="one_step"),
            "neumann_k": svc.submit_hypergrad(F, x_star, th, ct,
                                              backward="neumann_k",
                                              backward_iters=8),
            "jacobian_free": svc.submit_hypergrad(F, x_star, th, ct,
                                                  backward="jacobian_free"),
        }
        svc.flush()
        res = {m: f.result() for m, f in futs.items()}
        np.testing.assert_allclose(res["one_step"].x[0], 2 * ct - A @ ct,
                                   atol=1e-9)
        np.testing.assert_allclose(res["jacobian_free"].x[0], ct,
                                   atol=1e-12)
        np.testing.assert_allclose(res["exact"].x[0],
                                   jnp.linalg.solve(A, ct), atol=1e-5)
        # distinct matvec budgets prove distinct bucket arms
        assert [res[m].info.iterations for m in
                ("one_step", "neumann_k", "jacobian_free")] == [1, 8, 0]
        assert (res["neumann_k"].info.hypergrad_error_estimate
                < res["one_step"].info.hypergrad_error_estimate)

    def test_spec_default_and_override(self, rng):
        A, th, ct, F, x_star = self._system(rng)
        spec = diff_api.ImplicitDiffSpec(optimality_fun=F,
                                         backward="neumann_k",
                                         backward_iters=4)
        svc = SolveService()
        f_spec = svc.submit_hypergrad(F, x_star, th, ct, spec=spec)
        f_over = svc.submit_hypergrad(F, x_star, th, ct, spec=spec,
                                      backward="exact")
        svc.flush()
        assert int(f_spec.result().info.iterations) == 4
        np.testing.assert_allclose(f_over.result().x[0],
                                   jnp.linalg.solve(A, ct), atol=1e-5)

    def test_approx_requests_never_enter_cache(self, rng):
        A, th, ct, F, x_star = self._system(rng)
        svc = SolveService()
        svc.submit_hypergrad(F, x_star, th, ct, backward="one_step")
        svc.flush()
        assert len(svc.cache) == 0
        svc.submit_hypergrad(F, x_star, th, ct)
        svc.flush()
        assert len(svc.cache) == 1

    def test_block_jacobi_approx_rejected(self, rng):
        A, th, ct, F, x_star = self._system(rng)
        svc = SolveService()
        with pytest.raises(ValueError, match="block_jacobi"):
            svc.submit_hypergrad(F, x_star, th, ct, backward="one_step",
                                 precond="block_jacobi")

    def test_unknown_backward_rejected(self, rng):
        A, th, ct, F, x_star = self._system(rng)
        svc = SolveService()
        with pytest.raises(ValueError, match="backward"):
            svc.submit_hypergrad(F, x_star, th, ct, backward="bogus")


class TestWarmStartCachePersistence:
    def _populated(self, rng, n=3):
        cache = WarmStartCache(capacity=8)
        d = 5
        for i in range(n):
            A = _spd(jax.random.fold_in(rng, i), d, 0.2)
            b = jax.random.normal(jax.random.fold_in(rng, 100 + i), (d,))
            key = BucketKey(d=d, solver="cg", precond=None, symmetric=True,
                            positive_definite=True, dtype="float64",
                            tol=1e-6, maxiter=100 + i, ridge=0.0)
            fp = cache.fingerprint(np.asarray(A), np.asarray(b), key)
            cache.put(fp, np.linalg.solve(np.asarray(A), np.asarray(b)),
                      key=key)
        return cache

    def test_save_load_roundtrip(self, rng, tmp_path):
        cache = self._populated(rng)
        path = cache.save(os.path.join(tmp_path, "warm"))
        assert path.endswith(".npz")
        loaded = WarmStartCache.load(path)
        assert len(loaded) == len(cache)
        assert loaded.capacity == cache.capacity
        for fp, x in cache._store.items():
            np.testing.assert_allclose(loaded._store[fp], x)
            assert loaded._keys[fp] == cache._keys[fp]
            assert isinstance(loaded._keys[fp], BucketKey)

    def test_loaded_cache_serves_lookups(self, rng, tmp_path):
        cache = self._populated(rng, n=2)
        path = cache.save(os.path.join(tmp_path, "warm.npz"))
        loaded = WarmStartCache.load(path)
        for fp in cache._store:
            assert loaded.get(fp) is not None

    def test_version_mismatch_rejected(self, rng, tmp_path):
        cache = self._populated(rng, n=1)
        path = cache.save(os.path.join(tmp_path, "warm.npz"))
        with np.load(path, allow_pickle=False) as z:
            payload = {k: z[k] for k in z.files}
        payload["format_version"] = np.asarray(99)
        np.savez(path, **payload)
        with pytest.raises(ValueError, match="version"):
            WarmStartCache.load(path)


class TestShardedApprox:
    def test_sharded_neumann_matches_dense(self, rng):
        from repro.distributed.sharded_operators import SolveSharding
        from jax.sharding import Mesh, PartitionSpec as P
        d, B = 6, len(jax.devices())
        A = _spd(rng, d, 0.3)
        thetas = jax.random.normal(jax.random.fold_in(rng, 1), (B, d))

        def F(x, theta):
            return theta - x @ A.T

        mesh = Mesh(np.asarray(jax.devices()), ("data",))
        sharding = SolveSharding(mesh, P("data", None), batch_ndim=1,
                                 theta_specs=(P("data", None),))
        spec = diff_api.ImplicitDiffSpec(
            optimality_fun=F, sharding=sharding, backward="neumann_k",
            backward_iters=8)
        Ainv = jnp.linalg.inv(A)
        solver = diff_api.implicit_diff(spec)(lambda init, t: t @ Ainv.T)
        g = jax.grad(lambda t: jnp.sum(solver(jnp.zeros((B, d)), t)))(thetas)
        ref = jax.vmap(lambda _:
                       _neumann_ref(A, jnp.ones(d), 8))(jnp.arange(B))
        np.testing.assert_allclose(g, ref, atol=1e-7)

    def test_sharded_string_precond_rejected(self, rng):
        from repro.distributed.sharded_operators import SolveSharding
        from jax.sharding import Mesh, PartitionSpec as P
        mesh = Mesh(np.asarray(jax.devices()), ("data",))
        sharding = SolveSharding(mesh, P("data", None), batch_ndim=1)
        F = lambda x, t: t - x
        with pytest.raises(ValueError, match="precond"):
            diff_api.root_vjp(F, jnp.ones((1, 2)), (jnp.ones((1, 2)),),
                              jnp.ones((1, 2)), sharding=sharding,
                              backward="one_step", precond="jacobi")
