"""Solve-service scheduler: bucketing, padding, cache, and parity."""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DenseOperator, linear_solve as ls
from repro.core.diff_api import ImplicitDiffSpec, root_vjp
from repro.runtime import (BucketKey, ServiceResult, SolveService,
                           WarmStartCache, bucket_capacity)


def _spd(rng, d):
    M = rng.standard_normal((d, d))
    return M @ M.T + d * np.eye(d)


# -- bucket shaping ----------------------------------------------------------

def test_bucket_capacity_rounds_to_power_of_two():
    assert [bucket_capacity(n) for n in (1, 2, 3, 5, 9, 64)] == \
        [1, 2, 4, 8, 16, 64]
    assert bucket_capacity(100, max_batch=64) == 64
    with pytest.raises(ValueError):
        bucket_capacity(0)


def test_empty_flush_is_a_noop():
    svc = SolveService()
    assert svc.flush() == 0
    assert svc.metrics["dispatches"] == 0


def test_single_request_bucket():
    svc = SolveService(cache=None)
    fut = svc.submit(2.0 * np.eye(4), np.ones(4), positive_definite=True)
    assert svc.flush() == 1
    r = fut.result()
    assert isinstance(r, ServiceResult)
    assert (r.bucket_size, r.bucket_capacity) == (1, 1)
    assert bool(r.info.converged)
    np.testing.assert_allclose(np.asarray(r.x), 0.5, atol=1e-5)


def test_mixed_d_load_forms_multiple_buckets():
    rng = np.random.default_rng(0)
    svc = SolveService()
    futs = [svc.submit(_spd(rng, d), rng.standard_normal(d),
                       positive_definite=True)
            for d in (8, 12, 8, 12, 8, 12, 8, 12)]
    assert svc.flush() == 8
    assert svc.metrics["dispatches"] == 2          # one per d
    sizes = {f.result().bucket_size for f in futs}
    assert sizes == {4}                            # 4 requests per bucket
    for f in futs:
        assert bool(f.result().info.converged)


def test_padding_and_fixed_compiled_shapes():
    """3 requests pad to capacity 4; repeat traffic reuses the program."""
    rng = np.random.default_rng(1)
    svc = SolveService(cache=None)
    d = 6
    for _ in range(3):
        futs = [svc.submit(_spd(rng, d), rng.standard_normal(d),
                           positive_definite=True) for _ in range(3)]
        svc.flush()
        for f in futs:
            assert f.result().bucket_capacity == 4
    assert svc.metrics["padded"] == 3 * 1
    assert svc.metrics["compiled"] == 1            # ONE program for all rounds
    assert svc.occupancy == pytest.approx(0.75)


def test_oversized_bucket_splits_into_chunks():
    rng = np.random.default_rng(2)
    svc = SolveService(max_batch=4, cache=None)
    futs = [svc.submit(_spd(rng, 5), rng.standard_normal(5),
                       positive_definite=True) for _ in range(10)]
    assert svc.flush() == 10
    assert svc.metrics["dispatches"] == 3          # 4 + 4 + 2
    assert svc.metrics["compiled"] == 2            # cap=4 and cap=2 programs
    assert all(bool(f.result().info.converged) for f in futs)


# -- per-request diagnostics -------------------------------------------------

def test_solveinfo_parity_with_solo_route_solve():
    """A bucketed request's SolveInfo slice matches its solo solve."""
    rng = np.random.default_rng(3)
    d = 12
    systems = [(_spd(rng, d), rng.standard_normal(d)) for _ in range(5)]
    svc = SolveService(cache=None, solve="dense_gmres")
    futs = [svc.submit(A, b, positive_definite=True) for A, b in systems]
    svc.flush()
    for (A, b), fut in zip(systems, futs):
        r = fut.result()
        op = DenseOperator(jnp.asarray(A), symmetric=True,
                           positive_definite=True)
        x_solo, info = ls.route_solve("dense_gmres", op, jnp.asarray(b),
                                      return_info=True)
        np.testing.assert_allclose(np.asarray(r.x), np.asarray(x_solo),
                                   atol=1e-4)
        assert int(r.info.iterations) == int(np.asarray(info.iterations))
        assert bool(r.info.converged)
        assert r.queue_time >= 0.0 and r.solve_time > 0.0


def test_hypergrad_request_matches_root_vjp():
    def F(x, theta):
        return x * (1.0 + theta) - jnp.arange(1.0, 7.0)

    theta = jnp.asarray(0.3)
    x_star = jnp.arange(1.0, 7.0) / 1.3
    ct = jnp.asarray(np.random.default_rng(4).standard_normal(6))
    svc = SolveService()
    fut = svc.submit_hypergrad(F, x_star, (theta,), ct, solve="cg")
    svc.flush()
    (got,) = fut.result().x
    (want,) = root_vjp(F, x_star, (theta,), ct, solve="cg")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_spec_routing_overrides_and_rejections():
    svc = SolveService(cache=None)
    spec = ImplicitDiffSpec(solve="cg", tol=1e-9)
    fut = svc.submit(3.0 * np.eye(4), np.ones(4), positive_definite=True,
                     spec=spec, maxiter=77)
    svc.flush()
    key = fut.result()
    assert key.info is not None
    (bkey, _cap), = svc._compiled.keys()
    assert (bkey.solver, bkey.tol, bkey.maxiter) == ("cg", 1e-9, 77)
    with pytest.raises(ValueError, match="custom"):
        svc.submit(np.eye(3), np.ones(3), solve=lambda mv, b: b)
    with pytest.raises(ValueError, match="precond"):
        svc.submit(np.eye(3), np.ones(3), precond=lambda v: v)
    with pytest.raises(ValueError, match="MAX_DENSE_DIM"):
        svc.submit(np.eye(600), np.ones(600))


def test_explicit_none_overrides_spec_precond():
    """precond=None is a real override, not 'defer to the spec'."""
    svc = SolveService(cache=None)
    spec = ImplicitDiffSpec(solve="cg", precond="jacobi")
    svc.submit(3.0 * np.eye(4), np.ones(4), positive_definite=True,
               spec=spec)
    svc.submit(3.0 * np.eye(4), np.ones(4), positive_definite=True,
               spec=spec, precond=None)
    assert [r.key.precond for r in svc._queue] == ["jacobi", None]


def test_bad_routing_fails_fast_at_admission():
    """Unroutable requests raise in submit(), never inside a dispatch."""
    svc = SolveService(cache=None)
    upper = np.triu(np.ones((4, 4)))               # detectably nonsymmetric
    with pytest.raises(ValueError, match="symmetric-only"):
        svc.submit(upper, np.ones(4), solve="cg")
    with pytest.raises(ValueError, match="symmetric-only"):
        svc.submit(np.eye(4), np.ones(4), symmetric=False,
                   solve="pallas_cg")
    with pytest.raises(ValueError, match="unknown linear solver"):
        svc.submit(np.eye(4), np.ones(4), solve="no_such_solver")
    assert svc.metrics["requests"] == 0            # nothing was enqueued


# -- warm-start cache --------------------------------------------------------

def test_warm_start_hits_and_counters():
    rng = np.random.default_rng(5)
    A, b = _spd(rng, 8), rng.standard_normal(8)
    svc = SolveService()
    cold = svc.submit(A, b, positive_definite=True)
    svc.flush()
    warm = svc.submit(A, b, positive_definite=True)
    svc.flush()
    assert not cold.result().warm_start and warm.result().warm_start
    assert int(warm.result().info.iterations) == 0     # exact repeat
    assert (svc.cache.hits, svc.cache.misses) == (1, 1)
    assert svc.hit_rate == 0.5
    # nearby problem (drift below qtol) also hits
    near = svc.submit(A * (1 + 1e-9), b, positive_definite=True)
    svc.flush()
    assert near.result().warm_start


def test_cache_eviction_under_capacity_pressure():
    rng = np.random.default_rng(6)
    cache = WarmStartCache(capacity=4)
    svc = SolveService(cache=cache)
    systems = [(_spd(rng, 6), rng.standard_normal(6)) for _ in range(8)]
    for A, b in systems:
        svc.submit(A, b, positive_definite=True)
    svc.flush()
    assert len(cache) == 4                      # LRU kept the newest 4
    assert cache.evictions == 4
    # the evicted half misses again; the resident half hits
    futs = [svc.submit(A, b, positive_definite=True) for A, b in systems]
    svc.flush()
    warm_flags = [f.result().warm_start for f in futs]
    assert warm_flags[4:] == [True] * 4
    assert warm_flags[:4] == [False] * 4
    assert svc.metrics["cache_evictions"] == cache.evictions


def test_cache_respects_bucket_key():
    """Identical numbers under different routing never share warm starts."""
    cache = WarmStartCache()
    k1 = BucketKey(4, "cg", None, True, True, "float32", 1e-6, 100, 0.0)
    k2 = k1._replace(solver="dense_gmres")
    A, b = np.eye(4), np.ones(4)
    assert cache.fingerprint(A, b, k1) != cache.fingerprint(A, b, k2)


def test_warm_start_disabled_per_request_and_per_service():
    A, b = 2.0 * np.eye(4), np.ones(4)
    svc = SolveService()
    svc.submit(A, b, positive_definite=True); svc.flush()
    f = svc.submit(A, b, positive_definite=True, warm_start=False)
    svc.flush()
    assert not f.result().warm_start
    svc_off = SolveService(cache=None)
    g = svc_off.submit(A, b, positive_definite=True)
    svc_off.flush()
    assert not g.result().warm_start and svc_off.hit_rate == 0.0


# -- fault isolation ---------------------------------------------------------

@pytest.fixture
def _boom_solver():
    """A registered solver that always blows up inside dispatch."""
    name = "_svc_test_boom"

    def boom(matvec, b, **kwargs):
        raise RuntimeError("kaboom")

    ls.register_solver(name, boom)
    try:
        yield name
    finally:
        ls._REGISTRY.pop(name, None)


def test_dispatch_failure_is_fault_isolated(_boom_solver):
    """A poisoned bucket fails its own futures; other buckets still run."""
    svc = SolveService(cache=None)
    bad = svc.submit(np.eye(4), np.ones(4), solve=_boom_solver)
    good = svc.submit(2.0 * np.eye(6), np.ones(6), positive_definite=True)
    assert svc.flush() == 2                    # flush itself never raises
    with pytest.raises(RuntimeError, match="kaboom"):
        bad.result(timeout=5.0)
    assert bool(good.result(timeout=5.0).info.converged)


def test_scheduler_thread_survives_dispatch_failure(_boom_solver):
    """In start() mode a failing bucket must not kill the scheduler."""
    svc = SolveService(cache=None)
    svc.start(interval=0.001)
    try:
        bad = svc.submit(np.eye(4), np.ones(4), solve=_boom_solver)
        with pytest.raises(RuntimeError, match="kaboom"):
            bad.result(timeout=30.0)
        good = svc.submit(2.0 * np.eye(4), np.ones(4),
                          positive_definite=True)
        assert bool(good.result(timeout=30.0).info.converged)
    finally:
        svc.stop()


# -- concurrency -------------------------------------------------------------

def test_background_scheduler_thread():
    rng = np.random.default_rng(7)
    svc = SolveService()
    svc.start(interval=0.001)
    try:
        futs = [svc.submit(_spd(rng, 8), rng.standard_normal(8),
                           positive_definite=True) for _ in range(12)]
        svc.drain(timeout=30.0)
        assert all(f.done() for f in futs)     # drain => futures resolved
        results = [f.result(timeout=30.0) for f in futs]
    finally:
        svc.stop()
    assert all(bool(r.info.converged) for r in results)
    assert svc.metrics["requests"] == 12


def test_concurrent_submitters():
    rng = np.random.default_rng(8)
    svc = SolveService(cache=None)
    out = []

    def client(seed):
        r = np.random.default_rng(seed)
        f = svc.submit(_spd(r, 8), r.standard_normal(8),
                       positive_definite=True)
        out.append(f)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert svc.flush() == 8
    results = [f.result() for f in out]
    assert all(bool(r.info.converged) for r in results)
    assert len({r.uid for r in results}) == 8  # uids unique under races
