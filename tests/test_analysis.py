"""Tests for the loop-aware HLO analyzer and roofline model — these numbers
are the §Roofline deliverable, so they get their own unit coverage."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import hlo, roofline
from repro.launch import shapes as shp
from repro import configs


SYNTH_HLO = """
HloModule test, num_partitions=4

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %y = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%y), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %j = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%j, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[8,16]) -> f32[8,16] {
  %x = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]{1,0}) tuple(%zero, %x)
  %loop = (s32[], f32[8,16]{1,0}) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%loop), index=1
}
"""


class TestHLOAnalyzer:

    def test_trip_count_multiplies_loop_body(self):
        c = hlo.analyze_module(SYNTH_HLO)
        # dot: 2*8*16*16 = 4096 flops, x10 trips
        assert c.flops == pytest.approx(4096 * 10)
        # all-reduce operand: 8*16*4 bytes = 512, ×10
        assert c.collective_bytes == pytest.approx(512 * 10)
        assert c.collective_ops["all-reduce"] == 10

    def test_against_real_compiled_module(self):
        """End-to-end on a real jit: known matmul flops inside a scan."""
        def f(w, x):
            def body(h, wl):
                return h @ wl, None
            h, _ = jax.lax.scan(body, x, w)
            return h

        L, d = 7, 32
        w = jnp.zeros((L, d, d))
        x = jnp.zeros((4, d))
        text = jax.jit(f).lower(w, x).compile().as_text()
        c = hlo.analyze_module(text)
        expected = 2 * 4 * d * d * L          # 2·M·N·K per layer × L
        assert c.flops == pytest.approx(expected, rel=0.01)

    def test_collective_kinds_counted(self):
        text = SYNTH_HLO.replace("all-reduce", "reduce-scatter")
        c = hlo.analyze_module(text)
        assert "reduce-scatter" in c.per_collective
        assert c.per_collective["reduce-scatter"] > 0

    def test_fusion_boundary_bytes(self):
        """Fusion internals don't count toward HBM traffic (TPU model)."""
        def f(x):
            return jnp.sum(jnp.tanh(x) * 2.0 + 1.0)

        x = jnp.zeros((128, 128))
        text = jax.jit(f).lower(x).compile().as_text()
        c = hlo.analyze_module(text)
        # traffic should be O(input + output), not O(#elementwise ops × size)
        assert c.hbm_bytes < 6 * 128 * 128 * 8   # f64 under tests


class TestRoofline:

    def test_terms_and_dominant(self):
        t = roofline.analyze({"flops": 197e12, "bytes accessed": 819e9 * 2},
                             coll_bytes=50e9 * 3, chips=256,
                             model_flops=197e12 * 256 * 0.5)
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(2.0)
        assert t.collective_s == pytest.approx(3.0)
        assert t.dominant == "collective"
        assert t.step_time_s == pytest.approx(3.0)
        assert t.mfu == pytest.approx(0.5 / 3.0)

    def test_model_flops(self):
        assert roofline.model_flops_train(1e9, 1e6) == 6e15
        assert roofline.model_flops_decode(1e9, 128) == pytest.approx(
            2 * 1e9 * 128)


class TestShapeCells:

    def test_40_cells_defined(self):
        assert len(configs.names()) * len(shp.SHAPES) == 40

    def test_skip_rules(self):
        hub = configs.get("hubert-xlarge")
        assert shp.skip_reason(hub, "decode_32k")
        assert shp.skip_reason(hub, "long_500k")
        assert shp.skip_reason(hub, "train_4k") is None
        llama = configs.get("llama3-405b")
        assert shp.skip_reason(llama, "long_500k")
        assert shp.skip_reason(llama, "decode_32k") is None
        for a in ["rwkv6-3b", "zamba2-7b"]:
            assert shp.skip_reason(configs.get(a), "long_500k") is None

    def test_runnable_cell_count(self):
        total = sum(len(shp.runnable_cells(configs.get(a)))
                    for a in configs.names())
        assert total == 31     # 7 dense/moe/vlm ×3 + hubert ×2 + 2 ssm ×4

    def test_input_specs_no_allocation(self):
        for arch in configs.names():
            cfg = configs.get(arch)
            for shape in shp.runnable_cells(cfg):
                specs = shp.input_specs(cfg, shape)
                for v in specs.values():
                    assert isinstance(v, jax.ShapeDtypeStruct)

    def test_tokens_per_step(self):
        cfg = configs.get("llama3-405b")
        assert shp.tokens_per_step(cfg, "train_4k") == 256 * 4096
        assert shp.tokens_per_step(cfg, "decode_32k") == 128

    def test_param_counts_match_published_scale(self):
        """Sanity: analytic param counts are in the advertised ballpark."""
        expected = {
            "llama3-405b": (380e9, 430e9),
            "nemotron-4-340b": (320e9, 360e9),
            "qwen2.5-32b": (29e9, 36e9),
            "qwen1.5-4b": (3e9, 5e9),
            "deepseek-v2-236b": (200e9, 260e9),
            "rwkv6-3b": (2.5e9, 4e9),
            "zamba2-7b": (6e9, 9e9),
            "hubert-xlarge": (0.8e9, 1.3e9),
        }
        for arch, (lo, hi) in expected.items():
            n = configs.get(arch).param_count()
            assert lo < n < hi, (arch, n)
