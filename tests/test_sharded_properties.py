"""Hypothesis property tests for the sharded-solve subsystem.

Hard-gated like the PR 4 property suites: ``require_hypothesis()`` skips
locally without hypothesis but FAILS under ``REPRO_REQUIRE_HYPOTHESIS=1``
(both CI lanes set it), so these can never be silently dropped.  Like
``test_sharded_operators.py``, everything runs in-process over however
many devices the process sees (8 in the forced-host-device CI lane).
"""
import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from conftest import require_hypothesis
from repro.core import operators as ops
from repro.distributed.sharded_operators import ShardedOperator
from repro.launch.mesh import make_solve_mesh

require_hypothesis()   # hard-fails under REPRO_REQUIRE_HYPOTHESIS (CI)
from hypothesis import given, settings, strategies as st


B = 16          # divisible by 1/2/4/8 local devices

_leaf_shapes = st.lists(
    st.tuples(st.integers(1, 3), st.integers(1, 3)), min_size=1, max_size=3)


def _batched_spd(rng, B, d, shift=0.5):
    C = jnp.asarray(rng.randn(B, d, d)) / np.sqrt(d)
    return jnp.einsum("bji,bjk->bik", C, C) + shift * jnp.eye(d)


class TestRavelViewRoundTrip:

    @given(shapes=_leaf_shapes, batched=st.booleans(), data=st.data())
    @settings(max_examples=20, deadline=None)
    def test_round_trip_and_flat_matvec(self, shapes, batched, data):
        """``to_tree`` inverts the ravel for any pytree layout, batched or
        not, and the flat (B, d) matvec agrees with the tree matvec."""
        rng = np.random.RandomState(data.draw(st.integers(0, 2 ** 31)))
        lead = (4,) if batched else ()
        tree = {f"k{i}": jnp.asarray(rng.randn(*(lead + s)))
                for i, s in enumerate(shapes)}
        scale = {k: jnp.asarray(rng.randn(*leaf.shape))
                 for k, leaf in tree.items()}
        mv = lambda t: jax.tree_util.tree_map(lambda a, s: a * s, t, scale)
        view = ops.ravel_view(mv, tree, batch_ndim=1 if batched else 0)
        assert view.batched == batched
        round_tripped = view.to_tree(view.b)
        for k in tree:
            np.testing.assert_allclose(round_tripped[k], tree[k],
                                       rtol=1e-12)
        flat_out = view.to_tree(view.mv(view.b))
        tree_out = mv(tree)
        for k in tree:
            np.testing.assert_allclose(flat_out[k], tree_out[k], rtol=1e-10)

    @given(shapes=_leaf_shapes, data=st.data())
    @settings(max_examples=10, deadline=None)
    def test_operator_ravel_view_matches_free_function(self, shapes, data):
        rng = np.random.RandomState(data.draw(st.integers(0, 2 ** 31)))
        tree = {f"k{i}": jnp.asarray(rng.randn(*s))
                for i, s in enumerate(shapes)}
        scale = {k: 1.0 + jnp.asarray(rng.rand(*leaf.shape))
                 for k, leaf in tree.items()}
        mv = lambda t: jax.tree_util.tree_map(lambda a, s: a * s, t, scale)
        op = ops.FunctionOperator(mv, tree)
        view = op.ravel_view(tree)
        free = ops.ravel_view(mv, tree, 0)
        np.testing.assert_allclose(view.mv(view.b), free.mv(free.b),
                                   rtol=1e-12)


class TestShardedMatvecEquivalence:

    @given(d=st.integers(1, 5), extra=st.integers(1, 3), data=st.data())
    @settings(max_examples=15, deadline=None)
    def test_matches_single_device_under_vmap(self, d, extra, data):
        """``ShardedOperator.matvec`` == the base operator's matvec,
        including under ``jax.vmap`` over an extra leading axis
        (shard_map's batching rule keeps placement out of the math)."""
        rng = np.random.RandomState(data.draw(st.integers(0, 2 ** 31)))
        mesh = make_solve_mesh()
        A = _batched_spd(rng, B, d)
        base = ops.DenseOperator(A, positive_definite=True)
        sh = ShardedOperator(base, mesh, P("data", None))
        v = jnp.asarray(rng.randn(B, d))
        np.testing.assert_allclose(sh.matvec(v), base.matvec(v), rtol=1e-10)
        vb = jnp.asarray(rng.randn(extra, B, d))
        np.testing.assert_allclose(jax.vmap(sh.matvec)(vb),
                                   jax.vmap(base.matvec)(vb), rtol=1e-10)

    @given(d=st.integers(1, 4), data=st.data())
    @settings(max_examples=10, deadline=None)
    def test_rmatvec_and_transpose_consistency(self, d, data):
        rng = np.random.RandomState(data.draw(st.integers(0, 2 ** 31)))
        mesh = make_solve_mesh()
        A = jnp.asarray(rng.randn(B, d, d))
        base = ops.DenseOperator(A, symmetric=False)
        sh = ShardedOperator(base, mesh, P("data", None))
        v = jnp.asarray(rng.randn(B, d))
        np.testing.assert_allclose(sh.rmatvec(v), base.rmatvec(v),
                                   rtol=1e-10)
        np.testing.assert_allclose(sh.T.matvec(v), sh.rmatvec(v),
                                   rtol=1e-12)
