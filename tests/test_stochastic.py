"""Stochastic inner solvers: convergence, hypergrad parity, determinism,
batched-backward contract, and sampled-operator properties."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import require_hypothesis
from repro.core import GradientDescent, SampledJacobianOperator, diff_api
from repro.core import linear_solve as ls
from repro.core import bilevel
from repro.data.pipeline import DataConfig, PrefetchIterator, SyntheticLMStream
from repro.stochastic import (SGD, Adam, MinibatchSampler, MomentumSGD,
                              run_stochastic)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# shared problem: strongly-convex ridge least-squares
# ---------------------------------------------------------------------------

def _ridge_data(rng, n=256, d=8, noise=0.1):
    kx, kw, ke = jax.random.split(rng, 3)
    X = jax.random.normal(kx, (n, d)) / jnp.sqrt(d)
    w_true = jax.random.normal(kw, (d,))
    y = X @ w_true + noise * jax.random.normal(ke, (n,))
    return X, y


def _ridge_fun(w, batch, lam):
    """Per-example mean (the expectation contract) + ridge."""
    Xb, yb = batch
    r = Xb @ w - yb
    return 0.5 * jnp.mean(r ** 2) + 0.5 * lam * jnp.sum(w ** 2)


def _ridge_closed_form(X, y, lam):
    n, d = X.shape
    return jnp.linalg.solve(X.T @ X / n + lam * jnp.eye(d), X.T @ y / n)


def _sgd(sampler, **kw):
    kw.setdefault("stepsize", lambda k: 0.5 / (1.0 + 0.02 * k))
    kw.setdefault("epochs", 3)
    kw.setdefault("averaging", "polyak")
    kw.setdefault("average_from", sampler.num_batches)
    return SGD(_ridge_fun, sampler=sampler, **kw)


# ---------------------------------------------------------------------------
# fixed-point convergence
# ---------------------------------------------------------------------------

class TestFixedPointConvergence:
    """SGD/Adam with averaging land at the full-batch fixed point."""

    def test_sgd_polyak_reaches_closed_form(self, rng):
        X, y = _ridge_data(rng)
        lam = 0.1
        sampler = MinibatchSampler(data=(X, y), batch_size=32, seed=0)
        solver = _sgd(sampler, epochs=25, average_from=100)
        w, info = run_stochastic(solver, jnp.zeros(X.shape[1]), lam)
        w_star = _ridge_closed_form(X, y, lam)
        assert float(jnp.linalg.norm(w - w_star)) < 0.05
        # OptInfo.error is the FULL-batch residual at the averaged iterate
        g_full = jax.grad(_ridge_fun)(w, (X, y), lam)
        np.testing.assert_allclose(float(info.error),
                                   float(jnp.linalg.norm(g_full)), rtol=1e-6)

    def test_adam_reaches_closed_form(self, rng):
        X, y = _ridge_data(rng)
        lam = 0.1
        sampler = MinibatchSampler(data=(X, y), batch_size=32, seed=0)
        solver = Adam(_ridge_fun, sampler=sampler, stepsize=2e-2, epochs=30,
                      averaging="polyak", average_from=120)
        w, _ = run_stochastic(solver, jnp.zeros(X.shape[1]), lam)
        w_star = _ridge_closed_form(X, y, lam)
        assert float(jnp.linalg.norm(w - w_star)) < 0.05

    def test_momentum_sgd_decreases_objective(self, rng):
        X, y = _ridge_data(rng)
        lam = 0.1
        sampler = MinibatchSampler(data=(X, y), batch_size=32, seed=0)
        solver = MomentumSGD(_ridge_fun, sampler=sampler, stepsize=5e-2,
                             momentum=0.9, epochs=4)
        w0 = jnp.zeros(X.shape[1])
        w, _ = run_stochastic(solver, w0, lam)
        assert float(_ridge_fun(w, (X, y), lam)) \
            < float(_ridge_fun(w0, (X, y), lam))

    def test_epoch_and_step_budgets(self, rng):
        X, y = _ridge_data(rng, n=64)
        sampler = MinibatchSampler(data=(X, y), batch_size=16, seed=0)
        assert _sgd(sampler, epochs=3).num_steps() == 12
        assert _sgd(sampler, epochs=None, steps=7).num_steps() == 7
        assert SGD(_ridge_fun, sampler=sampler).num_steps() == 4  # 1 epoch


# ---------------------------------------------------------------------------
# hypergradient parity vs the full-batch reference
# ---------------------------------------------------------------------------

class TestHypergradParity:
    """Implicit diff at the averaged iterate vs full-batch root_vjp."""

    def _reference(self, X, y, w0, lam):
        full = GradientDescent(lambda w, t: _ridge_fun(w, (X, y), t),
                               stepsize=0.5, maxiter=400, tol=1e-12,
                               solve="cg")

        def loss(t):
            w, _ = full.run(w0, t)
            return jnp.sum(w ** 2)

        return jax.grad(loss)(jnp.asarray(lam))

    def test_stochastic_matches_full_batch_hypergrad(self, rng):
        X, y = _ridge_data(rng)
        lam, w0 = 0.1, jnp.zeros(X.shape[1])
        g_ref = self._reference(X, y, w0, lam)
        sampler = MinibatchSampler(data=(X, y), batch_size=32, seed=0)
        # converged averaged iterate; class-default sampled neumann_k+jacobi
        solver = _sgd(sampler, epochs=25, average_from=100,
                      backward_iters=10)

        def loss(t):
            w, _ = solver.run(w0, t)
            return jnp.sum(w ** 2)

        g = jax.grad(loss)(jnp.asarray(lam))
        # variance-scaled tolerance: the sampled operator averages
        # backward_batches minibatch Hessians (relative spread ~1/√k);
        # measured parity on this seed is ~5e-3
        tol = 0.5 / np.sqrt(solver.backward_batches)
        assert abs(float(g - g_ref)) / abs(float(g_ref)) < tol

    def test_full_batch_sampling_is_exact_contract(self, rng):
        """B=n and one backward batch ⇒ the sampled operator IS the
        full-batch operator: root_vjp through the factory must agree with
        the plain full-batch root_vjp to solver precision."""
        X, y = _ridge_data(rng, n=64)
        lam = 0.2
        n, d = X.shape
        w_star = _ridge_closed_form(X, y, lam)
        sampler = MinibatchSampler(data=(X, y), batch_size=n, seed=0)
        solver = SGD(_ridge_fun, sampler=sampler, backward_batches=1,
                     backward="exact", precond=None)
        spec = solver.diff_spec()
        assert spec.system_operator is not None

        def residual(w, t):
            return jax.grad(_ridge_fun)(w, (X, y), t)

        ct = jax.random.normal(jax.random.fold_in(rng, 7), (d,))
        g_sampled = diff_api.root_vjp(residual, w_star, (jnp.asarray(lam),),
                                      ct, solve="cg", tol=1e-12,
                                      system_operator=spec.system_operator)
        g_full = diff_api.root_vjp(residual, w_star, (jnp.asarray(lam),),
                                   ct, solve="cg", tol=1e-12)
        np.testing.assert_allclose(np.asarray(g_sampled[0]),
                                   np.asarray(g_full[0]), rtol=1e-6)

    def test_jvp_mode_through_sampled_operator(self, rng):
        X, y = _ridge_data(rng)
        lam, w0 = 0.1, jnp.zeros(X.shape[1])
        sampler = MinibatchSampler(data=(X, y), batch_size=32, seed=0)
        solver = _sgd(sampler)

        def sol(t):
            return solver.run(w0, t)[0]

        _, dw = jax.jvp(sol, (jnp.asarray(lam),), (jnp.asarray(1.0),))
        g = jax.grad(lambda t: jnp.sum(sol(t) ** 2))(jnp.asarray(lam))
        # chain rule consistency between the two modes at the same point
        w = sol(jnp.asarray(lam))
        np.testing.assert_allclose(float(2.0 * w @ dw), float(g), rtol=1e-4)

    def test_bilevel_surfaces_stochastic_error_estimate(self, rng):
        """solve_bilevel reports hypergrad_error_estimate for a stochastic
        inner solver even under backward="exact" (sampled operator)."""
        X, y = _ridge_data(rng, n=64)
        sampler = MinibatchSampler(data=(X, y), batch_size=16, seed=0)
        solver = _sgd(sampler, epochs=2, backward="exact", precond=None)
        sol = bilevel.solve_bilevel(
            lambda w, t: jnp.sum(w ** 2), solver, jnp.asarray(0.1),
            jnp.zeros(X.shape[1]), outer_steps=2, outer_lr=1e-2)
        est = sol.inner_info.hypergrad_error_estimate
        assert est is not None
        assert float(est) < 0.5          # honest but small on this problem


# ---------------------------------------------------------------------------
# (seed, step) determinism + restart
# ---------------------------------------------------------------------------

class TestDeterminism:
    def test_sampler_is_pure_in_seed_and_step(self, rng):
        X, y = _ridge_data(rng, n=64)
        s1 = MinibatchSampler(data=(X, y), batch_size=16, seed=3)
        s2 = MinibatchSampler(data=(X, y), batch_size=16, seed=3)
        for step in (0, 1, 17, 1000):
            np.testing.assert_array_equal(s1.indices(step), s2.indices(step))
        np.testing.assert_array_equal(
            s1.batch_indices(5, 4), np.stack([s1.indices(5 + i)
                                              for i in range(4)]))
        s3 = MinibatchSampler(data=(X, y), batch_size=16, seed=4)
        assert not np.array_equal(s1.indices(0), s3.indices(0))
        # backward stream: deterministic too, decorrelated from forward
        np.testing.assert_array_equal(np.asarray(s1.backward_batches(3)[0]),
                                      np.asarray(s2.backward_batches(3)[0]))
        assert not np.array_equal(
            np.asarray(s1.backward_batches(1)[0][0]),
            np.asarray(s1.gather(s1.indices(0))[0]))

    def test_bit_identical_trajectory(self, rng):
        X, y = _ridge_data(rng)
        sampler = MinibatchSampler(data=(X, y), batch_size=32, seed=0)
        solver = _sgd(sampler)
        w1, _ = run_stochastic(solver, jnp.zeros(X.shape[1]), 0.1)
        w2, _ = run_stochastic(solver, jnp.zeros(X.shape[1]), 0.1)
        np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))

    def test_restart_at_step_k_replays_tail(self, rng):
        """Stopping at step k and restarting with start_step=k replays the
        full run bit for bit (schedule included, via init_state)."""
        from repro.stochastic.solvers import SGDState
        X, y = _ridge_data(rng)
        sampler = MinibatchSampler(data=(X, y), batch_size=32, seed=0)
        # "last" averaging so the returned iterate IS the trajectory point
        solver = SGD(_ridge_fun, sampler=sampler,
                     stepsize=lambda k: 0.5 / (1.0 + 0.1 * k),
                     averaging="last")
        T, k = 12, 5
        w0 = jnp.zeros(X.shape[1])
        w_full, _ = run_stochastic(solver, w0, 0.1, steps=T)
        w_mid, _ = run_stochastic(solver, w0, 0.1, steps=k)
        w_tail, _ = run_stochastic(
            solver, w_mid, 0.1, steps=T - k, start_step=k,
            init_state=SGDState(jnp.asarray(k), jnp.asarray(jnp.inf)))
        np.testing.assert_array_equal(np.asarray(w_full), np.asarray(w_tail))

    def test_prefetch_iterator_seek_and_close(self):
        cfg = DataConfig(vocab_size=32, seq_len=4, global_batch=4, seed=1)
        stream = SyntheticLMStream(cfg)
        with PrefetchIterator(stream, daemon=False) as it:
            step, (xb, _) = next(it)
            assert step == 0
            np.testing.assert_array_equal(xb, stream.batch_at(0)[0])
            # seekable random access, then sequential continuation
            np.testing.assert_array_equal(it.batch_at(9)[1],
                                          stream.batch_at(9)[1])
            step, _ = next(it)
            assert step == 10
            np.testing.assert_array_equal(it.batch_at(2)[0],
                                          stream.batch_at(2)[0])
        assert not it.thread.is_alive()
        it.close()                       # idempotent

    def test_sampler_from_stream_picks_up_seed(self):
        cfg = DataConfig(vocab_size=32, seq_len=4, global_batch=8, seed=5)
        stream = SyntheticLMStream(cfg)
        s = MinibatchSampler.from_stream(stream, num_steps=4)
        assert s.seed == 5
        assert s.num_examples == 32
        assert s.batch_size == 8


# ---------------------------------------------------------------------------
# vmap executes ONE batched backward (PR 2/3 contract)
# ---------------------------------------------------------------------------

class TestVmapCounting:
    def test_vmap_stochastic_hypergrad_one_batched_solve(self, rng):
        X, y = _ridge_data(rng, n=64)
        sampler = MinibatchSampler(data=(X, y), batch_size=16, seed=0)
        traced, executed = [], []

        def counting_cg(matvec, b, **kw):
            traced.append(1)
            jax.debug.callback(lambda _: executed.append(1), jnp.zeros(()))
            return ls.solve_cg(matvec, b, **kw)

        ls.register_solver("counting_cg_sto", counting_cg,
                           symmetric_only=True, supports_precond=True)
        try:
            solver = _sgd(sampler, epochs=1, backward="exact",
                          solve="counting_cg_sto", precond=None)
            w0 = jnp.zeros(X.shape[1])

            def loss(t):
                w, _ = solver.run(w0, t)
                return jnp.sum(w ** 2)

            lams = jnp.array([0.05, 0.1, 0.2, 0.4])
            executed.clear()
            g_vmap = jax.vmap(jax.grad(loss))(lams)
            jax.effects_barrier()
            assert len(executed) == 1, \
                f"expected ONE batched backward solve, ran {len(executed)}"
            assert len(traced) == 2      # one template per autodiff direction
            executed.clear()
            g_loop = jnp.stack([jax.grad(loss)(t) for t in lams])
            jax.effects_barrier()
            assert len(executed) == len(lams)
        finally:
            ls._REGISTRY.pop("counting_cg_sto", None)
        np.testing.assert_allclose(np.asarray(g_vmap), np.asarray(g_loop),
                                   rtol=1e-8)


# ---------------------------------------------------------------------------
# SampledJacobianOperator properties
# ---------------------------------------------------------------------------

def _sampled_vs_full_errors(seed, d, ks, B=16, n=256):
    """‖sampled_k matvec − full matvec‖ for each k, plus partition check."""
    key = jax.random.PRNGKey(seed)
    X, y = _ridge_data(key, n=n, d=d)
    lam = 0.1
    w = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    v = jax.random.normal(jax.random.fold_in(key, 2), (d,))

    def residual(x, batch):
        return jax.grad(_ridge_fun)(x, batch, lam)

    full = jax.jvp(lambda x: residual(x, (X, y)), (w,), (v,))[1]
    sampler = MinibatchSampler(data=(X, y), batch_size=B, seed=seed)
    errs = []
    for k in ks:
        op = SampledJacobianOperator(residual, w,
                                     sampler.backward_batches(k),
                                     negate=True, symmetric=True)
        errs.append(float(jnp.linalg.norm(op.matvec(v) - (-full))))
    # equal-size partition of the dataset ⇒ the average IS the full matvec
    perm = np.random.default_rng(seed).permutation(n)
    part = jax.tree_util.tree_map(
        lambda leaf: jnp.asarray(np.asarray(leaf)[perm]).reshape(
            (n // B, B) + leaf.shape[1:]), (X, y))
    op_part = SampledJacobianOperator(residual, w, part, negate=True,
                                      symmetric=True)
    part_err = float(jnp.linalg.norm(op_part.matvec(v) - (-full)))
    return errs, part_err, float(jnp.linalg.norm(full))


class TestSampledOperator:
    def test_matvec_converges_with_k_fixed_seed(self, rng):
        errs, part_err, scale = _sampled_vs_full_errors(0, d=8, ks=(1, 4, 16))
        assert part_err < 1e-9 * max(scale, 1.0)
        assert errs[-1] < errs[0]        # variance shrinks with k
        assert errs[-1] < 0.25 * scale

    def test_rmatvec_equals_matvec_when_symmetric(self, rng):
        X, y = _ridge_data(rng, n=64)
        sampler = MinibatchSampler(data=(X, y), batch_size=16, seed=0)
        w = jax.random.normal(rng, (X.shape[1],))
        v = jax.random.normal(jax.random.fold_in(rng, 1), (X.shape[1],))

        def residual(x, batch):
            return jax.grad(_ridge_fun)(x, batch, 0.1)

        op = SampledJacobianOperator(residual, w,
                                     sampler.backward_batches(4),
                                     negate=True, symmetric=True)
        np.testing.assert_allclose(np.asarray(op.matvec(v)),
                                   np.asarray(op.rmatvec(v)), rtol=1e-10)

    def test_spec_guard_system_operator_vs_sharding(self):
        with pytest.raises(ValueError, match="mutually exclusive"):
            diff_api.ImplicitDiffSpec(
                optimality_fun=lambda x, t: x - t,
                system_operator=lambda x, t, symmetric: None,
                sharding=object())


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2 ** 16),
           d=st.integers(min_value=2, max_value=12))
    def test_sampled_matvec_property(seed, d):
        """Property: exact on an equal-size partition; the k-sample average
        tightens toward the full-batch matvec as k grows."""
        errs, part_err, scale = _sampled_vs_full_errors(
            seed, d=d, ks=(1, 16))
        assert part_err < 1e-9 * max(scale, 1.0)
        assert errs[1] <= errs[0] + 0.05 * scale   # noise-tolerant decrease
else:
    def test_sampled_matvec_property():
        require_hypothesis()    # skips locally, hard-fails in the CI lane
        raise AssertionError("unreachable")


# ---------------------------------------------------------------------------
# slow lane: data-scale smoke (the benchmark's Part B, minimally)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_data_scale_smoke():
    """The LM data-scale demo end to end: dataset ≥ 64× minibatch, cosine
    gate and decreasing validation loss (delegates to the benchmark)."""
    from benchmarks import stochastic_bilevel
    rows = []
    stochastic_bilevel._lm_datascale(
        lambda name, t, derived: rows.append((name, t, derived)),
        outer_steps=3)
    assert rows and "cos=" in rows[0][2]
    if "REPRO_KEEP_OUT" in os.environ:   # debugging hook
        print(rows)
