"""Matrix-free linear solver tests, incl. hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import require_hypothesis

require_hypothesis()   # hard-fails under REPRO_REQUIRE_HYPOTHESIS (CI)
from hypothesis import given, settings, strategies as st

from repro.core import linear_solve as ls


def _spd(key, d, cond=10.0):
    A = jax.random.normal(key, (d, d))
    A = A @ A.T
    return A + (jnp.trace(A) / d / cond) * jnp.eye(d)


@pytest.mark.parametrize("name", ["cg", "normal_cg", "bicgstab", "gmres",
                                  "lu"])
def test_spd_solve(rng, name):
    A = _spd(rng, 12)
    b = jax.random.normal(jax.random.fold_in(rng, 1), (12,))
    x = ls.get_solver(name)(lambda v: A @ v, b, tol=1e-12)
    np.testing.assert_allclose(A @ x, b, atol=1e-6)


@pytest.mark.parametrize("name", ["normal_cg", "bicgstab", "gmres"])
def test_nonsymmetric_solve(rng, name):
    A = jax.random.normal(rng, (10, 10)) + 5 * jnp.eye(10)
    b = jax.random.normal(jax.random.fold_in(rng, 1), (10,))
    x = ls.get_solver(name)(lambda v: A @ v, b, tol=1e-12)
    np.testing.assert_allclose(A @ x, b, atol=1e-6)


def test_pytree_rhs(rng):
    """Solvers operate on pytrees, not just flat vectors."""
    k1, k2 = jax.random.split(rng)
    Qa = _spd(k1, 4)
    Qb = _spd(k2, 3)

    def matvec(tree):
        return {"a": Qa @ tree["a"], "b": Qb @ tree["b"]}

    b = {"a": jnp.ones(4), "b": jnp.ones(3)}
    x = ls.solve_cg(matvec, b, tol=1e-12)
    np.testing.assert_allclose(Qa @ x["a"], b["a"], atol=1e-8)
    np.testing.assert_allclose(Qb @ x["b"], b["b"], atol=1e-8)


def test_neumann_contraction(rng):
    """(I − M)x = b with ||M||<1: Neumann series converges geometrically."""
    M = 0.4 * jax.random.orthogonal(rng, 6)
    A = jnp.eye(6) - M
    b = jnp.ones(6)
    x_exact = jnp.linalg.solve(A, b)
    x10 = ls.solve_neumann(lambda v: A @ v, b, maxiter=10)
    x40 = ls.solve_neumann(lambda v: A @ v, b, maxiter=40)
    assert jnp.linalg.norm(x40 - x_exact) < jnp.linalg.norm(x10 - x_exact)
    np.testing.assert_allclose(x40, x_exact, atol=1e-9)


def test_ridge_regularized_solve(rng):
    """Singular A + ridge damping still returns a finite least-squares-ish x."""
    A = jnp.diag(jnp.array([1.0, 2.0, 0.0]))
    b = jnp.array([1.0, 1.0, 0.0])
    x = ls.solve_cg(lambda v: A @ v, b, ridge=1e-3, tol=1e-12)
    assert jnp.all(jnp.isfinite(x))
    np.testing.assert_allclose(x[:2], jnp.array([1.0 / 1.001, 1.0 / 2.001]),
                               rtol=1e-3)


def test_make_rmatvec(rng):
    A = jax.random.normal(rng, (7, 7))
    rmv = ls.make_rmatvec(lambda v: A @ v, jnp.zeros(7))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (7,))
    np.testing.assert_allclose(rmv(v), A.T @ v, atol=1e-10)


def test_materialize_matrix(rng):
    A = jax.random.normal(rng, (5, 5))
    M = ls.materialize_matrix(lambda v: A @ v, jnp.zeros(5))
    np.testing.assert_allclose(M, A, atol=1e-12)


def test_solvers_jit_and_grad_safe(rng):
    """Solvers must be usable inside jit and under grad (while_loop based)."""
    A = _spd(rng, 6)

    @jax.jit
    def solve(b):
        return ls.solve_cg(lambda v: A @ v, b, tol=1e-12)

    b = jnp.ones(6)
    np.testing.assert_allclose(A @ solve(b), b, atol=1e-8)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), d=st.integers(2, 16))
def test_property_cg_solves_any_spd(seed, d):
    """Property: CG solves every well-conditioned SPD system to tolerance."""
    key = jax.random.PRNGKey(seed)
    A = _spd(key, d, cond=50.0)
    b = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    x = ls.solve_cg(lambda v: A @ v, b, tol=1e-10, maxiter=10 * d)
    residual = float(jnp.linalg.norm(A @ x - b) / jnp.linalg.norm(b))
    assert residual < 1e-6


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), d=st.integers(2, 12))
def test_property_gmres_equals_bicgstab(seed, d):
    """Property: two general-purpose solvers agree on the same system."""
    key = jax.random.PRNGKey(seed)
    A = jax.random.normal(key, (d, d)) + (d + 2) * jnp.eye(d)
    b = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    xg = ls.solve_gmres(lambda v: A @ v, b, tol=1e-12)
    xb = ls.solve_bicgstab(lambda v: A @ v, b, tol=1e-12)
    np.testing.assert_allclose(xg, xb, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), d=st.integers(2, 16),
       rho=st.floats(0.05, 0.9))
def test_property_hypergrad_error_estimate_monotone_in_k(seed, d, rho):
    """Property: on any contraction ``A = I − ρS`` (``‖S‖₂ = ρ < 1``), the
    ``neumann_k`` ``hypergrad_error_estimate`` decreases monotonically in
    the truncation depth k — the error-vs-cost accounting the approximate
    backward modes promise."""
    key = jax.random.PRNGKey(seed)
    S = jax.random.normal(key, (d, d))
    S = (S + S.T) / 2.0
    S = S / jnp.linalg.norm(S, 2)
    A = jnp.eye(d) - rho * S
    b = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    ests = []
    for k in (1, 2, 4, 8):
        _, info = ls.approx_inverse_apply(
            lambda v: A @ v, b, backward="neumann_k", backward_iters=k,
            return_info=True)
        ests.append(float(info.hypergrad_error_estimate))
    assert all(e1 >= e2 for e1, e2 in zip(ests, ests[1:])), ests
    # and the depth-k estimate is the contraction factor to the power k+1
    assert ests[-1] <= rho ** 9 + 1e-12
