"""Inner solver + bilevel driver + DEQ layer tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (bilevel, deq_fixed_point, make_deq_block, prox,
                        solvers)


class TestSolvers:

    def test_gradient_descent_quadratic(self, rng):
        Q = jnp.diag(jnp.array([1.0, 4.0, 9.0]))

        def f(x, theta):
            return 0.5 * x @ Q @ x - theta @ x

        theta = jnp.array([1.0, 2.0, 3.0])
        x = solvers.gradient_descent(f, jnp.zeros(3), theta, stepsize=0.1,
                                     maxiter=5000, tol=1e-12)
        np.testing.assert_allclose(x, jnp.linalg.solve(Q, theta), atol=1e-8)

    def test_gradient_descent_linesearch(self, rng):
        Q = jnp.diag(jnp.array([1.0, 100.0]))

        def f(x):
            return 0.5 * x @ Q @ x

        x = solvers.gradient_descent(f, jnp.ones(2), stepsize=1.0,
                                     maxiter=3000, tol=1e-10,
                                     linesearch=True)
        np.testing.assert_allclose(x, 0.0, atol=1e-6)

    def test_fista_faster_than_ista(self, rng):
        k1, k2 = jax.random.split(rng)
        X = jax.random.normal(k1, (30, 10))
        y = jax.random.normal(k2, (30,))
        L = float(jnp.linalg.eigvalsh(X.T @ X).max())

        def f(x, tf):
            return 0.5 * jnp.sum((X @ x - y) ** 2)

        pr = lambda v, lam, s: prox.prox_lasso(v, lam, s)
        kw = dict(stepsize=1.0 / L, tol=0.0)
        x_star = solvers.proximal_gradient(f, pr, jnp.zeros(10),
                                           (None, 0.1), maxiter=20000,
                                           stepsize=1.0 / L, tol=1e-15)

        def err(accel, n):
            x = solvers.proximal_gradient(f, pr, jnp.zeros(10), (None, 0.1),
                                          maxiter=n, accel=accel, **kw)
            return float(jnp.linalg.norm(x - x_star))

        # FISTA wins in the sublinear early phase (later, strong convexity on
        # the support gives ISTA a linear rate and the comparison flips).
        assert err(True, 20) < err(False, 20)

    def test_fixed_point_iteration_contraction(self, rng):
        M = 0.5 * jax.random.orthogonal(rng, 4)
        x = solvers.fixed_point_iteration(lambda v: M @ v + 1.0,
                                          jnp.zeros(4), maxiter=500,
                                          tol=1e-13)
        np.testing.assert_allclose(x, jnp.linalg.solve(jnp.eye(4) - M,
                                                       jnp.ones(4)),
                                   atol=1e-9)

    def test_anderson_beats_plain_iteration(self, rng):
        M = 0.95 * jax.random.orthogonal(rng, 8)   # slow contraction
        b = jnp.ones(8)
        T = lambda v: M @ v + b
        x_true = jnp.linalg.solve(jnp.eye(8) - M, b)
        x_plain = solvers.fixed_point_iteration(T, jnp.zeros(8), maxiter=40,
                                                tol=0.0)
        x_aa = solvers.anderson_acceleration(T, jnp.zeros(8), maxiter=40,
                                             tol=0.0)
        assert (jnp.linalg.norm(x_aa - x_true)
                < jnp.linalg.norm(x_plain - x_true))


class TestBilevel:
    """Hyperparameter optimization with implicit hypergradients (§4.1/4.2)."""

    def test_ridge_hyperparam_converges_to_oracle(self, rng):
        """Tune per-coordinate ridge: hypergrad descent reduces val loss."""
        k1, k2, k3 = jax.random.split(rng, 3)
        Xtr = jax.random.normal(k1, (40, 6))
        w_true = jnp.array([1.0, -2.0, 0.0, 0.0, 3.0, 0.0])
        ytr = Xtr @ w_true + 0.1 * jax.random.normal(k2, (40,))
        Xval = jax.random.normal(k3, (40, 6))
        yval = Xval @ w_true

        def inner_obj(x, lam):
            return 0.5 * jnp.sum((Xtr @ x - ytr) ** 2) + \
                0.5 * jnp.sum(jnp.exp(lam) * x ** 2)

        def inner_solver(init, lam):
            return jnp.linalg.solve(Xtr.T @ Xtr + jnp.diag(jnp.exp(lam)),
                                    Xtr.T @ ytr)

        def outer_loss(x, lam):
            return 0.5 * jnp.mean((Xval @ x - yval) ** 2)

        sol = bilevel.solve_bilevel(
            outer_loss, inner_solver, jnp.zeros(6), jnp.zeros(6),
            inner_objective=inner_obj, outer_steps=60, outer_lr=0.3)
        assert sol.outer_values[-1] < sol.outer_values[0] * 0.5
        assert jnp.all(jnp.isfinite(sol.theta))

    def test_hypergrad_matches_unrolled_on_strongly_convex(self, rng):
        """Implicit hypergradient ≈ unrolled-to-convergence hypergradient."""
        k1, k2 = jax.random.split(rng)
        X = jax.random.normal(k1, (20, 4))
        y = jax.random.normal(k2, (20,))

        def inner_obj(x, lam):
            return 0.5 * jnp.sum((X @ x - y) ** 2) + \
                0.5 * jnp.exp(lam) * jnp.sum(x ** 2)

        def outer_loss(x):
            return jnp.sum(x ** 2)

        # implicit
        def inner_solver(init, lam):
            return jnp.linalg.solve(X.T @ X + jnp.exp(lam) * jnp.eye(4),
                                    X.T @ y)

        implicit = bilevel.make_implicit_inner(
            inner_solver, inner_objective=inner_obj, tol=1e-12)
        g_imp = jax.grad(lambda lam: outer_loss(implicit(jnp.zeros(4),
                                                         lam)))(0.3)
        # unrolled
        L = float(jnp.linalg.eigvalsh(X.T @ X).max()) + 2.0
        step = lambda x, lam: x - (1.0 / L) * jax.grad(inner_obj)(x, lam)
        unrolled = bilevel.make_unrolled_inner(step, 3000)
        g_unr = jax.grad(lambda lam: outer_loss(unrolled(jnp.zeros(4),
                                                         lam)))(0.3)
        np.testing.assert_allclose(g_imp, g_unr, rtol=1e-4)


class TestDEQ:
    """Implicit (fixed-point) layer with implicit-diff backward."""

    def test_deq_forward_is_fixed_point(self, rng):
        k1, k2 = jax.random.split(rng)
        W = 0.4 * jax.random.orthogonal(k1, 8)
        x = jax.random.normal(k2, (8,))

        def cell(z, x, w):
            return jnp.tanh(w @ z + x)

        z_star = deq_fixed_point(cell, jnp.zeros(8), x, W,
                                 fwd_iters=100, fwd_tol=1e-12)
        np.testing.assert_allclose(z_star, cell(z_star, x, W), atol=1e-7)

    @pytest.mark.parametrize("bwd", ["neumann", "normal_cg"])
    def test_deq_gradient_matches_unrolled(self, rng, bwd):
        k1, k2 = jax.random.split(rng)
        W = 0.3 * jax.random.orthogonal(k1, 6)
        x = jax.random.normal(k2, (6,))

        def cell(z, x, w):
            return jnp.tanh(w @ z + x)

        def loss_implicit(w):
            z = deq_fixed_point(cell, jnp.zeros(6), x, w, fwd_iters=200,
                                fwd_tol=1e-13, bwd_solve=bwd, bwd_iters=60)
            return jnp.sum(z ** 2)

        def loss_unrolled(w):
            z = jnp.zeros(6)
            for _ in range(200):
                z = cell(z, x, w)
            return jnp.sum(z ** 2)

        g_i = jax.grad(loss_implicit)(W)
        g_u = jax.grad(loss_unrolled)(W)
        tol = 1e-3 if bwd == "neumann" else 1e-6
        np.testing.assert_allclose(g_i, g_u, atol=tol)

    def test_deq_block_wrapper(self, rng):
        k1, k2 = jax.random.split(rng)
        W = 0.3 * jax.random.orthogonal(k1, 5)
        x = jax.random.normal(k2, (5,))
        block = make_deq_block(lambda z, x, w: jnp.tanh(w @ z + x),
                               fwd_iters=80)
        z = block(x, W)
        assert z.shape == x.shape
        g = jax.grad(lambda x: jnp.sum(block(x, W)))(x)
        assert jnp.all(jnp.isfinite(g))
