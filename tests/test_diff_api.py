"""Tests for the mode-polymorphic implicit-diff API (``repro.core.diff_api``).

Covers the redesign's acceptance criteria:
  * ONE ``implicit_diff``-wrapped solver supports ``jax.grad``,
    ``jax.jacrev``, ``jax.jvp`` and ``jax.jacfwd`` without re-wrapping,
    with ``jacfwd``/``jacrev`` agreement on ridge regression and a
    fixed-point problem;
  * ``jax.vmap`` of either mode's derivative EXECUTES exactly one batched
    masked registry solve (counting assertion), matching the python loop;
  * ``solver_runtime.run(mode="jvp")`` works for every ported solver class
    (finite-difference checks);
  * the forward path supports ``has_aux`` (historically missing from
    ``custom_root_jvp``);
  * the deprecated names warn exactly once per process;
  * spec validation, per-call overrides, ``nondiff_argnums``, and the
    bilevel/DEQ ``diff_spec`` plumbing.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FixedPointIteration, GradientDescent, ImplicitDiffSpec,
                        implicit_diff)
from repro.core import linear_solve as ls
from repro.core import bilevel, diff_api


def _ridge_problem(key, m=20, d=5):
    kx, ky = jax.random.split(key)
    X = jax.random.normal(kx, (m, d))
    y = jax.random.normal(ky, (m,))
    return X, y


def _ridge_closed_form_jac(X, y, theta):
    d = X.shape[1]
    A = X.T @ X + theta * jnp.eye(d)
    return -jnp.linalg.solve(A, jnp.linalg.solve(A, X.T @ y))


def _make_wrapped_ridge(X, y, **spec_kw):
    d = X.shape[1]

    def f(x, theta):
        r = X @ x - y
        return (jnp.sum(r ** 2) + theta * jnp.sum(x ** 2)) / 2

    spec = ImplicitDiffSpec(optimality_fun=jax.grad(f, argnums=0),
                            tol=1e-12, **spec_kw)

    @implicit_diff(spec)
    def solver(init, theta):
        del init
        return jnp.linalg.solve(X.T @ X + theta * jnp.eye(d), X.T @ y)

    return solver


class TestModePolymorphic:
    """The tentpole: one wrapper, all four transforms, no re-wrapping."""

    def test_all_four_transforms_one_wrapper(self, rng):
        X, y = _ridge_problem(rng)
        solver = _make_wrapped_ridge(X, y)
        Jtrue = _ridge_closed_form_jac(X, y, 10.0)
        x_star = solver(None, 10.0)

        g = jax.grad(lambda t: jnp.sum(solver(None, t) ** 2))(10.0)
        np.testing.assert_allclose(g, 2 * x_star @ Jtrue, atol=1e-7)

        Jr = jax.jacrev(solver, argnums=1)(None, 10.0)
        np.testing.assert_allclose(Jr, Jtrue, atol=1e-7)

        Jf = jax.jacfwd(solver, argnums=1)(None, 10.0)
        np.testing.assert_allclose(Jf, Jtrue, atol=1e-7)

        _, jv = jax.jvp(lambda t: solver(None, t), (10.0,), (1.0,))
        np.testing.assert_allclose(jv, Jtrue, atol=1e-7)

    def test_jacfwd_jacrev_agree_ridge(self, rng):
        """Acceptance: forward/reverse agreement to 1e-5 (ridge)."""
        X, y = _ridge_problem(rng, m=25, d=7)
        solver = _make_wrapped_ridge(X, y)
        Jf = jax.jacfwd(solver, argnums=1)(None, 3.0)
        Jr = jax.jacrev(solver, argnums=1)(None, 3.0)
        np.testing.assert_allclose(Jf, Jr, atol=1e-5, rtol=1e-5)

    def test_jacfwd_jacrev_agree_fixed_point(self, rng):
        """Acceptance: forward/reverse agreement to 1e-5 (fixed point)."""
        M = 0.4 * jax.random.orthogonal(rng, 6)

        def T(x, theta):
            return M @ x + jnp.tanh(theta)

        spec = ImplicitDiffSpec(fixed_point_fun=T, tol=1e-12)

        @implicit_diff(spec)
        def solver(init, theta):
            return jnp.linalg.solve(jnp.eye(6) - M, jnp.tanh(theta))

        theta = jnp.linspace(-1.0, 1.0, 6)
        Jf = jax.jacfwd(solver, argnums=1)(jnp.zeros(6), theta)
        Jr = jax.jacrev(solver, argnums=1)(jnp.zeros(6), theta)
        np.testing.assert_allclose(Jf, Jr, atol=1e-5, rtol=1e-5)
        Jtrue = jnp.linalg.inv(jnp.eye(6) - M) @ jnp.diag(
            1.0 / jnp.cosh(theta) ** 2)
        np.testing.assert_allclose(Jf, Jtrue, atol=1e-7)

    def test_jit_and_zero_init_grad(self, rng):
        X, y = _ridge_problem(rng)
        solver = _make_wrapped_ridge(X, y)
        g = jax.jit(jax.grad(lambda t: jnp.sum(solver(None, t) ** 2)))(10.0)
        assert jnp.isfinite(g)
        gi = jax.grad(lambda i: jnp.sum(solver(i, 10.0) + 0.0 * i))(
            jnp.ones(X.shape[1]))
        np.testing.assert_allclose(gi, 0.0, atol=1e-12)

    def test_pytree_theta_partial_output_use(self, rng):
        """Regression: a loss touching only SOME x* leaves must not feed
        symbolic-zero cotangents into the transpose (the raveled-system
        guarantee), and forward mode must agree."""
        def F(x, theta):
            return {"a": 2.0 * x["a"] - theta["p"],
                    "b": 3.0 * x["b"] - theta["q"]}

        @implicit_diff(F, tol=1e-12)
        def solver(init, theta):
            return {"a": theta["p"] / 2.0, "b": theta["q"] / 3.0}

        theta = {"p": jnp.ones(3), "q": jnp.ones(2)}
        g = jax.grad(lambda t: jnp.sum(solver(None, t)["a"]))(theta)
        np.testing.assert_allclose(g["p"], 0.5, atol=1e-9)
        np.testing.assert_allclose(g["q"], 0.0, atol=1e-9)
        _, jv = jax.jvp(lambda t: solver(None, t),
                        (theta,), ({"p": jnp.ones(3), "q": jnp.zeros(2)},))
        np.testing.assert_allclose(jv["a"], 0.5, atol=1e-9)
        np.testing.assert_allclose(jv["b"], 0.0, atol=1e-9)


class TestVmapCounting:
    """Acceptance: vmap of either mode's derivative executes ONE batched
    masked solve through the registry — never N per-instance solves."""

    def _counting_ridge(self, rng, traced, executed):
        X, y = _ridge_problem(rng, m=16, d=4)

        def counting_cg(matvec, b, **kw):
            traced.append(1)
            jax.debug.callback(lambda _: executed.append(1), jnp.zeros(()))
            return ls.solve_cg(matvec, b, **kw)

        ls.register_solver("counting_cg_api", counting_cg,
                           symmetric_only=True, supports_precond=True)
        return _make_wrapped_ridge(X, y, solve="counting_cg_api")

    def test_vmap_grad_executes_one_batched_solve(self, rng):
        traced, executed = [], []
        solver = self._counting_ridge(rng, traced, executed)
        try:
            loss = lambda t: jnp.sum(solver(None, t) ** 2)
            thetas = jnp.array([0.5, 1.0, 2.0, 4.0])
            executed.clear()
            g_vmap = jax.vmap(jax.grad(loss))(thetas)
            jax.effects_barrier()
            assert len(executed) == 1, \
                f"expected ONE batched backward solve, ran {len(executed)}"
            # trace census: one staged template per direction, constant in B
            assert len(traced) == 2
            executed.clear()
            g_loop = jnp.stack([jax.grad(loss)(t) for t in thetas])
            jax.effects_barrier()
            assert len(executed) == len(thetas)
        finally:
            ls._REGISTRY.pop("counting_cg_api", None)
        np.testing.assert_allclose(g_vmap, g_loop, rtol=1e-12)

    def test_vmap_jvp_executes_one_batched_solve(self, rng):
        traced, executed = [], []
        solver = self._counting_ridge(rng, traced, executed)
        try:
            deriv = lambda t: jax.jvp(lambda tt: solver(None, tt),
                                      (t,), (1.0,))[1]
            thetas = jnp.array([0.5, 1.0, 2.0, 4.0])
            executed.clear()
            jv_vmap = jax.vmap(deriv)(thetas)
            jax.effects_barrier()
            assert len(executed) == 1, \
                f"expected ONE batched tangent solve, ran {len(executed)}"
            executed.clear()
            jv_loop = jnp.stack([deriv(t) for t in thetas])
            jax.effects_barrier()
            assert len(executed) == len(thetas)
        finally:
            ls._REGISTRY.pop("counting_cg_api", None)
        np.testing.assert_allclose(jv_vmap, jv_loop, rtol=1e-12)


class TestForcedModes:
    """mode="jvp"/"vjp" force single-mode wrappings with the historical
    contracts (the other transform raises)."""

    def test_jvp_mode_forward_only(self, rng):
        X, y = _ridge_problem(rng)
        solver = _make_wrapped_ridge(X, y)
        fwd_only = implicit_diff(solver.spec, mode="jvp")(
            lambda init, t: jnp.linalg.solve(
                X.T @ X + t * jnp.eye(X.shape[1]), X.T @ y))
        Jf = jax.jacfwd(fwd_only, argnums=1)(None, 3.0)
        np.testing.assert_allclose(Jf, _ridge_closed_form_jac(X, y, 3.0),
                                   atol=1e-7)
        # the forward-only wrapping has no transpose path: reverse mode
        # fails on the non-transposable registry while_loop
        with pytest.raises((TypeError, ValueError)):
            jax.grad(lambda t: jnp.sum(fwd_only(None, t) ** 2))(3.0)

    def test_vjp_mode_reverse_only(self, rng):
        X, y = _ridge_problem(rng)
        spec = ImplicitDiffSpec(
            optimality_fun=jax.grad(
                lambda x, t: 0.5 * jnp.sum((X @ x - y) ** 2)
                + 0.5 * t * jnp.sum(x ** 2), argnums=0), tol=1e-12)
        rev_only = implicit_diff(spec, mode="vjp")(
            lambda init, t: jnp.linalg.solve(
                X.T @ X + t * jnp.eye(X.shape[1]), X.T @ y))
        Jr = jax.jacrev(rev_only, argnums=1)(None, 3.0)
        np.testing.assert_allclose(Jr, _ridge_closed_form_jac(X, y, 3.0),
                                   atol=1e-7)
        with pytest.raises(TypeError):
            jax.jvp(lambda t: rev_only(None, t), (3.0,), (1.0,))


class TestHasAuxForward:
    """Satellite: the forward-mode path supports has_aux (historically
    missing from custom_root_jvp / custom_fixed_point_jvp)."""

    def _aux_solver(self, X, y, mode):
        def f(x, t):
            return 0.5 * jnp.sum((X @ x - y) ** 2) + 0.5 * t * jnp.sum(x ** 2)

        spec = ImplicitDiffSpec(optimality_fun=jax.grad(f, argnums=0),
                                tol=1e-12, has_aux=True)

        @implicit_diff(spec, mode=mode)
        def solver(init, theta):
            d = X.shape[1]
            x = jnp.linalg.solve(X.T @ X + theta * jnp.eye(d), X.T @ y)
            return x, {"iters": jnp.asarray(3), "resid": jnp.asarray(0.5)}

        return solver

    @pytest.mark.parametrize("mode", ["auto", "jvp"])
    def test_jacfwd_with_aux(self, rng, mode):
        X, y = _ridge_problem(rng)
        solver = self._aux_solver(X, y, mode)
        Jf = jax.jacfwd(lambda t: solver(None, t)[0])(10.0)
        np.testing.assert_allclose(Jf, _ridge_closed_form_jac(X, y, 10.0),
                                   atol=1e-7)
        (x, aux), (dx, daux) = jax.jvp(lambda t: solver(None, t),
                                       (10.0,), (1.0,))
        assert int(aux["iters"]) == 3
        # aux tangents are zero: float0 for ints, 0.0 for floats
        assert daux["iters"].dtype == jax.dtypes.float0
        np.testing.assert_allclose(daux["resid"], 0.0)

    def test_auto_mode_aux_reverse_too(self, rng):
        X, y = _ridge_problem(rng)
        solver = self._aux_solver(X, y, "auto")
        g = jax.grad(lambda t: jnp.sum(solver(None, t)[0] ** 2))(10.0)
        x_star = solver(None, 10.0)[0]
        Jtrue = _ridge_closed_form_jac(X, y, 10.0)
        np.testing.assert_allclose(g, 2 * x_star @ Jtrue, atol=1e-7)

    def test_custom_root_jvp_shim_has_aux(self, rng):
        from repro.core import custom_root_jvp
        X, y = _ridge_problem(rng)
        F = jax.grad(lambda x, t: 0.5 * jnp.sum((X @ x - y) ** 2)
                     + 0.5 * t * jnp.sum(x ** 2), argnums=0)

        @custom_root_jvp(F, tol=1e-12, has_aux=True)
        def solver(init, theta):
            d = X.shape[1]
            x = jnp.linalg.solve(X.T @ X + theta * jnp.eye(d), X.T @ y)
            return x, jnp.asarray(7)

        Jf = jax.jacfwd(lambda t: solver(None, t)[0])(10.0)
        np.testing.assert_allclose(Jf, _ridge_closed_form_jac(X, y, 10.0),
                                   atol=1e-7)


class TestSpecValidation:

    def test_both_mappings_rejected(self):
        with pytest.raises(ValueError, match="at most one"):
            ImplicitDiffSpec(optimality_fun=lambda x: x,
                             fixed_point_fun=lambda x: x)

    def test_routing_only_spec_cannot_wrap(self):
        spec = ImplicitDiffSpec(solve="cg", tol=1e-9)
        assert spec.is_routing_only
        with pytest.raises(ValueError, match="routing-only"):
            implicit_diff(spec)

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError, match="mode"):
            implicit_diff(lambda x, t: x - t, mode="sideways")

    def test_negative_nondiff_argnums_rejected(self):
        with pytest.raises(ValueError, match="nondiff_argnums"):
            ImplicitDiffSpec(optimality_fun=lambda x, t: x - t,
                             nondiff_argnums=(-1,))

    def test_per_call_override(self, rng):
        X, y = _ridge_problem(rng)
        base = ImplicitDiffSpec(
            optimality_fun=jax.grad(
                lambda x, t: 0.5 * jnp.sum((X @ x - y) ** 2)
                + 0.5 * t * jnp.sum(x ** 2), argnums=0), solve="cg")
        wrapped = implicit_diff(base, solve="bicgstab", tol=1e-11)(
            lambda init, t: jnp.linalg.solve(
                X.T @ X + t * jnp.eye(X.shape[1]), X.T @ y))
        assert wrapped.spec.solve == "bicgstab"
        assert wrapped.spec.tol == 1e-11
        assert base.solve == "cg"          # the original spec is untouched
        Jf = jax.jacfwd(wrapped, argnums=1)(None, 3.0)
        np.testing.assert_allclose(Jf, _ridge_closed_form_jac(X, y, 3.0),
                                   atol=1e-7)

    def test_nondiff_argnums_static_callable(self, rng):
        """A callable theta argument (a link function) rides along as a
        static nondiff arg; derivatives flow to the array args only."""
        X, y = _ridge_problem(rng)
        d = X.shape[1]

        def F(x, link, theta):
            return X.T @ (X @ x - y) + link(theta) * x

        spec = ImplicitDiffSpec(optimality_fun=F, tol=1e-12,
                                nondiff_argnums=(0,))

        @implicit_diff(spec)
        def solver(init, link, theta):
            return jnp.linalg.solve(X.T @ X + link(theta) * jnp.eye(d),
                                    X.T @ y)

        link = jnp.exp
        Jf = jax.jacfwd(solver, argnums=2)(None, link, 1.5)
        Jr = jax.jacrev(solver, argnums=2)(None, link, 1.5)
        # chain rule vs the plain-theta closed form
        Jtrue = _ridge_closed_form_jac(X, y, jnp.exp(1.5)) * jnp.exp(1.5)
        np.testing.assert_allclose(Jf, Jtrue, atol=1e-7)
        np.testing.assert_allclose(Jr, Jtrue, atol=1e-7)


class TestRuntimeModes:
    """Acceptance: run(mode="jvp") works for EVERY ported solver class."""

    def _fd_check(self, run_scalar, s0, jv, eps=1e-6, rtol=2e-3, atol=1e-6):
        fd = (run_scalar(s0 + eps) - run_scalar(s0 - eps)) / (2 * eps)
        np.testing.assert_allclose(jv, fd, rtol=rtol, atol=atol)

    @pytest.mark.parametrize("name", [
        "gradient_descent", "proximal_gradient", "projected_gradient",
        "mirror_descent", "block_cd", "newton", "lbfgs", "fixed_point",
        "anderson"])
    def test_run_jvp_mode_finite_difference(self, rng, name):
        from repro.core import (AndersonAcceleration, BlockCoordinateDescent,
                                LBFGS, MirrorDescent, Newton,
                                ProjectedGradient, ProximalGradient,
                                projections, prox)
        X, y = _ridge_problem(rng, m=12, d=3)
        L = float(jnp.linalg.eigvalsh(X.T @ X).max()) + 3.0

        def ridge(x, t):
            return 0.5 * jnp.sum((X @ x - y) ** 2) + 0.5 * t * jnp.sum(x ** 2)

        def quad(x, t):
            return 0.5 * jnp.sum((x - t) ** 2)

        M = 0.5 * jax.random.orthogonal(rng, 3)
        kw = dict(maxiter=4000, tol=1e-12)
        cases = {
            "gradient_descent": (
                GradientDescent(ridge, stepsize=1.0 / L, **kw),
                jnp.zeros(3), lambda s: s, 1.0),
            "proximal_gradient": (
                ProximalGradient(lambda x, tf: 0.5 * jnp.sum((X @ x - y) ** 2),
                                 lambda v, lam, st: prox.prox_lasso(v, lam, st),
                                 stepsize=1.0 / L, **kw),
                jnp.zeros(3), lambda s: (None, s), 0.2),
            "projected_gradient": (
                ProjectedGradient(quad,
                                  lambda v, tp: projections.projection_simplex(v),
                                  stepsize=0.4, **kw),
                jnp.ones(3) / 3, lambda s: (jnp.array([0.2, 0.9, 0.4]) * s,
                                            None), 1.0),
            "mirror_descent": (
                MirrorDescent(quad,
                              lambda v, tp: projections.projection_simplex_kl(v),
                              stepsize=0.8, maxiter=4000, tol=1e-12),
                jnp.ones(3) / 3, lambda s: (jnp.array([0.2, 0.9, 0.4]) * s,
                                            None), 1.0),
            "block_cd": (
                BlockCoordinateDescent(
                    lambda x, tf: 0.5 * jnp.sum((X @ x.ravel() - y) ** 2),
                    lambda v, lam, st: prox.prox_lasso(v, lam, st),
                    stepsize=1.0 / L, **kw),
                jnp.zeros((3, 1)), lambda s: (None, s), 0.1),
            "newton": (Newton(ridge, maxiter=40, tol=1e-12),
                       jnp.zeros(3), lambda s: s, 1.0),
            "lbfgs": (LBFGS(ridge, stepsize=0.02, maxiter=2000, tol=1e-12),
                      jnp.zeros(3), lambda s: s, 1.0),
            "fixed_point": (
                FixedPointIteration(lambda x, t: M @ x + t, maxiter=2000,
                                    tol=1e-13),
                jnp.zeros(3), lambda s: s * jnp.ones(3), 1.0),
            "anderson": (
                AndersonAcceleration(lambda x, t: M @ x + t, maxiter=200,
                                     tol=1e-13),
                jnp.zeros(3), lambda s: s * jnp.ones(3), 1.0),
        }
        solver, init, theta_of_s, s0 = cases[name]

        def run_scalar(s):
            return float(jnp.sum(
                solver.run(init, theta_of_s(s), mode="jvp")[0] ** 2))

        def fwd(s):
            return jnp.sum(solver.run(init, theta_of_s(s), mode="jvp")[0] ** 2)

        _, jv = jax.jvp(fwd, (s0,), (1.0,))
        assert jnp.isfinite(jv) and abs(float(jv)) > 1e-12
        self._fd_check(run_scalar, s0, float(jv))

    def test_run_auto_supports_both_modes(self, rng):
        """The default run() serves jacfwd AND jacrev from one wrapping."""
        X, y = _ridge_problem(rng, m=16, d=4)
        L = float(jnp.linalg.eigvalsh(X.T @ X).max()) + 2.0

        def f(x, t):
            return 0.5 * jnp.sum((X @ x - y) ** 2) + 0.5 * t * jnp.sum(x ** 2)

        solver = GradientDescent(f, stepsize=1.0 / L, maxiter=6000, tol=1e-13)
        run = lambda t: solver.run(jnp.zeros(4), t)[0]
        Jf = jax.jacfwd(run)(1.0)
        Jr = jax.jacrev(run)(1.0)
        np.testing.assert_allclose(Jf, Jr, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(Jf, _ridge_closed_form_jac(X, y, 1.0),
                                   atol=1e-6)

    def test_run_vjp_mode_matches_auto(self, rng):
        X, y = _ridge_problem(rng, m=16, d=4)
        L = float(jnp.linalg.eigvalsh(X.T @ X).max()) + 2.0

        def f(x, t):
            return 0.5 * jnp.sum((X @ x - y) ** 2) + 0.5 * t * jnp.sum(x ** 2)

        solver = GradientDescent(f, stepsize=1.0 / L, maxiter=6000, tol=1e-13)
        loss_auto = lambda t: jnp.sum(solver.run(jnp.zeros(4), t)[0] ** 2)
        loss_vjp = lambda t: jnp.sum(
            solver.run(jnp.zeros(4), t, mode="vjp")[0] ** 2)
        np.testing.assert_allclose(jax.grad(loss_auto)(1.0),
                                   jax.grad(loss_vjp)(1.0), rtol=1e-12)


class TestDeprecationOneShot:
    """Satellite: legacy names warn exactly once per process."""

    def test_solvers_factory_warns_exactly_once(self, rng):
        from repro.core import solvers
        Q = jnp.diag(jnp.array([1.0, 2.0]))

        def f(x, theta):
            return 0.5 * x @ Q @ x - theta @ x

        diff_api.reset_deprecation_warnings()
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            solvers.newton(f, jnp.zeros(2), jnp.ones(2), maxiter=10)
            solvers.newton(f, jnp.zeros(2), jnp.ones(2), maxiter=10)
        dep = [w for w in rec if issubclass(w.category, DeprecationWarning)
               and "newton" in str(w.message)]
        assert len(dep) == 1, f"expected exactly one warning, got {len(dep)}"

    def test_jvp_decorator_warns_exactly_once(self, rng):
        from repro.core import custom_root_jvp
        F = lambda x, t: x - t
        diff_api.reset_deprecation_warnings()
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            custom_root_jvp(F)
            custom_root_jvp(F)
        dep = [w for w in rec if issubclass(w.category, DeprecationWarning)
               and "custom_root_jvp" in str(w.message)]
        assert len(dep) == 1, f"expected exactly one warning, got {len(dep)}"


class TestBilevelSpec:
    """diff_spec plumbing through the bilevel driver."""

    def _problem(self, rng):
        k1, k2 = jax.random.split(rng)
        X = jax.random.normal(k1, (20, 4))
        y = jax.random.normal(k2, (20,))

        def inner_obj(x, lam):
            return 0.5 * jnp.sum((X @ x - y) ** 2) + \
                0.5 * jnp.exp(lam) * jnp.sum(x ** 2)

        return X, y, inner_obj

    def test_routing_only_spec_overrides_solver(self, rng):
        X, y, inner_obj = self._problem(rng)
        seen = {}

        def spy_cg(matvec, b, **kw):
            seen.update(kw)
            return ls.solve_cg(matvec, b, **kw)

        ls.register_solver("spy_cg_bilevel", spy_cg, symmetric_only=True,
                           supports_precond=True)
        try:
            L = float(jnp.linalg.eigvalsh(X.T @ X).max()) + 2.0
            inner = GradientDescent(inner_obj, stepsize=1.0 / L, maxiter=3000,
                                    tol=1e-12, solve="normal_cg")
            spec = ImplicitDiffSpec(solve="spy_cg_bilevel", tol=1e-9,
                                    maxiter=55, ridge=1e-11)
            sol = bilevel.solve_bilevel(lambda x, lam: jnp.sum(x ** 2), inner,
                                        0.3, jnp.zeros(4), outer_steps=2,
                                        outer_lr=0.1, diff_spec=spec)
            assert bool(sol.inner_info.converged)
            assert seen["tol"] == 1e-9
            assert seen["maxiter"] == 55
            assert seen["ridge"] == 1e-11
        finally:
            ls._REGISTRY.pop("spy_cg_bilevel", None)

    def test_spec_and_loose_kwargs_conflict(self, rng):
        X, y, inner_obj = self._problem(rng)
        inner = GradientDescent(inner_obj, stepsize=1e-2, maxiter=10)
        with pytest.raises(ValueError, match="not both"):
            bilevel.make_implicit_inner(inner, diff_spec=ImplicitDiffSpec(),
                                        solve="cg")

    def test_callable_inner_with_mapping_spec(self, rng):
        X, y, inner_obj = self._problem(rng)
        d = X.shape[1]

        def raw(init, lam):
            return jnp.linalg.solve(X.T @ X + jnp.exp(lam) * jnp.eye(d),
                                    X.T @ y)

        spec = ImplicitDiffSpec(
            optimality_fun=jax.grad(inner_obj, argnums=0), tol=1e-12)
        fn = bilevel.make_implicit_inner(raw, diff_spec=spec)
        # both modes work through the bilevel-wrapped callable
        g = jax.grad(lambda lam: jnp.sum(fn(None, lam) ** 2))(0.3)
        _, jv = jax.jvp(lambda lam: jnp.sum(fn(None, lam) ** 2),
                        (0.3,), (1.0,))
        np.testing.assert_allclose(g, jv, rtol=1e-8)

    def test_routing_only_spec_with_callable_and_objective(self, rng):
        """A bare callable + routing-only spec + inner_objective composes:
        the spec supplies the routing, the objective the mapping."""
        X, y, inner_obj = self._problem(rng)
        d = X.shape[1]

        def raw(init, lam):
            return jnp.linalg.solve(X.T @ X + jnp.exp(lam) * jnp.eye(d),
                                    X.T @ y)

        spec = ImplicitDiffSpec(solve="cg", tol=1e-12)
        fn = bilevel.make_implicit_inner(raw, inner_objective=inner_obj,
                                         diff_spec=spec)
        g = jax.grad(lambda lam: jnp.sum(fn(None, lam) ** 2))(0.3)
        fn_loose = bilevel.make_implicit_inner(raw, inner_objective=inner_obj,
                                               solve="cg", tol=1e-12)
        g_loose = jax.grad(lambda lam: jnp.sum(fn_loose(None, lam) ** 2))(0.3)
        np.testing.assert_allclose(g, g_loose, rtol=1e-12)
        # with neither mapping source, the error says how to fix it
        with pytest.raises(ValueError, match="optimality mapping"):
            bilevel.make_implicit_inner(raw, diff_spec=spec)

    def test_mapping_spec_supersedes_solver_mapping(self, rng):
        """An IterativeSolver + a spec carrying a mapping: the spec's
        mapping wins (the paper's decoupling promise)."""
        X, y, inner_obj = self._problem(rng)
        L = float(jnp.linalg.eigvalsh(X.T @ X).max()) + 2.0
        inner = GradientDescent(inner_obj, stepsize=1.0 / L, maxiter=3000,
                                tol=1e-12)
        spec = ImplicitDiffSpec(
            optimality_fun=jax.grad(inner_obj, argnums=0), tol=1e-12)
        fn = bilevel.make_implicit_inner(inner, diff_spec=spec)
        fn_default = bilevel.make_implicit_inner(inner)
        x0 = jnp.zeros(4)
        g_spec = jax.grad(lambda lam: jnp.sum(fn(x0, lam) ** 2))(0.3)
        g_default = jax.grad(
            lambda lam: jnp.sum(fn_default(x0, lam) ** 2))(0.3)
        np.testing.assert_allclose(g_spec, g_default, rtol=1e-6)


class TestDEQSpec:

    def test_deq_forward_mode_sensitivity(self, rng):
        from repro.core import deq_fixed_point
        W = 0.3 * jax.random.orthogonal(rng, 4)

        def cell(z, x, w):
            return jnp.tanh(W @ z * w + x)

        x = jax.random.normal(jax.random.fold_in(rng, 2), (4,))
        spec = ImplicitDiffSpec(solve="normal_cg", tol=1e-11)
        z_of_w = lambda w: deq_fixed_point(cell, jnp.zeros(4), x, w,
                                           fwd_tol=1e-12, diff_spec=spec)
        # forward-mode sensitivity wrt the scalar weight: one tangent solve
        Jf = jax.jacfwd(z_of_w)(0.7)
        Jr = jax.jacrev(z_of_w)(0.7)
        np.testing.assert_allclose(Jf, Jr, atol=1e-5, rtol=1e-5)
        eps = 1e-6
        fd = (z_of_w(0.7 + eps) - z_of_w(0.7 - eps)) / (2 * eps)
        np.testing.assert_allclose(Jf, fd, rtol=1e-3, atol=1e-6)

    def test_deq_rejects_mapping_spec(self):
        from repro.core import make_deq_solver
        spec = ImplicitDiffSpec(fixed_point_fun=lambda z: z)
        with pytest.raises(ValueError, match="routing-only"):
            make_deq_solver(lambda z, x, w: z, diff_spec=spec)
