"""Tests for the implicit differentiation core (paper §2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (custom_root, custom_fixed_point, custom_root_jvp,
                        custom_fixed_point_jvp, root_vjp, root_jvp,
                        optimality)


def _ridge_problem(key, m=20, d=5):
    kx, ky = jax.random.split(key)
    X = jax.random.normal(kx, (m, d))
    y = jax.random.normal(ky, (m,))
    return X, y


def _ridge_closed_form_jac(X, y, theta):
    d = X.shape[1]
    A = X.T @ X + theta * jnp.eye(d)
    return -jnp.linalg.solve(A, jnp.linalg.solve(A, X.T @ y))


class TestCustomRoot:
    """Fig. 1: ridge regression with a stationarity condition."""

    @pytest.mark.parametrize("solve", ["cg", "normal_cg", "bicgstab",
                                       "gmres", "lu"])
    def test_ridge_jacobian_matches_closed_form(self, rng, solve):
        X, y = _ridge_problem(rng)

        def f(x, theta):
            r = X @ x - y
            return (jnp.sum(r ** 2) + theta * jnp.sum(x ** 2)) / 2

        F = jax.grad(f, argnums=0)

        @custom_root(F, solve=solve, tol=1e-12)
        def ridge_solver(init_x, theta):
            del init_x
            d = X.shape[1]
            return jnp.linalg.solve(X.T @ X + theta * jnp.eye(d), X.T @ y)

        J = jax.jacobian(ridge_solver, argnums=1)(None, 10.0)
        np.testing.assert_allclose(J, _ridge_closed_form_jac(X, y, 10.0),
                                   atol=1e-7)

    def test_forward_mode_matches_reverse(self, rng):
        X, y = _ridge_problem(rng)

        def f(x, theta):
            r = X @ x - y
            return (jnp.sum(r ** 2) + theta * jnp.sum(x ** 2)) / 2

        F = jax.grad(f, argnums=0)

        def solver(init_x, theta):
            del init_x
            d = X.shape[1]
            return jnp.linalg.solve(X.T @ X + theta * jnp.eye(d), X.T @ y)

        Jr = jax.jacobian(custom_root(F)(solver), argnums=1)(None, 3.0)
        Jf = jax.jacfwd(custom_root_jvp(F)(solver), argnums=1)(None, 3.0)
        np.testing.assert_allclose(Jr, Jf, atol=1e-8)

    def test_multiple_theta_args_one_linear_solve(self, rng):
        """Per-coordinate ridge: theta is a vector; grads to every arg."""
        X, y = _ridge_problem(rng)
        d = X.shape[1]

        def f(x, theta_vec, offset):
            r = X @ x - y - offset
            return 0.5 * jnp.sum(r ** 2) + 0.5 * jnp.sum(theta_vec * x ** 2)

        F = jax.grad(f, argnums=0)

        @custom_root(F, tol=1e-12)
        def solver(init_x, theta_vec, offset):
            del init_x
            return jnp.linalg.solve(X.T @ X + jnp.diag(theta_vec),
                                    X.T @ (y + offset))

        tv = jnp.full((d,), 2.0)
        off = jnp.zeros(X.shape[0])
        g1, g2 = jax.grad(lambda a, b: jnp.sum(solver(None, a, b) ** 2),
                          argnums=(0, 1))(tv, off)
        # finite differences
        eps = 1e-6
        base = jnp.sum(solver(None, tv, off) ** 2)
        fd = (jnp.sum(solver(None, tv.at[0].add(eps), off) ** 2) - base) / eps
        np.testing.assert_allclose(g1[0], fd, rtol=1e-4)
        fd2 = (jnp.sum(solver(None, tv, off.at[3].add(eps)) ** 2) - base) / eps
        np.testing.assert_allclose(g2[3], fd2, rtol=1e-4)

    def test_has_aux(self, rng):
        X, y = _ridge_problem(rng)
        F = jax.grad(lambda x, t: 0.5 * jnp.sum((X @ x - y) ** 2)
                     + 0.5 * t * jnp.sum(x ** 2), argnums=0)

        @custom_root(F, has_aux=True)
        def solver(init_x, theta):
            d = X.shape[1]
            x = jnp.linalg.solve(X.T @ X + theta * jnp.eye(d), X.T @ y)
            return x, {"iters": jnp.asarray(3)}

        def loss(theta):
            x, aux = solver(None, theta)
            return jnp.sum(x ** 2)

        g = jax.grad(loss)(10.0)
        Jtrue = _ridge_closed_form_jac(X, y, 10.0)
        x_star = solver(None, 10.0)[0]
        np.testing.assert_allclose(g, 2 * x_star @ Jtrue, atol=1e-7)

    def test_init_gets_zero_gradient(self, rng):
        X, y = _ridge_problem(rng)
        F = jax.grad(lambda x, t: 0.5 * jnp.sum((X @ x - y) ** 2)
                     + 0.5 * t * jnp.sum(x ** 2), argnums=0)

        @custom_root(F)
        def solver(init_x, theta):
            d = X.shape[1]
            return jnp.linalg.solve(X.T @ X + theta * jnp.eye(d),
                                    X.T @ y) + 0.0 * init_x

        g = jax.grad(lambda i: jnp.sum(solver(i, 1.0)))(jnp.ones(X.shape[1]))
        np.testing.assert_allclose(g, 0.0, atol=1e-12)


class TestFixedPoint:

    def test_gradient_descent_fp_equals_stationary(self, rng):
        """Eq. (5): the stepsize cancels — same Jacobian as eq. (4)."""
        X, y = _ridge_problem(rng)
        d = X.shape[1]

        def f(x, theta):
            return 0.5 * jnp.sum((X @ x - y) ** 2) + \
                0.5 * theta * jnp.sum(x ** 2)

        def solver(init_x, theta):
            del init_x
            return jnp.linalg.solve(X.T @ X + theta * jnp.eye(d), X.T @ y)

        T = optimality.gradient_descent_fp(f, stepsize=0.123)
        J_fp = jax.jacobian(custom_fixed_point(T)(solver), argnums=1)(
            None, 5.0)
        np.testing.assert_allclose(J_fp, _ridge_closed_form_jac(X, y, 5.0),
                                   atol=1e-7)

    def test_contraction_fixed_point(self, rng):
        """x* = M x* + theta with ||M|| < 1: J = (I − M)⁻¹."""
        M = 0.3 * jax.random.orthogonal(rng, 6)

        def T(x, theta):
            return M @ x + theta

        def solver(init, theta):
            return jnp.linalg.solve(jnp.eye(6) - M, theta)

        J = jax.jacobian(custom_fixed_point(T)(solver), argnums=1)(
            jnp.zeros(6), jnp.ones(6))
        np.testing.assert_allclose(J, jnp.linalg.inv(jnp.eye(6) - M),
                                   atol=1e-8)

    def test_fixed_point_jvp_wrapper(self, rng):
        M = 0.3 * jax.random.orthogonal(rng, 6)

        def T(x, theta):
            return M @ x + theta

        def solver(init, theta):
            return jnp.linalg.solve(jnp.eye(6) - M, theta)

        wrapped = custom_fixed_point_jvp(T)(solver)
        v = jax.random.normal(rng, (6,))
        _, jv = jax.jvp(lambda t: wrapped(jnp.zeros(6), t),
                        (jnp.ones(6),), (v,))
        np.testing.assert_allclose(jv, jnp.linalg.solve(jnp.eye(6) - M, v),
                                   atol=1e-8)


class TestLowLevel:

    def test_root_vjp_root_jvp_consistent(self, rng):
        """<v, J u> computed both ways must agree."""
        k1, k2, k3 = jax.random.split(rng, 3)
        Q = jax.random.normal(k1, (5, 5))
        Q = Q @ Q.T + 5 * jnp.eye(5)

        def F(x, theta):
            return Q @ x - theta ** 2   # x*(θ) = Q⁻¹ θ²

        x_star = jnp.linalg.solve(Q, jnp.ones(5))
        theta = jnp.ones(5)
        v = jax.random.normal(k2, (5,))
        u = jax.random.normal(k3, (5,))
        (vjp_out,) = root_vjp(F, x_star, (theta,), v, tol=1e-12)
        jvp_out = root_jvp(F, x_star, (theta,), (u,), tol=1e-12)
        np.testing.assert_allclose(jnp.vdot(vjp_out, u),
                                   jnp.vdot(v, jvp_out), rtol=1e-8)

    def test_pytree_x_and_theta(self, rng):
        """x and theta both dict pytrees."""
        def F(x, theta):
            return {"a": 2.0 * x["a"] - theta["p"],
                    "b": 3.0 * x["b"] - theta["q"]}

        def solver(init, theta):
            return {"a": theta["p"] / 2.0, "b": theta["q"] / 3.0}

        wrapped = custom_root(F)(solver)
        theta = {"p": jnp.ones(3), "q": jnp.ones(2)}
        g = jax.grad(lambda t: jnp.sum(wrapped(None, t)["a"])
                     + jnp.sum(wrapped(None, t)["b"]))(theta)
        np.testing.assert_allclose(g["p"], 0.5, atol=1e-9)
        np.testing.assert_allclose(g["q"], 1 / 3, atol=1e-9)


class TestJacobianPrecision:
    """Theorem 1: ||J(x̂) − ∂x*|| ≤ C ||x̂ − x*|| — the Fig. 3 law."""

    def test_error_scales_linearly_with_iterate_error(self, rng):
        X, y = _ridge_problem(rng, m=30, d=8)
        d = 8
        theta = 1.0

        def f(x, theta):
            return 0.5 * jnp.sum((X @ x - y) ** 2) + \
                0.5 * theta * jnp.sum(x ** 2)

        F = jax.grad(f, argnums=0)
        x_star = jnp.linalg.solve(X.T @ X + theta * jnp.eye(d), X.T @ y)
        J_star = _ridge_closed_form_jac(X, y, theta)

        def J_at(x_hat):
            """Definition 1: solve A(x̂)J = B(x̂) at an approximate root."""
            jac_err = root_jvp(F, x_hat, (theta,), (1.0,), tol=1e-13)
            return jac_err

        errs_x, errs_j = [], []
        L = jnp.linalg.eigvalsh(X.T @ X + theta * jnp.eye(d)).max()
        x = jnp.zeros(d)
        for t in range(1, 60, 4):
            x_t = x
            for _ in range(t):
                x_t = x_t - (1.0 / L) * F(x_t, theta)
            errs_x.append(float(jnp.linalg.norm(x_t - x_star)))
            errs_j.append(float(jnp.linalg.norm(J_at(x_t) - J_star)))
        errs_x, errs_j = np.asarray(errs_x), np.asarray(errs_j)
        mask = errs_x > 1e-12
        ratio = errs_j[mask] / errs_x[mask]
        # Thm 1: ratio bounded by a constant (no blow-up as x̂ → x*)
        assert ratio.max() < 100 * ratio.min() + 1e-9
        # and the Jacobian error decreases with the iterate error
        assert errs_j[mask][-1] < errs_j[mask][0] * 1e-2
