"""Pallas kernel tests: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv_wkv.ops import wkv
from repro.kernels.rwkv_wkv.ref import wkv_scan_ref
from repro.kernels.simplex_proj.ops import projection_simplex_batched
from repro.kernels.simplex_proj.ref import projection_simplex_ref


class TestFlashAttention:

    @pytest.mark.parametrize("B,S,H,Hkv,D", [
        (2, 256, 4, 2, 64),     # GQA group 2
        (1, 128, 2, 2, 128),    # MHA, wide head
        (2, 512, 8, 2, 64),     # longer seq, group 4
        (1, 256, 4, 1, 64),     # MQA
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_reference(self, B, S, H, Hkv, D, causal):
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (B, S, H, D), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D),
                              jnp.float32)
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D),
                              jnp.float32)
        out = flash_attention(q, k, v, causal=causal, interpret=True)
        kr = jnp.repeat(k, H // Hkv, 2)
        vr = jnp.repeat(v, H // Hkv, 2)
        ref = attention_ref(q, kr, vr, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        key = jax.random.PRNGKey(1)
        q = jax.random.normal(key, (1, 128, 2, 64)).astype(dtype)
        k = jax.random.normal(jax.random.fold_in(key, 1),
                              (1, 128, 2, 64)).astype(dtype)
        v = jax.random.normal(jax.random.fold_in(key, 2),
                              (1, 128, 2, 64)).astype(dtype)
        out = flash_attention(q, k, v, interpret=True)
        ref = attention_ref(q, k, v)
        atol = 3e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=atol)
        assert out.dtype == dtype

    @pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 64),
                                                 (64, 128)])
    def test_block_shapes(self, block_q, block_k):
        key = jax.random.PRNGKey(2)
        q = jax.random.normal(key, (1, 256, 2, 64), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 2, 64),
                              jnp.float32)
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 256, 2, 64),
                              jnp.float32)
        out = flash_attention(q, k, v, block_q=block_q, block_k=block_k,
                              interpret=True)
        ref = attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5)


class TestWKV:

    @pytest.mark.parametrize("B,T,H", [(1, 64, 1), (2, 128, 3), (1, 256, 2)])
    def test_matches_reference(self, B, T, H):
        N = 64
        key = jax.random.PRNGKey(0)
        r = jax.random.normal(key, (B, T, H, N)) * 0.5
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, N)) * 0.5
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, N)) * 0.5
        w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 3),
                                             (B, T, H, N))) * 0.5 + 0.4
        u = jax.random.normal(jax.random.fold_in(key, 4), (H, N)) * 0.1
        out, sT = wkv(r, k, v, w, u, interpret=True)
        ref, sref = wkv_scan_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(sT), np.asarray(sref),
                                   atol=1e-4)

    def test_carried_state(self):
        """Two chunked calls with carried state == one long call."""
        N, B, T, H = 64, 1, 128, 2
        key = jax.random.PRNGKey(5)
        r = jax.random.normal(key, (B, T, H, N)) * 0.5
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, N)) * 0.5
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, N)) * 0.5
        w = jnp.full((B, T, H, N), 0.9)
        u = jnp.zeros((H, N))
        full, _ = wkv(r, k, v, w, u, interpret=True)
        h1, s1 = wkv(r[:, :64], k[:, :64], v[:, :64], w[:, :64], u,
                     interpret=True)
        h2, _ = wkv(r[:, 64:], k[:, 64:], v[:, 64:], w[:, 64:], u, s1,
                    interpret=True)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                                   np.asarray(full), atol=1e-4)

    @pytest.mark.parametrize("chunk", [32, 64, 128])
    def test_chunk_invariance(self, chunk):
        N, B, T, H = 64, 1, 128, 1
        key = jax.random.PRNGKey(7)
        args = [jax.random.normal(jax.random.fold_in(key, i),
                                  (B, T, H, N)) * 0.5 for i in range(3)]
        w = jnp.full((B, T, H, N), 0.95)
        u = jnp.zeros((H, N))
        out, _ = wkv(*args, w, u, chunk=chunk, interpret=True)
        ref, _ = wkv_scan_ref(*args, w, u)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4)


class TestSimplexKernel:

    @pytest.mark.parametrize("R,d", [(8, 16), (16, 33), (32, 128), (4, 5)])
    def test_matches_sort_based_oracle(self, R, d):
        key = jax.random.PRNGKey(0)
        Y = jax.random.normal(key, (R, d)) * 3
        out = projection_simplex_batched(Y, 1.0, True)
        ref = projection_simplex_ref(Y)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)

    @pytest.mark.parametrize("scale", [0.5, 1.0, 3.0])
    def test_scales(self, scale):
        Y = jax.random.normal(jax.random.PRNGKey(1), (8, 20)) * 2
        out = projection_simplex_batched(Y, scale, True)
        np.testing.assert_allclose(np.asarray(jnp.sum(out, -1)), scale,
                                   atol=1e-5)
        assert bool(jnp.all(out >= 0))

    def test_custom_jvp_matches_closed_form(self):
        # avoid kinks (coordinates exactly at the support boundary)
        y = jnp.array([0.3, -0.1, 0.8, 0.07])
        J = jax.jacfwd(
            lambda y: projection_simplex_batched(y[None], 1.0, True)[0])(y)
        Jr = jax.jacobian(projection_simplex_ref)(y)
        np.testing.assert_allclose(np.asarray(J), np.asarray(Jr), atol=1e-9)

    def test_3d_batch(self):
        Y = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 12))
        out = projection_simplex_batched(Y, 1.0, True)
        ref = projection_simplex_ref(Y)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)


class TestKernelsInsideModel:
    """use_kernel=True paths agree with the jnp reference paths."""

    def test_attention_layer_kernel_path(self):
        from repro import configs
        from repro.models import init_params, forward
        cfg = configs.get("llama3-405b", smoke=True)
        key = jax.random.PRNGKey(0)
        params = init_params(key, cfg)
        x = jax.random.randint(key, (1, 128), 0, cfg.vocab_size)
        ref, _ = forward(params, cfg, x, use_kernel=False, remat=False)
        # interpret=True is plumbed via ops default only in tests: monkey-
        # patch the op to force interpret mode on CPU.
        import repro.kernels.flash_attention.ops as fa_ops
        orig = fa_ops.flash_attention
        try:
            fa_ops.flash_attention = lambda q, k, v, causal=True: orig(
                q, k, v, causal=causal, interpret=True)
            out, _ = forward(params, cfg, x, use_kernel=True, remat=False)
        finally:
            fa_ops.flash_attention = orig
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=0.15, rtol=0.1)   # bf16 paths


class TestChunkedWKV:
    """The chunked WKV schedule (§Perf R1) must match the sequential oracle."""

    @pytest.mark.parametrize("T,chunk", [(64, 32), (128, 32), (256, 64)])
    def test_matches_sequential(self, T, chunk):
        from repro.models.rwkv import wkv_chunked
        N, B, H = 64, 2, 3
        key = jax.random.PRNGKey(0)
        r = jax.random.normal(key, (B, T, H, N)) * 0.5
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, N)) * 0.5
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, N)) * 0.5
        dec = -6.0 + jnp.tanh(jax.random.normal(jax.random.fold_in(key, 3),
                                                (B, T, H, N)))
        w = jnp.exp(-jnp.exp(dec))
        u = jax.random.normal(jax.random.fold_in(key, 4), (H, N)) * 0.1
        ref, sref = wkv_scan_ref(r, k, v, w, u)
        out, sT = wkv_chunked(r, k, v, w, u, chunk=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-4)
        np.testing.assert_allclose(np.asarray(sT), np.asarray(sref),
                                   atol=2e-4)

    def test_strong_decay_stable(self):
        """The log-space clamp keeps strong decay finite and accurate."""
        from repro.models.rwkv import wkv_chunked
        N, B, T, H = 64, 1, 64, 2
        key = jax.random.PRNGKey(5)
        r = jax.random.normal(key, (B, T, H, N)) * 0.5
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, N)) * 0.5
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, N)) * 0.5
        w = jnp.full((B, T, H, N), 0.1)   # strong decay (cum |log w| ≈ 74)
        u = jnp.zeros((H, N))
        ref, _ = wkv_scan_ref(r, k, v, w, u)
        out, _ = wkv_chunked(r, k, v, w, u, chunk=32)
        assert bool(jnp.all(jnp.isfinite(out)))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-4)

    def test_gradients_flow(self):
        from repro.models.rwkv import wkv_chunked
        N, B, T, H = 64, 1, 64, 1
        key = jax.random.PRNGKey(7)
        r = jax.random.normal(key, (B, T, H, N)) * 0.5
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, N)) * 0.5
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, N)) * 0.5
        w = jnp.full((B, T, H, N), 0.95)
        u = jnp.zeros((H, N))
        g = jax.grad(lambda k: jnp.sum(wkv_chunked(r, k, v, w, u)[0] ** 2))(k)
        assert bool(jnp.all(jnp.isfinite(g)))
        assert float(jnp.max(jnp.abs(g))) > 0
