"""Per-architecture smoke tests (assignment deliverable f).

Each assigned arch instantiates its REDUCED config and runs one forward +
train step on CPU, asserting output shapes and no NaNs.  Decode paths are
checked for causal consistency against the full forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (init_params, forward, loss_fn, init_decode_state,
                          decode_step)
from repro.models import moe as moe_lib

ALL_ARCHS = configs.names()


def _inputs(cfg, key, B=2, S=16):
    if cfg.embedding_frontend == "stub_embeddings":
        x = jax.random.normal(key, (B, S, cfg.d_model),
                              dtype=jnp.float32)
    else:
        x = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.fold_in(key, 7), (B, S), 0,
                                cfg.vocab_size)
    return x, labels


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    x, labels = _inputs(cfg, key)
    logits, aux = forward(params, cfg, x, remat=False)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))

    # one SGD train step
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, x, labels, remat=False))(params)
    assert jnp.isfinite(loss)
    new_params = jax.tree_util.tree_map(
        lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
    loss2 = loss_fn(new_params, cfg, x, labels, remat=False)
    assert jnp.isfinite(loss2)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert not bool(jnp.any(jnp.isnan(leaf)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_remat_matches_no_remat(arch):
    cfg = configs.get(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    x, labels = _inputs(cfg, key, B=1, S=8)
    l1 = loss_fn(params, cfg, x, labels, remat=False)
    l2 = loss_fn(params, cfg, x, labels, remat=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5)


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if configs.get(a).has_decoder])
def test_smoke_decode_step(arch):
    cfg = configs.get(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    x, _ = _inputs(cfg, key, B=2, S=4)
    state = init_decode_state(cfg, 2, 16)
    tok = x[:, :1]
    logits, state = decode_step(params, cfg, state, tok)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert int(state.index) == 1


@pytest.mark.parametrize("arch", [
    "llama3-405b", "qwen2.5-32b",
    pytest.param("deepseek-v2-236b", marks=pytest.mark.xfail(
        reason="pre-existing MLA latent-cache decode drift vs full forward "
               "(see ROADMAP open items)", strict=False)),
    "granite-moe-3b-a800m", "rwkv6-3b", "zamba2-7b", "qwen2-vl-72b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the full causal forward —
    validates KV caches, MLA latent caches, RWKV/Mamba recurrent states."""
    cfg = configs.get(arch, smoke=True)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, S = 2, 8
    x, _ = _inputs(cfg, key, B=B, S=S)
    full, _ = forward(params, cfg, x, remat=False)
    state = init_decode_state(cfg, B, S + 4)
    outs = []
    for t in range(S):
        tok = x[:, t:t + 1]
        lg, state = decode_step(params, cfg, state, tok)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full, np.float32),
        atol=5e-2, rtol=5e-2)   # bf16 accumulation tolerance


def test_encoder_only_has_no_decode():
    cfg = configs.get("hubert-xlarge", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError):
        decode_step(params, cfg, init_decode_state(cfg, 1, 4),
                    jnp.zeros((1, 1, cfg.d_model)))


def test_encoder_attention_is_bidirectional():
    cfg = configs.get("hubert-xlarge", smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    x, _ = _inputs(cfg, key, B=1, S=8)
    base, _ = forward(params, cfg, x, remat=False)
    # perturb the LAST frame: an encoder lets it affect position 0
    x2 = x.at[:, -1].add(1.0)
    out2, _ = forward(params, cfg, x2, remat=False)
    assert float(jnp.max(jnp.abs(out2[:, 0] - base[:, 0]))) > 0


def test_causal_lm_is_causal():
    cfg = configs.get("llama3-405b", smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    x, _ = _inputs(cfg, key, B=1, S=8)
    base, _ = forward(params, cfg, x, remat=False)
    x2 = x.at[:, -1].set((x[:, -1] + 1) % cfg.vocab_size)
    out2, _ = forward(params, cfg, x2, remat=False)
    np.testing.assert_allclose(np.asarray(out2[:, :-1], np.float32),
                               np.asarray(base[:, :-1], np.float32),
                               atol=1e-5)


def test_moe_sparse_matches_dense_dispatch():
    """Capacity-unbounded sparse dispatch == dense-gated mixture."""
    cfg = configs.get("granite-moe-3b-a800m", smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    bp = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])
    x = jax.random.normal(key, (2, 8, cfg.d_model),
                          dtype=jnp.float32).astype(jnp.bfloat16)
    dense_out, aux_d = moe_lib.moe_apply_dense(bp["mlp"], cfg, x)
    sparse_out, aux_s = moe_lib.moe_apply_sparse(bp["mlp"], cfg, x,
                                                 capacity_factor=100.0)
    np.testing.assert_allclose(np.asarray(dense_out, np.float32),
                               np.asarray(sparse_out, np.float32),
                               atol=2e-2)
    np.testing.assert_allclose(float(aux_d), float(aux_s), rtol=1e-5)


def test_moe_router_balanced_at_init():
    cfg = configs.get("granite-moe-3b-a800m", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    bp = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model)
                          ).astype(jnp.bfloat16)
    _, aux = moe_lib.moe_apply_dense(bp["mlp"], cfg, x)
    # perfectly balanced aux = k (top_k fraction routed × E);
    # near-random router at init should be within 2x
    assert float(aux) < 2.0 * cfg.moe.top_k + 1.0


def test_param_count_formula_close_to_actual():
    """Analytic 6ND input: formula within 25% of true parameter count."""
    for arch in ["llama3-405b", "granite-moe-3b-a800m", "rwkv6-3b"]:
        cfg = configs.get(arch, smoke=True)
        params = init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree_util.tree_leaves(params))
        predicted = cfg.param_count()
        assert abs(predicted - actual) / actual < 0.25, \
            (arch, predicted, actual)


def test_moe_gather_dispatch_matches_dense():
    """Gather/scatter sparse dispatch (§Perf D1) == dense-gated mixture when
    capacity is unbounded."""
    cfg = configs.get("deepseek-v2-236b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    bp = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)
                          ).astype(jnp.bfloat16)
    dense, aux_d = moe_lib.moe_apply_dense(bp["mlp"], cfg, x)
    sparse, aux_s = moe_lib.moe_apply_sparse_gather(bp["mlp"], cfg, x,
                                                    capacity_factor=100.0)
    np.testing.assert_allclose(np.asarray(dense, np.float32),
                               np.asarray(sparse, np.float32), atol=5e-2)
    np.testing.assert_allclose(float(aux_d), float(aux_s), rtol=1e-5)
    # capacity actually binds when small: outputs differ but stay finite
    tight, _ = moe_lib.moe_apply_sparse_gather(bp["mlp"], cfg, x,
                                               capacity_factor=0.5)
    assert bool(jnp.all(jnp.isfinite(tight.astype(jnp.float32))))
