"""Public-API surface snapshot for ``repro.core``.

The implicit-diff API redesign touches every layer of the package; this
snapshot pins the re-exported surface so an accidental rename, a dropped
re-export, or an unintended new public name fails CI immediately (the
fast lane runs this file first).  Update ``EXPECTED_SURFACE`` *explicitly*
when the public API changes on purpose — the diff then documents the
change in review.
"""
import importlib

import repro.core


# Names intentionally re-exported from repro.core (functions/classes), plus
# the submodules that importing repro.core necessarily binds on the package.
EXPECTED_SURFACE = {
    # pytree-native linear operators
    "LinearOperator", "JacobianOperator", "SampledJacobianOperator",
    "DenseOperator", "RidgeShifted", "BlockDiagonal", "ComposedOperator",
    "as_operator",
    # implicit-diff API (mode-polymorphic)
    "ImplicitDiffSpec", "implicit_diff",
    "custom_root", "custom_fixed_point",
    "custom_root_jvp", "custom_fixed_point_jvp",      # deprecated shims
    "root_vjp", "root_jvp",
    # solver runtime
    "IterativeSolver", "OptInfo",
    "GradientDescent", "ProximalGradient", "ProjectedGradient",
    "MirrorDescent", "BlockCoordinateDescent", "Newton", "LBFGS",
    "FixedPointIteration", "AndersonAcceleration",
    # batched linear-solve engine
    "solve", "SolverSpec", "SolveInfo",
    "register_solver", "get_solver", "get_spec", "available_solvers",
    "jacobi_preconditioner",
    "solve_cg", "solve_normal_cg", "solve_bicgstab", "solve_gmres",
    "solve_dense_gmres", "solve_lu", "solve_neumann",
    # DEQ layer
    "deq_fixed_point", "make_deq_block", "make_deq_solver",
    # submodules bound on the package by importing repro.core
    "bilevel", "diff_api", "implicit_layer", "linear_solve", "operators",
    "optimality", "projections", "prox", "solver_runtime", "solvers",
}


def test_core_public_surface_matches_snapshot():
    public = {n for n in dir(repro.core) if not n.startswith("_")}
    missing = EXPECTED_SURFACE - public
    unexpected = public - EXPECTED_SURFACE
    assert not missing, f"public names dropped from repro.core: {missing}"
    assert not unexpected, \
        f"new public names on repro.core (extend the snapshot): {unexpected}"


def test_implicit_diff_is_the_entry_point_not_the_module():
    """``repro.core.implicit_diff`` is the mode-polymorphic wrapper function
    (the submodule of the same name stays importable by full path)."""
    assert callable(repro.core.implicit_diff)
    assert not isinstance(repro.core.implicit_diff, type(importlib))
    module = importlib.import_module("repro.core.implicit_diff")
    assert module.implicit_diff is repro.core.implicit_diff


def test_registry_snapshot():
    """The built-in linear-solver registry — implicit-diff routing depends
    on these names (and their symmetry flags feed the transpose hook).
    The ``sharded_*`` names are registered here as lazy stubs (impl in
    ``repro.distributed.sharded_operators``), so the surface is identical
    whether or not the distribution layer was ever imported."""
    assert repro.core.available_solvers() == [
        "bicgstab", "cg", "dense_gmres", "gmres", "lu", "neumann",
        "normal_cg", "pallas_cg", "sharded_cg", "sharded_dense_gmres",
        "sharded_normal_cg"]
    from repro.core import linear_solve as ls
    assert ls.solver_is_symmetric("cg")
    assert ls.solver_is_symmetric("pallas_cg")
    assert ls.solver_is_symmetric("sharded_cg")
    assert not ls.solver_is_symmetric("normal_cg")
    assert not ls.solver_is_symmetric("gmres")
    assert not ls.solver_is_symmetric("sharded_normal_cg")


def test_sharded_upgrade_map_snapshot():
    """Placement-driven upgrades: classic names with a mesh-placed operand
    route to their distributed variants (and nothing else is remapped)."""
    from repro.core import linear_solve as ls
    assert ls._SHARDED_UPGRADE == {
        "cg": "sharded_cg", "normal_cg": "sharded_normal_cg",
        "dense_gmres": "sharded_dense_gmres", "pallas_cg": "sharded_cg",
        "lu": "sharded_dense_gmres"}
    # every upgrade target exists in the registry with matching symmetry
    for src, dst in ls._SHARDED_UPGRADE.items():
        assert ls.get_spec(dst).symmetric_only == \
            ls.get_spec(src).symmetric_only


def test_distributed_public_surface():
    """The distribution layer re-exports the sharded-solve seam."""
    import repro.distributed as dist
    assert callable(dist.ShardedOperator)
    assert callable(dist.SolveSharding)
    assert callable(dist.psum_reduction)
    spec = repro.core.ImplicitDiffSpec(optimality_fun=lambda x: x)
    assert spec.sharding is None          # placement is opt-in


def test_runtime_solvers_expose_diff_spec():
    """Every runtime solver can describe itself as an ImplicitDiffSpec."""
    import jax.numpy as jnp
    solver = repro.core.GradientDescent(
        lambda x, t: jnp.sum((x - t) ** 2), solve="cg", linsolve_tol=1e-9,
        ridge=1e-12)
    spec = solver.diff_spec()
    assert isinstance(spec, repro.core.ImplicitDiffSpec)
    assert spec.solve == "cg"
    assert spec.tol == 1e-9
    assert spec.ridge == 1e-12
    assert spec.has_aux       # run() returns (params, OptInfo)


def test_runtime_service_public_surface():
    """The serving layer re-exports the solve-service front end."""
    import repro.runtime as rt
    for name in ("SolveService", "ServiceResult", "WarmStartCache",
                 "BucketKey", "bucket_capacity"):
        assert callable(getattr(rt, name)), name
    # the service resolves "auto" host-side; its static policy must agree
    # with the registry resolver in the dense serving regime
    import jax.numpy as jnp
    from repro.core import DenseOperator
    from repro.core.linear_solve import _resolve_auto
    svc_cold = rt.SolveService(cache=None)
    svc_warm = rt.SolveService()
    b = jnp.ones(8)
    for pd in (True, False):
        for precond in (None, "jacobi"):
            op = DenseOperator(jnp.eye(8), symmetric=True,
                               positive_definite=pd)
            assert svc_cold._resolve_solver(pd, precond) == \
                _resolve_auto(op, b, precond, None)
            assert svc_warm._resolve_solver(pd, precond) == \
                _resolve_auto(op, b, precond, b)


def test_backward_mode_surface():
    """The approximate-backward feature's public contract: the mode tuple,
    the spec fields (with their defaults), and the polynomial apply."""
    from repro.core import linear_solve as ls
    assert ls.BACKWARD_MODES == ("exact", "one_step", "neumann_k",
                                 "jacobian_free")
    assert callable(ls.approx_inverse_apply)
    assert ls.approx_matvec_count("jacobian_free") == 0

    spec = repro.core.ImplicitDiffSpec(optimality_fun=lambda x: x)
    assert spec.backward == "exact"
    assert spec.backward_iters == 8
    assert spec.error_estimate is True
    assert spec.backward_kwargs() == {"backward": "exact",
                                      "backward_iters": 8}

    fields = set(repro.core.ImplicitDiffSpec.__dataclass_fields__)
    assert {"backward", "backward_iters", "error_estimate"} <= fields
    # info structures expose the accounting field, defaulted off
    assert ls.SolveInfo._field_defaults["hypergrad_error_estimate"] is None
    from repro.core.solver_runtime import OptInfo
    assert OptInfo._field_defaults["hypergrad_error_estimate"] is None


def test_submit_hypergrad_signature():
    """``SolveService.submit_hypergrad`` carries the approximate-backward
    selection; the deprecated decorator shims must NOT."""
    import inspect

    import repro.runtime as rt
    params = inspect.signature(rt.SolveService.submit_hypergrad).parameters
    assert "backward" in params and "backward_iters" in params

    from repro.core import custom_fixed_point, custom_root
    for fn in (custom_root, custom_fixed_point):
        p = inspect.signature(fn).parameters
        assert "backward" in p and p["backward"].default == "exact"
    # the runtime solvers default to the exact backward
    solver = repro.core.GradientDescent(lambda x, t: ((x - t) ** 2).sum())
    assert solver.backward == "exact"
    assert solver.diff_spec().backward == "exact"


def test_stochastic_public_surface():
    """The stochastic layer re-exports the data-scale solver seam, and the
    spec grew the ``system_operator`` hook it plugs into."""
    import repro.stochastic as sto
    for name in ("MinibatchSampler", "StochasticSolver", "SGD",
                 "MomentumSGD", "Adam", "run_stochastic",
                 "make_stochastic_train_step", "stochastic_data_iter"):
        assert callable(getattr(sto, name)), name
    assert sto.AVERAGING_MODES == ("polyak", "ema", "last")
    assert sto.BACKWARD_DATA_MODES == ("sampled", "full")
    # the spec hook the sampled backward rides on (None = classic path)
    fields = set(repro.core.ImplicitDiffSpec.__dataclass_fields__)
    assert "system_operator" in fields
    spec = repro.core.ImplicitDiffSpec(optimality_fun=lambda x: x)
    assert spec.system_operator is None
    # stochastic instances are IterativeSolvers (one runtime seam) and are
    # marked for the bilevel driver's error accounting
    import jax.numpy as jnp
    sampler = sto.MinibatchSampler(data=jnp.ones((4, 2)), batch_size=2)
    solver = sto.SGD(lambda x, b, t: jnp.sum(x ** 2), sampler=sampler)
    assert isinstance(solver, repro.core.IterativeSolver)
    assert solver.is_stochastic
    assert solver.backward == "neumann_k"       # truncated by default
    assert solver.precond == "jacobi"           # the PR-7 pairing
    assert solver.diff_spec().system_operator is not None


def test_bench_smoke_report_includes_stochastic_rows():
    """The committed smoke report carries the stochastic-vs-full rows with
    the cosine gate recorded."""
    import json
    import os

    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_smoke.json")
    with open(path) as f:
        report = json.load(f)
    assert report["failed"] == []
    rows = [r for r in report["rows"] if r["name"].startswith("stochastic_")]
    quad = [r for r in rows if "_sgd_" in r["name"]]
    lm = [r for r in rows if "lm_datascale" in r["name"]]
    assert quad and lm, rows
    for r in quad:
        assert "cos=" in r["derived"] and "speedup=" in r["derived"], r
    for r in lm:
        assert "cos=" in r["derived"] and "val_drop=" in r["derived"], r


def test_bench_smoke_report_includes_approx_rows():
    """The committed smoke report must be green and carry the
    error-vs-cost rows of the approximate backward modes (the fast lane
    asserts the artifact the bench lane regenerates)."""
    import json
    import os

    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_smoke.json")
    with open(path) as f:
        report = json.load(f)
    assert report["failed"] == []
    approx = [r for r in report["rows"] if r["name"].startswith(
        "approx_backward_")]
    modes_seen = {m for m in ("one_step", "neumann_k", "jacobian_free")
                  for r in approx if m in r["name"]}
    assert modes_seen == {"one_step", "neumann_k", "jacobian_free"}, approx
    for row in approx:
        if "exact" not in row["name"]:
            assert "est=" in row["derived"], row
            assert "speedup=" in row["derived"], row
    # interpret-mode Pallas rows are tagged and excluded from the summary
    interp = [r["name"] for r in report["rows"]
              if "interpret-mode" in r["derived"]]
    assert interp, "kernel micro rows lost their interpret-mode tag"
    summary = report["speedup_summary"]
    assert summary and not set(interp) & set(summary["rows"])
