"""Runtime substrate tests: optimizer, schedules, grad compression, data
pipeline, checkpointing, fault tolerance, end-to-end training loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import require_hypothesis

require_hypothesis()   # hard-fails under REPRO_REQUIRE_HYPOTHESIS (CI)
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, PrefetchIterator, SyntheticLMStream
from repro.optim import (adamw, lion, sgd, apply_updates,
                         clip_by_global_norm, schedules, grad_compression)
from repro.runtime import (TrainStepConfig, make_train_state,
                           make_train_step, run_train_loop,
                           StragglerMonitor, HeartbeatRegistry,
                           PreemptionHandler, ElasticPlan)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

class TestOptimizers:

    def _quad(self):
        Q = jnp.diag(jnp.array([1.0, 5.0, 10.0]))
        return lambda x: 0.5 * x @ Q @ x

    @pytest.mark.parametrize("make", [
        lambda: adamw(0.05, weight_decay=0.0),
        lambda: lion(0.01, weight_decay=0.0),
        lambda: sgd(0.05, momentum=0.9),
    ])
    def test_converges_on_quadratic(self, make):
        f = self._quad()
        opt = make()
        x = jnp.ones(3)
        state = opt.init(x)
        for _ in range(300):
            g = jax.grad(f)(x)
            upd, state = opt.update(g, state, x)
            x = apply_updates(x, upd)
        assert float(f(x)) < 1e-3

    def test_adamw_weight_decay_shrinks(self):
        opt = adamw(0.1, weight_decay=0.5)
        x = jnp.ones(4)
        state = opt.init(x)
        upd, state = opt.update(jnp.zeros(4), state, x)
        assert float(jnp.linalg.norm(apply_updates(x, upd))) < \
            float(jnp.linalg.norm(x))

    def test_state_tree_mirrors_params(self):
        """ZeRO property: moments share the params' tree structure (and so
        inherit their PartitionSpecs)."""
        cfg = configs.get("llama3-405b", smoke=True)
        from repro.models import init_params
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw(1e-3)
        st_ = opt.init(params)
        assert (jax.tree_util.tree_structure(st_.mu)
                == jax.tree_util.tree_structure(params))

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((4,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        np.testing.assert_allclose(float(norm), 20.0)
        np.testing.assert_allclose(
            float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-6)

    def test_schedules(self):
        s = schedules.linear_warmup_cosine(1.0, 10, 100)
        assert float(s(jnp.asarray(0))) == 0.0
        np.testing.assert_allclose(float(s(jnp.asarray(10))), 1.0)
        assert float(s(jnp.asarray(100))) < 0.2
        inv = schedules.inverse_sqrt(1.0, 10)
        np.testing.assert_allclose(float(inv(jnp.asarray(40))), 0.5)


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

class TestGradCompression:

    def test_roundtrip_error_bounded(self, rng):
        g = {"w": jax.random.normal(rng, (100,))}
        err = grad_compression.init_error_state(g)
        out, new_err = grad_compression.roundtrip(g, err)
        scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
        assert float(jnp.max(jnp.abs(out["w"] - g["w"]))) <= scale + 1e-6

    def test_error_feedback_accumulates(self, rng):
        """EF property: sum of quantized grads over steps tracks the true sum
        (bias cancels) — the reason convergence is preserved."""
        g = {"w": 0.01 * jax.random.normal(rng, (50,))}
        err = grad_compression.init_error_state(g)
        total_q = jnp.zeros(50)
        for _ in range(50):
            out, err = grad_compression.roundtrip(g, err)
            total_q = total_q + out["w"]
        true_total = 50 * g["w"]
        # relative error of accumulated signal far below one-step quant error
        rel = float(jnp.linalg.norm(total_q - true_total)
                    / jnp.linalg.norm(true_total))
        assert rel < 0.02

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2 ** 16))
    def test_property_compression_4x(self, seed):
        g = jax.random.normal(jax.random.PRNGKey(seed), (4096,))
        c = grad_compression._quantize(g)
        raw = g.size * 4
        comp = c.q.size * 1 + c.scale.size * 4
        assert comp * 3 < raw        # > 3x reduction


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

class TestData:

    def test_deterministic_replay(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=3)
        s = SyntheticLMStream(cfg)
        x1, y1 = s.batch_at(7)
        x2, y2 = s.batch_at(7)
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)

    def test_host_sharding_partitions_batch(self):
        h0 = SyntheticLMStream(DataConfig(vocab_size=100, seq_len=8,
                                          global_batch=8, num_hosts=2,
                                          host_id=0))
        h1 = SyntheticLMStream(DataConfig(vocab_size=100, seq_len=8,
                                          global_batch=8, num_hosts=2,
                                          host_id=1))
        assert h0.local_batch == 4 and h1.local_batch == 4
        x0, _ = h0.batch_at(0)
        x1, _ = h1.batch_at(0)
        assert x0.shape == (4, 8)
        assert not np.array_equal(x0, x1)     # different shards

    def test_labels_are_next_tokens(self):
        s = SyntheticLMStream(DataConfig(vocab_size=50, seq_len=12,
                                         global_batch=2))
        x, y = s.batch_at(0)
        np.testing.assert_array_equal(x[:, 1:], y[:, :-1])

    def test_prefetch_iterator(self):
        s = SyntheticLMStream(DataConfig(vocab_size=50, seq_len=8,
                                         global_batch=2))
        it = PrefetchIterator(s, start_step=0)
        try:
            step0, (x0, _) = next(it)
            step1, _ = next(it)
            assert (step0, step1) == (0, 1)
            np.testing.assert_array_equal(x0, s.batch_at(0)[0])
        finally:
            it.close()


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

class TestCheckpoint:

    def _tree(self, key):
        return {"w": jax.random.normal(key, (8, 4)),
                "opt": {"mu": jnp.ones((8, 4)), "step": jnp.asarray(5)}}

    def test_save_restore_roundtrip(self, tmp_path, rng):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = self._tree(rng)
        mgr.save(100, tree, blocking=True)
        target = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
        restored = mgr.restore(100, target)
        np.testing.assert_array_equal(restored["w"], tree["w"])
        assert int(restored["opt"]["step"]) == 5

    def test_keep_n_gc(self, tmp_path, rng):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = self._tree(rng)
        for s in [1, 2, 3, 4]:
            mgr.save(s, tree, blocking=True)
        assert mgr.all_steps() == [3, 4]

    def test_atomic_no_partial_checkpoints(self, tmp_path, rng):
        """A .tmp dir (simulated crash mid-write) is never listed."""
        mgr = CheckpointManager(str(tmp_path), keep=3)
        os.makedirs(os.path.join(str(tmp_path), "step_9.tmp"))
        mgr.save(1, self._tree(rng), blocking=True)
        assert mgr.all_steps() == [1]

    def test_shape_mismatch_rejected(self, tmp_path, rng):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"w": jnp.ones((4,))}, blocking=True)
        with pytest.raises(ValueError, match="mismatch"):
            mgr.restore(1, {"w": jax.ShapeDtypeStruct((5,), jnp.float32)})

    def test_async_save(self, tmp_path, rng):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(7, self._tree(rng), blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 7


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

class TestFaultTolerance:

    def test_straggler_detection(self):
        mon = StragglerMonitor(window=10, threshold=1.5)
        for step in range(10):
            for host in range(8):
                mon.record(step, 0.1 if host != 3 else 0.25, host=host)
        assert mon.stragglers() == [3]

    def test_no_false_positives(self):
        mon = StragglerMonitor()
        for step in range(10):
            for host in range(8):
                mon.record(step, 0.1 + 0.001 * host, host=host)
        assert mon.stragglers() == []

    def test_heartbeat_failure_detection(self):
        t = [0.0]
        reg = HeartbeatRegistry(timeout=10.0, clock=lambda: t[0])
        for h in range(4):
            reg.ping(h)
        t[0] = 5.0
        reg.ping(0); reg.ping(1); reg.ping(2)   # host 3 goes silent
        t[0] = 12.0
        assert reg.failed_hosts() == [3]
        assert sorted(reg.healthy_hosts()) == [0, 1, 2]

    def test_preemption_handler(self):
        h = PreemptionHandler()
        assert not h()
        h.preempt()
        assert h()

    def test_elastic_plan(self):
        plan = ElasticPlan(old_data=16, old_model=16)
        nd, nm = plan.survivor_mesh(failed_fraction=0.1)
        assert nm == 16 and nd < 16 and 16 % nd == 0
        assert plan.batch_scale(0.1) == nd / 16


# ---------------------------------------------------------------------------
# End-to-end training loop (smoke config, real loop with checkpoint/resume)
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestTrainLoopE2E:

    def test_loss_decreases_and_resume_is_exact(self, tmp_path):
        cfg = configs.get("qwen1.5-4b", smoke=True)
        optimizer = adamw(3e-3, weight_decay=0.0)
        tcfg = TrainStepConfig(microbatches=1, remat=False)
        step_fn = jax.jit(make_train_step(cfg, optimizer, tcfg))
        state = make_train_state(cfg, optimizer, jax.random.PRNGKey(0))
        stream = SyntheticLMStream(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))

        def data_iter(start=0):
            step = start
            while True:
                yield step, stream.batch_at(step)
                step += 1

        mgr = CheckpointManager(str(tmp_path), keep=2)
        state, hist = run_train_loop(
            step_fn, state, data_iter(), num_steps=30,
            checkpoint_manager=mgr, checkpoint_every=10, log_every=1)
        losses = [h["loss"] for h in hist]
        assert losses[-1] < losses[0]          # learns the synthetic structure
        assert mgr.latest_step() == 30

        # resume from step 20 and replay to 30: identical final loss
        target = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
        restored = mgr.restore(20, target)
        state2, hist2 = run_train_loop(
            step_fn, restored, data_iter(20), num_steps=10,
            log_every=1, start_step=20)
        np.testing.assert_allclose(hist2[-1]["loss"], losses[-1],
                                   rtol=1e-4)

    def test_preemption_checkpoints_and_stops(self, tmp_path):
        cfg = configs.get("qwen1.5-4b", smoke=True)
        optimizer = adamw(1e-3)
        step_fn = jax.jit(make_train_step(cfg, optimizer,
                                          TrainStepConfig(remat=False)))
        state = make_train_state(cfg, optimizer, jax.random.PRNGKey(0))
        stream = SyntheticLMStream(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=16, global_batch=2))

        def data_iter():
            step = 0
            while True:
                yield step, stream.batch_at(step)
                step += 1

        handler = PreemptionHandler()
        calls = {"n": 0}

        def flag():
            calls["n"] += 1
            if calls["n"] == 3:
                handler.preempt()
            return handler()

        mgr = CheckpointManager(str(tmp_path))
        state, hist = run_train_loop(
            step_fn, state, data_iter(), num_steps=100,
            checkpoint_manager=mgr, checkpoint_every=1000,
            preemption_flag=flag, log_every=1)
        assert len(hist) == 3                  # stopped early
        assert mgr.latest_step() == 3          # checkpointed at preemption

    def test_grad_compression_training_still_converges(self):
        cfg = configs.get("qwen1.5-4b", smoke=True)
        optimizer = adamw(3e-3, weight_decay=0.0)
        tcfg = TrainStepConfig(remat=False, compress_grads=True)
        step_fn = jax.jit(make_train_step(cfg, optimizer, tcfg))
        state = make_train_state(cfg, optimizer, jax.random.PRNGKey(0),
                                 compress=True)
        stream = SyntheticLMStream(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))
        losses = []
        for step in range(25):
            x, y = stream.batch_at(step)
            state, m = step_fn(state, x, y)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]

    def test_microbatched_step_matches_full_batch(self):
        """Grad accumulation must be loss/grad-equivalent to the full batch."""
        cfg = configs.get("llama3-405b", smoke=True)
        optimizer = sgd(1e-2, momentum=0.0)
        s1 = make_train_state(cfg, optimizer, jax.random.PRNGKey(0))
        s2 = jax.tree_util.tree_map(lambda a: a, s1)
        stream = SyntheticLMStream(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=16, global_batch=8))
        x, y = stream.batch_at(0)
        full = jax.jit(make_train_step(
            cfg, optimizer, TrainStepConfig(microbatches=1, remat=False)))
        micro = jax.jit(make_train_step(
            cfg, optimizer, TrainStepConfig(microbatches=4, remat=False)))
        s1, m1 = full(s1, x, y)
        s2, m2 = micro(s2, x, y)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=2e-2)
        w1 = jax.tree_util.tree_leaves(s1.params)[0]
        w2 = jax.tree_util.tree_leaves(s2.params)[0]
        np.testing.assert_allclose(np.asarray(w1, np.float32),
                                   np.asarray(w2, np.float32), atol=1e-2)
