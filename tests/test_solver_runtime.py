"""Tests for the unified state-based solver runtime.

Covers the PR's acceptance criteria:
  * every solver runs through the shared ``run()`` driver and its implicit
    gradients match the previous hand-wrapped ``@custom_root`` /
    ``@custom_fixed_point`` path to machine precision;
  * ``jax.vmap`` of a full inner solve runs as one batched masked loop with
    per-instance ``OptInfo`` and produces ONE batched backward linear solve;
  * honest convergence: ``OptInfo.converged`` is NaN-aware and maxiter-aware.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AndersonAcceleration, BlockCoordinateDescent,
                        FixedPointIteration, GradientDescent, LBFGS,
                        MirrorDescent, Newton, ProjectedGradient,
                        ProximalGradient, custom_fixed_point, custom_root,
                        optimality, projections, prox)
from repro.core import linear_solve as ls


def _ridge_problem(key, m=20, d=5):
    kx, ky = jax.random.split(key)
    X = jax.random.normal(kx, (m, d))
    y = jax.random.normal(ky, (m,))
    return X, y


def _hand_wrapped_grad(raw_solver, F, init, theta, *, fixed_point=False,
                       solve="normal_cg", tol=1e-6):
    """The pre-runtime composition: manual decorator around a bare solver."""
    deco = (custom_fixed_point if fixed_point else custom_root)(
        F, solve=solve, tol=tol)
    wrapped = deco(raw_solver)
    return jax.grad(lambda t: jnp.sum(wrapped(init, t) ** 2))(theta)


def _runtime_grad(solver, init, theta):
    return jax.grad(lambda t: jnp.sum(solver.run(init, t)[0] ** 2))(theta)


class TestGradMatchesHandWrapped:
    """run()'s self-attached implicit diff == the legacy manual wrap,
    solver by solver, to machine precision (same F, same linear solve)."""

    def test_gradient_descent(self, rng):
        X, y = _ridge_problem(rng)

        def f(x, theta):
            return 0.5 * jnp.sum((X @ x - y) ** 2) + \
                0.5 * theta * jnp.sum(x ** 2)

        L = float(jnp.linalg.eigvalsh(X.T @ X).max()) + 2.0
        solver = GradientDescent(f, stepsize=1.0 / L, maxiter=5000,
                                 tol=1e-13)
        raw = GradientDescent(f, stepsize=1.0 / L, maxiter=5000, tol=1e-13,
                              implicit_diff=False)
        g_rt = _runtime_grad(solver, jnp.zeros(5), 1.0)
        g_hand = _hand_wrapped_grad(
            lambda init, t: raw.run(init, t)[0], jax.grad(f, argnums=0),
            jnp.zeros(5), 1.0)
        np.testing.assert_allclose(g_rt, g_hand, rtol=1e-14)

    def test_lbfgs_instance_reused_across_structures(self, rng):
        """One solver instance on two problems with different pytree
        structures: the cached unravel closure must rebuild, not unravel
        problem B's flat iterate with problem A's structure."""
        def f(tree, t):
            leaves = jax.tree_util.tree_leaves(tree)
            return sum(0.5 * jnp.sum((leaf - t) ** 2) for leaf in leaves)

        solver = LBFGS(f, maxiter=200, tol=1e-12, stepsize=0.5)
        xa, _ = solver.run({"a": jnp.zeros(3)}, 2.0)
        xb, _ = solver.run({"u": jnp.zeros((2, 2)), "v": jnp.zeros(5)}, 3.0)
        np.testing.assert_allclose(xa["a"], 2.0, atol=1e-8)
        np.testing.assert_allclose(xb["u"], 3.0, atol=1e-8)
        np.testing.assert_allclose(xb["v"], 3.0, atol=1e-8)
        # and back to the first structure
        xa2, _ = solver.run({"a": jnp.zeros(3)}, 4.0)
        np.testing.assert_allclose(xa2["a"], 4.0, atol=1e-8)

    def test_newton_and_lbfgs(self, rng):
        X, y = _ridge_problem(rng)

        def f(x, theta):
            return 0.5 * jnp.sum((X @ x - y) ** 2) + \
                0.5 * theta * jnp.sum(x ** 2)

        F = jax.grad(f, argnums=0)
        for solver in (Newton(f, maxiter=30, tol=1e-12),
                       LBFGS(f, maxiter=400, tol=1e-12, stepsize=0.02)):
            raw_cls = type(solver)
            kwargs = dict(maxiter=solver.maxiter, tol=solver.tol,
                          stepsize=solver.stepsize, implicit_diff=False)
            raw = raw_cls(f, **kwargs)
            g_rt = _runtime_grad(solver, jnp.zeros(5), 1.0)
            g_hand = _hand_wrapped_grad(
                lambda init, t: raw.run(init, t)[0], F, jnp.zeros(5), 1.0)
            np.testing.assert_allclose(g_rt, g_hand, rtol=1e-14)

    def test_proximal_gradient(self, rng):
        X, y = _ridge_problem(rng)
        L = float(jnp.linalg.eigvalsh(X.T @ X).max())

        def f(x, theta_f):
            del theta_f
            return 0.5 * jnp.sum((X @ x - y) ** 2)

        pr = lambda v, lam, s: prox.prox_lasso(v, lam, s)
        solver = ProximalGradient(f, pr, stepsize=1.0 / L, maxiter=20000,
                                  tol=1e-14)
        raw = ProximalGradient(f, pr, stepsize=1.0 / L, maxiter=20000,
                               tol=1e-14, implicit_diff=False)
        T = optimality.proximal_gradient_fp(f, pr, stepsize=1.0 / L)
        lam = 0.5
        g_rt = jax.grad(
            lambda l: jnp.sum(solver.run(jnp.zeros(5), (None, l))[0] ** 2))(
                lam)
        deco = custom_fixed_point(T, solve="normal_cg", tol=1e-6)
        wrapped = deco(lambda init, th: raw.run(init, th)[0])
        g_hand = jax.grad(
            lambda l: jnp.sum(wrapped(jnp.zeros(5), (None, l)) ** 2))(lam)
        np.testing.assert_allclose(g_rt, g_hand, rtol=1e-14)

    def test_projected_gradient_and_mirror_descent(self, rng):
        theta0 = jnp.array([0.2, 0.8, 0.4])

        def f(x, theta_f):
            return 0.5 * jnp.sum((x - theta_f) ** 2)

        proj_e = lambda v, tp: projections.projection_simplex(v)
        proj_kl = lambda v, tp: projections.projection_simplex_kl(v)
        init = jnp.ones(3) / 3

        pg = ProjectedGradient(f, proj_e, stepsize=0.5, maxiter=5000,
                               tol=1e-14)
        raw_pg = ProjectedGradient(f, proj_e, stepsize=0.5, maxiter=5000,
                                   tol=1e-14, implicit_diff=False)
        T_pg = optimality.projected_gradient_fp(f, proj_e, stepsize=0.5)
        g_rt = jax.grad(
            lambda t: jnp.sum(pg.run(init, (t, None))[0] ** 2))(theta0)
        g_hand = _hand_wrapped_grad(
            lambda i, t: raw_pg.run(i, t)[0], T_pg, init, (theta0, None),
            fixed_point=True)[0]
        np.testing.assert_allclose(g_rt, g_hand, rtol=1e-14)

        md = MirrorDescent(f, proj_kl, stepsize=0.9, maxiter=5000, tol=1e-13)
        raw_md = MirrorDescent(f, proj_kl, stepsize=0.9, maxiter=5000,
                               tol=1e-13, implicit_diff=False)
        T_md = optimality.mirror_descent_fp(f, proj_kl,
                                            optimality.kl_phi_grad,
                                            stepsize=0.9)
        g_rt = jax.grad(
            lambda t: jnp.sum(md.run(init, (t, None))[0] ** 2))(theta0)
        g_hand = _hand_wrapped_grad(
            lambda i, t: raw_md.run(i, t)[0], T_md, init, (theta0, None),
            fixed_point=True)[0]
        np.testing.assert_allclose(g_rt, g_hand, rtol=1e-13)

    def test_block_coordinate_descent(self, rng):
        X = jax.random.normal(rng, (12, 4))
        y = jnp.ones(12)
        L = float(jnp.linalg.eigvalsh(X.T @ X).max())

        def f(x, theta_f):
            del theta_f
            return 0.5 * jnp.sum((X @ x.ravel() - y) ** 2)

        pr = lambda v, lam, s: prox.prox_lasso(v, lam, s)
        init = jnp.zeros((2, 2))
        solver = BlockCoordinateDescent(f, pr, stepsize=1.0 / L,
                                        maxiter=5000, tol=1e-14)
        raw = BlockCoordinateDescent(f, pr, stepsize=1.0 / L, maxiter=5000,
                                     tol=1e-14, implicit_diff=False)
        lam = 0.1
        g_rt = jax.grad(
            lambda l: jnp.sum(solver.run(init, (None, l))[0] ** 2))(lam)
        deco = custom_fixed_point(solver.fixed_point_fun, solve="normal_cg",
                                  tol=1e-6)
        wrapped = deco(lambda i, th: raw.run(i, th)[0])
        g_hand = jax.grad(
            lambda l: jnp.sum(wrapped(init, (None, l)) ** 2))(lam)
        np.testing.assert_allclose(g_rt, g_hand, rtol=1e-14)

    def test_fixed_point_and_anderson(self, rng):
        M = 0.5 * jax.random.orthogonal(rng, 4)

        def T(x, theta):
            return M @ x + theta

        for solver, raw in [
                (FixedPointIteration(T, maxiter=500, tol=1e-13),
                 FixedPointIteration(T, maxiter=500, tol=1e-13,
                                     implicit_diff=False)),
                (AndersonAcceleration(T, maxiter=100, tol=1e-13),
                 AndersonAcceleration(T, maxiter=100, tol=1e-13,
                                      implicit_diff=False))]:
            g_rt = _runtime_grad(solver, jnp.zeros(4), jnp.ones(4))
            g_hand = _hand_wrapped_grad(
                lambda i, t: raw.run(i, t)[0], T, jnp.zeros(4), jnp.ones(4),
                fixed_point=True)
            np.testing.assert_allclose(g_rt, g_hand, rtol=1e-14)


class TestVmapFullSolve:
    """jax.vmap of a whole inner solve: one masked loop, one backward solve."""

    def _make(self, rng, solve="cg"):
        X, y = _ridge_problem(rng, m=16, d=4)

        def f(x, theta):
            return 0.5 * jnp.sum((X @ x - y) ** 2) + \
                0.5 * theta * jnp.sum(x ** 2)

        L = float(jnp.linalg.eigvalsh(X.T @ X).max()) + 4.0
        solver = GradientDescent(f, stepsize=1.0 / L, maxiter=4000,
                                 tol=1e-12, solve=solve)
        loss = lambda t: jnp.sum(solver.run(jnp.zeros(4), t)[0] ** 2)
        return solver, loss

    def test_one_batched_backward_linear_solve(self, rng):
        """The acceptance assertion: under vmap the backward pass EXECUTES
        exactly ONE (batched) registry solve — never N per-instance solves —
        and matches the python loop.  Trace census: the mode-polymorphic
        wrapper stages one registry template per direction (tangent +
        transpose), independent of batch size; only one direction runs."""
        traced, executed = [], []

        def counting_cg(matvec, b, **kw):
            traced.append(1)
            jax.debug.callback(lambda _: executed.append(1), jnp.zeros(()))
            return ls.solve_cg(matvec, b, **kw)

        ls.register_solver("counting_cg", counting_cg, symmetric_only=True,
                           supports_precond=True)
        try:
            _, loss = self._make(rng, solve="counting_cg")
            thetas = jnp.array([0.5, 1.0, 2.0, 4.0])
            traced.clear(), executed.clear()
            g_vmap = jax.vmap(jax.grad(loss))(thetas)
            jax.effects_barrier()
            assert len(traced) == 2, \
                f"expected 2 staged direction templates, traced {len(traced)}"
            assert len(executed) == 1, \
                f"expected ONE batched backward solve, ran {len(executed)}"
            traced.clear(), executed.clear()
            g_loop = jnp.stack([jax.grad(loss)(t) for t in thetas])
            jax.effects_barrier()
            # the loop really solves N times
            assert len(executed) == len(thetas)
        finally:
            ls._REGISTRY.pop("counting_cg", None)
        np.testing.assert_allclose(g_vmap, g_loop, rtol=1e-12)

    def test_vmap_matches_solo_runs_exactly(self, rng):
        """Masked freezing: each instance's batched result is its solo run."""
        solver, _ = self._make(rng)
        thetas = jnp.array([0.5, 1.0, 8.0])
        xs, infos = jax.vmap(lambda t: solver.run(jnp.zeros(4), t))(thetas)
        for i, t in enumerate(thetas):
            x_solo, info_solo = solver.run(jnp.zeros(4), t)
            # identical algorithm path (exact iteration counts); values agree
            # to rounding (batched XLA schedules ops slightly differently)
            np.testing.assert_allclose(np.asarray(xs[i]), np.asarray(x_solo),
                                       rtol=1e-14, atol=1e-15)
            assert int(infos.iterations[i]) == int(info_solo.iterations)
        # better-conditioned instances converge in fewer masked iterations
        assert int(infos.iterations[2]) < int(infos.iterations[0])

    def test_vmap_linesearch_matches_solo(self, rng):
        """The backtracking inner loop is masked too."""
        Q = jnp.diag(jnp.array([1.0, 50.0]))

        def f(x, theta):
            return 0.5 * x @ Q @ x - theta @ x

        solver = GradientDescent(f, stepsize=1.0, linesearch=True,
                                 maxiter=2000, tol=1e-10,
                                 implicit_diff=False)
        thetas = jnp.stack([jnp.array([1.0, 2.0]), jnp.array([-3.0, 0.5])])
        xs, infos = jax.vmap(lambda t: solver.run(jnp.ones(2), t))(thetas)
        for i in range(2):
            x_solo, info_solo = solver.run(jnp.ones(2), thetas[i])
            np.testing.assert_allclose(np.asarray(xs[i]), np.asarray(x_solo),
                                       rtol=1e-14, atol=1e-15)
            assert int(infos.iterations[i]) == int(info_solo.iterations)


class TestBackwardSolveRouting:
    """solve= / precond= / ridge= flow from the solver constructor through
    custom_root to the SolverSpec registry."""

    def test_precond_and_ridge_reach_registry_solver(self, rng):
        seen = {}

        def spy_cg(matvec, b, **kw):
            seen.update(kw)
            return ls.solve_cg(matvec, b, **kw)

        ls.register_solver("spy_cg", spy_cg, symmetric_only=True,
                           supports_precond=True)
        try:
            X, y = _ridge_problem(rng, m=12, d=3)

            def f(x, theta):
                return 0.5 * jnp.sum((X @ x - y) ** 2) + \
                    0.5 * theta * jnp.sum(x ** 2)

            L = float(jnp.linalg.eigvalsh(X.T @ X).max()) + 2.0
            solver = GradientDescent(f, stepsize=1.0 / L, maxiter=2000,
                                     tol=1e-12, solve="spy_cg",
                                     precond="jacobi", ridge=1e-10,
                                     linsolve_tol=1e-9, linsolve_maxiter=77)
            g = jax.grad(
                lambda t: jnp.sum(solver.run(jnp.zeros(3), t)[0] ** 2))(1.0)
            assert jnp.isfinite(g)
            # "jacobi" is resolved by the diff layer from the implicit
            # system operator's diagonal(); the registry solver receives
            # the derived callable M⁻¹, never a silently dropped string
            assert callable(seen["precond"])
            assert seen["ridge"] == 1e-10
            assert seen["tol"] == 1e-9
            assert seen["maxiter"] == 77
        finally:
            ls._REGISTRY.pop("spy_cg", None)

    def test_unsupported_precond_raises(self, rng):
        solver = FixedPointIteration(lambda x, t: 0.5 * x + t, maxiter=100,
                                     tol=1e-12, solve="neumann",
                                     precond="jacobi")
        with pytest.raises(ValueError, match="precond"):
            jax.grad(lambda t: jnp.sum(
                solver.run(jnp.zeros(2), t)[0] ** 2))(jnp.ones(2))


class TestOptInfo:
    """Honest convergence semantics, mirroring SolveInfo."""

    def test_converged_true_within_budget(self, rng):
        M = 0.3 * jax.random.orthogonal(rng, 4)
        solver = FixedPointIteration(lambda x: M @ x + 1.0, maxiter=500,
                                     tol=1e-12, implicit_diff=False)
        x, info = solver.run(jnp.zeros(4))
        assert bool(info.converged)
        assert 0 < int(info.iterations) < 500
        assert float(info.error) <= 1e-12

    def test_maxiter_exhaustion_reports_unconverged(self, rng):
        M = 0.99 * jax.random.orthogonal(rng, 4)   # slow contraction
        solver = FixedPointIteration(lambda x: M @ x + 1.0, maxiter=3,
                                     tol=1e-12, implicit_diff=False)
        _, info = solver.run(jnp.zeros(4))
        assert not bool(info.converged)
        assert int(info.iterations) == 3

    def test_nan_iteration_is_never_converged(self):
        """A NaN-producing map must stop AND report converged=False — the
        legacy loop silently exited with err=NaN looking 'done'."""
        solver = FixedPointIteration(lambda x: x * jnp.nan, maxiter=100,
                                     tol=1e-8, implicit_diff=False)
        x, info = solver.run(jnp.ones(3))
        assert not bool(info.converged)
        assert jnp.isnan(info.error)
        assert int(info.iterations) == 1   # stopped immediately, honestly

    def test_divergent_gd_reports_unconverged(self, rng):
        X, y = _ridge_problem(rng, m=10, d=3)

        def f(x, theta):
            return 0.5 * jnp.sum((X @ x - y) ** 2) + \
                0.5 * theta * jnp.sum(x ** 2)

        solver = GradientDescent(f, stepsize=10.0, maxiter=500, tol=1e-10,
                                 implicit_diff=False)   # wildly too large
        _, info = solver.run(jnp.zeros(3), 1.0)
        assert not bool(info.converged)

    def test_info_is_nondiff_aux(self, rng):
        X, y = _ridge_problem(rng, m=10, d=3)

        def f(x, theta):
            return 0.5 * jnp.sum((X @ x - y) ** 2) + \
                0.5 * theta * jnp.sum(x ** 2)

        L = float(jnp.linalg.eigvalsh(X.T @ X).max()) + 2.0
        solver = GradientDescent(f, stepsize=1.0 / L, maxiter=2000,
                                 tol=1e-12)
        g = jax.grad(lambda t: jnp.sum(solver.run(jnp.zeros(3), t)[0] ** 2))(
            1.0)
        assert jnp.isfinite(g)


class TestLegacyShims:
    """The deprecated functional factories still match the runtime classes."""

    def test_shim_equals_class(self, rng):
        from repro.core import diff_api, solvers
        Q = jnp.diag(jnp.array([1.0, 4.0, 9.0]))

        def f(x, theta):
            return 0.5 * x @ Q @ x - theta @ x

        theta = jnp.array([1.0, 2.0, 3.0])
        # deprecation warnings are one-shot per process; reset so this test
        # observes one regardless of which test touched the shims first
        diff_api.reset_deprecation_warnings()
        with pytest.deprecated_call():
            x_shim = solvers.gradient_descent(f, jnp.zeros(3), theta,
                                              stepsize=0.1, maxiter=5000,
                                              tol=1e-12)
        x_cls, _ = GradientDescent(f, stepsize=0.1, maxiter=5000, tol=1e-12,
                                   implicit_diff=False).run(jnp.zeros(3),
                                                            theta)
        np.testing.assert_array_equal(np.asarray(x_shim), np.asarray(x_cls))

    def test_bilevel_accepts_runtime_solver(self, rng):
        from repro.core import bilevel
        k1, k2 = jax.random.split(rng)
        X = jax.random.normal(k1, (20, 4))
        y = jax.random.normal(k2, (20,))

        def inner_obj(x, lam):
            return 0.5 * jnp.sum((X @ x - y) ** 2) + \
                0.5 * jnp.exp(lam) * jnp.sum(x ** 2)

        def outer_loss(x, lam):
            return jnp.sum(x ** 2)

        L = float(jnp.linalg.eigvalsh(X.T @ X).max()) + 2.0
        inner = GradientDescent(inner_obj, stepsize=1.0 / L, maxiter=3000,
                                tol=1e-12)
        sol = bilevel.solve_bilevel(outer_loss, inner, 0.3, jnp.zeros(4),
                                    outer_steps=3, outer_lr=0.1)
        assert sol.inner_info is not None
        assert bool(sol.inner_info.converged)
        assert sol.outer_values[-1] <= sol.outer_values[0]

    def test_make_implicit_inner_multi_theta(self, rng):
        """Regression: the callable path keeps the *theta contract."""
        from repro.core import bilevel
        k1, k2 = jax.random.split(rng)
        X = jax.random.normal(k1, (15, 3))
        y = jax.random.normal(k2, (15,))

        def obj(x, lam, mu):
            return 0.5 * jnp.sum((X @ x - y - mu) ** 2) + \
                0.5 * jnp.exp(lam) * jnp.sum(x ** 2)

        def raw(init, lam, mu):
            return jnp.linalg.solve(X.T @ X + jnp.exp(lam) * jnp.eye(3),
                                    X.T @ (y + mu))

        fn = bilevel.make_implicit_inner(raw, inner_objective=obj, tol=1e-12)
        g_lam, g_mu = jax.grad(
            lambda a, b: jnp.sum(fn(None, a, b) ** 2),
            argnums=(0, 1))(0.3, jnp.zeros(15))
        assert jnp.isfinite(g_lam)
        assert bool(jnp.isfinite(g_mu).all())

    def test_solve_bilevel_zero_outer_steps(self, rng):
        """Regression: outer_steps=0 returns the init, not a crash."""
        from repro.core import bilevel

        def f(x, t):
            return 0.5 * jnp.sum((x - t) ** 2)

        solver = GradientDescent(f, stepsize=0.5, maxiter=100, tol=1e-10)
        sol = bilevel.solve_bilevel(lambda x, t: jnp.sum(x ** 2), solver,
                                    jnp.ones(2), jnp.zeros(2),
                                    outer_steps=0)
        assert sol.inner_info is None
        np.testing.assert_array_equal(np.asarray(sol.x_star), 0.0)
