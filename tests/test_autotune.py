"""Autotuned dispatch: tuning cache, cost model, crossover gating.

Decision tests seed the ``TuningCache`` explicitly, so they are
deterministic at any device count; the paths that build a real 8-extent
mesh are guarded on the process device count (the CI multidevice lane
forces ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  The
persistence tests mirror the ``WarmStartCache`` save/load suite:
round-trip, version rejection, and the env-var pre-load that ships a
pre-tuned cache with a deployment.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.analysis import autotune, roofline
from repro.core import linear_solve as ls
from repro.core import operators as ops
from repro.distributed.sharded_operators import ShardedOperator
from repro.launch.mesh import auto_mesh_size, make_solve_mesh

N_DEV = len(jax.devices())
BACKEND = jax.default_backend()

needs_8 = pytest.mark.skipif(
    N_DEV < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_"
                      "device_count=8 (the CI multidevice lane)")


def _key(solver, B, d, mesh_size=1, variant=""):
    return autotune.TuningKey(BACKEND, solver, B, d, "float32", mesh_size,
                              "", variant)


def _seeded(B, d, *, sharded_loses, mesh_sizes=(2, 4, 8), spd=True):
    """A cache where every sharded candidate measures 2x worse (or 2x
    better) than the measured single-device route."""
    cache = autotune.TuningCache()
    single = autotune.single_device_solver(spd, d)
    sharded = "sharded_cg" if spd else "sharded_normal_cg"
    cache.put(_key(single, B, d), 1e-3)
    for m in mesh_sizes:
        cache.put(_key(sharded, B, d, mesh_size=m),
                  2e-3 if sharded_loses else 5e-4)
    return cache


def _spd_batch(B, d, seed=0):
    # explicit float32: the repo enables x64, and the regime dtype is part
    # of the TuningKey the seeded caches are written under
    rng = np.random.RandomState(seed)
    C = rng.randn(B, d, d) / np.sqrt(d)
    A = np.einsum("bji,bjk->bik", C, C) + 0.5 * np.eye(d)
    return jnp.asarray(A, jnp.float32)


# ---------------------------------------------------------------------------
# TuningCache persistence (the WarmStartCache pattern)
# ---------------------------------------------------------------------------

class TestTuningCache:

    def test_put_get_lookup(self):
        cache = autotune.TuningCache()
        rec = cache.put(_key("cg", 8, 4), 1.5e-3, samples=5)
        assert cache.get(_key("cg", 8, 4)) == rec
        assert cache.lookup(backend=BACKEND, solver="cg", B=8, d=4) == rec
        assert cache.get(_key("cg", 8, 5)) is None
        assert len(cache) == 1 and _key("cg", 8, 4) in cache

    def test_save_load_round_trip(self, tmp_path):
        cache = autotune.TuningCache()
        cache.put(_key("pallas_cg", 64, 16), 4.2e-4)
        cache.put(_key("sharded_cg", 64, 16, mesh_size=8), 1.3e-3,
                  source="measured", samples=7)
        cache.put(_key("batched_cg", 16, 8, variant="block_b=16"), 2e-5)
        path = cache.save(tmp_path / "tuned")       # .json appended
        assert path.endswith(".json")
        restored = autotune.TuningCache.load(path)
        assert restored.items() == cache.items()
        rec = restored.get(_key("sharded_cg", 64, 16, mesh_size=8))
        assert rec.seconds == pytest.approx(1.3e-3) and rec.samples == 7

    def test_load_rejects_unknown_version(self, tmp_path):
        import json
        cache = autotune.TuningCache()
        cache.put(_key("cg", 8, 4), 1e-3)
        path = cache.save(tmp_path / "tuned.json")
        blob = json.load(open(path))
        blob["format_version"] = autotune.TuningCache._SAVE_VERSION + 1
        with open(path, "w") as f:
            json.dump(blob, f)
        with pytest.raises(ValueError, match="format version"):
            autotune.TuningCache.load(path)

    def test_env_var_preloads_default_cache(self, tmp_path, monkeypatch):
        cache = autotune.TuningCache()
        cache.put(_key("sharded_cg", 64, 16, mesh_size=8), 9e-4)
        path = cache.save(tmp_path / "shipped.json")
        monkeypatch.setenv(autotune.CACHE_ENV_VAR, path)
        prev = autotune.set_default_cache(None)     # force re-init
        try:
            loaded = autotune.default_cache()
            assert loaded.get(
                _key("sharded_cg", 64, 16, mesh_size=8)).seconds \
                == pytest.approx(9e-4)
        finally:
            autotune.set_default_cache(prev)

    def test_use_cache_scopes_default(self):
        inner = autotune.TuningCache()
        outer = autotune.default_cache()
        with autotune.use_cache(inner):
            assert autotune.default_cache() is inner
        assert autotune.default_cache() is outer


# ---------------------------------------------------------------------------
# roofline solve model (the cold-cache fallback)
# ---------------------------------------------------------------------------

class TestRooflineSolve:

    def test_mesh_divides_per_chip_work(self):
        one = roofline.analyze_solve(64, 16, mesh_size=1)
        eight = roofline.analyze_solve(64, 16, mesh_size=8)
        assert eight.compute_s == pytest.approx(one.compute_s / 8)
        assert eight.memory_s == pytest.approx(one.memory_s / 8)
        assert one.collective_s == eight.collective_s == 0.0
        assert one.solve_iteration_s > 0.0
        assert one.chips == 1 and eight.chips == 8

    def test_instance_sharding_pays_psum_latency(self):
        t = roofline.analyze_solve(4, 600, mesh_size=8,
                                   instance_sharded=True)
        iters = roofline.expected_solve_iters(600)
        assert t.collective_s == pytest.approx(
            iters * roofline.PSUM_LATENCY_S)
        # batch sharding communicates nothing
        assert roofline.analyze_solve(4, 600, mesh_size=8).collective_s \
            == 0.0

    def test_terms_surface_solve_iteration(self):
        t = roofline.analyze_solve(8, 32)
        assert t.to_dict()["solve_iteration_s"] == t.solve_iteration_s
        assert t.step_time_s == pytest.approx(
            t.solve_iteration_s * roofline.expected_solve_iters(32))

    def test_cold_cache_falls_back_to_roofline(self):
        with autotune.use_cache(autotune.TuningCache()):
            secs, source = autotune.predict_solve_seconds(
                "sharded_cg", 64, 16, mesh_size=8)
        assert source == "roofline" and secs > 0.0


# ---------------------------------------------------------------------------
# decisions (seeded — deterministic at any device count)
# ---------------------------------------------------------------------------

class TestDecisions:

    def test_mesh1_always_shards(self):
        with autotune.use_cache(_seeded(64, 16, sharded_loses=True)):
            assert autotune.should_shard(64, 16, mesh_size=1)

    def test_measured_loss_refuses_measured_win_accepts(self):
        with autotune.use_cache(_seeded(64, 16, sharded_loses=True)):
            assert not autotune.should_shard(64, 16, mesh_size=8)
        with autotune.use_cache(_seeded(64, 16, sharded_loses=False)):
            assert autotune.should_shard(64, 16, mesh_size=8)

    def test_cold_roofline_keeps_batch_sharding(self):
        with autotune.use_cache(autotune.TuningCache()):
            assert autotune.should_shard(64, 16, mesh_size=8)
            assert autotune.should_shard(16, 600, mesh_size=4, spd=False)

    def test_auto_mesh_size_prefers_measured_argmin(self):
        cache = _seeded(64, 16, sharded_loses=True)
        cache.put(_key("sharded_cg", 64, 16, mesh_size=1), 8e-4)
        cache.put(_key("sharded_cg", 64, 16, mesh_size=4), 3e-4)  # best
        with autotune.use_cache(cache):
            assert autotune.auto_mesh_size(64, 16, max_devices=8) == 4
        # a single measured candidate outranks every modeled one
        cache2 = autotune.TuningCache()
        cache2.put(_key("sharded_cg", 64, 16, mesh_size=2), 1e-3)
        with autotune.use_cache(cache2):
            assert autotune.auto_mesh_size(64, 16, max_devices=8) == 2

    def test_auto_mesh_size_cold_uses_all_devices(self):
        with autotune.use_cache(autotune.TuningCache()):
            assert autotune.auto_mesh_size(64, 16, max_devices=8) == 8
            assert autotune.auto_mesh_size(4, 16, max_devices=8) == 4
            assert autotune.auto_mesh_size(6, 16, max_devices=8) == 2

    def test_launch_wrapper_returns_valid_extent(self):
        n = auto_mesh_size(64, 16)
        assert n >= 1 and 64 % n == 0 and n <= N_DEV

    def test_choose_block_b_cold_is_legacy_schedule(self):
        with autotune.use_cache(autotune.TuningCache()):
            assert autotune.choose_block_b(64, 16) == \
                autotune.default_block_b(64, 16) == 8
            assert autotune.choose_block_b(4, 16) == 4   # shrunk divisor

    def test_choose_block_b_measured_argmin(self):
        cache = autotune.TuningCache()
        cache.put(_key("batched_cg", 64, 16, variant="block_b=8"), 2e-4)
        cache.put(_key("batched_cg", 64, 16, variant="block_b=32"), 9e-5)
        with autotune.use_cache(cache):
            assert autotune.choose_block_b(64, 16) == 32

    def test_operator_regime_reads_batch_shape(self):
        op = ops.DenseOperator(_spd_batch(8, 5), positive_definite=True)
        assert autotune.operator_regime(op) == (8, 5, "float32")
        single = ops.DenseOperator(jnp.eye(7, dtype=jnp.float32))
        assert autotune.operator_regime(single) == (1, 7, "float32")


# ---------------------------------------------------------------------------
# dispatch integration: batched_cg(block_b="auto")
# ---------------------------------------------------------------------------

class TestBlockAuto:

    def test_auto_matches_fixed_schedule(self):
        from repro.kernels.batched_cg.ops import batched_cg
        A = _spd_batch(8, 6)
        b = jnp.asarray(np.random.RandomState(1).randn(8, 6))
        x_auto = batched_cg(A, b, tol=1e-10, block_b="auto")
        x_fixed = batched_cg(A, b, tol=1e-10, block_b=8)
        np.testing.assert_allclose(x_auto, x_fixed, atol=1e-8)

    def test_auto_resolves_tuned_tile_in_interpret_mode(self):
        from repro.kernels.batched_cg.ops import batched_cg
        A = _spd_batch(8, 6, seed=2)
        b = jnp.asarray(np.random.RandomState(3).randn(8, 6))
        cache = autotune.TuningCache()
        cache.put(_key("batched_cg", 8, 6, variant="block_b=2"), 1e-5)
        cache.put(_key("batched_cg", 8, 6, variant="block_b=8"), 9e-5)
        with autotune.use_cache(cache):
            x = batched_cg(A, b, tol=1e-10, block_b="auto", interpret=True)
        x_ref = jnp.linalg.solve(A, b[..., None])[..., 0]
        np.testing.assert_allclose(x, x_ref, atol=1e-5)


# ---------------------------------------------------------------------------
# dispatch integration: the mesh=8 crossover (the regression this PR fixes)
# ---------------------------------------------------------------------------

@needs_8
class TestShardedCrossover:

    def _op(self, B=64, d=16):
        mesh = make_solve_mesh(devices=8)
        return ShardedOperator(
            ops.DenseOperator(_spd_batch(B, d), positive_definite=True),
            mesh, P("data", None))

    def test_seeded_loss_refuses_mesh8(self):
        op = self._op()
        with autotune.use_cache(_seeded(64, 16, sharded_loses=True)):
            assert ls._resolve_auto(op, jnp.zeros(16)) == "cg"
            assert ls._upgrade_for_sharded("cg", op) == "cg"
            # materializing names upgrade REGARDLESS — densifying a
            # mesh-placed operator yields per-shard pieces
            assert ls._upgrade_for_sharded("pallas_cg", op) == "sharded_cg"
            assert ls._upgrade_for_sharded("lu", op) == "sharded_dense_gmres"

    def test_seeded_win_accepts_mesh8(self):
        op = self._op()
        with autotune.use_cache(_seeded(64, 16, sharded_loses=False)):
            assert ls._resolve_auto(op, jnp.zeros(16)) == "sharded_cg"
            assert ls._upgrade_for_sharded("cg", op) == "sharded_cg"

    def test_refused_auto_solve_still_correct(self):
        op = self._op(B=16, d=6)
        dense = _spd_batch(16, 6)
        b = jnp.asarray(np.random.RandomState(4).randn(16, 6))
        x_ref = jnp.linalg.solve(dense, b[..., None])[..., 0]
        with autotune.use_cache(_seeded(16, 6, sharded_loses=True)):
            assert ls._resolve_auto(op, jnp.zeros(6)) == "cg"
            x = ls.solve(op, b, method="auto", tol=1e-10)
        np.testing.assert_allclose(x, x_ref, atol=1e-6)
        with autotune.use_cache(_seeded(16, 6, sharded_loses=False)):
            x_sh = ls.solve(op, b, method="auto", tol=1e-10)
        np.testing.assert_allclose(x_sh, x_ref, atol=1e-6)
