"""Observability subsystem tests: metrics, spans, jit-safe events, parity.

The contract under test, layer by layer:

  * ``MetricsRegistry`` — counter/gauge/histogram semantics, frozen
    snapshots, Prometheus text exposition, kind-conflict rejection;
  * ``Tracer`` — span nesting (ambient parents), cross-thread
    ``record_span``, JSONL round-trip through ``report.load_trace``;
  * events — the ``observe()`` switch compiles to a TRACE-TIME no-op when
    off (the jaxpr carries no callback), and when on, the per-solve
    events' diagnostics agree exactly with the ``SolveInfo`` the caller
    receives (the parity acceptance criterion);
  * the solve service — per-request lifecycle spans and registry counters
    agree with the ``ServiceResult`` futures;
  * sharded solves — the registry-seam instrumentation fires exactly ONE
    solve event per compiled program execution, not one per device
    (asserted on however many devices the process sees; the CI
    multidevice lane forces 8).
"""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro import observability as obs
from repro.core import diff_api
from repro.core import linear_solve as ls
from repro.core import operators as ops
from repro.observability import report
from repro.observability.metrics import ITERATION_BUCKETS, MetricsRegistry
from repro.observability.spans import Tracer


@pytest.fixture(autouse=True)
def _clean_observability():
    """Every test starts with observability off and empty global sinks."""
    obs.clear_recorded()
    obs.reset_global_registry()
    yield
    assert not obs.observing(), "a test leaked observe(enabled=True)"
    obs.remove_tracer()
    obs.clear_recorded()
    obs.reset_global_registry()


def _spd(rng, d):
    M = rng.standard_normal((d, d))
    return M @ M.T + d * np.eye(d)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class TestMetricsRegistry:

    def test_counter_gauge_histogram_semantics(self):
        reg = MetricsRegistry()
        c = reg.counter("c_total")
        c.inc()
        c.inc(2)
        assert c.value == 3
        with pytest.raises(ValueError, match="only go up"):
            c.inc(-1)
        g = reg.gauge("g")
        g.set(5)
        g.inc(-2)
        assert g.value == 3
        h = reg.histogram("h_seconds", buckets=(1.0, 10.0))
        h.observe_many([0.5, 5.0, 50.0])
        state = h.state()
        assert state["count"] == 3
        assert state["buckets"] == {1.0: 1, 10.0: 2}   # cumulative
        assert state["sum"] == pytest.approx(55.5)

    def test_get_or_create_and_label_identity(self):
        reg = MetricsRegistry()
        a = reg.counter("events_total", kind="solve")
        b = reg.counter("events_total", kind="solve")
        other = reg.counter("events_total", kind="dispatch")
        assert a is b and a is not other
        a.inc()
        snap = reg.snapshot()
        assert snap["events_total"]["values"]['kind="solve"'] == 1
        assert snap["events_total"]["values"]['kind="dispatch"'] == 0

    def test_kind_conflict_rejected(self):
        reg = MetricsRegistry()
        reg.counter("x_total")
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("x_total")

    def test_snapshot_is_frozen_copy(self):
        reg = MetricsRegistry()
        reg.counter("n_total").inc()
        snap = reg.snapshot()
        snap["n_total"]["values"][""] = 999
        assert reg.snapshot()["n_total"]["values"][""] == 1
        json.dumps(reg.snapshot())                     # JSON-ready

    def test_prometheus_exposition_format(self):
        reg = MetricsRegistry()
        reg.counter("req_total", help="requests", kind="solve").inc(4)
        reg.histogram("lat_seconds", buckets=(0.1, 1.0)).observe(0.05)
        text = reg.to_prometheus()
        assert "# HELP req_total requests" in text
        assert "# TYPE req_total counter" in text
        assert 'req_total{kind="solve"} 4' in text
        assert "# TYPE lat_seconds histogram" in text
        assert 'lat_seconds_bucket{le="0.1"} 1' in text
        assert 'lat_seconds_bucket{le="+Inf"} 1' in text
        assert "lat_seconds_sum 0.05" in text
        assert "lat_seconds_count 1" in text

    def test_shared_lock_snapshot_atomicity(self):
        """A snapshot taken while the owner holds the shared lock waits:
        multi-instrument updates inside owner critical sections can never
        be observed torn."""
        lock = threading.RLock()
        reg = MetricsRegistry(lock=lock)
        a, b = reg.counter("a_total"), reg.counter("b_total")
        stop = threading.Event()

        def writer():
            while not stop.is_set():
                with lock:              # a == b inside every critical section
                    a.inc()
                    b.inc()

        t = threading.Thread(target=writer, daemon=True)
        t.start()
        try:
            for _ in range(200):
                snap = reg.snapshot()
                assert snap["a_total"]["values"][""] == \
                    snap["b_total"]["values"][""]
        finally:
            stop.set()
            t.join(timeout=5.0)


# ---------------------------------------------------------------------------
# spans and the trace report
# ---------------------------------------------------------------------------

class TestSpans:

    def test_nesting_and_parent_ids(self, tmp_path):
        path = tmp_path / "trace.jsonl"
        tr = Tracer(path)
        with tr.span("outer") as outer:
            with tr.span("inner") as inner:
                assert inner.parent_id == outer.span_id
        tr.close()
        records = report.load_trace(path)
        by_name = {r["name"]: r for r in records}
        assert by_name["inner"]["parent"] == by_name["outer"]["id"]
        assert by_name["outer"]["parent"] is None
        # inner closed first and nests inside outer's interval
        assert by_name["inner"]["ts"] >= by_name["outer"]["ts"]
        assert by_name["inner"]["dur"] <= by_name["outer"]["dur"]

    def test_record_span_cross_thread(self):
        tr = Tracer()
        root = tr.record_span("request", 1.0, 3.0, uid=7)
        tr.record_span("queue", 1.0, 2.0, parent=root)
        recs = tr.records()
        assert recs[1]["parent"] == root
        assert recs[1]["dur"] == pytest.approx(1.0)
        assert recs[0]["tags"] == {"uid": 7}

    def test_module_span_noop_without_tracer(self):
        assert obs.current_tracer() is None
        with obs.span("anything") as sp:     # must not raise
            assert sp is None

    def test_report_summarize(self):
        records = [
            {"type": "span", "name": "solve", "id": 1, "parent": None,
             "ts": 0.0, "dur": 0.010, "tags": {"bucket": "cg:d=8"}},
            {"type": "span", "name": "solve", "id": 2, "parent": None,
             "ts": 1.0, "dur": 0.030, "tags": {"bucket": "cg:d=8"}},
            {"type": "event", "kind": "solve", "ts": 0.01, "span": 1,
             "tags": {"solver": "cg"}, "values": {"iterations": [3, 9, -1]}},
        ]
        s = report.summarize(records)
        assert s["spans"]["solve"]["count"] == 2
        assert s["spans"]["solve"]["p50_ms"] == pytest.approx(10.0)
        assert s["events"] == {"solve": 1}
        assert s["iterations_histogram"] == {"2-3": 1, "8-15": 1}  # -1 skipped
        assert s["buckets"]["cg:d=8"]["count"] == 2
        assert "solve" in report.format_summary(s)


# ---------------------------------------------------------------------------
# jit-safe events
# ---------------------------------------------------------------------------

class TestEvents:

    def test_disabled_mode_stages_nothing(self):
        """The zero-overhead guarantee: with observe off the jaxpr of a
        routed solve contains no callback at all."""
        A = jnp.eye(4) * 2.0
        mv = lambda v: A @ v
        jaxpr = str(jax.make_jaxpr(
            lambda b: ls.route_solve("cg", mv, b))(jnp.ones(4)))
        assert "callback" not in jaxpr

    def test_enabled_mode_stages_callback(self):
        A = jnp.eye(4) * 2.0
        mv = lambda v: A @ v
        with obs.observe(enabled=True):
            jaxpr = str(jax.make_jaxpr(
                lambda b: ls.route_solve("cg", mv, b))(jnp.ones(4)))
        assert "callback" in jaxpr

    def test_observe_handle_restores_state(self):
        assert not obs.observing()
        with obs.observe(enabled=True, iteration_events=True):
            assert obs.observing() and obs.observing_iterations()
            with obs.observe(enabled=False):
                assert not obs.observing()
            assert obs.observing()
        assert not obs.observing() and not obs.observing_iterations()

    def test_solve_event_matches_solve_info(self):
        """Parity: the solve event carries exactly the SolveInfo the
        caller gets — iterations, residual, convergence — under jit."""
        rng = np.random.default_rng(0)
        A = jnp.asarray(_spd(rng, 8))
        b = jnp.asarray(rng.standard_normal(8))
        mv = lambda v: A @ v
        with obs.observe(enabled=True, record=True):
            fn = jax.jit(lambda b: ls.route_solve(
                "cg", mv, b, tol=1e-10, return_info=True))
            x, info = fn(b)
            jax.block_until_ready(x)
            events = [e for e in obs.recorded() if e.kind == "solve"]
        assert len(events) == 1
        ev = events[0]
        assert ev.tags["solver"] == "cg"
        assert ev.tags["d"] == 8
        assert int(np.asarray(ev.values["iterations"])) == \
            int(info.iterations)
        assert float(np.asarray(ev.values["residual"])) == \
            pytest.approx(float(info.residual))
        assert bool(np.asarray(ev.values["converged"])) == \
            bool(info.converged)

    def test_iteration_events_opt_in(self):
        rng = np.random.default_rng(1)
        A = jnp.asarray(_spd(rng, 6))
        b = jnp.asarray(rng.standard_normal(6))
        mv = lambda v: A @ v
        with obs.observe(enabled=True, record=True):
            x, info = ls.solve_cg(mv, b, tol=1e-10, return_info=True)
            jax.block_until_ready(x)
            assert not [e for e in obs.recorded() if e.kind == "iteration"]
        with obs.observe(enabled=True, record=True, iteration_events=True):
            x, info = ls.solve_cg(mv, b, tol=1e-10, return_info=True)
            jax.block_until_ready(x)
            steps = [e for e in obs.recorded() if e.kind == "iteration"]
        assert len(steps) == int(info.iterations)

    def test_backward_events_carry_direction_and_estimate(self):
        def F(x, theta):
            return theta - 1.25 * x      # A = 1.25: Neumann converges
        x_star = jnp.asarray(4.8)
        theta = (jnp.asarray(6.0),)
        ct = jnp.asarray(1.0)
        with obs.observe(enabled=True, record=True):
            grads, info = diff_api.root_vjp(
                F, x_star, theta, ct, solve="cg", backward="neumann_k",
                backward_iters=4, error_estimate=True, return_info=True)
            jax.block_until_ready(grads)
            done = [e for e in obs.recorded() if e.kind == "backward_done"]
        assert len(done) == 1
        ev = done[0]
        assert ev.tags["direction"] == "vjp"
        assert ev.tags["backward"] == "neumann_k"
        assert ev.tags["matvec_budget"] == 4
        assert float(np.asarray(ev.values["hypergrad_error_estimate"])) == \
            pytest.approx(float(info.hypergrad_error_estimate))

    def test_events_bridge_into_global_registry(self):
        rng = np.random.default_rng(2)
        A = jnp.asarray(_spd(rng, 8))
        b = jnp.asarray(rng.standard_normal(8))
        with obs.observe(enabled=True):
            x, info = ls.route_solve("cg", lambda v: A @ v, b, tol=1e-10,
                                     return_info=True)
            jax.block_until_ready(x)
        snap = obs.global_registry().snapshot()
        counts = snap["repro_events_total"]["values"]
        assert counts['kind="solve",solver="cg"'] == 1
        hist = snap["repro_solve_iterations"]["values"]['solver="cg"']
        assert hist["count"] == 1
        assert hist["sum"] == float(info.iterations)
        assert tuple(hist["buckets"]) == ITERATION_BUCKETS

    def test_subscriber_receives_events_and_unsubscribes(self):
        seen = []
        unsub = obs.subscribe(seen.append)
        with obs.observe(enabled=True):
            obs.emit("dispatch", {"solver": "cg"})
        assert [e.kind for e in seen] == ["dispatch"]
        unsub()
        with obs.observe(enabled=True):
            obs.emit("dispatch", {"solver": "cg"})
        assert len(seen) == 1

    def test_emit_noop_when_disabled(self):
        obs.emit("dispatch", {"solver": "cg"})
        assert obs.recorded() == ()
        assert "repro_events_total" not in obs.global_registry().snapshot()


# ---------------------------------------------------------------------------
# solve service parity
# ---------------------------------------------------------------------------

class TestServiceObservability:

    def test_request_spans_and_counters_match_results(self, tmp_path):
        from repro.runtime.solve_service import SolveService

        rng = np.random.default_rng(3)
        d, n = 6, 5
        path = tmp_path / "svc.jsonl"
        with obs.observe(enabled=True, trace_path=path):
            svc = SolveService()
            futs = [svc.submit(_spd(rng, d), rng.standard_normal(d),
                               positive_definite=True) for _ in range(n)]
            svc.flush()
            results = [f.result(timeout=30.0) for f in futs]
            obs.current_tracer().flush()
            records = report.load_trace(path)

        # one lifecycle per request, with every segment parented under it
        spans = [r for r in records if r["type"] == "span"]
        requests = [s for s in spans if s["name"] == "request"]
        assert len(requests) == n
        ids = {s["id"] for s in requests}
        for seg in ("admission", "queue", "solve", "delivery"):
            segs = [s for s in spans if s["name"] == seg]
            assert len(segs) == n
            assert all(s["parent"] in ids for s in segs)
        # span tags agree with the per-request SolveInfo
        by_uid = {s["tags"]["uid"]: s for s in requests}
        for r in results:
            assert by_uid[r.uid]["tags"]["iterations"] == \
                int(r.info.iterations)

        # registry counters agree with the futures
        m = svc.metrics
        assert m["requests"] == n
        assert m["instances"] == n
        assert m["dispatches"] == 1
        text = svc.registry.to_prometheus()
        assert f"repro_service_requests_total {n}" in text
        assert "repro_service_solve_seconds_count 1" in text

    def test_metrics_property_is_frozen_copy(self):
        from repro.runtime.solve_service import SolveService

        svc = SolveService()
        m = svc.metrics
        m["requests"] = 999
        assert svc.metrics["requests"] == 0

    def test_snapshot_atomic_under_service_lock(self):
        """metrics_snapshot must come from the SAME lock the dispatch
        path updates under — a scrape during a dispatch critical section
        sees either all of its updates or none."""
        from repro.runtime.solve_service import SolveService

        svc = SolveService()
        with svc._lock:
            svc._m_dispatches.inc()
            svc._m_instances.inc(4)
            snap = svc.metrics_snapshot()    # reentrant, consistent
        assert snap["repro_service_dispatches_total"]["values"][""] == 1
        assert snap["repro_service_instances_total"]["values"][""] == 4


# ---------------------------------------------------------------------------
# sharded solves: once per program, not per device
# ---------------------------------------------------------------------------

class TestShardedEventSemantics:

    def test_one_solve_event_per_compiled_program(self):
        from repro.distributed.sharded_operators import ShardedOperator
        from repro.launch.mesh import make_solve_mesh

        rng = np.random.RandomState(0)
        Bn, d = 16, 6
        C = jnp.asarray(rng.randn(Bn, d, d)) / np.sqrt(d)
        A = jnp.einsum("bji,bjk->bik", C, C) + 0.5 * jnp.eye(d)
        mesh = make_solve_mesh()
        sh = ShardedOperator(ops.DenseOperator(A, positive_definite=True),
                             mesh, P("data", None))
        b = jnp.asarray(rng.randn(Bn, d))
        with obs.observe(enabled=True, record=True):
            x, info = ls.solve(sh, b, method="sharded_cg", tol=1e-10,
                               return_info=True)
            jax.block_until_ready(x)
            events = [e for e in obs.recorded() if e.kind == "solve"]
        # exactly ONE event for the whole program — not one per device —
        # because the telemetry seam sits outside shard_map
        assert len(events) == 1
        ev = events[0]
        assert ev.tags["solver"] == "sharded_cg"
        assert ev.tags["mesh_size"] == mesh.size
        assert ev.tags["B"] == Bn
        # and its values are the gathered global diagnostics
        np.testing.assert_array_equal(
            np.asarray(ev.values["iterations"]), np.asarray(info.iterations))

    def test_sharded_event_count_via_trace_file(self, tmp_path):
        """The CI multidevice lane's acceptance criterion, asserted the
        way an operator would check it: through the JSONL trace."""
        from repro.distributed.sharded_operators import ShardedOperator
        from repro.launch.mesh import make_solve_mesh

        rng = np.random.RandomState(1)
        Bn, d = 16, 5
        C = jnp.asarray(rng.randn(Bn, d, d)) / np.sqrt(d)
        A = jnp.einsum("bji,bjk->bik", C, C) + 0.5 * jnp.eye(d)
        mesh = make_solve_mesh()
        sh = ShardedOperator(ops.DenseOperator(A, positive_definite=True),
                             mesh, P("data", None))
        b = jnp.asarray(rng.randn(Bn, d))
        path = tmp_path / "sharded.jsonl"
        with obs.observe(enabled=True, trace_path=path):
            x = ls.solve(sh, b, method="sharded_cg", tol=1e-10)
            jax.block_until_ready(x)
            obs.current_tracer().flush()
            records = report.load_trace(path)
        solves = [r for r in records
                  if r["type"] == "event" and r["kind"] == "solve"]
        assert len(solves) == 1
        assert solves[0]["tags"]["mesh_size"] == mesh.size
