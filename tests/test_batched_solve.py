"""Batched linear-solve engine tests: vmap equivalence, per-instance
early-stop masking, Pallas batched-CG kernel parity, and batched implicit
differentiation through @custom_root."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import custom_root
from repro.core import linear_solve as ls
from repro.kernels.batched_cg.kernel import batched_cg_pallas
from repro.kernels.batched_cg.ops import batched_cg
from repro.kernels.batched_cg.ref import batched_cg_ref


def _spd_batch(key, B, d, cond=20.0):
    def one(k):
        A = jax.random.normal(k, (d, d))
        A = A @ A.T
        return A + (jnp.trace(A) / d / cond) * jnp.eye(d)
    return jax.vmap(one)(jax.random.split(key, B))


ITERATIVE = ["cg", "normal_cg", "bicgstab", "gmres"]


class TestVmapEquivalence:
    """Batched solve == stacked sequential solves, within tolerance."""

    @pytest.mark.parametrize("method", ITERATIVE + ["lu"])
    def test_engine_matches_sequential(self, rng, method):
        B, d = 6, 12
        As = _spd_batch(rng, B, d)
        bs = jax.random.normal(jax.random.fold_in(rng, 1), (B, d))
        batched = ls.solve(lambda v: jnp.einsum("bij,bj->bi", As, v), bs,
                           method=method, batch_axes=0, tol=1e-11,
                           maxiter=500)
        seq = jnp.stack([
            ls.solve(lambda v, A=As[i]: A @ v, bs[i], method=method,
                     tol=1e-11, maxiter=500)
            for i in range(B)])
        np.testing.assert_allclose(np.asarray(batched), np.asarray(seq),
                                   atol=1e-6)

    @pytest.mark.parametrize("method", ITERATIVE)
    def test_vmap_of_solver_matches_sequential(self, rng, method):
        B, d = 5, 10
        As = _spd_batch(rng, B, d)
        bs = jax.random.normal(jax.random.fold_in(rng, 2), (B, d))
        fn = ls.get_solver(method)
        vmapped = jax.vmap(
            lambda A, b: fn(lambda v: A @ v, b, tol=1e-11, maxiter=500))(
                As, bs)
        seq = jnp.stack([fn(lambda v, A=As[i]: A @ v, bs[i], tol=1e-11,
                            maxiter=500) for i in range(B)])
        np.testing.assert_allclose(np.asarray(vmapped), np.asarray(seq),
                                   atol=1e-6)

    def test_batch_axes_nonzero(self, rng):
        """Systems stacked along axis 1 solve identically to axis 0."""
        B, d = 4, 8
        As = _spd_batch(rng, B, d)
        bs = jax.random.normal(jax.random.fold_in(rng, 3), (B, d))
        x0 = ls.solve(lambda v: jnp.einsum("bij,bj->bi", As, v), bs,
                      method="cg", batch_axes=0, tol=1e-11)
        x1 = ls.solve(
            lambda v: jnp.einsum("bij,jb->ib", As, v), bs.T,
            method="cg", batch_axes=1, tol=1e-11)
        np.testing.assert_allclose(np.asarray(x0), np.asarray(x1.T),
                                   atol=1e-9)

    def test_pytree_batched(self, rng):
        """The engine batches pytree-structured systems, not just flat ones."""
        B = 4
        k1, k2 = jax.random.split(rng)
        Qa = _spd_batch(k1, B, 5)
        Qb = _spd_batch(k2, B, 3)

        def matvec(t):
            return {"a": jnp.einsum("bij,bj->bi", Qa, t["a"]),
                    "b": jnp.einsum("bij,bj->bi", Qb, t["b"])}

        b = {"a": jnp.ones((B, 5)), "b": jnp.ones((B, 3))}
        x = ls.solve(matvec, b, method="cg", batch_axes=0, tol=1e-11)
        res = matvec(x)
        np.testing.assert_allclose(np.asarray(res["a"]), 1.0, atol=1e-7)
        np.testing.assert_allclose(np.asarray(res["b"]), 1.0, atol=1e-7)


class TestEarlyStopMasking:
    """Converged instances freeze while stragglers keep iterating."""

    def test_per_instance_iteration_counts(self, rng):
        d = 16
        easy = jnp.eye(d)                       # converges in one iteration
        hard = _spd_batch(rng, 1, d, cond=1e4)[0]
        As = jnp.stack([easy, hard])
        bs = jax.random.normal(jax.random.fold_in(rng, 1), (2, d))
        x, info = ls.solve(lambda v: jnp.einsum("bij,bj->bi", As, v), bs,
                           method="cg", batch_axes=0, tol=1e-10,
                           return_info=True)
        iters = np.asarray(info.iterations)
        assert iters[0] <= 2                    # identity: immediate
        assert iters[1] > iters[0]              # straggler kept iterating
        assert bool(np.all(np.asarray(info.converged)))
        np.testing.assert_allclose(
            np.asarray(jnp.einsum("bij,bj->bi", As, x)), np.asarray(bs),
            atol=1e-5)

    def test_frozen_instance_solution_unchanged(self, rng):
        """The easy instance's solution is not degraded by extra iterations
        run for the straggler (its state is frozen, not re-updated)."""
        d = 8
        easy = 2.0 * jnp.eye(d)
        hard = _spd_batch(rng, 1, d, cond=1e5)[0]
        As = jnp.stack([easy, hard])
        bs = jnp.ones((2, d))
        x = ls.solve(lambda v: jnp.einsum("bij,bj->bi", As, v), bs,
                     method="cg", batch_axes=0, tol=1e-12, maxiter=300)
        np.testing.assert_allclose(np.asarray(x[0]), 0.5, atol=1e-12)

    def test_bicgstab_masking(self, rng):
        d = 12
        As = jnp.stack([jnp.eye(d), _spd_batch(rng, 1, d, cond=1e3)[0]])
        bs = jax.random.normal(jax.random.fold_in(rng, 2), (2, d))
        x, info = ls.solve(lambda v: jnp.einsum("bij,bj->bi", As, v), bs,
                           method="bicgstab", batch_axes=0, tol=1e-10,
                           return_info=True)
        iters = np.asarray(info.iterations)
        assert iters[0] < iters[1]
        np.testing.assert_allclose(
            np.asarray(jnp.einsum("bij,bj->bi", As, x)), np.asarray(bs),
            atol=1e-5)

    def test_maxiter_reports_nonconverged(self, rng):
        d = 16
        As = _spd_batch(rng, 2, d, cond=1e6)
        bs = jax.random.normal(jax.random.fold_in(rng, 3), (2, d))
        _, info = ls.solve(lambda v: jnp.einsum("bij,bj->bi", As, v), bs,
                           method="cg", batch_axes=0, tol=1e-14, maxiter=2,
                           return_info=True)
        assert not bool(np.all(np.asarray(info.converged)))


class TestSolverRegistry:

    def test_available_solvers(self):
        names = ls.available_solvers()
        for expected in ["cg", "normal_cg", "bicgstab", "gmres", "lu",
                         "neumann", "pallas_cg"]:
            assert expected in names

    def test_spec_properties(self):
        assert ls.get_spec("cg").symmetric_only
        assert not ls.get_spec("lu").matrix_free
        assert ls.get_spec("gmres").supports_precond

    def test_unknown_solver_raises(self):
        with pytest.raises(ValueError, match="unknown linear solver"):
            ls.get_spec("does_not_exist")

    def test_register_custom(self):
        def trivial(matvec, b, **kw):
            return b
        ls.register_solver("identity_test", trivial)
        try:
            assert ls.get_solver("identity_test") is trivial
        finally:
            ls._REGISTRY.pop("identity_test")

    def test_callable_with_batch_axes_rejected(self, rng):
        with pytest.raises(ValueError, match="batch_axes"):
            ls.solve(lambda v: v, jnp.ones((2, 3)),
                     method=lambda mv, b, **kw: b, batch_axes=0)


class TestPreconditioning:

    def test_jacobi_exact_for_diagonal(self, rng):
        d = 12
        diag = jnp.arange(1.0, d + 1.0)
        b = jax.random.normal(rng, (d,))
        x, info = ls.solve_cg(lambda v: diag * v, b, precond="jacobi",
                              tol=1e-12, return_info=True)
        assert int(info.iterations) <= 2        # M⁻¹A = I: immediate
        np.testing.assert_allclose(np.asarray(diag * x), np.asarray(b),
                                   atol=1e-10)

    def test_jacobi_reduces_iterations(self, rng):
        d = 32
        # badly scaled SPD system: diagonal spans 4 orders of magnitude
        scales = 10.0 ** jnp.linspace(-2, 2, d)
        A = _spd_batch(rng, 1, d)[0]
        A = scales[:, None] * A * scales[None, :]
        b = jax.random.normal(jax.random.fold_in(rng, 1), (d,))
        _, plain = ls.solve_cg(lambda v: A @ v, b, tol=1e-8, maxiter=4000,
                               return_info=True)
        _, jac = ls.solve_cg(lambda v: A @ v, b, precond="jacobi", tol=1e-8,
                             maxiter=4000, return_info=True)
        assert int(jac.iterations) < int(plain.iterations)

    def test_callable_precond(self, rng):
        d = 8
        A = _spd_batch(rng, 1, d)[0]
        b = jax.random.normal(jax.random.fold_in(rng, 1), (d,))
        M = ls.jacobi_preconditioner(jnp.diagonal(A))
        x = ls.solve_cg(lambda v: A @ v, b, precond=M, tol=1e-12)
        np.testing.assert_allclose(np.asarray(A @ x), np.asarray(b),
                                   atol=1e-8)

    def test_diagonal_of_matvec(self, rng):
        A = jax.random.normal(rng, (6, 6))
        diag = ls.diagonal_of_matvec(lambda v: A @ v, jnp.zeros(6))
        np.testing.assert_allclose(np.asarray(diag),
                                   np.asarray(jnp.diagonal(A)), atol=1e-12)


class TestPallasBatchedCG:
    """Pallas kernel vs ref.py parity on CPU interpret mode."""

    @pytest.mark.parametrize("B,d,block_b", [(8, 16, 8), (16, 32, 8),
                                             (4, 64, 2), (8, 8, 1)])
    def test_kernel_matches_ref(self, rng, B, d, block_b):
        As = _spd_batch(rng, B, d).astype(jnp.float32)
        bs = jax.random.normal(jax.random.fold_in(rng, 1), (B, d),
                               jnp.float32)
        out = batched_cg_pallas(As, bs, tol=1e-6, maxiter=2 * d,
                                block_b=block_b, interpret=True)
        ref = batched_cg_ref(As, bs, tol=1e-6, maxiter=2 * d)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)

    def test_ref_solves(self, rng):
        B, d = 8, 24
        As = _spd_batch(rng, B, d).astype(jnp.float32)
        bs = jax.random.normal(jax.random.fold_in(rng, 1), (B, d),
                               jnp.float32)
        x = batched_cg_ref(As, bs, tol=1e-8, maxiter=4 * d)
        res = jnp.linalg.norm(jnp.einsum("bij,bj->bi", As, x) - bs, axis=-1)
        rel = res / jnp.linalg.norm(bs, axis=-1)
        assert float(jnp.max(rel)) < 1e-5

    def test_op_custom_vjp_matches_dense_solve(self, rng):
        B, d = 4, 12
        As = _spd_batch(rng, B, d)
        bs = jax.random.normal(jax.random.fold_in(rng, 1), (B, d))

        def loss_cg(A, b):
            return jnp.sum(batched_cg(A, b, tol=1e-12, maxiter=40 * d) ** 2)

        def loss_dense(A, b):
            return jnp.sum(jnp.linalg.solve(A, b[..., None])[..., 0] ** 2)

        gA, gb = jax.grad(loss_cg, argnums=(0, 1))(As, bs)
        rA, rb = jax.grad(loss_dense, argnums=(0, 1))(As, bs)
        np.testing.assert_allclose(np.asarray(gA), np.asarray(rA), rtol=1e-4,
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(gb), np.asarray(rb), rtol=1e-4,
                                   atol=1e-6)

    def test_registry_pallas_cg_path(self, rng):
        B, d = 8, 16
        As = _spd_batch(rng, B, d).astype(jnp.float32)
        bs = jax.random.normal(jax.random.fold_in(rng, 1), (B, d),
                               jnp.float32)
        x = ls.solve(lambda v: jnp.einsum("bij,bj->bi", As, v), bs,
                     method="pallas_cg", batch_axes=0, tol=1e-6,
                     interpret=True)
        res = jnp.linalg.norm(jnp.einsum("bij,bj->bi", As, x) - bs, axis=-1)
        rel = res / jnp.linalg.norm(bs, axis=-1)
        assert float(jnp.max(rel)) < 1e-4

    def test_dense_dim_guard(self, rng):
        d = ls.MAX_DENSE_DIM + 1
        b = jnp.ones((2, d))
        with pytest.raises(ValueError, match="MAX_DENSE_DIM"):
            ls.solve(lambda v: v, b, method="pallas_cg", batch_axes=0)


class TestLanePadding:
    """Interpret-path coverage for d not a multiple of the 128-lane VMEM
    tile width — the shape-legalization half of the tuned TPU block
    schedule (identity pad, exact embedding; see kernel.pad_to_lanes)."""

    def test_pad_shape_math(self):
        from repro.kernels.batched_cg.kernel import LANES, pad_to_lanes
        A = jnp.eye(96)[None]
        b = jnp.ones((1, 96))
        Ap, bp, d0 = pad_to_lanes(A, b)
        assert Ap.shape == (1, 128, 128) and bp.shape == (1, 128)
        assert d0 == 96 and LANES == 128
        # padded block is the identity, coupling blocks are zero
        np.testing.assert_array_equal(np.asarray(Ap[0, 96:, 96:]),
                                      np.eye(32))
        assert float(jnp.abs(Ap[0, :96, 96:]).max()) == 0.0
        # already lane-aligned: no-op
        A128, b128, d0 = pad_to_lanes(jnp.eye(128)[None],
                                      jnp.ones((1, 128)))
        assert A128.shape == (1, 128, 128) and d0 == 128

    @pytest.mark.parametrize("B,d,block_b", [(4, 7, 2), (8, 96, 4),
                                             (4, 130, 2)])
    def test_interpret_padded_matches_ref(self, rng, B, d, block_b):
        from repro.kernels.batched_cg.kernel import pad_to_lanes
        As = _spd_batch(rng, B, d).astype(jnp.float32)
        bs = jax.random.normal(jax.random.fold_in(rng, 1), (B, d),
                               jnp.float32)
        out = batched_cg_pallas(As, bs, tol=1e-6, maxiter=2 * d,
                                block_b=block_b, interpret=True,
                                pad_lanes=True)
        ref = batched_cg_ref(As, bs, tol=1e-6, maxiter=2 * d)
        assert out.shape == (B, d)      # solution sliced back to d
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)
        assert pad_to_lanes(As, bs)[0].shape[-1] % 128 == 0

    def test_op_grad_with_padding_matches_dense(self, rng):
        """The implicit-diff custom VJP survives padding: the backward
        solve runs on the same padded system."""
        B, d = 4, 10
        As = _spd_batch(rng, B, d)
        bs = jax.random.normal(jax.random.fold_in(rng, 1), (B, d))

        def loss_cg(A, b):
            return jnp.sum(batched_cg(A, b, tol=1e-12, maxiter=40 * d,
                                      interpret=True, pad_lanes=True) ** 2)

        def loss_dense(A, b):
            return jnp.sum(jnp.linalg.solve(A, b[..., None])[..., 0] ** 2)

        gA, gb = jax.grad(loss_cg, argnums=(0, 1))(As, bs)
        rA, rb = jax.grad(loss_dense, argnums=(0, 1))(As, bs)
        np.testing.assert_allclose(np.asarray(gA), np.asarray(rA),
                                   rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(np.asarray(gb), np.asarray(rb),
                                   rtol=1e-4, atol=1e-6)


class TestBatchedImplicitDiff:
    """jax.vmap over a @custom_root solver == Python-loop baseline (1e-5)."""

    def _loss(self, Xi, yi, theta, solve_name):
        d = Xi.shape[1]

        def f(x, t):
            r = Xi @ x - yi
            return (jnp.sum(r ** 2) + t * jnp.sum(x ** 2)) / 2

        F = jax.grad(f, argnums=0)

        def raw(init, t):
            del init
            return jnp.linalg.solve(Xi.T @ Xi + t * jnp.eye(d), Xi.T @ yi)

        solver = custom_root(F, solve=solve_name, tol=1e-12)(raw)
        return jnp.sum(solver(None, theta) ** 2)

    @pytest.mark.parametrize("solve_name", ["cg", "normal_cg", "bicgstab"])
    def test_vmapped_grads_match_loop(self, rng, solve_name):
        B, m, d = 8, 20, 5
        X = jax.random.normal(rng, (B, m, d))
        y = jax.random.normal(jax.random.fold_in(rng, 1), (B, m))
        thetas = jnp.linspace(0.5, 5.0, B)

        g_loop = jnp.stack([
            jax.grad(self._loss, argnums=2)(X[i], y[i], thetas[i],
                                            solve_name)
            for i in range(B)])
        g_vmap = jax.vmap(
            lambda Xi, yi, t: jax.grad(self._loss, argnums=2)(
                Xi, yi, t, solve_name))(X, y, thetas)
        np.testing.assert_allclose(np.asarray(g_vmap), np.asarray(g_loop),
                                   atol=1e-5)

    def test_vmapped_jacobian_matches_closed_form(self, rng):
        """Whole-batch Jacobian dx*/dθ via vmap matches the analytic form."""
        B, m, d = 4, 15, 4
        X = jax.random.normal(rng, (B, m, d))
        y = jax.random.normal(jax.random.fold_in(rng, 1), (B, m))
        thetas = jnp.linspace(1.0, 4.0, B)

        def solve_one(Xi, yi, t):
            def f(x, tt):
                r = Xi @ x - yi
                return (jnp.sum(r ** 2) + tt * jnp.sum(x ** 2)) / 2
            F = jax.grad(f, argnums=0)

            def raw(init, tt):
                del init
                return jnp.linalg.solve(Xi.T @ Xi + tt * jnp.eye(d),
                                        Xi.T @ yi)
            return custom_root(F, solve="cg", tol=1e-12)(raw)(None, t)

        J = jax.vmap(jax.jacobian(solve_one, argnums=2))(X, y, thetas)
        for i in range(B):
            A = X[i].T @ X[i] + thetas[i] * jnp.eye(d)
            J_ref = -jnp.linalg.solve(A, jnp.linalg.solve(A, X[i].T @ y[i]))
            np.testing.assert_allclose(np.asarray(J[i]), np.asarray(J_ref),
                                       atol=1e-6)


class TestDenseGMRES:
    """Batched preconditioned GMRES for the nonsymmetric dense regime."""

    def _nonsym_batch(self, key, B, d, shift=6.0):
        A = jax.random.normal(key, (B, d, d))
        return A + shift * jnp.eye(d)

    def test_registered_with_correct_spec(self):
        spec = ls.get_spec("dense_gmres")
        assert spec.supports_precond
        assert not spec.matrix_free
        assert not spec.symmetric_only

    def test_batched_matches_dense_solve(self, rng):
        B, d = 6, 10
        As = self._nonsym_batch(rng, B, d)
        bs = jax.random.normal(jax.random.fold_in(rng, 1), (B, d))
        x, info = ls.solve(lambda v: jnp.einsum("bij,bj->bi", As, v), bs,
                           method="dense_gmres", batch_axes=0, tol=1e-11,
                           return_info=True)
        x_ref = jnp.linalg.solve(As, bs[..., None])[..., 0]
        np.testing.assert_allclose(np.asarray(x), np.asarray(x_ref),
                                   atol=1e-7)
        assert bool(np.asarray(info.converged).all())

    def test_vmap_of_solver_matches_sequential(self, rng):
        """vmap-equivalence: one batched masked solve == the python loop."""
        B, d = 5, 8
        As = self._nonsym_batch(rng, B, d)
        bs = jax.random.normal(jax.random.fold_in(rng, 2), (B, d))
        vmapped = jax.vmap(
            lambda A, b: ls.solve_dense_gmres(lambda v: A @ v, b,
                                              tol=1e-11))(As, bs)
        seq = jnp.stack([
            ls.solve_dense_gmres(lambda v, A=As[i]: A @ v, bs[i], tol=1e-11)
            for i in range(B)])
        np.testing.assert_allclose(np.asarray(vmapped), np.asarray(seq),
                                   atol=1e-8)

    def test_jacobi_precond_true_residual(self, rng):
        """Badly row-scaled batch: jacobi preconditioning converges and the
        reported residual is the TRUE one (not the preconditioned one)."""
        B, d = 4, 12
        scales = 10.0 ** jnp.linspace(-2, 2, d)
        As = self._nonsym_batch(rng, B, d) * scales[None, :, None]
        bs = jax.random.normal(jax.random.fold_in(rng, 3), (B, d))
        mv = lambda v: jnp.einsum("bij,bj->bi", As, v)
        x, info = ls.solve(mv, bs, method="dense_gmres", batch_axes=0,
                           tol=1e-10, precond="jacobi", return_info=True)
        true_rn = jnp.linalg.norm(bs - mv(x), axis=-1)
        np.testing.assert_allclose(np.asarray(info.residual),
                                   np.asarray(true_rn), rtol=1e-6, atol=1e-12)
        assert bool(np.asarray(info.converged).all())

    def test_callable_precond(self, rng):
        d = 9
        A = jax.random.normal(rng, (d, d)) + 5 * jnp.eye(d)
        b = jax.random.normal(jax.random.fold_in(rng, 4), (d,))
        M = lambda v: v / jnp.diagonal(A)
        x = ls.solve_dense_gmres(lambda v: A @ v, b, tol=1e-11, precond=M)
        np.testing.assert_allclose(np.asarray(A @ x), np.asarray(b),
                                   atol=1e-7)

    def test_dense_dim_guard(self):
        with pytest.raises(ValueError, match="MAX_DENSE_DIM"):
            ls.solve_dense_gmres(lambda v: v, jnp.ones(ls.MAX_DENSE_DIM + 1))

    def test_backward_solve_via_registry(self, rng):
        """dense_gmres as the custom_root backward solver: nonsymmetric
        fixed-point Jacobian matches the closed form."""
        M = 0.4 * jax.random.normal(rng, (6, 6))   # nonsymmetric contraction

        def T(x, theta):
            return M @ x + theta

        def raw(init, theta):
            return jnp.linalg.solve(jnp.eye(6) - M, theta)

        from repro.core import custom_fixed_point
        J = jax.jacobian(
            custom_fixed_point(T, solve="dense_gmres", tol=1e-12)(raw),
            argnums=1)(None, jnp.ones(6))
        np.testing.assert_allclose(np.asarray(J),
                                   np.asarray(jnp.linalg.inv(jnp.eye(6) - M)),
                                   atol=1e-8)
