"""Continuous-batching serving engine tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import init_params, init_decode_state, decode_step
from repro.runtime.serving import ContinuousBatchingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get("qwen1.5-4b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestContinuousBatching:

    def test_single_request_matches_sequential_decode(self, setup):
        """Engine output == plain greedy decode for one request."""
        cfg, params = setup
        prompt = np.array([3, 17, 42, 7], np.int32)
        gen_len = 6

        # reference: sequential decode_step
        state = init_decode_state(cfg, 1, 64)
        toks = list(prompt)
        logits = None
        for t in toks:
            logits, state = decode_step(params, cfg, state,
                                        jnp.asarray([[t]], jnp.int32))
        ref = []
        tok = int(jnp.argmax(logits[0, -1]))
        ref.append(tok)
        for _ in range(gen_len - 1):
            logits, state = decode_step(params, cfg, state,
                                        jnp.asarray([[tok]], jnp.int32))
            tok = int(jnp.argmax(logits[0, -1]))
            ref.append(tok)

        eng = ContinuousBatchingEngine(cfg, params, num_slots=2, max_len=64)
        eng.submit(prompt, max_new_tokens=gen_len)
        done = eng.run_until_drained()
        assert len(done) == 1
        assert done[0].generated == ref

    def test_concurrent_requests_all_complete(self, setup):
        cfg, params = setup
        eng = ContinuousBatchingEngine(cfg, params, num_slots=4, max_len=64)
        rng = np.random.default_rng(0)
        n_req = 10
        for i in range(n_req):
            eng.submit(rng.integers(0, cfg.vocab_size, size=3 + i % 4),
                       max_new_tokens=4 + i % 5)
        done = eng.run_until_drained()
        assert len(done) == n_req
        for r in done:
            assert r.state == "done"
            assert len(r.generated) >= r.max_new_tokens - 1

    def test_continuous_admission_keeps_slots_busy(self, setup):
        """More requests than slots: released slots get refilled mid-run."""
        cfg, params = setup
        eng = ContinuousBatchingEngine(cfg, params, num_slots=2, max_len=64)
        rng = np.random.default_rng(1)
        for i in range(6):
            eng.submit(rng.integers(0, cfg.vocab_size, size=2),
                       max_new_tokens=3)
        done = eng.run_until_drained()
        assert len(done) == 6
        assert eng.occupancy > 0.5     # slots mostly busy

    def test_isolation_between_slots(self, setup):
        """A request's output must not depend on what shares the batch."""
        cfg, params = setup
        prompt = np.array([5, 9, 21], np.int32)

        eng1 = ContinuousBatchingEngine(cfg, params, num_slots=4,
                                        max_len=64)
        eng1.submit(prompt, max_new_tokens=5)
        alone = eng1.run_until_drained()[0].generated

        eng2 = ContinuousBatchingEngine(cfg, params, num_slots=4,
                                        max_len=64)
        uid = eng2.submit(prompt, max_new_tokens=5)
        rng = np.random.default_rng(2)
        for _ in range(3):
            eng2.submit(rng.integers(0, cfg.vocab_size, size=4),
                        max_new_tokens=5)
        together = [r for r in eng2.run_until_drained()
                    if r.uid == uid][0].generated
        assert alone == together

    def test_no_recompilation_during_serving(self, setup):
        """The compiled decode signature is reused across ticks."""
        cfg, params = setup
        eng = ContinuousBatchingEngine(cfg, params, num_slots=2, max_len=64)
        eng.submit(np.array([1, 2], np.int32), max_new_tokens=3)
        eng.step()
        sizes0 = eng._step._cache_size()
        eng.submit(np.array([3, 4, 5], np.int32), max_new_tokens=4)
        eng.run_until_drained()
        assert eng._step._cache_size() == sizes0 == 1
