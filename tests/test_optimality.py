"""Optimality-condition catalog tests (paper Table 1, §2.2, Appendix A)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (custom_root, custom_fixed_point, optimality,
                        projections, prox, solvers)


class TestKKT:
    """Equality-constrained QP (paper eq. 16): closed-form check."""

    def _qp(self, rng):
        k1, k2 = jax.random.split(rng)
        Q = jax.random.normal(k1, (4, 4))
        Q = Q @ Q.T + 4 * jnp.eye(4)
        E = jax.random.normal(k2, (2, 4))
        return Q, E

    def test_eq_qp_jacobian(self, rng):
        Q, E = self._qp(rng)
        c = jnp.ones(4)
        d_vec = jnp.array([1.0, -1.0])

        def f(z, theta_f):
            cc = theta_f
            return 0.5 * z @ Q @ z + cc @ z

        def H(z, theta_H):
            dd = theta_H
            return E @ z - dd

        F = optimality.kkt(f, H=H)

        def kkt_solve(cc, dd):
            KKT = jnp.block([[Q, E.T], [E, jnp.zeros((2, 2))]])
            rhs = jnp.concatenate([-cc, dd])
            zn = jnp.linalg.solve(KKT, rhs)
            return zn[:4], zn[4:]

        @custom_root(F, tol=1e-12, solve="normal_cg")
        def solver(init, theta):
            cc, dd = theta
            z, nu = kkt_solve(cc, dd)
            return (z, nu)

        def primal(theta):
            return solver(None, theta)[0]

        theta = (c, d_vec)
        J_c = jax.jacobian(lambda cc: primal((cc, d_vec)))(c)
        # closed form via full KKT matrix inverse
        KKT = jnp.block([[Q, E.T], [E, jnp.zeros((2, 2))]])
        Kinv = jnp.linalg.inv(KKT)
        J_true = -Kinv[:4, :4]
        np.testing.assert_allclose(J_c, J_true, atol=1e-7)

    def test_ineq_qp_matches_projection(self, rng):
        """min ½‖z − y‖² s.t. −z ≤ 0  ⇒ z* = relu(y); check KKT Jacobian."""
        y0 = jnp.array([0.5, -0.3, 1.2])

        def f(z, theta_f):
            return 0.5 * jnp.sum((z - theta_f) ** 2)

        def G(z, theta_G):
            del theta_G
            return -z

        F = optimality.kkt(f, G=G)

        @custom_root(F, tol=1e-12)
        def solver(init, theta):
            y, _ = theta
            z = jnp.maximum(y, 0.0)
            lam = jnp.maximum(-y, 0.0)   # dual = negative part
            return (z, lam)

        J = jax.jacobian(lambda y: solver(None, (y, None))[0])(y0)
        s = (y0 > 0).astype(jnp.float64)
        np.testing.assert_allclose(J, jnp.diag(s), atol=1e-8)


class TestFixedPointMappings:

    def test_proximal_gradient_fp_lasso(self, rng):
        """Lasso via prox-grad fixed point; Jacobian wrt λ on the support
        matches the closed form dx*/dλ = −(XᵀX)⁻¹_supp sign(x*)."""
        k1, k2 = jax.random.split(rng)
        X = jax.random.normal(k1, (20, 5))
        y = jax.random.normal(k2, (20,))
        L = float(jnp.linalg.eigvalsh(X.T @ X).max())

        def f(x, theta_f):
            del theta_f
            return 0.5 * jnp.sum((X @ x - y) ** 2)

        def pr(v, lam, scaling):
            return prox.prox_lasso(v, lam, scaling)

        T = optimality.proximal_gradient_fp(f, pr, stepsize=1.0 / L)

        def solver(init, theta):
            _, lam = theta
            return solvers.proximal_gradient(
                f, pr, jnp.zeros(5), (None, lam), stepsize=1.0 / L,
                maxiter=20000, tol=1e-14)

        lam0 = 2.0
        wrapped = custom_fixed_point(T, tol=1e-12)(solver)
        x_star = wrapped(None, (None, lam0))
        supp = jnp.abs(x_star) > 1e-10
        dx = jax.jacobian(lambda lam: wrapped(None, (None, lam)))(lam0)
        # closed form on the support
        idx = np.where(np.asarray(supp))[0]
        Xs = X[:, idx]
        expected = -np.linalg.solve(np.asarray(Xs.T @ Xs),
                                    np.sign(np.asarray(x_star[idx])))
        np.testing.assert_allclose(np.asarray(dx)[idx], expected, atol=1e-5)
        np.testing.assert_allclose(np.asarray(dx)[~np.asarray(supp)], 0.0,
                                   atol=1e-8)

    def test_mirror_descent_fp_matches_projected_gradient_fp(self, rng):
        """Same x*, different F — both must give the same Jacobian (a.e.)."""
        theta0 = jnp.array([0.2, 0.8, 0.4])

        def f(x, theta_f):
            return 0.5 * jnp.sum((x - theta_f) ** 2)

        proj_e = lambda v, tp: projections.projection_simplex(v)
        proj_kl = lambda v, tp: projections.projection_simplex_kl(v)

        T_pg = optimality.projected_gradient_fp(f, proj_e, stepsize=0.7)
        T_md = optimality.mirror_descent_fp(f, proj_kl,
                                            optimality.kl_phi_grad,
                                            stepsize=0.9)

        def solver(init, theta):
            theta_f, _ = theta
            return solvers.projected_gradient(
                f, proj_e, jnp.ones(3) / 3, (theta_f, None), stepsize=0.5,
                maxiter=5000, tol=1e-14)

        J_pg = jax.jacobian(
            lambda t: custom_fixed_point(T_pg)(solver)(None, (t, None)))(
                theta0)
        J_md = jax.jacobian(
            lambda t: custom_fixed_point(T_md)(solver)(None, (t, None)))(
                theta0)
        np.testing.assert_allclose(J_pg, J_md, atol=1e-6)

    def test_newton_fp_same_system_as_gradient_fp(self, rng):
        """Appendix A: Newton fixed point ⇒ same implicit linear system."""
        Q = jnp.diag(jnp.array([1.0, 3.0]))

        def f(x, theta):
            return 0.5 * x @ Q @ x - theta @ x

        def solver(init, theta):
            return jnp.linalg.solve(Q, theta)

        T_gd = optimality.gradient_descent_fp(f, 0.1)
        G = jax.grad(f, argnums=0)
        T_nt = optimality.newton_fp(G, stepsize=0.5)
        theta0 = jnp.array([1.0, -2.0])
        J_gd = jax.jacobian(custom_fixed_point(T_gd)(solver), argnums=1)(
            None, theta0)
        J_nt = jax.jacobian(custom_fixed_point(T_nt)(solver), argnums=1)(
            None, theta0)
        np.testing.assert_allclose(J_gd, jnp.linalg.inv(Q), atol=1e-8)
        np.testing.assert_allclose(J_nt, jnp.linalg.inv(Q), atol=1e-6)

    def test_block_prox_fp_equals_prox_fp_with_shared_stepsize(self, rng):
        X = jax.random.normal(rng, (10, 4))
        y = jnp.ones(10)
        L = float(jnp.linalg.eigvalsh(X.T @ X).max())

        def f(x, theta_f):
            xx = jnp.concatenate(x) if isinstance(x, tuple) else x
            return 0.5 * jnp.sum((X @ xx - y) ** 2)

        pr = lambda v, lam, s: prox.prox_lasso(v, lam, s)
        T_full = optimality.proximal_gradient_fp(f, pr, stepsize=1.0 / L)

        def f_blocks(x, theta_f):
            return f(jnp.concatenate(x), theta_f)

        T_blk = optimality.block_proximal_gradient_fp(
            f_blocks, [pr, pr], stepsizes=(1.0 / L, 1.0 / L))

        x = jnp.array([0.1, -0.2, 0.3, 0.0])
        lam = 0.05
        full = T_full(x, (None, lam))
        blk = T_blk((x[:2], x[2:]), (None, (lam, lam)))
        np.testing.assert_allclose(full, jnp.concatenate(blk), atol=1e-12)


class TestImplicitGradsVsFiniteDifferences:
    """FD validation of previously-untested implicit-gradient paths:
    ``optimality.kkt`` and ``optimality.mirror_descent_fp``."""

    @staticmethod
    def _central_fd(fn, x, eps=1e-6):
        """Central finite differences of scalar fn over a flat vector."""
        out = []
        for i in range(x.shape[0]):
            hi = fn(x.at[i].add(eps))
            lo = fn(x.at[i].add(-eps))
            out.append((hi - lo) / (2 * eps))
        return jnp.asarray(out)

    def test_kkt_equality_gradient_matches_fd(self, rng):
        """Equality-constrained QP: ∇θ of an outer loss through the KKT
        system's primal solution vs central differences."""
        k1, k2 = jax.random.split(rng)
        Q = jax.random.normal(k1, (4, 4))
        Q = Q @ Q.T + 4 * jnp.eye(4)
        E = jax.random.normal(k2, (2, 4))

        def f(z, theta_f):
            return 0.5 * z @ Q @ z + theta_f @ z

        def H(z, theta_H):
            return E @ z - theta_H

        F = optimality.kkt(f, H=H)

        @custom_root(F, tol=1e-12, solve="normal_cg")
        def kkt_solver(init, theta):
            cc, dd = theta
            KKT = jnp.block([[Q, E.T], [E, jnp.zeros((2, 2))]])
            zn = jnp.linalg.solve(KKT, jnp.concatenate([-cc, dd]))
            return (zn[:4], zn[4:])

        c0 = jnp.array([1.0, -0.5, 0.3, 2.0])
        d0 = jnp.array([0.7, -1.2])

        def loss_c(cc):
            z, _ = kkt_solver(None, (cc, d0))
            return jnp.sum(z ** 2) + jnp.sum(jnp.sin(z))

        def loss_d(dd):
            z, _ = kkt_solver(None, (c0, dd))
            return jnp.sum(z ** 2) + jnp.sum(jnp.sin(z))

        np.testing.assert_allclose(jax.grad(loss_c)(c0),
                                   self._central_fd(loss_c, c0), rtol=1e-5)
        np.testing.assert_allclose(jax.grad(loss_d)(d0),
                                   self._central_fd(loss_d, d0), rtol=1e-5)

    def test_kkt_inequality_gradient_matches_fd(self, rng):
        """Inequality KKT (z* = relu(y)): gradient through the active set."""
        y0 = jnp.array([0.8, -0.6, 1.5])   # strictly active/inactive split

        def f(z, theta_f):
            return 0.5 * jnp.sum((z - theta_f) ** 2)

        def G(z, theta_G):
            del theta_G
            return -z

        F = optimality.kkt(f, G=G)

        @custom_root(F, tol=1e-12)
        def proj_solver(init, theta):
            y, _ = theta
            return (jnp.maximum(y, 0.0), jnp.maximum(-y, 0.0))

        def loss(y):
            z, _ = proj_solver(None, (y, None))
            return jnp.sum(z ** 3)

        np.testing.assert_allclose(jax.grad(loss)(y0),
                                   self._central_fd(loss, y0), rtol=1e-5,
                                   atol=1e-10)

    def test_mirror_descent_fp_gradient_matches_fd(self, rng):
        """MD fixed point through the runtime solver: implicit gradient of
        a simplex-constrained solve vs central differences."""
        from repro.core import MirrorDescent

        theta0 = jnp.array([0.2, 0.9, 0.4])

        def f(x, theta_f):
            return 0.5 * jnp.sum((x - theta_f) ** 2) + 0.1 * jnp.sum(x ** 4)

        proj_kl = lambda v, tp: projections.projection_simplex_kl(v)
        solver = MirrorDescent(f, proj_kl, stepsize=0.8, maxiter=8000,
                               tol=1e-14)

        def loss(t):
            x, _ = solver.run(jnp.ones(3) / 3, (t, None))
            return jnp.sum(x ** 2) + x[0]

        np.testing.assert_allclose(jax.grad(loss)(theta0),
                                   self._central_fd(loss, theta0), rtol=1e-4,
                                   atol=1e-8)


class TestConic:
    """Conic residual map (eq. 18) on a tiny LP."""

    def test_residual_zero_at_optimum(self):
        # min x s.t. x >= 1  (one var, one nonneg-cone constraint):
        # conic form: c=1, E=-1, d=-1, s = x - 1 ∈ K=R+
        c = jnp.array([1.0])
        E = jnp.array([[-1.0]])
        d = jnp.array([-1.0])
        theta = jnp.block([
            [jnp.zeros((1, 1)), E.T, c[:, None]],
            [-E, jnp.zeros((1, 1)), d[:, None]],
            [-c[None, :], -d[None, :], jnp.zeros((1, 1))],
        ])
        proj = optimality.make_cone_projector(
            1, [(1, lambda v: jnp.maximum(v, 0.0))])
        F = optimality.conic_residual(proj)
        # primal x*=1, dual y*=1, tau=1 -> u=(x, y, tau)=(1, 1, 1), v=0
        x = jnp.array([1.0, 1.0, 1.0])
        np.testing.assert_allclose(F(x, theta), 0.0, atol=1e-9)
