"""The docs lane: docs/ snippets execute, links resolve, docstrings exist.

Three gates keep the documentation honest:

* every fenced ```python block in ``docs/*.md`` runs (blocks within one
  file share a namespace, top to bottom, like a reader following along);
* every relative link in README.md and ``docs/*.md`` points at a real
  file;
* every public symbol in the API-surface snapshot (plus the distributed
  and serving layers) carries a docstring — the CI ruff ``D1xx`` gate
  enforces the module side, this enforces the exported-object side.
"""
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
DOCS = sorted((REPO / "docs").glob("*.md"))

_FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)
_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)\s]*)?\)")


def _python_blocks(path):
    return [m.group(1) for m in _FENCE.finditer(path.read_text())]


def test_docs_exist_and_have_snippets():
    names = {p.name for p in DOCS}
    assert {"architecture.md", "serving.md", "implicit_diff.md"} <= names
    for page in DOCS:
        assert _python_blocks(page), f"{page.name} has no runnable snippets"


@pytest.mark.parametrize("page", DOCS, ids=lambda p: p.name)
def test_docs_snippets_execute(page):
    """Blocks share one namespace per page, executed in order."""
    ns = {"__name__": f"docs_{page.stem}"}
    for i, block in enumerate(_python_blocks(page)):
        try:
            exec(compile(block, f"{page.name}[block {i}]", "exec"), ns)
        except Exception as exc:     # pragma: no cover - failure reporting
            pytest.fail(f"{page.name} block {i} failed: {exc!r}\n{block}")


@pytest.mark.parametrize(
    "page", [REPO / "README.md"] + DOCS, ids=lambda p: p.name)
def test_relative_links_resolve(page):
    text = page.read_text()
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        resolved = (page.parent / target).resolve()
        if not resolved.is_relative_to(REPO):
            continue        # GitHub-virtual paths (e.g. the ../../actions badge)
        assert resolved.exists(), \
            f"{page.name} links to missing file: {target}"


def _assert_documented(obj, name, where):
    doc = getattr(obj, "__doc__", None)
    assert doc and doc.strip(), f"{where}.{name} has no docstring"


def test_core_surface_is_documented():
    import repro.core
    from tests.test_api_surface import EXPECTED_SURFACE
    for name in sorted(EXPECTED_SURFACE):
        _assert_documented(getattr(repro.core, name), name, "repro.core")


def test_distributed_surface_is_documented():
    import repro.distributed as dist
    for name in sorted(n for n in dir(dist) if not n.startswith("_")):
        obj = getattr(dist, name)
        if callable(obj) or type(obj).__name__ == "module":
            _assert_documented(obj, name, "repro.distributed")


def test_service_surface_is_documented():
    import repro.runtime as rt
    from repro.runtime import solve_service as svc_mod
    _assert_documented(svc_mod, "solve_service", "repro.runtime")
    for name in ("SolveService", "ServiceResult", "WarmStartCache",
                 "BucketKey", "bucket_capacity"):
        _assert_documented(getattr(rt, name), name, "repro.runtime")
    for name, member in vars(rt.SolveService).items():
        if name.startswith("_") or not callable(member):
            continue
        _assert_documented(member, f"SolveService.{name}", "repro.runtime")
