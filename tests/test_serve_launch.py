"""Subprocess smoke test for ``repro.launch.serve --solve-service``.

Runs the real CLI end to end in a child process (its own scheduler
thread, observability switch, tracer and registry — nothing shared with
the test process) and checks the operator-facing contract: clean exit, a
well-formed Prometheus exposition on stdout, and a JSONL trace that the
report tooling can load and summarize.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _run_serve(tmp_path, *extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    cmd = [sys.executable, "-m", "repro.launch.serve", "--solve-service",
           "--requests", "8", "--dim", "8", *extra]
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=300, cwd=tmp_path)


def test_solve_service_cli_smoke(tmp_path):
    trace = tmp_path / "trace.jsonl"
    proc = _run_serve(tmp_path, "--trace", str(trace))
    assert proc.returncode == 0, proc.stderr
    out = proc.stdout

    # both traffic waves ran, and the warm wave saw the cache
    assert "[serve] cold:" in out
    assert "[serve] warm:" in out
    assert "hit_rate=" in out

    # well-formed Prometheus exposition: typed counters with the expected
    # request accounting (8 requests x 2 waves) and histogram series
    assert "# TYPE repro_service_requests_total counter" in out
    assert "repro_service_requests_total 16" in out
    assert "# TYPE repro_service_solve_seconds histogram" in out
    assert 'repro_service_solve_seconds_bucket{le="+Inf"}' in out
    assert "repro_service_solve_seconds_count" in out
    assert "# TYPE repro_service_cache_hits gauge" in out

    # the trace is valid JSONL with request lifecycles and solve events
    assert f"[serve] trace: {trace}" in out
    records = [json.loads(line) for line in
               trace.read_text().splitlines() if line.strip()]
    assert records, "trace file is empty"
    spans = [r for r in records if r["type"] == "span"]
    requests = [s for s in spans if s["name"] == "request"]
    assert len(requests) == 16
    ids = {s["id"] for s in requests}
    for seg in ("admission", "queue", "solve", "delivery"):
        segs = [s for s in spans if s["name"] == seg]
        assert len(segs) == 16
        assert all(s["parent"] in ids for s in segs)
    for s in spans:
        assert s["dur"] >= 0.0
    events = [r for r in records if r["type"] == "event"]
    assert sum(1 for e in events if e["kind"] == "cache_miss") == 8
    assert sum(1 for e in events if e["kind"] == "cache_hit") == 8

    # the report tooling loads and summarizes the same file
    from repro.observability import report
    summary = report.summarize(report.load_trace(trace))
    assert summary["spans"]["request"]["count"] == 16
    assert summary["events"]["cache_hit"] == 8
    assert summary["iterations_histogram"]


def test_solve_service_cli_without_trace(tmp_path):
    proc = _run_serve(tmp_path)
    assert proc.returncode == 0, proc.stderr
    assert "[serve] prometheus exposition:" in proc.stdout
    assert "repro_service_requests_total 16" in proc.stdout
    assert "[serve] trace:" not in proc.stdout
