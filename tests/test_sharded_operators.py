"""Mesh-aware sharded-solve subsystem tests.

These run IN-PROCESS: every mesh is built over however many devices the
process actually sees (``launch.mesh.make_solve_mesh``), so the whole file
passes on a 1-device laptop and exercises real multi-device execution in
the CI lane that forces ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(unlike ``test_distributed.py``, which subprocess-spawns devices).

Covers: the ``ShardedOperator`` protocol against its unsharded base
(matvec/rmatvec/transpose/diagonal/materialize, per-shard pieces, the
``psum`` reduction hook), the ``sharded_*`` registry solvers (parity with
the single-device solvers, per-instance masks, auto-routing + the
``cg → sharded_cg`` upgrade), and the acceptance criteria for the
implicit-diff threading: ``jax.grad`` of a decorated solver with a
``ShardedOperator`` backward solve executes exactly ONE sharded linear
solve (counting spy + trace census), matches the single-device gradient to
≤ 1e-5, and compiles with no host gather (all-gather census + sharded
output placement).  The hypothesis property tests (``ravel_view``
round-trip, ``ShardedOperator.matvec`` equivalence under ``jax.vmap``)
live in ``test_sharded_properties.py``, hard-gated like the PR 4 suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import linear_solve as ls
from repro.core import operators as ops
from repro.core.diff_api import ImplicitDiffSpec, implicit_diff
from repro.core.solver_runtime import GradientDescent
from repro.distributed.sharded_operators import (ShardedOperator,
                                                 SolveSharding,
                                                 instance_axes,
                                                 psum_reduction)
from repro.launch.mesh import make_solve_mesh


N_DEV = len(jax.devices())
B = 16          # divisible by 1/2/4/8 local devices


@pytest.fixture
def rng():
    return np.random.RandomState(0)


@pytest.fixture
def mesh():
    return make_solve_mesh()


def _batched_spd(rng, B, d, shift=0.5):
    C = jnp.asarray(rng.randn(B, d, d)) / np.sqrt(d)
    return jnp.einsum("bji,bjk->bik", C, C) + shift * jnp.eye(d)


def _put(mesh, tree, spec):
    return jax.device_put(tree, jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec,
        is_leaf=lambda x: isinstance(x, P)))


class _DiagOp(ops.LinearOperator):
    """Elementwise (block-diagonal) operator — shard-local along ANY dim."""

    def __init__(self, dg, **kw):
        super().__init__(jnp.zeros_like(dg), **kw)
        self.dg = dg

    def matvec(self, v):
        return self.dg * v


# ---------------------------------------------------------------------------
# the operator protocol under sharding
# ---------------------------------------------------------------------------

class TestShardedOperatorProtocol:

    def test_batch_sharded_dense_matches_base(self, rng, mesh):
        d = 5
        A = _batched_spd(rng, B, d)
        base = ops.DenseOperator(A, positive_definite=True)
        sh = ShardedOperator(base, mesh, P("data", None))
        assert sh.is_sharded and not base.is_sharded
        assert sh.symmetric and sh.positive_definite and sh.batch_ndim == 1
        assert not sh.instance_sharded
        v = jnp.asarray(rng.randn(B, d))
        np.testing.assert_allclose(sh.matvec(v), base.matvec(v), rtol=1e-12)
        np.testing.assert_allclose(sh.rmatvec(v), base.rmatvec(v),
                                   rtol=1e-12)
        np.testing.assert_allclose(sh.diagonal(), base.diagonal(),
                                   rtol=1e-12)
        np.testing.assert_allclose(sh.materialize(), A, rtol=1e-12)

    def test_nonsymmetric_transpose_roundtrip(self, rng, mesh):
        d = 4
        A = jnp.asarray(rng.randn(B, d, d))
        base = ops.DenseOperator(A, symmetric=False)
        sh = ShardedOperator(base, mesh, P("data", None))
        v = jnp.asarray(rng.randn(B, d))
        np.testing.assert_allclose(sh.T.matvec(v), base.rmatvec(v),
                                   rtol=1e-12)
        assert sh.T.is_sharded and sh.T.symmetric is False
        np.testing.assert_allclose(sh.T.T.matvec(v), base.matvec(v),
                                   rtol=1e-12)

    def test_factory_operands_shard_alongside_domain(self, rng, mesh):
        dg = 1.0 + jnp.asarray(rng.rand(B))
        sh = ShardedOperator(lambda g: _DiagOp(g, positive_definite=True),
                             mesh, P("data"), operands=(dg,),
                             operand_specs=(P("data"),))
        # spec-based, not size-based: naming an instance axis means the
        # dots go through the reduction hook (identity on a 1-device mesh)
        assert sh.instance_sharded
        v = jnp.asarray(rng.randn(B))
        np.testing.assert_allclose(sh.matvec(v), dg * v, rtol=1e-12)
        np.testing.assert_allclose(sh.diagonal(), dg, rtol=1e-12)

    def test_instance_sharded_materialize_returns_per_shard_blocks(
            self, rng, mesh):
        dg = 1.0 + jnp.asarray(rng.rand(B))
        sh = ShardedOperator(lambda g: _DiagOp(g), mesh, P("data"),
                             operands=(dg,), operand_specs=(P("data"),))
        blocks = sh.materialize()
        assert blocks.shape == (N_DEV, B // N_DEV, B // N_DEV)
        np.testing.assert_allclose(
            jax.vmap(jnp.diagonal)(blocks).reshape(-1), dg, rtol=1e-12)

    def test_psum_reduction_hook(self, mesh):
        assert instance_axes(P("data", None), batch_ndim=1) == ()
        assert instance_axes(P("data"), batch_ndim=0) == ("data",)
        assert instance_axes(P(None, "data"), batch_ndim=1) == ("data",)
        red = psum_reduction(())
        assert red(3.0) == 3.0          # identity without sharded axes
        calls = []

        def spy_reduce(x):
            calls.append(1)
            return x

        dg = jnp.ones(B)
        sh = ShardedOperator(lambda g: _DiagOp(g, positive_definite=True),
                             mesh, P("data"), operands=(dg,),
                             operand_specs=(P("data"),), reduce=spy_reduce)
        ls.solve(sh, jnp.ones(B), method="sharded_cg", tol=1e-10)
        assert calls, "custom reduction hook never reached the solver"

    def test_plain_capture_defaults_trace_at_local_shapes(self, rng, mesh):
        """A plain-wrapped operator that respects the capture contract
        (shard-local matvec, replicated captures) but relies on every
        matrix-free BASE default — rmatvec via linear_transpose, probing
        diagonal/materialize — must still work under shard_map: the
        defaults are re-anchored on the LOCAL shard example (regression:
        they used to trace at the captured global example, crashing
        rmatvec and silently duplicating diagonal/materialize output
        across shards)."""
        d = 3
        M = jnp.asarray(rng.randn(d, d))        # replicated capture (d, d)
        base = ops.FunctionOperator(
            lambda v: jnp.einsum("bd,de->be", v, M),
            jnp.zeros((B, d)), batch_ndim=1, symmetric=False)
        sh = ShardedOperator(base, mesh, P("data", None))
        v = jnp.asarray(rng.randn(B, d))
        np.testing.assert_allclose(sh.rmatvec(v), v @ M.T, atol=1e-12)
        np.testing.assert_allclose(sh.T.matvec(v), v @ M.T, atol=1e-12)
        diag = sh.diagonal()
        assert diag.shape == (B, d)             # not duplicated per shard
        np.testing.assert_allclose(
            diag, jnp.broadcast_to(jnp.diag(M), (B, d)), atol=1e-12)
        dense = sh.materialize()
        assert dense.shape == (B, d, d)
        np.testing.assert_allclose(dense, jnp.broadcast_to(M.T, (B, d, d)),
                                   atol=1e-12)
        b = jnp.asarray(rng.randn(B, d))
        x = ls.solve(sh, b, method="sharded_normal_cg", tol=1e-12,
                     maxiter=500)
        np.testing.assert_allclose(jnp.einsum("bd,de->be", x, M), b,
                                   atol=1e-6)

    def test_constructor_validation(self, rng, mesh):
        base = ops.DenseOperator(_batched_spd(rng, B, 3))
        with pytest.raises(ValueError, match="factory"):
            ShardedOperator(base, mesh, P("data", None),
                            operands=(jnp.ones(B),),
                            operand_specs=(P("data"),))
        with pytest.raises(ValueError, match="operand_specs"):
            ShardedOperator(lambda g: _DiagOp(g), mesh, P("data"),
                            operands=(jnp.ones(B),), operand_specs=())
        with pytest.raises(TypeError, match="LinearOperator"):
            ShardedOperator(lambda: 3.0, mesh, P("data"))


# ---------------------------------------------------------------------------
# the sharded registry solvers
# ---------------------------------------------------------------------------

class TestShardedSolvers:

    def test_sharded_cg_matches_single_device(self, rng, mesh):
        d = 6
        A = _batched_spd(rng, B, d)
        base = ops.DenseOperator(A, positive_definite=True)
        sh = ShardedOperator(base, mesh, P("data", None))
        b = jnp.asarray(rng.randn(B, d))
        x_ref, info_ref = ls.solve(base, b, method="cg", tol=1e-10,
                                   return_info=True)
        x, info = ls.solve(sh, b, method="sharded_cg", tol=1e-10,
                           return_info=True)
        np.testing.assert_allclose(x, x_ref, atol=1e-10)
        assert bool(info.converged.all())
        assert info.iterations.shape == (B,)    # per-instance masks intact
        np.testing.assert_array_equal(info.iterations, info_ref.iterations)

    def test_sharded_normal_cg_general_operator(self, rng, mesh):
        d = 5
        A = _batched_spd(rng, B, d) + 0.3 * jnp.asarray(rng.randn(B, d, d))
        base = ops.DenseOperator(A, symmetric=False)
        sh = ShardedOperator(base, mesh, P("data", None))
        b = jnp.asarray(rng.randn(B, d))
        x = ls.solve(sh, b, method="sharded_normal_cg", tol=1e-12,
                     maxiter=4000)
        np.testing.assert_allclose(
            x, jnp.linalg.solve(A, b[..., None])[..., 0], atol=1e-6)

    def test_sharded_dense_gmres_and_instance_shard_refusal(self, rng,
                                                            mesh):
        d = 5
        A = _batched_spd(rng, B, d) + 0.3 * jnp.asarray(rng.randn(B, d, d))
        sh = ShardedOperator(ops.DenseOperator(A, symmetric=False), mesh,
                             P("data", None))
        b = jnp.asarray(rng.randn(B, d))
        x = ls.solve(sh, b, method="sharded_dense_gmres", tol=1e-10)
        np.testing.assert_allclose(
            x, jnp.linalg.solve(A, b[..., None])[..., 0], atol=1e-8)
        dg_sh = ShardedOperator(lambda g: _DiagOp(g), mesh, P("data"),
                                operands=(jnp.ones(B),),
                                operand_specs=(P("data"),))
        assert dg_sh.instance_sharded    # spec-based, device-count-free
        with pytest.raises(ValueError, match="batch sharding only"):
            ls.solve(dg_sh, jnp.ones(B), method="sharded_dense_gmres")

    def test_auto_routing_and_upgrade(self, rng, mesh):
        from repro.analysis import autotune
        d = 6
        spd = ShardedOperator(
            ops.DenseOperator(_batched_spd(rng, B, d),
                              positive_definite=True),
            mesh, P("data", None))
        gen = ShardedOperator(
            ops.DenseOperator(jnp.asarray(rng.randn(B, d, d)),
                              symmetric=False), mesh, P("data", None))
        big = ShardedOperator(
            ops.FunctionOperator(lambda v: v, jnp.zeros((B, 600)),
                                 batch_ndim=1), mesh, P("data", None))
        # COLD cache: the roofline fallback predicts a win for batch
        # sharding, so structural routing is unchanged (PR 9 contract)
        with autotune.use_cache(autotune.TuningCache()):
            assert ls._resolve_auto(spd, jnp.zeros(d)) == "sharded_cg"
            assert ls._resolve_auto(gen, jnp.zeros(d)) == "sharded_dense_gmres"
            assert ls._resolve_auto(big, jnp.zeros(600)) == "sharded_normal_cg"
            # classic names upgrade once the operator carries a mesh
            assert ls._upgrade_for_sharded("cg", spd) == "sharded_cg"
            assert ls._upgrade_for_sharded("cg", ops.DenseOperator(
                _batched_spd(rng, B, d))) == "cg"
            b = jnp.asarray(rng.randn(B, d))
            np.testing.assert_allclose(
                ls.solve(spd, b, method="cg", tol=1e-10),
                ls.solve(spd, b, method="sharded_cg", tol=1e-10), rtol=1e-12)
            # materializing single-device solvers upgrade too (densifying a
            # mesh-placed operator outside shard_map would gather)
            assert ls._upgrade_for_sharded("pallas_cg", spd) == "sharded_cg"
            assert ls._upgrade_for_sharded("lu", gen) == "sharded_dense_gmres"
        # MEASURED crossover: the same regime with evidence it loses at
        # this mesh extent refuses the matrix-free upgrade; with evidence
        # it wins, accepts.  Keys are seeded at the operand's own regime
        # (dtype included — the suite runs under x64).
        Bn, dd, dtype = autotune.operator_regime(spd)
        backend = autotune.current_backend()
        single = autotune.single_device_solver(True, dd)

        def seeded(sharded_ratio):
            c = autotune.TuningCache()
            c.put(autotune.TuningKey(backend, single, Bn, dd, dtype), 1e-3)
            c.put(autotune.TuningKey(backend, "sharded_cg", Bn, dd, dtype,
                                     int(mesh.size)), sharded_ratio * 1e-3)
            return c

        if mesh.size > 1:       # a 1-device mesh is always accepted
            with autotune.use_cache(seeded(2.0)):
                assert ls._resolve_auto(spd, jnp.zeros(d)) == "cg"
                assert ls._upgrade_for_sharded("cg", spd) == "cg"
                # ...but materializing names stay a correctness upgrade
                assert ls._upgrade_for_sharded("pallas_cg", spd) \
                    == "sharded_cg"
        with autotune.use_cache(seeded(0.5)):
            assert ls._resolve_auto(spd, jnp.zeros(d)) == "sharded_cg"
            assert ls._upgrade_for_sharded("cg", spd) == "sharded_cg"

    def test_route_solve_auto_sizes_from_one_instance(self, rng, mesh):
        """route_solve's "auto" must size the system from ONE instance of a
        batch-aware operator: B·d > MAX_DENSE_DIM with small d still lands
        in the per-shard dense regime (regression: the raveled batched rhs
        used to inflate d past the crossover)."""
        d = 40                              # B * d = 640 > MAX_DENSE_DIM
        assert B * d > ls.MAX_DENSE_DIM and d < ls.MAX_DENSE_DIM
        # diagonally dominant so restarted GMRES converges tightly — the
        # property under test is the ROUTING, not solver conditioning
        A = 0.3 * jnp.asarray(rng.randn(B, d, d)) + 5.0 * jnp.eye(d)
        wide = ShardedOperator(ops.DenseOperator(A, symmetric=False), mesh,
                               P("data", None))
        calls = []
        orig = ls.get_spec("sharded_dense_gmres")

        def spy(mv, rhs, **kw):
            calls.append(1)
            return orig.fn(mv, rhs, **kw)

        ls.register_solver("sharded_dense_gmres", spy,
                           supports_precond=True, matrix_free=False,
                           description=orig.description)
        try:
            b = jnp.asarray(rng.randn(B, d))
            x = ls.route_solve("auto", wide, b, tol=1e-8, maxiter=2000)
        finally:
            ls._REGISTRY["sharded_dense_gmres"] = orig
        assert calls, "auto routed past the dense regime (sized from the " \
                      "raveled batch instead of one instance)"
        np.testing.assert_allclose(
            x, jnp.linalg.solve(A, b[..., None])[..., 0], atol=1e-5)

    def test_sharded_solver_requires_sharded_operator(self, rng):
        base = ops.DenseOperator(_batched_spd(rng, B, 4),
                                 positive_definite=True)
        with pytest.raises(ValueError, match="ShardedOperator"):
            ls.solve(base, jnp.ones((B, 4)), method="sharded_cg")

    def test_jacobi_precond_through_sharded_cg(self, rng, mesh):
        d = 6
        A = _batched_spd(rng, B, d) + 3.0 * jnp.eye(d)
        base = ops.DenseOperator(A, positive_definite=True)
        sh = ShardedOperator(base, mesh, P("data", None))
        b = jnp.asarray(rng.randn(B, d))
        x = ls.solve(sh, b, method="sharded_cg", precond="jacobi",
                     tol=1e-10)
        np.testing.assert_allclose(
            x, jnp.linalg.solve(A, b[..., None])[..., 0], atol=1e-8)

    def test_vmap_of_sharded_solve(self, rng, mesh):
        d = 4
        A = _batched_spd(rng, B, d)
        base = ops.DenseOperator(A, positive_definite=True)
        sh = ShardedOperator(base, mesh, P("data", None))
        rhs = jnp.asarray(rng.randn(3, B, d))
        xs = jax.vmap(lambda bi: ls.solve(sh, bi, method="sharded_cg",
                                          tol=1e-10))(rhs)
        xs_ref = jax.vmap(lambda bi: ls.solve(base, bi, method="cg",
                                              tol=1e-10))(rhs)
        np.testing.assert_allclose(xs, xs_ref, atol=1e-10)


# ---------------------------------------------------------------------------
# implicit differentiation on the mesh (the acceptance criteria)
# ---------------------------------------------------------------------------

def _ridge_problem(rng, B, m, d):
    X = jnp.asarray(rng.randn(B, m, d))
    y = jnp.asarray(rng.randn(B, m))
    return X, y


def _batched_ridge_F(x, theta, X, y):
    """Per-instance ridge stationarity — block-diagonal over the batch, so
    its Jacobian matvec is shard-local under batch sharding."""
    r = jnp.einsum("bmd,bd->bm", X, x) - y
    return jnp.einsum("bmd,bm->bd", X, r) + theta[:, None] * x


def _direct_ridge_solver(init, theta, X, y):
    d = X.shape[-1]
    A = jnp.einsum("bmd,bme->bde", X, X) \
        + theta[:, None, None] * jnp.eye(d)
    return jnp.linalg.solve(
        A, jnp.einsum("bmd,bm->bd", X, y)[..., None])[..., 0]


def _ridge_sharding(mesh):
    return SolveSharding(mesh, P("data", None), batch_ndim=1,
                         theta_specs=(P("data"), P("data", None, None),
                                      P("data", None)))


class TestShardedImplicitDiff:

    def _problem(self, rng, mesh, m=12, d=6):
        X, y = _ridge_problem(rng, B, m, d)
        spec = ImplicitDiffSpec(optimality_fun=_batched_ridge_F, solve="cg",
                                tol=1e-12, sharding=_ridge_sharding(mesh))
        ref_spec = spec.replace(sharding=None)
        theta = jnp.linspace(0.5, 2.0, B)
        return X, y, spec, ref_spec, theta

    def test_grad_matches_single_device(self, rng, mesh):
        X, y, spec, ref_spec, theta = self._problem(rng, mesh)
        dec = implicit_diff(spec)(_direct_ridge_solver)
        ref = implicit_diff(ref_spec)(_direct_ridge_solver)
        g_ref = jax.grad(lambda t: jnp.sum(ref(None, t, X, y) ** 2))(theta)
        sh = spec.sharding
        t_sh = _put(mesh, theta, P("data"))
        X_sh = _put(mesh, X, P("data", None, None))
        y_sh = _put(mesh, y, P("data", None))
        g = jax.jit(jax.grad(
            lambda t: jnp.sum(dec(None, t, X_sh, y_sh) ** 2)))(t_sh)
        np.testing.assert_allclose(g, g_ref, atol=1e-5)     # acceptance
        assert g.sharding == NamedSharding(sh.mesh, P("data"))

    def test_jvp_matches_single_device(self, rng, mesh):
        X, y, spec, ref_spec, theta = self._problem(rng, mesh)
        dec = implicit_diff(spec)(_direct_ridge_solver)
        ref = implicit_diff(ref_spec)(_direct_ridge_solver)
        tangent = jnp.ones(B)
        jv = jax.jvp(lambda t: dec(None, t, X, y), (theta,), (tangent,))[1]
        jv_ref = jax.jvp(lambda t: ref(None, t, X, y), (theta,),
                         (tangent,))[1]
        np.testing.assert_allclose(jv, jv_ref, atol=1e-5)

    def test_vjp_mode_matches(self, rng, mesh):
        X, y, spec, ref_spec, theta = self._problem(rng, mesh)
        dec = implicit_diff(spec, mode="vjp")(_direct_ridge_solver)
        ref = implicit_diff(ref_spec)(_direct_ridge_solver)
        g = jax.grad(lambda t: jnp.sum(dec(None, t, X, y) ** 2))(theta)
        g_ref = jax.grad(lambda t: jnp.sum(ref(None, t, X, y) ** 2))(theta)
        np.testing.assert_allclose(g, g_ref, atol=1e-5)

    def test_grad_executes_one_sharded_solve(self, rng, mesh):
        """Counting spy + trace census, mirroring the PR 2/3 tests: the
        backward pass of a sharded grad routes exactly ONE sharded linear
        solve (the cotangent system), while the trace stages one template
        per direction."""
        from repro.distributed import sharded_operators as dso
        X, y, spec, _, theta = self._problem(rng, mesh)
        traced, executed = [], []

        def counting_sharded_cg(matvec, b, **kw):
            traced.append(1)
            jax.debug.callback(lambda _: executed.append(1), jnp.zeros(()))
            return dso.sharded_solve_cg(matvec, b, **kw)

        ls.register_solver("counting_sharded_cg", counting_sharded_cg,
                           symmetric_only=True, supports_precond=True)
        try:
            dec = implicit_diff(spec.replace(solve="counting_sharded_cg"))(
                _direct_ridge_solver)
            g = jax.grad(lambda t: jnp.sum(dec(None, t, X, y) ** 2))(theta)
            jax.effects_barrier()
            assert len(executed) == 1, \
                f"expected ONE sharded backward solve, ran {len(executed)}"
            assert len(traced) == 2     # one template per direction
        finally:
            ls._REGISTRY.pop("counting_sharded_cg", None)
        assert np.isfinite(np.asarray(g)).all()

    def test_no_host_gather_with_sharded_forward(self, rng, mesh):
        """With the forward solve on the mesh too, the whole compiled grad
        contains NO all-gather: the backward solve runs per shard and only
        the loss/psum reductions cross devices."""
        from jax.experimental.shard_map import shard_map
        X, y, spec, _, theta = self._problem(rng, mesh)

        def sharded_solver(init, theta, X, y):
            return shard_map(
                lambda t, Xl, yl: _direct_ridge_solver(None, t, Xl, yl),
                mesh=mesh,
                in_specs=(P("data"), P("data", None, None),
                          P("data", None)),
                out_specs=P("data", None), check_rep=False)(theta, X, y)

        dec = implicit_diff(spec)(sharded_solver)
        t_sh = _put(mesh, theta, P("data"))
        X_sh = _put(mesh, X, P("data", None, None))
        y_sh = _put(mesh, y, P("data", None))
        gfun = jax.jit(jax.grad(
            lambda t: jnp.sum(dec(None, t, X_sh, y_sh) ** 2)))
        compiled = gfun.lower(t_sh).compile()
        hlo = compiled.as_text()
        assert hlo.count("all-gather") == 0, \
            "sharded hypergradient compiled with a gather"
        g = gfun(t_sh)
        assert g.sharding == NamedSharding(mesh, P("data"))
        ref = implicit_diff(spec.replace(sharding=None))(
            _direct_ridge_solver)
        g_ref = jax.grad(
            lambda t: jnp.sum(ref(None, t, X, y) ** 2))(theta)
        np.testing.assert_allclose(g, g_ref, atol=1e-5)

    def test_runtime_solver_with_sharding(self, rng, mesh):
        """The state-based runtime rides the same seam: an IterativeSolver
        with ``sharding`` pins its iterate to the mesh and its backward
        solve upgrades to the sharded variants."""
        d = 4
        w = 1.0 + jnp.asarray(rng.rand(B, d))

        def fun(x, theta, w):   # elementwise => shard-local optimality;
            # batched data rides as a theta arg (anything the residual
            # merely closed over would be replicated into every shard)
            return 0.5 * jnp.sum(w * (x - theta) ** 2)

        sharding = SolveSharding(mesh, P("data", None), batch_ndim=1,
                                 theta_specs=(P("data", None),
                                              P("data", None)))
        solver = GradientDescent(fun, stepsize=0.5, maxiter=400, tol=1e-12,
                                 solve="cg", linsolve_tol=1e-12,
                                 sharding=sharding)
        ref = GradientDescent(fun, stepsize=0.5, maxiter=400, tol=1e-12,
                              solve="cg", linsolve_tol=1e-12)
        theta = jnp.asarray(rng.randn(B, d))
        x0 = jnp.zeros((B, d))

        def loss(s):
            return lambda t: jnp.sum(s.run(x0, t, w)[0] ** 2)

        g = jax.grad(loss(solver))(theta)
        g_ref = jax.grad(loss(ref))(theta)
        np.testing.assert_allclose(g, g_ref, atol=1e-5)


# The hypothesis property tests for this subsystem (ravel_view round-trip,
# ShardedOperator.matvec equivalence under jax.vmap) live in
# tests/test_sharded_properties.py so this module stays runnable without
# hypothesis; that module hard-gates via conftest.require_hypothesis().
