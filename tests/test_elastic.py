"""Elastic scaling integration test: train on an 8-device mesh, checkpoint,
'lose' half the fleet, restore and continue on a 4-device mesh — losses must
continue from the same trajectory (the data stream is deterministic, so the
post-restore loss is bit-comparable to an uninterrupted run at the same
batch schedule)."""
import os
import subprocess
import sys
import textwrap

import pytest


def run_subprocess(code: str, devices: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


PHASE = """
import sys, json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLMStream
from repro.distributed import sharding as shd
from repro.optim import sgd
from repro.optim.optimizer import OptState
from repro.runtime import TrainStepConfig, TrainState, make_train_state, \\
    make_train_step

mesh_shape = {mesh_shape}
start_step, num_steps = {start_step}, {num_steps}
ckpt_dir = {ckpt_dir!r}

cfg = configs.get("qwen1.5-4b", smoke=True)
opt = sgd(1e-2, momentum=0.0)
step = make_train_step(cfg, opt, TrainStepConfig(remat=False))
state = make_train_state(cfg, opt, jax.random.PRNGKey(0))

mesh = jax.make_mesh(mesh_shape, ("data", "model"))
rules = shd.ShardingRules()
pspecs = shd.params_specs(state.params, rules, mesh)
sspec = TrainState(params=pspecs,
                   opt_state=OptState(step=P(), mu=pspecs, nu=None),
                   err_state=None)
N = lambda t: jax.tree_util.tree_map(
    lambda s: NamedSharding(mesh, s), t, is_leaf=lambda z: isinstance(z, P))
jstep = jax.jit(step, in_shardings=(N(sspec), NamedSharding(mesh, P("data")),
                                    NamedSharding(mesh, P("data"))),
                out_shardings=(N(sspec), None))

mgr = CheckpointManager(ckpt_dir)
latest = mgr.latest_step()
if latest is not None:
    target = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    state = mgr.restore(latest, target)     # full arrays; jit re-shards

stream = SyntheticLMStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                      global_batch=8))
losses = []
for s in range(start_step, start_step + num_steps):
    x, y = stream.batch_at(s)
    state, m = jstep(state, x, y)
    losses.append(float(m["loss"]))
mgr.save(start_step + num_steps, state, blocking=True)
print("LOSSES", json.dumps(losses))
"""


@pytest.mark.slow
def test_elastic_restart_on_smaller_mesh(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    # phase 1: 8 devices (4x2)
    run_subprocess(PHASE.format(mesh_shape=(4, 2), start_step=0,
                                num_steps=6, ckpt_dir=ckpt),
                   devices=8)
    # phase 2: HALF the fleet (2x2) — elastic restore, continue training
    out2 = run_subprocess(PHASE.format(mesh_shape=(2, 2), start_step=6,
                                       num_steps=4, ckpt_dir=ckpt),
                          devices=4)
    # control: uninterrupted single-mesh run of the full schedule
    import json
    ckpt2 = str(tmp_path / "ckpt2")
    ref = run_subprocess(PHASE.format(mesh_shape=(2, 2), start_step=0,
                                      num_steps=10, ckpt_dir=ckpt2),
                         devices=4)
    l2 = json.loads(out2.split("LOSSES", 1)[1])
    lref = json.loads(ref.split("LOSSES", 1)[1])[6:]
    # same data schedule + restored state: the continued trajectory matches
    # the uninterrupted one (bf16 tolerance)
    assert len(l2) == len(lref) == 4
    for a, b in zip(l2, lref):
        assert abs(a - b) < 5e-2, (l2, lref)
