"""Distribution layer tests.

These run in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8
where multi-device execution is required (the main test process must keep the
default 1-device view for everything else).  Pure spec-construction tests run
in-process against a degenerate mesh.
"""
import os
import subprocess
import sys
import textwrap

import jax

from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import sharding as shd


def run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestSpecConstruction:

    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_matrix_megatron_pairing(self):
        mesh = self._mesh()
        rules = shd.ShardingRules()
        # column-parallel in
        s = shd.param_spec(("blocks", "attn", "w_q"), (256, 256), rules,
                           mesh)
        assert s == P(None, "model") or s == P("data", "model")
        # row-parallel out
        s = shd.param_spec(("blocks", "attn", "w_o"), (256, 256), rules,
                           mesh)
        assert s[0] == "model"

    def test_embed_vocab_on_model_only(self):
        mesh = self._mesh()
        s = shd.param_spec(("embed", "tok"), (50304, 512),
                           shd.ShardingRules(), mesh)
        assert s == P("model", None)
        s = shd.param_spec(("embed", "unembed"), (512, 50304),
                           shd.ShardingRules(), mesh)
        assert s == P(None, "model")

    def test_vectors_replicated(self):
        mesh = self._mesh()
        s = shd.param_spec(("blocks", "ln1", "scale"), (512,),
                           shd.ShardingRules(), mesh)
        assert s == P()

    def test_moe_expert_dim_on_model_when_divisible(self):
        # shape-only: AbstractMesh needs no physical devices
        mesh = shd.abstract_mesh((1, 16), ("data", "model"))
        rules = shd.ShardingRules()
        s = shd.param_spec(("blocks", "mlp", "w_gate"), (160, 5120, 1536),
                           rules, mesh)
        assert s[0] == "model"
        # 40 experts don't divide 16: falls to matmul-dim sharding
        s = shd.param_spec(("blocks", "mlp", "w_gate"), (40, 1536, 512),
                           rules, mesh)
        assert s[0] is None and "model" in s

    def test_blocks_leading_layer_axis_never_sharded(self):
        mesh = self._mesh()
        cfg = configs.get("llama3-405b", smoke=True)
        from repro.models import model as mdl
        params = mdl.init_params_abstract(jax.random.PRNGKey(0), cfg)
        specs = shd.params_specs(params, shd.ShardingRules(), mesh)
        flat, _ = jax.tree_util.tree_flatten_with_path(specs)
        for path, spec in flat:
            keys = [getattr(k, "key", None) for k in path]
            if keys[0] == "blocks":
                assert spec[0] is None, (keys, spec)

    def test_all_archs_specs_constructible(self):
        """Spec construction must succeed for every assigned arch (full-size
        configs — shapes only, no allocation)."""
        mesh = shd.abstract_mesh((1, 16), ("data", "model"))
        from repro.models import model as mdl
        for name in configs.names():
            cfg = configs.get(name)
            params = mdl.init_params_abstract(jax.random.PRNGKey(0), cfg)
            specs = shd.params_specs(params, shd.ShardingRules(), mesh)
            # every leaf got a spec of matching rank
            flat_p = jax.tree_util.tree_leaves(params)
            flat_s = jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, P))
            assert len(flat_p) == len(flat_s)


class TestMultiDeviceExecution:
    """Real sharded execution on 8 host devices (subprocess)."""

    def test_sharded_train_step_matches_single_device(self):
        out = run_subprocess("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P, NamedSharding
            from repro import configs
            from repro.distributed import sharding as shd
            from repro.optim import sgd
            from repro.runtime import (TrainStepConfig, make_train_state,
                                       make_train_step)
            cfg = configs.get("llama3-405b", smoke=True)
            opt = sgd(1e-2, momentum=0.0)
            tcfg = TrainStepConfig(microbatches=1, remat=False)
            step = make_train_step(cfg, opt, tcfg)
            state = make_train_state(cfg, opt, jax.random.PRNGKey(0))
            x = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                   cfg.vocab_size)
            y = jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0,
                                   cfg.vocab_size)
            # single device reference
            s_ref, m_ref = jax.jit(step)(state, x, y)

            mesh = jax.make_mesh((4, 2), ("data", "model"))
            rules = shd.ShardingRules()
            pspecs = shd.params_specs(state.params, rules, mesh)
            import repro.optim.optimizer as O
            from repro.runtime import TrainState
            sspec = TrainState(params=pspecs,
                               opt_state=O.OptState(step=P(), mu=pspecs,
                                                    nu=None),
                               err_state=None)
            N = lambda t: jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), t,
                is_leaf=lambda z: isinstance(z, P))
            jstep = jax.jit(step, in_shardings=(N(sspec), NamedSharding(
                mesh, P("data")), NamedSharding(mesh, P("data"))),
                out_shardings=(N(sspec), None))
            s_sh, m_sh = jstep(state, x, y)
            print("LOSS", float(m_ref["loss"]), float(m_sh["loss"]))
            w_ref = jax.tree_util.tree_leaves(s_ref.params)[3]
            w_sh = jax.tree_util.tree_leaves(s_sh.params)[3]
            err = float(jnp.max(jnp.abs(w_ref.astype(jnp.float32)
                                        - w_sh.astype(jnp.float32))))
            print("WERR", err)
            assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 5e-2
            assert err < 5e-2
            print("OK")
        """)
        assert "OK" in out

    def test_pipeline_parallel_matches_sequential(self):
        out = run_subprocess("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.distributed.pipeline import pipeline_forward
            mesh = jax.make_mesh((4,), ("stage",))
            L, M, mb, d = 8, 8, 4, 16
            key = jax.random.PRNGKey(0)
            W = 0.3 * jax.random.normal(key, (L, d, d))

            def block(w, x):
                return jnp.tanh(x @ w)

            xs = jax.random.normal(jax.random.fold_in(key, 1), (M, mb, d))
            # sequential reference
            def seq(x):
                for i in range(L):
                    x = block(W[i], x)
                return x
            ref = jax.vmap(seq)(xs.reshape(M * mb, d)[None])[0] \
                .reshape(M, mb, d) if False else \
                jnp.stack([seq(xs[i]) for i in range(M)])
            out = pipeline_forward(block, W, xs, mesh)
            err = float(jnp.max(jnp.abs(out - ref)))
            print("ERR", err)
            assert err < 1e-5
            print("OK")
        """)
        assert "OK" in out

    def test_decode_state_sharding_executes(self):
        out = run_subprocess("""
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P, NamedSharding
            from repro import configs
            from repro.distributed import sharding as shd
            from repro.models import (init_params, init_decode_state,
                                      decode_step)
            from repro.models import model as mdl
            cfg = configs.get("llama3-405b", smoke=True)
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            rules = shd.ShardingRules()
            params = init_params(jax.random.PRNGKey(0), cfg)
            state = init_decode_state(cfg, 4, 32)
            pspecs = shd.params_specs(params, rules, mesh)
            sspecs = mdl.DecodeState(
                caches=shd.decode_state_specs(state.caches, rules, cfg,
                                              mesh),
                index=P())
            N = lambda t: jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), t,
                is_leaf=lambda z: isinstance(z, P))
            step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t),
                           in_shardings=(N(pspecs), N(sspecs),
                                         NamedSharding(mesh, P("data"))),
                           out_shardings=(NamedSharding(mesh, P("data")),
                                          N(sspecs)))
            tok = jnp.zeros((4, 1), jnp.int32)
            logits, state2 = step(params, state, tok)
            assert logits.shape == (4, 1, cfg.vocab_size)
            assert int(state2.index) == 1
            print("OK")
        """)
        assert "OK" in out


class TestShardedImplicitDiff:
    """The paper's machinery under sharding: hypergradient linear solves run
    on a mesh with the same collectives as the forward pass."""

    def test_sharded_custom_root_matches_single_device(self):
        out = run_subprocess("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P, NamedSharding
            jax.config.update("jax_enable_x64", True)
            from repro.core import custom_root
            mesh = jax.make_mesh((8,), ("data",))
            m, d = 64, 16
            key = jax.random.PRNGKey(0)
            X = jax.random.normal(key, (m, d))
            y = jax.random.normal(jax.random.fold_in(key, 1), (m,))

            def f(x, theta):
                r = X @ x - y
                return 0.5 * jnp.sum(r ** 2) + 0.5 * theta * jnp.sum(x ** 2)

            F = jax.grad(f, argnums=0)

            @custom_root(F, tol=1e-12)
            def solver(init, theta):
                return jnp.linalg.solve(X.T @ X + theta * jnp.eye(d),
                                        X.T @ y)

            def outer(theta):
                return jnp.sum(solver(None, theta) ** 2)

            g_single = jax.grad(outer)(2.0)
            # shard the data matrix across devices and re-run under jit
            Xs = jax.device_put(X, NamedSharding(mesh, P("data", None)))
            ys = jax.device_put(y, NamedSharding(mesh, P("data")))

            def f2(x, theta):
                r = Xs @ x - ys
                return 0.5 * jnp.sum(r ** 2) + 0.5 * theta * jnp.sum(x ** 2)

            F2 = jax.grad(f2, argnums=0)

            @custom_root(F2, tol=1e-12)
            def solver2(init, theta):
                return jnp.linalg.solve(Xs.T @ Xs + theta * jnp.eye(d),
                                        Xs.T @ ys)

            g_shard = jax.jit(jax.grad(
                lambda t: jnp.sum(solver2(None, t) ** 2)))(2.0)
            print("G", float(g_single), float(g_shard))
            assert abs(float(g_single) - float(g_shard)) < 1e-8
            print("OK")
        """)
        assert "OK" in out
