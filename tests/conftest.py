import jax
import pytest

# float64 gives the numerical headroom the implicit-diff precision tests need
jax.config.update("jax_enable_x64", True)


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
