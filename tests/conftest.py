import os

import jax
import pytest

# float64 gives the numerical headroom the implicit-diff precision tests need
jax.config.update("jax_enable_x64", True)


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)


def require_hypothesis():
    """Guard for property-test modules: skip without ``hypothesis`` locally,
    but HARD-FAIL when ``REPRO_REQUIRE_HYPOTHESIS`` is set (the CI fast lane
    sets it), so the property tests can never be silently skipped there.
    """
    try:
        import hypothesis  # noqa: F401
    except ImportError:
        if os.environ.get("REPRO_REQUIRE_HYPOTHESIS"):
            pytest.fail(
                "hypothesis is not installed but REPRO_REQUIRE_HYPOTHESIS "
                "is set — the property tests must actually run in CI "
                "(pip install -e .[dev])", pytrace=False)
        pytest.skip("hypothesis not installed", allow_module_level=True)
