"""Projection and prox catalog tests (paper Appendix C), with property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import require_hypothesis

require_hypothesis()   # hard-fails under REPRO_REQUIRE_HYPOTHESIS (CI)
from hypothesis import given, settings, strategies as st

from repro.core import projections as P
from repro.core import prox as prx


# ---------------------------------------------------------------------------
# Simplex
# ---------------------------------------------------------------------------

class TestSimplex:

    def test_projection_feasible(self, rng):
        y = jax.random.normal(rng, (7,)) * 3
        x = P.projection_simplex(y)
        assert jnp.all(x >= 0)
        np.testing.assert_allclose(jnp.sum(x), 1.0, atol=1e-9)

    def test_already_on_simplex_is_identity(self):
        y = jnp.array([0.2, 0.3, 0.5])
        np.testing.assert_allclose(P.projection_simplex(y), y, atol=1e-9)

    def test_jacobian_closed_form(self, rng):
        """Appendix C: ∂proj = diag(s) − ssᵀ/‖s‖₁ with s the support."""
        y = jnp.array([0.3, -0.1, 0.8, 0.05])
        x = P.projection_simplex(y)
        s = (x > 0).astype(jnp.float64)
        J = jax.jacobian(P.projection_simplex)(y)
        J_true = jnp.diag(s) - jnp.outer(s, s) / jnp.sum(s)
        np.testing.assert_allclose(J, J_true, atol=1e-9)

    def test_batched(self, rng):
        Y = jax.random.normal(rng, (5, 9))
        X = P.projection_simplex(Y)
        np.testing.assert_allclose(jnp.sum(X, -1), jnp.ones(5), atol=1e-9)
        Xv = jax.vmap(P.projection_simplex)(Y)
        np.testing.assert_allclose(X, Xv, atol=1e-12)

    def test_kl_projection_is_softmax(self, rng):
        y = jax.random.normal(rng, (6,))
        np.testing.assert_allclose(P.projection_simplex_kl(y),
                                   jax.nn.softmax(y), atol=1e-12)

    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), d=st.integers(2, 30),
           scale=st.floats(0.1, 10.0))
    def test_property_optimality(self, seed, d, scale):
        """Property: proj(y) is the closest simplex point — verify via the
        variational inequality <y − x*, z − x*> ≤ 0 for random feasible z."""
        key = jax.random.PRNGKey(seed)
        y = jax.random.normal(key, (d,)) * 2
        x = P.projection_simplex(y, scale)
        assert float(jnp.sum(x)) == pytest.approx(scale, abs=1e-6)
        assert jnp.all(x >= -1e-12)
        z = jax.random.dirichlet(jax.random.fold_in(key, 1),
                                 jnp.ones(d)) * scale
        assert float(jnp.vdot(y - x, z - x)) <= 1e-6


# ---------------------------------------------------------------------------
# Balls / boxes / planes
# ---------------------------------------------------------------------------

class TestSets:

    def test_box(self):
        y = jnp.array([-2.0, 0.5, 3.0])
        np.testing.assert_allclose(P.projection_box(y, (0.0, 1.0)),
                                   jnp.array([0.0, 0.5, 1.0]))

    def test_l2_ball(self, rng):
        y = jax.random.normal(rng, (5,)) * 10
        x = P.projection_l2_ball(y, 2.0)
        np.testing.assert_allclose(jnp.linalg.norm(x), 2.0, rtol=1e-9)
        y_in = y / jnp.linalg.norm(y) * 0.5
        np.testing.assert_allclose(P.projection_l2_ball(y_in, 2.0), y_in)

    def test_l1_ball(self, rng):
        y = jax.random.normal(rng, (6,)) * 5
        x = P.projection_l1_ball(y, 1.0)
        np.testing.assert_allclose(jnp.sum(jnp.abs(x)), 1.0, atol=1e-8)
        assert jnp.all(jnp.sign(x) * jnp.sign(y) >= 0)

    def test_linf_ball(self, rng):
        y = jax.random.normal(rng, (6,)) * 5
        assert jnp.max(jnp.abs(P.projection_linf_ball(y, 0.7))) <= 0.7 + 1e-12

    def test_hyperplane(self, rng):
        a = jax.random.normal(rng, (4,))
        y = jax.random.normal(jax.random.fold_in(rng, 1), (4,))
        x = P.projection_hyperplane(y, (a, 2.0))
        np.testing.assert_allclose(jnp.vdot(a, x), 2.0, atol=1e-9)

    def test_halfspace(self, rng):
        a = jnp.array([1.0, 1.0])
        x = P.projection_halfspace(jnp.array([2.0, 2.0]), (a, 1.0))
        assert float(jnp.vdot(a, x)) <= 1.0 + 1e-9
        inside = jnp.array([-1.0, -1.0])
        np.testing.assert_allclose(
            P.projection_halfspace(inside, (a, 1.0)), inside)

    def test_affine_set(self, rng):
        A = jax.random.normal(rng, (2, 5))
        b = jnp.array([1.0, -0.5])
        y = jax.random.normal(jax.random.fold_in(rng, 1), (5,))
        x = P.projection_affine_set(y, (A, b))
        np.testing.assert_allclose(A @ x, b, atol=1e-8)
        # y − x ⟂ null(A): x is the orthogonal projection
        ns = jnp.eye(5) - jnp.linalg.pinv(A) @ A
        np.testing.assert_allclose(ns @ (y - x), 0.0, atol=1e-8)

    def test_box_section(self, rng):
        """Appendix C: singly-constrained bounded QP by bisection."""
        d = 6
        alpha, beta = jnp.zeros(d), jnp.ones(d)
        w = jnp.ones(d)
        y = jax.random.normal(rng, (d,))
        x = P.projection_box_section(y, (alpha, beta, w, 1.0))
        np.testing.assert_allclose(jnp.vdot(w, x), 1.0, atol=1e-6)
        assert jnp.all(x >= -1e-9) and jnp.all(x <= 1 + 1e-9)
        # equal weights + unit budget in [0,1]^d == simplex projection
        np.testing.assert_allclose(x, P.projection_simplex(y), atol=1e-6)

    def test_box_section_gradient(self, rng):
        d = 4
        theta = (jnp.zeros(d), jnp.ones(d), jnp.ones(d), 1.0)
        # avoid kinks: no coordinate of the solution exactly at a bound
        y = jnp.array([0.31, -0.2, 0.9, 0.13])

        def f(y):
            return jnp.sum(P.projection_box_section(y, theta) ** 2)

        g = jax.grad(f)(y)
        eps = 1e-6
        for i in range(d):
            fd = (f(y.at[i].add(eps)) - f(y.at[i].add(-eps))) / (2 * eps)
            np.testing.assert_allclose(g[i], fd, atol=1e-4)

    def test_order_simplex(self):
        y = jnp.array([0.1, 0.9, 0.4, 0.45])
        x = P.projection_order_simplex(y, (1.0, 0.0))
        assert jnp.all(jnp.diff(x) <= 1e-9)          # non-increasing
        assert jnp.all(x >= 0) and jnp.all(x <= 1)

    def test_second_order_cone(self):
        # inside
        y = jnp.array([2.0, 1.0, 0.0])
        np.testing.assert_allclose(P.projection_second_order_cone(y), y)
        # polar
        y = jnp.array([-2.0, 1.0, 0.0])
        np.testing.assert_allclose(P.projection_second_order_cone(y), 0.0,
                                   atol=1e-12)
        # boundary projection
        y = jnp.array([0.0, 2.0, 0.0])
        x = P.projection_second_order_cone(y)
        np.testing.assert_allclose(x, jnp.array([1.0, 1.0, 0.0]), atol=1e-9)


class TestTransport:

    def test_sinkhorn_marginals(self, rng):
        a = jnp.array([0.2, 0.3, 0.5])
        b = jnp.array([0.25, 0.25, 0.25, 0.25])
        y = jax.random.normal(rng, (3, 4))
        X = P.projection_transport_kl(y, (a, b), num_iters=200)
        np.testing.assert_allclose(X.sum(1), a, atol=1e-6)
        np.testing.assert_allclose(X.sum(0), b, atol=1e-6)

    def test_birkhoff(self, rng):
        y = jax.random.normal(rng, (4, 4))
        X = P.projection_birkhoff_kl(y, num_iters=300)
        np.testing.assert_allclose(X.sum(0), 0.25, atol=1e-6)
        np.testing.assert_allclose(X.sum(1), 0.25, atol=1e-6)


# ---------------------------------------------------------------------------
# Prox operators
# ---------------------------------------------------------------------------

class TestProx:

    def test_lasso_soft_threshold(self):
        y = jnp.array([-2.0, -0.5, 0.0, 0.5, 2.0])
        np.testing.assert_allclose(
            prx.prox_lasso(y, 1.0),
            jnp.array([-1.0, 0.0, 0.0, 0.0, 1.0]))

    def test_elastic_net_reduces_to_lasso(self, rng):
        y = jax.random.normal(rng, (5,))
        np.testing.assert_allclose(prx.prox_elastic_net(y, (0.3, 0.0)),
                                   prx.prox_lasso(y, 0.3))

    def test_group_lasso_shrinks_norm(self, rng):
        y = jax.random.normal(rng, (3, 4))
        x = prx.prox_group_lasso(y, 0.5)
        n_y = jnp.linalg.norm(y, axis=-1)
        n_x = jnp.linalg.norm(x, axis=-1)
        np.testing.assert_allclose(n_x, jnp.maximum(n_y - 0.5, 0.0),
                                   atol=1e-9)

    def test_log_barrier_positive(self, rng):
        y = jax.random.normal(rng, (6,)) * 3
        assert jnp.all(prx.prox_log_barrier(y, 0.5) > 0)

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), lam=st.floats(0.01, 5.0))
    def test_property_prox_is_prox(self, seed, lam):
        """Property: x = prox_g(y) satisfies the prox optimality condition
        (for lasso: y − x ∈ λ∂‖x‖₁)."""
        y = jax.random.normal(jax.random.PRNGKey(seed), (8,))
        x = prx.prox_lasso(y, lam)
        r = y - x
        on = jnp.abs(x) > 0
        assert bool(jnp.all(jnp.where(on, jnp.abs(
            r - lam * jnp.sign(x)) < 1e-9, jnp.abs(r) <= lam + 1e-9)))

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2 ** 16))
    def test_property_prox_nonexpansive(self, seed):
        """Property (Moreau): prox operators are 1-Lipschitz."""
        k = jax.random.PRNGKey(seed)
        y1 = jax.random.normal(jax.random.fold_in(k, 0), (6,))
        y2 = jax.random.normal(jax.random.fold_in(k, 1), (6,))
        for fn in (lambda v: prx.prox_lasso(v, 0.7),
                   lambda v: prx.prox_elastic_net(v, (0.5, 0.2)),
                   lambda v: prx.prox_ridge(v, 1.3),
                   lambda v: P.projection_simplex(v),
                   lambda v: P.projection_l2_ball(v, 1.0)):
            d_out = jnp.linalg.norm(fn(y1) - fn(y2))
            d_in = jnp.linalg.norm(y1 - y2)
            assert float(d_out) <= float(d_in) + 1e-9
