"""Bi-level optimization driver built on implicit differentiation.

    min_θ  L_outer(x*(θ), θ)   s.t.   x*(θ) = argmin_x  L_inner(x, θ)

The hypergradient ∇θ L_outer flows through x*(θ) via ``custom_root`` on the
stationarity condition (or a user-supplied fixed point), i.e. one extra
matrix-free linear solve instead of unrolled backprop through the inner run —
the paper's headline efficiency claim, and what makes bilevel viable when the
inner problem is a sharded, multi-pod training run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import implicit_diff, optimality


@dataclasses.dataclass
class BilevelSolution:
    theta: Any
    x_star: Any
    outer_values: Any      # (steps,) trace of outer loss
    hypergrad_norms: Any   # (steps,)


def make_implicit_inner(inner_solver: Callable,
                        inner_objective: Optional[Callable] = None,
                        fixed_point: Optional[Callable] = None,
                        solve: str = "cg", tol: float = 1e-6,
                        maxiter: int = 1000, ridge: float = 0.0) -> Callable:
    """Wrap ``inner_solver(init, theta) -> x*`` with implicit derivatives.

    Provide either ``inner_objective`` (stationarity condition used) or an
    explicit ``fixed_point`` mapping T(x, theta).
    """
    if (inner_objective is None) == (fixed_point is None):
        raise ValueError("provide exactly one of inner_objective/fixed_point")
    if inner_objective is not None:
        F = optimality.stationary(inner_objective)
        deco = implicit_diff.custom_root(F, solve=solve, tol=tol,
                                         maxiter=maxiter, ridge=ridge)
    else:
        deco = implicit_diff.custom_fixed_point(fixed_point, solve=solve,
                                                tol=tol, maxiter=maxiter,
                                                ridge=ridge)
    return deco(inner_solver)


def solve_bilevel(outer_loss: Callable, inner_solver: Callable, theta0,
                  x_init, *, inner_objective: Optional[Callable] = None,
                  fixed_point: Optional[Callable] = None,
                  outer_steps: int = 100, outer_lr: float = 1e-2,
                  momentum: float = 0.9, solve: str = "cg",
                  inner_tol: float = 1e-6, linsolve_maxiter: int = 1000,
                  ridge: float = 0.0, warm_start: bool = True,
                  jit: bool = True) -> BilevelSolution:
    """Gradient descent (w/ momentum) on the outer problem.

    ``outer_loss(x_star, theta) -> scalar``;
    ``inner_solver(x_init, theta) -> x_star``.
    ``warm_start`` reuses the previous inner solution as init (the standard
    trick that makes the inner solves cheap along the outer trajectory).
    """
    implicit_solver = make_implicit_inner(
        inner_solver, inner_objective=inner_objective,
        fixed_point=fixed_point, solve=solve, tol=inner_tol,
        maxiter=linsolve_maxiter, ridge=ridge)

    def outer_value_and_grad(theta, x_init):
        def obj(theta):
            x_star = implicit_solver(x_init, theta)
            return outer_loss(x_star, theta), x_star
        (val, x_star), g = jax.value_and_grad(obj, has_aux=True)(theta)
        return val, g, x_star

    if jit:
        outer_value_and_grad = jax.jit(outer_value_and_grad)

    theta = theta0
    vel = jax.tree_util.tree_map(jnp.zeros_like, theta)
    xs = x_init
    vals, gnorms = [], []
    for _ in range(outer_steps):
        val, g, x_star = outer_value_and_grad(theta, xs)
        vel = jax.tree_util.tree_map(
            lambda v, gi: momentum * v + gi, vel, g)
        theta = jax.tree_util.tree_map(
            lambda t, v: t - outer_lr * v, theta, vel)
        if warm_start:
            xs = x_star
        vals.append(float(val))
        gnorms.append(float(jnp.sqrt(sum(
            jnp.vdot(x, x).real for x in jax.tree_util.tree_leaves(g)))))
    return BilevelSolution(theta=theta, x_star=x_star,
                           outer_values=jnp.asarray(vals),
                           hypergrad_norms=jnp.asarray(gnorms))


# ---------------------------------------------------------------------------
# Unrolled baseline (the paper's comparison axis)
# ---------------------------------------------------------------------------

def make_unrolled_inner(step_fn: Callable, num_steps: int) -> Callable:
    """Differentiate-through-the-solver baseline: backprop through
    ``num_steps`` applications of ``step_fn(x, theta) -> x``.  Memory grows
    O(num_steps); used by benchmarks to reproduce Fig. 3/4 comparisons."""

    def solver(x_init, theta):
        def body(x, _):
            return step_fn(x, theta), None
        x, _ = jax.lax.scan(body, x_init, None, length=num_steps)
        return x

    return solver
