"""Bi-level optimization driver built on implicit differentiation.

    min_θ  L_outer(x*(θ), θ)   s.t.   x*(θ) = argmin_x  L_inner(x, θ)

The hypergradient ∇θ L_outer flows through x*(θ) via implicit
differentiation of the inner optimality condition, i.e. one extra
matrix-free linear solve instead of unrolled backprop through the inner run —
the paper's headline efficiency claim, and what makes bilevel viable when the
inner problem is a sharded, multi-pod training run.  That solve runs against
a first-class ``operators.JacobianOperator`` of the inner optimality mapping
(built by the diff API), so routing here is pure configuration: the
``diff_spec``/loose kwargs pick the registry solver (``solve="auto"``
dispatches on the operator's structure) and ``precond="jacobi"`` /
``"block_jacobi"`` derive from the operator's diagonal/leaf blocks.

The preferred inner-solver form is a ``solver_runtime.IterativeSolver``:
it declares its own optimality mapping, self-wraps with ``custom_root``,
and reports per-step ``OptInfo`` diagnostics which this driver surfaces
(``BilevelSolution.inner_info``).  Bare callables with an explicit
``inner_objective`` / ``fixed_point`` keep working via
``make_implicit_inner``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import diff_api, optimality
from repro.core.diff_api import ImplicitDiffSpec
from repro.core.solver_runtime import IterativeSolver, OptInfo
from repro.observability import events as obs_events
from repro.observability import metrics as obs_metrics


@dataclasses.dataclass
class BilevelSolution:
    """Result of ``solve_bilevel``: final θ, inner solution and traces."""
    theta: Any
    x_star: Any
    outer_values: Any      # (steps,) trace of outer loss
    hypergrad_norms: Any   # (steps,)
    inner_info: Optional[OptInfo] = None   # last inner-solve diagnostics


def _make_inner_runner(inner_solver, inner_objective, fixed_point, solve,
                       tol, maxiter, ridge, precond, backward=None,
                       backward_iters=None, diff_spec=None,
                       mode=None) -> Callable:
    """``fn(init, theta) -> (x_star, OptInfo | None)``, implicit-diff'd.

    ``None`` loose routing arguments mean "not specified": an
    ``IterativeSolver`` keeps its own configured backward-solve routing for
    them (never silently clobbered by driver defaults); the bare-callable
    path falls back to the historical defaults (cg / 1e-6 / 1000 / 0.0).

    ``diff_spec`` (an ``ImplicitDiffSpec``) replaces the loose routing
    kwargs WHOLESALE — every routing field comes from the spec, including
    its defaults (to tweak one field of an ``IterativeSolver``'s existing
    config, pass ``inner_solver.diff_spec().replace(...)``).  A
    routing-only spec keeps the solver's declared mapping (combine it with
    ``inner_objective``/``fixed_point`` for bare callables); a spec
    carrying a mapping supersedes it.  ``mode`` selects the differentiation
    wrapping (``"auto"``/``"vjp"``/``"jvp"``; ``None`` keeps the solver's
    own setting, ``"auto"`` for bare callables).
    """
    loose = dict(solve=solve, tol=tol, maxiter=maxiter, ridge=ridge,
                 precond=precond, backward=backward,
                 backward_iters=backward_iters)
    if diff_spec is not None:
        if any(v is not None for v in loose.values()):
            raise ValueError("pass the backward-solve routing either via "
                             "diff_spec or via the loose solve/tol/maxiter/"
                             "ridge/precond/backward arguments, not both")
        if not diff_spec.is_routing_only and (
                inner_objective is not None or fixed_point is not None):
            raise ValueError("diff_spec already carries the optimality "
                             "mapping; drop inner_objective/fixed_point")

    if isinstance(inner_solver, IterativeSolver):
        if inner_objective is not None or fixed_point is not None:
            raise ValueError(
                "an IterativeSolver declares its own optimality mapping; "
                "drop inner_objective/fixed_point")
        if diff_spec is not None:
            overrides = dict(solve=diff_spec.solve, linsolve_tol=diff_spec.tol,
                             linsolve_maxiter=diff_spec.maxiter,
                             ridge=diff_spec.ridge, precond=diff_spec.precond,
                             backward=diff_spec.backward,
                             backward_iters=diff_spec.backward_iters,
                             error_estimate=diff_spec.error_estimate)
        else:
            overrides = {k: v for k, v in [("solve", solve),
                                           ("linsolve_tol", tol),
                                           ("linsolve_maxiter", maxiter),
                                           ("ridge", ridge),
                                           ("precond", precond),
                                           ("backward", backward),
                                           ("backward_iters", backward_iters)]
                         if v is not None}
        if mode is not None:
            overrides["mode"] = mode
        solver = dataclasses.replace(inner_solver, implicit_diff=True,
                                     **overrides)
        if diff_spec is not None and not diff_spec.is_routing_only:
            # the spec's mapping supersedes the solver's declared one: wrap
            # the raw masked iteration with it (paper's decoupling promise)
            deco = diff_api.implicit_diff(diff_spec.replace(has_aux=True),
                                          mode=solver.mode)
            return lambda init, *theta: deco(solver._iterate)(init, *theta)

        def runner(init, *theta):
            return solver.run(init, *theta)

        # exposed so drivers can replay the configured backward treatment
        # (solve_bilevel's hypergrad_error_estimate accounting)
        runner.solver = solver
        return runner

    mode = "auto" if mode is None else mode
    if diff_spec is not None:
        if diff_spec.is_routing_only:
            # graft the mapping from the loose arguments onto the spec
            if (inner_objective is None) == (fixed_point is None):
                raise ValueError(
                    "a bare-callable inner solver needs an optimality "
                    "mapping: set optimality_fun/fixed_point_fun on the "
                    "spec, or pass exactly one of inner_objective/"
                    "fixed_point alongside the routing-only spec")
            if inner_objective is not None:
                diff_spec = diff_spec.replace(
                    optimality_fun=optimality.stationary(inner_objective))
            else:
                diff_spec = diff_spec.replace(fixed_point_fun=fixed_point)
        wrapped = diff_api.implicit_diff(diff_spec, mode=mode)(inner_solver)
        return lambda init, *theta: (wrapped(init, *theta), None)
    solve = "cg" if solve is None else solve
    tol = 1e-6 if tol is None else tol
    maxiter = 1000 if maxiter is None else maxiter
    ridge = 0.0 if ridge is None else ridge
    backward = "exact" if backward is None else backward
    backward_iters = 8 if backward_iters is None else backward_iters
    if (inner_objective is None) == (fixed_point is None):
        raise ValueError("provide exactly one of inner_objective/fixed_point")
    if inner_objective is not None:
        spec = ImplicitDiffSpec(
            optimality_fun=optimality.stationary(inner_objective),
            solve=solve, tol=tol, maxiter=maxiter, ridge=ridge,
            precond=precond, backward=backward,
            backward_iters=backward_iters)
    else:
        spec = ImplicitDiffSpec(fixed_point_fun=fixed_point, solve=solve,
                                tol=tol, maxiter=maxiter, ridge=ridge,
                                precond=precond, backward=backward,
                                backward_iters=backward_iters)
    wrapped = diff_api.implicit_diff(spec, mode=mode)(inner_solver)
    return lambda init, *theta: (wrapped(init, *theta), None)


def make_implicit_inner(inner_solver: Union[Callable, IterativeSolver],
                        inner_objective: Optional[Callable] = None,
                        fixed_point: Optional[Callable] = None,
                        solve: Optional[str] = None,
                        tol: Optional[float] = None,
                        maxiter: Optional[int] = None,
                        ridge: Optional[float] = None,
                        precond=None,
                        backward: Optional[str] = None,
                        backward_iters: Optional[int] = None,
                        diff_spec: Optional[ImplicitDiffSpec] = None,
                        mode: Optional[str] = None) -> Callable:
    """Return ``fn(init, theta) -> x_star`` with implicit derivatives.

    An ``IterativeSolver`` already knows its optimality mapping AND its
    backward-solve routing; only the routing arguments you pass explicitly
    override it.  For a bare callable ``inner_solver(init, theta) -> x*``,
    provide exactly one of ``inner_objective`` (stationarity condition
    used) or an explicit ``fixed_point`` mapping T(x, theta); unspecified
    routing arguments default to cg / 1e-6 / 1000 / 0.0.

    ``backward``/``backward_iters`` swap the converged backward solve for
    an approximate mode (``"one_step"``/``"neumann_k"``/``"jacobian_free"``
    — O(1)–O(k) matvecs per hypergradient; see ``docs/implicit_diff.md``).

    ``diff_spec`` bundles the same configuration as one
    ``ImplicitDiffSpec`` (mapping + routing; a routing-only spec keeps an
    ``IterativeSolver``'s own mapping but replaces its routing WHOLESALE —
    start from ``inner_solver.diff_spec().replace(...)`` to tweak single
    fields); ``mode`` picks the differentiation wrapping — the default
    supports both ``jax.grad`` and ``jax.jvp`` through the returned
    function.
    """
    runner = _make_inner_runner(inner_solver, inner_objective, fixed_point,
                                solve, tol, maxiter, ridge, precond,
                                backward=backward,
                                backward_iters=backward_iters,
                                diff_spec=diff_spec, mode=mode)
    return lambda init, *theta: runner(init, *theta)[0]


def solve_bilevel(outer_loss: Callable,
                  inner_solver: Union[Callable, IterativeSolver], theta0,
                  x_init, *, inner_objective: Optional[Callable] = None,
                  fixed_point: Optional[Callable] = None,
                  outer_steps: int = 100, outer_lr: float = 1e-2,
                  momentum: float = 0.9, solve: Optional[str] = None,
                  inner_tol: Optional[float] = None,
                  linsolve_maxiter: Optional[int] = None,
                  ridge: Optional[float] = None, precond=None,
                  backward: Optional[str] = None,
                  backward_iters: Optional[int] = None,
                  diff_spec: Optional[ImplicitDiffSpec] = None,
                  mode: Optional[str] = None,
                  warm_start: bool = True,
                  jit: bool = True) -> BilevelSolution:
    """Gradient descent (w/ momentum) on the outer problem.

    ``outer_loss(x_star, theta) -> scalar``;
    ``inner_solver`` is an ``IterativeSolver`` (preferred: its ``run()``
    carries implicit derivatives and ``OptInfo`` automatically) or a bare
    callable ``inner_solver(x_init, theta) -> x_star`` plus
    ``inner_objective`` / ``fixed_point``.
    ``solve`` / ``inner_tol`` / ``linsolve_maxiter`` / ``ridge`` /
    ``precond`` route the backward linear solve; left ``None``, an
    ``IterativeSolver`` keeps its own configuration while the callable
    path uses cg / 1e-6 / 1000 / 0.0.  ``diff_spec`` passes the same
    configuration as one ``ImplicitDiffSpec`` instead of loose kwargs —
    a WHOLESALE per-call routing override (build it from
    ``inner_solver.diff_spec().replace(...)`` to keep the solver's other
    settings); a spec carrying a mapping supersedes the solver's declared
    one; ``theta`` may be any pytree either way.
    ``warm_start`` reuses the previous inner solution as init (the standard
    trick that makes the inner solves cheap along the outer trajectory).

    ``backward``/``backward_iters`` select an approximate hypergradient
    (see ``make_implicit_inner``).  With an ``IterativeSolver`` inner
    solver running an approximate mode (and ``error_estimate=True``, the
    default), each step's ``inner_info.hypergrad_error_estimate`` reports
    the relative residual of the cotangent system at the outer loss's
    cotangent — the error-vs-cost accounting of the cheap modes.  A
    stochastic inner solver (``repro.stochastic.StochasticSolver``) gets
    the same accounting even under ``backward="exact"``: its backward
    system is built from *sampled* minibatches, so the estimate re-measures
    the residual against the full-batch operator, capturing the operator
    sampling error on top of any truncation error.
    """
    implicit_solver = _make_inner_runner(
        inner_solver, inner_objective, fixed_point, solve, inner_tol,
        linsolve_maxiter, ridge, precond, backward=backward,
        backward_iters=backward_iters, diff_spec=diff_spec, mode=mode)

    def outer_value_and_grad(theta, x_init):
        def obj(theta):
            x_star, info = implicit_solver(x_init, theta)
            return outer_loss(x_star, theta), (x_star, info)
        (val, (x_star, info)), g = jax.value_and_grad(
            obj, has_aux=True)(theta)
        return val, g, x_star, info

    if jit:
        outer_value_and_grad = jax.jit(outer_value_and_grad)

    est_solver = getattr(implicit_solver, "solver", None)
    estimate_fn = None
    # Approximate backward modes AND stochastic inner solvers both deliver a
    # hypergradient whose backward system differs from the exact full-batch
    # one — a StochasticSolver solves against a sampled Jacobian operator
    # even under backward="exact".  Either way the estimate re-measures the
    # cotangent residual against the FULL-batch operator.
    if est_solver is not None and est_solver.error_estimate and (
            est_solver.backward != "exact"
            or getattr(est_solver, "is_stochastic", False)):
        def estimate_fn(x_star, theta):
            ct = jax.grad(outer_loss, argnums=0)(x_star, theta)
            return est_solver.estimate_hypergrad_error(x_star, theta,
                                                       cotangent=ct)
        if jit:
            estimate_fn = jax.jit(estimate_fn)

    theta = theta0
    vel = jax.tree_util.tree_map(jnp.zeros_like, theta)
    xs = x_init
    vals, gnorms = [], []
    x_star, info = x_init, None   # survive outer_steps=0
    for _ in range(outer_steps):
        val, g, x_star, info = outer_value_and_grad(theta, xs)
        if estimate_fn is not None and info is not None:
            info = info._replace(
                hypergrad_error_estimate=estimate_fn(x_star, theta))
        vel = jax.tree_util.tree_map(
            lambda v, gi: momentum * v + gi, vel, g)
        theta = jax.tree_util.tree_map(
            lambda t, v: t - outer_lr * v, theta, vel)
        if warm_start:
            xs = x_star
        vals.append(float(val))
        gnorms.append(float(jnp.sqrt(sum(
            jnp.vdot(x, x).real for x in jax.tree_util.tree_leaves(g)))))
        # host-side telemetry: always count outer steps in the global
        # registry (cheap, host-only); the per-step event is observe-gated
        obs_metrics.global_registry().counter(
            "repro_bilevel_steps_total",
            help="outer optimization steps taken by solve_bilevel").inc()
        obs_events.emit("bilevel_step",
                        {"solver": type(inner_solver).__name__},
                        outer_value=vals[-1], hypergrad_norm=gnorms[-1],
                        inner_iterations=(None if info is None
                                          else info.iterations))
    return BilevelSolution(theta=theta, x_star=x_star,
                           outer_values=jnp.asarray(vals),
                           hypergrad_norms=jnp.asarray(gnorms),
                           inner_info=info)


# ---------------------------------------------------------------------------
# Unrolled baseline (the paper's comparison axis)
# ---------------------------------------------------------------------------

def make_unrolled_inner(step_fn: Callable, num_steps: int) -> Callable:
    """Differentiate-through-the-solver baseline: backprop through
    ``num_steps`` applications of ``step_fn(x, theta) -> x``.  Memory grows
    O(num_steps); used by benchmarks to reproduce Fig. 3/4 comparisons."""

    def solver(x_init, theta):
        def body(x, _):
            return step_fn(x, theta), None
        x, _ = jax.lax.scan(body, x_init, None, length=num_steps)
        return x

    return solver
