"""Automatic implicit differentiation (the paper's core contribution).

Given a user-supplied optimality-condition mapping ``F(x, *theta)`` whose root
is the solver output ``x*(theta)``, the implicit function theorem gives

    -∂₁F(x*, θ) · ∂x*(θ) = ∂₂F(x*, θ)        i.e.   A J = B.

We never materialize A, B or J: JVPs/VJPs of F (obtained by autodiff) feed a
matrix-free linear solver.

Public API (mirrors the paper):

  * ``root_vjp`` / ``root_jvp``      — low-level products with ∂x*(θ)
  * ``@custom_root(F)``              — decorator attaching implicit derivatives
                                       to an arbitrary solver function
  * ``@custom_fixed_point(T)``       — same, for fixed points x* = T(x*, θ)

Most users never call the decorators directly anymore: the state-based
runtime (``repro.core.solver_runtime``) self-wraps each solver's ``run()``
with ``custom_root`` on the solver's declared optimality mapping, so
implicit derivatives and the registry-routed backward solve (``solve=``,
``precond=``, ``ridge=``) come for free.  The decorators remain the
low-level composition point for hand-written solvers.

Conventions: the decorated solver has signature ``solver(init, *theta)`` and
returns ``x*``.  ``F`` has signature ``F(x, *theta)`` returning a pytree of the
same structure as ``x``.  ``theta`` may be any number of pytree arguments;
derivatives flow to all of them.
"""
from __future__ import annotations

import functools
import inspect
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import linear_solve as ls


# ---------------------------------------------------------------------------
# Low-level products with the implicit Jacobian
# ---------------------------------------------------------------------------

def _call_solver(solve, matvec, b, *, tol, maxiter, ridge, precond):
    """Dispatch to a registry solver (with precond) or a bare callable.

    Mirrors ``linear_solve.solve``'s contract: precond requires a registry
    solver that supports it — never silently dropped.
    """
    if callable(solve):
        if precond is not None:
            raise ValueError("precond requires a registry solver name; "
                             "bake it into the custom solve callable instead")
        return solve(matvec, b, tol=tol, maxiter=maxiter, ridge=ridge)
    spec = ls.get_spec(solve)
    if precond is not None and not spec.supports_precond:
        raise ValueError(f"solver {spec.name!r} does not support "
                         "preconditioning; see SolverSpec.supports_precond")
    kwargs = dict(tol=tol, maxiter=maxiter, ridge=ridge)
    if precond is not None:
        kwargs["precond"] = precond
    return spec.fn(matvec, b, **kwargs)


def root_vjp(F: Callable, x_star, theta_args: tuple, cotangent,
             solve="normal_cg", tol: float = 1e-6, maxiter: int = 1000,
             ridge: float = 0.0, precond=None):
    """VJP through the implicitly-defined root: returns vᵀ ∂x*(θ) per θ arg.

    Solve Aᵀ u = v  (A = -∂₁F),  then  vᵀJ = uᵀB  (B = ∂₂F).
    One linear solve serves all theta arguments (paper §2.1).

    ``solve`` is a registry name (``repro.core.linear_solve.available_solvers``)
    or a solver callable; ``precond`` is forwarded to registry solvers
    (``None``, a callable v ↦ M⁻¹v, or ``"jacobi"``).  Because every registry
    solver is vmap-safe with per-instance convergence masks, a ``jax.vmap``
    of this function (or of a ``@custom_root`` gradient) runs ONE batched
    masked solve for the whole batch, not N sequential solves.
    """
    def f_of_x(x):
        return F(x, *theta_args)

    # vjp wrt x gives u ↦ uᵀ ∂₁F;  A = -∂₁F so Aᵀ u = -(∂₁F)ᵀ u.
    _, vjp_x = jax.vjp(f_of_x, x_star)

    def At_matvec(u):
        (out,) = vjp_x(u)
        return jax.tree_util.tree_map(jnp.negative, out)

    u = _call_solver(solve, At_matvec, cotangent, tol=tol, maxiter=maxiter,
                     ridge=ridge, precond=precond)

    # uᵀ B = uᵀ ∂₂F : one more VJP, wrt the theta args.
    def f_of_theta(*targs):
        return F(x_star, *targs)

    _, vjp_theta = jax.vjp(f_of_theta, *theta_args)
    return vjp_theta(u)


def root_jvp(F: Callable, x_star, theta_args: tuple, tangents: tuple,
             solve="normal_cg", tol: float = 1e-6, maxiter: int = 1000,
             ridge: float = 0.0, precond=None):
    """JVP through the implicitly-defined root: J · v.

    Solve A (Jv) = B v  with  Bv = ∂₂F · v  computed by one JVP of F in θ.
    Vmap-safe (see ``root_vjp``): batching dispatches to one masked solve.
    """
    def f_of_theta(*targs):
        return F(x_star, *targs)

    _, Bv = jax.jvp(f_of_theta, theta_args, tangents)

    def f_of_x(x):
        return F(x, *theta_args)

    def A_matvec(v):
        _, jv = jax.jvp(f_of_x, (x_star,), (v,))
        return jax.tree_util.tree_map(jnp.negative, jv)

    return _call_solver(solve, A_matvec, Bv, tol=tol, maxiter=maxiter,
                        ridge=ridge, precond=precond)


# ---------------------------------------------------------------------------
# Decorators
# ---------------------------------------------------------------------------

def custom_root(F: Callable, solve="normal_cg", tol: float = 1e-6,
                maxiter: int = 1000, ridge: float = 0.0,
                has_aux: bool = False, precond=None):
    """Decorator: attach implicit differentiation to ``solver(init, *theta)``.

    The returned function is differentiable (reverse mode) in every ``theta``
    argument; the ``init`` argument is treated as non-differentiable.

    ``has_aux=True`` means the solver returns ``(x_star, aux)``; only
    ``x_star`` participates in the implicit system, ``aux`` gets zero grads.

    Batched implicit differentiation: ``jax.vmap`` over the decorated solver
    (or over its gradient) batches the backward linear system through the
    masked solver engine — the whole batch solves in ONE ``lax.while_loop``
    where converged instances freeze while stragglers iterate, instead of N
    sequential solves.  ``precond`` (e.g. ``"jacobi"``) is forwarded to the
    registry solver named by ``solve``.

    Example (paper Fig. 1)::

        F = jax.grad(f)  # stationarity condition

        @custom_root(F)
        def ridge_solver(init_x, theta): ...
    """
    def wrapper(solver: Callable) -> Callable:

        @functools.wraps(solver)
        def solver_fwd_like(init, *theta):
            return solver(init, *theta)

        # ``init`` is a regular (possibly array) argument: it gets a zero
        # cotangent, since x*(θ) does not depend on the initialization.
        fun = jax.custom_vjp(solver_fwd_like)

        def fwd(init, *theta):
            out = solver(init, *theta)
            x_star = out[0] if has_aux else out
            return out, (init, x_star, theta)

        def bwd(res, cotangent):
            init, x_star, theta = res
            ct = cotangent[0] if has_aux else cotangent
            grads = root_vjp(F, x_star, theta, ct, solve=solve, tol=tol,
                             maxiter=maxiter, ridge=ridge, precond=precond)
            zero_init = jax.tree_util.tree_map(jnp.zeros_like, init)
            return (zero_init,) + tuple(grads)

        fun.defvjp(fwd, bwd)
        return fun

    return wrapper


def custom_fixed_point(T: Callable, solve="normal_cg", tol: float = 1e-6,
                       maxiter: int = 1000, ridge: float = 0.0,
                       has_aux: bool = False, precond=None):
    """Decorator for solvers of fixed points x* = T(x*, θ).

    Reduces to ``custom_root`` with the residual F(x, θ) = T(x, θ) − x (eq. 3).
    """
    def F(x, *theta):
        tx = T(x, *theta)
        return jax.tree_util.tree_map(lambda a, b: a - b, tx, x)

    return custom_root(F, solve=solve, tol=tol, maxiter=maxiter,
                       ridge=ridge, has_aux=has_aux, precond=precond)


# ---------------------------------------------------------------------------
# Forward-mode wrapper: a solver with custom JVP (for jax.jacfwd / jvp use).
# jax.custom_vjp functions do not support forward mode, so we expose a
# separate wrapper for JVP-dominant workloads (e.g. few parameters, many
# outputs — the molecular dynamics sensitivity experiment).
# ---------------------------------------------------------------------------

def custom_root_jvp(F: Callable, solve="normal_cg", tol: float = 1e-6,
                    maxiter: int = 1000, ridge: float = 0.0, precond=None):
    """Like ``custom_root`` but registers a JVP rule (forward mode only)."""
    def wrapper(solver: Callable) -> Callable:

        @jax.custom_jvp
        def fun(init, *theta):
            return solver(init, *theta)

        @fun.defjvp
        def jvp(primals, tangents):
            init, *theta = primals
            _, *theta_dot = tangents
            x_star = solver(init, *theta)
            dx = root_jvp(F, x_star, tuple(theta), tuple(theta_dot),
                          solve=solve, tol=tol, maxiter=maxiter, ridge=ridge,
                          precond=precond)
            return x_star, dx

        return fun

    return wrapper


def custom_fixed_point_jvp(T: Callable, **kw):
    def F(x, *theta):
        tx = T(x, *theta)
        return jax.tree_util.tree_map(lambda a, b: a - b, tx, x)
    return custom_root_jvp(F, **kw)
