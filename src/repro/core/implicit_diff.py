"""Decorator-form implicit differentiation — thin shims over ``diff_api``.

The implementation now lives in ``repro.core.diff_api``: one
``ImplicitDiffSpec`` plus the mode-polymorphic ``implicit_diff(spec)``
wrapper serve forward AND reverse mode from a single ``jax.custom_jvp``
rule whose tangent solve is reverse-transposable.  This module keeps the
paper-mirroring decorator names working on top of it:

  * ``@custom_root(F)``        — shim over ``implicit_diff(optimality_fun=F)``
  * ``@custom_fixed_point(T)`` — shim over ``implicit_diff(fixed_point_fun=T)``
  * ``root_vjp`` / ``root_jvp``— re-exported low-level products

Unlike their pre-redesign versions, the decorators now return functions
that support ``jax.grad`` / ``jax.jacrev`` *and* ``jax.jvp`` /
``jax.jacfwd`` without re-wrapping (they wrap in ``mode="auto"``).

``custom_root_jvp`` / ``custom_fixed_point_jvp`` are DEPRECATED: the split
forward-only wrappers exist only because ``jax.custom_vjp`` functions
cannot be forward-differentiated; ``implicit_diff`` (or plain
``custom_root``) now subsumes them.  They emit a one-shot
``DeprecationWarning`` and gained the ``has_aux`` support they historically
lacked.

Conventions: the decorated solver has signature ``solver(init, *theta)``
and returns ``x*``.  ``F`` has signature ``F(x, *theta)`` returning a
pytree of the same structure as ``x``.  ``theta`` may be any number of
pytree arguments; derivatives flow to all of them.
"""
from __future__ import annotations

from typing import Callable

# Re-exported so ``from repro.core.implicit_diff import root_vjp`` keeps
# working; the implementation (registry routing included) lives in diff_api.
from repro.core.diff_api import (ImplicitDiffSpec, implicit_diff,  # noqa: F401
                                 root_jvp, root_vjp, warn_once)


def _spec(F=None, T=None, solve="normal_cg", tol=1e-6, maxiter=1000,
          ridge=0.0, has_aux=False, precond=None) -> ImplicitDiffSpec:
    return ImplicitDiffSpec(optimality_fun=F, fixed_point_fun=T, solve=solve,
                            tol=tol, maxiter=maxiter, ridge=ridge,
                            precond=precond, has_aux=has_aux)


def custom_root(F: Callable, solve="normal_cg", tol: float = 1e-6,
                maxiter: int = 1000, ridge: float = 0.0,
                has_aux: bool = False, precond=None):
    """Decorator: attach implicit differentiation to ``solver(init, *theta)``.

    Shim over ``implicit_diff``: the returned function is differentiable in
    every ``theta`` argument in BOTH autodiff modes (``jax.grad``/``jacrev``
    and ``jax.jvp``/``jacfwd``); the ``init`` argument gets zero
    derivatives.

    ``has_aux=True`` means the solver returns ``(x_star, aux)``; only
    ``x_star`` participates in the implicit system, ``aux`` gets zero grads.

    Batched implicit differentiation: ``jax.vmap`` over the decorated solver
    (or over its gradient) batches the backward linear system through the
    masked solver engine — the whole batch solves in ONE ``lax.while_loop``
    where converged instances freeze while stragglers iterate, instead of N
    sequential solves.  ``precond`` (e.g. ``"jacobi"``) is forwarded to the
    registry solver named by ``solve``.

    Example (paper Fig. 1)::

        F = jax.grad(f)  # stationarity condition

        @custom_root(F)
        def ridge_solver(init_x, theta): ...
    """
    return implicit_diff(_spec(F=F, solve=solve, tol=tol, maxiter=maxiter,
                               ridge=ridge, has_aux=has_aux,
                               precond=precond))


def custom_fixed_point(T: Callable, solve="normal_cg", tol: float = 1e-6,
                       maxiter: int = 1000, ridge: float = 0.0,
                       has_aux: bool = False, precond=None):
    """Decorator for solvers of fixed points x* = T(x*, θ).

    Shim over ``implicit_diff`` with the residual F(x, θ) = T(x, θ) − x
    (eq. 3); both autodiff modes supported, like ``custom_root``.
    """
    return implicit_diff(_spec(T=T, solve=solve, tol=tol, maxiter=maxiter,
                               ridge=ridge, has_aux=has_aux,
                               precond=precond))


# ---------------------------------------------------------------------------
# DEPRECATED forward-only wrappers (subsumed by implicit_diff / custom_root)
# ---------------------------------------------------------------------------

def custom_root_jvp(F: Callable, solve="normal_cg", tol: float = 1e-6,
                    maxiter: int = 1000, ridge: float = 0.0, precond=None,
                    has_aux: bool = False):
    """DEPRECATED: ``custom_root`` (and ``implicit_diff``) now support
    forward mode directly; this separate wrapper is redundant.

    Kept as a forward-only shim (``mode="jvp"``) preserving its historical
    contract — a pure ``jax.custom_jvp`` function with no reverse rule —
    plus the ``has_aux`` support it previously lacked.
    """
    warn_once("custom_root_jvp",
              "repro.core.implicit_diff.custom_root_jvp is deprecated; "
              "custom_root / implicit_diff now support forward mode "
              "(jax.jvp / jax.jacfwd) directly")
    return implicit_diff(_spec(F=F, solve=solve, tol=tol, maxiter=maxiter,
                               ridge=ridge, has_aux=has_aux,
                               precond=precond), mode="jvp")


def custom_fixed_point_jvp(T: Callable, solve="normal_cg", tol: float = 1e-6,
                           maxiter: int = 1000, ridge: float = 0.0,
                           precond=None, has_aux: bool = False):
    """DEPRECATED: see ``custom_root_jvp``; use ``custom_fixed_point``."""
    warn_once("custom_fixed_point_jvp",
              "repro.core.implicit_diff.custom_fixed_point_jvp is "
              "deprecated; custom_fixed_point / implicit_diff now support "
              "forward mode (jax.jvp / jax.jacfwd) directly")
    return implicit_diff(_spec(T=T, solve=solve, tol=tol, maxiter=maxiter,
                               ridge=ridge, has_aux=has_aux,
                               precond=precond), mode="jvp")
