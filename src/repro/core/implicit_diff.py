"""Decorator-form implicit differentiation — thin shims over ``diff_api``.

The implementation now lives in ``repro.core.diff_api``: one
``ImplicitDiffSpec`` plus the mode-polymorphic ``implicit_diff(spec)``
wrapper serve forward AND reverse mode from a single ``jax.custom_jvp``
rule whose tangent solve is reverse-transposable.  This module keeps the
paper-mirroring decorator names working on top of it:

  * ``@custom_root(F)``        — shim over ``implicit_diff(optimality_fun=F)``
  * ``@custom_fixed_point(T)`` — shim over ``implicit_diff(fixed_point_fun=T)``
  * ``root_vjp`` / ``root_jvp``— re-exported low-level products

Unlike their pre-redesign versions, the decorators now return functions
that support ``jax.grad`` / ``jax.jacrev`` *and* ``jax.jvp`` /
``jax.jacfwd`` without re-wrapping (they wrap in ``mode="auto"``).

``custom_root_jvp`` / ``custom_fixed_point_jvp`` are DEPRECATED: the split
forward-only wrappers exist only because ``jax.custom_vjp`` functions
cannot be forward-differentiated; ``implicit_diff`` (or plain
``custom_root``) now subsumes them.  They emit a one-shot
``DeprecationWarning`` and gained the ``has_aux`` support they historically
lacked.  They deliberately REJECT the approximate ``backward=`` modes —
requesting one on a deprecated path that predates the feature raises
instead of silently differentiating exactly.

Conventions: the decorated solver has signature ``solver(init, *theta)``
and returns ``x*``.  ``F`` has signature ``F(x, *theta)`` returning a
pytree of the same structure as ``x``.  ``theta`` may be any number of
pytree arguments; derivatives flow to all of them.
"""
from __future__ import annotations

from typing import Callable

# Re-exported so ``from repro.core.implicit_diff import root_vjp`` keeps
# working; the implementation (registry routing included) lives in diff_api.
from repro.core.diff_api import (ImplicitDiffSpec, implicit_diff,  # noqa: F401
                                 root_jvp, root_vjp, warn_once)


def _spec(F=None, T=None, solve="normal_cg", tol=1e-6, maxiter=1000,
          ridge=0.0, has_aux=False, precond=None, backward="exact",
          backward_iters=8) -> ImplicitDiffSpec:
    return ImplicitDiffSpec(optimality_fun=F, fixed_point_fun=T, solve=solve,
                            tol=tol, maxiter=maxiter, ridge=ridge,
                            precond=precond, has_aux=has_aux,
                            backward=backward, backward_iters=backward_iters)


def custom_root(F: Callable, solve="normal_cg", tol: float = 1e-6,
                maxiter: int = 1000, ridge: float = 0.0,
                has_aux: bool = False, precond=None,
                backward: str = "exact", backward_iters: int = 8):
    """Decorator: attach implicit differentiation to ``solver(init, *theta)``.

    Shim over ``implicit_diff``: the returned function is differentiable in
    every ``theta`` argument in BOTH autodiff modes (``jax.grad``/``jacrev``
    and ``jax.jvp``/``jacfwd``); the ``init`` argument gets zero
    derivatives.

    ``has_aux=True`` means the solver returns ``(x_star, aux)``; only
    ``x_star`` participates in the implicit system, ``aux`` gets zero grads.

    Batched implicit differentiation: ``jax.vmap`` over the decorated solver
    (or over its gradient) batches the backward linear system through the
    masked solver engine — the whole batch solves in ONE ``lax.while_loop``
    where converged instances freeze while stragglers iterate, instead of N
    sequential solves.  ``precond`` (e.g. ``"jacobi"``) is forwarded to the
    registry solver named by ``solve``.

    ``backward`` selects an approximate treatment of the backward linear
    system (``"one_step"``/``"neumann_k"``/``"jacobian_free"``, with
    ``backward_iters`` the Neumann truncation depth) — O(1)–O(k) matvecs
    instead of a converged solve, in both autodiff modes.

    Example (paper Fig. 1)::

        F = jax.grad(f)  # stationarity condition

        @custom_root(F)
        def ridge_solver(init_x, theta): ...
    """
    return implicit_diff(_spec(F=F, solve=solve, tol=tol, maxiter=maxiter,
                               ridge=ridge, has_aux=has_aux, precond=precond,
                               backward=backward,
                               backward_iters=backward_iters))


def custom_fixed_point(T: Callable, solve="normal_cg", tol: float = 1e-6,
                       maxiter: int = 1000, ridge: float = 0.0,
                       has_aux: bool = False, precond=None,
                       backward: str = "exact", backward_iters: int = 8):
    """Decorator for solvers of fixed points x* = T(x*, θ).

    Shim over ``implicit_diff`` with the residual F(x, θ) = T(x, θ) − x
    (eq. 3); both autodiff modes supported, like ``custom_root`` —
    including the approximate ``backward`` modes (for a contractive ``T``,
    ``backward="neumann_k"`` is the phantom-gradient / truncated-unrolling
    approximation at O(k) matvecs).
    """
    return implicit_diff(_spec(T=T, solve=solve, tol=tol, maxiter=maxiter,
                               ridge=ridge, has_aux=has_aux, precond=precond,
                               backward=backward,
                               backward_iters=backward_iters))


# ---------------------------------------------------------------------------
# DEPRECATED forward-only wrappers (subsumed by implicit_diff / custom_root)
# ---------------------------------------------------------------------------

def _reject_backward(name: str, backward, backward_iters):
    """The deprecated shims must not accept approximate-backward requests."""
    if backward is not None or backward_iters is not None:
        raise TypeError(
            f"{name} is a deprecated forward-only shim and does not accept "
            "backward=/backward_iters=; use custom_root / custom_fixed_point "
            "/ implicit_diff for approximate backward modes")


def custom_root_jvp(F: Callable, solve="normal_cg", tol: float = 1e-6,
                    maxiter: int = 1000, ridge: float = 0.0, precond=None,
                    has_aux: bool = False, backward=None,
                    backward_iters=None):
    """DEPRECATED: ``custom_root`` (and ``implicit_diff``) now support
    forward mode directly; this separate wrapper is redundant.

    Kept as a forward-only shim (``mode="jvp"``) preserving its historical
    contract — a pure ``jax.custom_jvp`` function with no reverse rule —
    plus the ``has_aux`` support it previously lacked.  Passing
    ``backward=``/``backward_iters=`` raises ``TypeError``: use
    ``custom_root`` for the approximate modes.
    """
    _reject_backward("custom_root_jvp", backward, backward_iters)
    warn_once("custom_root_jvp",
              "repro.core.implicit_diff.custom_root_jvp is deprecated; "
              "custom_root / implicit_diff now support forward mode "
              "(jax.jvp / jax.jacfwd) directly")
    return implicit_diff(_spec(F=F, solve=solve, tol=tol, maxiter=maxiter,
                               ridge=ridge, has_aux=has_aux,
                               precond=precond), mode="jvp")


def custom_fixed_point_jvp(T: Callable, solve="normal_cg", tol: float = 1e-6,
                           maxiter: int = 1000, ridge: float = 0.0,
                           precond=None, has_aux: bool = False,
                           backward=None, backward_iters=None):
    """DEPRECATED: see ``custom_root_jvp``; use ``custom_fixed_point``.

    Passing ``backward=``/``backward_iters=`` raises ``TypeError``.
    """
    _reject_backward("custom_fixed_point_jvp", backward, backward_iters)
    warn_once("custom_fixed_point_jvp",
              "repro.core.implicit_diff.custom_fixed_point_jvp is "
              "deprecated; custom_fixed_point / implicit_diff now support "
              "forward mode (jax.jvp / jax.jacfwd) directly")
    return implicit_diff(_spec(T=T, solve=solve, tol=tol, maxiter=maxiter,
                               ridge=ridge, has_aux=has_aux,
                               precond=precond), mode="jvp")
