"""repro.core — automatic implicit differentiation (the paper's contribution).

Public API re-exports:
  pytree-native linear operators (the shared matvec abstraction under the
  solve registry, the diff API, the runtime and the kernels):
    LinearOperator protocol, JacobianOperator, SampledJacobianOperator,
    DenseOperator, RidgeShifted, BlockDiagonal, ComposedOperator, as_operator
                               — repro.core.operators
  implicit-diff API (mode-polymorphic: one wrapper serves jax.grad/jacrev
  AND jax.jvp/jacfwd):
    ImplicitDiffSpec, implicit_diff — repro.core.diff_api
    custom_root, custom_fixed_point (thin shims over implicit_diff),
    custom_root_jvp, custom_fixed_point_jvp (deprecated forward-only shims),
    root_vjp, root_jvp           — repro.core.implicit_diff
  solver runtime (state-based, auto implicit diff, run(mode=...)):
    IterativeSolver protocol, OptInfo diagnostics, and the solver classes
    GradientDescent, ProximalGradient, ProjectedGradient, MirrorDescent,
    BlockCoordinateDescent, Newton, LBFGS, FixedPointIteration,
    AndersonAcceleration    — repro.core.solver_runtime
  solve (batched engine entry), SolverSpec registry, SolveInfo,
  solve_cg / bicgstab / gmres / dense_gmres / normal_cg / lu / neumann /
  pallas_cg                    — repro.core.linear_solve
  optimality-condition catalog — repro.core.optimality
  projections / prox catalogs  — repro.core.projections, repro.core.prox
  legacy functional solvers    — repro.core.solvers (deprecated shims)
  bilevel driver               — repro.core.bilevel
  DEQ implicit layer           — repro.core.implicit_layer

Note: ``repro.core.implicit_diff`` the *submodule* is shadowed in this
namespace by ``implicit_diff`` the *function* (the API entry point);
``import repro.core.implicit_diff`` still reaches the submodule.
"""
from repro.core.operators import (LinearOperator, JacobianOperator,
                                  SampledJacobianOperator, DenseOperator,
                                  RidgeShifted, BlockDiagonal,
                                  ComposedOperator, as_operator)
from repro.core.implicit_diff import (custom_root, custom_fixed_point,
                                      custom_root_jvp, custom_fixed_point_jvp,
                                      root_vjp, root_jvp)
from repro.core.linear_solve import (solve, solve_cg, solve_bicgstab,
                                     solve_gmres, solve_dense_gmres,
                                     solve_normal_cg, solve_lu,
                                     solve_neumann, SolverSpec, SolveInfo,
                                     register_solver, get_solver, get_spec,
                                     available_solvers, jacobi_preconditioner)
from repro.core.solver_runtime import (IterativeSolver, OptInfo,
                                       GradientDescent, ProximalGradient,
                                       ProjectedGradient, MirrorDescent,
                                       BlockCoordinateDescent, Newton, LBFGS,
                                       FixedPointIteration,
                                       AndersonAcceleration)
from repro.core import optimality, projections, prox, solvers, bilevel
from repro.core.implicit_layer import (deq_fixed_point, make_deq_block,
                                       make_deq_solver)
# imported last: the ``implicit_diff`` FUNCTION shadows the submodule name
# in this namespace (see module docstring)
from repro.core.diff_api import ImplicitDiffSpec, implicit_diff
