"""Optimality-condition mappings F / fixed-point mappings T (paper Table 1).

Each factory returns a mapping with signature ``F(x, *theta)`` (root form) or
``T(x, *theta)`` (fixed-point form), ready to be plugged into
``@custom_root`` / ``@custom_fixed_point``.

Catalog (paper equation numbers):
  * ``stationary(f)``              — eq. (4): F = ∇₁f
  * ``gradient_descent_fp(f)``     — eq. (5): T = x − η∇₁f
  * ``kkt(f, G, H)``               — eq. (6): stationarity + feasibility + CS
  * ``proximal_gradient_fp(f, prox)``  — eq. (7)
  * ``projected_gradient_fp(f, proj)`` — eq. (9)
  * ``mirror_descent_fp(f, proj_kl, phi)`` — eq. (13)
  * ``newton_fp(G)``               — eq. (14)
  * ``block_proximal_gradient_fp`` — eq. (15)
  * ``conic_residual(cone_proj)``  — eq. (18): homogeneous self-dual embedding
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Smooth unconstrained
# ---------------------------------------------------------------------------

def stationary(f: Callable) -> Callable:
    """F(x, θ) = ∇₁f(x, θ) — eq. (4)."""
    return jax.grad(f, argnums=0)


def gradient_descent_fp(f: Callable, stepsize: float = 1.0) -> Callable:
    """T(x, θ) = x − η ∇₁f(x, θ) — eq. (5); η cancels in the linear system."""
    grad = jax.grad(f, argnums=0)

    def T(x, *theta):
        g = grad(x, *theta)
        return jax.tree_util.tree_map(lambda xi, gi: xi - stepsize * gi, x, g)

    return T


# ---------------------------------------------------------------------------
# KKT — eq. (6).  x = (z, nu, lambd); theta = (theta_f, theta_H, theta_G).
# ---------------------------------------------------------------------------

def kkt(f: Callable, G: Optional[Callable] = None,
        H: Optional[Callable] = None) -> Callable:
    """Build the KKT residual for min f(z,θf) s.t. G(z,θG) ≤ 0, H(z,θH) = 0.

    Mirrors paper Fig. 7: stationarity uses VJPs of H and G, feasibility and
    complementary slackness stack below.  ``x`` is a tuple whose members are
    present only for the constraints supplied.
    """
    grad = jax.grad(f, argnums=0)

    def F(x, theta):
        theta_f = theta[0]
        if H is not None and G is not None:
            z, nu, lambd = x
            theta_H, theta_G = theta[1], theta[2]
        elif H is not None:
            z, nu = x
            theta_H = theta[1]
        elif G is not None:
            z, lambd = x
            theta_G = theta[1]
        else:
            (z,) = x

        stationarity = grad(z, theta_f)
        out = []
        if H is not None:
            _, H_vjp = jax.vjp(H, z, theta_H)
            stationarity = stationarity + H_vjp(nu)[0]
        if G is not None:
            _, G_vjp = jax.vjp(G, z, theta_G)
            stationarity = stationarity + G_vjp(lambd)[0]
        out.append(stationarity)
        if H is not None:
            out.append(H(z, theta_H))
        if G is not None:
            out.append(lambd * G(z, theta_G))
        return tuple(out)

    return F


# ---------------------------------------------------------------------------
# Proximal / projected gradient fixed points — eqs. (7), (9)
# ---------------------------------------------------------------------------

def proximal_gradient_fp(f: Callable, prox: Callable,
                         stepsize: float = 1.0) -> Callable:
    """T(x, θ) = prox_ηg(x − η∇₁f(x, θf), θg);  θ = (θf, θg)."""
    grad = jax.grad(f, argnums=0)

    def T(x, theta):
        theta_f, theta_g = theta
        y = jax.tree_util.tree_map(
            lambda xi, gi: xi - stepsize * gi, x, grad(x, theta_f))
        return prox(y, theta_g, stepsize)

    return T


def projected_gradient_fp(f: Callable, proj: Callable,
                          stepsize: float = 1.0) -> Callable:
    """T(x, θ) = proj_C(x − η∇₁f(x, θf), θproj);  θ = (θf, θproj)."""
    grad = jax.grad(f, argnums=0)

    def T(x, theta):
        theta_f, theta_proj = theta
        y = jax.tree_util.tree_map(
            lambda xi, gi: xi - stepsize * gi, x, grad(x, theta_f))
        return proj(y, theta_proj)

    return T


# ---------------------------------------------------------------------------
# Mirror descent fixed point — eq. (13)
# ---------------------------------------------------------------------------

def mirror_descent_fp(f: Callable, proj_kl: Callable, phi_grad: Callable,
                      stepsize: float = 1.0) -> Callable:
    """T(x, θ) = proj^φ_C(∇φ(x) − η∇₁f(x, θf), θproj) — paper Fig. 8."""
    grad = jax.grad(f, argnums=0)

    def T(x, theta):
        theta_f, theta_proj = theta
        x_hat = phi_grad(x)
        y = jax.tree_util.tree_map(
            lambda xh, gi: xh - stepsize * gi, x_hat, grad(x, theta_f))
        return proj_kl(y, theta_proj)

    return T


def kl_phi_grad(x, eps: float = 1e-30):
    """∇φ for φ(x) = <x, log x − 1> (KL geometry): log(x)."""
    return jnp.log(jnp.maximum(x, eps))


# ---------------------------------------------------------------------------
# Newton fixed point — eq. (14)
# ---------------------------------------------------------------------------

def newton_fp(G: Callable, stepsize: float = 1.0) -> Callable:
    """T(x, θ) = x − η [∂₁G(x, θ)]⁻¹ G(x, θ) (root finding Newton)."""

    def T(x, *theta):
        g = G(x, *theta)
        J = jax.jacobian(G, argnums=0)(x, *theta)
        step = jnp.linalg.solve(J, g)
        return x - stepsize * step

    return T


# ---------------------------------------------------------------------------
# Block proximal gradient — eq. (15)
# ---------------------------------------------------------------------------

def block_proximal_gradient_fp(f: Callable, prox_blocks: Sequence[Callable],
                               stepsizes=None) -> Callable:
    """Block fixed point [T(x, θ)]ᵢ = prox_ηᵢgᵢ(xᵢ − ηᵢ[∇₁f(x, θf)]ᵢ, θgᵢ).

    ``x`` is a tuple of blocks; ``theta`` = (θf, (θg₁, ..., θg_m)).
    """
    grad = jax.grad(f, argnums=0)
    m = len(prox_blocks)
    if stepsizes is None:
        stepsizes = (1.0,) * m

    def T(x, theta):
        theta_f, theta_gs = theta
        g = grad(x, theta_f)
        return tuple(
            prox_blocks[i](x[i] - stepsizes[i] * g[i], theta_gs[i],
                           stepsizes[i])
            for i in range(m))

    return T


# ---------------------------------------------------------------------------
# Conic programming residual map — eq. (18)
# ---------------------------------------------------------------------------

def conic_residual(cone_proj: Callable) -> Callable:
    """F(x, θ) = ((θ − I) Π + I) x for the homogeneous self-dual embedding.

    ``theta`` is the skew-symmetric data matrix; ``cone_proj`` projects onto
    R^p × K* × R₊ (composition of per-block cone projections).
    """

    def F(x, theta):
        pix = cone_proj(x)
        return theta @ pix - pix + x

    return F


def make_cone_projector(p: int, cone_projs: Sequence[tuple]) -> Callable:
    """Build Π = proj_{R^p × K* × R₊} from per-block (size, projector) pairs.

    The first p coordinates are free; the last coordinate projects onto R₊.
    """

    def proj(x):
        parts = [x[:p]]
        off = p
        for size, blk in cone_projs:
            parts.append(blk(x[off:off + size]))
            off += size
        parts.append(jnp.maximum(x[off:], 0.0))
        return jnp.concatenate(parts)

    return proj
