"""Differentiable projections onto convex sets (paper Appendix C.1).

Euclidean projections ``projection_*`` and Bregman/KL projections
``projection_*_kl``.  All are written with jnp primitives so that JVPs/VJPs
come from autodiff; where the paper gives a closed-form Jacobian (simplex) we
rely on the autodiff of the closed-form solution, which matches it a.e.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# Orthants, boxes, balls
# ---------------------------------------------------------------------------

def projection_non_negative(y, theta=None):
    """C = R^d_+ : proj(y) = max(y, 0) (ReLU)."""
    del theta
    return jnp.maximum(y, 0.0)


def projection_non_negative_kl(y, theta=None):
    """KL projection onto the non-negative orthant: exp(y)."""
    del theta
    return jnp.exp(y)


def projection_box(y, theta):
    """C(θ) = [θ₁, θ₂]^d (scalars or per-coordinate arrays)."""
    lo, hi = theta
    return jnp.clip(y, lo, hi)


def projection_hypercube(y, theta=None):
    return projection_box(y, (0.0, 1.0) if theta is None else theta)


def projection_l2_ball(y, theta=1.0):
    """C(θ) = {x : ||x||₂ ≤ θ}."""
    norm = jnp.linalg.norm(y)
    scale = jnp.where(norm <= theta, 1.0, theta / jnp.maximum(norm, 1e-30))
    return scale * y


def projection_linf_ball(y, theta=1.0):
    return jnp.clip(y, -theta, theta)


def projection_l1_ball(y, theta=1.0):
    """Projection onto the ℓ1 ball via simplex projection of |y| [33]."""
    a = jnp.abs(y)
    inside = jnp.sum(a) <= theta
    p = projection_simplex(a, theta)
    return jnp.where(inside, y, jnp.sign(y) * p)


# ---------------------------------------------------------------------------
# Simplex
# ---------------------------------------------------------------------------

def projection_simplex(y, scale=1.0):
    """Euclidean projection onto the simplex {x ≥ 0, Σx = scale}.

    O(d log d) sort-based algorithm [49, 33].  Differentiable a.e.; autodiff
    of this composition yields the closed-form Jacobian diag(s) − s sᵀ/|s|₁.
    """
    d = y.shape[-1]
    # -- primal threshold via sort (under stop_gradient: sort's autodiff rule
    #    is irrelevant, and the derivative is recovered implicitly below) --
    y_sg = lax.stop_gradient(y)
    u = -jnp.sort(-y_sg, axis=-1)       # descending
    cssv = jnp.cumsum(u, axis=-1) - scale
    ind = jnp.arange(1, d + 1, dtype=y.dtype)
    cond = u - cssv / ind > 0           # True exactly on the first rho entries
    rho = jnp.sum(cond.astype(y.dtype), axis=-1)
    # cssv[rho-1] = sum of the rho largest entries − scale = Σ u·cond − scale
    tau0 = (jnp.sum(u * cond, axis=-1) - scale) / jnp.maximum(rho, 1.0)
    # -- differentiable correction: τ is the (1-D) root of
    #    φ(τ) = Σ max(yᵢ − τ, 0) − scale, with φ'(τ) = −|support|.  A single
    #    Newton step from the exact τ₀ is an identity on primals but carries
    #    the implicit-function-theorem gradient ∂τ/∂yᵢ = sᵢ/|s| (paper App. C).
    supp = (y_sg - tau0[..., None]) > 0
    nsupp = jnp.maximum(jnp.sum(supp.astype(y.dtype), axis=-1), 1.0)
    phi = jnp.sum(jnp.maximum(y - tau0[..., None], 0.0), axis=-1) - scale
    tau = tau0 + phi / nsupp
    return jnp.maximum(y - tau[..., None], 0.0)


def projection_simplex_kl(y, scale=1.0):
    """KL (Bregman) projection onto the simplex = softmax (closed form)."""
    return scale * jax.nn.softmax(y, axis=-1)


# ---------------------------------------------------------------------------
# Affine sets, hyperplanes, halfspaces
# ---------------------------------------------------------------------------

def projection_hyperplane(y, theta):
    """C(θ) = {x : aᵀx = b}, θ = (a, b)."""
    a, b = theta
    return y - (jnp.vdot(a, y) - b) / jnp.vdot(a, a) * a


def projection_halfspace(y, theta):
    """C(θ) = {x : aᵀx ≤ b}, θ = (a, b)."""
    a, b = theta
    return y - jnp.maximum(jnp.vdot(a, y) - b, 0.0) / jnp.vdot(a, a) * a


def projection_affine_set(y, theta):
    """C(θ) = {x : Ax = b}, θ = (A, b); A assumed full row rank."""
    A, b = theta
    gram = A @ A.T
    resid = A @ y - b
    return y - A.T @ jnp.linalg.solve(gram, resid)


# ---------------------------------------------------------------------------
# Box section (singly-constrained bounded QP) — solved by bisection on the
# dual variable; differentiable via the 1-D root formula ∇x*(θ) = Bᵀ/A.
# ---------------------------------------------------------------------------

def projection_box_section(y, theta, maxiter: int = 80):
    """Project onto {z : α ≤ z ≤ β, wᵀz = c}, θ = (alpha, beta, w, c).

    Dual-primal map L(x, θ)_i = clip(w_i x + y_i, α_i, β_i) with scalar dual x
    root of F(x, θ) = wᵀ L(x, θ) − c, found by bisection (Appendix C).
    """
    alpha, beta, w, c = theta

    def L(x):
        return jnp.clip(w * x + y, alpha, beta)

    def phi(x):
        return jnp.vdot(w, L(x)) - c

    # bracket the root
    wmax = jnp.max(jnp.abs(w)) + 1e-12
    span = (jnp.max(jnp.abs(y)) + jnp.max(jnp.abs(beta)) +
            jnp.max(jnp.abs(alpha)) + jnp.abs(c)) / wmax + 1.0
    lo, hi = -span, span

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        val = phi(mid)
        # phi is nondecreasing in x when w has mixed signs? Use sign test on
        # monotone transform: phi is nondecreasing in x (each clip term is
        # monotone in w_i x with slope w_i², ≥ 0).
        go_right = val < 0
        lo = jnp.where(go_right, mid, lo)
        hi = jnp.where(go_right, hi, mid)
        return lo, hi

    lo, hi = lax.fori_loop(0, maxiter, body, (lo, hi))
    x = 0.5 * (lo + hi)
    # straight-through the bisection: re-express via the differentiable L and
    # the 1-D implicit formula handled by stop_gradient + correction.
    x = _implicit_scalar_root(phi, x)
    return jnp.clip(w * x + y, alpha, beta)


def _implicit_scalar_root(phi, x_hat):
    """Return x̂ with gradients as if x were the exact root of phi (1-D IFT)."""
    x0 = lax.stop_gradient(x_hat)
    g = jax.grad(lambda x: phi(x))(x0)
    g = jnp.where(jnp.abs(g) < 1e-12, 1e-12, g)
    # x* ≈ x0 - phi(x0)/phi'(x0): Newton correction whose gradient implements
    # the implicit function theorem for the parameters captured in phi.
    return x0 - (phi(x0) - lax.stop_gradient(phi(x0))) / g


# ---------------------------------------------------------------------------
# Order simplex / isotonic regression (PAV) — Appendix C
# ---------------------------------------------------------------------------

def _isotonic_pav(y):
    """Pool-adjacent-violators for isotonic regression (non-increasing).

    O(d²) lax implementation (d is small in the paper's uses); returns the
    projection of y onto {x₁ ≥ x₂ ≥ ... ≥ x_d}.
    """
    d = y.shape[-1]

    def body(x, _):
        # one sweep of neighbor pooling: where x violates, average pools.
        viol = x[:-1] < x[1:]
        any_v = jnp.any(viol)

        def fix(x):
            # pool each adjacent violating pair (Jacobi-style sweep)
            avg = 0.5 * (x[:-1] + x[1:])
            left = jnp.where(viol, avg, x[:-1])
            right = jnp.where(viol, avg, x[1:])
            x = x.at[:-1].set(left)
            x = x.at[1:].set(jnp.where(viol, right, x[1:]))
            return x

        return jnp.where(any_v, fix(x), x), None

    x, _ = lax.scan(body, y, None, length=4 * d)
    return x


def projection_order_simplex(y, theta=(1.0, 0.0)):
    """Project onto {θ₁ ≥ x₁ ≥ ... ≥ x_d ≥ θ₂} = clip(isotonic(y))."""
    hi, lo = theta
    return jnp.clip(_isotonic_pav(y), lo, hi)


# ---------------------------------------------------------------------------
# Transportation polytope (Sinkhorn, KL geometry) — Appendix C
# ---------------------------------------------------------------------------

def projection_transport_kl(y, theta, num_iters: int = 100):
    """KL projection of exp(y) onto U(a, b) = {X1 = a, Xᵀ1 = b, X ≥ 0}.

    Sinkhorn iterations in log space; θ = (a, b) marginals.  Differentiable
    by unrolling (few iters) or wrap with custom_fixed_point for implicit.
    """
    a, b = theta
    log_a, log_b = jnp.log(a), jnp.log(b)
    f = jnp.zeros_like(a)
    g = jnp.zeros_like(b)

    def body(carry, _):
        f, g = carry
        f = log_a - jax.nn.logsumexp(y + g[None, :], axis=1)
        g = log_b - jax.nn.logsumexp(y + f[:, None], axis=0)
        return (f, g), None

    (f, g), _ = lax.scan(body, (f, g), None, length=num_iters)
    return jnp.exp(y + f[:, None] + g[None, :])


def projection_birkhoff_kl(y, num_iters: int = 100):
    d = y.shape[-1]
    u = jnp.full((d,), 1.0 / d)
    return projection_transport_kl(y, (u, u), num_iters)


# ---------------------------------------------------------------------------
# Polyhedra via KKT (generic) are handled by repro.core.optimality.kkt;
# cones for the conic residual map (18):
# ---------------------------------------------------------------------------

def projection_zero_cone(y):
    return jnp.zeros_like(y)


def projection_free_cone(y):
    return y


def projection_second_order_cone(y):
    """Project (t, x) onto {(t, x): ||x|| ≤ t}."""
    t, x = y[0], y[1:]
    nx = jnp.linalg.norm(x)
    in_cone = nx <= t
    in_polar = nx <= -t
    alpha = (t + nx) / 2.0
    scale = alpha / jnp.maximum(nx, 1e-30)
    proj = jnp.concatenate([jnp.array([alpha]), scale * x])
    out = jnp.where(in_cone, y, jnp.where(in_polar, jnp.zeros_like(y), proj))
    return out
