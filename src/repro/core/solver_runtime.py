"""Unified state-based solver runtime with automatic implicit differentiation.

The paper's core claim is modularity: *any* solver plus *any* optimality
mapping F yields automatic implicit derivatives.  This module makes the solver
layer itself the modular unit:

  * ``IterativeSolver`` protocol — ``init_state(params, *theta) -> state``,
    ``update(params, state, *theta) -> (params, state)``, plus a declared
    optimality mapping (``optimality_fun`` for root form, ``fixed_point_fun``
    for fixed-point form, both drawn from ``repro.core.optimality``).
  * a shared jit/vmap-safe ``run()`` driver: ONE ``lax.while_loop`` with
    per-instance convergence masks (like the PR-1 linear-solve engine), so
    ``jax.vmap`` of a whole inner *solve* runs as one batched masked loop —
    converged instances freeze while stragglers iterate.
  * ``OptInfo`` diagnostics mirroring ``SolveInfo``: per-instance iteration
    counts, final error, and an honest NaN-aware ``converged`` flag
    (``error <= tol`` is False for NaN — a diverged solve never reports
    success).
  * automatic implicit differentiation: ``run()`` self-wraps with
    ``custom_root`` on the solver's optimality mapping, routing the backward
    solve through the linear-solve ``SolverSpec`` registry (``solve=``,
    ``precond=``, ``ridge=`` flow end-to-end).  A ``jax.vmap`` of the
    gradient therefore dispatches ONE batched masked backward solve.

Solvers: ``GradientDescent``, ``ProximalGradient`` (FISTA momentum opt-out),
``ProjectedGradient``, ``MirrorDescent``, ``BlockCoordinateDescent``,
``Newton``, ``LBFGS``, ``FixedPointIteration``, ``AndersonAcceleration``.

The old functional factories in ``repro.core.solvers`` remain as thin
deprecation shims over these classes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Union

import jax
import jax.flatten_util
import jax.numpy as jnp
from jax import lax

from repro.core import diff_api, optimality
from repro.observability import events as obs_events
# tree math shared with the linear-solve engine (instance-shaped: the
# runtime never carries an explicit batch axis — vmap supplies it)
from repro.core.linear_solve import _tree_l2, _tree_sub
from repro.core.operators import _ravel1


# ---------------------------------------------------------------------------
# pytree helpers
# ---------------------------------------------------------------------------

def _tree_axpy(x, g, alpha):
    """x + alpha * g, leaf-wise (alpha a per-instance scalar)."""
    return jax.tree_util.tree_map(lambda xi, gi: xi + alpha * gi, x, g)


def _tree_where(done, old, new):
    """Freeze converged instances: where(done, old, new) leaf-wise.

    ``done`` is a per-instance boolean scalar (batched under ``jax.vmap``),
    which broadcasts against every leaf.
    """
    return jax.tree_util.tree_map(
        lambda o, n: jnp.where(done, o, n), old, new)


def _inf_like(params):
    """An +inf error scalar with the dtype ``_tree_l2(params)`` will have,
    so the while_loop carry dtype is stable from the first iteration."""
    return jnp.full((), jnp.inf, dtype=_tree_l2(params).dtype)


# ---------------------------------------------------------------------------
# raveled-iterate cache (LBFGS / Anderson hot-loop hoist)
#
# The iterate is raveled ONCE in init_state; update() carries the flat
# vector in the state and only needs the unravel closure, cached on the
# solver instance.  The cache is keyed by pytree structure + leaf shapes so
# one solver instance reused across problems with different structures
# safely rebuilds the closure instead of unraveling with the wrong one.
# ---------------------------------------------------------------------------

def _structure_key(params):
    return (jax.tree_util.tree_structure(params),
            tuple((jnp.shape(l), str(jnp.result_type(l)))
                  for l in jax.tree_util.tree_leaves(params)))


def _ravel_iterate(solver, params):
    """Ravel the iterate (init_state only) and cache the unravel closure."""
    x0, unravel = jax.flatten_util.ravel_pytree(params)
    solver._unravel_key = _structure_key(params)
    solver._unravel = unravel
    return x0


def _unravel_for(solver, params):
    """The cached unravel closure for ``params``'s structure (no ravel on
    the hot path; a structure mismatch — new problem on the same instance,
    or a direct update() call — rebuilds it)."""
    if getattr(solver, "_unravel_key", None) != _structure_key(params):
        _, unravel = jax.flatten_util.ravel_pytree(params)
        solver._unravel_key = _structure_key(params)
        solver._unravel = unravel
    return solver._unravel


# ---------------------------------------------------------------------------
# diagnostics
# ---------------------------------------------------------------------------

class OptInfo(NamedTuple):
    """Per-instance solve diagnostics (batch-shaped under ``jax.vmap``).

    Mirrors ``linear_solve.SolveInfo``: ``converged`` is ``error <= tol``,
    which is False for NaN errors — a diverged/NaN run is never reported as
    converged (honest-convergence semantics).
    """
    iterations: jnp.ndarray    # update() steps actually spent per instance
    error: jnp.ndarray         # solver-specific final error per instance
    converged: jnp.ndarray     # error <= tol per instance (NaN-aware False)
    # relative residual of the implicit backward system at the returned
    # cotangent — populated by drivers that request it (e.g. solve_bilevel
    # with an approximate backward mode); None otherwise
    hypergrad_error_estimate: Any = None


# ---------------------------------------------------------------------------
# the protocol + shared run() driver
# ---------------------------------------------------------------------------

def _kw(default):
    return dataclasses.field(default=default, kw_only=True)


@dataclasses.dataclass(eq=False)
class IterativeSolver:
    """State-based iterative solver protocol with a shared masked driver.

    Subclasses implement
      * ``init_state(params, *theta) -> state`` — a NamedTuple whose first
        two fields are ``iter_num`` (int scalar) and ``error`` (float
        scalar, ``inf`` initially);
      * ``update(params, state, *theta) -> (params, state)`` — one step;
      * the optimality mapping: either override ``optimality_fun`` (root
        form, eq. 4/6) or provide ``fixed_point_fun`` (eq. 3: the residual
        ``T(x) - x`` is derived automatically) — as a method or, for
        wrapper solvers, a dataclass field holding the user's ``T``.

    ``run(init_params, *theta) -> (params, OptInfo)`` then drives the solve
    in one ``lax.while_loop`` with per-instance convergence masks and, when
    ``implicit_diff=True`` (default), attaches implicit derivatives by
    self-wrapping with the mode-polymorphic ``diff_api.implicit_diff`` on
    the declared optimality mapping (see ``diff_spec()``).  The backward/
    tangent linear solve goes through the ``SolverSpec`` registry:
    ``solve`` names the registry solver (``"auto"`` dispatches on the
    implicit system's ``LinearOperator`` structure, or pass a callable),
    and ``precond`` (incl. operator-derived ``"jacobi"``/``"block_jacobi"``)
    / ``ridge`` / ``linsolve_tol`` / ``linsolve_maxiter`` are forwarded.

    ``mode`` selects the differentiation wrapping (overridable per call via
    ``run(..., mode=...)``):

      * ``"auto"`` (default) — one wrapper serving BOTH modes: ``jax.grad``
        / ``jacrev`` AND ``jax.jvp`` / ``jacfwd`` work on the same
        ``run()``;
      * ``"jvp"`` — forward-only (few parameters, many outputs — e.g. the
        MD sensitivity workload);
      * ``"vjp"`` — reverse-only (many parameters, scalar outer losses).
    """
    maxiter: int = _kw(1000)
    tol: float = _kw(1e-8)
    implicit_diff: bool = _kw(True)
    mode: str = _kw("auto")
    solve: Union[str, Callable] = _kw("normal_cg")
    linsolve_tol: float = _kw(1e-6)
    linsolve_maxiter: int = _kw(1000)
    ridge: float = _kw(0.0)
    precond: Any = _kw(None)
    # Approximate backward treatment of the implicit linear system (both
    # derivative directions): "exact" | "one_step" | "neumann_k" |
    # "jacobian_free"; ``backward_iters`` is the neumann_k truncation depth
    # and ``error_estimate`` opts info-returning entry points into the
    # one-extra-matvec relative-residual honesty check.
    backward: str = _kw("exact")
    backward_iters: int = _kw(8)
    error_estimate: bool = _kw(True)
    # Mesh placement (a distributed.sharded_operators.SolveSharding): the
    # iterate is pinned to its specs each step and the implicit backward/
    # tangent solve runs sharded (the JacobianOperator inherits the
    # placement; classic solver names upgrade to their sharded variants).
    sharding: Any = _kw(None)

    # -- protocol ----------------------------------------------------------
    def init_state(self, params, *theta):
        """Build the initial iteration state for ``params`` and θ."""
        raise NotImplementedError

    def update(self, params, state, *theta):
        """One iteration: ``(params, state) → (params, state)``."""
        raise NotImplementedError

    def optimality_fun(self, params, *theta):
        """Root residual F(x, θ); default derives it from the fixed point."""
        T = self.fixed_point_fun   # property/method, or a field holding T
        return _tree_sub(T(params, *theta), params)

    def fixed_point_fun(self, params, *theta):
        # plain method (not a property) so wrapper solvers may shadow it
        # with a dataclass field holding the user's T
        """The solver's fixed-point mapping ``T(x, θ)``, when it declares one."""
        raise NotImplementedError(
            f"{type(self).__name__} declares neither optimality_fun nor "
            "fixed_point_fun")

    # -- shared driver -----------------------------------------------------
    def _continuing(self, state):
        """Per-instance 'still iterating' flag.  NaN error compares False
        against tol on both sides, so a NaN instance stops immediately and
        is reported unconverged."""
        return jnp.logical_and(state.iter_num < self.maxiter,
                               state.error > self.tol)

    def _iterate(self, init_params, *theta):
        """The raw masked loop: no implicit diff attached."""
        if self.sharding is not None:
            # pin the iterate to its mesh placement before the loop (the
            # loop body is shape-preserving, so XLA keeps the layout)
            init_params = self.sharding.constrain(init_params)
        state0 = self.init_state(init_params, *theta)

        def cond(carry):
            _, state = carry
            return self._continuing(state)

        def body(carry):
            params, state = carry
            done = jnp.logical_not(self._continuing(state))
            new_params, new_state = self.update(params, state, *theta)
            # freeze instances that were already done at loop entry (under
            # vmap the loop runs until the last straggler; masked instances
            # must hold their solo-run result exactly)
            return (_tree_where(done, params, new_params),
                    _tree_where(done, state, new_state))

        params, state = lax.while_loop(cond, body, (init_params, state0))
        info = OptInfo(iterations=state.iter_num, error=state.error,
                       converged=state.error <= self.tol)
        obs_events.jit_event("converged", {"solver": type(self).__name__},
                             iterations=info.iterations, error=info.error,
                             converged=info.converged)
        return params, info

    def diff_spec(self) -> diff_api.ImplicitDiffSpec:
        """The solver's ``ImplicitDiffSpec``: its declared optimality
        mapping plus its configured backward-solve routing.  ``run()``
        self-wraps with this; drivers (``bilevel``, the DEQ layer) may
        override routing fields per call via ``spec.replace(...)``."""
        return diff_api.ImplicitDiffSpec(
            optimality_fun=self.optimality_fun, solve=self.solve,
            tol=self.linsolve_tol, maxiter=self.linsolve_maxiter,
            ridge=self.ridge, precond=self.precond, has_aux=True,
            sharding=self.sharding, backward=self.backward,
            backward_iters=self.backward_iters,
            error_estimate=self.error_estimate)

    def run(self, init_params, *theta, mode: str = None):
        """Solve from ``init_params``; returns ``(params, OptInfo)``.

        Differentiable in every ``theta`` argument via implicit
        differentiation of the declared optimality mapping (``init_params``
        gets zero gradient; ``OptInfo`` is non-differentiable aux).  With
        the default ``mode="auto"`` the same ``run`` supports reverse
        (``jax.grad``/``jacrev``) AND forward (``jax.jvp``/``jacfwd``)
        differentiation; ``mode`` (keyword) overrides the instance setting
        per call.  ``jax.vmap`` over ``run`` (or either mode's derivative)
        batches the forward loop AND the backward/tangent linear solve —
        each is one masked while_loop.
        """
        if not self.implicit_diff:
            return self._iterate(init_params, *theta)
        deco = diff_api.implicit_diff(
            self.diff_spec(), mode=self.mode if mode is None else mode)
        return deco(self._iterate)(init_params, *theta)

    def l2_optimality_error(self, params, *theta):
        """‖F(x, θ)‖ — a solver-independent certificate of optimality."""
        return _tree_l2(self.optimality_fun(params, *theta))

    def estimate_hypergrad_error(self, params, *theta, cotangent=None):
        """Relative residual ``‖v − Aᵀu‖/‖v‖`` of the cotangent system at
        the (possibly approximate) backward solution ``u``.

        The honesty check of the approximate ``backward`` modes: replays the
        configured backward treatment on the cotangent ``v`` (defaults to an
        all-ones tree matching ``params``) and spends one extra matvec on
        the implicit system's residual.  Near zero the hypergradient is
        trustworthy; large values mean ``backward_iters`` is too small or
        the system is too ill-conditioned for the selected mode.
        """
        if cotangent is None:
            cotangent = jax.tree_util.tree_map(jnp.ones_like, params)
        spec = self.diff_spec()
        _, info = diff_api.root_vjp(
            spec.residual_fun, params, theta, cotangent, solve=spec.solve,
            sharding=spec.sharding, error_estimate=True, return_info=True,
            system_operator=spec.system_operator,
            **spec.routing_kwargs(), **spec.backward_kwargs())
        return info.hypergrad_error_estimate


# ---------------------------------------------------------------------------
# Gradient descent (fixed step or backtracking line search)
# ---------------------------------------------------------------------------

class GradientDescentState(NamedTuple):
    """Iteration state of ``GradientDescent``."""
    iter_num: jnp.ndarray
    error: jnp.ndarray


@dataclasses.dataclass(eq=False)
class GradientDescent(IterativeSolver):
    """min f(x, θ) by x ← x − η∇f; optimality = stationarity (eq. 4).

    ``error`` is ``‖Δx‖`` for the fixed-step variant (matching the legacy
    ``fixed_point_iteration`` semantics) and ``‖∇f‖`` with backtracking.
    The backtracking inner loop is itself masked, so a vmapped solve keeps
    per-instance step sizes.
    """
    fun: Callable = None
    stepsize: float = 1e-2
    linesearch: bool = False

    def optimality_fun(self, params, *theta):
        """The optimality mapping ``F(x, θ)`` that ``run()`` differentiates through."""
        return jax.grad(self.fun, argnums=0)(params, *theta)

    def init_state(self, params, *theta):
        """See ``IterativeSolver.init_state``."""
        return GradientDescentState(jnp.asarray(0), _inf_like(params))

    def update(self, params, state, *theta):
        """See ``IterativeSolver.update``."""
        if not self.linesearch:
            g = jax.grad(self.fun, argnums=0)(params, *theta)
            new_params = _tree_axpy(params, g, -self.stepsize)
            error = _tree_l2(_tree_sub(new_params, params))
            return new_params, GradientDescentState(state.iter_num + 1, error)

        v, g = jax.value_and_grad(self.fun, argnums=0)(params, *theta)
        gnorm2 = sum(jnp.vdot(gi, gi).real
                     for gi in jax.tree_util.tree_leaves(g))

        def needs_shrink(eta):
            x_try = _tree_axpy(params, g, -eta)
            return jnp.logical_and(
                self.fun(x_try, *theta) > v - 0.5 * eta * gnorm2,
                eta > 1e-12)

        # masked backtracking, one objective evaluation per halving: the
        # carried shrink flag is the predicate, so instances whose Armijo
        # test already passes hold their eta while stragglers keep halving
        def ls_body(carry):
            eta, shrink = carry
            eta = jnp.where(shrink, 0.5 * eta, eta)
            return eta, jnp.logical_and(shrink, needs_shrink(eta))

        eta0 = jnp.asarray(self.stepsize)
        eta, _ = lax.while_loop(lambda c: c[1], ls_body,
                                (eta0, needs_shrink(eta0)))
        new_params = _tree_axpy(params, g, -eta)
        return new_params, GradientDescentState(state.iter_num + 1,
                                                jnp.sqrt(gnorm2))


# ---------------------------------------------------------------------------
# Proximal gradient / FISTA (and projected gradient as a special case)
# ---------------------------------------------------------------------------

class ProximalGradientState(NamedTuple):
    """Iteration state of ``ProximalGradient``."""
    iter_num: jnp.ndarray
    error: jnp.ndarray
    z: Any                     # momentum iterate (= params when accel off)
    t: jnp.ndarray             # FISTA momentum scalar


@dataclasses.dataclass(eq=False)
class ProximalGradient(IterativeSolver):
    """min f(x, θf) + g(x, θg); run signature ``run(init, (θf, θg))``.

    FISTA momentum is on by default (``accel=False`` gives plain ISTA).
    Optimality mapping: the prox-grad fixed point (paper eq. 7).
    """
    fun: Callable = None
    prox: Callable = None      # prox(y, theta_g, scaling) -> pytree
    stepsize: float = 1e-2
    accel: bool = True

    @property
    def fixed_point_fun(self):
        """The fixed-point mapping ``T(x, θ)`` (residual ``T(x) − x``)."""
        return optimality.proximal_gradient_fp(self.fun, self.prox,
                                               self.stepsize)

    def _pg_step(self, x, theta):
        theta_f, theta_g = theta
        y = _tree_axpy(x, jax.grad(self.fun, argnums=0)(x, theta_f),
                       -self.stepsize)
        return self.prox(y, theta_g, self.stepsize)

    def init_state(self, params, theta):
        """See ``IterativeSolver.init_state``."""
        return ProximalGradientState(jnp.asarray(0), _inf_like(params),
                                     z=params, t=jnp.asarray(1.0))

    def update(self, params, state, theta):
        """See ``IterativeSolver.update``."""
        if not self.accel:
            new_params = self._pg_step(params, theta)
            error = _tree_l2(_tree_sub(new_params, params))
            return new_params, ProximalGradientState(
                state.iter_num + 1, error, z=new_params, t=state.t)
        new_params = self._pg_step(state.z, theta)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * state.t * state.t))
        mom = (state.t - 1.0) / t_new
        z_new = jax.tree_util.tree_map(
            lambda a, b: a + mom * (a - b), new_params, params)
        error = _tree_l2(_tree_sub(new_params, params))
        return new_params, ProximalGradientState(state.iter_num + 1, error,
                                                 z=z_new, t=t_new)


def ProjectedGradient(fun: Callable, proj: Callable, **kw) -> ProximalGradient:
    """Projected gradient = proximal gradient with an indicator prox
    (paper eq. 9); run signature ``run(init, (θf, θproj))``."""
    def prox(y, theta_proj, scaling):
        del scaling
        return proj(y, theta_proj)

    return ProximalGradient(fun, prox, **kw)


# ---------------------------------------------------------------------------
# Mirror descent (KL geometry default)
# ---------------------------------------------------------------------------

class MirrorDescentState(NamedTuple):
    """Iteration state of ``MirrorDescent``."""
    iter_num: jnp.ndarray
    error: jnp.ndarray


@dataclasses.dataclass(eq=False)
class MirrorDescent(IterativeSolver):
    """Mirror descent with Bregman projection; ``run(init, (θf, θproj))``.

    Optimality mapping: the mirror-descent fixed point (paper eq. 13);
    the η decay schedule only affects the forward iteration.
    """
    fun: Callable = None
    proj_bregman: Callable = None          # proj(y, theta_proj) in dual space
    phi_grad: Callable = optimality.kl_phi_grad
    stepsize: float = 1.0
    sqrt_decay_after: int = 100

    @property
    def fixed_point_fun(self):
        """The fixed-point mapping ``T(x, θ)`` (residual ``T(x) − x``)."""
        return optimality.mirror_descent_fp(self.fun, self.proj_bregman,
                                            self.phi_grad, self.stepsize)

    def init_state(self, params, theta):
        """See ``IterativeSolver.init_state``."""
        return MirrorDescentState(jnp.asarray(0), _inf_like(params))

    def update(self, params, state, theta):
        """See ``IterativeSolver.update``."""
        theta_f, theta_proj = theta
        k = state.iter_num
        eta = self.stepsize * jnp.where(
            k < self.sqrt_decay_after, 1.0,
            jnp.sqrt(self.sqrt_decay_after / jnp.maximum(k, 1)))
        y = _tree_axpy(self.phi_grad(params),
                       jax.grad(self.fun, argnums=0)(params, theta_f), -eta)
        new_params = self.proj_bregman(y, theta_proj)
        error = _tree_l2(_tree_sub(new_params, params))
        return new_params, MirrorDescentState(state.iter_num + 1, error)


# ---------------------------------------------------------------------------
# Block coordinate descent (cyclic over rows)
# ---------------------------------------------------------------------------

class BlockCDState(NamedTuple):
    """Iteration state of ``BlockCoordinateDescent``."""
    iter_num: jnp.ndarray
    error: jnp.ndarray


@dataclasses.dataclass(eq=False)
class BlockCoordinateDescent(IterativeSolver):
    """Cyclic block CD; x has shape (m, k), blocks are rows;
    ``run(init, (θf, θg))``.  One update = one Gauss-Seidel sweep; the
    optimality mapping is the (Jacobi) row-wise prox fixed point — both
    share the same fixed points (paper eq. 15)."""
    fun: Callable = None
    block_prox: Callable = None        # block_prox(row, theta_g, stepsize)
    stepsize: float = 1.0

    def fixed_point_fun(self, x, theta):
        """The fixed-point mapping ``T(x, θ)`` (residual ``T(x) − x``)."""
        theta_f, theta_g = theta
        y = x - self.stepsize * jax.grad(self.fun, argnums=0)(x, theta_f)
        return jax.vmap(
            lambda row: self.block_prox(row, theta_g, self.stepsize))(y)

    def init_state(self, params, theta):
        """See ``IterativeSolver.init_state``."""
        return BlockCDState(jnp.asarray(0), _inf_like(params))

    def update(self, params, state, theta):
        """See ``IterativeSolver.update``."""
        theta_f, theta_g = theta
        grad = jax.grad(self.fun, argnums=0)

        def row_update(x, i):
            g = grad(x, theta_f)            # full grad; row i slice used
            row = x[i] - self.stepsize * g[i]
            x = x.at[i].set(self.block_prox(row, theta_g, self.stepsize))
            return x, None

        new_params, _ = lax.scan(row_update, params,
                                 jnp.arange(params.shape[0]))
        error = _tree_l2(new_params - params)
        return new_params, BlockCDState(state.iter_num + 1, error)


# ---------------------------------------------------------------------------
# Newton's method (optimization)
# ---------------------------------------------------------------------------

class NewtonState(NamedTuple):
    """Iteration state of ``Newton``."""
    iter_num: jnp.ndarray
    error: jnp.ndarray


@dataclasses.dataclass(eq=False)
class Newton(IterativeSolver):
    """Damped Newton on a flat-array iterate; optimality = stationarity.

    ``error`` is ‖∇f‖ at the pre-step iterate (the loop exits one step
    after the gradient passes tol, like the legacy implementation)."""
    fun: Callable = None
    stepsize: float = 1.0

    def optimality_fun(self, params, *theta):
        """The optimality mapping ``F(x, θ)`` that ``run()`` differentiates through."""
        return jax.grad(self.fun, argnums=0)(params, *theta)

    def init_state(self, params, *theta):
        """See ``IterativeSolver.init_state``."""
        return NewtonState(jnp.asarray(0), _inf_like(params))

    def update(self, params, state, *theta):
        """See ``IterativeSolver.update``."""
        g = jax.grad(self.fun, argnums=0)(params, *theta)
        H = jax.hessian(self.fun, argnums=0)(params, *theta)
        new_params = params - self.stepsize * jnp.linalg.solve(H, g)
        return new_params, NewtonState(state.iter_num + 1, _tree_l2(g))


# ---------------------------------------------------------------------------
# L-BFGS (two-loop recursion, fixed step)
# ---------------------------------------------------------------------------

class LbfgsState(NamedTuple):
    """Iteration state of ``LBFGS``."""
    iter_num: jnp.ndarray
    error: jnp.ndarray
    x_flat: jnp.ndarray        # (d,) the raveled iterate (ravel hoisted
                               # out of update(): once, in init_state)
    S: jnp.ndarray             # (history, d) step differences
    Y: jnp.ndarray             # (history, d) gradient differences
    rho: jnp.ndarray           # (history,)


@dataclasses.dataclass(eq=False)
class LBFGS(IterativeSolver):
    """L-BFGS with fixed step on the raveled iterate; optimality =
    stationarity.  ``error`` is ‖∇f‖ at the post-step iterate.

    The iterate is raveled ONCE in ``init_state`` (the flat vector rides in
    the state, the unravel closure on the instance) — ``update`` never
    re-ravels the params pytree.  Contract for direct protocol callers:
    ``state.x_flat`` is the CANONICAL iterate and ``update``'s ``params``
    argument supplies structure only; to override the iterate mid-run
    (e.g. a projection step), re-enter via ``init_state`` on the modified
    params instead of editing them between ``update`` calls.
    """
    fun: Callable = None
    history: int = 10
    stepsize: float = 1.0

    def optimality_fun(self, params, *theta):
        """The optimality mapping ``F(x, θ)`` that ``run()`` differentiates through."""
        return jax.grad(self.fun, argnums=0)(params, *theta)

    def init_state(self, params, *theta):
        """See ``IterativeSolver.init_state``."""
        x0 = _ravel_iterate(self, params)
        d, m = x0.shape[0], self.history
        return LbfgsState(jnp.asarray(0), _inf_like(params), x_flat=x0,
                          S=jnp.zeros((m, d), x0.dtype),
                          Y=jnp.zeros((m, d), x0.dtype),
                          rho=jnp.zeros((m,), x0.dtype))

    def update(self, params, state, *theta):
        # the flat iterate rides in the state; params supplies structure only
        """See ``IterativeSolver.update``."""
        x, unravel = state.x_flat, _unravel_for(self, params)
        grad = jax.grad(lambda v: self.fun(unravel(v), *theta))
        S, Y, rho, k = state.S, state.Y, state.rho, state.iter_num
        m = self.history

        def two_loop(g):
            n = jnp.minimum(k, m)
            q = g
            alphas = jnp.zeros((m,), x.dtype)

            def bwd(i, qa):
                q, alphas = qa
                j = (k - 1 - i) % m
                valid = i < n
                a = jnp.where(valid, rho[j] * jnp.dot(S[j], q), 0.0)
                q = q - a * Y[j] * valid
                alphas = alphas.at[j].set(a)
                return q, alphas

            q, alphas = lax.fori_loop(0, m, bwd, (q, alphas))
            j_last = (k - 1) % m
            ys = jnp.dot(S[j_last], Y[j_last])
            yy = jnp.dot(Y[j_last], Y[j_last])
            gamma = jnp.where(jnp.logical_and(k > 0, yy > 0), ys / yy, 1.0)
            r = gamma * q

            def fwd(i, r):
                j = (k - n + i) % m
                valid = i < n
                b = jnp.where(valid, rho[j] * jnp.dot(Y[j], r), 0.0)
                return r + (alphas[j] - b) * S[j] * valid

            return lax.fori_loop(0, m, fwd, r)

        g = grad(x)
        p = two_loop(g)
        x_new = x - self.stepsize * p
        g_new = grad(x_new)
        s, y = x_new - x, g_new - g
        sy = jnp.dot(s, y)
        slot = k % m
        ok = sy > 1e-10
        S = S.at[slot].set(jnp.where(ok, s, S[slot]))
        Y = Y.at[slot].set(jnp.where(ok, y, Y[slot]))
        rho = rho.at[slot].set(jnp.where(ok, 1.0 / jnp.where(ok, sy, 1.0),
                                         rho[slot]))
        new_state = LbfgsState(k + 1, jnp.linalg.norm(g_new), x_flat=x_new,
                               S=S, Y=Y, rho=rho)
        return unravel(x_new), new_state


# ---------------------------------------------------------------------------
# Fixed-point iteration + Anderson acceleration
# ---------------------------------------------------------------------------

class FixedPointState(NamedTuple):
    """Iteration state of ``FixedPointIteration``."""
    iter_num: jnp.ndarray
    error: jnp.ndarray


@dataclasses.dataclass(eq=False)
class FixedPointIteration(IterativeSolver):
    """x ← T(x, θ) until ‖T(x) − x‖ ≤ tol; implicit diff via eq. (3)."""
    fixed_point_fun: Callable = None     # T(x, *theta)

    def init_state(self, params, *theta):
        """See ``IterativeSolver.init_state``."""
        return FixedPointState(jnp.asarray(0), _inf_like(params))

    def update(self, params, state, *theta):
        """See ``IterativeSolver.update``."""
        new_params = self.fixed_point_fun(params, *theta)
        error = _tree_l2(_tree_sub(new_params, params))
        return new_params, FixedPointState(state.iter_num + 1, error)


class AndersonState(NamedTuple):
    """Iteration state of ``AndersonAcceleration``."""
    iter_num: jnp.ndarray
    error: jnp.ndarray
    x_flat: jnp.ndarray        # (d,) the raveled iterate (ravel hoisted
                               # out of update(): once, in init_state)
    X: jnp.ndarray             # (history, d) iterate history (raveled)
    F: jnp.ndarray             # (history, d) residual history g(x) = T(x) − x


@dataclasses.dataclass(eq=False)
class AndersonAcceleration(IterativeSolver):
    """Type-II Anderson acceleration of x = T(x, θ) on the raveled iterate.

    ``aa_ridge`` regularizes the least-squares mixing system (distinct from
    the inherited ``ridge``, which damps the *backward* linear solve).
    ``error`` is the residual ‖T(x) − x‖ at the pre-mixing iterate.
    The iterate is raveled ONCE in ``init_state`` (the flat vector rides in
    the state, the unravel closure on the instance) — ``update`` never
    re-ravels the params pytree.  As for ``LBFGS``: ``state.x_flat`` is the
    canonical iterate; ``update``'s ``params`` supplies structure only
    (re-enter via ``init_state`` to override the iterate mid-run).
    """
    fixed_point_fun: Callable = None     # T(x, *theta)
    history: int = 5
    aa_ridge: float = 1e-8
    beta: float = 1.0

    def init_state(self, params, *theta):
        """See ``IterativeSolver.init_state``."""
        x0 = _ravel_iterate(self, params)
        d, m = x0.shape[0], self.history
        return AndersonState(jnp.asarray(0), _inf_like(params), x_flat=x0,
                             X=jnp.zeros((m, d), x0.dtype),
                             F=jnp.zeros((m, d), x0.dtype))

    def update(self, params, state, *theta):
        # the flat iterate rides in the state; params supplies structure only
        """See ``IterativeSolver.update``."""
        x, unravel = state.x_flat, _unravel_for(self, params)
        m = self.history

        def T_flat(v):
            return _ravel1(self.fixed_point_fun(unravel(v), *theta))

        k = state.iter_num
        gx = T_flat(x) - x
        slot = k % m
        X = state.X.at[slot].set(x)
        Fh = state.F.at[slot].set(gx)
        n = jnp.minimum(k + 1, m)
        # solve min_alpha ||alpha^T Fh||, sum alpha = 1 via normal equations
        G = Fh @ Fh.T + self.aa_ridge * jnp.eye(m, dtype=x.dtype)
        mask = (jnp.arange(m) < n).astype(x.dtype)
        G = G * mask[:, None] * mask[None, :] + \
            jnp.diag(1.0 - mask)  # inactive rows → identity
        alpha = jnp.linalg.solve(G, mask)
        alpha = alpha * mask
        alpha = alpha / jnp.sum(alpha)
        x_new = alpha @ (X + self.beta * Fh)
        error = jnp.linalg.norm(gx)
        return unravel(x_new), AndersonState(k + 1, error, x_flat=x_new,
                                             X=X, F=Fh)
