"""Matrix-free linear system solvers.

All solvers take ``matvec: pytree -> pytree`` and a pytree right-hand side and
return a pytree solution.  They are implemented with ``lax.while_loop`` so they
can live inside jit/scan/custom_vjp bodies, and they only touch the operator
through matrix-vector products — exactly the contract the paper's implicit
differentiation needs (access to F only through JVPs/VJPs).

Solvers:
  * ``solve_cg``        — conjugate gradient (A symmetric PSD)
  * ``solve_normal_cg`` — CG on the normal equations AᵀA x = Aᵀ b (general A,
                          needs ``rmatvec`` or builds it via linear transpose)
  * ``solve_bicgstab``  — BiCGSTAB (general square A)
  * ``solve_gmres``     — restarted GMRES (general square A)
  * ``solve_lu``        — dense direct solve (materializes A; small systems)
  * ``solve_neumann``   — truncated Neumann series for I - M with ||M|| < 1
                          (the "Jacobian-free"/unrolled-free approximation)
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.flatten_util  # registers jax.flatten_util.ravel_pytree
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# pytree helpers
# ---------------------------------------------------------------------------

def _tree_dot(a, b):
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    return sum(jnp.vdot(x, y) for x, y in zip(leaves_a, leaves_b))


def _tree_add(a, b, alpha=1.0):
    return jax.tree_util.tree_map(lambda x, y: x + alpha * y, a, b)


def _tree_sub(a, b):
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def _tree_scale(a, alpha):
    return jax.tree_util.tree_map(lambda x: alpha * x, a)


def _tree_zeros_like(a):
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def _tree_l2(a):
    return jnp.sqrt(jnp.maximum(_tree_dot(a, a).real, 0.0))


def make_rmatvec(matvec: Callable, example_x):
    """Build x ↦ Aᵀx from x ↦ Ax via jax.linear_transpose (paper §2.1)."""
    transpose = jax.linear_transpose(matvec, example_x)

    def rmatvec(y):
        (out,) = transpose(y)
        return out

    return rmatvec


def materialize_matrix(matvec: Callable, example_x) -> jnp.ndarray:
    """Densify a matvec operating on flat vectors (diagnostics / direct solve)."""
    flat, unravel = jax.flatten_util.ravel_pytree(example_x)
    d = flat.shape[0]

    def col(i):
        e = jnp.zeros(d, flat.dtype).at[i].set(1.0)
        out, _ = jax.flatten_util.ravel_pytree(matvec(unravel(e)))
        return out

    return jax.vmap(col)(jnp.arange(d)).T


# ---------------------------------------------------------------------------
# Conjugate gradient
# ---------------------------------------------------------------------------

def solve_cg(matvec: Callable, b, *, init=None, tol: float = 1e-6,
             maxiter: int = 1000, ridge: float = 0.0):
    """Conjugate gradient for symmetric positive-(semi)definite operators.

    ``ridge`` adds λI damping, the common non-invertibility heuristic.
    """
    if ridge:
        inner = matvec
        matvec = lambda v: _tree_add(inner(v), v, ridge)
    x0 = _tree_zeros_like(b) if init is None else init
    r0 = _tree_sub(b, matvec(x0))
    p0 = r0
    rs0 = _tree_dot(r0, r0)
    b_norm = _tree_l2(b)
    atol2 = jnp.maximum(tol * b_norm, 1e-30) ** 2

    def cond(state):
        _, _, _, rs, k = state
        return jnp.logical_and(k < maxiter, rs.real > atol2)

    def body(state):
        x, r, p, rs, k = state
        ap = matvec(p)
        denom = _tree_dot(p, ap)
        alpha = rs / jnp.where(denom == 0, 1.0, denom)
        alpha = jnp.where(denom == 0, 0.0, alpha)
        x = _tree_add(x, p, alpha)
        r = _tree_add(r, ap, -alpha)
        rs_new = _tree_dot(r, r)
        beta = rs_new / jnp.where(rs == 0, 1.0, rs)
        p = _tree_add(r, p, beta)
        return x, r, p, rs_new, k + 1

    x, _, _, _, _ = lax.while_loop(cond, body, (x0, r0, p0, rs0, 0))
    return x


def solve_normal_cg(matvec: Callable, b, *, init=None, rmatvec=None,
                    tol: float = 1e-6, maxiter: int = 1000,
                    ridge: float = 0.0):
    """Solve A x = b via CG on AᵀA x = Aᵀ b.  Works for any square A."""
    example = _tree_zeros_like(b) if init is None else init
    if rmatvec is None:
        rmatvec = make_rmatvec(matvec, example)

    def normal_mv(v):
        return rmatvec(matvec(v))

    return solve_cg(normal_mv, rmatvec(b), init=init, tol=tol,
                    maxiter=maxiter, ridge=ridge)


# ---------------------------------------------------------------------------
# BiCGSTAB
# ---------------------------------------------------------------------------

def solve_bicgstab(matvec: Callable, b, *, init=None, tol: float = 1e-6,
                   maxiter: int = 1000, ridge: float = 0.0):
    """BiCGSTAB (van der Vorst, 1992) for general square operators."""
    if ridge:
        inner = matvec
        matvec = lambda v: _tree_add(inner(v), v, ridge)
    x0 = _tree_zeros_like(b) if init is None else init
    r0 = _tree_sub(b, matvec(x0))
    rhat = r0
    b_norm = _tree_l2(b)
    atol = jnp.maximum(tol * b_norm, 1e-30)

    init_state = dict(x=x0, r=r0, p=r0, v=_tree_zeros_like(b),
                      rho=_tree_dot(rhat, r0), alpha=jnp.asarray(1.0, b_norm.dtype),
                      omega=jnp.asarray(1.0, b_norm.dtype), k=0,
                      breakdown=jnp.asarray(False))

    def cond(s):
        return jnp.logical_and(
            s["k"] < maxiter,
            jnp.logical_and(_tree_l2(s["r"]) > atol,
                            jnp.logical_not(s["breakdown"])))

    def body(s):
        x, r, p, rho = s["x"], s["r"], s["p"], s["rho"]
        v = matvec(p)
        denom = _tree_dot(rhat, v)
        breakdown = denom == 0
        alpha = rho / jnp.where(breakdown, 1.0, denom)
        h = _tree_add(x, p, alpha)
        sres = _tree_add(r, v, -alpha)
        t = matvec(sres)
        tt = _tree_dot(t, t)
        omega = _tree_dot(t, sres) / jnp.where(tt == 0, 1.0, tt)
        omega = jnp.where(tt == 0, 0.0, omega)
        x_new = _tree_add(h, sres, omega)
        r_new = _tree_add(sres, t, -omega)
        rho_new = _tree_dot(rhat, r_new)
        beta = (rho_new / jnp.where(rho == 0, 1.0, rho)) * \
               (alpha / jnp.where(omega == 0, 1.0, omega))
        p_new = _tree_add(r_new,
                          _tree_add(p, v, -omega), beta)
        return dict(x=x_new, r=r_new, p=p_new, v=v, rho=rho_new,
                    alpha=alpha, omega=omega, k=s["k"] + 1,
                    breakdown=jnp.logical_or(breakdown, rho == 0))

    out = lax.while_loop(cond, body, init_state)
    return out["x"]


# ---------------------------------------------------------------------------
# GMRES (restarted, flat-vector core)
# ---------------------------------------------------------------------------

def solve_gmres(matvec: Callable, b, *, init=None, tol: float = 1e-6,
                restart: int = 20, maxiter: int = 50, ridge: float = 0.0):
    """Restarted GMRES.  Flattens the pytree to run Arnoldi on a matrix basis."""
    if ridge:
        inner = matvec
        matvec = lambda v: _tree_add(inner(v), v, ridge)

    b_flat, unravel = jax.flatten_util.ravel_pytree(b)
    d = b_flat.shape[0]
    m = min(restart, d)

    def mv_flat(v):
        out, _ = jax.flatten_util.ravel_pytree(matvec(unravel(v)))
        return out

    b_norm = jnp.linalg.norm(b_flat)
    atol = jnp.maximum(tol * b_norm, 1e-30)
    x0 = jnp.zeros_like(b_flat) if init is None else \
        jax.flatten_util.ravel_pytree(init)[0]

    def arnoldi_cycle(x):
        r = b_flat - mv_flat(x)
        beta = jnp.linalg.norm(r)
        safe_beta = jnp.where(beta == 0, 1.0, beta)
        V = jnp.zeros((m + 1, d), b_flat.dtype).at[0].set(r / safe_beta)
        H = jnp.zeros((m + 1, m), b_flat.dtype)

        def step(carry, j):
            V, H = carry
            w = mv_flat(V[j])
            # modified Gram-Schmidt against all basis vectors (masked)
            def ortho(i, w_h):
                w, H = w_h
                hij = jnp.where(i <= j, jnp.vdot(V[i], w), 0.0)
                w = w - hij * V[i]
                H = H.at[i, j].set(jnp.where(i <= j, hij, H[i, j]))
                return w, H
            w, H = lax.fori_loop(0, m, ortho, (w, H))
            hn = jnp.linalg.norm(w)
            H = H.at[j + 1, j].set(hn)
            V = V.at[j + 1].set(w / jnp.where(hn == 0, 1.0, hn))
            return (V, H), None

        (V, H), _ = lax.scan(step, (V, H), jnp.arange(m))
        # least squares: min ||beta e1 - H y||
        e1 = jnp.zeros(m + 1, b_flat.dtype).at[0].set(beta)
        y, *_ = jnp.linalg.lstsq(H, e1, rcond=None)
        return x + V[:m].T @ y

    def cond(state):
        x, k = state
        r = jnp.linalg.norm(b_flat - mv_flat(x))
        return jnp.logical_and(k < maxiter, r > atol)

    def body(state):
        x, k = state
        return arnoldi_cycle(x), k + 1

    x, _ = lax.while_loop(cond, body, (x0, 0))
    return unravel(x)


# ---------------------------------------------------------------------------
# Direct and Neumann
# ---------------------------------------------------------------------------

def solve_lu(matvec: Callable, b, *, init=None, **_):
    """Materialize A and solve densely.  For small/d≤few-thousand systems."""
    del init
    b_flat, unravel = jax.flatten_util.ravel_pytree(b)
    A = materialize_matrix(matvec, b)
    return unravel(jnp.linalg.solve(A, b_flat))


def solve_neumann(matvec: Callable, b, *, init=None, maxiter: int = 10, **_):
    """Approximate (I - M)⁻¹ b ≈ Σ_{k<K} Mᵏ b where matvec(v) = v - M v.

    I.e. interprets ``matvec`` as A = I - M and truncates the Neumann series.
    Matches "Jacobian-free backprop" / phantom-gradient style approximations.
    """
    del init

    def mfun(v):  # M v = v - A v
        return _tree_sub(v, matvec(v))

    def body(carry, _):
        acc, term = carry
        term = mfun(term)
        return (_tree_add(acc, term), term), None

    (acc, _), _ = lax.scan(body, (b, b), None, length=maxiter)
    return acc


SOLVERS = {
    "cg": solve_cg,
    "normal_cg": solve_normal_cg,
    "bicgstab": solve_bicgstab,
    "gmres": solve_gmres,
    "lu": solve_lu,
    "neumann": solve_neumann,
}


def get_solver(name_or_fn):
    if callable(name_or_fn):
        return name_or_fn
    try:
        return SOLVERS[name_or_fn]
    except KeyError:
        raise ValueError(f"unknown linear solver {name_or_fn!r}; "
                         f"available: {sorted(SOLVERS)}") from None
