"""Matrix-free linear solvers: the batched solve engine behind implicit diff.

All solvers take an operator — a ``repro.core.operators.LinearOperator`` or a
bare ``matvec: pytree -> pytree`` closure — and a pytree right-hand side and
return a pytree solution.  They are implemented with ``lax.while_loop`` so they
can live inside jit/scan/custom_vjp bodies, and they only touch the operator
through matrix-vector products — exactly the contract the paper's implicit
differentiation needs (access to F only through JVPs/VJPs).  Operators carry
their structure with them (symmetry/definiteness flags, O(1) ``diagonal``/
``materialize`` where available, batch awareness): routing validates
symmetric-only solvers against the flags, ``method="auto"`` picks the regime
(dense small systems auto-materialize, large ones stay matrix-free), and
``"jacobi"``/``"block_jacobi"`` preconditioners derive from
``operator.diagonal()`` instead of probing.

Registry (``SolverSpec``; see ``available_solvers()``):

  * ``cg``        — conjugate gradient (A symmetric PSD; preconditioned)
  * ``normal_cg`` — CG on the normal equations AᵀA x = Aᵀ b (general A,
                    needs ``rmatvec`` or builds it via linear transpose)
  * ``bicgstab``  — BiCGSTAB (general square A)
  * ``gmres``     — restarted GMRES (general square A; left-preconditioned)
  * ``dense_gmres`` — batched GMRES on materialized per-instance operators
                    (the nonsymmetric dense small-system regime, d ≤ 512)
  * ``lu``        — dense direct solve (materializes A; small systems)
  * ``neumann``   — truncated Neumann series for I - M with ||M|| < 1
                    (the "Jacobian-free"/unrolled-free approximation)
  * ``pallas_cg`` — fused Pallas batched-CG kernel for the dense small-system
                    regime (d ≤ 512); materializes per-instance operators

Batching
--------
Every iterative solver is **vmap-safe with per-instance convergence masks**:
the ``lax.while_loop`` state carries a ``done`` flag and converged instances
freeze (their state is held by ``where(done, old, new)``) while stragglers
keep iterating — one while_loop for the whole batch, never N sequential
solves.  Use either

  * ``jax.vmap`` over any solver (or over a ``@custom_root``-decorated solver:
    its backward pass then runs one batched solve), or
  * the uniform entry point ``solve(matvec, b, batch_axes=0, ...)`` where
    ``matvec`` maps batched pytrees to batched pytrees.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import operators
from repro.core.operators import (LinearOperator, RavelView, _ravel1,
                                  jacobi_preconditioner, ravel_view)
# bottom-adjacent telemetry (imports nothing from repro.core): solve events
# are staged jit-safely behind the process-level observe() switch — with
# observability disabled (default) every emission below is a trace-time
# no-op and compiled programs are bit-identical to an uninstrumented build
from repro.observability import events as obs_events


# ---------------------------------------------------------------------------
# batch-aware pytree helpers
#
# ``batch_ndim`` is the number of leading batch axes on every leaf (0 or 1).
# Reductions run over the instance axes only, so per-instance scalars
# (step sizes, residual norms, done flags) have the batch shape.
# ---------------------------------------------------------------------------

def _bc(s, leaf, batch_ndim: int):
    """Broadcast a per-instance scalar against an instance-shaped leaf."""
    if batch_ndim == 0:
        return s
    s = jnp.asarray(s)
    return s.reshape(s.shape + (1,) * (jnp.ndim(leaf) - batch_ndim))


def _tree_dot(a, b, batch_ndim: int = 0):
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    out = 0.0
    for x, y in zip(leaves_a, leaves_b):
        axes = tuple(range(batch_ndim, jnp.ndim(x)))
        out = out + jnp.sum(jnp.conj(x) * y, axis=axes)
    return out


def _tree_add(a, b, alpha=1.0, batch_ndim: int = 0):
    return jax.tree_util.tree_map(
        lambda x, y: x + _bc(alpha, x, batch_ndim) * y, a, b)


def _tree_sub(a, b):
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def _tree_scale(a, alpha, batch_ndim: int = 0):
    return jax.tree_util.tree_map(lambda x: _bc(alpha, x, batch_ndim) * x, a)


def _tree_zeros_like(a):
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def _tree_l2(a, batch_ndim: int = 0):
    return jnp.sqrt(jnp.maximum(_tree_dot(a, a, batch_ndim).real, 0.0))


def _tree_freeze(done, old, new, batch_ndim: int = 0):
    """Hold converged instances: where(done, old, new) leaf-wise."""
    return jax.tree_util.tree_map(
        lambda o, n: jnp.where(_bc(done, o, batch_ndim), o, n), old, new)


def _damped(matvec: Callable, ridge: float) -> Callable:
    if not ridge:
        return matvec
    if isinstance(matvec, LinearOperator):
        return operators.RidgeShifted(matvec, ridge)   # keeps flags/structure
    return lambda v: _tree_add(matvec(v), v, ridge)


def make_rmatvec(matvec: Callable, example_x):
    """Build x ↦ Aᵀx from x ↦ Ax.  ``LinearOperator``s answer directly
    (symmetric ones reuse the forward matvec); bare closures go through
    ``jax.linear_transpose`` (paper §2.1)."""
    if isinstance(matvec, LinearOperator):
        return matvec.rmatvec
    transpose = jax.linear_transpose(matvec, example_x)

    def rmatvec(y):
        (out,) = transpose(y)
        return out

    return rmatvec


def _as_probe_operator(matvec, example, batch_ndim: int) -> LinearOperator:
    """Coerce to an operator with matching batchedness, so the basis-vector
    probing loops live in ONE place (the ``LinearOperator`` defaults)."""
    if isinstance(matvec, LinearOperator) and matvec.batch_ndim == batch_ndim:
        return matvec
    return operators.FunctionOperator(matvec, example, batch_ndim=batch_ndim)


def materialize_matrix(matvec: Callable, example_x) -> jnp.ndarray:
    """Densify a matvec to its (d, d) matrix (diagnostics / direct solve).

    A ``LinearOperator`` materializes itself (O(1) for dense/structured
    operators); bare closures are probed with basis vectors.
    """
    return _as_probe_operator(matvec, example_x, 0).materialize()


# ---------------------------------------------------------------------------
# flat (B, d) view of a batched pytree operator
#
# The view itself lives in repro.core.operators (``ravel_view`` — one ravel
# shim for the whole stack); this layer adds the dense materialization with
# an operator fast path.
# ---------------------------------------------------------------------------

def materialize_batched(matvec: Callable, b, batch_ndim: int = 0,
                        view: Optional[RavelView] = None):
    """Densify a (possibly batched) operator to (B, d, d) plus the flat view.

    A ``LinearOperator`` (with matching batchedness) materializes itself —
    O(1) for ``DenseOperator``/``RidgeShifted`` stacks, which is what makes
    the dense-regime solvers auto-materialize instead of probing.  Bare
    closures are probed with basis vectors broadcast across the batch, so
    the cost is d matvecs regardless of batch size.
    """
    if view is None:
        view = ravel_view(matvec, b, batch_ndim)
    B, d = view.b.shape
    A = _as_probe_operator(matvec, b, batch_ndim).materialize()
    A = A if batch_ndim else A[None]
    return jnp.broadcast_to(A, (B, d, d)), view


# ---------------------------------------------------------------------------
# preconditioning hooks
# ---------------------------------------------------------------------------

def diagonal_of_matvec(matvec: Callable, b, batch_ndim: int = 0):
    """Extract diag(A) with the same (possibly batched) structure as ``b``.

    A ``LinearOperator`` (with matching batchedness) answers via its own
    ``diagonal()`` — O(1) for structured operators; bare closures pay d
    probing matvecs (vmapped across instances).
    """
    return _as_probe_operator(matvec, b, batch_ndim).diagonal()


def _resolve_precond(precond, matvec, b, batch_ndim: int, diag=None,
                     materialized=None):
    """None | callable | "jacobi" | "block_jacobi" -> callable M⁻¹ (or None).

    ``diag``/``materialized`` short-circuit the operator probing when the
    caller already holds the diagonal or the dense matrix (the dense-regime
    solvers materialize anyway — no second probing pass).  ``"block_jacobi"``
    needs a ``LinearOperator`` (the domain's pytree leaves — or a
    ``BlockDiagonal``'s blocks — define the blocks).
    """
    if precond is None or callable(precond):
        return precond
    if precond == "jacobi":
        if diag is None:
            diag = diagonal_of_matvec(matvec, b, batch_ndim)
        return jacobi_preconditioner(diag)
    if precond == "block_jacobi":
        if not isinstance(matvec, LinearOperator):
            raise ValueError("precond='block_jacobi' derives blocks from "
                             "operator structure; pass a LinearOperator "
                             "(or use 'jacobi' / a callable M⁻¹)")
        return operators.block_jacobi_preconditioner(
            matvec, materialized=materialized)
    raise ValueError(f"unknown preconditioner {precond!r}; expected None, "
                     "a callable M⁻¹, 'jacobi', or 'block_jacobi'")


# ---------------------------------------------------------------------------
# solve diagnostics
# ---------------------------------------------------------------------------

class SolveInfo(NamedTuple):
    """Per-instance diagnostics (batch-shaped under vmap / batch_axes).

    ``iterations`` counts the solver's outer steps: matvec iterations for
    cg/normal_cg/bicgstab, *restart cycles* (each up to ``restart`` Arnoldi
    steps) for gmres, 0 for direct solves, -1 when untracked (pallas_cg).
    """
    iterations: jnp.ndarray    # outer steps actually spent per instance
    residual: jnp.ndarray      # final ||b - A x|| per instance
    converged: jnp.ndarray     # residual <= tol * ||b|| per instance
    # relative residual ||rhs - A u|| / ||rhs|| of the implicit system at the
    # returned (co)tangent — populated by the approximate backward modes (and
    # by exact solves when error_estimate=True is requested); None otherwise
    hypergrad_error_estimate: Optional[jnp.ndarray] = None


def _maybe_info(x, info: Optional[SolveInfo], return_info: bool):
    return (x, info) if return_info else x


def _squeeze_info(info: SolveInfo) -> SolveInfo:
    """Collapse the internal B=1 batch axis for unbatched calls — the one
    place the flat-core solvers' per-instance diagnostics lose their
    synthetic leading axis."""
    return SolveInfo(*(None if leaf is None
                       else jnp.asarray(leaf).reshape(-1)[0] for leaf in info))


# ---------------------------------------------------------------------------
# Conjugate gradient (preconditioned, masked)
# ---------------------------------------------------------------------------

def solve_cg(matvec: Callable, b, *, init=None, tol: float = 1e-6,
             maxiter: int = 1000, ridge: float = 0.0, precond=None,
             return_info: bool = False, batch_ndim: int = 0, reduce=None):
    """(Preconditioned) conjugate gradient for symmetric PSD operators.

    ``ridge`` adds λI damping, the common non-invertibility heuristic.
    ``precond`` is ``None``, a callable v ↦ M⁻¹v, or ``"jacobi"``.
    Vmap-safe: converged instances freeze inside the single while_loop.
    ``reduce`` post-processes every dot-product/norm reduction — the hook
    the sharded solvers use to ``psum`` partial sums when the instance
    dims are split across devices (``None``: plain local sums).
    """
    nb = batch_ndim
    red = (lambda s: s) if reduce is None else reduce
    tdot = lambda u, w: red(_tree_dot(u, w, nb))
    tl2 = lambda u: jnp.sqrt(jnp.maximum(tdot(u, u).real, 0.0))
    matvec = _damped(matvec, ridge)
    M = _resolve_precond(precond, matvec, b, nb)
    x0 = _tree_zeros_like(b) if init is None else init
    r0 = _tree_sub(b, matvec(x0))
    z0 = M(r0) if M is not None else r0
    p0 = z0
    rz0 = tdot(r0, z0)
    rr0 = tdot(r0, r0).real
    b_norm = tl2(b)
    atol2 = jnp.maximum(tol * b_norm, 1e-30) ** 2
    done0 = rr0 <= atol2
    it0 = jnp.zeros_like(b_norm, dtype=jnp.int32)
    # trace-time flag: per-iteration telemetry is opt-in (a host callback
    # per loop step); the default compiles an uninstrumented loop body
    iter_events = obs_events.observing_iterations()

    def cond(state):
        k = state[-2]
        done = state[-1]
        return jnp.logical_and(k < maxiter, jnp.logical_not(jnp.all(done)))

    def body(state):
        x, r, p, rz, rr, it, k, done = state
        ap = matvec(p)
        denom = tdot(p, ap)
        alpha = jnp.where(denom == 0, 0.0, rz / jnp.where(denom == 0, 1.0,
                                                          denom))
        x1 = _tree_add(x, p, alpha, nb)
        r1 = _tree_add(r, ap, -alpha, nb)
        rr1 = tdot(r1, r1).real
        z1 = M(r1) if M is not None else r1
        rz1 = tdot(r1, z1)
        beta = rz1 / jnp.where(rz == 0, 1.0, rz)
        beta = jnp.where(rz == 0, 0.0, beta)
        p1 = _tree_add(z1, p, beta, nb)
        # freeze instances that were already done at loop entry
        x = _tree_freeze(done, x, x1, nb)
        r = _tree_freeze(done, r, r1, nb)
        p = _tree_freeze(done, p, p1, nb)
        rz = jnp.where(done, rz, rz1)
        rr = jnp.where(done, rr, rr1)
        it = it + jnp.logical_not(done)
        done = jnp.logical_or(done, rr <= atol2)
        if iter_events:
            obs_events.jit_event("iteration", {"solver": "cg"},
                                 step=k + 1, residual_sq=rr)
        return x, r, p, rz, rr, it, k + 1, done

    x, r, _, _, rr, it, _, done = lax.while_loop(
        cond, body, (x0, r0, p0, rz0, rr0, it0, 0, done0))
    info = SolveInfo(iterations=it, residual=jnp.sqrt(rr),
                     converged=rr <= atol2)
    return _maybe_info(x, info, return_info)


def solve_normal_cg(matvec: Callable, b, *, init=None, rmatvec=None,
                    tol: float = 1e-6, maxiter: int = 1000,
                    ridge: float = 0.0, precond=None,
                    return_info: bool = False, batch_ndim: int = 0,
                    reduce=None):
    """Solve A x = b via CG on AᵀA x = Aᵀ b.  Works for any square A."""
    example = _tree_zeros_like(b) if init is None else init
    if rmatvec is None:
        rmatvec = make_rmatvec(matvec, example)

    def normal_mv(v):
        return rmatvec(matvec(v))

    return solve_cg(normal_mv, rmatvec(b), init=init, tol=tol,
                    maxiter=maxiter, ridge=ridge, precond=precond,
                    return_info=return_info, batch_ndim=batch_ndim,
                    reduce=reduce)


# ---------------------------------------------------------------------------
# BiCGSTAB (masked)
# ---------------------------------------------------------------------------

def solve_bicgstab(matvec: Callable, b, *, init=None, tol: float = 1e-6,
                   maxiter: int = 1000, ridge: float = 0.0, precond=None,
                   return_info: bool = False, batch_ndim: int = 0):
    """BiCGSTAB (van der Vorst, 1992) for general square operators.

    ``precond`` applies as a left preconditioner (wraps the operator); the
    loop iterates on the preconditioned residual, but ``SolveInfo`` always
    reports the TRUE residual ||b - A x|| so ``converged`` means the same
    thing across solvers.  Vmap-safe: per-instance done/breakdown masks
    inside one while_loop.
    """
    nb = batch_ndim
    matvec = _damped(matvec, ridge)
    matvec0, b0 = matvec, b
    M = _resolve_precond(precond, matvec, b, nb)
    if M is not None:
        inner = matvec
        matvec = lambda v: M(inner(v))
        b = M(b)
    x0 = _tree_zeros_like(b) if init is None else init
    r0 = _tree_sub(b, matvec(x0))
    rhat = r0
    b_norm = _tree_l2(b, nb)
    atol = jnp.maximum(tol * b_norm, 1e-30)
    rn0 = _tree_l2(r0, nb)
    done0 = rn0 <= atol

    init_state = dict(x=x0, r=r0, p=r0, rho=_tree_dot(rhat, r0, nb),
                      alpha=jnp.ones_like(b_norm),
                      omega=jnp.ones_like(b_norm),
                      rnorm=rn0, it=jnp.zeros_like(b_norm, dtype=jnp.int32),
                      k=0, done=done0,
                      breakdown=jnp.zeros_like(done0))

    def cond(s):
        return jnp.logical_and(s["k"] < maxiter,
                               jnp.logical_not(jnp.all(s["done"])))

    def body(s):
        x, r, p, rho, done = s["x"], s["r"], s["p"], s["rho"], s["done"]
        v = matvec(p)
        denom = _tree_dot(rhat, v, nb)
        breakdown = denom == 0
        alpha = rho / jnp.where(breakdown, 1.0, denom)
        alpha = jnp.where(breakdown, 0.0, alpha)
        h = _tree_add(x, p, alpha, nb)
        sres = _tree_add(r, v, -alpha, nb)
        t = matvec(sres)
        tt = _tree_dot(t, t, nb)
        omega = _tree_dot(t, sres, nb) / jnp.where(tt == 0, 1.0, tt)
        omega = jnp.where(tt == 0, 0.0, omega)
        x1 = _tree_add(h, sres, omega, nb)
        r1 = _tree_add(sres, t, -omega, nb)
        rho1 = _tree_dot(rhat, r1, nb)
        beta = (rho1 / jnp.where(rho == 0, 1.0, rho)) * \
               (alpha / jnp.where(omega == 0, 1.0, omega))
        p1 = _tree_add(r1, _tree_add(p, v, -omega, nb), beta, nb)
        rn1 = _tree_l2(r1, nb)
        breakdown = jnp.logical_or(breakdown, rho == 0)
        # freeze instances that were already done at loop entry
        x = _tree_freeze(done, x, x1, nb)
        r = _tree_freeze(done, r, r1, nb)
        p = _tree_freeze(done, p, p1, nb)
        rho = jnp.where(done, rho, rho1)
        alpha = jnp.where(done, s["alpha"], alpha)
        omega = jnp.where(done, s["omega"], omega)
        rnorm = jnp.where(done, s["rnorm"], rn1)
        it = s["it"] + jnp.logical_not(done)
        done = jnp.logical_or(done, jnp.logical_or(rnorm <= atol, breakdown))
        return dict(x=x, r=r, p=p, rho=rho, alpha=alpha, omega=omega,
                    rnorm=rnorm, it=it, k=s["k"] + 1, done=done,
                    breakdown=jnp.logical_or(s["breakdown"], breakdown))

    out = lax.while_loop(cond, body, init_state)
    if return_info:
        rn, cutoff = out["rnorm"], atol
        if M is not None:   # report the true residual, not M(b - A x)
            rn = _tree_l2(_tree_sub(b0, matvec0(out["x"])), nb)
            cutoff = jnp.maximum(tol * _tree_l2(b0, nb), 1e-30)
        return out["x"], SolveInfo(iterations=out["it"], residual=rn,
                                   converged=rn <= cutoff)
    return out["x"]


# ---------------------------------------------------------------------------
# GMRES (restarted; flat (B, d) core, masked restarts)
# ---------------------------------------------------------------------------

def _flat_init(init, b_flat, batch_ndim: int):
    """Flatten an init pytree to the (B, d) layout (zeros when None)."""
    if init is None:
        return jnp.zeros_like(b_flat)
    if batch_ndim == 0:
        return _ravel1(init)[None]
    return jax.vmap(_ravel1)(init)


def _gmres_flat(mv: Callable, b_flat, x0, *, tol: float, restart: int,
                maxiter: int):
    """Shared restarted-GMRES core on the flat (B, d) layout.

    Runs batched Arnoldi cycles in one masked while_loop; returns
    ``(x, rn, it, atol)`` with per-instance residuals/iteration counts.
    ``maxiter`` is the total matvec budget; the cycle cap is
    ``ceil(maxiter / restart)``.
    """
    B, d = b_flat.shape
    m = min(restart, d)
    max_cycles = max(1, -(-maxiter // m))       # ceil: total matvec budget

    b_norm = jnp.linalg.norm(b_flat, axis=-1)                    # (B,)
    atol = jnp.maximum(tol * b_norm, 1e-30)

    def arnoldi_cycle(x):
        r = b_flat - mv(x)                                       # (B, d)
        beta = jnp.linalg.norm(r, axis=-1)                       # (B,)
        safe_beta = jnp.where(beta == 0, 1.0, beta)
        V = jnp.zeros((B, m + 1, d), b_flat.dtype)
        V = V.at[:, 0].set(r / safe_beta[:, None])
        H = jnp.zeros((B, m + 1, m), b_flat.dtype)

        def step(carry, j):
            V, H = carry
            w = mv(V[:, j])                                      # (B, d)
            # modified Gram-Schmidt against all basis vectors (masked)
            def ortho(i, w_h):
                w, H = w_h
                hij = jnp.where(i <= j,
                                jnp.sum(jnp.conj(V[:, i]) * w, axis=-1), 0.0)
                w = w - hij[:, None] * V[:, i]
                H = H.at[:, i, j].set(jnp.where(i <= j, hij, H[:, i, j]))
                return w, H
            w, H = lax.fori_loop(0, m, ortho, (w, H))
            hn = jnp.linalg.norm(w, axis=-1)
            H = H.at[:, j + 1, j].set(hn)
            V = V.at[:, j + 1].set(w / jnp.where(hn == 0, 1.0, hn)[:, None])
            return (V, H), None

        (V, H), _ = lax.scan(step, (V, H), jnp.arange(m))
        # least squares per instance: min ||beta e1 - H y||
        e1 = jnp.zeros((B, m + 1), b_flat.dtype).at[:, 0].set(beta)
        y = jax.vmap(lambda Hi, ei: jnp.linalg.lstsq(Hi, ei, rcond=None)[0])(
            H, e1)
        return x + jnp.einsum("bmd,bm->bd", V[:, :m], y)

    rn0 = jnp.linalg.norm(b_flat - mv(x0), axis=-1)
    done0 = rn0 <= atol
    it0 = jnp.zeros((B,), jnp.int32)

    def cond(state):
        _, _, _, k, done = state
        return jnp.logical_and(k < max_cycles, jnp.logical_not(jnp.all(done)))

    def body(state):
        x, rn, it, k, done = state
        x1 = arnoldi_cycle(x)
        rn1 = jnp.linalg.norm(b_flat - mv(x1), axis=-1)
        x = jnp.where(done[:, None], x, x1)                      # freeze
        rn = jnp.where(done, rn, rn1)
        it = it + jnp.logical_not(done)
        done = jnp.logical_or(done, rn <= atol)
        return x, rn, it, k + 1, done

    x, rn, it, _, done = lax.while_loop(cond, body,
                                        (x0, rn0, it0, 0, done0))
    return x, rn, it, atol


def solve_gmres(matvec: Callable, b, *, init=None, tol: float = 1e-6,
                restart: int = 20, maxiter: int = 1000, ridge: float = 0.0,
                precond=None, return_info: bool = False, batch_ndim: int = 0):
    """Restarted GMRES.  Flattens instances to run batched Arnoldi cycles.

    ``maxiter`` is the total matvec budget, like the other iterative
    solvers; the cycle cap is ``ceil(maxiter / restart)`` (so the uniform
    engine default of 1000 means ~50 restart cycles, not 1000).
    ``precond`` applies as a left preconditioner; the loop iterates on the
    preconditioned residual, but ``SolveInfo`` always reports the TRUE
    residual.  Converged instances skip further cycles via per-instance
    masks.
    """
    matvec = _damped(matvec, ridge)
    matvec0, b0 = matvec, b
    M = _resolve_precond(precond, matvec, b, batch_ndim)
    if M is not None:
        inner = matvec
        matvec = lambda v: M(inner(v))
        b = M(b)

    view = ravel_view(matvec, b, batch_ndim)
    x0 = _flat_init(init, view.b, batch_ndim)
    x, rn, it, atol = _gmres_flat(view.mv, view.b, x0, tol=tol,
                                  restart=restart, maxiter=maxiter)
    x_tree = view.to_tree(x)
    if not return_info:
        return x_tree
    cutoff = atol
    if M is not None:   # report the true residual, not M(b - A x)
        rn = _tree_l2(_tree_sub(b0, matvec0(x_tree)), batch_ndim)
        cutoff = jnp.maximum(tol * _tree_l2(b0, batch_ndim), 1e-30)
    info = SolveInfo(iterations=it, residual=rn, converged=rn <= cutoff)
    if batch_ndim == 0:
        info = _squeeze_info(info)
    return x_tree, info


def solve_dense_gmres(matvec: Callable, b, *, init=None, tol: float = 1e-6,
                      restart: int = 20, maxiter: int = 1000,
                      ridge: float = 0.0, precond=None,
                      return_info: bool = False, batch_ndim: int = 0):
    """Batched preconditioned GMRES for the nonsymmetric *dense* regime.

    The nonsymmetric sibling of ``pallas_cg``'s regime: materializes the
    per-instance operators once (d probing matvecs for the whole batch,
    d ≤ ``MAX_DENSE_DIM``) and then runs the shared restarted-Arnoldi core
    with each matvec as one batched (B, d, d) × (B, d) contraction — no
    re-tracing of the user's matvec closure inside the cycles.  ``"jacobi"``
    preconditioning reads the diagonal straight off the materialized
    operator (no extra probing); a callable ``precond`` is applied on the
    flat (instance-shaped) vectors as a left preconditioner.  ``SolveInfo``
    always reports the TRUE residual.
    """
    matvec = _damped(matvec, ridge)
    view = ravel_view(matvec, b, batch_ndim)
    d = view.b.shape[-1]
    if d > MAX_DENSE_DIM:   # guard BEFORE the d-matvec dense materialization
        raise ValueError(
            f"dense_gmres materializes dense systems; d={d} exceeds "
            f"MAX_DENSE_DIM={MAX_DENSE_DIM} — use method='gmres' instead")
    A, _ = materialize_batched(matvec, b, batch_ndim, view=view)

    def dense_mv(vf):                                   # (B, d) -> (B, d)
        return jnp.einsum("bij,bj->bi", A, vf)

    # "jacobi" reads the diagonal straight off the materialized operator
    # (no extra probing); validation and the safe-diagonal threshold live
    # in _resolve_precond/jacobi_preconditioner, shared with all solvers.
    M_tree = _resolve_precond(
        precond, matvec, b, batch_ndim,
        diag=view.to_tree(jnp.diagonal(A, axis1=-2, axis2=-1)),
        materialized=A if view.batched else A[0])
    if M_tree is None:
        M_flat = None
    elif view.batched:
        M_flat = lambda vf: jax.vmap(_ravel1)(M_tree(view.to_tree(vf)))
    else:
        M_flat = lambda vf: _ravel1(M_tree(view.to_tree(vf)))[None]

    mv = dense_mv if M_flat is None else (lambda vf: M_flat(dense_mv(vf)))
    b_flat = view.b if M_flat is None else M_flat(view.b)
    x0 = _flat_init(init, view.b, batch_ndim)
    x, rn, it, atol = _gmres_flat(mv, b_flat, x0, tol=tol, restart=restart,
                                  maxiter=maxiter)
    x_tree = view.to_tree(x)
    if not return_info:
        return x_tree
    if M_flat is not None:   # report the true residual, not M(b - A x)
        rn = jnp.linalg.norm(view.b - dense_mv(x), axis=-1)
        atol = jnp.maximum(tol * jnp.linalg.norm(view.b, axis=-1), 1e-30)
    info = SolveInfo(iterations=it, residual=rn, converged=rn <= atol)
    if batch_ndim == 0:
        info = _squeeze_info(info)
    return x_tree, info


# ---------------------------------------------------------------------------
# Direct and Neumann
# ---------------------------------------------------------------------------

def solve_lu(matvec: Callable, b, *, init=None, tol: float = 1e-6,
             ridge: float = 0.0, return_info: bool = False,
             batch_ndim: int = 0, **_):
    """Materialize A and solve densely.  For small/d≤few-thousand systems."""
    del init
    matvec = _damped(matvec, ridge)
    A, view = materialize_batched(matvec, b, batch_ndim)
    x = jnp.linalg.solve(A, view.b[..., None])[..., 0]
    if return_info:
        rn = jnp.linalg.norm(view.b - jnp.einsum("bij,bj->bi", A, x), axis=-1)
        atol = jnp.maximum(tol * jnp.linalg.norm(view.b, axis=-1), 1e-30)
        it = jnp.zeros_like(rn, dtype=jnp.int32)
        # rn <= atol is False for NaN residuals (singular A) — reported honestly
        info = SolveInfo(iterations=it, residual=rn, converged=rn <= atol)
        if batch_ndim == 0:
            info = _squeeze_info(info)
        return view.to_tree(x), info
    return view.to_tree(x)


def solve_neumann(matvec: Callable, b, *, init=None, maxiter: int = 10,
                  tol: float = 0.0, ridge: float = 0.0,
                  return_info: bool = False, batch_ndim: int = 0, **_):
    """Approximate (I - M)⁻¹ b ≈ Σ_{k<K} Mᵏ b where matvec(v) = v - M v.

    I.e. interprets ``matvec`` as A = I - M and truncates the Neumann series.
    Matches "Jacobian-free backprop" / phantom-gradient style approximations.
    ``ridge`` damps A (shrinks M, improving contraction) like the other
    solvers.  Vmap-safe: instances whose series term drops below tolerance
    freeze while stragglers keep summing, and the loop exits early once the
    whole batch is done (so the engine-level maxiter is a cap, not a cost).
    The local default ``tol=0`` preserves the classic fixed-K truncation;
    ``solve()`` forwards its tol, making engine-routed calls tol-aware.
    """
    del init
    nb = batch_ndim
    matvec = _damped(matvec, ridge)
    atol = jnp.maximum(tol * _tree_l2(b, nb), 1e-30)

    def mfun(v):  # M v = v - A v
        return _tree_sub(v, matvec(v))

    it0 = jnp.zeros_like(atol, dtype=jnp.int32)
    done0 = _tree_l2(b, nb) <= atol   # b = first series term

    def cond(state):
        _, _, _, k, done = state
        return jnp.logical_and(k < maxiter, jnp.logical_not(jnp.all(done)))

    def body(state):
        acc, term, it, k, done = state
        term1 = mfun(term)
        acc = _tree_freeze(done, acc, _tree_add(acc, term1), nb)
        term = _tree_freeze(done, term, term1, nb)
        it = it + jnp.logical_not(done)
        done = jnp.logical_or(done, _tree_l2(term, nb) <= atol)
        return acc, term, it, k + 1, done

    acc, _, it, _, _ = lax.while_loop(cond, body, (b, b, it0, 0, done0))
    if return_info:
        rn = _tree_l2(_tree_sub(b, matvec(acc)), nb)
        # rn <= atol is False for NaN/diverged series — reported honestly
        info = SolveInfo(iterations=it, residual=rn, converged=rn <= atol)
        return acc, info
    return acc


# ---------------------------------------------------------------------------
# approximate backward application (fixed matvec budget, no convergence loop)
# ---------------------------------------------------------------------------

BACKWARD_MODES = ("exact", "one_step", "neumann_k", "jacobian_free")


def approx_matvec_count(backward: str, backward_iters: int = 8) -> int:
    """Operator applications an approximate backward mode spends (host int).

    ``jacobian_free`` → 0, ``one_step`` → 1, ``neumann_k`` → k.  The error
    estimate, when requested, costs one extra matvec on top of this.
    """
    if backward == "jacobian_free":
        return 0
    if backward == "one_step":
        return 1
    if backward == "neumann_k":
        return int(backward_iters)
    raise ValueError(f"unknown approximate backward mode {backward!r}; "
                     f"expected one of {BACKWARD_MODES[1:]}")


def approx_inverse_apply(matvec: Callable, b, *, backward: str,
                         backward_iters: int = 8, ridge: float = 0.0,
                         precond=None, batch_ndim: int = 0, tol: float = 1e-6,
                         error_estimate: bool = True,
                         return_info: bool = False):
    """Apply an O(k)-matvec polynomial approximation of ``A⁻¹`` to ``b``.

    The cheap-backward counterpart of ``route_solve``: instead of iterating a
    solver to convergence, spend a *fixed* matvec budget — trip counts are
    static, so jit/vmap shapes never depend on conditioning:

    - ``"jacobian_free"``: ``u = b`` (0 matvecs — the Bolte et al. 2023 limit
      where ``A ≈ I``; any ``precond`` is ignored by construction).
    - ``"one_step"``: one preconditioned Richardson step from ``u₀ = M⁻¹b``,
      i.e. ``u = u₀ + M⁻¹(b − A u₀)`` (1 matvec).  Unpreconditioned this is
      the hand formula ``u = 2b − A b``.
    - ``"neumann_k"``: exactly ``k = backward_iters`` preconditioned
      Richardson steps ``u ← u + M⁻¹(b − A u)`` from ``u₀ = M⁻¹b`` (k
      matvecs, one ``fori_loop`` with a static trip count; contrast
      ``solve_neumann``'s tolerance-masked loop).  Unpreconditioned this
      is the truncated Neumann series ``Σ_{j≤k} (I − A)ʲ b``, which
      converges iff ``‖I − A‖ < 1`` — true for contractive fixed-point
      declarations (``A = I − ∂T``), NOT for stationarity declarations
      (``A = −H`` with ``H ⪰ 0``), where ``precond="jacobi"`` restores
      ``‖I − M⁻¹A‖ < 1`` for diagonally dominant Hessians.

    ``ridge`` damps ``A`` exactly as in the iterative solvers.  With
    ``return_info=True`` returns ``(u, SolveInfo)`` where ``iterations`` is
    the matvec budget spent and — when ``error_estimate=True`` — the
    ``hypergrad_error_estimate`` field carries the relative residual
    ``‖b − A u‖ / ‖b‖`` (one extra matvec, the honesty contract of the
    approximate modes).  For a contraction ``‖I − A‖ = ρ`` the neumann_k
    estimate is exactly ``ρ`` to the power ``k+1``-ish, hence monotone
    decreasing in ``k``.
    """
    if backward == "exact" or backward not in BACKWARD_MODES:
        raise ValueError(f"approx_inverse_apply handles {BACKWARD_MODES[1:]}; "
                         f"got backward={backward!r} (route 'exact' through "
                         "route_solve)")
    nb = batch_ndim
    mv = _damped(matvec, ridge)
    if backward == "jacobian_free":
        u = b
    elif backward == "one_step":
        M = _resolve_precond(precond, mv, b, nb)
        if M is None:
            u = _tree_sub(_tree_scale(b, 2.0, nb), mv(b))
        else:
            u0 = M(b)
            u = _tree_add(u0, M(_tree_sub(b, mv(u0))), batch_ndim=nb)
    else:  # neumann_k
        k = int(backward_iters)
        if k < 1:
            raise ValueError("backward='neumann_k' needs backward_iters >= 1")
        M = _resolve_precond(precond, mv, b, nb)

        if M is None:
            def body(_, u):
                return _tree_add(u, _tree_sub(b, mv(u)), batch_ndim=nb)
            u0 = b
        else:
            def body(_, u):
                return _tree_add(u, M(_tree_sub(b, mv(u))), batch_ndim=nb)
            u0 = M(b)

        u = lax.fori_loop(0, k, body, u0)

    if not return_info:
        return u
    bn = _tree_l2(b, nb)
    spent = jnp.full(bn.shape, approx_matvec_count(backward, backward_iters),
                     dtype=jnp.int32)
    if error_estimate:
        rn = _tree_l2(_tree_sub(b, mv(u)), nb)
        est = rn / jnp.maximum(bn, 1e-30)
        info = SolveInfo(iterations=spent, residual=rn,
                         converged=rn <= jnp.maximum(tol * bn, 1e-30),
                         hypergrad_error_estimate=est)
    else:
        rn = jnp.full(bn.shape, jnp.nan, dtype=bn.dtype)
        info = SolveInfo(iterations=spent, residual=rn,
                         converged=jnp.zeros(bn.shape, dtype=bool))
    if obs_events.observing():
        tags = _solve_event_tags(f"approx_{backward}", matvec, b,
                                 {"batch_ndim": nb})
        extra = ({"hypergrad_error_estimate": info.hypergrad_error_estimate}
                 if info.hypergrad_error_estimate is not None else {})
        obs_events.jit_event("solve", tags, iterations=info.iterations,
                             residual=info.residual,
                             converged=info.converged, **extra)
    return u, info


# ---------------------------------------------------------------------------
# Pallas fused batched-CG (dense small-system regime)
# ---------------------------------------------------------------------------

MAX_DENSE_DIM = 512


def solve_pallas_cg(matvec: Callable, b, *, init=None, tol: float = 1e-6,
                    maxiter: int = 1000, ridge: float = 0.0, precond=None,
                    return_info: bool = False, batch_ndim: int = 0,
                    interpret: Optional[bool] = None, block_b="auto"):
    """Materialize per-instance operators and run the fused Pallas CG kernel.

    Dense small-system regime (d ≤ ``MAX_DENSE_DIM``) that dominates
    hyperopt and DEQ workloads: the whole batch of (d × d) systems iterates
    inside one kernel, VMEM-resident, with per-instance convergence masks.

    ``block_b`` defaults to ``"auto"``: the tile height resolves through
    the autotuning cache (``analysis.autotune.choose_block_b``) per
    ``(backend, B, d, dtype)``, falling back to the legacy schedule when
    the regime was never swept — so the solve service's bucket dispatch
    and ``IterativeSolver``'s backward solve ride tuned schedules with no
    caller changes.  Pass an int to pin the schedule by hand.
    """
    if init is not None:
        raise ValueError("pallas_cg always starts from zero; warm starts "
                         "are not supported — use method='cg' instead")
    if precond is not None:
        raise ValueError("pallas_cg does not support preconditioning")
    from repro.kernels.batched_cg.ops import batched_cg  # lazy: avoid cycle

    matvec = _damped(matvec, ridge)
    view = ravel_view(matvec, b, batch_ndim)
    d = view.b.shape[-1]
    if d > MAX_DENSE_DIM:   # guard BEFORE the d-matvec dense materialization
        raise ValueError(
            f"pallas_cg materializes dense systems; d={d} exceeds "
            f"MAX_DENSE_DIM={MAX_DENSE_DIM} — use a matrix-free solver")
    A, _ = materialize_batched(matvec, b, batch_ndim, view=view)
    x = batched_cg(A, view.b, tol=tol, maxiter=maxiter, block_b=block_b,
                   interpret=interpret)
    if return_info:
        r = view.b - jnp.einsum("bij,bj->bi", A, x)
        rn = jnp.linalg.norm(r, axis=-1)
        atol = jnp.maximum(tol * jnp.linalg.norm(view.b, axis=-1), 1e-30)
        info = SolveInfo(iterations=jnp.full_like(rn, -1, dtype=jnp.int32),
                         residual=rn, converged=rn <= atol)
        if batch_ndim == 0:
            info = _squeeze_info(info)
        return view.to_tree(x), info
    return view.to_tree(x)


# ---------------------------------------------------------------------------
# SolverSpec registry and the uniform entry point
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """A registered linear solver and its dispatch-relevant properties."""
    name: str
    fn: Callable
    symmetric_only: bool = False     # requires A symmetric (PSD)
    matrix_free: bool = True         # False: materializes A densely
    supports_precond: bool = False
    description: str = ""


_REGISTRY: dict = {}


def _solve_event_tags(name, matvec, b, kw) -> dict:
    """Trace-time static tags for a solve event: solver, B, d, dtype (+
    mesh_size for mesh-placed operators).  Shapes/dtypes are read off the
    rhs tracers, so this is jit/vmap-safe."""
    nb = kw.get("batch_ndim")
    if nb is None and isinstance(matvec, LinearOperator):
        nb = matvec.batch_ndim
    nb = int(nb or 0)
    leaves = jax.tree_util.tree_leaves(b)
    B, total, dtype = 1, 0, ""
    for leaf in leaves:
        shape = tuple(getattr(leaf, "shape", ()))
        size = 1
        for s in shape:
            size *= int(s)
        total += size
    if leaves:
        first = getattr(leaves[0], "shape", ())
        dtype = str(getattr(leaves[0], "dtype", ""))
        if nb >= 1 and len(first) >= 1:
            B = int(first[0])
    tags = {"solver": str(name), "B": B, "d": total // max(B, 1),
            "dtype": dtype}
    if getattr(matvec, "is_sharded", False):
        tags["mesh_size"] = int(matvec.mesh.size)
    return tags


def _observed(name: str, fn: Callable) -> Callable:
    """Wrap a registry solver with jit-safe solve telemetry.

    The wrapper is the instrumentation seam for *every* registry solver:
    with observability off (the default) it is a pure pass-through, so
    traced programs are bit-identical to an uninstrumented build.  With
    ``observe(enabled=True)`` at trace time it forces ``return_info=True``
    on the underlying solver and stages the ``solve_start``/``solve``
    event pair carrying the per-instance diagnostics as ONE
    ``jax.debug.callback`` (host callbacks dominate enabled-mode cost),
    returning exactly what the caller asked for.  Because the seam sits
    *outside* the sharded solvers' ``shard_map``, the callback fires once
    per compiled program execution — not once per device.
    """
    @functools.wraps(fn)
    def wrapper(matvec, b, **kw):
        if not obs_events.observing():
            return fn(matvec, b, **kw)
        tags = _solve_event_tags(name, matvec, b, kw)
        want_info = bool(kw.pop("return_info", False))
        try:
            x, info = fn(matvec, b, return_info=True, **kw)
        except TypeError:
            # a custom-registered solver outside the return_info contract:
            # announce the solve, run it uninstrumented rather than fail
            obs_events.jit_event("solve_start", tags)
            if want_info:
                return fn(matvec, b, return_info=True, **kw)
            return fn(matvec, b, **kw)
        extra = {}
        if getattr(info, "hypergrad_error_estimate", None) is not None:
            extra["hypergrad_error_estimate"] = info.hypergrad_error_estimate
        obs_events.jit_event_pair("solve_start", "solve", tags,
                                  iterations=info.iterations,
                                  residual=info.residual,
                                  converged=info.converged, **extra)
        return (x, info) if want_info else x

    wrapper.__wrapped__ = fn
    return wrapper


def register_solver(name: str, fn: Callable, **attrs) -> SolverSpec:
    """Register (or override) a solver under ``name`` in the global registry.

    The stored ``fn`` is wrapped with the jit-safe telemetry seam (see
    ``_observed``) — a pure pass-through unless ``repro.observability``
    is enabled at trace time.
    """
    spec = SolverSpec(name=name, fn=_observed(name, fn), **attrs)
    _REGISTRY[name] = spec
    return spec


def get_spec(name: str) -> SolverSpec:
    """Look up a registered ``SolverSpec`` by name (ValueError if absent)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown linear solver {name!r}; "
                         f"available: {available_solvers()}") from None


def available_solvers():
    """Sorted names of every solver currently in the registry."""
    return sorted(_REGISTRY)


def get_solver(name_or_fn):
    """Resolve a registry name (or pass through a callable) to a solver fn.

    Returns the function as *registered*: the registry stores solvers
    behind the jit-safe telemetry seam (``_observed``), which is a
    routing detail — it is unwrapped here, so
    ``get_solver(name) is fn`` holds after ``register_solver(name, fn)``.
    """
    if callable(name_or_fn):
        return name_or_fn
    fn = get_spec(name_or_fn).fn
    return getattr(fn, "__wrapped__", fn)


def solver_is_symmetric(name_or_fn) -> bool:
    """True when the routed solver asserts a symmetric operator.

    The implicit-diff layer consults this when it *constructs* its
    ``JacobianOperator``: choosing a symmetric-only solver (``cg``,
    ``pallas_cg``) certifies ``A = Aᵀ``, so the operator is built with
    ``symmetric=True`` and the cotangent system ``Aᵀ u = v`` reuses the
    forward matvec (``A.T is A``).  Downstream, everything reads the flag
    off the operator, not off this hook.  Custom callables conservatively
    report False (general A).
    """
    if callable(name_or_fn):
        return False
    return get_spec(name_or_fn).symmetric_only


def _check_operator_routing(spec: SolverSpec, A) -> None:
    """Symmetric-only solvers must never receive an operator that declares
    itself nonsymmetric (an undeclared ``symmetric=None`` trusts the
    caller's solver choice, as matvec closures always had to).  The error
    names BOTH sides of the mismatch — the requested solver and the
    operator's declared flags — so auto-routing failures point at the
    declaration to fix."""
    if (isinstance(A, LinearOperator) and spec.symmetric_only
            and A.symmetric is False):
        raise ValueError(
            f"requested solver {spec.name!r} is symmetric-only, but the "
            f"operator {A!r} declares symmetric={A.symmetric} "
            f"(positive_definite={A.positive_definite}) — route a general "
            "solver (gmres/bicgstab/normal_cg/dense_gmres) instead, or fix "
            "the operator's declared flags if it really is symmetric")


def _resolve_auto(A, example, precond=None, init=None) -> str:
    """Pick a registry solver from operator structure + system size.

    Sharded operands dispatch first: a ``ShardedOperator`` (carrying a mesh
    + PartitionSpecs) routes to the distributed variants — ``sharded_cg``
    for declared-SPD, ``sharded_dense_gmres`` for small nonsymmetric
    systems whose instance dims stay device-local (each shard materializes
    its own batch slice), ``sharded_normal_cg`` otherwise — so every solve
    a mesh-placed operator reaches runs inside ``shard_map`` with no host
    gather.

    Sharded routing is COST-GATED (PR 9): the structural candidate above
    only wins when ``analysis.autotune.should_shard`` predicts it beats
    the single-device path at the operand's mesh size — measured tuning
    entries first, roofline model cold (which preserves the structural
    choice for batch sharding until measurements prove a regime loses).
    A refused regime falls back to the MATRIX-FREE classic solver
    (``cg``/``normal_cg``): the operator's matvec still runs its own
    ``shard_map``, but the solve loop stays out of the losing sharded
    dispatch.  Materializing fallbacks are never chosen — densifying a
    mesh-placed operator yields per-shard pieces, not the global stack.

    Single-device: the dense small-system regime (d ≤ ``MAX_DENSE_DIM``)
    auto-materializes: SPD operators take the fused ``pallas_cg`` kernel
    (falling back to the batched ``dense_gmres`` when a preconditioner or a
    warm start is requested — ``pallas_cg`` supports neither), everything
    else ``dense_gmres``.  Above the crossover the solve stays matrix-free:
    ``cg`` only for declared-SPD operators (symmetric alone is not enough —
    CG on a symmetric *indefinite* system can report convergence with a
    wrong answer), ``normal_cg`` (general, transpose-capable) otherwise.
    ``example`` is one instance-shaped right-hand side (sizes the system).
    """
    spd = A.positive_definite if isinstance(A, LinearOperator) else False
    d = _ravel1(example).shape[0]
    if getattr(A, "is_sharded", False):
        from repro.analysis import autotune  # lazy: avoid import cycle
        Bn, _, dtype = autotune.operator_regime(A)
        plain = precond is None and init is None
        if autotune.should_shard(Bn, d, mesh_size=int(A.mesh.size),
                                 instance_sharded=A.instance_sharded,
                                 spd=spd, dtype=dtype, precond=precond,
                                 plain=plain):
            if spd:
                return "sharded_cg"
            if d <= MAX_DENSE_DIM and not A.instance_sharded:
                return "sharded_dense_gmres"
            return "sharded_normal_cg"
        return "cg" if spd else "normal_cg"
    if d <= MAX_DENSE_DIM:
        plain = precond is None and init is None
        return "pallas_cg" if spd and plain else "dense_gmres"
    return "cg" if spd else "normal_cg"


# A mesh-placed operator upgrades the classic method names to their
# distributed variants, so ``solve="cg"`` in an ``ImplicitDiffSpec`` (which
# also certifies symmetry — see ``solver_is_symmetric``) transparently runs
# the sharded solve once placement is attached.  The single-device
# MATERIALIZING solvers also upgrade (``pallas_cg`` → ``sharded_cg``,
# ``lu`` → ``sharded_dense_gmres``): densifying a mesh-placed operator
# outside shard_map would gather the global (B, d, d) stack to one device,
# which this subsystem exists to avoid.  Matrix-free general solvers
# (gmres/bicgstab/neumann) keep their names: their matvecs already run
# under shard_map through the operator, with reductions partitioned by XLA.
_SHARDED_UPGRADE = {"cg": "sharded_cg", "normal_cg": "sharded_normal_cg",
                    "dense_gmres": "sharded_dense_gmres",
                    "pallas_cg": "sharded_cg",
                    "lu": "sharded_dense_gmres"}


def _upgrade_for_sharded(method, matvec, *, precond=None):
    """Upgrade a classic solver name for a mesh-placed operand — when the
    cost model approves the operand's mesh size.

    Matrix-free upgrades (``cg``/``normal_cg``) are COST-GATED through
    ``analysis.autotune.should_shard``: with measured evidence that this
    (B, d, mesh) regime loses to the single-device path, the classic name
    is kept (its matvec still runs under the operator's ``shard_map``;
    only the solve-loop dispatch stays single-device).  MATERIALIZING
    names (``pallas_cg``/``lu``/``dense_gmres``) always upgrade: their
    single-device forms would densify a mesh-placed operator into
    per-shard pieces, so the sharded variant is a correctness matter, not
    a tuning choice.  ``mesh.size == 1`` always upgrades (a 1-device mesh
    IS the single-device path, under the declared placement).
    """
    if callable(method) or not getattr(matvec, "is_sharded", False):
        return method
    target = _SHARDED_UPGRADE.get(method)
    if target is None:
        return method
    spec = _REGISTRY.get(method)
    if spec is not None and not spec.matrix_free:
        return target
    from repro.analysis import autotune  # lazy: avoid import cycle
    Bn, d, dtype = autotune.operator_regime(matvec)
    if autotune.should_shard(Bn, d, mesh_size=int(matvec.mesh.size),
                             instance_sharded=matvec.instance_sharded,
                             spd=bool(spec and spec.symmetric_only),
                             dtype=dtype, precond=precond):
        return target
    return method


def route_solve(solve, matvec, b, *, tol: float = 1e-6, maxiter: int = 1000,
                ridge: float = 0.0, precond=None, init=None,
                return_info: bool = False):
    """Route one instance-shaped solve to a registry solver or a callable.

    The single dispatch point the differentiation layer calls for both the
    tangent (``A dx = b``) and cotangent (``Aᵀ u = v``) systems — ``solve``
    is a registry name, ``"auto"``, or a bare callable ``fn(matvec, b, tol,
    maxiter, ridge)``.  ``matvec`` may be a ``LinearOperator``: its
    symmetry flag is validated against the routed solver (symmetric-only
    solvers never receive a declared-nonsymmetric operator), ``"auto"``
    dispatches on its structure (dense small systems auto-materialize — see
    ``_resolve_auto``), and ``"jacobi"``/``"block_jacobi"`` preconditioners
    derive from ``operator.diagonal()`` instead of probing.  Mirrors
    ``solve()``'s contract: ``precond`` requires a registry solver that
    supports it and is never silently dropped.  Vmap-safe like every
    registry solver: batched tracers dispatch ONE masked solve for the
    whole batch.

    A *batch-aware* operator (``batch_ndim == 1``, e.g. a stacked
    ``DenseOperator`` the solve service dispatches per bucket) routes the
    whole batch as ONE masked solve — registry solvers receive
    ``batch_ndim=1`` and ``b``/``init`` carry the batch axis on every leaf.

    ``init`` warm-starts the routed solver (``"auto"`` then steers off
    ``pallas_cg``, which always starts from zero); ``return_info`` also
    returns the per-instance ``SolveInfo``.  Both require a registry
    solver — custom callables own their initialization and diagnostics.
    """
    requested = solve if isinstance(solve, str) else getattr(
        solve, "__name__", "custom")
    if solve == "auto":
        # _resolve_auto sizes the system from ONE instance: batch-aware
        # operators (batch_ndim == 1, e.g. sharded batched systems) carry
        # a leading batch axis on b that must not inflate d
        example = b
        if isinstance(matvec, LinearOperator) and matvec.batch_ndim == 1:
            example = jax.tree_util.tree_map(lambda l: l[0], b)
        solve = _resolve_auto(matvec, example, precond, init)
    solve = _upgrade_for_sharded(solve, matvec, precond=precond)
    if obs_events.observing():
        routed = solve if isinstance(solve, str) else getattr(
            solve, "__name__", "custom")
        obs_events.emit("dispatch",
                        dict(_solve_event_tags(routed, matvec, b, {}),
                             requested=requested))
    if callable(solve):
        if precond is not None:
            raise ValueError("precond requires a registry solver name; "
                             "bake it into the custom solve callable instead")
        if init is not None or return_info:
            raise ValueError("init/return_info require a registry solver "
                             "name; custom solve callables own their "
                             "initialization and diagnostics")
        return solve(matvec, b, tol=tol, maxiter=maxiter, ridge=ridge)
    spec = get_spec(solve)
    _check_operator_routing(spec, matvec)
    if precond is not None and not spec.supports_precond:
        raise ValueError(f"solver {spec.name!r} does not support "
                         "preconditioning; see SolverSpec.supports_precond")
    kwargs = dict(tol=tol, maxiter=maxiter, ridge=ridge)
    if precond is not None:
        kwargs["precond"] = precond
    if init is not None:
        kwargs["init"] = init
    if return_info:
        kwargs["return_info"] = True
    if isinstance(matvec, LinearOperator) and matvec.batch_ndim == 1 \
            and not spec.name.startswith("sharded_"):
        # sharded SOLVERS read batchedness off the operator themselves
        # (inside shard_map); every other batch-aware operator — including
        # a mesh-placed one whose sharded upgrade the cost model refused —
        # gets the whole batch dispatched as ONE masked solve
        kwargs["batch_ndim"] = 1
    return spec.fn(matvec, b, **kwargs)


register_solver("cg", solve_cg, symmetric_only=True, supports_precond=True,
                description="conjugate gradient (A symmetric PSD)")
register_solver("normal_cg", solve_normal_cg, supports_precond=True,
                description="CG on the normal equations (general A)")
register_solver("bicgstab", solve_bicgstab, supports_precond=True,
                description="BiCGSTAB (general square A)")
register_solver("gmres", solve_gmres, supports_precond=True,
                description="restarted GMRES (general square A)")
register_solver("dense_gmres", solve_dense_gmres, supports_precond=True,
                matrix_free=False,
                description="batched dense GMRES (materializes A; "
                            "nonsymmetric, d<=512)")
register_solver("lu", solve_lu, matrix_free=False,
                description="dense direct solve (materializes A)")
register_solver("neumann", solve_neumann,
                description="truncated Neumann series for I - M")
register_solver("pallas_cg", solve_pallas_cg, symmetric_only=True,
                matrix_free=False,
                description="fused Pallas batched-CG kernel (dense, d<=512)")


# --- distributed variants (impl in repro.distributed.sharded_operators) ----
# Registered here with lazy stubs so the registry surface is deterministic
# (importing repro.core never pulls the distributed layer; the import cycle
# linear_solve -> sharded_operators -> linear_solve resolves because this
# side is deferred to call time).  They require a ShardedOperator operand —
# the whole masked solve loop runs inside one shard_map on its mesh.

def solve_sharded_cg(matvec, b, **kw):
    """Distributed CG (SPD): whole masked loop under ``shard_map``; dot
    products go through the operator's ``psum`` reduction hook."""
    from repro.distributed import sharded_operators as dso
    return dso.sharded_solve_cg(matvec, b, **kw)


def solve_sharded_normal_cg(matvec, b, **kw):
    """Distributed CG on the normal equations (general square A)."""
    from repro.distributed import sharded_operators as dso
    return dso.sharded_solve_normal_cg(matvec, b, **kw)


def solve_sharded_dense_gmres(matvec, b, **kw):
    """Distributed dense GMRES: each shard materializes + solves its batch
    slice (batch sharding only)."""
    from repro.distributed import sharded_operators as dso
    return dso.sharded_solve_dense_gmres(matvec, b, **kw)


register_solver("sharded_cg", solve_sharded_cg, symmetric_only=True,
                supports_precond=True,
                description="distributed CG under shard_map "
                            "(ShardedOperator; A symmetric PSD)")
register_solver("sharded_normal_cg", solve_sharded_normal_cg,
                supports_precond=True,
                description="distributed normal-equations CG under "
                            "shard_map (ShardedOperator; general A)")
register_solver("sharded_dense_gmres", solve_sharded_dense_gmres,
                supports_precond=True, matrix_free=False,
                description="per-shard dense GMRES under shard_map "
                            "(ShardedOperator; batch sharding, d<=512)")

def __getattr__(name):
    # Back-compat: the pre-registry name -> fn mapping, computed live so
    # register_solver() stays visible.  Extend via register_solver, not by
    # mutating this dict (mutations are discarded).
    if name == "SOLVERS":
        return {n: spec.fn for n, spec in _REGISTRY.items()}
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def solve(matvec: Callable, b, *, method="cg", batch_axes: Optional[int] = None,
          precond=None, tol: float = 1e-6, maxiter: int = 1000,
          ridge: float = 0.0, init=None, return_info: bool = False,
          **solver_kwargs):
    """Uniform entry point of the batched linear-solve engine.

    Args:
      matvec: linear operator — a ``LinearOperator`` or a matvec closure.
        Unbatched: maps an instance pytree to an instance pytree.  With
        ``batch_axes`` set: maps *batched* pytrees (every leaf carrying the
        batch axis) to batched pytrees — i.e. the block-diagonal operator
        over all instances, applied at once.  A batch-aware operator
        (``batch_ndim == 1``) implies ``batch_axes=0`` automatically, and
        its symmetry/definiteness flags drive validation, ``"auto"``
        dispatch, and preconditioner derivation.
      b: right-hand side pytree (batched along ``batch_axes`` if set).
      method: registry name (see ``available_solvers()``), ``"auto"``
        (structure-driven dispatch: dense small systems auto-materialize to
        ``pallas_cg``/``dense_gmres``, large ones stay matrix-free), or a
        solver callable ``fn(matvec, b, **kw)``.  Callables cannot be
        combined with ``batch_axes`` (they would need to handle batching
        themselves); a batch-aware *operator* passes to a callable as-is,
        batching included.
      batch_axes: ``None`` for a single system, or an int axis carried by
        every leaf of ``b``/``init`` along which independent systems stack.
        The whole batch is solved by ONE masked while_loop: converged
        instances freeze while stragglers iterate.
      precond: ``None``, a callable v ↦ M⁻¹v, ``"jacobi"`` (diagonal — from
        ``operator.diagonal()`` when available, else probing), or
        ``"block_jacobi"`` (``LinearOperator`` only; blocks from the
        domain's pytree leaves or a ``BlockDiagonal``'s blocks).
      tol / maxiter / ridge / init: the usual solver controls.
      return_info: also return a ``SolveInfo`` with per-instance iteration
        counts, residuals and convergence flags.
    """
    # a callable method takes the operator as-is (it owns batching); the
    # batch-axes implication below is for registry solvers only
    if isinstance(matvec, LinearOperator) and not callable(method):
        if batch_axes is None and matvec.batch_ndim == 1:
            batch_axes = 0
        expected = 0 if batch_axes is None else 1
        if matvec.batch_ndim != expected or batch_axes not in (None, 0):
            raise ValueError(
                f"operator batch_ndim={matvec.batch_ndim} is incompatible "
                f"with batch_axes={batch_axes}; batch-aware operators carry "
                "their batch on axis 0")
    if method == "auto":
        example = b
        if batch_axes is not None:
            example = jax.tree_util.tree_map(
                lambda l: jnp.take(l, 0, axis=int(batch_axes)), b)
        method = _resolve_auto(matvec, example, precond, init)
    method = _upgrade_for_sharded(method, matvec, precond=precond)
    if callable(method):
        if batch_axes is not None:
            raise ValueError("batch_axes requires a registry solver name; "
                             "custom callables must handle batching")
        if precond is not None or return_info:
            raise ValueError("precond/return_info require a registry solver "
                             "name; pass them to the callable directly")
        return method(matvec, b, tol=tol, maxiter=maxiter, ridge=ridge,
                      init=init, **solver_kwargs)

    spec = get_spec(method)
    _check_operator_routing(spec, matvec)
    if precond is not None and not spec.supports_precond:
        raise ValueError(f"solver {spec.name!r} does not support "
                         "preconditioning; see SolverSpec.supports_precond")
    if batch_axes is None:
        return spec.fn(matvec, b, init=init, tol=tol, maxiter=maxiter,
                       ridge=ridge, precond=precond,
                       return_info=return_info, **solver_kwargs)

    axis = int(batch_axes)
    if axis != 0:
        move_in = functools.partial(jax.tree_util.tree_map,
                                    lambda l: jnp.moveaxis(l, axis, 0))
        move_out = functools.partial(jax.tree_util.tree_map,
                                     lambda l: jnp.moveaxis(l, 0, axis))
        inner_mv = matvec
        matvec = lambda v: move_in(inner_mv(move_out(v)))
        b = move_in(b)
        init = move_in(init) if init is not None else None

    out = spec.fn(matvec, b, init=init, tol=tol, maxiter=maxiter,
                  ridge=ridge, precond=precond, return_info=return_info,
                  batch_ndim=1, **solver_kwargs)
    if axis == 0:
        return out
    if return_info:
        x, info = out
        return move_out(x), info
    return move_out(out)
