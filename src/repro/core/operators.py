"""Pytree-native linear operators: the shared matvec abstraction.

Every layer of the stack ultimately touches the same object — a linear map
``A`` over a pytree domain, accessed through matrix-vector products.  The
paper's implicit differentiation needs ``A = -∂₁F(x*, θ)`` only through
JVPs/VJPs; the solve engine needs ``matvec``/``rmatvec`` plus structure
(symmetry, definiteness, diagonal access) to pick solvers and
preconditioners; the dense kernels need ``materialize()``.  This module
makes that object first class so the knowledge travels with the operator
instead of through side channels:

  * ``LinearOperator`` — the protocol: ``matvec`` / ``rmatvec`` /
    ``transpose()`` (``.T``) / ``diagonal()`` / ``materialize()`` /
    ``ravel_view()``, plus ``symmetric`` / ``positive_definite`` flags and
    ``batch_ndim`` batch-axis awareness.
  * ``JacobianOperator`` — ``∂f(x)`` (optionally negated) of a pytree
    mapping, with ``matvec`` as a JVP and ``rmatvec`` as a VJP — exactly the
    operator implicit differentiation solves against (paper §2.1).
  * ``DenseOperator`` — an explicit ``(d, d)`` or batched ``(B, d, d)``
    matrix acting on pytrees through a ravel.
  * ``RidgeShifted`` — ``A + λI`` damping that preserves structure
    (diagonal/materialize shift; symmetry survives, definiteness improves).
  * ``BlockDiagonal`` — independent blocks over a tuple of sub-domains;
    the source of block-Jacobi preconditioners.
  * ``ComposedOperator`` — ``outer ∘ inner`` products (preconditioner
    wrapping).
  * ``ravel_view()`` — the single flat ``(B, d)`` view of a (possibly
    batched) operator, shared by every dense-regime solver.

Defaults are matrix-free: ``rmatvec`` falls back to ``jax.linear_transpose``
(or reuses ``matvec`` when the operator declares symmetry), and
``diagonal()`` / ``materialize()`` fall back to basis-vector probing
(``d`` matvecs, batched across instances).  Structured operators override
them with O(1) access, which is what lets the dense small-system regime
auto-materialize instead of probing.

Example::

    F = jax.grad(inner_objective)                  # optimality mapping
    A = JacobianOperator(lambda x: F(x, theta), x_star,
                         negate=True, symmetric=True)
    u = linear_solve.route_solve("cg", A.T, cotangent, tol=1e-8)
    M = jacobi_preconditioner_from(A)              # from A.diagonal()

This module is the bottom layer: it imports nothing from ``repro`` so the
solve registry, the diff API, the runtime and the kernels can all build on
it without cycles.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.flatten_util  # registers jax.flatten_util.ravel_pytree
import jax.numpy as jnp
import numpy as np


def _ravel1(tree) -> jnp.ndarray:
    """Ravel one instance-shaped pytree to a flat vector."""
    return jax.flatten_util.ravel_pytree(tree)[0]


def _tree_add_scaled(a, b, alpha):
    return jax.tree_util.tree_map(lambda x, y: x + alpha * y, a, b)


# ---------------------------------------------------------------------------
# flat (B, d) view of a (possibly batched) operator
# ---------------------------------------------------------------------------

class RavelView(NamedTuple):
    """Batched flat representation: leaves ``(B, ...)`` <-> matrix ``(B, d)``.

    Unbatched calls get a synthetic ``B = 1`` axis (``batched=False``), so
    the dense-regime solver cores run one uniform ``(B, d)`` layout.
    """
    mv: Callable          # (B, d) -> (B, d)
    b: jnp.ndarray        # (B, d) raveled right-hand side
    to_tree: Callable     # (B, d) -> (batched) pytree
    batched: bool         # whether the original call was batch_ndim == 1


def ravel_view(matvec: Callable, b, batch_ndim: int = 0) -> RavelView:
    """The single flat view of an operator: ``matvec`` on raveled vectors.

    ``matvec`` may be a bare callable or a ``LinearOperator`` (operators are
    callable).  ``b`` supplies the domain structure and the raveled
    right-hand side.
    """
    if batch_ndim == 0:
        b_flat, unravel = jax.flatten_util.ravel_pytree(b)

        def mv(vf):  # (1, d) -> (1, d)
            return _ravel1(matvec(unravel(vf[0])))[None]

        return RavelView(mv, b_flat[None], lambda xf: unravel(xf[0]), False)

    example = jax.tree_util.tree_map(lambda l: l[0], b)
    _, unravel = jax.flatten_util.ravel_pytree(example)
    b_flat = jax.vmap(_ravel1)(b)

    def mv(vf):  # (B, d) -> (B, d)
        return jax.vmap(_ravel1)(matvec(jax.vmap(unravel)(vf)))

    return RavelView(mv, b_flat, jax.vmap(unravel), True)


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------

class LinearOperator:
    """A linear map over a pytree domain, known through matvecs + metadata.

    Attributes:
      example: an instance of the domain pytree (batched leaves when
        ``batch_ndim == 1``) — the structural witness every ravel-based
        default needs.
      batch_ndim: 0 for one system, 1 when every leaf carries a leading
        batch axis of independent systems (the block-diagonal-over-batch
        operator the vmap-safe solvers consume).
      symmetric: ``True`` (A = Aᵀ per instance), ``False`` (known general),
        or ``None`` (unknown — routing trusts the caller's solver choice).
      positive_definite: ``True`` asserts per-instance SPD (enables CG-family
        routing and Cholesky-style consumers downstream).

    Subclasses implement ``matvec``; everything else has matrix-free
    defaults.  Operators are callable (``A(v) == A.matvec(v)``) so they pass
    anywhere a matvec closure is expected.

    ``is_sharded`` marks mesh-placed operators
    (``repro.distributed.sharded_operators.ShardedOperator``); the solve
    registry reads it to dispatch the distributed solver variants without
    this bottom layer importing the distribution layer.
    """

    is_sharded = False

    def __init__(self, example, *, batch_ndim: int = 0,
                 symmetric: Optional[bool] = None,
                 positive_definite: bool = False):
        if batch_ndim not in (0, 1):
            raise ValueError(f"batch_ndim must be 0 or 1, got {batch_ndim}")
        if positive_definite and symmetric is False:
            raise ValueError("positive_definite=True asserts symmetry; "
                             "symmetric=False contradicts it")
        self.example = example
        self.batch_ndim = batch_ndim
        self.symmetric = True if positive_definite else symmetric
        self.positive_definite = positive_definite

    # -- core ------------------------------------------------------------
    def matvec(self, v):
        """Apply the operator to ``v`` (pytree → pytree)."""
        raise NotImplementedError

    def __call__(self, v):
        return self.matvec(v)

    def rmatvec(self, v):
        """Aᵀ v.  Symmetric operators reuse ``matvec``; the general default
        builds the transpose via ``jax.linear_transpose``.  Built per call,
        NOT cached on the instance: operators are long-lived public API and
        a closure traced under one jit/vmap leaks its tracers into later
        calls under a different (or no) transformation."""
        if self.symmetric:
            return self.matvec(v)
        (out,) = jax.linear_transpose(self.matvec, self.example)(v)
        return out

    def transpose(self) -> "LinearOperator":
        """Aᵀ as an operator (``self`` when symmetry is declared)."""
        if self.symmetric:
            return self
        return TransposedOperator(self)

    @property
    def T(self) -> "LinearOperator":
        """The transposed operator (alias for ``transpose()``)."""
        return self.transpose()

    # -- structure access (matrix-free probing defaults) -----------------
    def ravel_view(self, b=None) -> RavelView:
        """The flat ``(B, d)`` view of this operator (``b`` defaults to the
        structural example)."""
        return ravel_view(self.matvec, self.example if b is None else b,
                          self.batch_ndim)

    def _instance_dim(self) -> int:
        example = self.example
        if self.batch_ndim:
            example = jax.tree_util.tree_map(lambda l: l[0], example)
        return _ravel1(example).shape[0]

    def diagonal(self):
        """diag(A) with the domain's structure (default: ``d`` probing
        matvecs, batched across instances)."""
        view = self.ravel_view()
        B, d = view.b.shape

        def entry(i):
            e = jnp.zeros(d, view.b.dtype).at[i].set(1.0)
            return view.mv(jnp.broadcast_to(e, (B, d)))[:, i]   # (B,)

        diag = jax.vmap(entry)(jnp.arange(d)).T                 # (B, d)
        return view.to_tree(diag)

    def materialize(self) -> jnp.ndarray:
        """The dense matrix: ``(d, d)`` unbatched, ``(B, d, d)`` batched.

        Default probes with basis vectors broadcast across the batch, so the
        cost is ``d`` matvecs regardless of batch size; structured operators
        (``DenseOperator``, ``RidgeShifted`` over one) override with O(1)
        access — the auto-materialization the dense solvers rely on.
        """
        view = self.ravel_view()
        B, d = view.b.shape

        def col(i):
            e = jnp.zeros(d, view.b.dtype).at[i].set(1.0)
            return view.mv(jnp.broadcast_to(e, (B, d)))         # (B, d)

        cols = jax.vmap(col)(jnp.arange(d))                     # (d, B, d)
        A = cols.transpose(1, 2, 0)                             # A[b][:, i]
        return A if self.batch_ndim else A[0]

    def raveled(self) -> "RaveledOperator":
        """This operator re-expressed on the raveled flat vector domain."""
        return RaveledOperator(self)

    def __repr__(self):
        flags = []
        if self.symmetric:
            flags.append("symmetric")
        if self.positive_definite:
            flags.append("PD")
        if self.batch_ndim:
            flags.append("batched")
        return (f"{type(self).__name__}(d={self._instance_dim()}"
                + (", " + ",".join(flags) if flags else "") + ")")


class TransposedOperator(LinearOperator):
    """Aᵀ of a wrapped operator; transpose of the transpose is the original.

    Assumes a square operator (domain == codomain structure), which is what
    every implicit-diff system in this codebase is.
    """

    def __init__(self, op: LinearOperator):
        super().__init__(op.example, batch_ndim=op.batch_ndim,
                         symmetric=op.symmetric,
                         positive_definite=op.positive_definite)
        self.op = op

    def matvec(self, v):
        """Apply ``Aᵀ`` (the base operator's ``rmatvec``)."""
        return self.op.rmatvec(v)

    def rmatvec(self, v):
        """Apply ``A`` (the base operator's ``matvec``)."""
        return self.op.matvec(v)

    def transpose(self) -> LinearOperator:
        """The original operator back."""
        return self.op


# ---------------------------------------------------------------------------
# concrete operators
# ---------------------------------------------------------------------------

class FunctionOperator(LinearOperator):
    """Adapt a matvec closure (and optional rmatvec) to the protocol.

    The bridge between the callable world and the operator world: routing
    layers wrap incoming closures with the flags they know, and everything
    downstream reads the flags off the operator.
    """

    def __init__(self, matvec: Callable, example, *,
                 rmatvec: Optional[Callable] = None, batch_ndim: int = 0,
                 symmetric: Optional[bool] = None,
                 positive_definite: bool = False):
        super().__init__(example, batch_ndim=batch_ndim, symmetric=symmetric,
                         positive_definite=positive_definite)
        self._matvec = matvec
        self._rmatvec = rmatvec

    def matvec(self, v):
        """Apply the wrapped matvec callable."""
        return self._matvec(v)

    def rmatvec(self, v):
        """Apply the adjoint (supplied, or derived via ``jax.vjp``)."""
        if self._rmatvec is not None:
            return self._rmatvec(v)
        return super().rmatvec(v)


class JacobianOperator(LinearOperator):
    """``∂f(x₀)`` (optionally negated) of a pytree mapping ``f``.

    ``matvec`` is a JVP at ``x₀`` and ``rmatvec`` a VJP (linearized once and
    cached), so the operator is exactly the paper's access pattern: the
    implicit system ``A dx = b`` with ``A = -∂₁F(x*, θ)`` is
    ``JacobianOperator(lambda x: F(x, *theta), x_star, negate=True)``.

    ``symmetric=True`` certifies ``A = Aᵀ`` — true whenever ``f`` is itself
    a gradient mapping (A is then a Hessian), which is what lets the
    cotangent system reuse the forward matvec.
    """

    def __init__(self, fun: Callable, primal, *, negate: bool = False,
                 batch_ndim: int = 0, symmetric: Optional[bool] = None,
                 positive_definite: bool = False):
        super().__init__(primal, batch_ndim=batch_ndim, symmetric=symmetric,
                         positive_definite=positive_definite)
        self.fun = fun
        self.primal = primal
        self.negate = negate
        self._sign = -1.0 if negate else 1.0

    def matvec(self, v):
        """Jacobian-vector product: JVP of the map at the primal point."""
        _, jv = jax.jvp(self.fun, (self.primal,), (v,))
        return jax.tree_util.tree_map(jnp.negative, jv) if self.negate else jv

    def rmatvec(self, v):
        """Vector-Jacobian product: VJP of the map at the primal point."""
        if self.symmetric:
            return self.matvec(v)
        # linearized per call (not cached on the instance): a VJP closure
        # traced under one transformation would leak its tracers into
        # calls made under another — see LinearOperator.rmatvec
        _, vjp_fun = jax.vjp(self.fun, self.primal)
        (out,) = vjp_fun(v)
        return jax.tree_util.tree_map(jnp.negative, out) if self.negate \
            else out


class SampledJacobianOperator(LinearOperator):
    """Monte-Carlo estimate of an expectation Jacobian ``E_b[∂₁f(x₀, b)]``.

    ``fun(x, batch)`` maps the domain pytree to itself for one minibatch
    (the canonical case: a minibatch gradient mapping, whose Jacobian is a
    minibatch Hessian); ``batches`` is a pytree whose leaves carry a
    leading resample axis of length ``k``.  ``matvec`` vmaps one JVP per
    batch and averages over the resample axis — ``k`` Hessian-vector
    products per application when ``fun`` is a gradient mapping.  The
    average is an unbiased estimate of the full-batch Jacobian-vector
    product whose variance shrinks like ``1/k``; when the ``k`` batches
    are equal-sized and partition the dataset, the average IS the
    full-batch product exactly (the stochastic implicit-diff layer's
    ``backward_data="full"`` escape hatch relies on this identity).

    ``negate`` flips the sign (the implicit system solves against
    ``A = -∂₁F``); ``symmetric=True`` certifies every per-batch Jacobian
    is symmetric (``fun`` a per-batch gradient mapping), which makes the
    mean symmetric and lets the cotangent solve reuse ``matvec``.
    """

    def __init__(self, fun: Callable, primal, batches, *,
                 negate: bool = False, batch_ndim: int = 0,
                 symmetric: Optional[bool] = None,
                 positive_definite: bool = False):
        super().__init__(primal, batch_ndim=batch_ndim, symmetric=symmetric,
                         positive_definite=positive_definite)
        leaves = jax.tree_util.tree_leaves(batches)
        if not leaves:
            raise ValueError("batches must be a non-empty pytree whose "
                             "leaves carry a leading resample axis")
        self.fun = fun
        self.primal = primal
        self.batches = batches
        self.negate = negate
        self.num_samples = int(leaves[0].shape[0])

    def _mean(self, stacked):
        sign = -1.0 if self.negate else 1.0
        return jax.tree_util.tree_map(
            lambda leaf: sign * jnp.mean(leaf, axis=0), stacked)

    def matvec(self, v):
        """Resample-averaged JVP of the per-batch map at the primal."""
        def one(batch):
            _, jv = jax.jvp(lambda x: self.fun(x, batch),
                            (self.primal,), (v,))
            return jv

        return self._mean(jax.vmap(one)(self.batches))

    def rmatvec(self, v):
        """Resample-averaged VJP (reuses ``matvec`` under declared
        symmetry).  Linearized per call, not cached on the instance — see
        ``LinearOperator.rmatvec``."""
        if self.symmetric:
            return self.matvec(v)

        def one(batch):
            _, vjp_fun = jax.vjp(lambda x: self.fun(x, batch), self.primal)
            return vjp_fun(v)[0]

        return self._mean(jax.vmap(one)(self.batches))


class DenseOperator(LinearOperator):
    """An explicit matrix ``(d, d)`` (or batched ``(B, d, d)``) acting on
    pytrees through a ravel.  ``diagonal``/``materialize`` are O(1)."""

    def __init__(self, A: jnp.ndarray, example=None, *,
                 symmetric: Optional[bool] = None,
                 positive_definite: bool = False):
        A = jnp.asarray(A)
        if A.ndim not in (2, 3) or A.shape[-1] != A.shape[-2]:
            raise ValueError(f"expected (d, d) or (B, d, d), got {A.shape}")
        batch_ndim = 1 if A.ndim == 3 else 0
        d = A.shape[-1]
        if example is None:
            example = jnp.zeros(A.shape[:-1], A.dtype)
        super().__init__(example, batch_ndim=batch_ndim, symmetric=symmetric,
                         positive_definite=positive_definite)
        self.A = A
        if self._instance_dim() != d:
            raise ValueError(f"example ravels to d={self._instance_dim()} "
                             f"but the matrix is {d}x{d}")

    def matvec(self, v):
        """Dense matvec ``A @ v`` (batched over ``batch_ndim``)."""
        view = ravel_view(lambda t: t, v, self.batch_ndim)  # structure only
        out = jnp.einsum("bij,bj->bi",
                         self.A if self.batch_ndim else self.A[None], view.b)
        return view.to_tree(out)

    def rmatvec(self, v):
        """Dense adjoint matvec ``Aᵀ @ u``."""
        if self.symmetric:
            return self.matvec(v)
        return DenseOperator(jnp.swapaxes(self.A, -1, -2),
                             self.example).matvec(v)

    def transpose(self) -> LinearOperator:
        """Operator over the transposed matrix (``self`` when symmetric)."""
        if self.symmetric:
            return self
        return DenseOperator(jnp.swapaxes(self.A, -1, -2), self.example,
                             symmetric=self.symmetric)

    def diagonal(self):
        """The matrix diagonal, O(1)."""
        diag = jnp.diagonal(self.A, axis1=-2, axis2=-1)
        view = ravel_view(lambda t: t, self.example, self.batch_ndim)
        return view.to_tree(diag if self.batch_ndim else diag[None])

    def materialize(self) -> jnp.ndarray:
        """The stored dense matrix itself, O(1)."""
        return self.A


class RidgeShifted(LinearOperator):
    """``A + λI``: the damping every solver applies, as structure-preserving
    composition — symmetry survives, definiteness survives (and ``λ > 0``
    turns a *PSD* operator SPD, but that promotion needs knowledge this
    wrapper doesn't have: symmetric alone does not rule out negative
    eigenvalues, so assert it explicitly via ``positive_definite=True`` when
    the base operator is known PSD).  ``diagonal``/``materialize`` shift
    instead of re-probing.
    """

    def __init__(self, op: LinearOperator, ridge: float, *,
                 positive_definite: Optional[bool] = None):
        pd = op.positive_definite if positive_definite is None \
            else positive_definite
        super().__init__(op.example, batch_ndim=op.batch_ndim,
                         symmetric=op.symmetric, positive_definite=pd)
        self.op = op
        self.ridge = ridge

    def matvec(self, v):
        """Apply ``A + ridge·I``."""
        return _tree_add_scaled(self.op.matvec(v), v, self.ridge)

    def rmatvec(self, v):
        """Apply ``(A + ridge·I)ᵀ``."""
        return _tree_add_scaled(self.op.rmatvec(v), v, self.ridge)

    def transpose(self) -> LinearOperator:
        """Ridge shift of the transposed base operator."""
        if self.symmetric:
            return self
        return RidgeShifted(self.op.transpose(), self.ridge,
                            positive_definite=self.positive_definite)

    def diagonal(self):
        """Base diagonal plus ``ridge``."""
        return jax.tree_util.tree_map(lambda dg: dg + self.ridge,
                                      self.op.diagonal())

    def materialize(self) -> jnp.ndarray:
        """Base matrix plus ``ridge·I``."""
        A = self.op.materialize()
        eye = jnp.eye(A.shape[-1], dtype=A.dtype)
        return A + self.ridge * eye


class BlockDiagonal(LinearOperator):
    """Independent blocks over a tuple domain: ``A = diag(A₁, …, Aₖ)``.

    The domain is a tuple with one entry per block (each entry any pytree).
    Symmetry/definiteness are the conjunction of the blocks'; ``diagonal``
    concatenates block diagonals — the natural source of block-Jacobi
    preconditioners (``block_jacobi_preconditioner``).
    """

    def __init__(self, ops: Sequence[LinearOperator]):
        ops = tuple(ops)
        if not ops:
            raise ValueError("BlockDiagonal needs at least one block")
        batch = {op.batch_ndim for op in ops}
        if len(batch) != 1:
            raise ValueError("blocks disagree on batch_ndim")
        syms = [op.symmetric for op in ops]
        symmetric = (True if all(s is True for s in syms)
                     else False if any(s is False for s in syms) else None)
        super().__init__(tuple(op.example for op in ops),
                         batch_ndim=batch.pop(), symmetric=symmetric,
                         positive_definite=all(op.positive_definite
                                               for op in ops))
        self.ops = ops

    def matvec(self, v):
        """Apply each block to its leaf of the domain pytree."""
        return tuple(op.matvec(vi) for op, vi in zip(self.ops, v))

    def rmatvec(self, v):
        """Apply each block's adjoint to its leaf."""
        return tuple(op.rmatvec(vi) for op, vi in zip(self.ops, v))

    def transpose(self) -> LinearOperator:
        """Blockwise transpose."""
        if self.symmetric:
            return self
        return BlockDiagonal(tuple(op.transpose() for op in self.ops))

    def diagonal(self):
        """Blockwise diagonals as a pytree."""
        return tuple(op.diagonal() for op in self.ops)

    def materialize(self) -> jnp.ndarray:
        """Dense block-diagonal matrix in ravel order."""
        blocks = [op.materialize() for op in self.ops]
        d = sum(b.shape[-1] for b in blocks)
        if self.batch_ndim:
            B = blocks[0].shape[0]
            A = jnp.zeros((B, d, d), blocks[0].dtype)
        else:
            A = jnp.zeros((d, d), blocks[0].dtype)
        i = 0
        for b in blocks:
            n = b.shape[-1]
            A = A.at[..., i:i + n, i:i + n].set(b)
            i += n
        return A


class ComposedOperator(LinearOperator):
    """``outer ∘ inner`` — the product operator, e.g. a left-preconditioned
    system ``M⁻¹ A``.  Flags default to unknown (products rarely preserve
    them) unless asserted explicitly."""

    def __init__(self, outer: LinearOperator, inner: LinearOperator, *,
                 symmetric: Optional[bool] = None,
                 positive_definite: bool = False):
        super().__init__(inner.example, batch_ndim=inner.batch_ndim,
                         symmetric=symmetric,
                         positive_definite=positive_definite)
        self.outer = outer
        self.inner = inner

    def matvec(self, v):
        """Apply the composition right to left."""
        return self.outer.matvec(self.inner.matvec(v))

    def rmatvec(self, v):
        """Apply the adjoint composition left to right."""
        return self.inner.rmatvec(self.outer.rmatvec(v))

    def transpose(self) -> LinearOperator:
        """Compose the transposes in reverse order."""
        if self.symmetric:
            return self
        # (M A)ᵀ = Aᵀ Mᵀ; symmetry/definiteness are properties of the
        # product as a whole, so the declared flags carry over verbatim
        return ComposedOperator(self.inner.transpose(),
                                self.outer.transpose(),
                                symmetric=self.symmetric,
                                positive_definite=self.positive_definite)


class RaveledOperator(LinearOperator):
    """An operator re-expressed on its raveled flat-vector domain.

    The one place the differentiation layer needs a flat system:
    ``lax.custom_linear_solve`` binds per-leaf cotangents without
    instantiating symbolic zeros, so the transposable tangent solve must run
    on ONE vector leaf.  ``ravel``/``unravel`` move right-hand sides and
    solutions across, and ``ravel_fn`` lifts tree-to-tree callables (user
    preconditioners) to the flat domain.  Unbatched operators only —
    batching is vmap's job at this layer.
    """

    def __init__(self, op: LinearOperator):
        if op.batch_ndim != 0:
            raise ValueError("RaveledOperator wraps instance-shaped "
                             "operators; vmap supplies batching")
        flat_example, unravel = jax.flatten_util.ravel_pytree(op.example)
        super().__init__(flat_example, batch_ndim=0, symmetric=op.symmetric,
                         positive_definite=op.positive_definite)
        self.op = op
        self._unravel = unravel

    def ravel(self, tree) -> jnp.ndarray:
        """Ravel a domain pytree to the flat vector domain."""
        return _ravel1(tree)

    def unravel(self, flat):
        """Unravel a flat vector back to the domain pytree."""
        return self._unravel(flat)

    def ravel_fn(self, fn: Callable) -> Callable:
        """Lift a tree→tree linear map (e.g. a preconditioner) to flat."""
        return lambda vf: _ravel1(fn(self._unravel(vf)))

    def matvec(self, vf):
        """Flat-domain matvec (unravel → base matvec → ravel)."""
        return _ravel1(self.op.matvec(self._unravel(vf)))

    def rmatvec(self, vf):
        """Flat-domain adjoint matvec."""
        return _ravel1(self.op.rmatvec(self._unravel(vf)))

    def diagonal(self):
        """Base diagonal, raveled flat."""
        return _ravel1(self.op.diagonal())

    def materialize(self) -> jnp.ndarray:
        """The base operator's dense matrix (already ravel-ordered)."""
        return self.op.materialize()

    def raveled(self) -> "RaveledOperator":
        """Already flat: ``self``."""
        return self


# ---------------------------------------------------------------------------
# adapters and derived preconditioners
# ---------------------------------------------------------------------------

def as_operator(obj, example=None, *, batch_ndim: int = 0,
                symmetric: Optional[bool] = None,
                positive_definite: bool = False) -> LinearOperator:
    """Coerce to a ``LinearOperator``.

    Operators pass through unchanged (flags must not conflict); a 2-D/3-D
    array becomes a ``DenseOperator``; a callable becomes a
    ``FunctionOperator`` (``example`` required for the domain structure).
    """
    if isinstance(obj, LinearOperator):
        return obj
    if isinstance(obj, (np.ndarray, jnp.ndarray)) and obj.ndim in (2, 3):
        return DenseOperator(obj, example, symmetric=symmetric,
                             positive_definite=positive_definite)
    if callable(obj):
        if example is None:
            raise ValueError("as_operator(callable) needs an example of the "
                             "domain pytree")
        return FunctionOperator(obj, example, batch_ndim=batch_ndim,
                                symmetric=symmetric,
                                positive_definite=positive_definite)
    raise TypeError(f"cannot interpret {type(obj)!r} as a LinearOperator")


def jacobi_preconditioner(diag) -> Callable:
    """``M⁻¹ v = v / diag``, elementwise over a pytree of diagonals (the
    one safe-divide definition — ``linear_solve`` re-exports it)."""
    safe = jax.tree_util.tree_map(
        lambda dg: jnp.where(jnp.abs(dg) > 1e-30, dg, 1.0), diag)
    return lambda v: jax.tree_util.tree_map(lambda x, dg: x / dg, v, safe)


def jacobi_preconditioner_from(op: LinearOperator) -> Callable:
    """``M⁻¹ v = v / diag(A)`` derived from ``op.diagonal()``.

    Structured operators provide the diagonal in O(1); matrix-free ones pay
    ``d`` probing matvecs exactly once, here, instead of inside the solver.
    """
    return jacobi_preconditioner(op.diagonal())


def block_jacobi_preconditioner(op: LinearOperator,
                                materialized=None) -> Callable:
    """Per-block dense inverse preconditioner from the operator's structure.

    For a ``BlockDiagonal`` operator this is exact (each block materialized
    and inverted); for any other operator the *leaves* of the domain pytree
    define the blocks — the corresponding diagonal sub-blocks of ``A`` are
    extracted from one materialization and inverted, off-diagonal coupling
    dropped.  ``materialized`` short-circuits that materialization when the
    caller already holds the dense matrix (e.g. a dense-regime solver).
    Returns a tree→tree callable usable as ``precond``.  Intended for the
    dense small-system regime (one materialize + per-block ``n³``).
    """
    if isinstance(op, BlockDiagonal):
        if materialized is None:
            mats = [blk.materialize() for blk in op.ops]
        else:   # slice the supplied dense matrix along the declared blocks
            mats, i = [], 0
            for blk in op.ops:
                example = blk.example
                if blk.batch_ndim:
                    example = jax.tree_util.tree_map(lambda l: l[0], example)
                n = _ravel1(example).shape[0]
                mats.append(materialized[..., i:i + n, i:i + n])
                i += n
        inv_ops = [DenseOperator(jnp.linalg.inv(m), blk.example,
                                 symmetric=blk.symmetric)
                   for m, blk in zip(mats, op.ops)]

        def M_blockwise(v):
            return tuple(inv.matvec(vi) for inv, vi in zip(inv_ops, v))

        return M_blockwise

    example = op.example
    if op.batch_ndim:
        example = jax.tree_util.tree_map(lambda l: l[0], example)
    leaves, treedef = jax.tree_util.tree_flatten(example)
    sizes = [int(leaf.size) for leaf in leaves]
    A = op.materialize() if materialized is None else materialized
    bounds, i = [], 0
    for n in sizes:
        bounds.append((i, i + n))
        i += n
    invs = [jnp.linalg.inv(A[..., s:e, s:e]) for s, e in bounds]

    def M(v):
        vleaves = jax.tree_util.tree_leaves(v)
        batch_shape = () if op.batch_ndim == 0 else vleaves[0].shape[:1]
        out = [jnp.einsum("...ij,...j->...i", inv,
                          vl.reshape(batch_shape + (-1,))).reshape(vl.shape)
               for inv, vl in zip(invs, vleaves)]
        return jax.tree_util.tree_unflatten(treedef, out)

    return M
