"""DEQ-style implicit (fixed-point) layers with implicit-diff backward.

A deep-equilibrium block solves z* = f(z*, x; w) in the forward pass and
backpropagates through the equilibrium with the paper's machinery, so memory
is O(1) in solver depth — the property that makes implicit layers attractive
inside large sharded models.

The layer rides the state-based solver runtime: the forward solve is an
``AndersonAcceleration`` or ``FixedPointIteration`` ``run()`` (one masked
``lax.while_loop``; ``jax.vmap`` over a batch of layer inputs executes ONE
batched solve), and implicit differentiation is automatic — the solver
declares the fixed-point mapping and routes its backward linear solve
through the ``SolverSpec`` registry: Neumann (cheap, approximate) or
normal-CG (exact), mirroring the trade-offs in the implicit-deep-nets
literature the paper cites [8, 43, 44].

Solve routing can also be passed as one ``ImplicitDiffSpec`` (``diff_spec``,
routing-only: the layer's optimality mapping is always the cell's fixed
point) instead of loose keyword arguments, and ``mode`` selects the
differentiation wrapping — the default ``"auto"`` makes the equilibrium
differentiable in BOTH autodiff modes, so ``jax.jacfwd`` sensitivities of
z* with respect to a few scalar inputs cost one tangent solve each.

The backward system I − ∂z f is built by the diff API as a
``operators.JacobianOperator`` of the declared fixed point, so
``bwd_solve="auto"`` auto-materializes small equilibria into the dense
batched kernels and ``precond="jacobi"`` derives from the operator's
diagonal — no per-layer ravel plumbing.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

from repro.core.diff_api import ImplicitDiffSpec
from repro.core.solver_runtime import (AndersonAcceleration,
                                       FixedPointIteration)


def make_deq_solver(cell: Callable, *, fwd_solver: str = "anderson",
                    fwd_iters: int = 30, fwd_tol: float = 1e-5,
                    bwd_solve: str = "neumann", bwd_iters: int = 12,
                    ridge: float = 0.0, precond=None,
                    backward: str = "exact", backward_iters: int = 8,
                    diff_spec: Optional[ImplicitDiffSpec] = None,
                    mode: Optional[str] = None):
    """Build the runtime solver for z* = cell(z*, x, w).

    Returns an ``IterativeSolver`` whose ``run(z0, x, w)`` yields
    ``(z_star, OptInfo)`` with derivatives flowing to ``x`` and ``w`` in
    both autodiff modes.  ``diff_spec`` (routing-only) replaces the loose
    ``bwd_solve`` / ``bwd_iters`` / ``ridge`` / ``precond`` /
    ``backward`` / ``backward_iters`` arguments wholesale; the cell's
    fixed point is always the optimality mapping.

    ``backward`` selects the approximate backward treatment (see
    ``ImplicitDiffSpec``): for a contractive cell,
    ``backward="neumann_k"`` with small ``backward_iters`` is the classic
    truncated-backprop DEQ approximation at a fixed O(k) matvec budget —
    unlike ``bwd_solve="neumann"``, which still runs a tolerance-checked
    convergence loop.
    """
    if diff_spec is not None:
        if not diff_spec.is_routing_only:
            raise ValueError(
                "the DEQ layer's optimality mapping is the cell's fixed "
                "point; pass a routing-only ImplicitDiffSpec (no "
                "optimality_fun/fixed_point_fun)")
        kw = dict(maxiter=fwd_iters, tol=fwd_tol, solve=diff_spec.solve,
                  linsolve_tol=diff_spec.tol,
                  linsolve_maxiter=diff_spec.maxiter, ridge=diff_spec.ridge,
                  precond=diff_spec.precond, backward=diff_spec.backward,
                  backward_iters=diff_spec.backward_iters)
    else:
        kw = dict(maxiter=fwd_iters, tol=fwd_tol, solve=bwd_solve,
                  linsolve_maxiter=bwd_iters, ridge=ridge, precond=precond,
                  backward=backward, backward_iters=backward_iters)
    if mode is not None:
        kw["mode"] = mode
    if fwd_solver == "anderson":
        return AndersonAcceleration(cell, **kw)
    if fwd_solver == "iteration":
        return FixedPointIteration(cell, **kw)
    raise ValueError(f"unknown fwd_solver {fwd_solver!r}; "
                     "expected 'anderson' or 'iteration'")


def deq_fixed_point(cell: Callable, z_init, x, w, *,
                    fwd_solver: str = "anderson", fwd_iters: int = 30,
                    fwd_tol: float = 1e-5, bwd_solve: str = "neumann",
                    bwd_iters: int = 12, backward: str = "exact",
                    backward_iters: int = 8,
                    diff_spec: Optional[ImplicitDiffSpec] = None,
                    mode: Optional[str] = None, return_info: bool = False):
    """Solve z* = cell(z*, x, w) and register implicit derivatives wrt x, w.

    Returns z* (and the solve's ``OptInfo`` when ``return_info=True``).
    Derivatives flow to both ``x`` (previous activations) and ``w`` (the
    block's weights) in both autodiff modes; ``z_init`` gets zero
    derivatives.  ``backward``/``backward_iters``/``diff_spec``/``mode``
    forward to ``make_deq_solver``.
    """
    solver = make_deq_solver(cell, fwd_solver=fwd_solver,
                             fwd_iters=fwd_iters, fwd_tol=fwd_tol,
                             bwd_solve=bwd_solve, bwd_iters=bwd_iters,
                             backward=backward,
                             backward_iters=backward_iters,
                             diff_spec=diff_spec, mode=mode)
    z_star, info = solver.run(z_init, x, w)
    return (z_star, info) if return_info else z_star


def make_deq_block(cell: Callable, **kw) -> Callable:
    """Return ``block(x, w) -> z*`` with z initialized at zero like x."""

    def block(x, w):
        z0 = jnp.zeros_like(x)
        return deq_fixed_point(cell, z0, x, w, **kw)

    return block
