"""DEQ-style implicit (fixed-point) layers with implicit-diff backward.

A deep-equilibrium block solves z* = f(z*, x; w) in the forward pass and
backpropagates through the equilibrium with the paper's machinery
(``custom_fixed_point``), so memory is O(1) in solver depth — the property
that makes implicit layers attractive inside large sharded models.

The layer is model-agnostic: ``cell(z, x, w) -> z`` may be any JAX function
(e.g. a transformer block); the solver is Anderson acceleration or plain
iteration, and the backward linear solve is Neumann (cheap, approximate) or
normal-CG (exact) — selectable, mirroring the trade-offs in the implicit-deep-
nets literature the paper cites [8, 43, 44].
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import implicit_diff, solvers


def deq_fixed_point(cell: Callable, z_init, x, w, *,
                    fwd_solver: str = "anderson", fwd_iters: int = 30,
                    fwd_tol: float = 1e-5, bwd_solve: str = "neumann",
                    bwd_iters: int = 12):
    """Solve z* = cell(z*, x, w) and register implicit derivatives wrt x, w.

    Returns z*.  Gradients flow to both ``x`` (previous activations) and
    ``w`` (the block's weights); ``z_init`` gets zero gradient.
    """

    def T(z, x, w):
        return cell(z, x, w)

    def solver(z0, x, w):
        if fwd_solver == "anderson":
            return solvers.anderson_acceleration(
                T, z0, x, w, maxiter=fwd_iters, tol=fwd_tol)
        return solvers.fixed_point_iteration(
            T, z0, x, w, maxiter=fwd_iters, tol=fwd_tol)

    wrapped = implicit_diff.custom_fixed_point(
        T, solve=bwd_solve, maxiter=bwd_iters)(solver)
    return wrapped(z_init, x, w)


def make_deq_block(cell: Callable, **kw) -> Callable:
    """Return ``block(x, w) -> z*`` with z initialized at zero like x."""

    def block(x, w):
        z0 = jnp.zeros_like(x)
        return deq_fixed_point(cell, z0, x, w, **kw)

    return block
