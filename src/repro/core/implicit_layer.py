"""DEQ-style implicit (fixed-point) layers with implicit-diff backward.

A deep-equilibrium block solves z* = f(z*, x; w) in the forward pass and
backpropagates through the equilibrium with the paper's machinery, so memory
is O(1) in solver depth — the property that makes implicit layers attractive
inside large sharded models.

The layer rides the state-based solver runtime: the forward solve is an
``AndersonAcceleration`` or ``FixedPointIteration`` ``run()`` (one masked
``lax.while_loop``; ``jax.vmap`` over a batch of layer inputs executes ONE
batched solve), and implicit differentiation is automatic — the solver
declares the fixed-point mapping and routes its backward linear solve
through the ``SolverSpec`` registry: Neumann (cheap, approximate) or
normal-CG (exact), mirroring the trade-offs in the implicit-deep-nets
literature the paper cites [8, 43, 44].
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.core.solver_runtime import (AndersonAcceleration,
                                       FixedPointIteration)


def make_deq_solver(cell: Callable, *, fwd_solver: str = "anderson",
                    fwd_iters: int = 30, fwd_tol: float = 1e-5,
                    bwd_solve: str = "neumann", bwd_iters: int = 12,
                    ridge: float = 0.0, precond=None):
    """Build the runtime solver for z* = cell(z*, x, w).

    Returns an ``IterativeSolver`` whose ``run(z0, x, w)`` yields
    ``(z_star, OptInfo)`` with gradients flowing to ``x`` and ``w``.
    """
    kw = dict(maxiter=fwd_iters, tol=fwd_tol, solve=bwd_solve,
              linsolve_maxiter=bwd_iters, ridge=ridge, precond=precond)
    if fwd_solver == "anderson":
        return AndersonAcceleration(cell, **kw)
    if fwd_solver == "iteration":
        return FixedPointIteration(cell, **kw)
    raise ValueError(f"unknown fwd_solver {fwd_solver!r}; "
                     "expected 'anderson' or 'iteration'")


def deq_fixed_point(cell: Callable, z_init, x, w, *,
                    fwd_solver: str = "anderson", fwd_iters: int = 30,
                    fwd_tol: float = 1e-5, bwd_solve: str = "neumann",
                    bwd_iters: int = 12, return_info: bool = False):
    """Solve z* = cell(z*, x, w) and register implicit derivatives wrt x, w.

    Returns z* (and the solve's ``OptInfo`` when ``return_info=True``).
    Gradients flow to both ``x`` (previous activations) and ``w`` (the
    block's weights); ``z_init`` gets zero gradient.
    """
    solver = make_deq_solver(cell, fwd_solver=fwd_solver,
                             fwd_iters=fwd_iters, fwd_tol=fwd_tol,
                             bwd_solve=bwd_solve, bwd_iters=bwd_iters)
    z_star, info = solver.run(z_init, x, w)
    return (z_star, info) if return_info else z_star


def make_deq_block(cell: Callable, **kw) -> Callable:
    """Return ``block(x, w) -> z*`` with z initialized at zero like x."""

    def block(x, w):
        z0 = jnp.zeros_like(x)
        return deq_fixed_point(cell, z0, x, w, **kw)

    return block
