"""Inner solvers.

The paper's point is that implicit differentiation composes with *any* solver.
We provide the solvers used in its experiments — gradient descent (with
optional backtracking), proximal gradient / FISTA, mirror descent, block
coordinate descent, Newton, Anderson acceleration, L-BFGS — all jit-safe
(``lax.while_loop`` / ``lax.scan``) and all returning plain ``x*`` so they can
be wrapped with ``@custom_root`` / ``@custom_fixed_point``.

All solvers share the signature ``solver(init_x, *theta)`` expected by the
decorators, via factories that capture f/g/projections.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.flatten_util
import jax.numpy as jnp
from jax import lax

from repro.core import optimality


def _tree_sub(a, b):
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def _tree_l2(a):
    return jnp.sqrt(sum(jnp.vdot(x, x).real
                        for x in jax.tree_util.tree_leaves(a)))


# ---------------------------------------------------------------------------
# Generic fixed-point iteration + Anderson acceleration
# ---------------------------------------------------------------------------

def fixed_point_iteration(T: Callable, init, *theta, maxiter: int = 1000,
                          tol: float = 1e-8):
    """Iterate x ← T(x, θ) until ‖T(x) − x‖ ≤ tol."""

    def cond(state):
        x, k, err = state
        return jnp.logical_and(k < maxiter, err > tol)

    def body(state):
        x, k, _ = state
        x_new = T(x, *theta)
        err = _tree_l2(_tree_sub(x_new, x))
        return x_new, k + 1, err

    x, _, _ = lax.while_loop(cond, body, (init, 0, jnp.inf))
    return x


def anderson_acceleration(T: Callable, init, *theta, history: int = 5,
                          maxiter: int = 200, tol: float = 1e-8,
                          ridge: float = 1e-8, beta: float = 1.0):
    """Anderson-accelerated fixed-point solve (type-II AA).

    Useful for DEQ-style layers where plain iteration converges slowly.
    Operates on the raveled vector.
    """
    x0_flat, unravel = jax.flatten_util.ravel_pytree(init)
    d = x0_flat.shape[0]
    m = history

    def T_flat(v):
        out, _ = jax.flatten_util.ravel_pytree(T(unravel(v), *theta))
        return out

    X = jnp.zeros((m, d), x0_flat.dtype)      # iterates
    Fh = jnp.zeros((m, d), x0_flat.dtype)     # residuals g(x) = T(x) − x

    def body(state):
        x, X, Fh, k, _ = state
        gx = T_flat(x) - x
        slot = k % m
        X = X.at[slot].set(x)
        Fh = Fh.at[slot].set(gx)
        n = jnp.minimum(k + 1, m)
        # solve min_alpha ||alpha^T Fh||, sum alpha = 1 via normal equations
        G = Fh @ Fh.T + ridge * jnp.eye(m, dtype=x.dtype)
        mask = (jnp.arange(m) < n).astype(x.dtype)
        G = G * mask[:, None] * mask[None, :] + \
            jnp.diag(1.0 - mask)  # inactive rows → identity
        rhs = mask
        alpha = jnp.linalg.solve(G, rhs)
        alpha = alpha * mask
        alpha = alpha / jnp.sum(alpha)
        x_new = alpha @ (X + beta * Fh)
        err = jnp.linalg.norm(gx)
        return x_new, X, Fh, k + 1, err

    def cond(state):
        _, _, _, k, err = state
        return jnp.logical_and(k < maxiter, err > tol)

    x, _, _, _, _ = lax.while_loop(
        cond, body, (x0_flat, X, Fh, 0, jnp.inf))
    return unravel(x)


# ---------------------------------------------------------------------------
# Gradient descent (fixed step or backtracking line search)
# ---------------------------------------------------------------------------

def gradient_descent(f: Callable, init, *theta, stepsize: float = 1e-2,
                     maxiter: int = 1000, tol: float = 1e-8,
                     linesearch: bool = False):
    value_and_grad = jax.value_and_grad(f, argnums=0)

    if not linesearch:
        T = optimality.gradient_descent_fp(f, stepsize)
        return fixed_point_iteration(T, init, *theta, maxiter=maxiter,
                                     tol=tol)

    def body(state):
        x, k, _ = state
        v, g = value_and_grad(x, *theta)
        gnorm2 = sum(jnp.vdot(gi, gi).real
                     for gi in jax.tree_util.tree_leaves(g))

        def ls_cond(eta):
            x_try = jax.tree_util.tree_map(lambda xi, gi: xi - eta * gi, x, g)
            return jnp.logical_and(
                f(x_try, *theta) > v - 0.5 * eta * gnorm2, eta > 1e-12)

        eta = lax.while_loop(ls_cond, lambda e: e * 0.5,
                             jnp.asarray(stepsize))
        x_new = jax.tree_util.tree_map(lambda xi, gi: xi - eta * gi, x, g)
        return x_new, k + 1, jnp.sqrt(gnorm2)

    def cond(state):
        _, k, err = state
        return jnp.logical_and(k < maxiter, err > tol)

    x, _, _ = lax.while_loop(cond, body, (init, 0, jnp.inf))
    return x


# ---------------------------------------------------------------------------
# Proximal gradient / FISTA
# ---------------------------------------------------------------------------

def proximal_gradient(f: Callable, prox: Callable, init, theta,
                      stepsize: float = 1e-2, maxiter: int = 1000,
                      tol: float = 1e-8, accel: bool = True):
    """Minimize f(x, θf) + g(x, θg) with θ = (θf, θg); FISTA momentum opt-in."""
    theta_f, theta_g = theta
    grad = jax.grad(f, argnums=0)

    def pg_step(x):
        y = jax.tree_util.tree_map(
            lambda xi, gi: xi - stepsize * gi, x, grad(x, theta_f))
        return prox(y, theta_g, stepsize)

    if not accel:
        return fixed_point_iteration(lambda x: pg_step(x), init,
                                     maxiter=maxiter, tol=tol)

    def body(state):
        x, z, t, k, _ = state
        x_new = pg_step(z)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        mom = (t - 1.0) / t_new
        z_new = jax.tree_util.tree_map(
            lambda a, b: a + mom * (a - b), x_new, x)
        err = _tree_l2(_tree_sub(x_new, x))
        return x_new, z_new, t_new, k + 1, err

    def cond(state):
        _, _, _, k, err = state
        return jnp.logical_and(k < maxiter, err > tol)

    x, _, _, _, _ = lax.while_loop(
        cond, body, (init, init, jnp.asarray(1.0), 0, jnp.inf))
    return x


def projected_gradient(f: Callable, proj: Callable, init, theta,
                       stepsize: float = 1e-2, maxiter: int = 1000,
                       tol: float = 1e-8, accel: bool = True):
    def prox(y, theta_proj, scaling):
        del scaling
        return proj(y, theta_proj)

    return proximal_gradient(f, prox, init, theta, stepsize=stepsize,
                             maxiter=maxiter, tol=tol, accel=accel)


# ---------------------------------------------------------------------------
# Mirror descent (KL geometry default)
# ---------------------------------------------------------------------------

def mirror_descent(f: Callable, proj_kl: Callable, init, theta,
                   phi_grad: Callable = optimality.kl_phi_grad,
                   stepsize: float = 1.0, maxiter: int = 1000,
                   tol: float = 1e-8, sqrt_decay_after: int = 100):
    theta_f, theta_proj = theta
    grad = jax.grad(f, argnums=0)

    def body(state):
        x, k, _ = state
        eta = stepsize * jnp.where(
            k < sqrt_decay_after, 1.0,
            jnp.sqrt(sqrt_decay_after / jnp.maximum(k, 1)))
        y = phi_grad(x) - eta * grad(x, theta_f)
        x_new = proj_kl(y, theta_proj)
        err = _tree_l2(_tree_sub(x_new, x))
        return x_new, k + 1, err

    def cond(state):
        _, k, err = state
        return jnp.logical_and(k < maxiter, err > tol)

    x, _, _ = lax.while_loop(cond, body, (init, 0, jnp.inf))
    return x


# ---------------------------------------------------------------------------
# Block coordinate descent (cyclic, for row-separable constraints like the
# product of simplices in the multiclass SVM dual)
# ---------------------------------------------------------------------------

def block_coordinate_descent(f: Callable, block_prox: Callable, init, theta,
                             stepsize: float = 1.0, maxiter: int = 500,
                             tol: float = 1e-8):
    """x has shape (m, k); blocks are rows.  One sweep = one scan over rows."""
    theta_f, theta_g = theta
    grad = jax.grad(f, argnums=0)

    def sweep(x):
        def row_update(x, i):
            g = grad(x, theta_f)            # full grad; row i slice used
            row = x[i] - stepsize * g[i]
            x = x.at[i].set(block_prox(row, theta_g, stepsize))
            return x, None
        x, _ = lax.scan(row_update, x, jnp.arange(x.shape[0]))
        return x

    def body(state):
        x, k, _ = state
        x_new = sweep(x)
        err = _tree_l2(x_new - x)
        return x_new, k + 1, err

    def cond(state):
        _, k, err = state
        return jnp.logical_and(k < maxiter, err > tol)

    x, _, _ = lax.while_loop(cond, body, (init, 0, jnp.inf))
    return x


# ---------------------------------------------------------------------------
# Newton's method (optimization) and L-BFGS
# ---------------------------------------------------------------------------

def newton(f: Callable, init, *theta, maxiter: int = 50, tol: float = 1e-10,
           stepsize: float = 1.0):
    grad = jax.grad(f, argnums=0)
    hess = jax.hessian(f, argnums=0)

    def body(state):
        x, k, _ = state
        g = grad(x, *theta)
        Hm = hess(x, *theta)
        x_new = x - stepsize * jnp.linalg.solve(Hm, g)
        return x_new, k + 1, jnp.linalg.norm(g)

    def cond(state):
        _, k, err = state
        return jnp.logical_and(k < maxiter, err > tol)

    x, _, _ = lax.while_loop(cond, body, (init, 0, jnp.inf))
    return x


def lbfgs(f: Callable, init, *theta, maxiter: int = 200, tol: float = 1e-8,
          history: int = 10, stepsize: float = 1.0):
    """L-BFGS with fixed step (sufficient for the well-conditioned inner
    problems used in the experiments; backtracking available via
    ``gradient_descent(linesearch=True)`` when needed)."""
    x0, unravel = jax.flatten_util.ravel_pytree(init)
    grad = jax.grad(lambda v: f(unravel(v), *theta))
    d, m = x0.shape[0], history

    S = jnp.zeros((m, d), x0.dtype)
    Y = jnp.zeros((m, d), x0.dtype)
    rho = jnp.zeros((m,), x0.dtype)

    def two_loop(g, S, Y, rho, k):
        n = jnp.minimum(k, m)
        q = g
        alphas = jnp.zeros((m,), x0.dtype)

        def bwd(i, qa):
            q, alphas = qa
            j = (k - 1 - i) % m
            valid = i < n
            a = jnp.where(valid, rho[j] * jnp.dot(S[j], q), 0.0)
            q = q - a * Y[j] * valid
            alphas = alphas.at[j].set(a)
            return q, alphas

        q, alphas = lax.fori_loop(0, m, bwd, (q, alphas))
        # initial Hessian scaling
        j_last = (k - 1) % m
        ys = jnp.dot(S[j_last], Y[j_last])
        yy = jnp.dot(Y[j_last], Y[j_last])
        gamma = jnp.where(jnp.logical_and(k > 0, yy > 0), ys / yy, 1.0)
        r = gamma * q

        def fwd(i, r):
            j = (k - n + i) % m
            valid = i < n
            b = jnp.where(valid, rho[j] * jnp.dot(Y[j], r), 0.0)
            return r + (alphas[j] - b) * S[j] * valid

        return lax.fori_loop(0, m, fwd, r)

    def body(state):
        x, S, Y, rho, k, _ = state
        g = grad(x)
        p = two_loop(g, S, Y, rho, k)
        x_new = x - stepsize * p
        g_new = grad(x_new)
        s, y = x_new - x, g_new - g
        sy = jnp.dot(s, y)
        slot = k % m
        ok = sy > 1e-10
        S = S.at[slot].set(jnp.where(ok, s, S[slot]))
        Y = Y.at[slot].set(jnp.where(ok, y, Y[slot]))
        rho = rho.at[slot].set(jnp.where(ok, 1.0 / jnp.where(ok, sy, 1.0),
                                         rho[slot]))
        return x_new, S, Y, rho, k + 1, jnp.linalg.norm(g_new)

    def cond(state):
        _, _, _, _, k, err = state
        return jnp.logical_and(k < maxiter, err > tol)

    x, _, _, _, _, _ = lax.while_loop(
        cond, body, (x0, S, Y, rho, 0, jnp.inf))
    return unravel(x)
