"""Inner solvers — DEPRECATED functional shims.

The solver layer now lives in ``repro.core.solver_runtime`` as state-based
``IterativeSolver`` classes with a shared jit/vmap-safe ``run()`` driver,
``OptInfo`` diagnostics, and *automatic* implicit differentiation (each
solver declares its optimality mapping and ``run()`` self-wraps with
``custom_root`` / ``custom_fixed_point``).

These factories keep the pre-runtime signatures working: they build the
matching runtime solver with ``implicit_diff=False`` (call sites of this era
hand-wrapped the decorators themselves) and return the bare ``x*``.  New code
should construct the classes directly::

    from repro.core import GradientDescent
    solver = GradientDescent(f, stepsize=1e-2, maxiter=1000, tol=1e-8)
    x_star, info = solver.run(x0, theta)     # gradients flow through x_star

Migration map:
  fixed_point_iteration     -> FixedPointIteration
  anderson_acceleration     -> AndersonAcceleration
  gradient_descent          -> GradientDescent
  proximal_gradient         -> ProximalGradient
  projected_gradient        -> ProjectedGradient
  mirror_descent            -> MirrorDescent
  block_coordinate_descent  -> BlockCoordinateDescent
  newton                    -> Newton
  lbfgs                     -> LBFGS
"""
from __future__ import annotations

from typing import Callable

from repro.core import optimality
from repro.core.diff_api import warn_once
from repro.core.solver_runtime import (AndersonAcceleration,
                                       BlockCoordinateDescent,
                                       FixedPointIteration, GradientDescent,
                                       LBFGS, MirrorDescent, Newton,
                                       ProximalGradient, ProjectedGradient)

__all__ = [
    "fixed_point_iteration", "anderson_acceleration", "gradient_descent",
    "proximal_gradient", "projected_gradient", "mirror_descent",
    "block_coordinate_descent", "newton", "lbfgs",
]


def _deprecated(old: str, new: str):
    # one-shot per factory name (see diff_api.warn_once): a training loop
    # calling a legacy factory every step warns once, not per call.  Tests
    # asserting the warning reset via diff_api.reset_deprecation_warnings().
    warn_once(
        f"solvers.{old}",
        f"repro.core.solvers.{old} is deprecated; use "
        f"repro.core.solver_runtime.{new} (state-based runtime with "
        "automatic implicit differentiation) instead",
        stacklevel=4)


def fixed_point_iteration(T: Callable, init, *theta, maxiter: int = 1000,
                          tol: float = 1e-8):
    """Iterate x ← T(x, θ) until ‖T(x) − x‖ ≤ tol."""
    _deprecated("fixed_point_iteration", "FixedPointIteration")
    solver = FixedPointIteration(T, maxiter=maxiter, tol=tol,
                                 implicit_diff=False)
    return solver.run(init, *theta)[0]


def anderson_acceleration(T: Callable, init, *theta, history: int = 5,
                          maxiter: int = 200, tol: float = 1e-8,
                          ridge: float = 1e-8, beta: float = 1.0):
    """Anderson-accelerated fixed-point solve (type-II AA)."""
    _deprecated("anderson_acceleration", "AndersonAcceleration")
    solver = AndersonAcceleration(T, history=history, aa_ridge=ridge,
                                  beta=beta, maxiter=maxiter, tol=tol,
                                  implicit_diff=False)
    return solver.run(init, *theta)[0]


def gradient_descent(f: Callable, init, *theta, stepsize: float = 1e-2,
                     maxiter: int = 1000, tol: float = 1e-8,
                     linesearch: bool = False):
    _deprecated("gradient_descent", "GradientDescent")
    solver = GradientDescent(f, stepsize=stepsize, linesearch=linesearch,
                             maxiter=maxiter, tol=tol, implicit_diff=False)
    return solver.run(init, *theta)[0]


def proximal_gradient(f: Callable, prox: Callable, init, theta,
                      stepsize: float = 1e-2, maxiter: int = 1000,
                      tol: float = 1e-8, accel: bool = True):
    """Minimize f(x, θf) + g(x, θg) with θ = (θf, θg); FISTA momentum opt-in."""
    _deprecated("proximal_gradient", "ProximalGradient")
    solver = ProximalGradient(f, prox, stepsize=stepsize, accel=accel,
                              maxiter=maxiter, tol=tol, implicit_diff=False)
    return solver.run(init, theta)[0]


def projected_gradient(f: Callable, proj: Callable, init, theta,
                       stepsize: float = 1e-2, maxiter: int = 1000,
                       tol: float = 1e-8, accel: bool = True):
    _deprecated("projected_gradient", "ProjectedGradient")
    solver = ProjectedGradient(f, proj, stepsize=stepsize, accel=accel,
                               maxiter=maxiter, tol=tol, implicit_diff=False)
    return solver.run(init, theta)[0]


def mirror_descent(f: Callable, proj_kl: Callable, init, theta,
                   phi_grad: Callable = optimality.kl_phi_grad,
                   stepsize: float = 1.0, maxiter: int = 1000,
                   tol: float = 1e-8, sqrt_decay_after: int = 100):
    _deprecated("mirror_descent", "MirrorDescent")
    solver = MirrorDescent(f, proj_kl, phi_grad=phi_grad, stepsize=stepsize,
                           sqrt_decay_after=sqrt_decay_after,
                           maxiter=maxiter, tol=tol, implicit_diff=False)
    return solver.run(init, theta)[0]


def block_coordinate_descent(f: Callable, block_prox: Callable, init, theta,
                             stepsize: float = 1.0, maxiter: int = 500,
                             tol: float = 1e-8):
    """x has shape (m, k); blocks are rows.  One sweep = one scan over rows."""
    _deprecated("block_coordinate_descent", "BlockCoordinateDescent")
    solver = BlockCoordinateDescent(f, block_prox, stepsize=stepsize,
                                    maxiter=maxiter, tol=tol,
                                    implicit_diff=False)
    return solver.run(init, theta)[0]


def newton(f: Callable, init, *theta, maxiter: int = 50, tol: float = 1e-10,
           stepsize: float = 1.0):
    _deprecated("newton", "Newton")
    solver = Newton(f, stepsize=stepsize, maxiter=maxiter, tol=tol,
                    implicit_diff=False)
    return solver.run(init, *theta)[0]


def lbfgs(f: Callable, init, *theta, maxiter: int = 200, tol: float = 1e-8,
          history: int = 10, stepsize: float = 1.0):
    """L-BFGS with fixed step (see ``solver_runtime.LBFGS``)."""
    _deprecated("lbfgs", "LBFGS")
    solver = LBFGS(f, history=history, stepsize=stepsize, maxiter=maxiter,
                   tol=tol, implicit_diff=False)
    return solver.run(init, *theta)[0]
