"""One mode-polymorphic implicit-differentiation API.

The paper's promise is that the optimality-condition *spec* is decoupled from
the differentiation *mechanism*.  This module is the single composition point
that delivers it:

  * ``ImplicitDiffSpec`` — the declarative spec: an optimality mapping
    ``F(x, *theta)`` (root form) or fixed-point mapping ``T(x, *theta)``
    (eq. 3), plus the backward/tangent linear-solve routing (``solve`` /
    ``precond`` / ``ridge`` / ``tol`` / ``maxiter``), ``has_aux`` and
    ``nondiff_argnums``.
  * ``implicit_diff(spec)(solver)`` — one wrapper serving BOTH autodiff
    modes: the returned function supports ``jax.grad`` / ``jax.jacrev``
    *and* ``jax.jvp`` / ``jax.jacfwd`` without re-wrapping.
  * ``root_vjp`` / ``root_jvp`` — the low-level products with the implicit
    Jacobian (paper §2.1), shared by every mode.

How one wrapper serves both modes
---------------------------------
The derivative is registered as a single ``jax.custom_jvp`` rule.  Its
tangent is the solution of the implicit-function-theorem system

    A dx = B θ̇,      A = -∂₁F(x*, θ),   B = ∂₂F(x*, θ),

where ``A`` is built as one first-class ``operators.JacobianOperator`` per
direction (matvec = JVP, rmatvec = VJP, symmetry certified at construction
when the routed solver is symmetric-only), and the linear solve is made
*reverse-transposable* by expressing the operator's raveled view as a
``lax.custom_linear_solve`` pair: the forward direction routes ``A dx = b``
through the ``SolverSpec`` registry, and the declared transpose direction
routes ``Aᵀ u = v`` through the same registry (a symmetric operator reuses
the forward matvec — ``A.T is A``).  Reverse mode therefore linearizes
through the JVP rule and transposes into exactly the ``root_vjp`` linear
system; forward mode uses the tangent solve directly.

Batching: every registry solver is vmap-safe with per-instance convergence
masks, so ``jax.vmap`` of either mode's derivative executes ONE batched
masked solve for the whole batch — never N sequential solves.  (Trace-time
census: ``custom_linear_solve`` stages both direction templates, one
registry trace per direction, independent of batch size; exactly one
direction *executes* per derivative.)

Mode selection (``mode=``)
--------------------------
  * ``"auto"`` (default) — the mode-polymorphic wrapper above.
  * ``"jvp"``  — forward-only ``custom_jvp`` (no transpose template is
    staged; reverse mode raises).  For JVP-dominant workloads: few
    parameters, many outputs (e.g. the molecular-dynamics sensitivity
    experiment; see the Jacobian-shape analysis in Margossian &
    Betancourt).
  * ``"vjp"``  — reverse-only ``custom_vjp`` (forward mode raises).  For
    VJP-dominant workloads: many parameters, scalar losses.

Conventions: the wrapped solver has signature ``solver(init, *theta)`` and
returns ``x*`` (or ``(x*, aux)`` with ``has_aux=True``).  ``F``/``T`` take
``(x, *theta)`` and return a pytree with the structure of ``x``.  ``init``
always gets a zero derivative — x*(θ) does not depend on the initialization.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import linear_solve as ls
from repro.core import operators as ops
from repro.observability import events as obs_events


# ---------------------------------------------------------------------------
# one-shot deprecation plumbing (shared with repro.core.solvers)
# ---------------------------------------------------------------------------

_WARNED: set = set()


def warn_once(key: str, message: str, *, stacklevel: int = 3) -> None:
    """Emit ``DeprecationWarning`` exactly once per ``key`` per process."""
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)


def reset_deprecation_warnings() -> None:
    """Forget which one-shot deprecation warnings fired (test hook)."""
    _WARNED.clear()


# ---------------------------------------------------------------------------
# the spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ImplicitDiffSpec:
    """Declarative spec of an implicitly-differentiated solver.

    Exactly one of ``optimality_fun`` (root form: F(x*, θ) = 0) or
    ``fixed_point_fun`` (fixed-point form: x* = T(x*, θ); the residual
    T(x) − x is derived automatically, eq. 3) should be set before the spec
    is used to wrap a solver.  A spec with neither is a *routing-only* spec
    — legal to construct and pass around as a bundle of backward-solve
    settings (e.g. ``bilevel.solve_bilevel(diff_spec=...)`` overriding an
    ``IterativeSolver``'s own routing), but not wrappable by itself.

    ``solve`` is a ``SolverSpec`` registry name (see
    ``linear_solve.available_solvers()``), ``"auto"`` (structure-driven
    dispatch on the implicit system's ``LinearOperator`` — dense small
    systems auto-materialize), or a callable
    ``fn(matvec, b, *, tol, maxiter, ridge)``; ``tol`` / ``maxiter`` /
    ``ridge`` / ``precond`` are forwarded to it for BOTH the tangent system
    ``A dx = Bθ̇`` and the cotangent system ``Aᵀ u = v``.  ``precond`` may
    be a callable ``v ↦ M⁻¹v`` (x-pytree contract), ``"jacobi"``, or
    ``"block_jacobi"`` — the named ones derive from the system operator's
    ``diagonal()`` / leaf-block structure.

    ``has_aux=True`` means the solver returns ``(x_star, aux)``; only
    ``x_star`` enters the implicit system, ``aux`` gets zero derivatives
    (both modes — the forward path emits ``float0`` tangents for integer/
    bool aux leaves).

    ``nondiff_argnums`` are indices into the solver's ``*theta`` arguments
    (0 = first argument after ``init``) that are static non-array values —
    Python callables, strings, hashable config.  They are passed through
    untouched and excluded from differentiation.

    ``backward`` selects how the backward linear system is treated in BOTH
    derivative directions (the tangent solve ``A dx = Bθ̇`` and the
    cotangent solve ``Aᵀ u = v``): ``"exact"`` (default) iterates the routed
    solver to convergence; ``"one_step"`` spends one preconditioned
    application (O(1) matvecs); ``"neumann_k"`` truncates the Neumann series
    at exactly ``backward_iters`` terms (O(k) matvecs, static trip count);
    ``"jacobian_free"`` treats ``A ≈ I`` (zero matvecs).  See
    ``linear_solve.approx_inverse_apply`` for the exact polynomials and
    ``docs/implicit_diff.md`` for choosing a mode.  ``error_estimate``
    controls whether info-returning entry points (``root_vjp(...,
    return_info=True)``, ``IterativeSolver.estimate_hypergrad_error``) spend
    one extra matvec on the relative-residual honesty check.

    ``system_operator`` overrides how the implicit system's ``A`` is
    *built* (not how it is solved): a factory
    ``(x_star, theta_args, *, symmetric) -> LinearOperator`` returning the
    full ``A = -∂₁F(x*, θ)`` **including the negation**, where
    ``symmetric`` is the routing layer's certification hint (``True`` when
    the routed solver is symmetric-only, else ``None`` — the factory may
    strengthen it from structural knowledge, e.g. a sampled Hessian of a
    per-batch gradient mapping).  This is how the stochastic layer swaps
    in a ``SampledJacobianOperator`` whose matvec averages Hessian-vector
    products over resampled minibatches while ``B = ∂₂F`` stays exact.
    The factory is called with the same ``theta`` tuple the residual
    receives.  Mutually exclusive with ``sharding``.

    ``sharding`` (a ``repro.distributed.sharded_operators.SolveSharding``)
    places the implicit system on a mesh: the ``JacobianOperator`` inherits
    the primal solution's mesh + PartitionSpecs, the classic solver names
    upgrade to their distributed variants (``cg`` → ``sharded_cg``, …), and
    both modes' linear solves execute under ``shard_map`` with no host
    gather.  The sharded tangent/cotangent solve runs on the native ``x``
    pytree (the single-device path ravels to one flat leaf to sidestep
    jax's per-leaf symbolic-zero transpose limitation) — with a multi-leaf
    sharded ``x*``, reverse mode needs the downstream loss to engage every
    leaf.
    """
    optimality_fun: Optional[Callable] = None
    fixed_point_fun: Optional[Callable] = None
    solve: Union[str, Callable] = "normal_cg"
    tol: float = 1e-6
    maxiter: int = 1000
    ridge: float = 0.0
    precond: Any = None
    has_aux: bool = False
    nondiff_argnums: Tuple[int, ...] = ()
    sharding: Any = None
    backward: str = "exact"
    backward_iters: int = 8
    error_estimate: bool = True
    system_operator: Optional[Callable] = None

    def __post_init__(self):
        if self.system_operator is not None and self.sharding is not None:
            raise ValueError(
                "system_operator and sharding are mutually exclusive: a "
                "factory-built system has no mesh placement contract")
        if self.optimality_fun is not None and \
                self.fixed_point_fun is not None:
            raise ValueError("provide at most one of optimality_fun / "
                             "fixed_point_fun, not both")
        nd = tuple(sorted(set(int(i) for i in self.nondiff_argnums)))
        if any(i < 0 for i in nd):
            raise ValueError("nondiff_argnums are 0-based indices into the "
                             f"theta arguments; got {self.nondiff_argnums}")
        object.__setattr__(self, "nondiff_argnums", nd)
        if self.backward not in ls.BACKWARD_MODES:
            raise ValueError(f"unknown backward mode {self.backward!r}; "
                             f"expected one of {ls.BACKWARD_MODES}")
        if self.backward == "neumann_k" and int(self.backward_iters) < 1:
            raise ValueError("backward='neumann_k' needs backward_iters >= 1;"
                             f" got {self.backward_iters}")

    @property
    def residual_fun(self) -> Callable:
        """The root residual F(x, *theta) this spec differentiates through."""
        if self.optimality_fun is not None:
            return self.optimality_fun
        if self.fixed_point_fun is not None:
            T = self.fixed_point_fun

            def residual(x, *theta):
                return jax.tree_util.tree_map(
                    lambda a, b: a - b, T(x, *theta), x)

            return residual
        raise ValueError(
            "routing-only ImplicitDiffSpec: set optimality_fun or "
            "fixed_point_fun before wrapping a solver with it")

    @property
    def is_routing_only(self) -> bool:
        """True when no optimality/fixed-point mapping is declared."""
        return self.optimality_fun is None and self.fixed_point_fun is None

    def replace(self, **changes) -> "ImplicitDiffSpec":
        """A copy of the spec with ``changes`` applied (per-call overrides)."""
        return dataclasses.replace(self, **changes)

    def routing_kwargs(self) -> dict:
        """The backward-solve routing as ``route_solve`` keyword arguments."""
        return dict(tol=self.tol, maxiter=self.maxiter, ridge=self.ridge,
                    precond=self.precond)

    def backward_kwargs(self) -> dict:
        """The approximate-backward selection as keyword arguments."""
        return dict(backward=self.backward,
                    backward_iters=self.backward_iters)


# ---------------------------------------------------------------------------
# low-level products with the implicit Jacobian (paper §2.1)
# ---------------------------------------------------------------------------

def _implicit_system_operator(F: Callable, x_star, theta_args: tuple,
                              solve, sharding=None,
                              system_operator=None) -> ops.LinearOperator:
    """``A = -∂₁F(x*, θ)`` as a ``JacobianOperator``.

    The symmetry flag is set at construction — routing a symmetric-only
    solver (``cg``/``pallas_cg``/``sharded_cg``) certifies ``A = Aᵀ`` — and
    every downstream consumer (transpose reuse, ``custom_linear_solve``'s
    ``symmetric=``, route validation, preconditioner derivation) reads it
    off the operator.

    ``system_operator`` (see ``ImplicitDiffSpec``) replaces the default
    construction entirely: the factory receives ``(x_star, theta_args)``
    plus the certification hint and must return the full (negated)
    operator — e.g. the stochastic layer's ``SampledJacobianOperator``.

    With ``sharding`` set, the operator is placed on the mesh: the primal
    point and every theta argument become ``shard_map`` operands (specs
    from the primal solution / ``theta_specs``), so the Jacobian matvec is
    a per-shard JVP and the solve registry dispatches the distributed
    solvers — the backward solve inherits the forward solve's placement.
    """
    certified = solve != "auto" and ls.solver_is_symmetric(solve)
    sym = True if certified else None
    if system_operator is not None:
        if sharding is not None:
            raise ValueError("system_operator and sharding are mutually "
                             "exclusive")
        A = system_operator(x_star, theta_args, symmetric=sym)
        if not isinstance(A, ops.LinearOperator):
            raise TypeError("system_operator factory must return a "
                            f"LinearOperator; got {type(A)!r}")
        if certified and A.symmetric is False:
            raise ValueError(
                f"routed solver {solve!r} is symmetric-only but the "
                "system_operator factory declared symmetric=False")
        return A
    if sharding is None:
        return ops.JacobianOperator(
            lambda x: F(x, *theta_args), x_star, negate=True, symmetric=sym)

    def jacobian_factory(x_local, *theta_local):
        return ops.JacobianOperator(
            lambda x: F(x, *theta_local), x_local, negate=True,
            symmetric=sym, batch_ndim=sharding.batch_ndim)

    return sharding.wrap(jacobian_factory, (x_star, *theta_args))


def _check_approx_routing(precond, sharding):
    """Reject routing combos the approximate backward modes can't honor."""
    if sharding is not None and isinstance(precond, str):
        raise ValueError(
            "approximate backward modes with a sharded system do not "
            "support named preconditioners (deriving the global diagonal "
            "outside shard_map would capture replicated state); pass a "
            "callable M⁻¹ or precond=None")


def _backward_apply(A, rhs, *, solve, tol, maxiter, ridge, precond,
                    backward, backward_iters, batch_ndim: int,
                    error_estimate: bool, return_info: bool,
                    direction: str = "vjp"):
    """Apply the selected backward treatment of ``A`` to ``rhs``.

    ``backward="exact"`` routes the registry solver to convergence; the
    approximate modes spend their fixed matvec budget via
    ``approx_inverse_apply``.  With ``return_info=True`` both paths return
    ``(u, SolveInfo)`` and — when ``error_estimate`` — populate
    ``hypergrad_error_estimate`` with the relative residual
    ``‖rhs − A u‖/‖rhs‖`` at one extra matvec (uniformly recomputed even
    for exact solves: normal_cg's reported residual is the *normal
    equations'* residual, not the system's).

    ``direction`` ("vjp" from ``root_vjp``, "jvp" from ``root_jvp``) only
    tags the ``backward_start``/``backward_done`` telemetry events; with
    observability enabled the registry paths force info out of the solver
    so ``backward_done`` carries real diagnostics even when the caller
    asked for none.
    """
    observing = obs_events.observing()
    tags = {"direction": direction, "backward": backward,
            "matvec_budget": (-1 if backward == "exact" else
                              ls.approx_matvec_count(backward,
                                                     backward_iters)),
            "solver": solve if isinstance(solve, str) else "custom"}
    # custom exact-solve callables own their diagnostics (route_solve
    # rejects return_info for them) — they get start/done without values
    can_force = backward != "exact" or not callable(solve)
    want_info = return_info
    if observing:
        return_info = return_info or can_force
    if backward != "exact":
        out = ls.approx_inverse_apply(
            A, rhs, backward=backward, backward_iters=backward_iters,
            ridge=ridge, precond=precond, batch_ndim=batch_ndim, tol=tol,
            error_estimate=error_estimate, return_info=return_info)
    elif not return_info:
        out = ls.route_solve(solve, A, rhs, tol=tol, maxiter=maxiter,
                             ridge=ridge, precond=precond)
    else:
        u, info = ls.route_solve(solve, A, rhs, tol=tol, maxiter=maxiter,
                                 ridge=ridge, precond=precond,
                                 return_info=True)
        if error_estimate:
            mv = ls._damped(A, ridge)
            rn = ls._tree_l2(ls._tree_sub(rhs, mv(u)), batch_ndim)
            est = rn / jnp.maximum(ls._tree_l2(rhs, batch_ndim), 1e-30)
            info = info._replace(hypergrad_error_estimate=est)
        out = (u, info)
    if not observing:
        return out
    if return_info:
        u, info = out
        extra = ({"hypergrad_error_estimate": info.hypergrad_error_estimate}
                 if info.hypergrad_error_estimate is not None else {})
        obs_events.jit_event_pair("backward_start", "backward_done", tags,
                                  iterations=info.iterations,
                                  residual=info.residual,
                                  converged=info.converged, **extra)
        return (u, info) if want_info else u
    obs_events.jit_event_pair("backward_start", "backward_done", tags)
    return out


def root_vjp(F: Callable, x_star, theta_args: tuple, cotangent,
             solve="normal_cg", tol: float = 1e-6, maxiter: int = 1000,
             ridge: float = 0.0, precond=None, sharding=None,
             backward: str = "exact", backward_iters: int = 8,
             error_estimate: bool = False, return_info: bool = False,
             system_operator=None):
    """VJP through the implicitly-defined root: returns vᵀ ∂x*(θ) per θ arg.

    Solve Aᵀ u = v  (A = -∂₁F),  then  vᵀJ = uᵀB  (B = ∂₂F).
    One linear solve serves all theta arguments (paper §2.1).

    ``solve`` is a registry name (``linear_solve.available_solvers()``) or a
    solver callable; ``precond`` is forwarded to registry solvers (``None``,
    a callable v ↦ M⁻¹v, ``"jacobi"``, or ``"block_jacobi"``).  Because
    every registry solver is vmap-safe with per-instance convergence masks,
    a ``jax.vmap`` of this function (or of an ``implicit_diff``-wrapped
    gradient) runs ONE batched masked solve for the whole batch, not N
    sequential solves.

    ``backward`` swaps the converged cotangent solve for a fixed-budget
    approximation (``"one_step"``/``"neumann_k"``/``"jacobian_free"``, see
    ``linear_solve.approx_inverse_apply``).  ``return_info=True`` returns
    ``(grads, SolveInfo)``; with ``error_estimate=True`` the info carries
    ``hypergrad_error_estimate = ‖v − Aᵀu‖/‖v‖`` at one extra matvec.
    """
    # A = -∂₁F(x*, θ) as a first-class operator: matvec is a JVP, rmatvec a
    # VJP, and choosing a symmetric-only solver certifies A = Aᵀ (so A.T is
    # A and the cotangent solve reuses the forward matvec).  ``sharding``
    # places it on a mesh (route_solve then dispatches the shard_map'd
    # solvers — no host gather).
    if backward != "exact":
        _check_approx_routing(precond, sharding)
    A = _implicit_system_operator(F, x_star, theta_args, solve, sharding,
                                  system_operator)
    out = _backward_apply(
        A.T, cotangent, solve=solve, tol=tol, maxiter=maxiter, ridge=ridge,
        precond=precond, backward=backward, backward_iters=backward_iters,
        batch_ndim=0 if sharding is None else sharding.batch_ndim,
        error_estimate=error_estimate, return_info=return_info,
        direction="vjp")
    u, info = out if return_info else (out, None)

    # uᵀ B = uᵀ ∂₂F : one more VJP, wrt the theta args.
    def f_of_theta(*targs):
        return F(x_star, *targs)

    _, vjp_theta = jax.vjp(f_of_theta, *theta_args)
    return ls._maybe_info(vjp_theta(u), info, return_info)


def root_jvp(F: Callable, x_star, theta_args: tuple, tangents: tuple,
             solve="normal_cg", tol: float = 1e-6, maxiter: int = 1000,
             ridge: float = 0.0, precond=None, sharding=None,
             backward: str = "exact", backward_iters: int = 8,
             error_estimate: bool = False, return_info: bool = False,
             system_operator=None):
    """JVP through the implicitly-defined root: J · v.

    Solve A (Jv) = B v  with  Bv = ∂₂F · v  computed by one JVP of F in θ.
    Vmap-safe (see ``root_vjp``): batching dispatches to one masked solve.
    ``backward``/``backward_iters``/``error_estimate``/``return_info``
    mirror ``root_vjp`` — the same fixed-budget approximation applied to
    the tangent system.
    """
    if backward != "exact":
        _check_approx_routing(precond, sharding)

    def f_of_theta(*targs):
        return F(x_star, *targs)

    _, Bv = jax.jvp(f_of_theta, theta_args, tangents)
    A = _implicit_system_operator(F, x_star, theta_args, solve, sharding,
                                  system_operator)
    out = _backward_apply(
        A, Bv, solve=solve, tol=tol, maxiter=maxiter, ridge=ridge,
        precond=precond, backward=backward, backward_iters=backward_iters,
        batch_ndim=0 if sharding is None else sharding.batch_ndim,
        error_estimate=error_estimate, return_info=return_info,
        direction="jvp")
    return out


# ---------------------------------------------------------------------------
# shared wrapper plumbing
# ---------------------------------------------------------------------------

def _merge_theta(nondiff_idx: Tuple[int, ...], nondiff_vals, diff_vals):
    """Reassemble the full ordered theta tuple from its split parts."""
    nd, dv = iter(nondiff_vals), iter(diff_vals)
    nondiff_set = set(nondiff_idx)
    total = len(nondiff_idx) + len(diff_vals)
    return tuple(next(nd) if i in nondiff_set else next(dv)
                 for i in range(total))


def _zero_tangent(primal):
    """A zero tangent for ``primal``: zeros for inexact leaves, ``float0``
    for integer/bool leaves (the tangent dtype JAX mandates for them)."""
    if jnp.issubdtype(jnp.result_type(primal), jnp.inexact):
        return jnp.zeros_like(primal)
    return np.zeros(jnp.shape(primal), jax.dtypes.float0)


def _aux_zero_tangents(aux):
    return jax.tree_util.tree_map(_zero_tangent, aux)


def _check_solver_arity(spec: ImplicitDiffSpec, n_theta: int):
    if spec.nondiff_argnums and spec.nondiff_argnums[-1] >= n_theta:
        raise ValueError(
            f"nondiff_argnums {spec.nondiff_argnums} out of range for a "
            f"solver called with {n_theta} theta argument(s)")


def _routes_matrix_free(solve, A, b, precond) -> bool:
    """Whether the routed registry solver touches the system only through
    matvecs (then named preconditioners must be derived up front from the
    operator); a materializing solver resolves them off its own dense
    matrix instead."""
    if callable(solve):
        return True     # route_solve rejects string preconds for callables
    name = ls._resolve_auto(A, b, precond=precond) if solve == "auto" \
        else solve
    return ls.get_spec(name).matrix_free


def _tangent_root_solve(spec: ImplicitDiffSpec, residual: Callable, x_star,
                        theta: tuple, nondiff_idx: Tuple[int, ...],
                        nondiff_vals, diff_theta: tuple, diff_dot: tuple,
                        *, transposable: bool):
    """Solve A dx = B θ̇ for the output tangent, optionally staged so that
    reverse mode can transpose it into the cotangent system Aᵀ u = v."""
    def F_of_diff_theta(*dts):
        return residual(x_star, *_merge_theta(nondiff_idx, nondiff_vals, dts))

    # B θ̇ : one JVP of F in the differentiable theta args (linear in θ̇,
    # built from transposable primitives — reverse mode pulls cotangents
    # back through it after the transpose solve).
    _, b = jax.jvp(F_of_diff_theta, tuple(diff_theta), tuple(diff_dot))

    if spec.sharding is not None:
        # Mesh-placed system: A (and Aᵀ) are ShardedOperators inheriting
        # the primal solution's specs; the solve runs under shard_map via
        # the sharded registry variants.  The solve stays on the native x
        # pytree — the ShardedOperator's spec trees ARE its placement, and
        # raveling would scramble them (see the spec docstring for the
        # resulting multi-leaf cotangent caveat).  custom_linear_solve
        # hands each direction a re-derived matvec closure; both directions
        # route the ORIGINAL operator (forward) / its declared transpose
        # instead, so the placement and flags travel into routing intact.
        def F_diff(x, *dts):
            return residual(x, *_merge_theta(nondiff_idx, nondiff_vals,
                                             dts))

        A = _implicit_system_operator(F_diff, x_star, diff_theta,
                                      spec.solve, spec.sharding)
        # String preconditioners ("jacobi"/"block_jacobi") stay strings
        # here, unlike the unsharded branch's derive-once optimization:
        # deriving outside shard_map would bake the GLOBAL diagonal into a
        # closure that the per-shard solver then applies to LOCAL shards
        # (shape mismatch / replicated capture).  Each direction's template
        # resolves the string inside shard_map from its local operator —
        # per-shard probing, correct by construction.
        routing = spec.routing_kwargs()
        if spec.backward != "exact":
            # Approximate backward on the mesh: the polynomial apply is
            # nothing but matvecs of A / Aᵀ — each one a shard_map'd
            # per-shard JVP with the operator's psum hook, so the Neumann
            # terms ride the exact path's collectives (no new ones).
            _check_approx_routing(spec.precond, spec.sharding)
            approx = dict(spec.backward_kwargs(), ridge=spec.ridge,
                          precond=spec.precond,
                          batch_ndim=spec.sharding.batch_ndim)
            if not transposable:
                return ls.approx_inverse_apply(A, b, **approx)

            def sharded_approx(_matvec, rhs):
                return ls.approx_inverse_apply(A, rhs, **approx)

            def sharded_approx_transpose(_vecmat, rhs):
                return ls.approx_inverse_apply(A.T, rhs, **approx)

            return lax.custom_linear_solve(
                A.matvec, b, solve=sharded_approx,
                transpose_solve=sharded_approx_transpose,
                symmetric=bool(A.symmetric))
        if not transposable:
            return ls.route_solve(spec.solve, A, b, **routing)

        def sharded_solve(_matvec, rhs):
            return ls.route_solve(spec.solve, A, rhs, **routing)

        def sharded_transpose_solve(_vecmat, rhs):
            return ls.route_solve(spec.solve, A.T, rhs, **routing)

        return lax.custom_linear_solve(
            A.matvec, b, solve=sharded_solve,
            transpose_solve=sharded_transpose_solve,
            symmetric=bool(A.symmetric))

    # One JacobianOperator per direction: A = -∂₁F(x*, θ), with the
    # symmetry certificate picked up at construction (see
    # ``_implicit_system_operator``).  A spec-level system_operator factory
    # (the stochastic layer's sampled Hessian) replaces the construction;
    # B θ̇ above stays the exact ∂₂F — only A is sampled.
    A = _implicit_system_operator(residual, x_star, theta, spec.solve,
                                  system_operator=spec.system_operator)

    if spec.backward != "exact" and not transposable:
        return ls.approx_inverse_apply(
            A, b, ridge=spec.ridge, precond=spec.precond,
            **spec.backward_kwargs())

    if not transposable:
        return ls.route_solve(spec.solve, A, b, **spec.routing_kwargs())

    # The transposable system runs on the operator's raveled view, not the
    # x pytree: jax's linear_solve transpose rule binds per-leaf cotangents
    # without instantiating symbolic zeros, so a downstream loss touching
    # only some x* leaves would feed Zero into the bind.  A single leaf is
    # either fully skipped (all-zero cotangent) or fully instantiated.
    flat = A.raveled()
    routing = spec.routing_kwargs()
    precond = routing["precond"]
    if callable(precond):
        # user preconditioners keep their x-pytree contract
        routing["precond"] = flat.ravel_fn(precond)
    elif precond in ("jacobi", "block_jacobi") and \
            (spec.backward != "exact"
             or _routes_matrix_free(spec.solve, A, b, precond)):
        # matrix-free route: derive ONCE from the operator's structure
        # (diagonal / leaf blocks) instead of probing inside each
        # direction's template.  Materializing solvers (dense_gmres) keep
        # the string — they read diag/blocks off their own dense matrix
        # for free, so probing here would be redundant work.  The
        # approximate modes have no materializing solver in the loop, so
        # they always take the derive-once path.
        damped = ops.RidgeShifted(A, routing["ridge"]) if routing["ridge"] \
            else A
        make = (ops.jacobi_preconditioner_from if precond == "jacobi"
                else ops.block_jacobi_preconditioner)
        routing["precond"] = flat.ravel_fn(make(damped))

    if spec.backward != "exact":
        # Same custom_linear_solve scaffold as the exact route, with the
        # registry solver swapped for the fixed-budget polynomial apply.
        # custom_linear_solve swaps solve/transpose_solve when transposed —
        # the transpose direction's closure computes Aᵀ·, so the SAME
        # polynomial serves both the tangent and the cotangent system.
        approx = dict(spec.backward_kwargs(), ridge=routing["ridge"],
                      precond=routing["precond"])

        def approx_apply(matvec, rhs):
            return ls.approx_inverse_apply(matvec, rhs, **approx)

        dx_flat = lax.custom_linear_solve(
            flat.matvec, flat.ravel(b), solve=approx_apply,
            transpose_solve=approx_apply, symmetric=bool(A.symmetric))
        return flat.unravel(dx_flat)

    def registry_solve(matvec, rhs):
        # custom_linear_solve hands each direction its own matvec closure;
        # re-wrap it so the operator's flags travel into routing
        op = ops.FunctionOperator(matvec, rhs, symmetric=A.symmetric,
                                  positive_definite=A.positive_definite)
        return ls.route_solve(spec.solve, op, rhs, **routing)

    # custom_linear_solve makes the solve reverse-transposable: the declared
    # transpose direction routes Aᵀu = v through the SAME registry solver.
    # A symmetric operator (certified by a symmetric-only routed solver —
    # cg/pallas_cg) lets the transpose template reuse the forward matvec.
    dx_flat = lax.custom_linear_solve(
        flat.matvec, flat.ravel(b), solve=registry_solve,
        transpose_solve=registry_solve, symmetric=bool(A.symmetric))
    return flat.unravel(dx_flat)


# ---------------------------------------------------------------------------
# the three wrapping strategies
# ---------------------------------------------------------------------------

def _wrap_jvp(spec: ImplicitDiffSpec, solver: Callable, *,
              transposable: bool):
    """custom_jvp wrapping; ``transposable=True`` is the mode-polymorphic
    form (forward AND reverse), ``False`` the forward-only form."""
    residual = spec.residual_fun
    nondiff_idx = spec.nondiff_argnums
    jax_nondiff = tuple(i + 1 for i in nondiff_idx)   # shift past ``init``

    @functools.wraps(solver)
    def solver_like(init, *theta):
        return solver(init, *theta)

    fun = jax.custom_jvp(solver_like, nondiff_argnums=jax_nondiff)

    def jvp_rule(*args):
        nondiff_vals = args[:len(nondiff_idx)]
        primals, tangents = args[len(nondiff_idx):]
        init, *diff_theta = primals
        _, *diff_dot = tangents          # init tangent is ignored: x*(θ)
        theta = _merge_theta(nondiff_idx, nondiff_vals, diff_theta)
        _check_solver_arity(spec, len(theta))
        out = solver(init, *theta)
        x_star = out[0] if spec.has_aux else out
        dx = _tangent_root_solve(spec, residual, x_star, theta, nondiff_idx,
                                 nondiff_vals, tuple(diff_theta),
                                 tuple(diff_dot), transposable=transposable)
        if spec.has_aux:
            return out, (dx, _aux_zero_tangents(out[1]))
        return out, dx

    fun.defjvp(jvp_rule)
    return fun


def _wrap_vjp(spec: ImplicitDiffSpec, solver: Callable):
    """custom_vjp wrapping (reverse-only)."""
    residual = spec.residual_fun
    nondiff_idx = spec.nondiff_argnums
    jax_nondiff = tuple(i + 1 for i in nondiff_idx)

    @functools.wraps(solver)
    def solver_like(init, *theta):
        return solver(init, *theta)

    fun = jax.custom_vjp(solver_like, nondiff_argnums=jax_nondiff)

    def fwd(*args):
        nondiff_vals = args[:len(nondiff_idx)]
        init, *diff_theta = args[len(nondiff_idx):]
        theta = _merge_theta(nondiff_idx, nondiff_vals, tuple(diff_theta))
        _check_solver_arity(spec, len(theta))
        out = solver(init, *theta)
        x_star = out[0] if spec.has_aux else out
        return out, (init, x_star, tuple(diff_theta))

    def bwd(*args):
        nondiff_vals = args[:len(nondiff_idx)]
        res, cotangent = args[len(nondiff_idx):]
        init, x_star, diff_theta = res
        ct = cotangent[0] if spec.has_aux else cotangent

        def F_diff(x, *dts):
            return residual(x, *_merge_theta(nondiff_idx, nondiff_vals, dts))

        grads = root_vjp(F_diff, x_star, diff_theta, ct, solve=spec.solve,
                         sharding=spec.sharding,
                         system_operator=spec.system_operator,
                         **spec.routing_kwargs(), **spec.backward_kwargs())
        zero_init = jax.tree_util.tree_map(jnp.zeros_like, init)
        return (zero_init,) + tuple(grads)

    fun.defvjp(fwd, bwd)
    return fun


# ---------------------------------------------------------------------------
# the entry point
# ---------------------------------------------------------------------------

MODES = ("auto", "vjp", "jvp")


def implicit_diff(spec: Union[ImplicitDiffSpec, Callable, None] = None, *,
                  mode: str = "auto", **spec_kwargs) -> Callable:
    """Attach implicit differentiation to a solver, per an ``ImplicitDiffSpec``.

    ``implicit_diff(spec)(solver)`` returns a function with the solver's
    signature ``(init, *theta)`` whose derivatives in every differentiable
    ``theta`` argument come from the implicit function theorem on the
    spec's optimality mapping — never from differentiating through the
    solver's iterations.  ``init`` gets a zero derivative.

    With the default ``mode="auto"`` the SAME wrapped function supports
    ``jax.grad`` / ``jax.jacrev`` / ``jax.jvp`` / ``jax.jacfwd`` (and
    ``jax.vmap`` of any of them batches the linear solve into ONE masked
    registry solve).  ``mode="jvp"`` / ``mode="vjp"`` force a single-mode
    wrapping (see module docstring for when to prefer them).

    ``spec`` may be an ``ImplicitDiffSpec``, a bare callable (treated as
    ``optimality_fun``), or ``None`` with the spec's fields given as
    keyword arguments; keyword arguments on top of a spec/callable are
    per-call overrides::

        spec = ImplicitDiffSpec(optimality_fun=F, solve="cg")
        solver = implicit_diff(spec)(my_solver)             # both modes
        fast = implicit_diff(spec, solve="neumann", maxiter=8)(my_solver)

        @implicit_diff(jax.grad(f), solve="cg")             # F shorthand
        def ridge_solver(init, theta): ...
    """
    if isinstance(spec, ImplicitDiffSpec):
        spec = spec.replace(**spec_kwargs) if spec_kwargs else spec
    elif callable(spec):
        spec = ImplicitDiffSpec(optimality_fun=spec, **spec_kwargs)
    elif spec is None:
        spec = ImplicitDiffSpec(**spec_kwargs)
    else:
        raise TypeError("spec must be an ImplicitDiffSpec, a callable "
                        f"optimality_fun, or None; got {type(spec)!r}")
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; expected one of {MODES}")
    if spec.is_routing_only:
        raise ValueError("routing-only ImplicitDiffSpec: set optimality_fun "
                         "or fixed_point_fun to wrap a solver")

    def wrapper(solver: Callable) -> Callable:
        if mode == "vjp":
            fun = _wrap_vjp(spec, solver)
        else:
            fun = _wrap_jvp(spec, solver, transposable=(mode == "auto"))
        fun.spec = spec
        fun.mode = mode
        return fun

    return wrapper
