"""Proximity operators (paper Appendix C.2).

All are closed-form jnp compositions → differentiable a.e. by autodiff.
Signature convention: ``prox(y, hyperparams, scaling=1.0)`` computes

    argmin_x  (1/2)||x − y||² + scaling · g(x, hyperparams).
"""
from __future__ import annotations

import jax.numpy as jnp


def prox_none(y, hyperparams=None, scaling=1.0):
    del hyperparams, scaling
    return y


def prox_lasso(y, lam=1.0, scaling=1.0):
    """Soft thresholding: prox of scaling·λ‖x‖₁ (λ may be per-coordinate)."""
    thr = scaling * lam
    return jnp.sign(y) * jnp.maximum(jnp.abs(y) - thr, 0.0)


def prox_non_negative_lasso(y, lam=1.0, scaling=1.0):
    return jnp.maximum(y - scaling * lam, 0.0)


def prox_elastic_net(y, hyperparams=(1.0, 1.0), scaling=1.0):
    """prox of scaling·(λ‖x‖₁ + (γ/2)‖x‖²)."""
    lam, gamma = hyperparams
    st = prox_lasso(y, lam, scaling)
    return st / (1.0 + scaling * gamma)


def prox_ridge(y, gamma=1.0, scaling=1.0):
    return y / (1.0 + scaling * gamma)


def prox_group_lasso(y, lam=1.0, scaling=1.0):
    """Block soft thresholding on the last axis (one group per row)."""
    thr = scaling * lam
    norm = jnp.linalg.norm(y, axis=-1, keepdims=True)
    scale = jnp.maximum(1.0 - thr / jnp.maximum(norm, 1e-30), 0.0)
    return scale * y


def prox_log_barrier(y, mu=1.0, scaling=1.0):
    """prox of −scaling·μ Σ log(xᵢ): positive root of x² − xy − sμ = 0."""
    s = scaling * mu
    return 0.5 * (y + jnp.sqrt(y * y + 4.0 * s))


PROX_OPERATORS = {
    "none": prox_none,
    "lasso": prox_lasso,
    "nn_lasso": prox_non_negative_lasso,
    "elastic_net": prox_elastic_net,
    "ridge": prox_ridge,
    "group_lasso": prox_group_lasso,
    "log_barrier": prox_log_barrier,
}
