"""Architecture config system.

One ``ArchConfig`` describes an LM-family backbone.  Every assigned arch gets
a module ``repro.configs.<id>`` exporting ``CONFIG`` (exact published config)
and ``SMOKE_CONFIG`` (same family, tiny).  ``registry.get(name)`` resolves
``--arch <id>`` CLI flags.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # routed experts
    num_shared_experts: int = 0     # DeepSeek-style always-on experts
    top_k: int = 2
    expert_d_ff: int = 0            # per-expert FFN width
    router_aux_loss: float = 0.001  # load-balancing loss weight


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_size: int = 64            # per-channel recurrent state (Mamba2)
    conv_width: int = 4
    expand: int = 2
    num_heads: int = 0              # Mamba2 value heads (0 = d_inner/state)
    head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    # block types per layer for hybrids: 'attn' | 'rwkv' | 'mamba' | 'shared_attn'
    block_pattern: Optional[Tuple[str, ...]] = None
    mlp_activation: str = "silu"    # silu | gelu | relu2 (squared ReLU)
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False             # multimodal rotary (Qwen2-VL)
    tie_embeddings: bool = False
    causal: bool = True             # False => encoder-only (HuBERT)
    has_decoder: bool = True        # False => no serve_step decode shapes
    # MLA (DeepSeek-V2) options
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    moe_layer_start: int = 0        # DeepSeek: first k layers dense
    norm_eps: float = 1e-5
    # frontends ([vlm]/[audio]) are stubs: inputs arrive as embeddings
    embedding_frontend: str = "tokens"   # tokens | stub_embeddings
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic context handling (SSM / hybrid) — long_500k cells."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline MODEL_FLOPS)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        n = V * d                      # embedding
        if not self.tie_embeddings:
            n += V * d                 # unembedding
        pattern = self.block_pattern or self._default_pattern()
        for kind in pattern:
            n += 2 * d                 # norms (pre-attn + pre-mlp, RMS)
            if kind in ("attn", "shared_attn"):
                if self.use_mla:
                    r_kv, r_q = self.kv_lora_rank, (self.q_lora_rank or d)
                    qk = self.qk_rope_head_dim + self.qk_nope_head_dim
                    n += d * r_q + r_q * self.num_heads * qk
                    n += d * (r_kv + self.qk_rope_head_dim)
                    n += r_kv * self.num_heads * (self.qk_nope_head_dim
                                                  + self.v_head_dim)
                    n += self.num_heads * self.v_head_dim * d
                else:
                    n += d * self.num_heads * hd          # Q
                    n += 2 * d * self.num_kv_heads * hd   # K, V
                    n += self.num_heads * hd * d          # O
            elif kind == "rwkv":
                n += 4 * d * d + 2 * d * d // 1          # r,k,v,o + w,u approx
            elif kind == "mamba":
                s = self.ssm or SSMConfig()
                d_in = s.expand * d
                n += d * 2 * d_in + d_in * d + d_in * (2 * s.state_size)
            # MLP
            if kind == "mamba":
                pass                                      # mamba block has no extra MLP
            elif self.moe and kind != "dense_mlp_only":
                m = self.moe
                act = d * m.expert_d_ff * 3
                n += m.num_experts * act + m.num_shared_experts * act
                n += d * m.num_experts                    # router
            else:
                mult = 3 if self.mlp_activation == "silu" else 2
                n += mult * d * self.d_ff
        if self.family == "hybrid":
            # one weight-shared attention (+MLP) block reused across depth
            hd = self.resolved_head_dim
            n += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
                + self.num_heads * hd * d
            n += (3 if self.mlp_activation == "silu" else 2) * d * self.d_ff
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared only)."""
        if not self.moe:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        act = 3 * self.d_model * m.expert_d_ff
        inactive = (m.num_experts - m.top_k) * act * self.num_layers
        return full - inactive

    def _default_pattern(self) -> Tuple[str, ...]:
        if self.family == "ssm":
            return ("rwkv",) * self.num_layers
        if self.family == "hybrid":
            return ("mamba",) * self.num_layers
        return ("attn",) * self.num_layers


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY = {}


def register(config: ArchConfig, smoke: ArchConfig):
    _REGISTRY[config.name] = (config, smoke)
    return config


def get(name: str, smoke: bool = False) -> ArchConfig:
    try:
        full, small = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}") \
            from None
    return small if smoke else full


def names():
    return sorted(_REGISTRY)
