"""Llama-3-405B [arXiv:2407.21783] — dense GQA, 128k vocab, SwiGLU."""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
    d_ff=53248, vocab_size=128256, mlp_activation="silu",
    rope_theta=500000.0)

SMOKE_CONFIG = ArchConfig(
    name="llama3-405b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
    d_ff=192, vocab_size=512, mlp_activation="silu",
    rope_theta=500000.0)

register(CONFIG, SMOKE_CONFIG)
