"""DeepSeek-V2-236B [arXiv:2405.04434] — MLA (kv_lora=512),
2 shared + 160 routed experts top-6; first layer dense."""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=1536, vocab_size=102400, mlp_activation="silu",
    use_mla=True, kv_lora_rank=512, q_lora_rank=1536,
    qk_rope_head_dim=64, qk_nope_head_dim=128, v_head_dim=128,
    moe=MoEConfig(num_experts=160, num_shared_experts=2, top_k=6,
                  expert_d_ff=1536))

SMOKE_CONFIG = ArchConfig(
    name="deepseek-v2-236b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=96, vocab_size=512, mlp_activation="silu",
    use_mla=True, kv_lora_rank=32, q_lora_rank=48,
    qk_rope_head_dim=16, qk_nope_head_dim=32, v_head_dim=32,
    moe=MoEConfig(num_experts=8, num_shared_experts=2, top_k=2,
                  expert_d_ff=96))

register(CONFIG, SMOKE_CONFIG)
