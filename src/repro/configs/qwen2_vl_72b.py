"""Qwen2-VL-72B [arXiv:2409.12191] — VLM backbone with M-RoPE.

The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings; the backbone transformer is fully implemented
with multimodal rotary position embeddings (t/h/w sections)."""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064, mlp_activation="silu", qkv_bias=True,
    mrope=True, rope_theta=1000000.0,
    embedding_frontend="stub_embeddings")

SMOKE_CONFIG = ArchConfig(
    name="qwen2-vl-72b-smoke", family="vlm",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, mlp_activation="silu", qkv_bias=True,
    mrope=True, embedding_frontend="stub_embeddings")

register(CONFIG, SMOKE_CONFIG)
