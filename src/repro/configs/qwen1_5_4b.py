"""Qwen1.5-4B [hf:Qwen] — dense MHA (kv == q heads) with QKV bias."""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="qwen1.5-4b", family="dense",
    num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
    d_ff=6912, vocab_size=151936, mlp_activation="silu", qkv_bias=True)

SMOKE_CONFIG = ArchConfig(
    name="qwen1.5-4b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=192, vocab_size=512, mlp_activation="silu", qkv_bias=True)

register(CONFIG, SMOKE_CONFIG)
