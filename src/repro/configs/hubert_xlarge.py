"""HuBERT-XLarge [arXiv:2106.07447] — encoder-only audio transformer.

The audio frontend (CNN feature extractor) is a STUB per the assignment:
input_specs() provides precomputed frame embeddings.  Encoder-only: no
decode shapes (noted in DESIGN.md)."""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504, mlp_activation="gelu",
    causal=False, has_decoder=False,
    embedding_frontend="stub_embeddings")

SMOKE_CONFIG = ArchConfig(
    name="hubert-xlarge-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=128, mlp_activation="gelu",
    causal=False, has_decoder=False,
    embedding_frontend="stub_embeddings")

register(CONFIG, SMOKE_CONFIG)
