"""Granite-MoE-3B-A800M [hf:ibm-granite] — 40 experts, top-8, d_ff=512/expert."""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49155, mlp_activation="silu",
    moe=MoEConfig(num_experts=40, top_k=8, expert_d_ff=512))

SMOKE_CONFIG = ArchConfig(
    name="granite-moe-3b-a800m-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=64, vocab_size=512, mlp_activation="silu",
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=64))

register(CONFIG, SMOKE_CONFIG)
