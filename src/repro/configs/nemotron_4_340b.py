"""Nemotron-4-340B [arXiv:2402.16819] — dense GQA, squared-ReLU MLP."""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
    d_ff=73728, vocab_size=256000, mlp_activation="relu2",
    rope_theta=10000.0)

SMOKE_CONFIG = ArchConfig(
    name="nemotron-4-340b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, mlp_activation="relu2")

register(CONFIG, SMOKE_CONFIG)
