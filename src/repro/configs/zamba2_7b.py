"""Zamba2-7B [arXiv:2411.15242] — Mamba2 trunk + weight-shared attention
blocks (hybrid; runs the long_500k cell)."""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, mlp_activation="silu",
    ssm=SSMConfig(state_size=64, conv_width=4, expand=2, head_dim=64))

SMOKE_CONFIG = ArchConfig(
    name="zamba2-7b-smoke", family="hybrid",
    num_layers=3, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, mlp_activation="silu",
    ssm=SSMConfig(state_size=16, conv_width=4, expand=2, head_dim=32))

register(CONFIG, SMOKE_CONFIG)
