"""RWKV-6 "Finch" 3B [arXiv:2404.05892] — attention-free, data-dependent
decay, O(1)-state decode (runs the long_500k cell)."""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40,
    d_ff=8960, vocab_size=65536)

SMOKE_CONFIG = ArchConfig(
    name="rwkv6-3b-smoke", family="ssm",
    num_layers=2, d_model=128, num_heads=2, num_kv_heads=2,
    d_ff=448, vocab_size=512)

register(CONFIG, SMOKE_CONFIG)
