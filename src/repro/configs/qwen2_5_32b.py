"""Qwen2.5-32B [hf:Qwen] — dense GQA with QKV bias."""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="qwen2.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=27648, vocab_size=152064, mlp_activation="silu", qkv_bias=True,
    rope_theta=1000000.0)

SMOKE_CONFIG = ArchConfig(
    name="qwen2.5-32b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=192, vocab_size=512, mlp_activation="silu", qkv_bias=True)

register(CONFIG, SMOKE_CONFIG)
