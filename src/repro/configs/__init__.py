"""Assigned architecture configs.  Import registers every arch."""
from repro.configs import base
from repro.configs import (nemotron_4_340b, llama3_405b, qwen2_5_32b,
                           qwen1_5_4b, qwen2_vl_72b, rwkv6_3b,
                           granite_moe_3b_a800m, deepseek_v2_236b,
                           zamba2_7b, hubert_xlarge)
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, get, names

# CLI alias map: --arch <id> uses the published names with dashes/dots
ALIASES = {
    "nemotron-4-340b": "nemotron-4-340b",
    "llama3-405b": "llama3-405b",
    "qwen2.5-32b": "qwen2.5-32b",
    "qwen1.5-4b": "qwen1.5-4b",
    "qwen2-vl-72b": "qwen2-vl-72b",
    "rwkv6-3b": "rwkv6-3b",
    "granite-moe-3b-a800m": "granite-moe-3b-a800m",
    "deepseek-v2-236b": "deepseek-v2-236b",
    "zamba2-7b": "zamba2-7b",
    "hubert-xlarge": "hubert-xlarge",
}
