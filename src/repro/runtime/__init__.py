"""Runtime layer: training loop, fault tolerance, and the serving stack.

Serving has two front ends: ``repro.runtime.serving`` (token-level
continuous batching for LM decode) and ``repro.runtime.solve_service`` (the
continuous-batching implicit-diff solve service — independent solve and
hypergradient requests aggregated into batched masked solves, with a
warm-start cache).
"""
from repro.runtime.solve_service import (SolveService, ServiceResult,
                                         WarmStartCache, BucketKey,
                                         bucket_capacity)
from repro.runtime.train_loop import (TrainState, TrainStepConfig,
                                      make_train_state, make_train_step,
                                      make_prefill_step, make_decode_step)
from repro.runtime.train_loop import train_loop as run_train_loop
from repro.runtime.fault_tolerance import (StragglerMonitor, HeartbeatRegistry,
                                           PreemptionHandler, ElasticPlan)
# keep the submodule accessible as repro.runtime.train_loop
from repro.runtime import train_loop as _tl_module
import sys as _sys
_sys.modules[__name__ + ".train_loop"] = _tl_module
