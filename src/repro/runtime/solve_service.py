"""Continuous-batching implicit-diff solve service with a warm-start cache.

The batched masked-solve engine (``repro.core.linear_solve``) is 20–80x
faster than looped solves — but only if a single caller hands it a
pre-batched problem.  This module is the missing front end for serving that
capability to *independent* concurrent callers: requests for linear solves
and implicit hypergradients are aggregated into **shape buckets** and each
bucket is dispatched as ONE batched masked solve through the
``route_solve`` + ``LinearOperator`` path.

Design (mirrors the ``ContinuousBatchingEngine`` slot discipline in
``repro.runtime.serving``, and the bucket-by-size batching idiom of
tensor2tensor's ``data_reader``):

  * **Bucketing** — requests are keyed by
    ``(d, solver, precond, symmetric/PD flags, dtype, tol, maxiter, ridge)``
    (``BucketKey``); everything in one bucket is mathematically one batched
    block-diagonal system, so one masked ``lax.while_loop`` serves all of it
    with per-instance convergence.
  * **Fixed compiled shapes** — buckets are padded to power-of-two
    capacities (``bucket_capacity``) with identity systems and zero
    right-hand sides; padded slots converge at loop entry, so their cost is
    ~zero and the compiled batch shape never changes during serving (no
    recompilation under traffic — the property that matters on TPU).  The
    set of compiled ``(key, capacity)`` programs is tracked in
    ``metrics["compiled"]``.
  * **Warm-start cache** — a ``WarmStartCache`` keyed by a problem
    fingerprint (operator sketch + rhs sketch, quantized so repeat/nearby
    problems collide on purpose) with LRU eviction and hit-rate counters.
    A hit seeds the request's slot with the cached solution (``init``), so
    repeat traffic — the common case under load — starts near the answer.
  * **Per-request diagnostics** — every request resolves to a
    ``ServiceResult`` carrying the solution, its own ``SolveInfo`` slice
    (exact per-instance iteration counts: masked batching preserves each
    instance's solo trajectory), queue/dispatch latency, bucket occupancy
    and cache provenance.

Hypergradient requests (``submit_hypergrad``) batch the *linear-solve* step
of implicit differentiation — the dominant, amortizable cost (cf.
"Efficient Automatic Differentiation of Implicit Functions"): the implicit
system ``Aᵀ u = v`` (``A = -∂₁F`` at ``x*``) joins a bucket like any other
solve, and the cheap per-request θ-VJP ``θ̄ = Bᵀu`` runs at completion.

Quickstart::

    from repro.runtime import SolveService

    svc = SolveService()                      # warm-start cache on
    futs = [svc.submit(A_i, b_i) for i in range(64)]   # e.g. (d, d) SPD
    svc.flush()                               # ONE batched masked solve
    results = [f.result() for f in futs]      # ServiceResult each
    results[0].info.iterations, svc.metrics["cache_hits"]

``docs/serving.md`` is the full reference (request lifecycle, bucketing
rules, warm-start semantics, metrics glossary).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import itertools
import json
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core import linear_solve as ls
from repro.core import operators as ops
from repro.core.linear_solve import MAX_DENSE_DIM, SolveInfo
from repro.observability import events as obs_events
from repro.observability import spans as obs_spans
from repro.observability.metrics import LATENCY_BUCKETS, MetricsRegistry

# "argument not given" marker, distinct from None: an explicit ``None`` is a
# real override (e.g. ``precond=None`` clears a spec's preconditioner).
_UNSET = object()


class BucketKey(NamedTuple):
    """The bucket identity: requests sharing a key batch into one solve.

    Every field participates in compiled-program identity — two requests
    with the same key run through the SAME jitted dispatch function at some
    fixed capacity, so serving steady traffic never recompiles.
    """
    d: int                       # instance dimension (raveled)
    solver: str                  # resolved registry solver name
    precond: Optional[str]       # None | "jacobi" | "block_jacobi"
    symmetric: Optional[bool]    # operator's declared symmetry flag
    positive_definite: bool      # operator's declared PD flag
    dtype: str                   # promoted result dtype of (A, b)
    tol: float                   # solve controls are part of the program
    maxiter: int
    ridge: float
    # approximate-backward arm: exact and approximate hypergradient traffic
    # never share a compiled program ("exact" | "one_step" | "neumann_k" |
    # "jacobian_free"; backward_iters is the neumann_k depth, 0 otherwise)
    backward: str = "exact"
    backward_iters: int = 0


def _bucket_label(key: BucketKey) -> str:
    """Compact, stable bucket tag for spans/events (trace breakdowns)."""
    label = f"{key.solver}:d={key.d}:{key.dtype}"
    if key.backward != "exact":
        label += f":{key.backward}"
    return label


def bucket_capacity(n: int, max_batch: int = 64) -> int:
    """Pad a bucket of ``n`` requests to its fixed compiled capacity.

    Power-of-two capacities clamped to ``max_batch`` — a handful of
    compiled programs per ``BucketKey`` covers every load level, and a
    given traffic mix reuses the same programs forever (no recompilation
    during serving).
    """
    if n < 1:
        raise ValueError(f"bucket needs at least one request, got n={n}")
    cap = 1
    while cap < n:
        cap *= 2
    return min(cap, max_batch)


@dataclasses.dataclass
class ServiceResult:
    """What a request's ``Future`` resolves to.

    ``x`` is the request's payload — the solution for a solve request (host
    numpy for a flat ``(d,)`` rhs, the unraveled pytree otherwise), the
    per-θ-argument gradient tuple for a hypergradient request.
    ``info`` is this request's own ``SolveInfo`` slice out of the batched
    dispatch (masked batching preserves each instance's solo iteration
    count).  ``queue_time``/``solve_time`` are seconds spent waiting for a
    flush / inside the batched dispatch; ``bucket_size``/``bucket_capacity``
    expose the occupancy of the dispatch that served this request;
    ``warm_start`` says whether a cached solution seeded the slot.
    """
    uid: int
    x: Any
    info: SolveInfo
    queue_time: float
    solve_time: float
    bucket_size: int
    bucket_capacity: int
    warm_start: bool


@dataclasses.dataclass
class _PendingRequest:
    """Internal queue entry: one admitted, not-yet-dispatched request."""
    uid: int
    key: BucketKey
    A: np.ndarray                # (d, d) materialized operator (host)
    b: np.ndarray                # (d,) raveled right-hand side (host)
    unravel: Optional[Callable]  # flat (d,) -> pytree; None = flat rhs
    future: Future
    fingerprint: Optional[str]   # warm-start cache key (None: cache off)
    init: Optional[np.ndarray]   # cached warm-start solution, if any
    finish: Optional[Callable]   # post-solve hook (hypergrad θ-VJP)
    enqueue_t: float = 0.0
    admit_t: float = 0.0         # admission start (span tracing)


class WarmStartCache:
    """LRU cache of solved systems keyed by a quantized problem fingerprint.

    The fingerprint is a sketch — ``A @ p`` for a fixed per-``d`` probe
    vector ``p``, concatenated with ``b``, normalized and quantized to
    ``qtol`` relative resolution, then hashed.  Exact repeats always
    collide; *nearby* problems (relative perturbation ≲ ``qtol``) usually
    collide, which is the point: under heavy traffic the same and
    slightly-drifted systems recur, and a hit seeds the solver with the
    previous solution so it starts near the answer.  A spurious collision
    only costs a worse initial guess — never a wrong answer (the solver
    still iterates to ``tol``).

    ``hits`` / ``misses`` / ``evictions`` counters and ``hit_rate`` are
    read by the service metrics.  All operations are thread-safe: the
    cache is shared between submitter threads (lookups at admission) and
    the scheduler thread (inserts at dispatch).

    ``save(path)`` / ``WarmStartCache.load(path)`` persist the cache as a
    version-stamped ``.npz`` (fingerprints + solutions + the ``BucketKey``
    provenance of each entry), so warm starts survive service restarts.
    """

    _SAVE_VERSION = 1

    def __init__(self, capacity: int = 256, qtol: float = 1e-3,
                 seed: int = 1234):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.qtol = float(qtol)
        self._seed = int(seed)
        self._mutex = threading.Lock()
        self._store: "collections.OrderedDict[str, np.ndarray]" = \
            collections.OrderedDict()
        self._keys: dict = {}       # fingerprint -> BucketKey provenance
        self._probes: dict = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def _probe(self, d: int) -> np.ndarray:
        """The fixed unit probe vector for dimension ``d`` (built once)."""
        with self._mutex:
            p = self._probes.get(d)
            if p is None:
                rng = np.random.default_rng(self._seed + d)
                p = rng.standard_normal(d)
                p /= np.linalg.norm(p)
                self._probes[d] = p
            return p

    def fingerprint(self, A, b, key: BucketKey) -> str:
        """Hash a problem to its cache key.

        The sketch ``[A @ p, b]`` identifies the operator's action and the
        right-hand side without hashing all of ``A``; quantizing by
        ``qtol`` relative to the sketch norm folds nearby problems onto one
        key.  The ``BucketKey`` participates so distinct solver routings
        never share warm starts of mismatched meaning.
        """
        A = np.asarray(A, np.float64)
        b = np.asarray(b, np.float64)
        sketch = np.concatenate([A @ self._probe(A.shape[-1]), b])
        scale = float(np.linalg.norm(sketch))
        if not np.isfinite(scale) or scale == 0.0:
            scale = 1.0
        q = np.round(sketch / (scale * self.qtol)).astype(np.int64)
        h = hashlib.blake2b(digest_size=16)
        h.update(repr(key).encode())
        h.update(q.tobytes())
        return h.hexdigest()

    def get(self, fingerprint: str) -> Optional[np.ndarray]:
        """Look up a warm start; counts a hit or a miss and refreshes LRU."""
        with self._mutex:
            x = self._store.get(fingerprint)
            if x is None:
                self.misses += 1
                return None
            self.hits += 1
            self._store.move_to_end(fingerprint)
            return x

    def put(self, fingerprint: str, x, key: Optional[BucketKey] = None) -> \
            None:
        """Insert/refresh a solution; evicts the LRU entry over capacity.

        ``key`` records the entry's ``BucketKey`` provenance — carried
        through ``save``/``load`` so a restored cache knows what routing
        produced each solution.
        """
        with self._mutex:
            self._store[fingerprint] = np.asarray(x)
            self._store.move_to_end(fingerprint)
            if key is not None:
                self._keys[fingerprint] = key
            while len(self._store) > self.capacity:
                evicted, _ = self._store.popitem(last=False)
                self._keys.pop(evicted, None)
                self.evictions += 1

    def __len__(self) -> int:
        """Number of cached solutions currently resident."""
        with self._mutex:
            return len(self._store)

    @property
    def hit_rate(self) -> float:
        """Fraction of lookups served from cache (0.0 when none yet)."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def save(self, path) -> str:
        """Persist the cache contents to ``path`` as version-stamped ``.npz``.

        Layout: ``format_version``/``qtol``/``seed`` scalars, a
        ``fingerprints`` string array, one ``solution_{i}`` array per entry
        (solutions may differ in ``d``), and a ``bucket_keys`` string array
        of JSON-encoded ``BucketKey`` provenance ("" when unknown).
        Returns the path written (numpy may append ``.npz``).
        """
        path = str(path)
        if not path.endswith(".npz"):
            path += ".npz"
        with self._mutex:
            items = list(self._store.items())
            keys = dict(self._keys)
        payload = {
            "format_version": np.asarray(self._SAVE_VERSION),
            "qtol": np.asarray(self.qtol),
            "seed": np.asarray(self._seed),
            "capacity": np.asarray(self.capacity),
            "fingerprints": np.asarray([fp for fp, _ in items]),
            "bucket_keys": np.asarray(
                [json.dumps(keys[fp]._asdict()) if fp in keys else ""
                 for fp, _ in items]),
        }
        for i, (_, x) in enumerate(items):
            payload[f"solution_{i}"] = np.asarray(x)
        np.savez(path, **payload)
        return path

    @classmethod
    def load(cls, path) -> "WarmStartCache":
        """Restore a cache written by ``save``; rejects unknown versions.

        The restored cache keeps the saved ``qtol``/``seed``/``capacity``
        (fingerprints are a function of both, so lookups keep colliding
        with pre-restart traffic) and starts with fresh hit/miss counters.
        """
        with np.load(str(path), allow_pickle=False) as z:
            version = int(z["format_version"])
            if version != cls._SAVE_VERSION:
                raise ValueError(
                    f"warm-start cache file {path!r} has format version "
                    f"{version}; this build reads version "
                    f"{cls._SAVE_VERSION}")
            cache = cls(capacity=int(z["capacity"]), qtol=float(z["qtol"]),
                        seed=int(z["seed"]))
            fingerprints = [str(fp) for fp in z["fingerprints"]]
            key_blobs = [str(s) for s in z["bucket_keys"]]
            for i, fp in enumerate(fingerprints):
                cache._store[fp] = np.asarray(z[f"solution_{i}"])
                if key_blobs[i]:
                    cache._keys[fp] = BucketKey(**json.loads(key_blobs[i]))
        return cache


class SolveService:
    """Async front end that batches independent solve requests per bucket.

    ``submit`` / ``submit_hypergrad`` enqueue work and return
    ``concurrent.futures.Future`` objects; ``flush()`` drains the queue,
    groups requests by ``BucketKey``, pads each group to a fixed capacity
    and dispatches it as ONE batched masked solve via
    ``linear_solve.route_solve`` on a stacked ``DenseOperator``.  A
    background scheduler thread (``start()`` / ``stop()``) can flush
    continuously; tests and benchmarks drive ``flush()`` explicitly for
    determinism.

    Admission materializes each request's operator to its dense
    ``(d, d)`` instance form (O(1) for ``DenseOperator``/arrays, ``d``
    probing matvecs for matrix-free operators, ``d ≤ MAX_DENSE_DIM``
    enforced) — that is what makes *independent* requests stackable into
    one batch.  The linear solve is the dominant, amortizable cost;
    admission is the price of cross-request batching.

    Parameters:
      max_batch: bucket capacity ceiling (larger groups split into chunks).
      cache: a ``WarmStartCache`` (default: capacity 256) or ``None`` to
        disable warm starts.
      solve / tol / maxiter / ridge / precond: per-request defaults;
        every one can be overridden per ``submit`` call or by a
        routing-only ``ImplicitDiffSpec`` via ``spec=``.
    """

    _DEFAULT_CACHE = object()    # sentinel: build a fresh cache per service

    def __init__(self, *, max_batch: int = 64,
                 cache: Optional[WarmStartCache] = _DEFAULT_CACHE,
                 solve: Union[str, Callable] = "auto", tol: float = 1e-6,
                 maxiter: int = 1000, ridge: float = 0.0,
                 precond: Optional[str] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = int(max_batch)
        self.cache = WarmStartCache() if cache is self._DEFAULT_CACHE \
            else cache
        self.defaults = dict(solve=solve, tol=float(tol),
                             maxiter=int(maxiter), ridge=float(ridge),
                             precond=precond)
        self._queue: "collections.deque[_PendingRequest]" = \
            collections.deque()
        self._compiled: dict = {}          # (BucketKey, cap) -> jitted fn
        # reentrant: the MetricsRegistry below shares this lock, so every
        # instrument update inside a service critical section — and a
        # snapshot taken against one — stays atomic without deadlocking
        self._lock = threading.RLock()
        self._uid = itertools.count()      # atomic next(): uids never collide
        self._inflight = 0                 # requests popped but not resolved
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.registry = MetricsRegistry(lock=self._lock)
        reg = self.registry
        self._m_requests = reg.counter(
            "repro_service_requests_total", help="requests admitted")
        self._m_dispatches = reg.counter(
            "repro_service_dispatches_total", help="batched dispatches run")
        self._m_instances = reg.counter(
            "repro_service_instances_total",
            help="real (non-padding) instances dispatched")
        self._m_padded = reg.counter(
            "repro_service_padded_total",
            help="padding slots dispatched alongside real instances")
        self._m_occupancy_sum = reg.gauge(
            "repro_service_occupancy_sum",
            help="sum over dispatches of real/capacity occupancy")
        self._m_solve_time = reg.histogram(
            "repro_service_solve_seconds", buckets=LATENCY_BUCKETS,
            help="wall-clock seconds per batched dispatch")
        self._m_queue_wait = reg.histogram(
            "repro_service_queue_wait_seconds", buckets=LATENCY_BUCKETS,
            help="per-request seconds between enqueue and dispatch start")
        self._m_compiled = reg.gauge(
            "repro_service_compiled_programs",
            help="distinct (BucketKey, capacity) programs compiled")
        self._m_cache_hits = reg.gauge(
            "repro_service_cache_hits", help="warm-start cache hits")
        self._m_cache_misses = reg.gauge(
            "repro_service_cache_misses", help="warm-start cache misses")
        self._m_cache_evictions = reg.gauge(
            "repro_service_cache_evictions",
            help="warm-start cache LRU evictions")

    # -- admission -----------------------------------------------------------

    def _routing(self, spec, solve, tol, maxiter, ridge, precond) -> dict:
        """Merge service defaults, a routing-only spec, and per-call kwargs.

        Precedence (lowest to highest): service defaults < ``spec``
        (an ``ImplicitDiffSpec`` — its ``solve``/``tol``/``maxiter``/
        ``ridge``/``precond`` routing fields) < explicit keyword overrides.
        Omitted keywords arrive as ``_UNSET``, so an explicit ``None`` is a
        real override — ``precond=None`` clears a spec's preconditioner
        rather than silently deferring to it.
        """
        r = dict(self.defaults)
        if spec is not None:
            r.update(solve=spec.solve, **spec.routing_kwargs())
        for name, val in (("solve", solve), ("tol", tol),
                          ("maxiter", maxiter), ("ridge", ridge),
                          ("precond", precond)):
            if val is not _UNSET:
                r[name] = val
        if callable(r["solve"]):
            raise ValueError(
                "the solve service buckets by registry solver name; custom "
                "solve callables cannot be batched across requests — call "
                "route_solve directly for those")
        if r["precond"] is not None and not isinstance(r["precond"], str):
            raise ValueError(
                "the solve service buckets by preconditioner kind; pass "
                "precond=None/'jacobi'/'block_jacobi' (a callable M⁻¹ is "
                "request-specific and cannot key a shared bucket)")
        # normalize the numeric controls now so a bad override (e.g. an
        # explicit tol=None) fails in submit(), not at dispatch
        r["tol"] = float(r["tol"])
        r["maxiter"] = int(r["maxiter"])
        r["ridge"] = float(r["ridge"])
        return r

    def _admit_operator(self, A, b, symmetric, positive_definite):
        """Materialize the request operator and ravel the rhs.

        Accepts a ``LinearOperator`` (instance-shaped, ``batch_ndim=0``), a
        dense ``(d, d)`` array, or a bare matvec callable (probed).
        Returns ``(A_host, b_flat, unravel, symmetric, pd)`` with flags
        taken from the operator when it carries them.  ``A_host`` and
        ``b_flat`` are **host numpy** arrays and — for the common case of a
        concrete matrix and a flat rhs — admission never touches JAX at
        all (``unravel is None`` marks the flat fast path).  Keeping
        admission off the device dispatch path is what lets one batched
        dispatch amortize across 64 submits instead of drowning in 64
        rounds of per-request op overhead.
        """
        if isinstance(A, ops.LinearOperator):
            if A.batch_ndim != 0:
                raise ValueError(
                    "submit() takes ONE instance per request (batch_ndim=0);"
                    " the service does the batching — split a batched "
                    "operator into per-instance requests")
            symmetric = A.symmetric if symmetric is None else symmetric
            positive_definite = A.positive_definite or bool(positive_definite)
            A_host = np.asarray(A.materialize())    # d probing matvecs
        elif callable(A) and not hasattr(A, "ndim"):
            op = ops.FunctionOperator(
                A, b, symmetric=symmetric,
                positive_definite=bool(positive_definite))
            A_host = np.asarray(op.materialize())
        else:
            A_host = np.asarray(A)
            if A_host.ndim != 2 or A_host.shape[0] != A_host.shape[1]:
                raise ValueError(
                    f"expected a (d, d) operator, got {A_host.shape}")
            if symmetric is None:       # concrete matrix: detect, don't guess
                if positive_definite:   # declared PD certifies symmetry
                    symmetric = True
                else:                   # allclose semantics, one temporary
                    tol = 1e-8 * max(float(np.abs(A_host).max()), 1.0) + 1e-10
                    symmetric = bool(
                        np.abs(A_host - A_host.T).max() <= tol)
        if isinstance(b, (np.ndarray, jax.Array)) and b.ndim == 1:
            b_flat, unravel = np.asarray(b), None   # flat fast path: no JAX
        else:
            b_jax, unravel = ravel_pytree(b)
            b_flat = np.asarray(b_jax)
        d = b_flat.shape[0]
        if d > MAX_DENSE_DIM:
            raise ValueError(
                f"the solve service batches dense instance systems; d={d} "
                f"exceeds MAX_DENSE_DIM={MAX_DENSE_DIM} — solve oversized "
                "systems directly through linear_solve.solve")
        return A_host, b_flat, unravel, symmetric, bool(positive_definite)

    def _resolve_solver(self, positive_definite: bool, precond) -> str:
        """Resolve ``"auto"`` ONCE at admission so bucket keys are stable.

        This is ``linear_solve._resolve_auto`` restricted to the service's
        regime (single-device dense, ``d ≤ MAX_DENSE_DIM``), evaluated
        host-side so admission stays off the JAX dispatch path — a test
        pins it against the real resolver.  With the warm-start cache
        enabled the resolution assumes an ``init`` may arrive (steering
        off ``pallas_cg``, which always starts from zero) — cold and warm
        requests for the same problem must land in the SAME bucket and
        reuse one compiled program.
        """
        plain = precond is None and self.cache is None
        return "pallas_cg" if positive_definite and plain else "dense_gmres"

    def _enqueue(self, pending: _PendingRequest) -> Future:
        pending.enqueue_t = time.perf_counter()
        with self._lock:
            self._queue.append(pending)
            self._m_requests.inc()
        return pending.future

    def _build_request(self, A, b, symmetric, positive_definite, spec,
                       solve, tol, maxiter, ridge, precond,
                       warm_start: bool, backward: str = "exact",
                       backward_iters: int = 0) -> _PendingRequest:
        """Admission: normalize, bucket-key, warm-start lookup (no enqueue)."""
        admit_t = time.perf_counter()
        r = self._routing(spec, solve, tol, maxiter, ridge, precond)
        A_dense, b_flat, unravel, sym, pd = self._admit_operator(
            A, b, symmetric, positive_definite)
        d = int(b_flat.shape[0])
        solver = r["solve"]
        if solver == "auto":
            solver = self._resolve_solver(pd, r["precond"])
        # admission-time mirror of linear_solve._check_operator_routing:
        # an unknown solver name or a symmetric-only solver paired with a
        # declared-nonsymmetric operator must fail HERE, in the caller's
        # submit(), not inside a batched dispatch where the whole bucket
        # (and, in background mode, the scheduler thread) would pay for it
        solver_spec = ls.get_spec(solver)
        if solver_spec.symmetric_only and sym is False:
            raise ValueError(
                f"requested solver {solver!r} is symmetric-only, but this "
                f"request's operator declares symmetric={sym} "
                f"(positive_definite={pd}) — route a general solver "
                "(gmres/bicgstab/normal_cg/dense_gmres) instead, or fix "
                "the declared flags if the operator really is symmetric")
        dtype = jax.dtypes.canonicalize_dtype(
            np.result_type(A_dense.dtype, b_flat.dtype))
        key = BucketKey(d=d, solver=solver, precond=r["precond"],
                        symmetric=sym, positive_definite=pd,
                        dtype=str(dtype),
                        tol=r["tol"], maxiter=r["maxiter"], ridge=r["ridge"],
                        backward=backward, backward_iters=backward_iters)
        fingerprint = init = None
        if self.cache is not None and warm_start and backward == "exact":
            # approximate buckets skip the warm-start path entirely: the
            # polynomial apply has no init to seed, and caching its
            # truncated output would poison exact buckets' starts
            fingerprint = self.cache.fingerprint(A_dense, b_flat, key)
            init = self.cache.get(fingerprint)
            if init is not None and solver == "pallas_cg":
                init = None     # pallas_cg always starts from zero
            obs_events.emit("cache_hit" if init is not None
                            else "cache_miss", {"solver": solver, "d": d})
        return _PendingRequest(uid=next(self._uid), key=key, A=A_dense,
                               b=b_flat, unravel=unravel, future=Future(),
                               fingerprint=fingerprint, init=init,
                               finish=None, admit_t=admit_t)

    def submit(self, A, b, *, symmetric: Optional[bool] = None,
               positive_definite: bool = False, spec=None, solve=_UNSET,
               tol=_UNSET, maxiter=_UNSET, ridge=_UNSET, precond=_UNSET,
               warm_start: bool = True) -> Future:
        """Enqueue one linear solve ``A x = b``; returns a ``Future``.

        ``A`` is a ``(d, d)`` array (symmetry auto-detected when not
        declared), an instance-shaped ``LinearOperator`` (flags read off
        it), or a matvec callable; ``b`` any pytree raveling to ``d ≤ 512``.
        Routing defaults come from the service; a routing-only
        ``ImplicitDiffSpec`` (``spec=``) or explicit keywords override them
        per request (an explicit ``precond=None`` clears a spec's
        preconditioner — omitted keywords defer, ``None`` overrides).
        Bad routing — an unknown solver name, a symmetric-only solver on a
        declared-nonsymmetric operator — raises here, never at dispatch.
        The future resolves to a ``ServiceResult`` at the flush that
        dispatches this request's bucket.
        """
        return self._enqueue(self._build_request(
            A, b, symmetric, positive_definite, spec, solve, tol, maxiter,
            ridge, precond, warm_start))

    def submit_hypergrad(self, optimality_fun, x_star, theta, cotangent, *,
                         spec=None, solve=_UNSET, tol=_UNSET, maxiter=_UNSET,
                         ridge=_UNSET, precond=_UNSET, backward=_UNSET,
                         backward_iters=_UNSET,
                         warm_start: bool = True) -> Future:
        """Enqueue one implicit hypergradient: resolves to ``vᵀ ∂x*(θ)``.

        Batches the linear-solve step of ``root_vjp`` — the system
        ``Aᵀ u = v`` with ``A = -∂₁F(x*, θ)`` — into the service's shape
        buckets; the cheap per-request θ-VJP ``θ̄ = Bᵀ u`` runs when the
        bucket completes.  ``theta`` is a tuple of θ arguments (a single
        non-tuple value is accepted), ``cotangent`` has the structure of
        ``x*``.  The future's ``ServiceResult.x`` is the per-θ-argument
        gradient tuple, exactly ``root_vjp``'s return value.

        A mapping-carrying ``ImplicitDiffSpec`` may supply *both* the
        optimality mapping (pass ``optimality_fun=None``) and the routing;
        an explicit ``optimality_fun`` wins when both are given.

        ``backward`` selects an approximate cotangent treatment
        (``"one_step"``/``"neumann_k"``/``"jacobian_free"``, with
        ``backward_iters`` the Neumann depth) — resolution order matches
        the routing kwargs (service default "exact" < ``spec`` < explicit
        keyword).  Approximate requests land in their own ``BucketKey``
        arm, never sharing a compiled program (or warm starts) with exact
        traffic, and their ``ServiceResult.info`` reports the
        ``hypergrad_error_estimate`` relative residual.
        """
        if optimality_fun is None:
            if spec is None or spec.is_routing_only:
                raise ValueError("submit_hypergrad needs an optimality "
                                 "mapping: pass optimality_fun= or a spec "
                                 "carrying one")
            optimality_fun = spec.residual_fun
        if not isinstance(theta, tuple):
            theta = (theta,)
        r = self._routing(spec, solve, tol, maxiter, ridge, precond)
        bw = spec.backward if spec is not None else "exact"
        bwk = spec.backward_iters if spec is not None else 8
        if backward is not _UNSET:
            bw = backward
        if backward_iters is not _UNSET:
            bwk = backward_iters
        if bw not in ls.BACKWARD_MODES:
            raise ValueError(f"unknown backward mode {bw!r}; expected one "
                             f"of {ls.BACKWARD_MODES}")
        if bw == "neumann_k" and int(bwk) < 1:
            raise ValueError("backward='neumann_k' needs backward_iters >= "
                             f"1; got {bwk}")
        if bw != "exact" and r["precond"] == "block_jacobi":
            raise ValueError(
                "precond='block_jacobi' inverts the full flat block — that "
                "would make the 'approximate' backward an exact solve; use "
                "precond=None or 'jacobi' with approximate backward modes")
        # one_step/jacobian_free don't consume a depth: pin the key arm to 0
        # so e.g. one_step traffic with different spec defaults still shares
        # one compiled program
        bwk = int(bwk) if bw == "neumann_k" else 0
        solver = r["solve"]
        certified = solver != "auto" and ls.solver_is_symmetric(solver)
        A = ops.JacobianOperator(
            lambda x: optimality_fun(x, *theta), x_star, negate=True,
            symmetric=True if certified else None)
        # the bucketed system is Aᵀ u = v (a symmetric-certified A is its
        # own transpose); the θ-VJP below finishes the hypergradient
        AT = A if certified else A.T

        def finish(u_tree):
            _, vjp_theta = jax.vjp(
                lambda *targs: optimality_fun(x_star, *targs), *theta)
            return vjp_theta(u_tree)

        pending = self._build_request(
            AT, cotangent, A.symmetric, False, spec, solve, tol, maxiter,
            ridge, precond, warm_start, backward=bw, backward_iters=bwk)
        pending.finish = finish
        return self._enqueue(pending)

    # -- dispatch ------------------------------------------------------------

    def _dispatch_fn(self, key: BucketKey, cap: int) -> Callable:
        """The jitted batched dispatch for ``(key, cap)``, compiled once.

        Builds the stacked ``DenseOperator`` (structure flags from the
        bucket key) inside the jit and routes ONE batched masked solve
        through ``route_solve`` with ``return_info=True``.  ``pallas_cg``
        buckets never carry warm starts, so the init argument is dropped
        for them (the kernel always starts from zero).
        """
        with self._lock:
            fn = self._compiled.get((key, cap))
        if fn is not None:
            return fn
        takes_init = key.solver != "pallas_cg"

        if key.backward != "exact":
            # approximate arm: the fixed-budget polynomial apply replaces
            # the converged solve; no warm start (there is no init to
            # seed), and the error estimate is always computed — it IS the
            # approximate modes' honesty contract, at one extra matvec on
            # an already-cheap dispatch
            def dispatch(A_stack, b_stack, init_stack):
                del init_stack
                op = ops.DenseOperator(
                    A_stack, symmetric=key.symmetric,
                    positive_definite=key.positive_definite)
                return ls.approx_inverse_apply(
                    op, b_stack, backward=key.backward,
                    backward_iters=max(key.backward_iters, 1),
                    ridge=key.ridge, precond=key.precond, batch_ndim=1,
                    tol=key.tol, error_estimate=True, return_info=True)
        else:
            def dispatch(A_stack, b_stack, init_stack):
                op = ops.DenseOperator(A_stack, symmetric=key.symmetric,
                                       positive_definite=key.positive_definite)
                return ls.route_solve(
                    key.solver, op, b_stack, tol=key.tol,
                    maxiter=key.maxiter, ridge=key.ridge,
                    precond=key.precond,
                    init=init_stack if takes_init else None,
                    return_info=True)

        fn = jax.jit(dispatch)
        with self._lock:
            # concurrent flushers may race to build the same program; keep
            # the first so compiled-program identity stays stable
            fn = self._compiled.setdefault((key, cap), fn)
            self._m_compiled.set(len(self._compiled))
        return fn

    def _dispatch_bucket(self, key: BucketKey, reqs) -> None:
        """Pad one bucket to capacity and run its single batched solve."""
        n = len(reqs)
        cap = bucket_capacity(n, self.max_batch)
        d = key.d
        dtype = np.dtype(key.dtype)
        label = _bucket_label(key)
        obs_events.emit("dispatch", {"bucket": label, "solver": key.solver},
                        n=n, capacity=cap)
        stage_t = time.perf_counter()
        # host-side staging: padded slots get identity systems with zero
        # rhs/init (they converge at while_loop entry); the jitted dispatch
        # transfers each stacked buffer to device ONCE per flush
        A_stack = np.empty((cap, d, d), dtype)
        b_stack = np.zeros((cap, d), dtype)
        init_stack = np.zeros((cap, d), dtype)
        A_stack[n:] = np.eye(d, dtype=dtype)
        for i, r in enumerate(reqs):
            A_stack[i] = r.A
            b_stack[i] = r.b
            if r.init is not None:
                init_stack[i] = r.init

        fn = self._dispatch_fn(key, cap)
        t0 = time.perf_counter()
        x, info = fn(A_stack, b_stack, init_stack)
        x = jax.block_until_ready(x)
        t1 = time.perf_counter()
        solve_t = t1 - t0

        with self._lock:
            self._m_dispatches.inc()
            self._m_instances.inc(n)
            self._m_padded.inc(cap - n)
            self._m_occupancy_sum.inc(n / cap)
            self._m_solve_time.observe(solve_t)

        x_host = np.asarray(x)
        it = np.asarray(info.iterations).tolist()
        rn = np.asarray(info.residual).tolist()
        cv = np.asarray(info.converged).tolist()
        est = info.hypergrad_error_estimate
        est = [None] * cap if est is None else np.asarray(est).tolist()
        if not isinstance(it, list):        # scalar (unbatched) diagnostics
            it, rn, cv = [it] * cap, [rn] * cap, [cv] * cap
            est = est if isinstance(est, list) else [est] * cap
        tracer = obs_spans.current_tracer()
        for i, req in enumerate(reqs):
            xi = x_host[i]
            if req.fingerprint is not None and self.cache is not None:
                self.cache.put(req.fingerprint, xi, key=req.key)
            queue_t = max(t0 - req.enqueue_t, 0.0)
            deliver_t = time.perf_counter()
            try:
                payload = xi if req.unravel is None \
                    else req.unravel(jnp.asarray(xi))
                if req.finish is not None:
                    payload = req.finish(payload)
                req.future.set_result(ServiceResult(
                    uid=req.uid, x=payload,
                    info=SolveInfo(iterations=it[i], residual=rn[i],
                                   converged=cv[i],
                                   hypergrad_error_estimate=est[i]),
                    queue_time=queue_t, solve_time=solve_t,
                    bucket_size=n, bucket_capacity=cap,
                    warm_start=req.init is not None))
            except Exception as exc:
                req.future.set_exception(exc)
            if tracer is not None:
                # the request lifecycle crosses threads (submitter admits
                # and enqueues; this — possibly the scheduler — thread
                # dispatches and delivers), so the segments are recorded
                # from measured timestamps under an explicit parent id
                end = time.perf_counter()
                root = tracer.record_span(
                    "request", req.admit_t, end, uid=req.uid, bucket=label,
                    warm_start=req.init is not None, iterations=it[i])
                tracer.record_span("admission", req.admit_t, req.enqueue_t,
                                   parent=root)
                tracer.record_span("queue", req.enqueue_t, t0, parent=root)
                tracer.record_span("solve", t0, t1, parent=root,
                                   bucket=label)
                tracer.record_span("delivery", deliver_t, end, parent=root)
        if tracer is not None:
            tracer.record_span("dispatch", stage_t, time.perf_counter(),
                               bucket=label, n=n, capacity=cap)
        with self._lock:
            self._m_queue_wait.observe_many(
                max(t0 - req.enqueue_t, 0.0) for req in reqs)
            if self.cache is not None:
                self._m_cache_hits.set(self.cache.hits)
                self._m_cache_misses.set(self.cache.misses)
                self._m_cache_evictions.set(self.cache.evictions)

    def flush(self) -> int:
        """Drain the queue: dispatch every bucket once; returns #requests.

        An empty queue is a no-op (returns 0) — flushing never pays a
        dispatch for nothing.  Buckets larger than ``max_batch`` split
        into successive full chunks (slot reuse: same compiled program).

        Dispatch failures are **fault-isolated per bucket chunk**: an
        exception inside one batched dispatch is delivered to that chunk's
        futures (``future.result()`` re-raises it) and every other bucket
        still dispatches — a poisoned bucket can neither strand its own
        callers nor kill the background scheduler thread.
        """
        with self._lock:
            pending = list(self._queue)
            self._queue.clear()
            if not pending:
                return 0
            self._inflight += len(pending)
        try:
            buckets: "collections.OrderedDict[BucketKey, list]" = \
                collections.OrderedDict()
            for req in pending:
                buckets.setdefault(req.key, []).append(req)
            for key, reqs in buckets.items():
                for lo in range(0, len(reqs), self.max_batch):
                    chunk = reqs[lo:lo + self.max_batch]
                    try:
                        self._dispatch_bucket(key, chunk)
                    except Exception as exc:
                        for req in chunk:
                            if not req.future.done():
                                req.future.set_exception(exc)
        finally:
            with self._lock:
                self._inflight -= len(pending)
        return len(pending)

    def drain(self, timeout: float = 30.0) -> None:
        """Block until every admitted request has been *resolved*.

        Waits for the queue to empty AND for in-flight dispatches to
        complete — the background thread pops the queue before dispatching,
        so queue emptiness alone would not mean the futures are done.
        After ``drain()`` returns, every future submitted before the call
        carries a result or an exception.
        """
        deadline = time.perf_counter() + timeout
        while time.perf_counter() < deadline:
            with self._lock:
                if not self._queue and self._inflight == 0:
                    return
            time.sleep(0.001)
        raise TimeoutError("solve service did not drain in time")

    # -- background scheduler ------------------------------------------------

    def start(self, interval: float = 0.001) -> None:
        """Start a scheduler thread flushing every ``interval`` seconds."""
        if self._thread is not None:
            raise RuntimeError("service already started")
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                self.flush()
                time.sleep(interval)
            self.flush()                    # final drain

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        """Stop the scheduler thread (flushes once more on the way out)."""
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=10.0)
        self._thread = None

    # -- metrics -------------------------------------------------------------

    @property
    def metrics(self) -> dict:
        """Frozen scheduler-counter snapshot (the legacy flat-dict shape).

        Built atomically under the service lock from the
        :class:`MetricsRegistry` instruments, so a read never observes a
        torn multi-counter update mid-dispatch.  The returned dict is a
        copy — mutating it does not touch the service.
        """
        with self._lock:
            return {
                "requests": int(self._m_requests.value),
                "dispatches": int(self._m_dispatches.value),
                "instances": int(self._m_instances.value),
                "padded": int(self._m_padded.value),
                "occupancy_sum": self._m_occupancy_sum.value,
                "queue_wait_sum": self._m_queue_wait.sum,
                "solve_time_sum": self._m_solve_time.sum,
                "compiled": int(self._m_compiled.value),
                "cache_hits": int(self._m_cache_hits.value),
                "cache_misses": int(self._m_cache_misses.value),
                "cache_evictions": int(self._m_cache_evictions.value),
            }

    @property
    def occupancy(self) -> float:
        """Mean bucket occupancy (real requests / padded capacity)."""
        with self._lock:
            n = self._m_dispatches.value
            return self._m_occupancy_sum.value / n if n else 0.0

    @property
    def hit_rate(self) -> float:
        """Warm-start cache hit rate (0.0 with the cache disabled)."""
        return self.cache.hit_rate if self.cache is not None else 0.0

    @property
    def throughput(self) -> float:
        """Requests served per second of batched solve time."""
        with self._lock:
            t = self._m_solve_time.sum
            return self._m_instances.value / t if t > 0 else 0.0

    def metrics_summary(self) -> dict:
        """One flat dict of scheduler metrics (CLI / benchmark reporting).

        Atomic under the service lock: the counter snapshot and the
        derived rates come from ONE critical section, so concurrent
        dispatches can never skew e.g. ``throughput`` against
        ``instances``.
        """
        with self._lock:
            return dict(self.metrics, occupancy=self.occupancy,
                        hit_rate=self.hit_rate, throughput=self.throughput,
                        cache_size=len(self.cache) if self.cache else 0)

    def metrics_snapshot(self) -> dict:
        """Full structured registry snapshot (names/labels/histograms).

        The JSON-ready form of every service instrument — see
        ``MetricsRegistry.snapshot``; taken atomically under the service
        lock.  ``to_prometheus()`` on :attr:`registry` renders the same
        data in Prometheus text exposition format.
        """
        return self.registry.snapshot()
