"""Fault-tolerance utilities: straggler detection, heartbeat registry,
preemption handling, elastic re-meshing.

On a real multi-host deployment these hooks bind to the cluster scheduler;
here every mechanism is fully implemented and unit-tested against simulated
hosts so the control logic (the hard part) is real.
"""
from __future__ import annotations

import collections
import dataclasses
import signal
import threading
import time
from typing import Callable, Dict, List

import numpy as np


# ---------------------------------------------------------------------------
# Straggler mitigation
# ---------------------------------------------------------------------------

class StragglerMonitor:
    """Tracks per-step wall times; flags hosts whose rolling median exceeds
    the fleet median by ``threshold``×.  At scale this feeds the scheduler's
    hot-swap decision; the detector itself is the deliverable."""

    def __init__(self, window: int = 20, threshold: float = 1.5):
        self.window = window
        self.threshold = threshold
        self.times: Dict[int, collections.deque] = {}

    def record(self, step: int, dt: float, host: int = 0):
        """Record one step duration ``dt`` for ``host``."""
        self.times.setdefault(host, collections.deque(
            maxlen=self.window)).append(dt)

    def medians(self) -> Dict[int, float]:
        """Rolling median step time per host."""
        return {h: float(np.median(list(v)))
                for h, v in self.times.items() if v}

    def stragglers(self) -> List[int]:
        """Hosts whose median exceeds the fleet median by ``threshold``×."""
        meds = self.medians()
        if len(meds) < 2:
            return []
        fleet = float(np.median(list(meds.values())))
        return [h for h, m in meds.items() if m > self.threshold * fleet]


# ---------------------------------------------------------------------------
# Heartbeats / failure detection
# ---------------------------------------------------------------------------

class HeartbeatRegistry:
    """Host-liveness registry: hosts ping; anyone silent for ``timeout``
    seconds is declared failed and the run controller triggers
    checkpoint-restore on the surviving mesh."""

    def __init__(self, timeout: float = 30.0, clock: Callable = time.time):
        self.timeout = timeout
        self.clock = clock
        self.last_seen: Dict[int, float] = {}
        self.lock = threading.Lock()

    def ping(self, host: int):
        """Mark ``host`` alive now."""
        with self.lock:
            self.last_seen[host] = self.clock()

    def failed_hosts(self) -> List[int]:
        """Hosts silent longer than ``timeout`` seconds."""
        now = self.clock()
        with self.lock:
            return [h for h, t in self.last_seen.items()
                    if now - t > self.timeout]

    def healthy_hosts(self) -> List[int]:
        """Hosts seen within the last ``timeout`` seconds."""
        now = self.clock()
        with self.lock:
            return [h for h, t in self.last_seen.items()
                    if now - t <= self.timeout]


# ---------------------------------------------------------------------------
# Preemption
# ---------------------------------------------------------------------------

class PreemptionHandler:
    """SIGTERM-driven graceful shutdown flag (callable for train_loop)."""

    def __init__(self, install: bool = False):
        self._flag = threading.Event()
        if install:
            signal.signal(signal.SIGTERM, self._on_signal)

    def _on_signal(self, signum, frame):
        self._flag.set()

    def preempt(self):
        """Set the shutdown flag programmatically (as SIGTERM would)."""
        self._flag.set()

    def __call__(self) -> bool:
        return self._flag.is_set()


# ---------------------------------------------------------------------------
# Elastic re-meshing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ElasticPlan:
    """Given a failed host set, compute the survivor mesh shape.

    Policy: drop whole ``data``-axis rows (each row = one host group) so the
    model axis stays intact; global batch shrinks proportionally and the
    data pipeline re-shards deterministically (stream is a pure function of
    host_id/num_hosts)."""
    old_data: int
    old_model: int

    def survivor_mesh(self, failed_fraction: float):
        """New ``(data, model)`` mesh shape after dropping failed rows."""
        lost_rows = int(np.ceil(self.old_data * failed_fraction))
        new_data = max(1, self.old_data - lost_rows)
        # keep power-of-two friendliness for collectives
        while new_data > 1 and (self.old_data % new_data):
            new_data -= 1
        return (new_data, self.old_model)

    def batch_scale(self, failed_fraction: float) -> float:
        """Fraction of the global batch the survivor mesh sustains."""
        nd, _ = self.survivor_mesh(failed_fraction)
        return nd / self.old_data
