"""Continuous-batching serving engine (vLLM-style scheduling on JAX).

Production serving at scale interleaves prefill and decode across a dynamic
request population.  This engine implements the control plane:

  * a **slot-based KV cache**: the decode batch is a fixed-capacity tensor
    batch (compiled once); requests claim/release slots;
  * **continuous batching**: finished requests release their slot
    immediately and queued requests are admitted without stopping decode;
  * **chunked prefill**: prompts enter through the decode path in slot-local
    steps (keeps one compiled program; an optimized full-prefill path is
    exercised separately by the prefill_32k dry-run cells);
  * per-request state tracking (queued → prefilling → decoding → done) and
    scheduler metrics (throughput, slot occupancy).

Batch shapes never change ⇒ no recompilation during serving — the property
that matters on TPU.

This is the *token-generation* front end.  Its sibling,
``repro.runtime.solve_service``, applies the same continuous-batching
discipline (fixed compiled batch shapes, slot padding, scheduler metrics)
to implicit-differentiation workloads: linear solves and hypergradient
requests batched into bucketed masked solves with a warm-start cache.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as mdl


@dataclasses.dataclass
class Request:
    """One LM decode request and its scheduling lifecycle state."""
    uid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int
    state: str = "queued"           # queued|prefill|decode|done
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    prefill_pos: int = 0
    enqueue_t: float = 0.0
    finish_t: float = 0.0


class ContinuousBatchingEngine:
    """Fixed-slot continuous batching over ``decode_step``."""

    def __init__(self, cfg: ArchConfig, params, num_slots: int = 8,
                 max_len: int = 256, eos_token: Optional[int] = None):
        if not cfg.has_decoder:
            raise ValueError(f"{cfg.name} is encoder-only")
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.eos = eos_token
        self.state = mdl.init_decode_state(cfg, num_slots, max_len)
        # per-slot scalar write index (the shared DecodeState.index cannot
        # serve slots at different positions — we re-derive it per step)
        self.slot_pos = np.zeros(num_slots, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * num_slots
        self.queue: "collections.deque[Request]" = collections.deque()
        self.done: List[Request] = []
        self._uid = 0
        self.metrics = {"steps": 0, "tokens": 0, "occupancy_sum": 0.0}

        def step_fn(params, state, tokens, slot_mask):
            logits, new_state = mdl.decode_step(params, cfg, state, tokens)
            # frozen slots keep their previous cache contents: mask the
            # cache update by re-selecting per slot
            def select(new, old):
                mask = slot_mask.reshape(
                    (-1,) + (1,) * (new.ndim - 1)) if new.ndim >= 1 else \
                    slot_mask
                return jnp.where(mask, new, old)

            merged = jax.tree_util.tree_map(
                lambda n, o: _merge_slot(n, o, slot_mask),
                new_state.caches, state.caches)
            return logits, mdl.DecodeState(caches=merged,
                                           index=new_state.index)

        self._step = jax.jit(step_fn)

    # -- request lifecycle ---------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        """Enqueue a prompt; returns the request uid."""
        req = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens,
                      enqueue_t=time.perf_counter())
        self._uid += 1
        self.queue.append(req)
        return req.uid

    def _admit(self):
        for slot in range(self.num_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.popleft()
                req.state = "prefill"
                req.slot = slot
                req.prefill_pos = 0
                self.slot_pos[slot] = 0
                self.slot_req[slot] = req

    # -- one engine tick -----------------------------------------------------

    def step(self):
        """One batched decode step across all active slots."""
        self._admit()
        active = [r for r in self.slot_req if r is not None]
        if not active:
            return False

        tokens = np.zeros((self.num_slots, 1), np.int32)
        mask = np.zeros((self.num_slots,), bool)
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            mask[slot] = True
            if req.state == "prefill":
                tokens[slot, 0] = req.prompt[req.prefill_pos]
            else:
                tokens[slot, 0] = req.generated[-1]

        # the batched cache index must be per-slot; decode_step uses a
        # single scalar — we set it to each slot's position via the shared
        # index trick: all active slots advance one position per tick, and
        # slots are zero-reset on admission, so positions stay in lockstep
        # per slot through masking on the host side.
        idx = int(np.max(self.slot_pos[mask])) if mask.any() else 0
        state = mdl.DecodeState(caches=self.state.caches,
                                index=jnp.asarray(idx, jnp.int32))
        logits, new_state = self._step(self.params, state,
                                       jnp.asarray(tokens),
                                       jnp.asarray(mask))
        self.state = new_state
        next_tok = np.asarray(jnp.argmax(logits[:, -1], axis=-1))

        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.slot_pos[slot] += 1
            if req.state == "prefill":
                req.prefill_pos += 1
                if req.prefill_pos >= len(req.prompt):
                    req.state = "decode"
                    req.generated.append(int(next_tok[slot]))
            else:
                req.generated.append(int(next_tok[slot]))
            full = len(req.generated) >= req.max_new_tokens
            eos = self.eos is not None and req.generated and \
                req.generated[-1] == self.eos
            over = self.slot_pos[slot] >= self.max_len - 1
            if req.state == "decode" and (full or eos or over):
                req.state = "done"
                req.finish_t = time.perf_counter()
                self.done.append(req)
                self.slot_req[slot] = None       # release immediately

        self.metrics["steps"] += 1
        self.metrics["tokens"] += int(mask.sum())
        self.metrics["occupancy_sum"] += float(mask.mean())
        return True

    def run_until_drained(self, max_steps: int = 10000):
        """Step until queue and slots drain; returns finished requests."""
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.done

    @property
    def occupancy(self) -> float:
        """Mean fraction of decode slots active per step."""
        if self.metrics["steps"] == 0:
            return 0.0
        return self.metrics["occupancy_sum"] / self.metrics["steps"]


def _merge_slot(new, old, slot_mask):
    """Select per-slot between updated and previous cache entries.

    Cache leaves are stacked (L, B, ...) — the slot/batch dim is axis 1;
    recurrent leaves may be (L, B, ...) too.  Scalars pass through."""
    if new.ndim < 2:
        return new
    shape = [1] * new.ndim
    shape[1] = slot_mask.shape[0]
    mask = slot_mask.reshape(shape)
    return jnp.where(mask, new, old)
