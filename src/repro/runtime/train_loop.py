"""Train-step / serve-step factories and the training loop.

``make_train_step`` builds the jit-able function the dry-run lowers for the
``train_4k`` cells: forward+loss (remat'd scan over layers), backward,
gradient clip, optional int8 error-feedback compression on the DP reduction,
optimizer update.  Gradient accumulation (microbatching) happens INSIDE the
step via ``lax.scan`` so the compiled program overlaps the per-microbatch
backward with the gradient reduction.

``make_prefill_step`` / ``make_decode_step`` are the serving entry points
(the ``prefill_*`` / ``decode_*`` / ``long_*`` cells).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import model as mdl
from repro.optim import optimizer as opt
from repro.optim import grad_compression as gc


class TrainState(NamedTuple):
    """Carried training state: params, optimizer state, error feedback."""
    params: Any
    opt_state: opt.OptState
    err_state: Any            # grad-compression error feedback (or None)


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    """Static configuration of the compiled train step."""
    microbatches: int = 1
    clip_norm: float = 1.0
    compress_grads: bool = False
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots | dots_no_batch
    use_kernel: bool = False
    # sharding constraint applied to the microbatched (mb, b, ...) inputs;
    # without it GSPMD shards the scan dim and replicates each microbatch.
    microbatch_sharding: Optional[Any] = None
    # constraint for (B, S, d) activations after the embedding gather
    act_sharding: Optional[Any] = None
    # sequence-parallel sharding for residual activations between blocks
    sp_sharding: Optional[Any] = None
    moe_dispatch: str = "dense"     # dense | sparse (gather-based, capacity)
    # dtype for the gradient accumulator / cross-device dW reductions.
    # bf16 halves the reduce-scatter payload and accumulator traffic; the
    # optimizer still updates in f32 moments (§Perf L3).
    grad_accum_dtype: Any = jnp.float32
    # pytree of NamedShardings (like params) for the grad accumulator; keeps
    # the per-microbatch dW reduction a reduce-scatter (ZeRO-3) instead of a
    # full all-reduce of replicated gradients
    grad_sharding: Optional[Any] = None


def make_train_state(cfg: ArchConfig, optimizer: opt.Optimizer, key,
                     compress: bool = False) -> TrainState:
    params = mdl.init_params(key, cfg)
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        err_state=gc.init_error_state(params) if compress else None)


def make_train_state_abstract(cfg: ArchConfig, optimizer: opt.Optimizer,
                              compress: bool = False):
    """ShapeDtypeStruct TrainState for the dry-run (no allocation)."""
    return jax.eval_shape(
        lambda k: make_train_state(cfg, optimizer, k, compress),
        jax.random.PRNGKey(0))


def make_train_step(cfg: ArchConfig, optimizer: opt.Optimizer,
                    tcfg: TrainStepConfig = TrainStepConfig()) -> Callable:
    """Returns train_step(state, inputs, labels) -> (state, metrics)."""

    def loss_for(params, x, y):
        return mdl.loss_fn(params, cfg, x, y, use_kernel=tcfg.use_kernel,
                           remat=tcfg.remat, act_sharding=tcfg.act_sharding,
                           remat_policy=tcfg.remat_policy,
                           sp_sharding=tcfg.sp_sharding,
                           moe_dispatch=tcfg.moe_dispatch)

    grad_fn = jax.value_and_grad(loss_for)

    def train_step(state: TrainState, inputs, labels):
        if tcfg.microbatches > 1:
            B = inputs.shape[0]
            mb = tcfg.microbatches
            assert B % mb == 0, (B, mb)
            xs = inputs.reshape(mb, B // mb, *inputs.shape[1:])
            ys = labels.reshape(mb, B // mb, *labels.shape[1:])
            if tcfg.microbatch_sharding is not None:
                c = lambda a: jax.lax.with_sharding_constraint(
                    a, tcfg.microbatch_sharding)
                xs, ys = c(xs), c(ys)

            def micro(acc, xy):
                x, y = xy
                loss, g = grad_fn(state.params, x, y)
                if tcfg.grad_sharding is not None:
                    # force the dW partial-sum reduction to land sharded
                    # (reduce-scatter) instead of replicated (all-reduce)
                    g = jax.tree_util.tree_map(
                        jax.lax.with_sharding_constraint, g,
                        tcfg.grad_sharding)
                acc_loss, acc_g = acc
                acc_g = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), acc_g, g)
                return (acc_loss + loss, acc_g), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, tcfg.grad_accum_dtype),
                state.params)
            if tcfg.grad_sharding is not None:
                zeros = jax.tree_util.tree_map(
                    jax.lax.with_sharding_constraint, zeros,
                    tcfg.grad_sharding)
            (loss, grads), _ = lax.scan(micro, (0.0, zeros), (xs, ys))
            loss = loss / mb
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
        else:
            loss, grads = grad_fn(state.params, inputs, labels)

        grads, gnorm = opt.clip_by_global_norm(grads, tcfg.clip_norm)

        err_state = state.err_state
        if tcfg.compress_grads:
            grads, err_state = gc.roundtrip(grads, err_state)

        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = opt.apply_updates(state.params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": opt_state.step}
        return TrainState(params, opt_state, err_state), metrics

    return train_step


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ArchConfig, use_kernel: bool = False,
                      act_sharding=None) -> Callable:
    """prefill_step(params, inputs) -> logits (forward only, remat off)."""

    def prefill_step(params, inputs):
        logits, _ = mdl.forward(params, cfg, inputs, use_kernel=use_kernel,
                                remat=False, act_sharding=act_sharding)
        return logits

    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    """decode_step(params, state, tokens) -> (logits, state)."""

    def step(params, state, tokens):
        return mdl.decode_step(params, cfg, state, tokens)

    return step


# ---------------------------------------------------------------------------
# Host-side training loop with fault tolerance hooks
# ---------------------------------------------------------------------------

def train_loop(train_step: Callable, state: TrainState, data_iter,
               num_steps: int, *, checkpoint_manager=None,
               checkpoint_every: int = 100, monitor=None,
               preemption_flag=None, log_every: int = 10,
               start_step: int = 0):
    """Run the loop with checkpoint/restart + straggler monitoring hooks.

    ``preemption_flag``: a callable returning True when this host must stop
    (SIGTERM handler sets it in launch/train.py); we checkpoint and exit
    cleanly — the restart resumes from the same step with identical data.
    """
    history = []
    step = start_step
    for _ in range(num_steps):
        t0 = time.perf_counter()
        data_step, (x, y) = next(data_iter)
        state, metrics = train_step(state, x, y)
        if monitor is not None:
            jax.block_until_ready(metrics["loss"])
            monitor.record(step, time.perf_counter() - t0)
        if step % log_every == 0:
            history.append({k: float(v) for k, v in metrics.items()})
        step += 1
        if checkpoint_manager is not None and step % checkpoint_every == 0:
            checkpoint_manager.save(step, state)
        if preemption_flag is not None and preemption_flag():
            if checkpoint_manager is not None:
                checkpoint_manager.save(step, state, blocking=True)
            break
    if checkpoint_manager is not None:
        checkpoint_manager.wait()
    return state, history
