"""Assigned input-shape cells and ``input_specs`` (ShapeDtypeStruct stand-ins).

Four shapes per LM arch (assignment):
  train_4k     seq 4096,   global_batch 256   -> train_step
  prefill_32k  seq 32768,  global_batch 32    -> prefill_step
  decode_32k   kv 32768,   global_batch 128   -> serve (decode) step
  long_500k    kv 524288,  global_batch 1     -> serve step, SSM/hybrid only

Skips (DESIGN.md §Arch-applicability):
  * long_500k for pure full-attention archs (quadratic prefill);
  * decode_32k / long_500k for encoder-only (hubert).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def skip_reason(cfg: ArchConfig, shape: str) -> Optional[str]:
    cell = SHAPES[shape]
    if cell.kind == "decode" and not cfg.has_decoder:
        return "encoder-only arch: no autoregressive decode step"
    if shape == "long_500k" and not cfg.supports_long_context:
        return ("pure full-attention arch: long_500k requires sub-quadratic "
                "context (run for SSM/hybrid only per assignment)")
    return None


def runnable_cells(cfg: ArchConfig):
    return [s for s in SHAPES if skip_reason(cfg, s) is None]


def input_specs(cfg: ArchConfig, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.
    Weak-type-correct, shardable, zero allocation."""
    cell = SHAPES[shape]
    B, S = cell.global_batch, cell.seq_len
    tok_dtype = jnp.int32
    if cfg.embedding_frontend == "stub_embeddings":
        def tokens(b, s):
            return jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                        jnp.dtype(cfg.dtype))
    else:
        def tokens(b, s):
            return jax.ShapeDtypeStruct((b, s), tok_dtype)

    if cell.kind == "train":
        return {"inputs": tokens(B, S),
                "labels": jax.ShapeDtypeStruct((B, S), tok_dtype)}
    if cell.kind == "prefill":
        return {"inputs": tokens(B, S)}
    # decode: one new token against a KV/state cache of length S
    return {"tokens": tokens(B, 1)}


def tokens_per_step(cfg: ArchConfig, shape: str) -> int:
    cell = SHAPES[shape]
    if cell.kind == "train":
        return cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return cell.global_batch * cell.seq_len
    return cell.global_batch      # decode: 1 token per sequence
