"""Serving launcher: batched prefill + decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --smoke \
        --batch 4 --prompt-len 16 --gen 16

Implements the production serve loop shape: one prefill pass fills the
cache, then decode steps run one token/step for the whole batch (greedy).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import decode_step, init_decode_state, init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.names())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch, smoke=args.smoke)
    if not cfg.has_decoder:
        raise SystemExit(f"{cfg.name} is encoder-only; nothing to decode")
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    B, P, G = args.batch, args.prompt_len, args.gen

    if cfg.embedding_frontend == "stub_embeddings":
        prompts = jax.random.normal(key, (B, P, cfg.d_model))
        def embed_tok(tok):
            return jax.random.normal(jax.random.fold_in(key, 1),
                                     (B, 1, cfg.d_model))
    else:
        prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
        embed_tok = None

    state = init_decode_state(cfg, B, P + G)
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t))

    # prefill: feed the prompt through decode steps (cache-filling).  A
    # chunked prefill (full forward + cache scatter) is the optimized path
    # exercised by the prefill_32k dry-run cells.
    t0 = time.perf_counter()
    logits = None
    for i in range(P):
        tok = prompts[:, i:i + 1]
        logits, state = step(params, state, tok)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    generated = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    for _ in range(G):
        if embed_tok is not None:
            inp = embed_tok(tok)
        else:
            inp = tok
        logits, state = step(params, state, inp)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        generated.append(tok)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"[serve] arch={cfg.name} batch={B} prompt={P} gen={G}")
    print(f"[serve] prefill={t_prefill*1e3:.1f}ms "
          f"decode={t_decode*1e3:.1f}ms "
          f"({B * G / max(t_decode, 1e-9):.1f} tok/s)")
    print(f"[serve] sample tokens: {out[0, :8].tolist()}")


if __name__ == "__main__":
    main()
