"""Serving launcher: LM decode loop or the implicit-diff solve service.

LM decode (batched prefill + decode with a KV cache)::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --smoke \
        --batch 4 --prompt-len 16 --gen 16

Solve service (continuous-batching linear-solve front end; drives two
traffic waves — the second replays the first, so the warm-start cache
hit rate and scheduler metrics are exercised end to end)::

    PYTHONPATH=src python -m repro.launch.serve --solve-service \
        --requests 64 --dim 32 --max-batch 64

The LM path implements the production serve loop shape: one prefill pass
fills the cache, then decode steps run one token/step for the whole batch
(greedy).  The solve-service path is documented in ``docs/serving.md``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import decode_step, init_decode_state, init_params


def serve_solves(args) -> None:
    """Drive the solve service with synthetic SPD traffic; print metrics.

    Observability is enabled for the whole run (``--trace PATH`` also
    streams a JSONL span/event trace for
    ``python -m repro.observability.report``); the scheduler metrics come
    from the service's ``MetricsRegistry`` snapshot and the full
    Prometheus text exposition is printed once at exit.
    """
    import numpy as np

    from repro import observability as obs
    from repro.runtime.solve_service import SolveService, WarmStartCache

    rng = np.random.default_rng(args.seed)
    n, d = args.requests, args.dim
    problems = []
    for _ in range(n):
        M = rng.standard_normal((d, d))
        problems.append((M @ M.T + d * np.eye(d), rng.standard_normal(d)))

    # enable BEFORE constructing the service: programs jitted while
    # disabled would stay uninstrumented until re-traced
    with obs.observe(enabled=True, trace_path=args.trace):
        svc = SolveService(max_batch=args.max_batch,
                           cache=WarmStartCache(
                               capacity=args.cache_capacity))
        svc.start()                   # background scheduler thread
        try:
            for wave in ("cold", "warm"):   # wave 2 replays wave 1: hits
                t0 = time.perf_counter()
                futs = [svc.submit(A, b, positive_definite=True)
                        for A, b in problems]
                results = [f.result(timeout=60.0) for f in futs]
                dt = time.perf_counter() - t0
                iters = [int(r.info.iterations) for r in results]
                print(f"[serve] {wave}: {n} requests d={d} in "
                      f"{dt*1e3:.1f}ms ({n / dt:.0f} req/s) "
                      f"iters(median)={int(np.median(iters))} "
                      f"warm_started={sum(r.warm_start for r in results)}")
        finally:
            svc.stop()
        snap = svc.metrics_snapshot()

        def _val(name, default=0.0):
            values = snap.get(name, {}).get("values", {})
            v = values.get("", default)
            return v["sum"] if isinstance(v, dict) else v

        dispatches = _val("repro_service_dispatches_total")
        print(f"[serve] dispatches={int(dispatches)} "
              f"compiled={int(_val('repro_service_compiled_programs'))} "
              f"occupancy="
              f"{_val('repro_service_occupancy_sum') / max(dispatches, 1):.2f} "
              f"hit_rate={svc.hit_rate:.2f} "
              f"cache_size={len(svc.cache) if svc.cache else 0}")
        print("[serve] prometheus exposition:")
        print(svc.registry.to_prometheus(), end="")
        tracer = obs.current_tracer()
        if tracer is not None:
            tracer.flush()
            n_spans = sum(1 for r in tracer.records()
                          if r.get("type") == "span")
            print(f"[serve] trace: {tracer.path} ({n_spans} spans)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=configs.names(),
                    help="LM decode mode (required unless --solve-service)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--solve-service", action="store_true",
                    help="serve the implicit-diff solve service instead of "
                         "LM decode")
    ap.add_argument("--requests", type=int, default=64,
                    help="solve-service: concurrent requests per wave")
    ap.add_argument("--dim", type=int, default=32,
                    help="solve-service: instance dimension d")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="solve-service: bucket capacity ceiling")
    ap.add_argument("--cache-capacity", type=int, default=256,
                    help="solve-service: warm-start cache capacity")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="solve-service: write a JSONL span/event trace "
                         "(summarize with repro.observability.report)")
    args = ap.parse_args()

    if args.solve_service:
        serve_solves(args)
        return
    if args.arch is None:
        ap.error("--arch is required unless --solve-service is given")

    cfg = configs.get(args.arch, smoke=args.smoke)
    if not cfg.has_decoder:
        raise SystemExit(f"{cfg.name} is encoder-only; nothing to decode")
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    B, P, G = args.batch, args.prompt_len, args.gen

    if cfg.embedding_frontend == "stub_embeddings":
        prompts = jax.random.normal(key, (B, P, cfg.d_model))
        def embed_tok(tok):
            return jax.random.normal(jax.random.fold_in(key, 1),
                                     (B, 1, cfg.d_model))
    else:
        prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
        embed_tok = None

    state = init_decode_state(cfg, B, P + G)
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t))

    # prefill: feed the prompt through decode steps (cache-filling).  A
    # chunked prefill (full forward + cache scatter) is the optimized path
    # exercised by the prefill_32k dry-run cells.
    t0 = time.perf_counter()
    logits = None
    for i in range(P):
        tok = prompts[:, i:i + 1]
        logits, state = step(params, state, tok)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    generated = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    for _ in range(G):
        if embed_tok is not None:
            inp = embed_tok(tok)
        else:
            inp = tok
        logits, state = step(params, state, inp)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        generated.append(tok)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"[serve] arch={cfg.name} batch={B} prompt={P} gen={G}")
    print(f"[serve] prefill={t_prefill*1e3:.1f}ms "
          f"decode={t_decode*1e3:.1f}ms "
          f"({B * G / max(t_decode, 1e-9):.1f} tok/s)")
    print(f"[serve] sample tokens: {out[0, :8].tolist()}")


if __name__ == "__main__":
    main()
