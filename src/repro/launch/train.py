"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --smoke \
        --steps 100 --batch 8 --seq 64 [--mesh 1x1] [--ckpt-dir /tmp/ckpt]

On the CPU container this runs REDUCED configs end-to-end (the full configs
are exercised via the dry-run).  The same driver binds to a real mesh on
TPU: ``--mesh DxM`` selects (data, model) axes over available devices.
Fault tolerance: SIGTERM checkpoints and exits; rerunning with the same
``--ckpt-dir`` resumes exactly (deterministic data stream).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLMStream
from repro.distributed import sharding as shd
from repro.optim import adamw, schedules
from repro.runtime import (PreemptionHandler, StragglerMonitor,
                           TrainStepConfig, make_train_state,
                           make_train_step, run_train_loop)
from repro.runtime import train_loop as tl_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.names())
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default=None, help="DxM, e.g. 4x2")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch, smoke=args.smoke)
    optimizer = adamw(schedules.linear_warmup_cosine(
        args.lr, warmup=10, total=args.steps), weight_decay=0.01)
    tcfg = TrainStepConfig(microbatches=args.microbatches,
                           remat=not args.smoke,
                           compress_grads=args.compress_grads)
    step_fn = make_train_step(cfg, optimizer, tcfg)

    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        rules = shd.ShardingRules()
        state0 = make_train_state(cfg, optimizer, jax.random.PRNGKey(
            args.seed), compress=args.compress_grads)
        pspecs = shd.params_specs(state0.params, rules, mesh)
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.optim.optimizer import OptState
        sspec = tl_mod.TrainState(
            params=pspecs,
            opt_state=OptState(step=P(), mu=pspecs, nu=pspecs),
            err_state=pspecs if args.compress_grads else None)
        N = lambda t: jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda z: isinstance(z, P))
        step_fn = jax.jit(step_fn,
                          in_shardings=(N(sspec),
                                        NamedSharding(mesh, P("data")),
                                        NamedSharding(mesh, P("data"))),
                          out_shardings=(N(sspec), None))
        state = state0
    else:
        step_fn = jax.jit(step_fn)
        state = make_train_state(cfg, optimizer,
                                 jax.random.PRNGKey(args.seed),
                                 compress=args.compress_grads)

    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(state.params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps}", flush=True)

    stream = SyntheticLMStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed))

    mgr = None
    start_step = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        latest = mgr.latest_step()
        if latest is not None:
            target = jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
            state = mgr.restore(latest, target)
            start_step = latest
            print(f"[train] resumed from step {latest}", flush=True)

    def data_iter():
        step = start_step
        while True:
            yield step, stream.batch_at(step)
            step += 1

    handler = PreemptionHandler(install=True)
    monitor = StragglerMonitor()
    state, hist = run_train_loop(
        step_fn, state, data_iter(), num_steps=args.steps - start_step,
        checkpoint_manager=mgr, checkpoint_every=args.ckpt_every,
        monitor=monitor, preemption_flag=handler, log_every=10,
        start_step=start_step)
    for h in hist:
        print(f"[train] step={int(h['step'])} loss={h['loss']:.4f} "
              f"gnorm={h['grad_norm']:.3f}", flush=True)
    if mgr:
        mgr.save(args.steps, state, blocking=True)
    print("[train] done", flush=True)


if __name__ == "__main__":
    main()
