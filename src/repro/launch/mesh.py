"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: (data=16, model=16) = 256 chips (TPU v5e
pod).  Multi-pod: (pod=2, data=16, model=16) = 512 chips, with the ``pod``
axis mapped to the slowest (DCN/ICI-bridge) links — pure data parallelism
crosses it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def auto_mesh_size(B: int, d: int, *, spd: bool = True,
                   dtype: str = "float32", max_devices: int = None) -> int:
    """The cost-model-selected 1-D solve-mesh extent for a (B, d) regime.

    Thin front end over ``analysis.autotune.auto_mesh_size``: candidates
    are power-of-two extents dividing ``B`` up to the local device count,
    ranked by measured tuning-cache entries when any exist and by the
    roofline solve model otherwise.  Pair with ``make_solve_mesh``::

        n = auto_mesh_size(B, d)
        mesh = make_solve_mesh(devices=n)

    so examples and benchmarks pick their extent empirically instead of
    hardcoding "all devices" (which BENCH showed oversharding at mesh=8
    for B=64, d=16).
    """
    from repro.analysis import autotune
    return autotune.auto_mesh_size(B, d, spd=spd, dtype=dtype,
                                   max_devices=max_devices)


def make_solve_mesh(devices: int = None, axis: str = "data"):
    """1-D mesh for sharded linear solves (``ShardedOperator`` and the
    ``sharded_*`` registry solvers).

    Uses the first ``devices`` local devices (all by default, so the same
    call serves a laptop, a CI lane with forced host devices, and a real
    slice).  Batched hypergradient workloads shard the instance batch over
    this axis; ``devices`` must then divide the batch size.
    """
    import numpy as np
    from jax.sharding import Mesh
    devs = jax.devices()
    if devices is not None:
        if devices > len(devs):
            raise ValueError(f"requested {devices} devices, have "
                             f"{len(devs)}")
        devs = devs[:devices]
    return Mesh(np.asarray(devs), (axis,))
