"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: (data=16, model=16) = 256 chips (TPU v5e
pod).  Multi-pod: (pod=2, data=16, model=16) = 512 chips, with the ``pod``
axis mapped to the slowest (DCN/ICI-bridge) links — pure data parallelism
crosses it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))
