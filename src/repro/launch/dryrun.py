import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
mesh and extract the roofline terms.

MUST be run as its own process (the XLA_FLAGS line above executes before any
other import so jax sees 512 host devices).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
        --shape train_4k [--multi-pod] [--out results/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.analysis import hlo as hlo_an
from repro.analysis import roofline as rf
from repro.distributed import sharding as shd
from repro.launch import shapes as shp
from repro.launch.mesh import make_production_mesh
from repro.models import model as mdl
from repro.optim import optimizer as opt
from repro.runtime import train_loop as tl


def _rules(multi_pod: bool, layout: str = "2d") -> shd.ShardingRules:
    """``2d``: FSDP(data) × TP(model).  ``dp``: pure data parallelism over
    BOTH axes (the right layout for small-activation archs where TP only
    buys collective traffic — §Perf G3)."""
    pod = "pod" if multi_pod else None
    if layout == "dp":
        return shd.ShardingRules(data=("data", "model"), model=None,
                                 pod=pod)
    return shd.ShardingRules(pod=pod)


def _attn_tp(cfg, mesh, rules):
    """TP on attention projections only when the heads divide the axis."""
    n_model = shd.mesh_axis_size(mesh, rules.model)
    if cfg.use_mla:
        return cfg.num_heads % n_model == 0
    return (cfg.num_heads % n_model == 0
            and cfg.num_kv_heads * cfg.resolved_head_dim % n_model == 0)


def _train_state_specs(abstract_state, rules, mesh, attn_tp=True):
    pspecs = shd.params_specs(abstract_state.params, rules, mesh,
                              attn_tp=attn_tp)
    mu = shd.params_specs(abstract_state.opt_state.mu, rules, mesh,
                          attn_tp=attn_tp)
    nu = (shd.params_specs(abstract_state.opt_state.nu, rules, mesh,
                           attn_tp=attn_tp)
          if abstract_state.opt_state.nu is not None else None)
    return tl.TrainState(
        params=pspecs,
        opt_state=opt.OptState(step=P(), mu=mu, nu=nu),
        err_state=None)


def lower_cell(arch: str, shape: str, multi_pod: bool = False,
               microbatches: int = 16, fsdp: bool = True,
               donate: bool = True, extra_tag: str = "",
               layout: str = "2d", remat_policy: str = "nothing",
               seq_parallel: bool = False, grad_accum_bf16: bool = False,
               moe_dispatch: str = "dense"):
    """Lower + compile one (arch × shape × mesh) cell; return result dict."""
    cfg = configs.get(arch)
    reason = shp.skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = _rules(multi_pod, layout)
    chips = 512 if multi_pod else 256
    cell = shp.SHAPES[shape]
    specs = shp.input_specs(cfg, shape)
    t0 = time.time()

    def N(spec_tree):
        return shd.named(mesh, spec_tree)

    if True:
        if cell.kind == "train":
            optimizer = opt.adamw(1e-4, state_dtype=jnp.bfloat16)
            attn_tp = _attn_tp(cfg, mesh, rules)
            mb = microbatches if cell.global_batch % microbatches == 0 else 1
            mb_shard = jax.sharding.NamedSharding(
                mesh, P(None, rules.batch_axes))
            act_shard = jax.sharding.NamedSharding(
                mesh, P(rules.batch_axes, None, None))
            pspecs_for_grads = shd.params_specs(
                jax.eval_shape(lambda k: mdl.init_params(k, cfg),
                               jax.random.PRNGKey(0)), rules, mesh,
                attn_tp=attn_tp)
            grad_shard = jax.tree_util.tree_map(
                lambda s: jax.sharding.NamedSharding(mesh, s),
                pspecs_for_grads,
                is_leaf=lambda x: isinstance(x, P))
            sp_shard = None
            if seq_parallel and layout == "2d":
                sp_shard = jax.sharding.NamedSharding(
                    mesh, P(rules.batch_axes, "model", None))
            tcfg = tl.TrainStepConfig(microbatches=mb, remat=True,
                                      remat_policy=remat_policy,
                                      microbatch_sharding=mb_shard,
                                      act_sharding=act_shard,
                                      grad_sharding=grad_shard,
                                      sp_sharding=sp_shard,
                                      moe_dispatch=moe_dispatch,
                                      grad_accum_dtype=(
                                          jnp.bfloat16 if grad_accum_bf16
                                          else jnp.float32))
            step_fn = tl.make_train_step(cfg, optimizer, tcfg)
            abstract = tl.make_train_state_abstract(cfg, optimizer)
            state_specs = _train_state_specs(abstract, rules, mesh,
                                             attn_tp=attn_tp)
            in_shardings = (N(state_specs),
                            N(shd.batch_spec(rules)),
                            N(shd.batch_spec(rules)))
            out_shardings = (N(state_specs), None)
            jitted = jax.jit(step_fn, in_shardings=in_shardings,
                             out_shardings=out_shardings,
                             donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(abstract, specs["inputs"],
                                   specs["labels"])
        elif cell.kind == "prefill":
            act_shard = jax.sharding.NamedSharding(
                mesh, P(rules.batch_axes, None, None))
            step_fn = tl.make_prefill_step(cfg, act_sharding=act_shard)

            def prefill_last(params, inputs):
                logits = step_fn(params, inputs)
                return logits[:, -1]          # serving: last-position logits

            abstract_params = mdl.init_params_abstract(
                jax.random.PRNGKey(0), cfg)
            pspecs = shd.params_specs(abstract_params, rules, mesh,
                                      attn_tp=_attn_tp(cfg, mesh, rules))
            jitted = jax.jit(
                prefill_last,
                in_shardings=(N(pspecs), N(shd.batch_spec(rules))),
                out_shardings=N(jax.sharding.PartitionSpec(
                    rules.batch_axes)))
            lowered = jitted.lower(abstract_params, specs["inputs"])
        else:  # decode
            step_fn = tl.make_decode_step(cfg)
            abstract_params = mdl.init_params_abstract(
                jax.random.PRNGKey(0), cfg)
            pspecs = shd.params_specs(abstract_params, rules, mesh,
                                      attn_tp=_attn_tp(cfg, mesh, rules))
            seq_shard = cell.global_batch == 1
            abstract_state = jax.eval_shape(
                lambda: mdl.init_decode_state(cfg, cell.global_batch,
                                              cell.seq_len))
            sspecs_caches = shd.decode_state_specs(
                abstract_state.caches, rules, cfg, mesh,
                seq_shard=seq_shard)
            sspecs = mdl.DecodeState(caches=sspecs_caches, index=P())
            # batch=1 (long_500k): tokens/logits replicate; the cache is
            # sequence-sharded instead
            tok_spec = P() if seq_shard else shd.batch_spec(rules)
            jitted = jax.jit(
                step_fn,
                in_shardings=(N(pspecs), N(sspecs), N(tok_spec)),
                out_shardings=(N(tok_spec), N(sspecs)),
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(abstract_params, abstract_state,
                                   specs["tokens"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis()
    xla_cost = xla_cost[0] if isinstance(xla_cost, (list, tuple)) \
        else xla_cost
    text = compiled.as_text()
    # loop-aware analysis (XLA's HloCostAnalysis counts scan bodies once;
    # see repro.analysis.hlo docstring)
    costs = hlo_an.analyze_module(text)
    coll = {k: int(v) for k, v in costs.per_collective.items()}
    coll["total"] = int(costs.collective_bytes)
    counts = dict(costs.collective_ops)

    n_active = cfg.active_param_count()
    toks = shp.tokens_per_step(cfg, shape)
    model_flops = (rf.model_flops_train(n_active, toks)
                   if cell.kind == "train"
                   else rf.model_flops_decode(n_active, toks))
    terms = rf.analyze({"flops": costs.flops,
                        "bytes accessed": costs.hbm_bytes},
                       costs.collective_bytes, chips, model_flops)

    def _mem(attr):
        v = getattr(mem, attr, None)
        return int(v) if v is not None else None

    result = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "tag": extra_tag,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": _mem("argument_size_in_bytes"),
            "output_bytes": _mem("output_size_in_bytes"),
            "temp_bytes": _mem("temp_size_in_bytes"),
            "generated_code_bytes": _mem("generated_code_size_in_bytes"),
        },
        "collective_bytes": coll,
        "collective_ops": counts,
        "xla_cost_analysis": {
            "flops": float(xla_cost.get("flops", 0.0)),
            "bytes_accessed": float(xla_cost.get("bytes accessed", 0.0)),
            "note": "loop bodies counted once by XLA; see roofline for "
                    "loop-aware numbers",
        },
        "roofline": terms.to_dict(),
    }
    return result


CELLS = [(a, s) for a in configs.names()
         for s in shp.SHAPES]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=16)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--layout", default="2d", choices=["2d", "dp"])
    ap.add_argument("--remat-policy", default="nothing",
                    choices=["nothing", "dots", "dots_no_batch"])
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--grad-accum-bf16", action="store_true")
    ap.add_argument("--moe-dispatch", default="dense",
                    choices=["dense", "sparse"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = CELLS if args.all else [(args.arch, args.shape)]
    failures = 0
    for arch, shape in cells:
        mesh_tag = "2x16x16" if args.multi_pod else "16x16"
        suffix = f"_{args.tag}" if args.tag else ""
        fname = os.path.join(
            args.out, f"{arch}_{shape}_{mesh_tag}{suffix}.json")
        if os.path.exists(fname):
            print(f"[skip-cached] {fname}")
            continue
        print(f"[dryrun] {arch} × {shape} × {mesh_tag} ...", flush=True)
        try:
            res = lower_cell(arch, shape, multi_pod=args.multi_pod,
                             microbatches=args.microbatches,
                             fsdp=not args.no_fsdp, extra_tag=args.tag,
                             layout=args.layout,
                             remat_policy=args.remat_policy,
                             seq_parallel=args.seq_parallel,
                             grad_accum_bf16=args.grad_accum_bf16,
                             moe_dispatch=args.moe_dispatch)
        except Exception as e:
            failures += 1
            res = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()}
        with open(fname, "w") as f:
            json.dump(res, f, indent=1)
        status = res["status"]
        extra = ""
        if status == "ok":
            r = res["roofline"]
            extra = (f" dom={r['dominant']} mfu={r['mfu']:.3f} "
                     f"compile={res['compile_s']}s")
        elif status == "error":
            extra = " " + res["error"][:120]
        print(f"  -> {status}{extra}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
