"""Fault-tolerant checkpointing.

Production properties implemented here:
  * **atomic**: write to ``step_K.tmp`` then rename — a crash mid-write never
    corrupts the latest checkpoint;
  * **keep-N** garbage collection;
  * **async**: serialization runs on a background thread so the train loop
    is not blocked (``wait()`` joins before exit / next save);
  * **multi-host layout**: each host writes only its addressable shards under
    ``host_<i>/`` (single-host containers write host_0), plus a metadata
    manifest for restore-time validation;
  * **elastic restore**: ``restore(..., target=...)`` reshapes to the current
    mesh by reading full arrays and letting jit re-shard them — changing the
    device count between runs is supported (elastic scaling).

Format: one ``.npz`` per host per step + a small JSON manifest.  (No orbax
offline; this is a complete self-contained implementation.)
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        # npz cannot round-trip ml_dtypes (bf16 etc.) — widen to f32;
        # restore() casts back to the target leaf dtype.
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            arr = arr.astype(np.float32)
        out[key] = arr
    return out, treedef


class CheckpointManager:

    def __init__(self, directory: str, keep: int = 3, host_id: int = 0,
                 num_hosts: int = 1):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        self.num_hosts = num_hosts
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = False):
        """Snapshot ``tree`` at ``step`` (async unless blocking)."""
        self.wait()
        arrays, _ = _flatten(tree)

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(os.path.join(tmp, f"host_{self.host_id}"),
                        exist_ok=True)
            np.savez(os.path.join(tmp, f"host_{self.host_id}",
                                  "shards.npz"), **arrays)
            manifest = {
                "step": step,
                "num_hosts": self.num_hosts,
                "keys": sorted(arrays.keys()),
                "shapes": {k: list(v.shape) for k, v in arrays.items()},
                "time": time.time(),
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            os.replace(tmp, final)           # atomic publish
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any) -> Any:
        """Restore into the structure of ``target`` (shapes validated).
        ``target`` may be ShapeDtypeStructs; arrays come back as numpy and
        are device_put/re-sharded by the caller's jit — elastic-safe."""
        path = os.path.join(self.dir, f"step_{step}",
                            f"host_{self.host_id}", "shards.npz")
        data = np.load(path)
        flat, treedef = jax.tree_util.tree_flatten_with_path(target)
        leaves = []
        for p, leaf in flat:
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in p)
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"checkpoint/model shape mismatch at {key}: "
                    f"{arr.shape} vs {leaf.shape}")
            leaves.append(np.asarray(jnp.asarray(arr).astype(leaf.dtype)))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, target: Any):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, target)
