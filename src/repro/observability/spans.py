"""Host-side span tracer: JSONL traces with monotonic timestamps.

A :class:`Tracer` records **spans** (named intervals with parent ids, so
nested work reconstructs as a tree) and **events** (instants forwarded
from the jit-safe event stream).  Records are kept in memory and — when a
path is configured — appended to a JSONL trace file, one JSON object per
line:

    {"type": "span",  "name": "dispatch", "id": 3, "parent": 1,
     "ts": 12.031, "dur": 0.0042, "tags": {...}}
    {"type": "event", "kind": "solve", "ts": 12.034, "span": 3,
     "tags": {...}, "values": {...}}

Timestamps are ``time.perf_counter()`` — monotonic seconds within the
process, which is what latency analysis needs (wall-clock epochs are
deliberately absent: traces compare *within* a run).

Nesting is tracked with a :mod:`contextvars` variable, so ``with
span("dispatch"):`` blocks parent correctly per thread/task; lifecycles
that cross threads (the solve service's per-request spans) record their
segments explicitly via :meth:`Tracer.record_span` with measured start/end
times and an explicit parent id.

``repro.observability.report`` loads and summarizes these files
(p50/p95/p99 latency per span name, iteration histograms, per-bucket
breakdowns).
"""
from __future__ import annotations

import contextlib
import contextvars
import itertools
import json
import threading
import time
from typing import Optional

__all__ = ["Span", "Tracer", "configure_tracer", "current_tracer", "span"]

_CURRENT_SPAN: contextvars.ContextVar = contextvars.ContextVar(
    "repro_observability_span", default=None)

# "inherit the ambient span" marker for start_span's parent argument,
# distinct from an explicit parent=None (a root span)
_INHERIT = object()


class Span:
    """An open span handle returned by :meth:`Tracer.start_span`."""

    __slots__ = ("name", "span_id", "parent_id", "t_start", "tags", "_token")

    def __init__(self, name: str, span_id: int, parent_id: Optional[int],
                 t_start: float, tags: dict):
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.t_start = t_start
        self.tags = tags
        self._token = None


class Tracer:
    """Span/event recorder writing JSONL; thread-safe, append-only.

    ``path=None`` keeps records in memory only (``records()``); a path
    opens the file for writing at construction (truncating — one tracer
    is one trace) and appends each record as it completes.
    """

    def __init__(self, path=None):
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._records: list = []
        self.path = str(path) if path is not None else None
        self._file = open(self.path, "w") if self.path else None

    # -- low-level record sink ----------------------------------------------

    def _write(self, rec: dict) -> None:
        with self._lock:
            self._records.append(rec)
            if self._file is not None:
                self._file.write(json.dumps(rec) + "\n")

    def records(self) -> list:
        """Copy of every record written so far (spans and events)."""
        with self._lock:
            return list(self._records)

    def flush(self) -> None:
        """Flush the backing file (if any) to disk."""
        with self._lock:
            if self._file is not None:
                self._file.flush()

    def close(self) -> None:
        """Flush and close the backing file; the tracer stays readable."""
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None

    # -- spans ---------------------------------------------------------------

    def new_id(self) -> int:
        """A fresh span id (monotonic per tracer)."""
        return next(self._ids)

    def start_span(self, name: str, *, parent=_INHERIT, **tags) -> Span:
        """Open a span; parent defaults to the ambient span of this task.

        Pass ``parent=None`` to force a root span, or an explicit span id
        (int) / :class:`Span` for cross-thread lifecycles.  The ambient
        span is NOT redirected — use :meth:`span` for scoped nesting.
        """
        if parent is _INHERIT:
            amb = _CURRENT_SPAN.get()
            parent_id = amb.span_id if amb is not None else None
        elif isinstance(parent, Span):
            parent_id = parent.span_id
        else:
            parent_id = parent
        return Span(name, self.new_id(), parent_id, time.perf_counter(),
                    dict(tags))

    def end_span(self, sp: Span, **tags) -> None:
        """Close a span: records it with its measured duration."""
        t_end = time.perf_counter()
        if tags:
            sp.tags.update(tags)
        self._write({"type": "span", "name": sp.name, "id": sp.span_id,
                     "parent": sp.parent_id, "ts": sp.t_start,
                     "dur": t_end - sp.t_start, "tags": sp.tags})

    def record_span(self, name: str, t_start: float, t_end: float, *,
                    parent=None, **tags) -> int:
        """Record a completed span from measured timestamps.

        For lifecycles that cross threads (queue wait, batched dispatch
        segments): the caller supplies ``perf_counter`` start/end times
        and an explicit ``parent`` id.  Returns the new span's id.
        """
        parent_id = parent.span_id if isinstance(parent, Span) else parent
        sid = self.new_id()
        self._write({"type": "span", "name": name, "id": sid,
                     "parent": parent_id, "ts": float(t_start),
                     "dur": float(t_end) - float(t_start),
                     "tags": dict(tags)})
        return sid

    @contextlib.contextmanager
    def span(self, name: str, **tags):
        """Scoped span: opens, redirects the ambient span, closes on exit."""
        sp = self.start_span(name, **tags)
        token = _CURRENT_SPAN.set(sp)
        try:
            yield sp
        finally:
            _CURRENT_SPAN.reset(token)
            self.end_span(sp)

    # -- events --------------------------------------------------------------

    def add_event(self, kind: str, t: float, *, tags=None,
                  values=None) -> None:
        """Record an instant event, parented under the ambient span."""
        amb = _CURRENT_SPAN.get()
        self._write({"type": "event", "kind": kind, "ts": float(t),
                     "span": amb.span_id if amb is not None else None,
                     "tags": dict(tags or {}), "values": dict(values or {})})


_tracer: Optional[Tracer] = None


def configure_tracer(path=None) -> Tracer:
    """Install (and return) the process-global tracer.

    ``path=None`` gives an in-memory tracer; a string/path writes JSONL.
    An existing :class:`Tracer` instance is installed as-is.  The
    previous tracer (if any) is closed.
    """
    global _tracer
    if _tracer is not None:
        _tracer.close()
    _tracer = path if isinstance(path, Tracer) else Tracer(path)
    return _tracer


def remove_tracer() -> None:
    """Close and uninstall the process-global tracer (no-op when absent)."""
    global _tracer
    if _tracer is not None:
        _tracer.close()
        _tracer = None


def current_tracer() -> Optional[Tracer]:
    """The installed process-global tracer, or ``None``."""
    return _tracer


@contextlib.contextmanager
def span(name: str, **tags):
    """Scoped span on the global tracer; a silent no-op when tracing is
    not configured (yields ``None``)."""
    tr = current_tracer()
    if tr is None:
        yield None
        return
    with tr.span(name, **tags) as sp:
        yield sp
