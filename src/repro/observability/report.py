"""Trace summarizer: load a JSONL trace and report latency/iteration stats.

``load_trace(path)`` reads the records a ``Tracer`` wrote;
``summarize(records)`` reduces them to:

  * per-span-name latency percentiles (count, p50/p95/p99, in ms);
  * event counts by kind;
  * an iterations-per-solve histogram (power-of-two buckets) folded from
    every ``solve``/``converged`` event's per-instance iteration counts;
  * per-bucket breakdowns: spans tagged with a ``bucket`` (the solve
    service's ``BucketKey`` label) grouped into count + p50 latency.

CLI::

    PYTHONPATH=src python -m repro.observability.report trace.jsonl
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

__all__ = ["load_trace", "summarize", "format_summary", "main"]


def load_trace(path) -> List[dict]:
    """Read a JSONL trace file into a list of record dicts."""
    records = []
    with open(str(path)) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


def _percentile(sorted_vals: List[float], q: float) -> float:
    """Nearest-rank percentile of an already-sorted list."""
    if not sorted_vals:
        return float("nan")
    idx = min(len(sorted_vals) - 1,
              max(0, int(round(q / 100.0 * (len(sorted_vals) - 1)))))
    return sorted_vals[idx]


def _latency_stats(durs_s: List[float]) -> dict:
    vals = sorted(d * 1e3 for d in durs_s)
    return {"count": len(vals),
            "p50_ms": _percentile(vals, 50.0),
            "p95_ms": _percentile(vals, 95.0),
            "p99_ms": _percentile(vals, 99.0)}


def _iter_histogram(counts: List[float]) -> Dict[str, int]:
    """Power-of-two bucket histogram of iteration counts."""
    hist: Dict[str, int] = {}
    for c in counts:
        if c < 0:                    # -1 marks untracked (pallas_cg)
            continue
        lo = 1
        while lo * 2 <= max(c, 1):
            lo *= 2
        label = f"{lo}-{lo * 2 - 1}" if c >= 1 else "0"
        hist[label] = hist.get(label, 0) + 1
    return dict(sorted(hist.items(),
                       key=lambda kv: int(kv[0].split("-")[0])))


def summarize(records: List[dict]) -> dict:
    """Reduce trace records to the summary dict documented above."""
    span_durs: Dict[str, List[float]] = {}
    bucket_durs: Dict[str, List[float]] = {}
    event_counts: Dict[str, int] = {}
    iterations: List[float] = []
    for rec in records:
        if rec.get("type") == "span":
            span_durs.setdefault(rec["name"], []).append(float(rec["dur"]))
            bucket = rec.get("tags", {}).get("bucket")
            if bucket is not None:
                bucket_durs.setdefault(str(bucket), []).append(
                    float(rec["dur"]))
        elif rec.get("type") == "event":
            kind = rec.get("kind", "?")
            event_counts[kind] = event_counts.get(kind, 0) + 1
            if kind in ("solve", "converged"):
                its = rec.get("values", {}).get("iterations")
                if its is None:
                    continue
                if isinstance(its, (int, float)):
                    iterations.append(float(its))
                else:
                    flat = its
                    while flat and isinstance(flat[0], list):
                        flat = [x for sub in flat for x in sub]
                    iterations.extend(float(x) for x in flat)
    return {
        "spans": {name: _latency_stats(durs)
                  for name, durs in sorted(span_durs.items())},
        "events": dict(sorted(event_counts.items())),
        "iterations_histogram": _iter_histogram(iterations),
        "buckets": {label: {"count": len(durs),
                            "p50_ms": _percentile(
                                sorted(d * 1e3 for d in durs), 50.0)}
                    for label, durs in sorted(bucket_durs.items())},
    }


def format_summary(summary: dict) -> str:
    """Human-readable rendering of :func:`summarize`'s output."""
    lines = ["spans (count / p50 / p95 / p99 ms):"]
    for name, s in summary["spans"].items():
        lines.append(f"  {name:<12} {s['count']:>6}  {s['p50_ms']:8.3f}"
                     f"  {s['p95_ms']:8.3f}  {s['p99_ms']:8.3f}")
    lines.append("events:")
    for kind, n in summary["events"].items():
        lines.append(f"  {kind:<16} {n}")
    if summary["iterations_histogram"]:
        lines.append("iterations per solve:")
        for label, n in summary["iterations_histogram"].items():
            lines.append(f"  {label:<10} {n}")
    if summary["buckets"]:
        lines.append("per-bucket (count / p50 ms):")
        for label, s in summary["buckets"].items():
            lines.append(f"  {label:<40} {s['count']:>6}  "
                         f"{s['p50_ms']:8.3f}")
    return "\n".join(lines)


def main(argv=None) -> None:
    """CLI: summarize one or more JSONL trace files."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+", help="JSONL trace files")
    ap.add_argument("--json", action="store_true",
                    help="print the raw summary dict as JSON")
    args = ap.parse_args(argv)
    records: List[dict] = []
    for path in args.paths:
        records.extend(load_trace(path))
    summary = summarize(records)
    if args.json:
        print(json.dumps(summary, indent=2))
    else:
        print(format_summary(summary))


if __name__ == "__main__":
    main()
