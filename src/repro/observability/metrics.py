"""Metrics registry: counters, gauges and histograms with two export paths.

One ``MetricsRegistry`` instance is a self-contained namespace of named,
labelled instruments.  Every layer of the stack reports through a registry
instead of a hand-rolled counter dict:

  * the solve service holds its own registry (sharing the service lock, so
    a scrape never observes torn counters mid-dispatch);
  * host-side control paths (autotune decisions, bilevel outer steps, the
    warm-start cache) report into the process-global registry returned by
    :func:`global_registry`;
  * the jit-safe event stream (``repro.observability.events``) bridges
    per-solve diagnostics into the global registry when observability is
    enabled.

Export paths: :meth:`MetricsRegistry.snapshot` returns one frozen plain
dict (JSON-ready), :meth:`MetricsRegistry.to_prometheus` renders the
standard Prometheus text exposition format — no client library required.

Instruments are cheap host-side objects (a float behind a lock); none of
this code ever runs on device or inside a compiled program.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "global_registry", "reset_global_registry",
    "DEFAULT_BUCKETS", "ITERATION_BUCKETS", "LATENCY_BUCKETS",
]

# generic magnitude buckets (unitless values, occupancies, ratios)
DEFAULT_BUCKETS = (0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
                   5.0, 10.0)
# iteration-count buckets: powers of two out to the default maxiter
ITERATION_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0,
                     512.0, 1024.0)
# wall-clock buckets in seconds (microseconds out to tens of seconds)
LATENCY_BUCKETS = (1e-5, 1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 5e-2, 0.1, 0.5,
                   1.0, 5.0, 10.0)


def _label_key(labels: Dict[str, str]) -> str:
    """Render a label dict to its canonical (sorted) Prometheus form."""
    if not labels:
        return ""
    return ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))


class Counter:
    """A monotonically increasing value (``inc`` only)."""

    __slots__ = ("_lock", "_value")

    def __init__(self, lock: threading.RLock):
        self._lock = lock
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        """Add ``n`` (must be >= 0) to the counter."""
        if n < 0:
            raise ValueError(f"counters only go up; got inc({n})")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        """Current accumulated value."""
        with self._lock:
            return self._value


class Gauge:
    """A value that can go up and down (``set``/``inc``)."""

    __slots__ = ("_lock", "_value")

    def __init__(self, lock: threading.RLock):
        self._lock = lock
        self._value = 0.0

    def set(self, v: float) -> None:
        """Set the gauge to ``v``."""
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        """Add ``n`` (may be negative) to the gauge."""
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        """Current value."""
        with self._lock:
            return self._value


class Histogram:
    """A cumulative-bucket histogram (Prometheus semantics).

    ``observe(v)`` increments every bucket whose upper bound ``le`` is
    >= v (cumulative counts), plus ``sum`` and ``count`` — exactly the
    ``_bucket``/``_sum``/``_count`` triplet the text exposition renders.
    """

    __slots__ = ("_lock", "buckets", "_counts", "_sum", "_count")

    def __init__(self, lock: threading.RLock,
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self._lock = lock
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")
        self._counts = [0] * len(self.buckets)
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        """Record one observation ``v``."""
        v = float(v)
        with self._lock:
            self._sum += v
            self._count += 1
            for i, le in enumerate(self.buckets):
                if v <= le:
                    self._counts[i] += 1

    def observe_many(self, vs) -> None:
        """Record every observation in an iterable of floats."""
        for v in vs:
            self.observe(v)

    @property
    def sum(self) -> float:
        """Sum of all observed values."""
        with self._lock:
            return self._sum

    @property
    def count(self) -> int:
        """Number of observations."""
        with self._lock:
            return self._count

    def state(self) -> dict:
        """Frozen copy: ``{"count", "sum", "buckets": {le: cum_count}}``."""
        with self._lock:
            return {"count": self._count, "sum": self._sum,
                    "buckets": dict(zip(self.buckets, self._counts))}


_KIND_NAMES = {Counter: "counter", Gauge: "gauge", Histogram: "histogram"}


class MetricsRegistry:
    """A namespace of named, labelled counters/gauges/histograms.

    ``counter(name, **labels)`` (and ``gauge``/``histogram``) get-or-create
    the instrument for that exact ``(name, labels)`` pair — repeated calls
    return the same object, so callers can either cache the handle or
    re-resolve it on every update.  One ``name`` is always one instrument
    kind; mixing kinds under a name raises.

    ``lock`` lets an owner share its own mutex with the registry (the
    solve service passes its service lock), making *every* instrument
    update and the :meth:`snapshot` atomic with respect to the owner's
    critical sections.  The default is a private ``RLock``.
    """

    def __init__(self, lock: Optional[threading.RLock] = None):
        self._lock = lock if lock is not None else threading.RLock()
        self._instruments: Dict[Tuple[str, str], object] = {}
        self._kinds: Dict[str, type] = {}
        self._help: Dict[str, str] = {}

    def _get(self, cls, name: str, labels: Dict[str, str], help: str,
             **extra):
        with self._lock:
            known = self._kinds.get(name)
            if known is not None and known is not cls:
                raise ValueError(
                    f"metric {name!r} is already registered as a "
                    f"{_KIND_NAMES[known]}; cannot re-register as a "
                    f"{_KIND_NAMES[cls]}")
            key = (name, _label_key({k: str(v) for k, v in labels.items()}))
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls(self._lock, **extra)
                self._instruments[key] = inst
                self._kinds[name] = cls
                if help:
                    self._help[name] = help
            return inst

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        """Get-or-create the :class:`Counter` for ``(name, labels)``."""
        return self._get(Counter, name, labels, help)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        """Get-or-create the :class:`Gauge` for ``(name, labels)``."""
        return self._get(Gauge, name, labels, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        """Get-or-create the :class:`Histogram` for ``(name, labels)``."""
        return self._get(Histogram, name, labels, help, buckets=buckets)

    def snapshot(self) -> dict:
        """One frozen, JSON-ready copy of every instrument.

        Shape: ``{name: {"type": kind, "help": str, "values":
        {label_key: value}}}`` where a histogram's value is its
        ``state()`` dict.  Taken atomically under the registry lock — a
        scrape never observes a torn multi-counter update from an owner
        that shares the lock.
        """
        with self._lock:
            out: dict = {}
            for (name, lk), inst in self._instruments.items():
                entry = out.setdefault(
                    name, {"type": _KIND_NAMES[type(inst)],
                           "help": self._help.get(name, ""), "values": {}})
                if isinstance(inst, Histogram):
                    entry["values"][lk] = inst.state()
                else:
                    entry["values"][lk] = inst.value
            return out

    def to_prometheus(self) -> str:
        """Render the standard Prometheus text exposition format.

        ``# HELP`` / ``# TYPE`` headers per metric name, one sample line
        per label set; histograms expand to the ``_bucket`` (cumulative,
        with the ``+Inf`` terminal), ``_sum`` and ``_count`` series.
        """
        snap = self.snapshot()
        lines = []
        for name in sorted(snap):
            entry = snap[name]
            if entry["help"]:
                lines.append(f"# HELP {name} {entry['help']}")
            lines.append(f"# TYPE {name} {entry['type']}")
            for lk in sorted(entry["values"]):
                val = entry["values"][lk]
                if entry["type"] == "histogram":
                    for le, c in val["buckets"].items():
                        sep = "," if lk else ""
                        lines.append(
                            f'{name}_bucket{{{lk}{sep}le="{le:g}"}} {c}')
                    sep = "," if lk else ""
                    lines.append(
                        f'{name}_bucket{{{lk}{sep}le="+Inf"}} '
                        f'{val["count"]}')
                    suffix = f"{{{lk}}}" if lk else ""
                    lines.append(f'{name}_sum{suffix} {val["sum"]:g}')
                    lines.append(f'{name}_count{suffix} {val["count"]}')
                else:
                    suffix = f"{{{lk}}}" if lk else ""
                    lines.append(f"{name}{suffix} {val:g}")
        return "\n".join(lines) + ("\n" if lines else "")

    def reset(self) -> None:
        """Drop every instrument (tests; a fresh registry is cheaper)."""
        with self._lock:
            self._instruments.clear()
            self._kinds.clear()
            self._help.clear()


_GLOBAL = MetricsRegistry()


def global_registry() -> MetricsRegistry:
    """The process-global registry host-side control paths report into."""
    return _GLOBAL


def reset_global_registry() -> MetricsRegistry:
    """Clear the process-global registry (test isolation); returns it."""
    _GLOBAL.reset()
    return _GLOBAL
