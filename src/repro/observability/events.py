"""Jit-safe solve telemetry: the ``SolveEvent`` stream.

Every layer of the stack emits events through two entry points:

  * :func:`emit` — host-side code (the solve service, the bilevel outer
    loop, caches) emits immediately;
  * :func:`jit_event` — traced code (solver bodies, the implicit-diff
    backward path) stages a ``jax.debug.callback`` so the event fires at
    *execution* time with runtime values (iteration counts, residuals),
    from inside ``jit``/``lax.while_loop``/``lax.custom_linear_solve``
    (:func:`jit_event_pair` delivers a ``*_start``/``*_done`` pair from
    one staged callback — host callbacks are the dominant enabled-mode
    cost, so pairs are never staged as two).

Both are gated by the process-level :func:`observe` switch.  The gate is
checked at **trace time**: with observability disabled (the default),
``jit_event`` returns before staging anything, so the compiled program is
bit-identical to an uninstrumented build — the disabled-mode overhead is
zero by construction (``benchmarks/obs_overhead.py`` gates it at <= 2%
against the raw solver anyway).  The flip side: programs compiled while
disabled stay uninstrumented until re-traced — enable observability
*before* building jitted functions or services you want telemetry from.

Sharded solves are instrumented at the solver-registry seam, *outside*
``shard_map`` — the callback therefore fires **once per compiled program
execution**, not once per device, and its values are the gathered global
diagnostics (asserted by the 8-device CI lane).  Per-iteration events
(``iteration_events=True``) are the one exception: they ride inside the
solver loop body, which for the sharded solvers runs per shard.

Event kinds (the schema; ``tags`` are static strings/ints fixed at trace
time, ``values`` are runtime arrays):

  ==================  =====================================================
  ``solve_start``     a registry solver begins (tags: solver, B, d, dtype,
                      mesh_size)
  ``solve``           a registry solve finished (values: iterations,
                      residual, converged — per instance)
  ``iteration``       one solver-loop step (opt-in; deep debugging)
  ``converged``       an ``IterativeSolver.run``/``run_stochastic`` outer
                      loop finished (values: iterations, error, converged)
  ``backward_start``  an implicit-diff backward/tangent solve begins
                      (tags: direction, backward mode, matvec_budget)
  ``backward_done``   ... and finished (values incl.
                      hypergrad_error_estimate when measured)
  ``dispatch``        a routing decision resolved (host, trace-time)
  ``cache_hit`` / ``cache_miss``  warm-start cache lookups (host)
  ``bilevel_step``    one outer step of ``solve_bilevel`` (host)
  ==================  =====================================================

Events fan out to: the in-memory recorder (``record=True``), registered
subscribers, the global tracer's JSONL stream (when configured), and a
metrics bridge that folds per-solve iteration counts into the global
``MetricsRegistry`` histograms.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.observability import metrics as _metrics
from repro.observability import spans as _spans

__all__ = [
    "SolveEvent", "EVENT_KINDS", "observe", "observing",
    "observing_iterations", "emit", "jit_event", "jit_event_pair",
    "subscribe", "recorded", "clear_recorded",
]

EVENT_KINDS = (
    "solve_start", "solve", "iteration", "converged", "backward_start",
    "backward_done", "dispatch", "cache_hit", "cache_miss", "bilevel_step",
)


@dataclasses.dataclass(frozen=True)
class SolveEvent:
    """One telemetry event: a kind, static tags, and runtime values.

    ``t`` is ``time.perf_counter()`` at emission (host receipt time for
    ``jit_event`` — ordering within a device stream is preserved, exact
    device-side timing is not the contract).  ``tags`` are trace-time
    statics (solver name, B, d, dtype, mesh_size, backward mode);
    ``values`` are host copies of runtime arrays (iterations, residuals,
    convergence flags, error estimates).
    """
    kind: str
    t: float
    tags: Dict[str, Any]
    values: Dict[str, Any]


_lock = threading.Lock()
_enabled = False
_iteration_events = False
_recording = False
_records: list = []
_subscribers: list = []


def observing() -> bool:
    """True when the process-level observability switch is on."""
    return _enabled


def observing_iterations() -> bool:
    """True when per-iteration events are enabled (opt-in; expensive)."""
    return _enabled and _iteration_events


class _ObserveHandle:
    """Context manager restoring the prior observability configuration."""

    def __init__(self, prev_state, owns_tracer: bool):
        self._prev = prev_state
        self._owns_tracer = owns_tracer

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        global _enabled, _iteration_events, _recording
        _enabled, _iteration_events, _recording = self._prev
        if self._owns_tracer:
            _spans.remove_tracer()
        return False


def observe(enabled: bool = True, *, iteration_events: bool = False,
            record: bool = False, trace_path=None) -> _ObserveHandle:
    """Flip the process-level observability switch.

    Applies immediately; the return value doubles as a context manager
    that restores the previous configuration (and removes a tracer this
    call installed) on exit — ``with observe(enabled=True): ...`` is the
    test/benchmark idiom.

    ``iteration_events`` opts into per-loop-step events (deep debugging —
    a host callback per solver iteration; never on by default).
    ``record=True`` accumulates events in-process for :func:`recorded`.
    ``trace_path`` installs a global JSONL tracer at that path (see
    ``repro.observability.spans``), so events and spans stream to disk.

    The switch is read at trace time: functions jitted while disabled
    stay uninstrumented until re-traced (and vice versa) — enable first,
    then build the jitted functions/services you want telemetry from.
    Beware that jax's trace cache keys on callable identity: wrapping
    the SAME function object in a new ``jax.jit`` (or re-running
    ``make_jaxpr`` on it) after flipping the switch can serve the stale
    trace — build a fresh callable for a fresh trace.
    """
    global _enabled, _iteration_events, _recording
    prev = (_enabled, _iteration_events, _recording)
    _enabled = bool(enabled)
    _iteration_events = bool(iteration_events)
    _recording = bool(record)
    owns_tracer = trace_path is not None
    if owns_tracer:
        _spans.configure_tracer(trace_path)
    return _ObserveHandle(prev, owns_tracer)


def recorded() -> tuple:
    """Events captured so far under ``observe(record=True)``."""
    with _lock:
        return tuple(_records)


def clear_recorded() -> None:
    """Drop the in-process event recording buffer."""
    with _lock:
        _records.clear()


def subscribe(fn: Callable[[SolveEvent], None]) -> Callable[[], None]:
    """Register an event subscriber; returns an unsubscribe callable."""
    with _lock:
        _subscribers.append(fn)

    def unsubscribe():
        with _lock:
            if fn in _subscribers:
                _subscribers.remove(fn)

    return unsubscribe


# -- dispatch ----------------------------------------------------------------

def _host(v):
    """Copy a runtime value to host numpy (labels/strings pass through)."""
    if isinstance(v, (str, bytes, bool, int, float, type(None))):
        return v
    try:
        return np.asarray(v)
    except Exception:
        return v


def _jsonable(v):
    """Best-effort JSON-safe rendering of an event value."""
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, (np.generic,)):
        return v.item()
    return v


def _bridge_metrics(ev: SolveEvent) -> None:
    """Fold an event into the global registry (counters + histograms)."""
    reg = _metrics.global_registry()
    solver = str(ev.tags.get("solver", ""))
    reg.counter("repro_events_total",
                help="telemetry events by kind and solver",
                kind=ev.kind, solver=solver).inc()
    its = ev.values.get("iterations")
    if its is not None and ev.kind in ("solve", "converged"):
        arr = np.asarray(its, dtype=np.float64).ravel()
        arr = arr[arr >= 0]          # -1 marks untracked (pallas_cg)
        if arr.size:
            reg.histogram("repro_solve_iterations",
                          help="per-instance solver iteration counts",
                          buckets=_metrics.ITERATION_BUCKETS,
                          solver=solver).observe_many(arr.tolist())
    est = ev.values.get("hypergrad_error_estimate")
    if est is not None and ev.kind == "backward_done":
        arr = np.asarray(est, dtype=np.float64).ravel()
        arr = arr[np.isfinite(arr)]
        if arr.size:
            reg.histogram("repro_hypergrad_error_estimate",
                          help="relative residual of the implicit "
                               "backward system",
                          buckets=_metrics.DEFAULT_BUCKETS,
                          backward=str(ev.tags.get("backward", "")),
                          ).observe_many(arr.tolist())


def _dispatch(kind: str, tags: Dict[str, Any],
              values: Dict[str, Any]) -> None:
    """Deliver one event to every sink (recorder/metrics/tracer/subs)."""
    vals = {k: _host(v) for k, v in values.items()}
    ev = SolveEvent(kind=kind, t=time.perf_counter(), tags=dict(tags),
                    values=vals)
    with _lock:
        if _recording:
            _records.append(ev)
        subs = list(_subscribers)
    _bridge_metrics(ev)
    tr = _spans.current_tracer()
    if tr is not None:
        tr.add_event(ev.kind, ev.t, tags=ev.tags,
                     values={k: _jsonable(v) for k, v in vals.items()})
    for fn in subs:
        fn(ev)


def emit(kind: str, tags: Optional[Dict[str, Any]] = None,
         **values) -> None:
    """Emit one event from host code; no-op while observability is off."""
    if not _enabled:
        return
    _dispatch(kind, tags or {}, values)


def jit_event(kind: str, tags: Optional[Dict[str, Any]] = None,
              **values) -> None:
    """Emit one event from *traced* code, jit-safely.

    When observability is enabled at trace time, stages a
    ``jax.debug.callback`` carrying ``values`` (arrays allowed — they are
    copied to host at execution time); when disabled, returns before
    staging anything, so the compiled program is unchanged.  Safe inside
    ``jit``, ``lax.while_loop`` bodies, and ``custom_linear_solve``
    templates; place calls *outside* ``shard_map`` for once-per-program
    semantics.
    """
    if not _enabled:
        return
    cb = functools.partial(_dispatch, kind, dict(tags or {}))
    jax.debug.callback(cb, values)


def jit_event_pair(start_kind: str, end_kind: str,
                   tags: Optional[Dict[str, Any]] = None, **values) -> None:
    """Stage ONE callback delivering a start/end event pair.

    A bare ``jax.debug.callback`` costs hundreds of microseconds of
    host-sync per staged call on CPU — it dominates enabled-mode
    overhead, dwarfing anything the dispatch fan-out does.  Pairing the
    ``*_start``/``*_done`` idiom into a single callback halves that
    cost.  The start event carries tags only and shares the end event's
    host receipt time; stream *ordering* is preserved, and per-event
    host timing was never the contract (spans measure time).
    """
    if not _enabled:
        return
    start_tags, end_tags = dict(tags or {}), dict(tags or {})

    def cb(vals):
        _dispatch(start_kind, start_tags, {})
        _dispatch(end_kind, end_tags, vals)

    jax.debug.callback(cb, values)
