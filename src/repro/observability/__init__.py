"""Observability: jit-safe solve telemetry, span tracing, and metrics.

A bottom-adjacent subsystem (it imports nothing above
``repro.core.operators`` — in fact nothing from ``repro`` at all), so
every layer of the stack can report through it without import cycles:

  * **events** (``repro.observability.events``) — the ``SolveEvent``
    stream: solver iteration counts, residuals, backward-solve
    diagnostics, emitted jit-safely from inside compiled programs via
    ``jax.debug.callback`` behind the process-level :func:`observe`
    switch (a trace-time no-op when off: zero disabled-mode overhead);
  * **spans** (``repro.observability.spans``) — a host-side tracer
    writing JSONL traces with monotonic timestamps and parent ids
    (request lifecycles in the solve service, ``span("dispatch")``
    blocks anywhere);
  * **metrics** (``repro.observability.metrics``) — a
    ``MetricsRegistry`` of counters/gauges/histograms with a frozen JSON
    snapshot and Prometheus text exposition;
  * **report** (``repro.observability.report``) — loads JSONL traces and
    summarizes p50/p95/p99 latency, iterations-per-solve histograms and
    per-bucket breakdowns (also a CLI:
    ``python -m repro.observability.report trace.jsonl``).

See ``docs/observability.md`` for the full schema, lifecycle diagram and
overhead numbers.
"""
from repro.observability.events import (EVENT_KINDS, SolveEvent,
                                        clear_recorded, emit, jit_event,
                                        jit_event_pair, observe, observing,
                                        observing_iterations, recorded,
                                        subscribe)
from repro.observability.metrics import (DEFAULT_BUCKETS, ITERATION_BUCKETS,
                                         LATENCY_BUCKETS, Counter, Gauge,
                                         Histogram, MetricsRegistry,
                                         global_registry,
                                         reset_global_registry)
from repro.observability.report import (format_summary, load_trace,
                                        summarize)
from repro.observability.spans import (Span, Tracer, configure_tracer,
                                       current_tracer, remove_tracer, span)

__all__ = [
    # events
    "EVENT_KINDS", "SolveEvent", "observe", "observing",
    "observing_iterations", "emit", "jit_event", "jit_event_pair",
    "subscribe", "recorded", "clear_recorded",
    # metrics
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "global_registry",
    "reset_global_registry", "DEFAULT_BUCKETS", "ITERATION_BUCKETS",
    "LATENCY_BUCKETS",
    # spans
    "Span", "Tracer", "configure_tracer", "current_tracer",
    "remove_tracer", "span",
    # report
    "load_trace", "summarize", "format_summary",
]
