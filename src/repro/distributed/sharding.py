"""Sharding rules: map every param/activation/optimizer leaf to a
PartitionSpec on the production mesh.

Strategy (DESIGN.md §5) — 2-D "FSDP × TP" layout:
  * Each weight matrix shards its LARGEST dim over ``model`` (tensor
    parallelism) and its second-largest over ``data`` (ZeRO-3/FSDP),
    subject to divisibility; non-divisible dims fall back to replication
    on that axis.
  * Vectors (norm scales, biases) replicate.
  * Embedding / unembedding shard vocab over ``model``, d_model over
    ``data`` (vocab is always the largest dim).
  * MoE expert tensors (E, d, f): experts over ``model`` when divisible
    (DeepSeek 160/16), else the f/d dims take the 2-D layout.
  * The ``pod`` axis is pure data parallelism: batch shards over
    ("pod", "data"); params never shard over ``pod``.
  * Activations: batch over ("pod", "data") [or ``data`` single-pod];
    d_model replicated; for long-context decode with batch=1, the KV cache /
    recurrent state shards sequence/heads instead (see kv_cache_spec).

Everything returns ``jax.sharding.PartitionSpec`` trees aligned with the
params pytree, so ``jax.jit(in_shardings=...)`` consumes them directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Axis names on the mesh."""
    data: str = "data"
    model: str = "model"
    pod: Optional[str] = None        # present on multi-pod meshes

    @property
    def batch_axes(self):
        return (self.pod, self.data) if self.pod else self.data


def abstract_mesh(axis_sizes: Tuple[int, ...], axis_names: Tuple[str, ...]):
    """Version-portable ``jax.sharding.AbstractMesh`` constructor.

    jax ≤ 0.4.x takes one ``((name, size), ...)`` shape tuple; jax ≥ 0.5
    takes ``(axis_sizes, axis_names)`` positionally.  Shape-only meshes need
    no physical devices, so spec construction works on any host.
    """
    try:
        return jax.sharding.AbstractMesh(tuple(axis_sizes),
                                         tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_sizes)))


def mesh_axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh_axis_size(mesh, n)
        return out
    return mesh.shape[name]


def _divisible(dim: int, n: int) -> bool:
    return n > 0 and dim % n == 0


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               rules: ShardingRules, mesh: Mesh,
               fsdp: bool = True, attn_tp: bool = True) -> P:
    """2-D FSDP×TP spec for one parameter leaf.

    ``path`` is the flattened dict path (used for embedding special-casing);
    ``shape`` EXCLUDES the stacked layer axis (callers strip it).
    """
    n_model = mesh_axis_size(mesh, rules.model)
    n_data = mesh_axis_size(mesh, rules.data)
    name = "/".join(str(p) for p in path)

    if len(shape) == 0 or max(shape) == 1:
        return P()
    if len(shape) == 1:
        # vectors: shard over model when large & divisible (e.g. MoE biases)
        if shape[0] >= 8192 and _divisible(shape[0], n_model):
            return P(rules.model)
        return P()

    # embedding tables: vocab dim -> model (column-parallel unembed), d
    # replicated.  FSDP-sharding d over `data` makes XLA partial-sum the
    # LOGITS over the data axis (GBs per microbatch) instead of gathering
    # the 10s-of-MB weight shard — measured 2.5GB/mb on qwen1.5-4b.
    if "embed" in name or "unembed" in name:
        spec = [None] * len(shape)
        vocab_dim = int(np.argmax(shape))
        if _divisible(shape[vocab_dim], n_model):
            spec[vocab_dim] = rules.model
        return P(*spec)

    # MoE expert stacks: (E, d_in, d_out)
    if len(shape) == 3 and ("mlp" in name or "expert" in name):
        E = shape[0]
        spec = [None, None, None]
        leaf = str(path[-1]) if path else ""
        if _divisible(E, n_model):
            spec[0] = rules.model      # expert parallelism
            if fsdp:
                big = 1 + int(shape[2] > shape[1])
                if _divisible(shape[big], n_data):
                    spec[big] = rules.data
        else:
            # Megatron pairing inside each expert (E too ragged to shard):
            # in-projections column-parallel (f on model), out-projection
            # row-parallel — otherwise the up-matmul contracts the model-
            # sharded d and all-reduces (b,s,E,f) activations (§Perf G2).
            out_dim = 1 if leaf in ("w_down", "w_out") else 2
            in_dim = 3 - out_dim
            if _divisible(shape[out_dim], n_model):
                spec[out_dim] = rules.model
            if fsdp and _divisible(shape[in_dim], n_data):
                spec[in_dim] = rules.data
        return P(*spec)

    # other ≥3-D tensors (LoRA stacks, conv filters): largest divisible dim
    # on model, second on data
    if len(shape) != 2:
        spec = [None] * len(shape)
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        if _divisible(shape[order[0]], n_model) and shape[order[0]] >= 128:
            spec[order[0]] = rules.model
        if fsdp and len(order) > 1 and \
                _divisible(shape[order[1]], n_data) and \
                shape[order[1]] >= 128:
            spec[order[1]] = rules.data
        return P(*spec)

    # generic matrices — Megatron pairing: project-in weights are
    # column-parallel (output dim on `model`), project-out weights are
    # row-parallel (input dim on `model`), so each attention/MLP block costs
    # ONE activation all-reduce instead of one per matmul.
    leaf = str(path[-1]) if path else ""
    attn_leaf = ("attn" in name) and leaf in ("w_q", "w_k", "w_v", "w_o")
    if attn_leaf and not attn_tp:
        # heads don't divide the model axis: TP would split head_dim and
        # partial-sum the attention logits over `model` (§Perf G2) — use
        # FSDP-only sharding for the attention projections instead.
        spec = [None, None]
        if fsdp:
            io_dim = 0 if leaf != "w_o" else 1    # the d_model side
            if _divisible(shape[io_dim], n_data):
                spec[io_dim] = rules.data
        return P(*spec)
    if leaf in ("w_o", "w_down", "w_out", "w_v" if "cm" in name else "_"):
        big = 0        # row-parallel: contract dim on model
    elif leaf in ("w_q", "w_k", "w_up", "w_gate", "w_r", "w_g", "w_in",
                  "w_uq", "w_uk", "w_uv", "w_dq", "w_dkv") or \
            leaf == "w_v":
        big = 1        # column-parallel: output dim on model
    else:
        big = int(np.argmax(shape))
    small = 1 - big
    spec = [None, None]
    if _divisible(shape[big], n_model):
        spec[big] = rules.model
    if fsdp and _divisible(shape[small], n_data):
        spec[small] = rules.data
    return P(*spec)


def params_specs(params_shape: Any, rules: ShardingRules, mesh: Mesh,
                 stacked_layers: bool = True, fsdp: bool = True,
                 attn_tp: bool = True) -> Any:
    """PartitionSpec tree for the whole params pytree.

    ``params_shape`` is a pytree of ShapeDtypeStructs (or arrays); the
    leading stacked-layer axis of ``blocks/**`` leaves is never sharded.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        keys = tuple(getattr(k, "key", getattr(k, "idx", None))
                     for k in path)
        shape = tuple(leaf.shape)
        if stacked_layers and keys and keys[0] == "blocks" and shape:
            inner = param_spec(keys, shape[1:], rules, mesh, fsdp, attn_tp)
            specs.append(P(None, *inner))
        else:
            specs.append(param_spec(keys, shape, rules, mesh, fsdp,
                                    attn_tp))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_spec(rules: ShardingRules) -> P:
    """Token batches: (B, S) or (B, S, d) — batch over (pod, data)."""
    return P(rules.batch_axes)


def activation_spec(rules: ShardingRules) -> P:
    return P(rules.batch_axes, None, None)


def kv_cache_spec(rules: ShardingRules, cfg: ArchConfig, mesh: Mesh,
                  batch: int, seq_shard: bool = False) -> P:
    """KV caches (L, B, S, H, d): batch over data, heads over model.
    ``seq_shard=True`` (long_500k, batch=1): shard S over data instead —
    sequence parallelism for the cache."""
    n_model = mesh_axis_size(mesh, rules.model)
    heads_ok = _divisible(cfg.num_kv_heads, n_model)
    if seq_shard:
        return P(None, None, rules.data, rules.model if heads_ok else None,
                 None)
    return P(None, rules.batch_axes, None,
             rules.model if heads_ok else None, None)


def decode_state_specs(state_shape: Any, rules: ShardingRules,
                       cfg: ArchConfig, mesh: Mesh,
                       seq_shard: bool = False) -> Any:
    """Specs for a DecodeState pytree (stacked caches + scalar index)."""
    n_model = mesh_axis_size(mesh, rules.model)
    n_data = mesh_axis_size(mesh, rules.data)

    def spec_for(leaf):
        shape = tuple(leaf.shape)
        if len(shape) == 0:
            return P()
        # all caches have a leading stacked-layer axis
        spec = [None] * len(shape)
        if len(shape) >= 2:
            batch_dim = 1
            if seq_shard and len(shape) >= 3:
                # shard the longest non-layer dim (the sequence) over data
                seq_dim = int(np.argmax(shape[1:])) + 1
                if _divisible(shape[seq_dim], n_data):
                    spec[seq_dim] = rules.data
            elif _divisible(shape[batch_dim],
                            mesh_axis_size(mesh, rules.data)
                            * mesh_axis_size(mesh, rules.pod)):
                spec[batch_dim] = rules.batch_axes
            # shard the LARGEST remaining divisible dim over model — for
            # 32k/500k KV caches that is the sequence dim (GQA kv=8 heads
            # cannot split 16 ways; sequence-parallel caches can)
            cand = sorted(range(2, len(shape)),
                          key=lambda i: -shape[i])
            for dim in cand:
                if spec[dim] is None and _divisible(shape[dim], n_model) \
                        and shape[dim] >= n_model:
                    spec[dim] = rules.model
                    break
        return P(*spec)

    return jax.tree_util.tree_map(spec_for, state_shape)


def named(mesh: Mesh, spec_tree: Any) -> Any:
    """Bind a tree of ``PartitionSpec``s to ``mesh`` as ``NamedSharding``s."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
