"""Mesh-aware sharded operators: distributed linear solves behind one seam.

The paper's implicit differentiation rides "on top of any state-of-the-art
solver" once the optimality conditions ``F`` are specified — and at
production scale the solver runs on a mesh.  The Jacobian operator
``A = -∂₁F`` should never be gathered to one device: its matvec is a JVP
that executes under ``shard_map`` with the same PartitionSpecs as the
forward solve.  This module makes placement a property of the operator,
exactly like symmetry and batching already are (PR 4):

  * ``ShardedOperator`` — wraps any ``LinearOperator`` (or a per-shard
    *factory* of one) with a ``Mesh`` + in/out ``PartitionSpec`` trees.
    ``matvec``/``rmatvec`` run under ``shard_map``; ``diagonal()`` /
    ``materialize()`` return per-shard pieces; the dot-product/norm
    reductions CG needs go through a pluggable ``psum``-based hook.
  * ``SolveSharding`` — the placement bundle the implicit-diff layer
    threads through ``ImplicitDiffSpec.sharding``: mesh + spec for the
    solution ``x`` (+ optional per-theta specs), so ``JacobianOperator``
    inherits the primal solution's placement and ``jax.grad``/``jax.jvp``
    of a decorated solver execute ONE sharded backward solve with no host
    gather.
  * ``sharded_solve_cg`` / ``sharded_solve_normal_cg`` /
    ``sharded_solve_dense_gmres`` — the distributed variants behind the
    ``"sharded_cg"`` / ``"sharded_normal_cg"`` / ``"sharded_dense_gmres"``
    ``SolverSpec`` registry names: the WHOLE masked solve loop runs inside
    one ``shard_map`` (per-instance convergence masks intact), with
    cross-device communication confined to the reduction hook.

Shard-locality contract
-----------------------
``shard_map`` hands the wrapped operator *local shards*.  The base
operator's matvec must therefore be **shard-local**: applying it to the
local shard of ``v`` yields the local shard of ``A v``.  That holds for

  * batch sharding (``batch_ndim == 1``, the leading batch axis sharded):
    the operator is block-diagonal over instances, so each device's local
    matvec over its batch slice is exact — the production case for batched
    hypergradients;
  * instance-dim sharding of operators that are block-diagonal along the
    sharded dim (diagonal/elementwise systems), or whose matvec performs
    its own collectives (mesh axis names are in scope inside the matvec).

Anything the matvec *closes over* is replicated into every shard; arrays
that must be sharded alongside the domain (the Jacobian's primal point,
batched theta) are passed as ``operands`` with ``operand_specs`` and reach
the operator through a per-shard factory.

Reductions: per-instance scalars (step sizes, residual norms, ``done``
masks) are local under pure batch sharding — the only cross-device
communication is the ``psum`` over *instance-sharding* axes, which is why
the hook receives exactly those axes.  Devices holding different batch
shards never communicate and may even exit their solve loops at different
iteration counts.

Example::

    mesh = make_solve_mesh()                      # 1-D mesh over devices
    sh = SolveSharding(mesh, P("data", None), batch_ndim=1,
                       theta_specs=(P("data"),))
    spec = ImplicitDiffSpec(optimality_fun=F, solve="cg", sharding=sh)
    solver = implicit_diff(spec)(my_sharded_solver)
    jax.grad(loss)(theta)    # ONE sharded backward solve, no host gather
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import linear_solve as ls
from repro.core import operators as ops
from repro.core.operators import LinearOperator


# ---------------------------------------------------------------------------
# spec utilities
# ---------------------------------------------------------------------------

def spec_tree(spec, tree):
    """Broadcast a single ``PartitionSpec`` over ``tree`` (a matching pytree
    of specs passes through)."""
    if isinstance(spec, P):
        return jax.tree_util.tree_map(lambda _: spec, tree)
    return spec


def _spec_leaves(specs):
    return jax.tree_util.tree_leaves(specs,
                                     is_leaf=lambda x: isinstance(x, P))


def _axes_of(entry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, (tuple, list)):
        out: Tuple[str, ...] = ()
        for e in entry:
            out += _axes_of(e)
        return out
    return (entry,)


def instance_axes(specs, batch_ndim: int) -> Tuple[str, ...]:
    """Mesh axes that shard *instance* dims (spec positions ≥ batch_ndim) —
    the axes a distributed dot product must ``psum`` over."""
    found: list = []
    for leaf in _spec_leaves(specs):
        for entry in tuple(leaf)[batch_ndim:]:
            for name in _axes_of(entry):
                if name not in found:
                    found.append(name)
    return tuple(found)


def batch_axes(specs, batch_ndim: int) -> Tuple[str, ...]:
    """Mesh axes that shard the leading batch dim (spec position 0 when
    ``batch_ndim == 1``)."""
    if batch_ndim == 0:
        return ()
    found: list = []
    for leaf in _spec_leaves(specs):
        entries = tuple(leaf)
        if entries:
            for name in _axes_of(entries[0]):
                if name not in found:
                    found.append(name)
    return tuple(found)


def psum_reduction(axis_names: Tuple[str, ...]) -> Callable:
    """The default reduction hook: ``lax.psum`` over the instance-sharding
    axes (identity when nothing cross-device is needed, e.g. pure batch
    sharding).  Plug a custom hook for hierarchical/approximate reductions.
    """
    if not axis_names:
        return lambda x: x
    return lambda x: jax.lax.psum(x, axis_names)


# ---------------------------------------------------------------------------
# the sharded operator
# ---------------------------------------------------------------------------

def _overrides(op: LinearOperator, name: str) -> bool:
    """Whether ``op`` brings its own ``name`` instead of the matrix-free
    base default.  ``FunctionOperator.rmatvec`` only counts when an
    explicit rmatvec closure was supplied (its override otherwise falls
    through to the base default)."""
    if name == "rmatvec" and isinstance(op, ops.FunctionOperator):
        return op._rmatvec is not None
    return getattr(type(op), name) is not getattr(LinearOperator, name)


class _LocalShardView(LinearOperator):
    """A plain-captured operator re-examined at the LOCAL shard.

    Inside ``shard_map`` the base operator still carries its GLOBAL
    structural ``example``, so its matrix-free defaults — ``rmatvec`` via
    ``jax.linear_transpose``, probing ``diagonal``/``materialize`` — would
    trace the matvec at global shapes against local shards (shape errors,
    or worse: silently duplicated probing output concatenated across
    shards).  This view delegates genuinely overridden methods and
    re-anchors the defaults on the local example, so they trace at shard
    shapes.  Square systems (domain structure == codomain structure), like
    everything the implicit-diff stack solves.
    """

    def __init__(self, op: LinearOperator, example_local):
        super().__init__(example_local, batch_ndim=op.batch_ndim,
                         symmetric=op.symmetric,
                         positive_definite=op.positive_definite)
        self._op = op

    def matvec(self, v):
        return self._op.matvec(v)

    def rmatvec(self, v):
        if self._op.symmetric or _overrides(self._op, "rmatvec"):
            return self._op.rmatvec(v)
        return super().rmatvec(v)       # linear_transpose at LOCAL shapes

    def diagonal(self):
        if _overrides(self._op, "diagonal"):
            return self._op.diagonal()
        return super().diagonal()       # probing at LOCAL shapes

    def materialize(self):
        if _overrides(self._op, "materialize"):
            return self._op.materialize()
        return super().materialize()    # probing at LOCAL shapes

class ShardedOperator(LinearOperator):
    """A ``LinearOperator`` placed on a mesh.

    ``op`` is either a plain operator (its matvec must be shard-local with
    replicated captures — see the module docstring) or a *factory*
    ``factory(*operands_local) -> LinearOperator`` building the per-shard
    operator from sharded operands (the Jacobian case: the primal point and
    batched theta shard alongside the domain).  ``in_specs``/``out_specs``
    are ``PartitionSpec`` trees over the domain/codomain (square systems
    default ``out_specs = in_specs``); a single spec broadcasts over the
    tree.  ``reduce`` overrides the ``psum``-over-instance-axes reduction
    hook the sharded solvers use for their dot products.

    Flags (``symmetric``/``positive_definite``/``batch_ndim``) and the
    structural ``example`` are read off the (template) base operator, so
    routing, validation and preconditioner derivation see through the
    placement wrapper unchanged.
    """

    is_sharded = True

    def __init__(self, op, mesh: Mesh, in_specs, *, out_specs=None,
                 operands: tuple = (), operand_specs: tuple = (),
                 reduce: Optional[Callable] = None, check_rep: bool = False):
        if isinstance(op, LinearOperator):
            if operands:
                raise ValueError("operands require a factory; a plain "
                                 "LinearOperator captures its arrays "
                                 "(replicated into every shard)")
            template = op
        elif callable(op):
            template = op(*operands)
            if not isinstance(template, LinearOperator):
                raise TypeError("factory must build a LinearOperator; got "
                                f"{type(template)!r}")
        else:
            raise TypeError(f"cannot shard {type(op)!r}; expected a "
                            "LinearOperator or a factory callable")
        if len(operands) != len(operand_specs):
            raise ValueError(f"{len(operands)} operands but "
                             f"{len(operand_specs)} operand_specs")
        super().__init__(template.example, batch_ndim=template.batch_ndim,
                         symmetric=template.symmetric,
                         positive_definite=template.positive_definite)
        self.mesh = mesh
        self.in_specs = spec_tree(in_specs, template.example)
        self.out_specs = self.in_specs if out_specs is None \
            else spec_tree(out_specs, template.example)
        self.check_rep = check_rep
        self._psum_axes = instance_axes(self.in_specs, self.batch_ndim)
        self._batch_axes = batch_axes(self.in_specs, self.batch_ndim)
        self._plain = isinstance(op, LinearOperator)
        if self._plain:
            op, operands, operand_specs = self._lift_plain(op)
            self._plain = not operands      # DenseOperator auto-lift is
            # a factory over local matrices — already local-examined
        self._factory = op
        self.operands = tuple(operands)
        self.operand_specs = tuple(
            spec_tree(s, o) for s, o in zip(operand_specs, self.operands))
        self._reduce_arg = reduce
        self.reduce = reduce if reduce is not None \
            else psum_reduction(self._psum_axes)

    def _lift_plain(self, op: LinearOperator):
        """Turn a plain operator into (factory, operands, operand_specs).

        A batch-sharded ``DenseOperator`` carries its ``(B, d, d)`` stack as
        a sharded operand (each device holds its batch slice of matrices);
        everything else is captured by closure — replicated into every
        shard, so its matvec must be shard-local (see module docstring).
        """
        if isinstance(op, ops.DenseOperator) and self.batch_ndim == 1 \
                and not self.instance_sharded and self._batch_axes:
            baxis = self._batch_axes[0] if len(self._batch_axes) == 1 \
                else self._batch_axes
            sym, pd = op.symmetric, op.positive_definite

            def dense_factory(A_local):
                return ops.DenseOperator(A_local, symmetric=sym,
                                         positive_definite=pd)

            return dense_factory, (op.A,), (P(baxis, None, None),)
        return (lambda: op), (), ()

    # -- shard-level access ----------------------------------------------
    @property
    def instance_sharded(self) -> bool:
        """Whether instance dims (not just the batch) are split across
        devices — i.e. whether dot products need cross-device reduction."""
        return bool(self._psum_axes)

    def local_operator(self, *operands_local,
                       example_local=None) -> LinearOperator:
        """The per-shard base operator (called INSIDE ``shard_map``).

        Factory-built operators are already anchored on local operands; a
        plain-captured operator is re-examined at ``example_local`` (the
        local shard) so the matrix-free base defaults trace at shard
        shapes — see ``_LocalShardView``.
        """
        local = self._factory(*operands_local)
        if self._plain and example_local is not None:
            if isinstance(local, ops.TransposedOperator):
                # re-anchor the UNDERLYING operator, then transpose: the
                # transposed matvec is the base rmatvec, which must trace
                # at local shapes too
                return _LocalShardView(local.op,
                                       example_local).transpose()
            return _LocalShardView(local, example_local)
        return local

    def shard_map(self, body: Callable, extra_in_specs: tuple,
                  out_specs) -> Callable:
        """``shard_map`` ``body(*operands_local, *extra_local)`` on this
        operator's mesh, with the operands automatically prepended."""
        mapped = shard_map(body, mesh=self.mesh,
                           in_specs=(*self.operand_specs, *extra_in_specs),
                           out_specs=out_specs, check_rep=self.check_rep)
        return lambda *extra: mapped(*self.operands, *extra)

    # -- LinearOperator protocol -----------------------------------------
    def matvec(self, v):
        def body(*args):
            *ops_l, v_l = args
            # example_local matters for transposed plain-capture wrappers,
            # whose matvec is the base linear-transpose default
            return self.local_operator(*ops_l,
                                       example_local=v_l).matvec(v_l)

        return self.shard_map(body, (self.in_specs,), self.out_specs)(v)

    def rmatvec(self, v):
        if self.symmetric:
            return self.matvec(v)

        def body(*args):
            *ops_l, v_l = args
            # square system: the codomain shard doubles as the local
            # domain example for the linear-transpose default
            return self.local_operator(*ops_l,
                                       example_local=v_l).rmatvec(v_l)

        return self.shard_map(body, (self.out_specs,), self.in_specs)(v)

    def transpose(self) -> LinearOperator:
        if self.symmetric:
            return self
        out = ShardedOperator(
            lambda *o: self._factory(*o).transpose(), self.mesh,
            self.out_specs, out_specs=self.in_specs,
            operands=self.operands, operand_specs=self.operand_specs,
            reduce=self._reduce_arg, check_rep=self.check_rep)
        out._plain = self._plain    # plain-capture local re-examining
        # survives transposition (the wrapper factory is ours, not a
        # user factory over local operands)
        return out

    def diagonal(self):
        """diag(A), assembled from per-shard diagonals (each device probes
        only its local block)."""
        def body(*args):
            *ops_l, ex_l = args
            return self.local_operator(*ops_l,
                                       example_local=ex_l).diagonal()

        return self.shard_map(body, (self.in_specs,),
                              self.in_specs)(self.example)

    def materialize(self) -> jnp.ndarray:
        """Per-shard dense pieces.  Batch sharding assembles the global
        ``(B, d, d)`` stack (each device holds its batch slice); instance
        sharding returns the local diagonal blocks stacked along a leading
        shard axis ``(n_shards, d_local, d_local)`` — there is no global
        dense form without a gather, which this subsystem never does.
        """
        if not self.instance_sharded:
            bspec = self._batch_axes[0] if len(self._batch_axes) == 1 \
                else (self._batch_axes or None)
            out = P(bspec, None, None) if self.batch_ndim else P(None, None)

            def body(*args):
                *ops_l, ex_l = args
                return self.local_operator(
                    *ops_l, example_local=ex_l).materialize()

            return self.shard_map(body, (self.in_specs,),
                                  out)(self.example)

        out = P(self._psum_axes if len(self._psum_axes) > 1
                else self._psum_axes[0], None, None)

        def body(*args):
            *ops_l, ex_l = args
            return self.local_operator(
                *ops_l, example_local=ex_l).materialize()[None]

        return self.shard_map(body, (self.in_specs,), out)(self.example)


# ---------------------------------------------------------------------------
# the placement bundle the diff layer threads through ImplicitDiffSpec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SolveSharding:
    """Mesh placement for an implicit system (``ImplicitDiffSpec.sharding``).

    ``spec`` is the PartitionSpec (tree) of the solution ``x`` — the specs
    the backward/tangent solve inherits from the primal solution.
    ``theta_specs`` aligns with the solver's *differentiable* theta
    arguments (``None`` → replicated; per-entry ``None`` → that argument
    replicated).  ``batch_ndim = 1`` declares a leading batch axis on every
    ``x`` leaf (independent instances → per-instance convergence masks in
    the sharded solvers).  ``reduce`` overrides the ``psum`` reduction hook.
    """
    mesh: Mesh
    spec: Any
    theta_specs: Optional[Tuple[Any, ...]] = None
    batch_ndim: int = 0
    reduce: Optional[Callable] = None

    def x_specs(self, x):
        return spec_tree(self.spec, x)

    def theta_spec(self, i: int, arg):
        specs = self.theta_specs
        entry = None if specs is None or i >= len(specs) else specs[i]
        return spec_tree(P() if entry is None else entry, arg)

    def wrap(self, factory: Callable, operands: tuple) -> ShardedOperator:
        """Place a per-shard operator factory on the mesh.  ``operands``
        are ``(x_like, *theta)``: the first operand shards like the
        solution, the rest per ``theta_specs``."""
        operand_specs = (self.x_specs(operands[0]),) + tuple(
            self.theta_spec(i, a) for i, a in enumerate(operands[1:]))
        return ShardedOperator(factory, self.mesh, self.x_specs(
            operands[0]), operands=operands, operand_specs=operand_specs,
            reduce=self.reduce)

    def constrain(self, tree):
        """Pin ``tree`` to this placement: ``device_put`` for concrete
        arrays, ``with_sharding_constraint`` for tracers (inside jit)."""
        specs = spec_tree(self.spec, tree)
        named = jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        leaves = jax.tree_util.tree_leaves(tree)
        if not leaves:
            return tree
        if any(isinstance(leaf, jax.core.Tracer) for leaf in leaves):
            return jax.lax.with_sharding_constraint(tree, named)
        return jax.device_put(tree, named)


# ---------------------------------------------------------------------------
# sharded registry solvers: the whole masked loop inside ONE shard_map
# ---------------------------------------------------------------------------

def _require_sharded(name: str, matvec) -> ShardedOperator:
    if not isinstance(matvec, ShardedOperator):
        raise ValueError(
            f"solver {name!r} runs inside shard_map and needs mesh + "
            f"PartitionSpecs; wrap the operator in a ShardedOperator "
            f"(got {type(matvec).__name__})")
    return matvec


def _info_specs(op: ShardedOperator):
    """SolveInfo leaves are per-instance scalars: sharded along the batch
    axes under batch sharding, replicated (post-``psum``) otherwise."""
    if op.batch_ndim and op._batch_axes:
        axes = op._batch_axes[0] if len(op._batch_axes) == 1 \
            else op._batch_axes
        leaf = P(axes)
    else:
        leaf = P()
    return ls.SolveInfo(iterations=leaf, residual=leaf, converged=leaf)


def _sharded_call(inner: Callable, name: str, matvec, b, *, init=None,
                  return_info: bool = False, batch_ndim: int = 0,
                  with_reduce: bool = True, **kw):
    """Run ``inner(local_op, b_local, ...)`` inside one ``shard_map``."""
    op = _require_sharded(name, matvec)
    if batch_ndim not in (0, op.batch_ndim):
        raise ValueError(f"batch_ndim={batch_ndim} does not match the "
                         f"sharded operator's batch_ndim={op.batch_ndim}")
    kw = dict(kw, batch_ndim=op.batch_ndim, return_info=return_info)
    if with_reduce:
        kw["reduce"] = op.reduce
    n_op = len(op.operands)
    has_init = init is not None

    def body(*args):
        ops_l = args[:n_op]
        b_l = args[n_op]
        init_l = args[n_op + 1] if has_init else None
        # square system: the codomain rhs shard doubles as the local
        # domain example for the plain-capture path's defaults
        local = op.local_operator(*ops_l, example_local=b_l)
        return inner(local, b_l, init=init_l, **kw)

    # the right-hand side lives in the CODOMAIN (out_specs); the warm start
    # and the solution in the domain (in_specs) — identical for the square
    # same-placement common case, distinct for transposed operators built
    # with out_specs != in_specs
    extra_in = (op.out_specs,) + ((op.in_specs,) if has_init else ())
    out_specs = (op.in_specs, _info_specs(op)) if return_info \
        else op.in_specs
    args = (b, init) if has_init else (b,)
    return op.shard_map(body, extra_in, out_specs)(*args)


def sharded_solve_cg(matvec, b, **kw):
    """Distributed CG: one ``shard_map``, matvec per shard, dot products
    through the operator's reduction hook, per-instance masks intact."""
    return _sharded_call(ls.solve_cg, "sharded_cg", matvec, b, **kw)


def sharded_solve_normal_cg(matvec, b, **kw):
    """Distributed CG on the normal equations (general square A; the local
    operator answers ``rmatvec`` per shard)."""
    return _sharded_call(ls.solve_normal_cg, "sharded_normal_cg", matvec, b,
                         **kw)


def sharded_solve_dense_gmres(matvec, b, **kw):
    """Distributed dense GMRES: each device materializes + solves its local
    batch slice.  Batch sharding only — a dense instance-sharded system has
    no local (d, d) form."""
    op = _require_sharded("sharded_dense_gmres", matvec)
    if op.instance_sharded:
        raise ValueError(
            "sharded_dense_gmres materializes per-shard dense systems, "
            "which needs the instance dims unsharded (batch sharding only);"
            " use sharded_cg/sharded_normal_cg for instance-dim sharding")
    return _sharded_call(ls.solve_dense_gmres, "sharded_dense_gmres",
                         matvec, b, with_reduce=False, **kw)
