"""Pipeline parallelism via shard_map + collective_permute.

GPipe-style microbatch pipelining over a ``stage`` mesh axis: the layer
stack is split into S stages (stage s holds layers [s·L/S, (s+1)·L/S));
microbatches stream through with activations moved stage→stage by
``lax.ppermute``.  The steady-state loop is a ``lax.scan`` over
(num_microbatches + S − 1) ticks — the classic pipelined schedule, bubble
fraction (S−1)/(M+S−1).

This is an opt-in alternative to the default DP×TP layout (DESIGN.md §5);
unit tests validate numerical equality with the unpipelined forward on a
small host mesh.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_forward(block_fn: Callable, params_stacked: Any,
                     x_microbatches: jnp.ndarray, mesh: Mesh,
                     stage_axis: str = "stage") -> jnp.ndarray:
    """Run ``block_fn(params_layer, x) -> x`` over a stage-sharded stack.

    params_stacked: pytree with leading layer axis L (L % S == 0), sharded
      so each stage holds its L/S layers.
    x_microbatches: (M, mb, ...) microbatched input, replicated across
      stages (stage 0 consumes; results exit from the last stage).
    Returns (M, mb, ...) outputs.
    """
    S = mesh.shape[stage_axis]

    def stage_body(params_local, xs):
        """Runs on ONE stage. params_local: (L/S, ...); xs: (M, mb, ...)."""
        stage_id = lax.axis_index(stage_axis)
        M = xs.shape[0]

        def run_stage(x):
            def layer(h, p):
                return block_fn(p, h), None
            h, _ = lax.scan(layer, x, params_local)
            return h

        # schedule: tick t processes microbatch (t - stage_id) at this stage
        state = jnp.zeros_like(xs[0])
        outputs = jnp.zeros_like(xs)
        num_ticks = M + S - 1
        perm = [(i, (i + 1) % S) for i in range(S)]

        def tick(carry, t):
            state, outputs = carry
            mb_idx = t - stage_id
            # stage 0 ingests a fresh microbatch at ticks [0, M)
            fresh = xs[jnp.clip(t, 0, M - 1)]
            inp = jnp.where(stage_id == 0, fresh, state)
            active = (mb_idx >= 0) & (mb_idx < M)
            out = run_stage(inp)
            out = jnp.where(active, out, state)
            # last stage commits finished microbatches
            outputs = lax.cond(
                (stage_id == S - 1) & active,
                lambda o: o.at[jnp.clip(mb_idx, 0, M - 1)].set(out),
                lambda o: o, outputs)
            # rotate activations to the next stage
            state = lax.ppermute(out, stage_axis, perm)
            return (state, outputs), None

        (_, outputs), _ = lax.scan(tick, (state, outputs),
                                   jnp.arange(num_ticks))
        # only the last stage holds real outputs; broadcast them
        outputs = lax.psum(
            jnp.where(stage_id == S - 1, outputs, jnp.zeros_like(outputs)),
            stage_axis)
        return outputs

    pspec = jax.tree_util.tree_map(
        lambda l: P(stage_axis, *([None] * (l.ndim - 1))), params_stacked)
    return shard_map(
        stage_body, mesh=mesh,
        in_specs=(pspec, P()), out_specs=P(),
        check_rep=False)(params_stacked, x_microbatches)
