from repro.distributed.sharding import (ShardingRules, params_specs,
                                        batch_spec, decode_state_specs,
                                        kv_cache_spec, named)
from repro.distributed.sharded_operators import (ShardedOperator,
                                                 SolveSharding,
                                                 psum_reduction)
