"""Mamba-2 (SSD) blocks — for the Zamba2 hybrid backbone.

State-space duality form (Dao & Gu, 2024): per head with head dim P and
state size Nst,

    h_t = exp(a_t) · h_{t−1} + (b_t ⊗ x_t) · Δ_t      h ∈ R^{Nst×P}
    y_t = c_tᵀ h_t + D · x_t

with scalar per-head decay a_t = −Δ_t·exp(A_log) (data-dependent via Δ).
Implemented as a chunked parallel scan (the TPU-friendly SSD layout: chunk
the sequence, intra-chunk dense matmuls on the MXU, inter-chunk recurrence
carried by a tiny scan).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, SSMConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init

Params = Dict[str, Any]


def _dims(cfg: ArchConfig):
    s = cfg.ssm or SSMConfig()
    d_inner = s.expand * cfg.d_model
    nheads = s.num_heads or d_inner // s.head_dim
    return s, d_inner, nheads


def mamba_init(key, cfg: ArchConfig) -> Params:
    s, d_inner, nheads = _dims(cfg)
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    conv_dim = d_inner + 2 * s.state_size
    return {
        # fused input projection: [z (gate), x, B, C, dt]
        "w_in": dense_init(ks[0], d, 2 * d_inner + 2 * s.state_size
                           + nheads, dt),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_dim))
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)
                         ).astype(jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "norm": rmsnorm_init(d_inner, dt),
        "w_out": dense_init(ks[2], d_inner, d, dt),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv1d.  x: (B, T, C); w: (K, C).
    state: (B, K−1, C) trailing context for decode.  Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(x[:, : K - 1])
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    # sum_k w[k] * x[t - K + 1 + k]
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return jax.nn.silu(y + b), new_state


def ssd_scan_ref(x, a, B, C, D, state0=None, chunk: int = 64):
    """Chunked SSD scan (reference implementation, also the TPU layout).

    x: (Bb, T, H, P) inputs (already Δ-scaled); a: (Bb, T, H) log-decay
    (negative); B, C: (Bb, T, Nst); D: (H,).
    Returns (y (Bb,T,H,P), final_state (Bb,H,Nst,P))."""
    Bb, T, H, P = x.shape
    Nst = B.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((Bb, H, Nst, P), jnp.float32)
    nchunks = T // chunk
    assert T % chunk == 0, (T, chunk)

    xf = x.astype(jnp.float32).reshape(Bb, nchunks, chunk, H, P)
    af = a.astype(jnp.float32).reshape(Bb, nchunks, chunk, H)
    Bf = B.astype(jnp.float32).reshape(Bb, nchunks, chunk, Nst)
    Cf = C.astype(jnp.float32).reshape(Bb, nchunks, chunk, Nst)

    cum_a = jnp.cumsum(af, axis=2)                      # (Bb,nc,L,H)
    total_a = cum_a[:, :, -1]                           # (Bb,nc,H)

    # --- intra-chunk (dense, MXU-friendly) ---
    # decay from step j to step i (i >= j): exp(cum_a_i - cum_a_j)
    rel = cum_a[:, :, :, None, :] - cum_a[:, :, None, :, :]   # (Bb,nc,L,L,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # mask the EXPONENT (not the value): exp of the masked upper triangle
    # overflows and poisons gradients through the where (inf · 0 = nan).
    decay = jnp.exp(jnp.where(mask, rel, -jnp.inf))
    cb = jnp.einsum("bnis,bnjs->bnij", Cf, Bf)                # (Bb,nc,L,L)
    y_intra = jnp.einsum("bnij,bnijh,bnjhp->bnihp", cb, decay, xf)

    # --- chunk states: S_n = sum_j exp(cum_a_last - cum_a_j) B_j x_j ---
    dec_to_end = jnp.exp(total_a[:, :, None, :] - cum_a)      # (Bb,nc,L,H)
    chunk_state = jnp.einsum("bnjs,bnjh,bnjhp->bnhsp", Bf, dec_to_end, xf)

    # --- inter-chunk recurrence over nchunks (tiny scan) ---
    def step(S, inp):
        cs, ta = inp                                    # (Bb,H,Nst,P),(Bb,H)
        S_new = jnp.exp(ta)[..., None, None] * S + cs
        return S_new, S                                 # emit state *before*

    (S_final, prev_states) = lax.scan(
        step, state0,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(total_a, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)       # (Bb,nc,H,Nst,P)

    # --- contribution of carried state to each position ---
    dec_from_start = jnp.exp(cum_a)                     # (Bb,nc,L,H)
    y_inter = jnp.einsum("bnis,bnih,bnhsp->bnihp", Cf, dec_from_start,
                         prev_states)

    y = (y_intra + y_inter).reshape(Bb, T, H, P)
    y = y + D[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), S_final


def mamba_apply(params: Params, cfg: ArchConfig, x: jnp.ndarray,
                state: Optional[Tuple] = None, chunk: int = 64):
    """Mamba-2 block.  state = (conv_state, ssm_state) for decode.
    Returns (out, new_state)."""
    s, d_inner, nheads = _dims(cfg)
    B_, T, d = x.shape
    P = d_inner // nheads
    Nst = s.state_size

    proj = x @ params["w_in"]
    z, xbc_dt = proj[..., :d_inner], proj[..., d_inner:]
    xbc = xbc_dt[..., : d_inner + 2 * Nst]
    dt_raw = xbc_dt[..., d_inner + 2 * Nst:]

    conv_state = None if state is None else state[0]
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state)
    xs = xbc[..., :d_inner].reshape(B_, T, nheads, P)
    Bmat = xbc[..., d_inner: d_inner + Nst]
    Cmat = xbc[..., d_inner + Nst:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])            # (B,T,H)
    a = -jnp.exp(params["A_log"])[None, None] * dt       # log decay (neg)
    x_scaled = xs.astype(jnp.float32) * dt[..., None]

    ssm_state = None if state is None else state[1]
    if T % chunk != 0:
        chunk = 1 if T == 1 else math.gcd(T, chunk) or 1
    y, new_ssm = ssd_scan_ref(x_scaled, a, Bmat, Cmat, params["D"],
                              ssm_state, chunk=chunk)
    y = y.reshape(B_, T, d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = y @ params["w_out"]
    return out, (new_conv, new_ssm)


def mamba_state_init(cfg: ArchConfig, batch: int):
    s, d_inner, nheads = _dims(cfg)
    conv_dim = d_inner + 2 * s.state_size
    P = d_inner // nheads
    return (jnp.zeros((batch, s.conv_width - 1, conv_dim),
                      jnp.dtype(cfg.dtype)),
            jnp.zeros((batch, nheads, s.state_size, P), jnp.float32))
