"""Model zoo: dense GQA/MLA transformers, MoE, RWKV-6, Mamba-2 hybrid."""
from repro.models.model import (init_params, init_params_abstract, forward,
                                loss_fn, init_decode_state, decode_step,
                                DecodeState)
