"""RWKV-6 ("Finch") blocks — attention-free token mixing with
data-dependent decay (arXiv:2404.05892).

Per head (head dim N), per time step t, with data-dependent decay w_t ∈ (0,1):

    S_t = diag(w_t) · S_{t−1} + k_tᵀ v_t           (state: N×N per head)
    o_t = (r_t · (S_{t−1} + diag(u) k_tᵀ v_t))      (u: bonus for current token)

The time-mixing projections use RWKV's token-shift (lerp of x_t and x_{t−1})
with data-dependent mixing (LoRA-style ddlerp), and the channel-mixing block
is the standard RWKV squared-ReLU FFN.

The sequential scan is the hot loop; ``repro.kernels.rwkv_wkv`` provides the
chunked Pallas kernel, with this module's ``wkv_scan_ref`` as its oracle.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init

Params = Dict[str, Any]

HEAD_SIZE = 64   # RWKV-6 fixed head size


def _heads(cfg: ArchConfig) -> int:
    assert cfg.d_model % HEAD_SIZE == 0
    return cfg.d_model // HEAD_SIZE


def time_mix_init(key, cfg: ArchConfig) -> Params:
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    H = _heads(cfg)
    ks = jax.random.split(key, 12)
    lora = 32
    p = {
        # token-shift data-dependent lerp params (5 targets: w,k,v,r,g)
        "mix_base": (jax.random.uniform(ks[0], (5, d)) * 0.5).astype(dt),
        "mix_lora_a": dense_init(ks[1], d, 5 * lora, dt),
        "mix_lora_b": (jnp.zeros((5, lora, d))).astype(dt),
        # projections
        "w_r": dense_init(ks[2], d, d, dt),
        "w_k": dense_init(ks[3], d, d, dt),
        "w_v": dense_init(ks[4], d, d, dt),
        "w_g": dense_init(ks[5], d, d, dt),
        "w_o": dense_init(ks[6], d, d, dt),
        # decay: base + LoRA (data-dependent, the RWKV-6 novelty)
        "decay_base": (jnp.full((d,), -6.0)).astype(jnp.float32),
        "decay_lora_a": dense_init(ks[7], d, 64, dt),
        "decay_lora_b": (jnp.zeros((64, d))).astype(dt),
        "bonus": (jax.random.normal(ks[8], (H, HEAD_SIZE)) * 0.05
                  ).astype(jnp.float32),
        "ln_x": {"scale": jnp.ones((d,), dt), "bias": jnp.zeros((d,), dt)},
    }
    return p


def channel_mix_init(key, cfg: ArchConfig) -> Params:
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mix_k": (jax.random.uniform(k1, (d,)) * 0.5).astype(dt),
        "w_k": dense_init(k2, d, cfg.d_ff, dt),
        "w_v": dense_init(k3, cfg.d_ff, d, dt),
    }


def token_shift(x: jnp.ndarray, x_prev: Optional[jnp.ndarray] = None):
    """Shift sequence right by one; x_prev supplies the t=−1 row (decode)."""
    if x_prev is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = x_prev[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def wkv_scan_ref(r, k, v, w, u, state0=None):
    """Reference WKV-6 recurrence (pure jnp, oracle for the Pallas kernel).

    r,k,v: (B, T, H, N); w: (B, T, H, N) decay in (0,1); u: (H, N) bonus.
    Returns (out (B,T,H,N), final state (B,H,N,N))."""
    B, T, H, N = r.shape
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)
    if state0 is None:
        state0 = jnp.zeros((B, H, N, N), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp              # (B, H, N)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,N,N)
        out = jnp.einsum("bhn,bhnm->bhm", rt,
                         S + uf[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    S, outs = lax.scan(step, state0, xs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), S


def wkv_chunked(r, k, v, w, u, state0=None, chunk: int = 32):
    """Chunked WKV-6 (the TPU/Pallas schedule, jnp form).

    Per chunk of length C: with per-channel decay cumprods cw_t (exclusive),
      out_t = (r_t ⊙ cw_t)·S₀ + Σ_{j<t} ((r_t⊙cw_t)·(k_j/cw_{j+1})) v_j
              + (r_t⊙u)·k_t v_t
    i.e. ONE (C×C) matmul per head instead of C rank-1 state updates — the
    recurrent state is materialized once per chunk, not once per step
    (§Perf R1: cuts the HBM-resident state traffic by C×).

    Decay ratios are factorized as exp(clwₜ − c)·exp(c − clw_{j+1}) with c
    the chunk-midpoint cumulative log-decay, so intermediate exponents stay
    within ±(chunk·|log w|)/2.  Valid when the per-chunk cumulative decay
    satisfies Σ|log wᵢ| ≤ 120 — guaranteed by RWKV-6's parameterization
    (w = exp(−exp(d)), d ≈ −6 ± 1 ⇒ |log w| ≤ 0.01/step, chunk ≤ 64 ⇒
    cum ≤ 0.6), and checked by tests up to w = 0.1 (cum ≈ 74).
    """
    B, T, H, N = r.shape
    if T % chunk != 0:
        return wkv_scan_ref(r, k, v, w, u, state0)
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    logw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38))
    uf = u.astype(jnp.float32)
    if state0 is None:
        state0 = jnp.zeros((B, H, N, N), jnp.float32)
    nc = T // chunk

    shape5 = (B, nc, chunk, H, N)
    rf, kf, vf, logw = (a.reshape(shape5) for a in (rf, kf, vf, logw))
    # exclusive cumulative log-decay within the chunk: cw_t = Π_{i<t} w_i
    clw = jnp.cumsum(logw, axis=2) - logw                 # (B,nc,C,H,N)
    total_lw = clw[:, :, -1] + logw[:, :, -1]             # (B,nc,H,N)

    c = clw[:, :, chunk // 2][:, :, None]                 # midpoint anchor
    rt = rf * jnp.exp(jnp.clip(clw - c, -60.0, 60.0))     # r̃ = r ⊙ cw/e^c
    kt = kf * jnp.exp(jnp.clip(c - (clw + logw), -60.0, 60.0))

    # intra-chunk: one (C×C) score matmul per head
    scores = jnp.einsum("bnchx,bnjhx->bnhcj", rt, kt)     # (B,nc,H,C,C)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    out_intra = jnp.einsum("bnhcj,bnjhm->bnchm", scores, vf)
    bonus = jnp.einsum("bnchx,bnchx->bnch", rf * uf[None, None, None],
                       kf)
    out_intra = out_intra + bonus[..., None] * vf

    # chunk summaries: S_chunk = Σ_j diag(exp(total−cum₊₁(j))) k_jᵀ v_j
    dec_to_end = jnp.exp(jnp.clip(
        total_lw[:, :, None] - (clw + logw), -80.0, 0.0))  # (B,nc,C,H,N)
    chunk_kv = jnp.einsum("bnchx,bnchm->bnhxm", kf * dec_to_end, vf)

    r_state = rf * jnp.exp(clw)     # un-anchored r ⊙ cw for the S₀ term
                                     # (cum ≤ 0.6 in-model: no overflow)

    def step(S, inp):
        rt_c, tlw_c, ckv_c = inp
        out0 = jnp.einsum("chx,hxm->chm", rt_c, S)
        S_new = jnp.exp(tlw_c)[..., None] * S + ckv_c
        return S_new, out0

    def batch_scan(rt_b, tlw_b, ckv_b, S0_b):
        S_final, outs0 = jax.lax.scan(
            step, S0_b, (rt_b, tlw_b, ckv_b))
        return S_final, outs0

    S_final, out_inter = jax.vmap(batch_scan)(
        r_state, total_lw, chunk_kv, state0)               # scan over nc

    out = (out_intra + out_inter).reshape(B, T, H, N)
    return out.astype(r.dtype), S_final


def time_mix_apply(params: Params, cfg: ArchConfig, x: jnp.ndarray,
                   state: Optional[Tuple] = None, use_kernel: bool = False):
    """RWKV-6 time mixing.  ``state`` = (x_prev (B,d), wkv_state (B,H,N,N))
    for O(1) decode; None for full-sequence training.
    Returns (out, new_state)."""
    B, T, d = x.shape
    H, N = _heads(cfg), HEAD_SIZE
    x_prev = None if state is None else state[0]
    wkv_state = None if state is None else state[1]

    xs = token_shift(x, x_prev)
    delta = xs - x
    # data-dependent lerp (ddlerp): 5 mixing vectors from a small LoRA
    lora = jnp.tanh(x @ params["mix_lora_a"]).reshape(B, T, 5, -1)
    mix = params["mix_base"][None, None] + \
        jnp.einsum("btfl,fld->btfd", lora, params["mix_lora_b"])
    xw, xk, xv, xr, xg = [x + delta * mix[:, :, i] for i in range(5)]

    r = (xr @ params["w_r"]).reshape(B, T, H, N)
    k = (xk @ params["w_k"]).reshape(B, T, H, N)
    v = (xv @ params["w_v"]).reshape(B, T, H, N)
    g = jax.nn.silu(xg @ params["w_g"])

    # data-dependent decay w_t = exp(-exp(base + lora(xw)))
    dec = params["decay_base"][None, None] + \
        (jnp.tanh(xw @ params["decay_lora_a"]) @ params["decay_lora_b"]
         ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec)).reshape(B, T, H, N)

    if use_kernel:
        from repro.kernels.rwkv_wkv import ops as wkv_ops
        out, new_wkv = wkv_ops.wkv(r, k, v, w, params["bonus"], wkv_state)
    elif T > 1 and T % 32 == 0:
        # chunked schedule (the Pallas kernel's algorithm): state touched
        # once per chunk, not once per step — §Perf R1
        out, new_wkv = wkv_chunked(r, k, v, w, params["bonus"], wkv_state)
    else:
        out, new_wkv = wkv_scan_ref(r, k, v, w, params["bonus"], wkv_state)

    out = out.reshape(B, T, d)
    # group norm over heads (ln_x in RWKV)
    outf = out.astype(jnp.float32).reshape(B, T, H, N)
    mu = outf.mean(-1, keepdims=True)
    var = outf.var(-1, keepdims=True)
    outf = (outf - mu) * lax.rsqrt(var + 64e-5)
    out = outf.reshape(B, T, d) * params["ln_x"]["scale"].astype(jnp.float32) \
        + params["ln_x"]["bias"].astype(jnp.float32)
    out = (out.astype(x.dtype) * g) @ params["w_o"]
    new_state = (x[:, -1], new_wkv)
    return out, new_state


def channel_mix_apply(params: Params, cfg: ArchConfig, x: jnp.ndarray,
                      x_prev: Optional[jnp.ndarray] = None):
    """RWKV channel mixing (squared-ReLU FFN with token shift).
    Returns (out, last_x)."""
    xs = token_shift(x, x_prev)
    xk = x + (xs - x) * params["mix_k"]
    h = jnp.square(jax.nn.relu(xk @ params["w_k"]))
    return h @ params["w_v"], x[:, -1]


def rwkv_state_init(cfg: ArchConfig, batch: int):
    """Per-layer decode state: (x_prev_tm, wkv (B,H,N,N), x_prev_cm)."""
    H, N = _heads(cfg), HEAD_SIZE
    return (jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.dtype)),
            jnp.zeros((batch, H, N, N), jnp.float32),
            jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.dtype)))
