"""Top-level model: init / forward / loss / decode for every arch family.

Design notes (these matter at scale):
  * Layer parameters are **stacked** (leading L axis) and the layer loop is a
    ``lax.scan`` — the compiled HLO contains ONE block body regardless of
    depth, keeping dry-run compiles tractable and enabling per-layer remat.
  * Hybrid (Zamba2) = scanned Mamba2 trunk + a **shared** attention block
    (single weight set) applied every ``shared_attn_every`` layers — faithful
    to Zamba2's weight-shared attention.
  * ``[vlm]``/``[audio]`` archs take precomputed embeddings
    (``embedding_frontend == 'stub_embeddings'``) per the assignment.
  * Decode: ``init_decode_state`` builds per-layer stacked caches;
    ``decode_step`` advances one token (the serve_step the decode/long
    shapes lower).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as X
from repro.models import rwkv as R

Params = Dict[str, Any]

SHARED_ATTN_EVERY = 27   # Zamba2: shared attention block cadence


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ArchConfig) -> Params:
    """One layer's params for the arch's (homogeneous, scanned) trunk."""
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.family == "ssm":                       # RWKV-6
        return {"ln1": L.rmsnorm_init(cfg.d_model, dt),
                "tm": R.time_mix_init(k1, cfg),
                "ln2": L.rmsnorm_init(cfg.d_model, dt),
                "cm": R.channel_mix_init(k2, cfg)}
    if cfg.family == "hybrid":                    # Mamba2 trunk
        return {"ln1": L.rmsnorm_init(cfg.d_model, dt),
                "mamba": M.mamba_init(k1, cfg)}
    p = {"ln1": L.rmsnorm_init(cfg.d_model, dt),
         "ln2": L.rmsnorm_init(cfg.d_model, dt)}
    p["attn"] = (L.mla_init(k1, cfg) if cfg.use_mla
                 else L.attention_init(k1, cfg))
    if cfg.moe:
        p["mlp"] = X.moe_init(k2, cfg)
    else:
        p["mlp"] = L.mlp_init(k2, cfg)
    return p


def init_params(key, cfg: ArchConfig) -> Params:
    ke, kb, ks, kf = jax.random.split(key, 4)
    lkeys = jax.random.split(kb, cfg.num_layers)
    blocks = jax.vmap(lambda k: _block_init(k, cfg))(lkeys)
    p = {"embed": L.embedding_init(ke, cfg),
         "blocks": blocks,
         "final_norm": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype))}
    if cfg.family == "hybrid":
        # shared attention (+ its MLP) — ONE weight set reused across depth
        ka, km = jax.random.split(ks)
        p["shared_attn"] = {
            "ln1": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
            "attn": L.attention_init(ka, cfg),
            "ln2": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
            "mlp": L.mlp_init(km, cfg),
        }
    return p


def init_params_abstract(key, cfg: ArchConfig) -> Params:
    """Shape/dtype-only params (for dry-run sharding without allocation)."""
    return jax.eval_shape(lambda k: init_params(k, cfg), key)


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _dense_block(bp: Params, cfg: ArchConfig, h: jnp.ndarray,
                 use_kernel: bool, moe_dispatch: str = "dense"):
    a, _ = (L.mla_apply(bp["attn"], cfg, L.rmsnorm(bp["ln1"], h,
                                                   cfg.norm_eps))
            if cfg.use_mla else
            L.attention_apply(bp["attn"], cfg,
                              L.rmsnorm(bp["ln1"], h, cfg.norm_eps),
                              use_kernel=use_kernel))
    h = h + a
    m_in = L.rmsnorm(bp["ln2"], h, cfg.norm_eps)
    if cfg.moe:
        if moe_dispatch == "sparse":
            mo, aux = X.moe_apply_sparse_gather(bp["mlp"], cfg, m_in)
        else:
            mo, aux = X.moe_apply_dense(bp["mlp"], cfg, m_in)
    else:
        mo, aux = L.mlp_apply(bp["mlp"], m_in, cfg.mlp_activation), 0.0
    return h + mo, aux


def _rwkv_block(bp: Params, cfg: ArchConfig, h: jnp.ndarray,
                use_kernel: bool):
    a, _ = R.time_mix_apply(bp["tm"], cfg,
                            L.rmsnorm(bp["ln1"], h, cfg.norm_eps),
                            use_kernel=use_kernel)
    h = h + a
    c, _ = R.channel_mix_apply(bp["cm"], cfg,
                               L.rmsnorm(bp["ln2"], h, cfg.norm_eps))
    return h + c, 0.0


def _mamba_block(bp: Params, cfg: ArchConfig, h: jnp.ndarray):
    a, _ = M.mamba_apply(bp["mamba"], cfg,
                         L.rmsnorm(bp["ln1"], h, cfg.norm_eps))
    return h + a, 0.0


def _shared_attn_block(sp: Params, cfg: ArchConfig, h: jnp.ndarray,
                       use_kernel: bool):
    a, _ = L.attention_apply(sp["attn"], cfg,
                             L.rmsnorm(sp["ln1"], h, cfg.norm_eps),
                             use_kernel=use_kernel)
    h = h + a
    return h + L.mlp_apply(sp["mlp"],
                           L.rmsnorm(sp["ln2"], h, cfg.norm_eps),
                           cfg.mlp_activation)


REMAT_POLICIES = {
    "nothing": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


def forward(params: Params, cfg: ArchConfig, inputs: jnp.ndarray,
            use_kernel: bool = False, remat: bool = True,
            act_sharding=None, remat_policy: str = "nothing",
            sp_sharding=None, moe_dispatch: str = "dense") -> Tuple:
    """Full forward pass.  ``inputs``: int tokens (B, S) or precomputed
    embeddings (B, S, d) for stub frontends.  Returns (logits, aux_loss).

    ``act_sharding``: optional NamedSharding for the (B, S, d) activations.
    GSPMD replicates the output of the embedding gather (the table is
    2-D-sharded), so without this constraint the whole layer stack runs
    batch-replicated on the data axis."""
    if cfg.embedding_frontend == "stub_embeddings" and inputs.ndim == 3:
        h = inputs.astype(jnp.dtype(cfg.dtype))
    else:
        h = L.embed(params["embed"], inputs)
    if act_sharding is not None:
        h = jax.lax.with_sharding_constraint(h, act_sharding)

    if cfg.family == "ssm":
        block = lambda bp, h: _rwkv_block(bp, cfg, h, use_kernel)
    elif cfg.family == "hybrid":
        block = lambda bp, h: _mamba_block(bp, cfg, h)
    else:
        block = lambda bp, h: _dense_block(bp, cfg, h, use_kernel,
                                           moe_dispatch)

    if remat:
        block = jax.checkpoint(block, policy=REMAT_POLICIES[remat_policy])

    if cfg.family == "hybrid":
        # scan in chunks of SHARED_ATTN_EVERY, interleaving the shared block
        n = cfg.num_layers
        every = min(SHARED_ATTN_EVERY, n)
        aux_total = 0.0

        def scan_body(h, bp):
            h, aux = block(bp, h)
            return h, aux

        done = 0
        while done < n:
            take = min(every, n - done)
            seg = jax.tree_util.tree_map(
                lambda a: lax.slice_in_dim(a, done, done + take, axis=0),
                params["blocks"])
            h, auxs = lax.scan(scan_body, h, seg)
            aux_total = aux_total + jnp.sum(auxs)
            h = _shared_attn_block(params["shared_attn"], cfg, h,
                                   use_kernel)
            done += take
    else:
        def scan_body(h, bp):
            h, aux = block(bp, h)
            if sp_sharding is not None:
                # Megatron sequence parallelism: residual/norm regions hold
                # (b, s/TP, d) shards; GSPMD turns the block's all-reduce
                # into reduce-scatter + all-gather pairs (§Perf L2)
                h = jax.lax.with_sharding_constraint(h, sp_sharding)
            return h, aux

        h, auxs = lax.scan(scan_body, h, params["blocks"])
        aux_total = jnp.sum(auxs)

    if act_sharding is not None:
        # re-anchor before the unembed: attention paths for non-divisible
        # head counts can leave d partially sharded, which would otherwise
        # turn the logits matmul into a model-axis partial sum (§Perf G2)
        h = jax.lax.with_sharding_constraint(h, act_sharding)
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = L.unembed(params["embed"], h)
    return logits, aux_total


def loss_fn(params: Params, cfg: ArchConfig, inputs, labels,
            use_kernel: bool = False, remat: bool = True,
            act_sharding=None, remat_policy: str = "nothing",
            sp_sharding=None, moe_dispatch: str = "dense") -> jnp.ndarray:
    """Mean next-token cross-entropy (+ MoE aux).  ``labels``: (B, S) int."""
    logits, aux = forward(params, cfg, inputs, use_kernel, remat,
                          act_sharding=act_sharding,
                          remat_policy=remat_policy,
                          sp_sharding=sp_sharding,
                          moe_dispatch=moe_dispatch)
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    if cfg.moe:
        loss = loss + cfg.moe.router_aux_loss * aux / cfg.num_layers
    return loss


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DecodeState:
    caches: Any            # per-family stacked per-layer caches
    index: jnp.ndarray     # current length (scalar int32)

    def tree_flatten(self):
        return (self.caches, self.index), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    DecodeState, DecodeState.tree_flatten, DecodeState.tree_unflatten)


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int
                      ) -> DecodeState:
    Ln = cfg.num_layers
    dt = jnp.dtype(cfg.dtype)

    def stack(make):
        one = make()
        return jax.tree_util.tree_map(
            lambda a: jnp.zeros((Ln,) + a.shape, a.dtype), one)

    if cfg.family == "ssm":
        caches = stack(lambda: R.rwkv_state_init(cfg, batch))
    elif cfg.family == "hybrid":
        trunk = stack(lambda: M.mamba_state_init(cfg, batch))
        n_shared = -(-cfg.num_layers // min(SHARED_ATTN_EVERY,
                                            cfg.num_layers))
        k, v = L.make_kv_cache(cfg, batch, max_len, dt)
        shared = (jnp.zeros((n_shared,) + k.shape, dt),
                  jnp.zeros((n_shared,) + v.shape, dt))
        caches = {"trunk": trunk, "shared": shared}
    elif cfg.use_mla:
        lat, kr = L.make_mla_cache(cfg, batch, max_len, dt)
        caches = (jnp.zeros((Ln,) + lat.shape, dt),
                  jnp.zeros((Ln,) + kr.shape, dt))
    else:
        k, v = L.make_kv_cache(cfg, batch, max_len, dt)
        caches = (jnp.zeros((Ln,) + k.shape, dt),
                  jnp.zeros((Ln,) + v.shape, dt))
    return DecodeState(caches=caches, index=jnp.zeros((), jnp.int32))


def decode_step(params: Params, cfg: ArchConfig, state: DecodeState,
                tokens: jnp.ndarray) -> Tuple[jnp.ndarray, DecodeState]:
    """One serve step: tokens (B, 1) int (or (B, 1, d) embeddings) →
    (logits (B, 1, V), new state)."""
    if not cfg.has_decoder:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    if cfg.embedding_frontend == "stub_embeddings" and tokens.ndim == 3:
        h = tokens.astype(jnp.dtype(cfg.dtype))
    else:
        h = L.embed(params["embed"], tokens)
    idx = state.index

    if cfg.family == "ssm":
        def body(h, blk):
            bp, st = blk
            x_tm, wkv, x_cm = st
            a, (nx_tm, nwkv) = R.time_mix_apply(
                bp["tm"], cfg, L.rmsnorm(bp["ln1"], h, cfg.norm_eps),
                state=(x_tm, wkv))
            h = h + a
            c, nx_cm = R.channel_mix_apply(
                bp["cm"], cfg, L.rmsnorm(bp["ln2"], h, cfg.norm_eps),
                x_prev=x_cm)
            return h + c, (nx_tm, nwkv, nx_cm)

        h, new_caches = lax.scan(body, h,
                                 (params["blocks"], state.caches))
    elif cfg.family == "hybrid":
        every = min(SHARED_ATTN_EVERY, cfg.num_layers)

        def body(h, blk):
            bp, st = blk
            a, nst = M.mamba_apply(
                bp["mamba"], cfg, L.rmsnorm(bp["ln1"], h, cfg.norm_eps),
                state=st)
            return h + a, nst

        n, done, si = cfg.num_layers, 0, 0
        new_trunk_parts, new_shared_k, new_shared_v = [], [], []
        trunk = state.caches["trunk"]
        sk, sv = state.caches["shared"]
        while done < n:
            take = min(every, n - done)
            seg_p = jax.tree_util.tree_map(
                lambda a: lax.slice_in_dim(a, done, done + take, axis=0),
                params["blocks"])
            seg_s = jax.tree_util.tree_map(
                lambda a: lax.slice_in_dim(a, done, done + take, axis=0),
                trunk)
            h, nst = lax.scan(body, h, (seg_p, seg_s))
            new_trunk_parts.append(nst)
            sp = params["shared_attn"]
            a, (nk, nv) = L.attention_apply(
                sp["attn"], cfg, L.rmsnorm(sp["ln1"], h, cfg.norm_eps),
                kv_cache=(sk[si], sv[si]), cache_index=idx)
            h = h + a
            h = h + L.mlp_apply(sp["mlp"],
                                L.rmsnorm(sp["ln2"], h, cfg.norm_eps),
                                cfg.mlp_activation)
            new_shared_k.append(nk)
            new_shared_v.append(nv)
            done += take
            si += 1
        new_caches = {
            "trunk": jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs, 0), *new_trunk_parts),
            "shared": (jnp.stack(new_shared_k), jnp.stack(new_shared_v)),
        }
    else:
        def body(h, blk):
            bp, cache = blk
            x = L.rmsnorm(bp["ln1"], h, cfg.norm_eps)
            if cfg.use_mla:
                a, ncache = L.mla_apply(bp["attn"], cfg, x, kv_cache=cache,
                                        cache_index=idx)
            else:
                a, ncache = L.attention_apply(bp["attn"], cfg, x,
                                              kv_cache=cache,
                                              cache_index=idx)
            h = h + a
            m_in = L.rmsnorm(bp["ln2"], h, cfg.norm_eps)
            if cfg.moe:
                mo, _ = X.moe_apply_dense(bp["mlp"], cfg, m_in)
            else:
                mo = L.mlp_apply(bp["mlp"], m_in, cfg.mlp_activation)
            return h + mo, ncache

        h, new_caches = lax.scan(body, h, (params["blocks"], state.caches))

    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = L.unembed(params["embed"], h)
    new_state = DecodeState(caches=new_caches,
                            index=idx + tokens.shape[1])
    return logits, new_state
