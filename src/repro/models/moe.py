"""Mixture-of-Experts layer (Granite-MoE and DeepSeek-V2 styles).

Dense-dispatch formulation: every expert runs on every token and the router's
top-k weights gate the contributions.  This is the einsum form that shards
cleanly under GSPMD (expert dim on the `model`/expert axis; tokens on `data`)
and is mathematically identical to sparse dispatch.  A capacity-based sparse
dispatch (one-hot combine matrices, à la Switch) is also provided for the
train-step variants where FLOP savings matter; both are tested for agreement.

DeepSeek-V2 details supported: shared experts (always on), top-k over routed
experts, and the auxiliary load-balancing loss.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.layers import dense_init

Params = Dict[str, Any]


def moe_init(key, cfg: ArchConfig) -> Params:
    m = cfg.moe
    d, dff = cfg.d_model, m.expert_d_ff
    dt = jnp.dtype(cfg.dtype)
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    E = m.num_experts
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(dff)
    p = {
        "router": dense_init(kr, d, E, jnp.float32),   # router in f32
        "w_gate": (jax.random.normal(kg, (E, d, dff)) * scale_in).astype(dt),
        "w_up": (jax.random.normal(ku, (E, d, dff)) * scale_in).astype(dt),
        "w_down": (jax.random.normal(kd, (E, dff, d)) * scale_out).astype(dt),
    }
    if m.num_shared_experts:
        sdff = dff * m.num_shared_experts
        k1, k2, k3 = jax.random.split(ks, 3)
        p["shared"] = {
            "w_gate": dense_init(k1, d, sdff, dt),
            "w_up": dense_init(k2, d, sdff, dt),
            "w_down": dense_init(k3, sdff, d, dt),
        }
    return p


def _router_probs(params: Params, m: MoEConfig, x: jnp.ndarray):
    """Returns (topk_weights (..., E) dense-masked, aux_loss)."""
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    k = m.top_k
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)   # renormalize top-k
    E = probs.shape[-1]
    gates = jnp.sum(jax.nn.one_hot(topi, E, dtype=probs.dtype)
                    * topv[..., None], axis=-2)           # (..., E)
    # Switch-style load balancing: E * Σ_e f_e · p̄_e
    flat_g = gates.reshape(-1, E)
    flat_p = probs.reshape(-1, E)
    frac_routed = jnp.mean((flat_g > 0).astype(jnp.float32), axis=0)
    mean_prob = jnp.mean(flat_p, axis=0)
    aux = E * jnp.sum(frac_routed * mean_prob)
    return gates, aux


def moe_apply_dense(params: Params, cfg: ArchConfig,
                    x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dense-dispatch MoE: out = Σ_e gate_e · FFN_e(x) (+ shared experts)."""
    m = cfg.moe
    gates, aux = _router_probs(params, m, x)              # (B, S, E)
    h_gate = jnp.einsum("bsd,edf->bsef", x, params["w_gate"])
    h_up = jnp.einsum("bsd,edf->bsef", x, params["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    # gate BEFORE the down-projection and contract (e, f) jointly: the
    # partial-sum collective is then (b,s,d), not (b,s,E,d) — E× less
    # traffic when the expert FFN dim is tensor-sharded (§Perf iteration G1)
    h = h * gates.astype(x.dtype)[..., None]
    out = jnp.einsum("bsef,efd->bsd", h, params["w_down"])
    if m.num_shared_experts:
        sp = params["shared"]
        out = out + (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) \
            @ sp["w_down"]
    return out, aux


def moe_apply_sparse_gather(params: Params, cfg: ArchConfig,
                            x: jnp.ndarray, capacity_factor: float = 2.0
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-bounded sparse dispatch via gather/scatter (no one-hot
    matmuls — the dispatch einsum of the one-hot form costs more FLOPs than
    the expert compute it saves once E is large; §Perf D1).

    Per expert: token ids = stable argsort of the keep mask (first ``cap``
    rows), gather (E, cap, d), run the expert FFN batched over E, scatter-
    add gated outputs back.  Compute scales with E·cap ≈ cf·k·N instead of
    E·N.
    """
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k
    N = B * S
    xf = x.reshape(N, d)
    gates, aux = _router_probs(params, m, x)
    gflat = gates.reshape(N, E)

    cap = max(1, int(capacity_factor * N * k / E))
    active = gflat > 0
    pos = jnp.cumsum(active.astype(jnp.int32), axis=0) - 1
    keep = active & (pos < cap)
    # stable argsort: kept tokens first, in token order, per expert column
    order = jnp.argsort(~keep, axis=0, stable=True)        # (N, E)
    ids = order[:cap].T                                    # (E, cap)
    valid = jnp.take_along_axis(keep, order[:cap], axis=0).T  # (E, cap)

    xe = xf[ids]                                           # (E, cap, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"])   # (E, cap, d)

    g_slot = jnp.take_along_axis(
        gflat.T, ids, axis=1) * valid.astype(gflat.dtype)  # (E, cap)
    contrib = (ye * g_slot[..., None].astype(ye.dtype)).reshape(-1, d)
    out = jnp.zeros((N, d), x.dtype).at[ids.reshape(-1)].add(
        contrib.astype(x.dtype), mode="drop")
    out = out.reshape(B, S, d)
    if m.num_shared_experts:
        sp = params["shared"]
        out = out + (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) \
            @ sp["w_down"]
    return out, aux


def moe_apply_sparse(params: Params, cfg: ArchConfig, x: jnp.ndarray,
                     capacity_factor: float = 2.0
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-bounded sparse dispatch (einsum one-hot combine).

    Tokens beyond an expert's capacity are dropped (residual passes through),
    matching production MoE training.  FLOPs scale with capacity, not E.
    """
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k
    N = B * S
    xf = x.reshape(N, d)
    gates, aux = _router_probs(params, m, x)
    gflat = gates.reshape(N, E)

    cap = max(1, int(capacity_factor * N * k / E))
    # position of each token in each expert's queue
    active = (gflat > 0).astype(jnp.int32)
    pos = jnp.cumsum(active, axis=0) - 1                   # (N, E)
    keep = (pos < cap) & (active > 0)
    # dispatch tensor: (N, E, cap) one-hot
    disp = keep[..., None] & (jax.nn.one_hot(pos, cap, dtype=jnp.bool_))
    disp_f = disp.astype(x.dtype)
    xe = jnp.einsum("nec,nd->ecd", disp_f, xf)             # (E, cap, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"])   # (E, cap, d)
    combine = disp_f * gflat[..., None].astype(x.dtype)
    out = jnp.einsum("nec,ecd->nd", combine, ye).reshape(B, S, d)
    if m.num_shared_experts:
        sp = params["shared"]
        out = out + (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) \
            @ sp["w_down"]
    return out, aux
