"""Shared neural layers for the model zoo.

Functional style: each layer is ``init(key, cfg, ...) -> params`` plus
``apply(params, x, ...) -> y``.  Everything is pure JAX (pjit/GSPMD sharding
is applied from outside via PartitionSpec trees; see repro.distributed).

Attention comes in three flavours:
  * GQA multi-head attention with RoPE (optionally M-RoPE) and QKV bias
  * MLA (DeepSeek-V2 multi-head latent attention, kv_lora compression)
  * decode-mode variants operating against a KV cache

The attention inner product can be routed through the Pallas flash-attention
kernel (``repro.kernels``) or the pure-jnp reference; selectable per call so
dry-runs/smoke tests stay kernel-free on CPU.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig

Params = Dict[str, Any]


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    # compute in f32 for stability, cast back
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, positions: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (..., seq) int32 -> cos/sin of shape (..., seq, head_dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                      dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray,
               sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim//2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.astype(x.dtype)


def mrope_positions(batch: int, seq: int,
                    sections=(16, 24, 24)) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE position ids, text-only fallback: all three
    channels (temporal, h, w) share the 1-D position.  Returns (3, B, S)."""
    pos = jnp.broadcast_to(jnp.arange(seq)[None, :], (batch, seq))
    return jnp.stack([pos, pos, pos], axis=0)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections=None) -> jnp.ndarray:
    """M-RoPE: the head_dim/2 frequency slots are split into 3 sections fed
    by (t, h, w) position channels.  positions: (3, B, S).

    Default sections follow Qwen2-VL's (16, 24, 24) 1:1.5:1.5 split, scaled
    to the actual head_dim (exact (16,24,24) at head_dim=128)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    if sections is None:
        t = half // 4
        rem = half - t
        sections = (t, rem - rem // 2, rem // 2)
    assert sum(sections) == half, (sections, half)
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                      dtype=jnp.float32) / head_dim))
    # section id of each frequency slot
    sec = jnp.repeat(jnp.arange(3), jnp.asarray(sections), total_repeat_length=half)
    pos_per_slot = positions.astype(jnp.float32)[sec]        # (half, B, S)
    ang = jnp.moveaxis(pos_per_slot, 0, -1) * inv             # (B, S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    return apply_rope(x, cos, sin)


# ---------------------------------------------------------------------------
# Feed-forward blocks
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ArchConfig, d_ff: Optional[int] = None) -> Params:
    d, dt = cfg.d_model, _dtype(cfg)
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_activation == "silu":      # gated (SwiGLU): 3 matrices
        return {"w_gate": dense_init(k1, d, d_ff, dt),
                "w_up": dense_init(k2, d, d_ff, dt),
                "w_down": dense_init(k3, d_ff, d, dt)}
    return {"w_up": dense_init(k1, d, d_ff, dt),
            "w_down": dense_init(k2, d_ff, d, dt)}


def mlp_apply(params: Params, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation == "silu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif activation == "gelu":
        h = jax.nn.gelu(x @ params["w_up"])
    elif activation == "relu2":          # squared ReLU (Nemotron-4)
        h = jnp.square(jax.nn.relu(x @ params["w_up"]))
    else:
        raise ValueError(f"unknown activation {activation}")
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ArchConfig) -> Params:
    d, dt = cfg.d_model, _dtype(cfg)
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {"w_q": dense_init(kq, d, cfg.num_heads * hd, dt),
         "w_k": dense_init(kk, d, cfg.num_kv_heads * hd, dt),
         "w_v": dense_init(kv, d, cfg.num_kv_heads * hd, dt),
         "w_o": dense_init(ko, cfg.num_heads * hd, d, dt)}
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((cfg.num_heads * hd,), dt)
        p["b_k"] = jnp.zeros((cfg.num_kv_heads * hd,), dt)
        p["b_v"] = jnp.zeros((cfg.num_kv_heads * hd,), dt)
    return p


def _sdpa(q, k, v, causal: bool, q_offset: int = 0):
    """Reference scaled-dot-product attention.
    q: (B, Sq, H, D); k/v: (B, Sk, Hkv, D) with H % Hkv == 0."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    qf = q.astype(jnp.float32) / math.sqrt(D)
    # expand kv heads over the group without materializing repeats: reshape q
    qg = qf.reshape(B, Sq, Hkv, group, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(k.shape[1])
        mask = kpos[None, :] <= qpos[:, None]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def attention_apply(params: Params, cfg: ArchConfig, x: jnp.ndarray,
                    positions: Optional[jnp.ndarray] = None,
                    kv_cache: Optional[Tuple] = None,
                    cache_index: Optional[jnp.ndarray] = None,
                    use_kernel: bool = False):
    """GQA attention.  Returns (out, new_kv_cache).

    Training/prefill: kv_cache=None, full self-attention over x.
    Decode: x is (B, 1, D); kv_cache=(k, v) with static max length; the new
    k/v are scattered at ``cache_index``.
    """
    B, S, _ = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ params["w_q"]
    k = x @ params["w_k"]
    v = x @ params["w_v"]
    if cfg.qkv_bias:
        q, k, v = q + params["b_q"], k + params["b_k"], v + params["b_v"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)

    if positions is None:
        if cache_index is not None:
            positions = jnp.broadcast_to(cache_index, (B,))[:, None] + \
                jnp.arange(S)[None, :]
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    if cfg.mrope:
        if positions.ndim == 2:       # text-only: replicate channels
            positions = jnp.stack([positions] * 3, axis=0)
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    else:
        cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    if kv_cache is not None:
        ck, cv = kv_cache
        ck = _scatter_cache(ck, k, cache_index)
        cv = _scatter_cache(cv, v, cache_index)
        # decode attention over the full (padded) cache with length masking
        out = _decode_sdpa(q, ck, cv, cache_index + S)
        new_cache = (ck, cv)
    else:
        if use_kernel:
            from repro.kernels.flash_attention import ops as fa_ops
            out = fa_ops.flash_attention(q, k, v, causal=cfg.causal)
        else:
            out = _sdpa(q, k, v, causal=cfg.causal)
        new_cache = None

    out = out.reshape(B, S, H * hd) @ params["w_o"]
    return out, new_cache


def _scatter_cache(cache: jnp.ndarray, new: jnp.ndarray,
                   index: jnp.ndarray) -> jnp.ndarray:
    """cache: (B, Smax, Hkv, D); new: (B, s, Hkv, D) written at ``index``."""
    idx = jnp.asarray(index, jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    return lax.dynamic_update_slice(
        cache, new.astype(cache.dtype), (zero, idx, zero, zero))


def _decode_sdpa(q, k_cache, v_cache, valid_len):
    """Decode attention: q (B,1,H,D) against padded cache with length mask."""
    B, Sq, H, D = q.shape
    Smax = k_cache.shape[1]
    Hkv = k_cache.shape[2]
    group = H // Hkv
    qf = q.astype(jnp.float32) / math.sqrt(D)
    qg = qf.reshape(B, Sq, Hkv, group, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                        k_cache.astype(jnp.float32))
    mask = jnp.arange(Smax)[None, :] < valid_len
    logits = jnp.where(mask[:, None, None, None, :]
                       if mask.ndim == 2 else mask[None, None, None, None, :],
                       logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs,
                     v_cache.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def make_kv_cache(cfg: ArchConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, cfg.num_kv_heads, hd)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


# ---------------------------------------------------------------------------
# MLA — DeepSeek-V2 multi-head latent attention
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ArchConfig) -> Params:
    d, dt = cfg.d_model, _dtype(cfg)
    H = cfg.num_heads
    r_kv = cfg.kv_lora_rank
    r_q = cfg.q_lora_rank or 0
    dr, dn, dv = cfg.qk_rope_head_dim, cfg.qk_nope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = {}
    if r_q:
        p["w_dq"] = dense_init(ks[0], d, r_q, dt)
        p["q_norm"] = rmsnorm_init(r_q, dt)
        p["w_uq"] = dense_init(ks[1], r_q, H * (dr + dn), dt)
    else:
        p["w_q"] = dense_init(ks[1], d, H * (dr + dn), dt)
    p["w_dkv"] = dense_init(ks[2], d, r_kv + dr, dt)   # compress + shared rope k
    p["kv_norm"] = rmsnorm_init(r_kv, dt)
    p["w_uk"] = dense_init(ks[3], r_kv, H * dn, dt)
    p["w_uv"] = dense_init(ks[4], r_kv, H * dv, dt)
    p["w_o"] = dense_init(ks[5], H * dv, d, dt)
    return p


def mla_apply(params: Params, cfg: ArchConfig, x: jnp.ndarray,
              positions: Optional[jnp.ndarray] = None,
              kv_cache: Optional[Tuple] = None,
              cache_index: Optional[jnp.ndarray] = None):
    """MLA attention; the KV cache stores the *compressed* latent (r_kv) and
    the shared rope key (dr) — the memory win that defines the method.
    Cache layout: (latent (B,S,r_kv), k_rope (B,S,dr))."""
    B, S, _ = x.shape
    H = cfg.num_heads
    dr, dn, dv = cfg.qk_rope_head_dim, cfg.qk_nope_head_dim, cfg.v_head_dim
    r_kv = cfg.kv_lora_rank

    if positions is None:
        base = cache_index if cache_index is not None else 0
        positions = (jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
                     + (base if cache_index is None else
                        jnp.broadcast_to(cache_index, (B,))[:, None]))

    if "w_dq" in params:
        q_lat = rmsnorm(params["q_norm"], x @ params["w_dq"],
                        cfg.norm_eps)
        q = q_lat @ params["w_uq"]
    else:
        q = x @ params["w_q"]
    q = q.reshape(B, S, H, dr + dn)
    q_rope, q_nope = q[..., :dr], q[..., dr:]
    cos, sin = rope_freqs(dr, cfg.rope_theta, positions)
    q_rope = apply_rope(q_rope, cos, sin)

    dkv = x @ params["w_dkv"]
    latent = rmsnorm(params["kv_norm"], dkv[..., :r_kv], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., r_kv:][:, :, None, :], cos, sin)[:, :, 0]

    if kv_cache is not None:
        c_lat, c_kr = kv_cache
        idx = jnp.asarray(cache_index, jnp.int32)
        zero = jnp.zeros((), jnp.int32)
        c_lat = lax.dynamic_update_slice(
            c_lat, latent.astype(c_lat.dtype), (zero, idx, zero))
        c_kr = lax.dynamic_update_slice(
            c_kr, k_rope.astype(c_kr.dtype), (zero, idx, zero))
        latent_full, k_rope_full = c_lat, c_kr
        valid = cache_index + S
        new_cache = (c_lat, c_kr)
    else:
        latent_full, k_rope_full = latent, k_rope
        valid = None
        new_cache = None

    k_nope = (latent_full @ params["w_uk"]).reshape(
        B, latent_full.shape[1], H, dn)
    v = (latent_full @ params["w_uv"]).reshape(
        B, latent_full.shape[1], H, dv)

    scale = 1.0 / math.sqrt(dr + dn)
    logits = (jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32),
                         k_nope.astype(jnp.float32))
              + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                           k_rope_full.astype(jnp.float32))) * scale
    Sk = latent_full.shape[1]
    if valid is None:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(S)[:, None]
        logits = jnp.where(mask[None, None], logits, -1e30)
    else:
        mask = jnp.arange(Sk)[None, :] < valid
        logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    out = out.reshape(B, S, H * dv).astype(x.dtype) @ params["w_o"]
    return out, new_cache


def make_mla_cache(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    return (jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype))


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def embedding_init(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    p = {"tok": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model))
                 * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(jax.random.fold_in(key, 1), cfg.d_model,
                                  cfg.vocab_size, dt)
    return p


def embed(params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["tok"], tokens, axis=0)


def unembed(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    if "unembed" in params:
        return x @ params["unembed"]
    return x @ params["tok"].T.astype(x.dtype)
