"""Optimizers with shard-friendly state (ZeRO: states inherit param specs).

Self-contained (no optax dependency): AdamW, Lion, SGD-momentum, plus
gradient clipping and schedule support.  State is a pytree of the same
structure as params so the params' PartitionSpecs apply verbatim — that is
what makes optimizer sharding free under GSPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any            # first moment (or momentum)
    nu: Any            # second moment (None for lion/sgd)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable   # (grads, state, params) -> (updates, new_state)


def _tree_zeros(params, dtype=None):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, dtype or p.dtype), params)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), \
        norm


def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          state_dtype=jnp.float32) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=_tree_zeros(params, state_dtype),
                        nu=_tree_zeros(params, state_dtype))

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            gf = g.astype(state_dtype)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * jnp.square(gf)
            mhat = m / (1 - b1 ** step.astype(state_dtype))
            vhat = v / (1 - b2 ** step.astype(state_dtype))
            u = mhat / (jnp.sqrt(vhat) + eps)
            u = u + weight_decay * p.astype(state_dtype)
            return (-lr_t * u).astype(p.dtype), m, v

        flat_out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu,
                                          params)
        updates = jax.tree_util.tree_map(lambda t: t[0], flat_out,
                                         is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree_util.tree_map(lambda t: t[1], flat_out,
                                    is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree_util.tree_map(lambda t: t[2], flat_out,
                                    is_leaf=lambda x: isinstance(x, tuple))
        return updates, OptState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def lion(lr: Callable | float, b1: float = 0.9, b2: float = 0.99,
         weight_decay: float = 0.1, state_dtype=jnp.float32) -> Optimizer:
    """Lion: sign-momentum — halves optimizer memory vs Adam (one moment)."""
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=_tree_zeros(params, state_dtype), nu=None)

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m, p):
            gf = g.astype(state_dtype)
            u = jnp.sign(b1 * m + (1 - b1) * gf) \
                + weight_decay * p.astype(state_dtype)
            m_new = b2 * m + (1 - b2) * gf
            return (-lr_t * u).astype(p.dtype), m_new

        out = jax.tree_util.tree_map(upd, grads, state.mu, params)
        updates = jax.tree_util.tree_map(lambda t: t[0], out,
                                         is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
        return updates, OptState(step=step, mu=mu, nu=None)

    return Optimizer(init=init, update=update)


def sgd(lr: Callable | float, momentum: float = 0.9,
        nesterov: bool = False) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=_tree_zeros(params, jnp.float32), nu=None)

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m, p):
            gf = g.astype(jnp.float32)
            m_new = momentum * m + gf
            u = gf + momentum * m_new if nesterov else m_new
            return (-lr_t * u).astype(p.dtype), m_new

        out = jax.tree_util.tree_map(upd, grads, state.mu, params)
        updates = jax.tree_util.tree_map(lambda t: t[0], out,
                                         is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
        return updates, OptState(step=step, mu=mu, nu=None)

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype),
                                  params, updates)


OPTIMIZERS = {"adamw": adamw, "lion": lion, "sgd": sgd}
