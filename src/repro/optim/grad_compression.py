"""Error-feedback gradient compression for the DP all-reduce.

At 1000+ node scale the data-parallel gradient all-reduce dominates the
inter-pod link budget.  We provide int8 uniform quantization with per-chunk
scales and **error feedback** (the residual is carried to the next step),
which preserves convergence (Karimireddy et al., 2019) while cutting
all-reduce bytes 4x vs f32 / 2x vs bf16.

Usage inside a train step (the compressed tensor is what crosses the
``pod``/``data`` axis):

    cgrads, new_err = compress_tree(grads, err_state)
    cgrads = jax.lax.psum(cgrads, axis_name)        # int8 payload semantics
    grads  = decompress_tree(cgrads)

In the pjit (non-shard_map) path, we model the same arithmetic by
quantize→dequantize around the mean; XLA still moves the quantized payload
when the collective is materialized by GSPMD on the reduced tensor.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Compressed(NamedTuple):
    q: jnp.ndarray        # int8 payload
    scale: jnp.ndarray    # per-chunk scale (f32)


CHUNK = 2048


def _quantize(x: jnp.ndarray, chunk: int = CHUNK) -> Compressed:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % chunk
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, chunk)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / safe), -127, 127).astype(jnp.int8)
    return Compressed(q=q, scale=scale)


def _dequantize(c: Compressed, shape, dtype) -> jnp.ndarray:
    flat = (c.q.astype(jnp.float32) * c.scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def init_error_state(grads: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_tree(grads: Any, err: Any) -> Tuple[Any, Any]:
    """Quantize grads+error; returns (compressed tree, new error state)."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        c = _quantize(target)
        recon = _dequantize(c, g.shape, jnp.float32)
        return c, target - recon

    pairs = jax.tree_util.tree_map(one, grads, err)
    comp = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                  is_leaf=lambda x: isinstance(x, tuple)
                                  and len(x) == 2
                                  and isinstance(x[0], Compressed))
    new_err = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple)
                                     and len(x) == 2
                                     and isinstance(x[0], Compressed))
    return comp, new_err


def decompress_tree(comp: Any, like: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda c, g: _dequantize(c, g.shape, g.dtype), comp, like,
        is_leaf=lambda x: isinstance(x, Compressed))


def roundtrip(grads: Any, err: Any) -> Tuple[Any, Any]:
    """Quantize-dequantize with error feedback (the pjit-path transform)."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        c = _quantize(target)
        recon = _dequantize(c, g.shape, jnp.float32)
        return recon.astype(g.dtype), target - recon

    pairs = jax.tree_util.tree_map(one, grads, err)
    out = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
    return out, new_err
