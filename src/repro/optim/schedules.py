"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup_cosine(lr: float, warmup: int, total: int,
                         final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = lr * (final_frac + (1 - final_frac)
                    * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return fn


def inverse_sqrt(lr: float, warmup: int):
    def fn(step):
        step = jnp.maximum(step.astype(jnp.float32), 1.0)
        return lr * jnp.minimum(step / max(warmup, 1),
                                jnp.sqrt(warmup / step))
    return fn
