from repro.optim.optimizer import (adamw, lion, sgd, apply_updates,
                                   clip_by_global_norm, global_norm,
                                   OptState, Optimizer, OPTIMIZERS)
from repro.optim import schedules, grad_compression
