"""Pallas TPU kernels for the framework's compute hot-spots.

flash_attention/ — blockwise online-softmax attention (train/prefill)
rwkv_wkv/        — RWKV-6 WKV chunked recurrence (the SSM hot loop)
simplex_proj/    — batched simplex projection (the paper's hot operator in
                   the multiclass-SVM experiment), sort-free bisection form
batched_cg/      — fused batched conjugate gradient over dense small SPD
                   systems (d ≤ 512), the implicit-diff backward hot path;
                   per-instance convergence masks, implicit-diff custom VJP

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with the public API) and ref.py (pure-jnp oracle); tests sweep
shapes/dtypes in interpret=True mode against the oracle.
"""
