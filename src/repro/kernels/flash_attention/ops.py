"""Public flash-attention op: (B, S, H, D) API with GQA group folding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, S, H, D); k/v: (B, S, Hkv, D) with H % Hkv == 0.
    Returns (B, S, H, D)."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    if group > 1:   # GQA: repeat kv heads (kernel sees equal head counts)
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
