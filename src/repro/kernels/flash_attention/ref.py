"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True) -> jnp.ndarray:
    """q,k,v: (B, S, H, D) same head count (GQA folded outside).
    Returns (B, S, H, D).  f32 softmax, output in q.dtype."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None] \
            + (Sk - Sq)
        logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
