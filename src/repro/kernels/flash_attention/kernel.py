"""Flash attention Pallas TPU kernel.

Blockwise online-softmax (Flash-2 schedule) adapted to the TPU memory
hierarchy:
  * grid = (batch·heads, num_q_blocks, num_kv_blocks); TPU executes the last
    grid dim sequentially, so the (m, l, acc) running statistics live in VMEM
    scratch and persist across kv steps — the HBM→VMEM streaming pattern.
  * block shapes are MXU-aligned: q/kv blocks are multiples of 128 on the
    sequence dim and the full head dim D (≤ 256) on the lane dim.
  * causal masking skips fully-masked kv blocks via ``pl.when`` (no wasted
    MXU work past the diagonal), and applies an iota-based mask on the
    diagonal block.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, block_q: int, block_k: int,
               seq_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # causal: skip blocks entirely above the diagonal
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, D)
        k = k_ref[0].astype(jnp.float32)                # (bk, D)
        v = v_ref[0].astype(jnp.float32)                # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _final():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False) -> jnp.ndarray:
    """q,k,v: (BH, S, D) — batch·heads folded.  Returns (BH, Sq, D)."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    grid = (BH, Sq // block_q, Sk // block_k)
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, seq_k=Sk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
