"""Public WKV op: (B, T, H, N) API matching the model's reference scan."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv_wkv.kernel import wkv_bh


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(r, k, v, w, u, state0=None, chunk: int = 64,
        interpret: bool = False):
    """r,k,v,w: (B, T, H, N); u: (H, N); state0: (B, H, N, N) f32 or None.
    Returns (out (B,T,H,N), final state (B,H,N,N)) — same contract as
    repro.models.rwkv.wkv_scan_ref."""
    B, T, H, N = r.shape
    if state0 is None:
        state0 = jnp.zeros((B, H, N, N), jnp.float32)

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, T, N)

    u_b = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, N)
    s0 = state0.reshape(B * H, N, N)
    out, sT = wkv_bh(fold(r), fold(k), fold(v), fold(w), u_b, s0,
                     chunk=chunk, interpret=interpret)
    out = out.reshape(B, H, T, N).transpose(0, 2, 1, 3)
    return out, sT.reshape(B, H, N, N)
