"""Pure-jnp oracle for the RWKV-6 WKV recurrence (re-export of the model's
reference scan so kernel tests and the model share one source of truth)."""
from repro.models.rwkv import wkv_scan_ref  # noqa: F401
