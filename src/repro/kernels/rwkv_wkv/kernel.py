"""RWKV-6 WKV recurrence as a Pallas TPU kernel.

TPU adaptation of the CUDA wkv6 kernel (which uses one thread block per
(batch, head) with shared-memory tiles): here one GRID STEP per (batch·head,
time-chunk), executed sequentially along the time axis, with the (N×N) state
matrix resident in VMEM scratch across chunks — the TPU analogue of keeping
state in registers/smem.  Within a chunk the recurrence is a fori_loop of
rank-1 updates; N = 64 matches the VPU lane width so the row operations are
fully vectorized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                state_scr, *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = s0_ref[0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)            # (N,)

    def step(t, state):
        rt = r_ref[0, t].astype(jnp.float32)    # (N,)
        kt = k_ref[0, t].astype(jnp.float32)
        vt = v_ref[0, t].astype(jnp.float32)
        wt = w_ref[0, t].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]          # (N, N) rank-1
        out = jnp.sum((state + u[:, None] * kv) * rt[:, None], axis=0)
        o_ref[0, t] = out.astype(o_ref.dtype)
        return wt[:, None] * state + kv

    state = jax.lax.fori_loop(0, chunk, step, state_scr[...])
    state_scr[...] = state

    @pl.when(ic == pl.num_programs(1) - 1)
    def _final():
        sT_ref[0] = state_scr[...]


def wkv_bh(r, k, v, w, u, s0, *, chunk: int = 64, interpret: bool = False):
    """r,k,v,w: (BH, T, N); u: (BH, N); s0: (BH, N, N) f32.
    Returns (out (BH, T, N), final_state (BH, N, N))."""
    BH, T, N = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    grid = (BH, T // chunk)

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    out, sT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, N), lambda b, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ic: (b, ic, 0)),
            pl.BlockSpec((1, N), lambda b, ic: (b, 0)),
            pl.BlockSpec((1, N, N), lambda b, ic: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, N), lambda b, ic: (b, ic, 0)),
            pl.BlockSpec((1, N, N), lambda b, ic: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, N), r.dtype),
            jax.ShapeDtypeStruct((BH, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return out, sT
