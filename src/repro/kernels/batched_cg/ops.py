"""Public batched-CG op with implicit-differentiation custom VJP.

Forward: one fused Pallas kernel solves the whole (B, d, d) batch of SPD
systems (``ref.py`` fallback off-TPU / in tests).  Backward: instead of
differentiating through the CG iterations, we apply the paper's move at the
kernel boundary — x = A⁻¹b is implicitly defined by Ax − b = 0, so

    u  = A⁻ᵀ g          (one more batched solve, same kernel)
    ∂b = u,   ∂A = −u xᵀ

which makes the op exactly as differentiable as a dense solve at the cost of
one extra batched CG.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.core.operators import LinearOperator, ravel_view
from repro.kernels.batched_cg.kernel import batched_cg_pallas
from repro.kernels.batched_cg.ref import batched_cg_ref


def _pick_block_b(B: int, block_b: int) -> int:
    bb = min(block_b, B)
    while B % bb:
        bb -= 1
    return max(bb, 1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _solve(A, b, tol, maxiter, block_b, interpret, pad_lanes):
    if interpret is None:      # no TPU: identical masked-CG reference path
        return batched_cg_ref(A, b, tol=tol, maxiter=maxiter)
    return batched_cg_pallas(A, b, tol=tol, maxiter=maxiter,
                             block_b=_pick_block_b(A.shape[0], block_b),
                             interpret=interpret, pad_lanes=pad_lanes)


def _fwd(A, b, tol, maxiter, block_b, interpret, pad_lanes):
    x = _solve(A, b, tol, maxiter, block_b, interpret, pad_lanes)
    return x, (A, x)


def _bwd(tol, maxiter, block_b, interpret, pad_lanes, res, g):
    A, x = res
    u = _solve(A.transpose(0, 2, 1), g, tol, maxiter, block_b, interpret,
               pad_lanes)
    dA = -u[:, :, None] * x[:, None, :]
    return dA, u


_solve.defvjp(_fwd, _bwd)


def batched_cg(A, b, *, tol: float = 1e-6, maxiter: Optional[int] = None,
               block_b=8, interpret: Optional[bool] = None,
               pad_lanes: bool = False):
    """Solve the batch of SPD systems A[i] x[i] = b[i] in one fused kernel.

    Args:
      A: (B, d, d) symmetric positive-definite operators, d ≤ 512 — or a
        batch-aware SPD ``LinearOperator``, which auto-materializes
        (O(1) for dense/structured operators, d probing matvecs otherwise)
        with ``b`` the matching pytree of right-hand sides.
      b: (B, d) right-hand sides ((batched) pytree for operator input).
      tol: relative residual tolerance per instance.
      maxiter: CG iteration cap (default: d, the exact-arithmetic bound).
      block_b: instances per Pallas program (VMEM tile height), or
        ``"auto"`` to resolve a tuned tile for this ``(backend, B, d,
        dtype)`` from the autotuning cache (host-side, at trace time;
        falls back to the legacy default-8 schedule when the regime was
        never swept — see ``analysis.autotune.choose_block_b``).
      interpret: True forces Pallas interpret mode; None auto-selects the
        pure-JAX reference path off-TPU and the compiled kernel on TPU.
      pad_lanes: embed d into the next multiple of the 128-lane VMEM tile
        width (identity pad, exact — see ``kernel.pad_to_lanes``) before
        the Pallas call; ignored on the reference path, which has no
        tiling constraint.

    Differentiable in A and b via the implicit-diff custom VJP (operator
    input: in b, through the materialized matrix).
    """
    if isinstance(A, LinearOperator):
        if A.symmetric is False:
            raise ValueError(f"batched_cg requires an SPD operator; {A!r} "
                             "declares symmetric=False")
        view = ravel_view(A, b, A.batch_ndim)
        dense = A.materialize()
        if A.batch_ndim == 0:
            dense = dense[None]
        x = batched_cg(dense, view.b, tol=tol, maxiter=maxiter,
                       block_b=block_b, interpret=interpret,
                       pad_lanes=pad_lanes)
        return view.to_tree(x)
    B, d, _ = A.shape
    if maxiter is None:
        maxiter = d
    if block_b == "auto":
        # resolved HOST-SIDE before the custom-VJP call (block_b is a
        # nondiff static arg): shapes are concrete even under jit tracing
        from repro.analysis import autotune
        block_b = autotune.choose_block_b(B, d, dtype=str(A.dtype),
                                          pad_lanes=pad_lanes)
    if interpret is None and jax.default_backend() != "tpu":
        interpret = None   # sentinel: ref path (see _solve)
    elif interpret is None:
        interpret = False
    return _solve(A, b, float(tol), int(maxiter), int(block_b), interpret,
                  bool(pad_lanes))
