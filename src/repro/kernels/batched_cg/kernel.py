"""Fused batched conjugate-gradient as a Pallas TPU kernel.

The implicit-differentiation hot path (paper §2.1) solves many small,
independent, dense SPD systems — one per example in a bilevel batch, one per
dataset in a hyperparameter sweep, one per molecule in a sensitivity scan.
Launching an XLA while_loop per system wastes the chip on dispatch and HBM
round-trips; here the whole block of systems lives in VMEM and every CG
iteration is one fused step:

  * the batched matvec ``A p`` is a single (block_b, d, d) × (block_b, d)
    contraction on the MXU,
  * the reductions (α, β, residual norms) are VPU row-reductions,
  * per-instance ``active`` masks freeze converged systems while stragglers
    iterate, and the while_loop exits as soon as the whole block converged.

Dense small-system regime: d ≤ 512 (a (8, 512, 512) f32 block of operators is
8 MB — comfortably VMEM-resident next to the CG vectors).  For larger or
matrix-free systems use the masked solvers in ``repro.core.linear_solve``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _batched_cg_kernel(a_ref, b_ref, x_ref, *, tol: float, maxiter: int):
    # compute in the input precision, floored at f32 (so f64 solves under
    # jax_enable_x64 keep f64 accuracy instead of silently degrading)
    dtype = jnp.promote_types(jnp.result_type(a_ref.dtype, b_ref.dtype),
                              jnp.float32)
    A = a_ref[...].astype(dtype)                        # (bb, d, d)
    b = b_ref[...].astype(dtype)                        # (bb, d)

    def matvec(p):                                      # (bb, d) -> (bb, d)
        return lax.dot_general(
            A, p,
            dimension_numbers=(((2,), (1,)), ((0,), (0,))),
            preferred_element_type=dtype)

    x0 = jnp.zeros_like(b)
    r0 = b                                              # r = b - A·0
    p0 = r0
    rs0 = jnp.sum(r0 * r0, axis=-1)                     # (bb,)
    b2 = jnp.sum(b * b, axis=-1)
    atol2 = jnp.maximum(tol * tol * b2, 1e-30)

    def cond(state):
        _, _, _, rs, k = state
        return jnp.logical_and(k < maxiter, jnp.any(rs > atol2))

    def body(state):
        x, r, p, rs, k = state
        active = rs > atol2                             # (bb,)
        ap = matvec(p)
        denom = jnp.sum(p * ap, axis=-1)
        safe = jnp.where(denom == 0, 1.0, denom)
        alpha = jnp.where(denom == 0, 0.0, rs / safe)
        alpha = jnp.where(active, alpha, 0.0)[:, None]  # frozen rows: no-op
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.sum(r * r, axis=-1)
        beta = jnp.where(rs == 0, 0.0, rs_new / jnp.where(rs == 0, 1.0, rs))
        p = jnp.where(active[:, None], r + beta[:, None] * p, p)
        rs = jnp.where(active, rs_new, rs)
        return x, r, p, rs, k + 1

    x, _, _, _, _ = lax.while_loop(cond, body, (x0, r0, p0, rs0, 0))
    x_ref[...] = x.astype(x_ref.dtype)


LANES = 128     # TPU vector-lane width: the last dim of a VMEM tile


def pad_to_lanes(A, b, lanes: int = LANES):
    """Embed the (B, d, d) batch into the next lane multiple d' ≥ d.

    The pad block is the identity and the padded right-hand side is zero,
    so CG on the embedded system reproduces the original iterates exactly:
    the padded residual/search-direction components start at zero and
    ``A' e_pad = e_pad`` keeps them there (no coupling into the original
    coordinates), while per-instance step sizes and convergence masks are
    untouched.  This is the shape-legalization step of the tuned TPU block
    schedule — a (block_b, d', d') VMEM tile wants d' % 128 == 0 — shared
    with the interpret path so CPU tests cover the exact padded system the
    TPU kernel will run.  Returns ``(A_padded, b_padded, d_original)``.
    """
    B, d, d2 = A.shape
    assert d == d2, (d, d2)
    dp = -(-d // lanes) * lanes
    if dp == d:
        return A, b, d
    pad = dp - d
    A = jnp.pad(A, ((0, 0), (0, pad), (0, pad)))
    eye_pad = jnp.eye(pad, dtype=A.dtype)
    A = A.at[:, d:, d:].set(eye_pad)
    b = jnp.pad(b, ((0, 0), (0, pad)))
    return A, b, d


def batched_cg_pallas(A, b, *, tol: float = 1e-6, maxiter: int = 64,
                      block_b: int = 8, interpret: bool = False,
                      pad_lanes: bool = False):
    """A: (B, d, d) SPD batch; b: (B, d).  Returns x: (B, d) with A x ≈ b.

    ``pad_lanes=True`` embeds systems whose d is not a multiple of the
    128-lane VMEM tile width into the next lane multiple (identity pad —
    see ``pad_to_lanes``) and slices the solution back.
    """
    if pad_lanes:
        A, b, d0 = pad_to_lanes(A, b)
        x = batched_cg_pallas(A, b, tol=tol, maxiter=maxiter,
                              block_b=block_b, interpret=interpret)
        return x[:, :d0]
    B, d, d2 = A.shape
    assert d == d2, (d, d2)
    assert b.shape == (B, d), (A.shape, b.shape)
    block_b = min(block_b, B)
    assert B % block_b == 0, (B, block_b)
    kernel = functools.partial(_batched_cg_kernel, tol=tol, maxiter=maxiter)
    return pl.pallas_call(
        kernel,
        grid=(B // block_b,),
        in_specs=[pl.BlockSpec((block_b, d, d), lambda i: (i, 0, 0)),
                  pl.BlockSpec((block_b, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, d), b.dtype),
        cost_estimate=pl.CostEstimate(   # whole-call totals, worst case
            flops=2 * maxiter * B * d * d,
            bytes_accessed=4 * (B * d * d + 2 * B * d),
            transcendentals=0),
        interpret=interpret,
    )(A, b)
