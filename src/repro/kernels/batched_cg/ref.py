"""Pure-JAX reference for the fused batched-CG kernel.

Same algorithm as ``kernel.py`` — masked CG over a (B, d) batch inside one
``lax.while_loop`` — expressed with plain jnp ops.  Used as the correctness
oracle for kernel parity tests and as the CPU/GPU fallback path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


@jax.jit
def batched_cg_ref(A, b, tol: float = 1e-6, maxiter: int = 64):
    """A: (B, d, d) SPD batch; b: (B, d).  Returns x: (B, d)."""
    dtype = jnp.promote_types(jnp.result_type(A.dtype, b.dtype), jnp.float32)
    out_dtype = b.dtype
    A = A.astype(dtype)
    b = b.astype(dtype)
    x0 = jnp.zeros_like(b)
    r0 = b
    p0 = r0
    rs0 = jnp.sum(r0 * r0, axis=-1)
    atol2 = jnp.maximum(tol * tol * jnp.sum(b * b, axis=-1), 1e-30)

    def cond(state):
        _, _, _, rs, k = state
        return jnp.logical_and(k < maxiter, jnp.any(rs > atol2))

    def body(state):
        x, r, p, rs, k = state
        active = rs > atol2
        ap = jnp.einsum("bij,bj->bi", A, p)
        denom = jnp.sum(p * ap, axis=-1)
        safe = jnp.where(denom == 0, 1.0, denom)
        alpha = jnp.where(denom == 0, 0.0, rs / safe)
        alpha = jnp.where(active, alpha, 0.0)[:, None]
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.sum(r * r, axis=-1)
        beta = jnp.where(rs == 0, 0.0, rs_new / jnp.where(rs == 0, 1.0, rs))
        p = jnp.where(active[:, None], r + beta[:, None] * p, p)
        rs = jnp.where(active, rs_new, rs)
        return x, r, p, rs, k + 1

    x, _, _, _, _ = lax.while_loop(cond, body, (x0, r0, p0, rs0, 0))
    return x.astype(out_dtype)
