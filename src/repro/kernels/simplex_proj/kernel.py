"""Batched simplex projection as a Pallas TPU kernel.

The paper's multiclass-SVM experiment projects every row of an (m × k) dual
matrix onto the simplex each iteration — the hot operator of §4.1.  The
classic O(d log d) algorithm sorts each row, but sorting maps poorly onto the
TPU vector unit.  TPU adaptation: the threshold τ solves the 1-D monotone
equation

    φ(τ) = Σᵢ max(yᵢ − τ, 0) − scale = 0,

so we find it by **vectorized bisection** (~f32-mantissa-many iterations ⇒
exact to machine precision), entirely with VPU max/sum ops on a VMEM-resident
block of rows.  No sort, no gather — every iteration is a fused
compare/select/reduce over the (rows_block × d) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _simplex_kernel(y_ref, o_ref, *, scale: float, iters: int):
    y = y_ref[...].astype(jnp.float32)                  # (rows, d)
    d = y.shape[-1]
    hi = jnp.max(y, axis=-1)                            # τ ∈ [max−scale/d? , max]
    lo = hi - 1.0 * scale                               # φ(lo) ≥ 0 ≥ φ(hi)
    lo = jnp.minimum(lo, jnp.min(y, axis=-1) - scale / d)

    def body(i, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        phi = jnp.sum(jnp.maximum(y - mid[:, None], 0.0), axis=-1) - scale
        go_right = phi > 0                              # τ too small
        lo = jnp.where(go_right, mid, lo)
        hi = jnp.where(go_right, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    tau = 0.5 * (lo + hi)
    o_ref[...] = jnp.maximum(y - tau[:, None], 0.0).astype(o_ref.dtype)


def projection_simplex_rows(y, scale: float = 1.0, rows_block: int = 8,
                            iters: int = 50, interpret: bool = False):
    """y: (R, d) — project every row onto the scale-simplex."""
    R, d = y.shape
    rows_block = min(rows_block, R)
    assert R % rows_block == 0, (R, rows_block)
    kernel = functools.partial(_simplex_kernel, scale=scale, iters=iters)
    return pl.pallas_call(
        kernel,
        grid=(R // rows_block,),
        in_specs=[pl.BlockSpec((rows_block, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows_block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), y.dtype),
        interpret=interpret,
    )(y)
